"""Quickstart: quantize one linear layer with every method in the paper.

Runs in seconds on CPU.  Shows the paper's §3.4 metric (relative calibration
error) for RTN / AWQ / GPTQ / QuantEase / outlier-aware QuantEase / SpQR on
a realistic heavy-tailed weight matrix.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    awq_quantize,
    gptq_quantize,
    outlier_quantease,
    quantease_quantize,
    relative_error,
    rtn_quantize,
    spqr_quantize,
)
from repro.quant import GridSpec


def main():
    rng = np.random.default_rng(0)
    q, p, n = 256, 256, 1024
    x = rng.standard_normal((p, n)).astype(np.float32)
    w = rng.standard_normal((q, p)).astype(np.float32)
    w[rng.random((q, p)) < 0.003] *= 10.0  # outlier weights
    w[:, rng.choice(p, 2, replace=False)] *= 4.0  # hot input channels
    sigma = jnp.asarray(x @ x.T)
    w = jnp.asarray(w)
    s = int(0.01 * q * p)

    for bits in (4, 3):
        spec = GridSpec(bits=bits)
        rows = {
            "rtn": rtn_quantize(w, spec),
            "awq": awq_quantize(w, sigma, spec),
            "gptq": gptq_quantize(w, sigma, spec),
            "quantease (25 it)": quantease_quantize(w, sigma, spec, iterations=25)[0],
            "spqr 1%": spqr_quantize(w, sigma, spec, s=s)[0],
            "qe+outlier 1%": outlier_quantease(w, sigma, spec, s=s, iterations=15).w_eff,
        }
        print(f"\n== {bits}-bit, relative calibration error ‖WX−ŴX‖²/‖WX‖² ==")
        for name, w_hat in rows.items():
            print(f"  {name:18s} {float(relative_error(w, w_hat, sigma)):.5f}")


if __name__ == "__main__":
    main()
