"""Serve a quantized model with batched requests (paged continuous batching).

Trains a small LM, QuantEase-quantizes it to 4 bits, and runs a batch of
prompts through the **paged** serving engine (shared KV page pool, chunked
prefill, prefix cache) — verifying quantized greedy outputs stay close to
dense ones and that the paged engine matches the contiguous baseline
token-for-token on the dense model.

    PYTHONPATH=src python examples/serve_quantized.py
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec
from repro.serve.engine import PagedServingEngine, Request, ServingEngine


def main():
    from benchmarks.common import calib_batches, trained_model

    plan, params, batch_fn, _ = trained_model()
    calib = calib_batches(batch_fn, n=2)

    qparams, report = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=10),
    )
    print(f"quantized {len(report)} linears; mean layer error "
          f"{np.mean(list(report.values())):.5f}")

    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 250, rng.integers(6, 24)).astype(np.int32)
               for _ in range(6)]

    def serve(p, paged=True):
        if paged:
            eng = PagedServingEngine(plan, p, max_batch=3, max_seq=256,
                                     page_size=16, prefill_chunk=16)
        else:
            eng = ServingEngine(plan, p, max_batch=3, max_seq=256, prefill_pad=32)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new_tokens=8))
        fin = sorted(eng.run(), key=lambda r: r.rid)
        return [r.output for r in fin], eng

    dense_out, _ = serve(params)
    contig_out, _ = serve(params, paged=False)
    quant_out, eng = serve(qparams)
    agree = np.mean([
        np.mean([a == b for a, b in zip(d, q)]) for d, q in zip(dense_out, quant_out)
    ])
    print(f"served {len(prompts)} requests on {eng.n_decode_steps} shared decode "
          f"steps, {eng.n_prefill_chunks} prefill chunks")
    assert dense_out == contig_out, "paged engine diverged from contiguous (bf16 KV)"
    print("paged == contiguous (dense): True")
    for i, (d, q) in enumerate(zip(dense_out, quant_out)):
        print(f"  req{i}: dense={d}\n        4bit ={q}")
    print(f"token agreement dense vs 4-bit: {agree:.2%}")


if __name__ == "__main__":
    main()
