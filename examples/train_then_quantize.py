"""End-to-end driver: train a small LM for a few hundred steps, then PTQ it
with RTN / GPTQ / QuantEase and compare perplexities (the paper's Tables 1–3
flow on the synthetic corpus).

    PYTHONPATH=src python examples/train_then_quantize.py [--steps 300]
"""

import argparse

import numpy as np

from repro.configs.base import BlockDef, ModelConfig
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--bits", type=int, default=3)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="example_lm",
        d_model=128, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=384,
        vocab=256, pattern=(BlockDef(),), n_periods=4, max_seq=512,
    )
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=2e-3, total_steps=args.steps),
        TrainerConfig(steps=args.steps, batch=16, seq=96,
                      ckpt_every=args.steps, ckpt_dir="/tmp/example_lm"),
    )
    out = trainer.run()
    print(f"trained {args.steps} steps; final loss {out['final_loss']:.4f} "
          f"(corpus entropy floor {trainer.corpus.entropy_floor():.4f})")

    from benchmarks.common import calib_batches, perplexity

    calib = calib_batches(trainer.batch_fn)
    base = perplexity(trainer.plan, trainer.params, trainer.batch_fn)
    print(f"\n{'method':12s} ppl  ({args.bits}-bit)")
    print(f"{'full':12s} {base:.4f}")
    for method in ("rtn", "gptq", "quantease"):
        qp, _ = ptq_quantize_model(
            trainer.plan, trainer.params, calib,
            PTQConfig(method=method, spec=GridSpec(bits=args.bits), iterations=20),
        )
        print(f"{method:12s} {perplexity(trainer.plan, qp, trainer.batch_fn):.4f}")


if __name__ == "__main__":
    main()
