"""Sub-3-bit quantization with outliers (paper §5.4.1, Table 5).

Shows plain 2-bit collapse vs outlier-aware QuantEase keeping the model
usable, and the effective bits-per-weight accounting.

    PYTHONPATH=src python examples/outlier_sub3bit.py
"""

import numpy as np

from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec


def main():
    from benchmarks.common import calib_batches, perplexity, trained_model

    plan, params, batch_fn, _ = trained_model()
    calib = calib_batches(batch_fn, n=2)
    base = perplexity(plan, params, batch_fn)
    print(f"full precision ppl: {base:.4f}\n")

    for name, pcfg, bpw in [
        ("2-bit plain", PTQConfig(method="quantease", spec=GridSpec(bits=2), iterations=15), 2.0),
        ("2-bit + 2% outliers", PTQConfig(method="qe_outlier", spec=GridSpec(bits=2), iterations=15, outlier_frac=0.02), 2.0 + 0.02 * 48),
        ("3-bit + 1% outliers", PTQConfig(method="qe_outlier", spec=GridSpec(bits=3), iterations=15, outlier_frac=0.01), 3.0 + 0.01 * 48),
    ]:
        qp, _ = ptq_quantize_model(plan, params, calib, pcfg)
        ppl = perplexity(plan, qp, batch_fn)
        print(f"{name:22s} ~{bpw:.2f} bits/weight  ppl {ppl:.4f}")


if __name__ == "__main__":
    main()
