"""Table 5 — extreme (2-bit) quantization with 2% outliers.

Paper claim: at 2 bits, plain uniform quantization collapses; 2% outliers
keep QuantEase usable and far ahead of SpQR 2%.
"""

from __future__ import annotations

from benchmarks.common import Csv, calib_batches, perplexity, trained_model
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec


def run(csv: Csv):
    plan, params, batch_fn, _ = trained_model()
    calib = calib_batches(batch_fn)
    spec = GridSpec(bits=2)
    for name, pcfg in [
        ("plain2bit", PTQConfig(method="quantease", spec=spec, iterations=20)),
        ("spqr_2pct", PTQConfig(method="spqr", spec=spec, outlier_frac=0.02)),
        ("qe_outlier_2pct", PTQConfig(method="qe_outlier", spec=spec, iterations=20, outlier_frac=0.02)),
    ]:
        qp, _ = ptq_quantize_model(plan, params, calib, pcfg)
        csv.add(f"table5_{name}", ppl=round(perplexity(plan, qp, batch_fn), 4))


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.print()
