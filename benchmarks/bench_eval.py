"""BENCH_eval — end-to-end quality trajectory of the quantization engines.

The committed quality companion to ``BENCH_solver.json`` (speed) and
``BENCH_serve.json`` (serving): trains the shared benchmark model
(benchmarks/common.py, cached under /tmp), quantizes it over the paper's
Tables 1-3 grid — RTN / GPTQ / QuantEase at 4 and 3 bits plus the
outlier-aware 3-bit cell — and scores every cell **as the restacked
QuantizedTensor serving artifact** on the disjoint ``split="eval"`` stream
(repro/eval): perplexity, cloze top-1/top-5, multi-choice continuation
accuracy, plus the scorer-vs-serving-engine logit parity check on the
quantized checkpoint.

The full document must reproduce the paper's orderings (QuantEase ≤ GPTQ ≤
RTN perplexity at 3 and 4 bits; outlier-aware 3-bit < plain 3-bit) —
``--validate`` enforces them on non-smoke documents, so a regression in any
engine's *quality* fails CI the same way a schema break does.  ``--smoke``
runs a seconds-scale random-init subset with the same schema (CI guards
shape, not numbers, there).  Mirrors bench_solver/bench_serve conventions.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def collect(smoke: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.pipeline import DataConfig, make_batch_fn
    from repro.eval import EVAL_SCHEMA, quantized_parity, run_grid
    from repro.eval.harness import EvalBudget

    if smoke:
        import dataclasses as dc

        import benchmarks.common as C
        from repro.models import init_params, make_plan

        cfg = dc.replace(C.BENCH_CFG, d_model=64, head_dim=16, d_ff=128,
                         n_periods=2)
        plan = make_plan(cfg, 1)
        params = init_params(plan, jax.random.PRNGKey(0))
        cells = [
            {"method": "rtn", "bits": 4},
            {"method": "quantease", "bits": 3, "iterations": 2},
        ]
        budget = EvalBudget.smoke()
        iterations, seq, n_calib, parity_iters = 2, 64, 1, 2
    else:
        from benchmarks.common import trained_model

        # Longer-trained model than the perf benches: near the corpus
        # entropy floor the weights are finely tuned, so quantization
        # damage — and the paper's method ordering — rises well above
        # model error (at the perf benches' fast budget every method sits
        # within ~0.02 ppl of dense and the ordering drowns in noise).
        plan, params, _, _ = trained_model(
            steps=int(os.environ.get("BENCH_EVAL_TRAIN_STEPS", "1600"))
        )
        cfg = plan.cfg
        cells = [
            {"method": m, "bits": b}
            for b in (4, 3) for m in ("rtn", "gptq", "quantease")
        ] + [{"method": "qe_outlier", "bits": 3, "outlier_frac": 0.02}]
        # 24 eval batches: at 4 bits every method sits within ~0.01 ppl of
        # dense, so the paired method gaps need ~9k scored tokens to
        # resolve above eval-sampling noise.
        budget = EvalBudget(n_ppl_batches=24)
        iterations, seq, n_calib, parity_iters = 25, 96, 24, 10

    # Corpus seed must match the trainer's chain (TrainerConfig.seed = 0 in
    # benchmarks/common.py) — DataConfig.seed fixes the Markov chain itself.
    dcfg = DataConfig(vocab=cfg.vocab, seed=0)
    calib_fn, _ = make_batch_fn(dcfg, cfg, batch=4, seq=seq, split="calib")
    eval_fn, corpus = make_batch_fn(dcfg, cfg, batch=4, seq=seq, split="eval")
    calib = [
        {k: jnp.asarray(v) for k, v in calib_fn(i).items()} for i in range(n_calib)
    ]

    doc = {
        "schema": EVAL_SCHEMA,
        "smoke": smoke,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "data": {
            "vocab": cfg.vocab, "seq": seq,
            "eval_split": "eval", "calib_split": "calib",
            "entropy_floor_ppl": round(float(np.exp(corpus.entropy_floor())), 4),
        },
        "iterations": iterations,
        "emit": "qt",
    }
    doc.update(run_grid(
        plan, params, calib, eval_fn, cells,
        iterations=iterations, emit="qt", budget=budget,
        progress_cb=lambda r: print(
            f"# [{r['cell']}] ppl={r.get('ppl', 0):.4f}", file=sys.stderr
        ),
    ))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (5, 13, 29)]
    doc["parity"] = quantized_parity(
        plan, params, calib, prompts, iterations=parity_iters,
        max_seq=64, page_size=8, prefill_chunk=16,
    )
    return doc


def validate(path: str) -> list[str]:
    """Schema + (full runs) ordering problems; empty means well-formed."""
    from repro.eval import validate_doc

    if not os.path.exists(path):
        return [f"{path}: missing"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    return validate_doc(doc)


def run(csv):
    """benchmarks/run.py entry point: measure, write BENCH_eval.json, and
    mirror headline numbers into the shared CSV.  Under BENCH_FAST=1 the
    smoke subset writes ``BENCH_eval_smoke.json`` instead — the committed
    trajectory is only overwritten by full-budget runs."""
    smoke = os.environ.get("BENCH_FAST", "0") == "1"
    doc = collect(smoke=smoke)
    name = "BENCH_eval_smoke.json" if smoke else "BENCH_eval.json"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", name)
    with open(os.path.normpath(out), "w") as f:
        json.dump(doc, f, indent=1)
    csv.add("eval_dense", ppl=doc["dense"]["ppl"], top1=doc["dense"]["top1"])
    for row in doc["grid"]:
        csv.add(
            f"eval_{row['method']}_{row['bits']}bit",
            ppl=row["ppl"], top1=row["top1"], choice_acc=row["choice_acc"],
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="seconds-scale subset")
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_eval.json, or "
                         "BENCH_eval_smoke.json under --smoke so a smoke run "
                         "never clobbers the committed trajectory)")
    ap.add_argument("--validate", metavar="PATH", help="check an existing file")
    args = ap.parse_args()
    if args.out is None:
        args.out = "BENCH_eval_smoke.json" if args.smoke else "BENCH_eval.json"
    if args.validate:
        probs = validate(args.validate)
        for pr in probs:
            print(f"INVALID: {pr}", file=sys.stderr)
        print(f"{args.validate}: {'FAIL' if probs else 'ok'}")
        sys.exit(1 if probs else 0)
    doc = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"dense ppl {doc['dense']['ppl']:.4f} "
          f"(entropy floor {doc['data']['entropy_floor_ppl']})")
    for row in doc["grid"]:
        print(f"{row['method']:>12} {row['bits']}bit: ppl {row['ppl']:.4f}  "
              f"top1 {row['top1']:.3f}  top5 {row['top5']:.3f}  "
              f"choice {row['choice_acc']:.3f}  layer_err {row['mean_layer_err']:.5f}")
    print(f"parity: {doc['parity']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    main()
