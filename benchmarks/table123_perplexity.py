"""Tables 1–3 — perplexity after 3/4-bit quantization, method comparison.

Paper claim (OPT/BLOOM/Falcon → our synthetic-corpus model): QuantEase ≤
GPTQ ≤ AWQ ≪ RTN at 3 bits; all methods ≈ full precision at 4 bits.
"""

from __future__ import annotations

from benchmarks.common import Csv, calib_batches, perplexity, trained_model
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec


def run(csv: Csv):
    plan, params, batch_fn, corpus = trained_model()
    calib = calib_batches(batch_fn)
    full = perplexity(plan, params, batch_fn)
    csv.add("table1_full", ppl=round(full, 4), entropy_floor_ppl=round(
        float(__import__("numpy").exp(corpus.entropy_floor())), 3))
    for bits in (4, 3):
        for method in ("rtn", "awq", "gptq", "quantease"):
            qp, _ = ptq_quantize_model(
                plan, params, calib,
                PTQConfig(method=method, spec=GridSpec(bits=bits), iterations=20),
            )
            ppl = perplexity(plan, qp, batch_fn)
            csv.add(f"table1_{bits}bit_{method}", ppl=round(ppl, 4))


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.print()
