"""BENCH_solver — perf trajectory of the CD hot path + serving GEMM.

Measures, on the `benchmarks/runtime.py` layer shapes:

  * per-iteration wall-clock of the QuantEase solve for each engine —
    ``legacy_obj`` (the pre-fused production default: full Ŵ@Σ̃ recompute,
    full-width Δ corrections, always-on objective history), ``legacy``
    (same schedule, objective off), ``fused`` (rolling-Δ incremental
    engine, the new default) and ``fused_bf16`` (bf16 Σ̃ correction
    operands) — plus GPTQ's total wall-clock for the paper's
    "one QuantEase iteration ≈ one GPTQ solve" structural claim,
  * per-outer-iteration wall-clock of the **outlier-aware** solve
    (Algorithm 3) for ``legacy_obj`` (the pre-PR production default:
    re-prepped quantease re-entry + dense IHT-gradient matmul +
    unconditional objective, unrolled Python loop), ``legacy`` (same
    schedule, objective off), ``fused`` (scanned resident-base engine,
    DESIGN.md §Outlier-aware-fused) and ``fused_bf16`` — unstructured and
    structured variants.  Per-outer-iteration numbers are *marginal*
    ((t(iters) − t(1)) / (iters − 1)) so the shared one-time prep (grid
    shrink, λ_max power iteration) doesn't flatter either engine,
  * serving-GEMM throughput of ``ops.dequant_matmul`` (per-channel,
    grouped, packed-int4 variants) in effective weight-GB/s.

Emits ``BENCH_solver.json`` (schema below) so every future PR has a perf
trajectory to answer to; ``--smoke`` runs a seconds-scale subset with the
same schema (CI guards the file shape, not the numbers).  ``--validate``
checks an existing file and exits non-zero on malformed/missing.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

SCHEMA = 3
_CD_KEYS = {
    "q", "p", "block_size", "iterations",
    "legacy_obj_us_per_iter", "legacy_us_per_iter",
    "fused_us_per_iter", "fused_bf16_us_per_iter",
    "speedup_fused_vs_legacy_obj", "speedup_fused_vs_legacy",
    "gptq_total_us", "fused_iter_vs_gptq",
}
_OUTLIER_KEYS = {
    "q", "p", "s", "structured", "iterations",
    "legacy_obj_us_per_iter", "legacy_us_per_iter",
    "fused_us_per_iter", "fused_bf16_us_per_iter",
    "speedup_fused_vs_legacy_obj", "speedup_fused_vs_legacy",
}
_GEMM_KEYS = {"m", "q", "p", "variant", "us", "weight_gbps"}


def _time(fn, reps):
    """Best-of-reps wall clock (min filters scheduler noise on shared CPUs)."""
    import jax

    jax.block_until_ready(fn())  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def bench_cd(shapes, iterations, reps, block_size=128):
    import jax.numpy as jnp
    import numpy as np

    from repro.core import gptq, quantease
    from repro.quant import GridSpec

    rng = np.random.default_rng(0)
    spec = GridSpec(bits=4)
    rows = []
    for q, p in shapes:
        w = jnp.asarray(rng.standard_normal((q, p)).astype(np.float32))
        x = rng.standard_normal((p, 2 * p)).astype(np.float32)
        sig = jnp.asarray(x @ x.T)

        def solve(engine, matmul_dtype="float32", track=False):
            return lambda: quantease.quantease_quantize(
                w, sig, spec, iterations=iterations, block_size=block_size,
                engine=engine, matmul_dtype=matmul_dtype, track_objective=track,
                use_kernel="auto",
            )[0]

        us_legacy_obj = _time(solve("legacy", track=True), reps)
        us_legacy = _time(solve("legacy"), reps)
        us_fused = _time(solve("fused"), reps)
        us_bf16 = _time(solve("fused", matmul_dtype="bfloat16"), reps)
        us_gptq = _time(lambda: gptq.gptq_quantize(w, sig, spec), reps)
        rows.append({
            "q": q, "p": p, "block_size": block_size, "iterations": iterations,
            "legacy_obj_us_per_iter": round(us_legacy_obj / iterations, 1),
            "legacy_us_per_iter": round(us_legacy / iterations, 1),
            "fused_us_per_iter": round(us_fused / iterations, 1),
            "fused_bf16_us_per_iter": round(us_bf16 / iterations, 1),
            "speedup_fused_vs_legacy_obj": round(us_legacy_obj / us_fused, 2),
            "speedup_fused_vs_legacy": round(us_legacy / us_fused, 2),
            "gptq_total_us": round(us_gptq, 1),
            "fused_iter_vs_gptq": round(us_fused / iterations / us_gptq, 2),
        })
    return rows


def _time_pair(fn_short, fn_long, reps):
    """Best-of-reps for a (1-iteration, N-iteration) pair, interleaved so
    machine-load drift hits both measurements equally."""
    import jax

    jax.block_until_ready(fn_short())
    jax.block_until_ready(fn_long())
    bs = bl = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_short())
        bs = min(bs, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_long())
        bl = min(bl, time.perf_counter() - t0)
    return bs * 1e6, bl * 1e6


def bench_outlier(shapes, iterations, reps, outlier_frac=0.01):
    """Outlier-aware Algorithm 3: legacy (pre-PR schedule) vs the fused
    resident-base engine, marginal us per outer iteration."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import outlier
    from repro.quant import GridSpec

    rng = np.random.default_rng(2)
    spec = GridSpec(bits=3)  # the paper's outlier headline regime
    rows = []
    for q, p in shapes:
        w = jnp.asarray(rng.standard_normal((q, p)).astype(np.float32))
        x = rng.standard_normal((p, 2 * p)).astype(np.float32)
        sig = jnp.asarray(x @ x.T)
        s = max(int(outlier_frac * q * p), 1)

        for structured in (False, True):
            def solve(engine, iters, matmul_dtype="float32", track=False):
                return lambda: outlier.outlier_quantease(
                    w, sig, spec, s=s, iterations=iters, structured=structured,
                    engine=engine, matmul_dtype=matmul_dtype,
                    track_objective=track, use_kernel="auto",
                ).w_hat

            marg = {}
            for name, engine, kw in (
                ("legacy_obj", "legacy", dict(track=True)),
                ("legacy", "legacy", {}),
                ("fused", "fused", {}),
                ("fused_bf16", "fused", dict(matmul_dtype="bfloat16")),
            ):
                u1, un = _time_pair(
                    solve(engine, 1, **kw), solve(engine, iterations, **kw), reps
                )
                marg[name] = max(un - u1, 1e-9) / (iterations - 1)
            rows.append({
                "q": q, "p": p, "s": s, "structured": structured,
                "iterations": iterations,
                "legacy_obj_us_per_iter": round(marg["legacy_obj"], 1),
                "legacy_us_per_iter": round(marg["legacy"], 1),
                "fused_us_per_iter": round(marg["fused"], 1),
                "fused_bf16_us_per_iter": round(marg["fused_bf16"], 1),
                "speedup_fused_vs_legacy_obj": round(
                    marg["legacy_obj"] / marg["fused"], 2
                ),
                "speedup_fused_vs_legacy": round(
                    marg["legacy"] / marg["fused"], 2
                ),
            })
    return rows


def bench_serve_gemm(shapes, reps):
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.quant import pack_codes

    rng = np.random.default_rng(1)
    rows = []
    for m, q, p in shapes:
        x = jnp.asarray(rng.standard_normal((m, p)).astype(np.float32), jnp.bfloat16)
        codes = jnp.asarray(rng.integers(0, 16, (q, p)).astype(np.uint8))
        gsz = 128 if p % 128 == 0 else p
        variants = {
            "perchannel": dict(
                codes=codes,
                scale=jnp.asarray((rng.random(q) * 0.1 + 0.01).astype(np.float32)),
                zero=jnp.zeros((q,), jnp.float32),
                packed4=False,
                wbytes=q * p,
            ),
            f"grouped{gsz}": dict(
                codes=codes,
                scale=jnp.asarray(
                    (rng.random((q, p // gsz)) * 0.1 + 0.01).astype(np.float32)
                ),
                zero=jnp.zeros((q, p // gsz), jnp.float32),
                packed4=False,
                wbytes=q * p,
            ),
            "packed4": dict(
                codes=pack_codes(codes, 4),
                scale=jnp.asarray((rng.random(q) * 0.1 + 0.01).astype(np.float32)),
                zero=jnp.zeros((q,), jnp.float32),
                packed4=True,
                wbytes=q * p // 2,
            ),
        }
        for name, v in variants.items():
            fn = lambda v=v: ops.dequant_matmul(
                x, v["codes"], v["scale"], v["zero"], packed4=v["packed4"]
            )
            us = _time(fn, reps)
            rows.append({
                "m": m, "q": q, "p": p, "variant": name, "us": round(us, 1),
                "weight_gbps": round(v["wbytes"] / (us * 1e-6) / 1e9, 2),
            })
    return rows


def collect(smoke: bool) -> dict:
    import jax

    if smoke:
        cd = bench_cd([(64, 64)], iterations=2, reps=1, block_size=32)
        outl = bench_outlier([(64, 64)], iterations=3, reps=1)
        gemm = bench_serve_gemm([(4, 64, 64)], reps=1)
    else:
        cd = bench_cd([(128, 128), (256, 256), (512, 512)], iterations=5, reps=7)
        outl = bench_outlier(
            [(128, 128), (256, 256), (512, 512)], iterations=13, reps=7
        )
        gemm = bench_serve_gemm([(8, 512, 512), (64, 1024, 1024)], reps=7)
    return {
        "schema": SCHEMA,
        "smoke": smoke,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "cd": cd,
        "outlier": outl,
        "serve_gemm": gemm,
    }


def validate(path: str) -> list[str]:
    """Returns a list of problems; empty means the file is well-formed."""
    if not os.path.exists(path):
        return [f"{path}: missing"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    probs = []
    if doc.get("schema") != SCHEMA:
        probs.append(f"schema != {SCHEMA}")
    for section, keys in (
        ("cd", _CD_KEYS), ("outlier", _OUTLIER_KEYS), ("serve_gemm", _GEMM_KEYS)
    ):
        rows = doc.get(section)
        if not isinstance(rows, list) or not rows:
            probs.append(f"{section}: missing/empty")
            continue
        for i, row in enumerate(rows):
            missing = keys - set(row)
            if missing:
                probs.append(f"{section}[{i}]: missing keys {sorted(missing)}")
    return probs


def run(csv):
    """benchmarks/run.py entry point: measure, write BENCH_solver.json, and
    mirror the headline numbers into the shared CSV.

    Under BENCH_FAST=1 the smoke subset is measured and written to
    ``BENCH_solver_smoke.json`` instead — the committed full trajectory
    must only ever be overwritten by full-budget runs.
    """
    smoke = os.environ.get("BENCH_FAST", "0") == "1"
    doc = collect(smoke=smoke)
    name = "BENCH_solver_smoke.json" if smoke else "BENCH_solver.json"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", name)
    with open(os.path.normpath(out), "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["cd"]:
        csv.add(
            f"solver_p{row['p']}_q{row['q']}",
            us=row["fused_us_per_iter"],
            fused_speedup=row["speedup_fused_vs_legacy_obj"],
            iter_vs_gptq=row["fused_iter_vs_gptq"],
        )
    for row in doc["outlier"]:
        kind = "struct" if row["structured"] else "unstruct"
        csv.add(
            f"outlier_{kind}_p{row['p']}_q{row['q']}",
            us=row["fused_us_per_iter"],
            fused_speedup=row["speedup_fused_vs_legacy_obj"],
        )
    for row in doc["serve_gemm"]:
        csv.add(
            f"gemm_{row['variant']}_m{row['m']}_p{row['p']}",
            us=row["us"],
            weight_gbps=row["weight_gbps"],
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="seconds-scale subset")
    ap.add_argument("--out", default="BENCH_solver.json")
    ap.add_argument("--validate", metavar="PATH", help="check an existing file")
    args = ap.parse_args()
    if args.validate:
        probs = validate(args.validate)
        for pr in probs:
            print(f"INVALID: {pr}", file=sys.stderr)
        print(f"{args.validate}: {'FAIL' if probs else 'ok'}")
        sys.exit(1 if probs else 0)
    doc = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["cd"]:
        print(
            f"cd p={row['p']} q={row['q']}: fused {row['fused_us_per_iter']}us/iter "
            f"(legacy+obj {row['legacy_obj_us_per_iter']}, legacy {row['legacy_us_per_iter']}, "
            f"bf16 {row['fused_bf16_us_per_iter']}) "
            f"speedup {row['speedup_fused_vs_legacy_obj']}x/{row['speedup_fused_vs_legacy']}x"
        )
    for row in doc["outlier"]:
        kind = "struct" if row["structured"] else "unstruct"
        print(
            f"outlier[{kind}] p={row['p']} q={row['q']}: "
            f"fused {row['fused_us_per_iter']}us/outer-iter "
            f"(legacy+obj {row['legacy_obj_us_per_iter']}, "
            f"legacy {row['legacy_us_per_iter']}, "
            f"bf16 {row['fused_bf16_us_per_iter']}) "
            f"speedup {row['speedup_fused_vs_legacy_obj']}x"
            f"/{row['speedup_fused_vs_legacy']}x"
        )
    for row in doc["serve_gemm"]:
        print(
            f"gemm {row['variant']} m={row['m']} p={row['p']}: {row['us']}us "
            f"({row['weight_gbps']} weight-GB/s)"
        )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    main()
