"""Shared benchmark substrate: a small *trained* model + calibration data.

The paper's tables quantize pretrained OPT/BLOOM/Falcon checkpoints; offline
we train a small decoder on the synthetic Markov corpus (data/pipeline.py)
until it is meaningfully better than chance, then PTQ it.  Orderings
(QuantEase ≤ GPTQ ≤ AWQ/RTN; outlier-aware ≤ plain; 2-bit needs outliers)
are the reproduction targets — absolute OPT perplexities need the real
checkpoints (DESIGN.md §7).

The trained checkpoint is cached under /tmp keyed by config, so the ~10
benchmark entry points share one training run.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import BlockDef, ModelConfig
from repro.data.pipeline import DataConfig, make_batch_fn
from repro.dist import checkpoint as ckpt
from repro.models import init_params, make_plan, train_loss
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

BENCH_CFG = ModelConfig(
    name="bench_opt_s",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=384,
    vocab=256,
    pattern=(BlockDef(kind="attn", mlp="dense"),),
    n_periods=4,
    max_seq=512,
)

_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "240"))
_BATCH, _SEQ = 16, 96


def _cache_dir(cfg: ModelConfig, steps: int) -> str:
    key = hashlib.md5(
        f"{cfg.name}-{cfg.d_model}-{cfg.n_periods}-{cfg.vocab}-{steps}".encode()
    ).hexdigest()[:10]
    return f"/tmp/repro_bench_{key}"


def trained_model(cfg: ModelConfig = BENCH_CFG, steps: int = None):
    """Returns (plan, params, batch_fn, corpus).

    ``steps`` overrides the shared training budget (cache is keyed by it):
    the perf benches use the fast default, while the end-to-end quality
    bench (bench_eval) trains closer to the corpus entropy floor so
    quantization damage — and the method ordering — rises above model
    error.
    """
    steps = _STEPS if steps is None else steps
    plan = make_plan(cfg, 1)
    tcfg = TrainerConfig(
        steps=steps, batch=_BATCH, seq=_SEQ, ckpt_every=steps,
        ckpt_dir=_cache_dir(cfg, steps), log_every=max(steps // 4, 1),
    )
    trainer = Trainer(cfg, AdamWConfig(lr=2e-3, total_steps=steps), tcfg)
    if ckpt.latest_step(tcfg.ckpt_dir) != steps:
        trainer.run()
        trainer.save(steps)
    else:
        trainer.restore()
    return plan, trainer.params, trainer.batch_fn, trainer.corpus


def perplexity(plan, params, batch_fn, n_batches: int = 4, offset: int = 10_000):
    """eval ppl on held-out steps (different seed-stream region)."""
    losses = []
    for i in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in batch_fn(offset + i).items()}
        losses.append(float(train_loss(plan, params, b)))
    return float(np.exp(np.mean(losses)))


def calib_batches(batch_fn, n: int = 4, offset: int = 20_000):
    return [
        {k: jnp.asarray(v) for k, v in batch_fn(offset + i).items()} for i in range(n)
    ]


class Csv:
    """Collect `name,us_per_call,derived` rows (the benchmarks/run.py contract)."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us: float = 0.0, **derived):
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        self.rows.append(f"{name},{us:.1f},{d}")

    def print(self):
        print("name,us_per_call,derived")
        for r in self.rows:
            print(r)
