"""Tables A.8–A.10 — runtime scaling of QuantEase layer quantization.

The paper reports wall-clock per model (GPU); here we measure per-layer CD
cost vs (p, q) on CPU and verify the O(pqn + K·p²q) scaling plus the paper's
headline structural claims: per-iteration cost comparable to GPTQ's total,
and the accelerated (blocked, Eq. 13) form beating a naive Algorithm-1 sweep.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import gptq, quantease
from repro.quant import GridSpec


def _sigma(p, n, rng):
    x = rng.standard_normal((p, n)).astype(np.float32)
    return jnp.asarray(x @ x.T)


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(csv: Csv):
    rng = np.random.default_rng(0)
    spec = GridSpec(bits=3)
    for p, q in [(128, 128), (256, 256), (512, 512)]:
        w = jnp.asarray(rng.standard_normal((q, p)).astype(np.float32))
        sig = _sigma(p, 2 * p, rng)
        us_qe = _time(
            lambda: quantease.quantease_quantize(w, sig, spec, iterations=5)[0]
        )
        us_gptq = _time(lambda: gptq.gptq_quantize(w, sig, spec))
        csv.add(
            f"runtime_p{p}_q{q}",
            us=us_qe,
            us_per_iter=round(us_qe / 5, 1),
            gptq_us=round(us_gptq, 1),
            iter_vs_gptq=round(us_qe / 5 / us_gptq, 2),
        )


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.print()
