"""Render benchmark artifacts as markdown: the EXPERIMENTS.md §Dry-run /
§Roofline tables from the dryrun_results JSONs, plus the committed perf
trajectories ``BENCH_solver.json`` (CD/outlier engines + serving GEMM) and
``BENCH_serve.json`` (paged vs contiguous serving).

    PYTHONPATH=src python -m benchmarks.report [--dir benchmarks/dryrun_results]
"""

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "stablelm_12b", "gemma2_27b", "qwen15_32b", "phi3_mini_3_8b",
    "whisper_large_v3", "jamba_1_5_large", "olmoe_1b_7b", "mixtral_8x22b",
    "mamba2_2_7b", "llava_next_34b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath):
    out = {}
    for f in glob.glob(os.path.join(dirpath, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def roofline_table(cells, mesh_name):
    lines = [
        f"### Roofline — {mesh_name} mesh",
        "",
        "| arch | shape | compute | memory | collective | bottleneck | "
        "peakHBM/dev | MODEL_FLOPS ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | — | — | — | skip | — | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | FAIL | | | | | | |")
                continue
            rf = r["roofline"]
            dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            # roofline fraction: ideal compute time (MODEL_FLOPS) over the
            # dominant term — "how close does the step run to the pure
            # model-math roofline".
            ideal = rf["model_flops"] / (197e12 * r["devices"])
            frac = ideal / dom if dom > 0 else 0.0
            lines.append(
                "| {a} | {s} | {c} | {m} | {x} | **{b}** | {h:.1f} GB | {r:.2f} | {f:.1%} |".format(
                    a=arch, s=shape,
                    c=fmt_s(rf["compute_s"]), m=fmt_s(rf["memory_s"]),
                    x=fmt_s(rf["collective_s"]), b=rf["bottleneck"],
                    h=rf["memory_stats"]["peak_hbm_est"] / 1e9,
                    r=rf["model_flops_ratio"], f=frac,
                )
            )
    return "\n".join(lines)


def dryrun_table(cells, mesh_name):
    lines = [
        f"### Dry-run — {mesh_name} mesh",
        "",
        "| arch | shape | status | devices | compile | flops/dev | bytes/dev | "
        "coll.link bytes/dev | AG/AR/RS/A2A/CP counts | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape))
            if r is None:
                continue
            if r["status"] != "ok":
                note = r.get("reason", r.get("error", ""))[:60]
                lines.append(
                    f"| {arch} | {shape} | {r['status']} | | | | | | | {note} |"
                )
                continue
            rf = r["roofline"]
            cd = rf["coll_detail"]["counts"]
            counts = "/".join(
                str(cd[k]) for k in
                ["all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute"]
            )
            lines.append(
                "| {a} | {s} | ok | {d} | {t:.0f}s | {f:.2e} | {b:.2e} | {c:.2e} | {n} | {note} |".format(
                    a=arch, s=shape, d=r["devices"], t=r["compile_s"],
                    f=rf["flops_per_device"], b=rf["bytes_per_device"],
                    c=rf["collective_link_bytes"], n=counts, note=r.get("note", ""),
                )
            )
    return "\n".join(lines)


def _load_json(path):
    """(doc, problem) — never raises: a missing/corrupt artifact becomes a
    rendered note instead of a crashed report."""
    if not os.path.exists(path):
        return None, "missing — regenerate with the matching benchmarks/ script"
    try:
        with open(path) as f:
            return json.load(f), None
    except (OSError, json.JSONDecodeError) as e:
        return None, f"unreadable/not JSON ({e})"


_KNOWN_SCHEMAS = {"BENCH_solver.json": (1, 2, 3), "BENCH_serve.json": (1, 2, 3, 4),
                  "BENCH_eval.json": (1,), "BENCH_tune.json": (1,)}


def solver_bench_table(doc):
    lines = [
        f"### BENCH_solver (schema {doc.get('schema')}, backend {doc.get('backend')})",
        "",
        "| section | shape | fused us/iter | vs legacy+obj | vs legacy |",
        "|---|---|---|---|---|",
    ]
    for row in doc.get("cd", []):
        lines.append(
            f"| cd | {row.get('q')}×{row.get('p')} | {row.get('fused_us_per_iter', '?')} "
            f"| {row.get('speedup_fused_vs_legacy_obj', '?')}x | {row.get('speedup_fused_vs_legacy', '?')}x |"
        )
    for row in doc.get("outlier", []):
        kind = "outlier/struct" if row.get("structured") else "outlier/unstruct"
        lines.append(
            f"| {kind} | {row.get('q')}×{row.get('p')} | {row.get('fused_us_per_iter', '?')} "
            f"| {row.get('speedup_fused_vs_legacy_obj', '?')}x | {row.get('speedup_fused_vs_legacy', '?')}x |"
        )
    lines += ["", "| GEMM variant | m×q×p | us | weight-GB/s |", "|---|---|---|---|"]
    for row in doc.get("serve_gemm", []):
        lines.append(
            f"| {row.get('variant')} | {row.get('m')}×{row.get('q')}×{row.get('p')} "
            f"| {row.get('us', '?')} | {row.get('weight_gbps', '?')} |"
        )
    return "\n".join(lines)


def serve_bench_table(doc):
    schema = doc.get("schema")
    lines = [
        f"### BENCH_serve (schema {schema}, backend {doc.get('backend')})",
        "",
    ]
    if schema == 1:
        # Pre-upgrade artifact: no weights/layout dimension, no KV-traffic
        # columns — render the old shape and say why the new ones are absent.
        lines.append(
            "_schema-1 artifact (pre packed-decode upgrade): no weights/"
            "layout cells or bytes/token columns — regenerate with "
            "benchmarks/bench_serve.py for the full table_"
        )
        lines.append("")
    lines += [
        "| scenario | engine | kv | weights | layout | batch | tok/s | speedup "
        "| ttft mean | ttft p90 | kv B/tok pred | kv B/tok meas | prefix-hit tok | preempt |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for row in doc.get("serve", []):
        sp = row.get("speedup_vs_contiguous")
        fmt = lambda v: "—" if v is None else v
        lines.append(
            "| {sc} | {en} | {kv} | {w} | {ly} | {mb} | {t} | {sp} | {tm}ms | {tp}ms "
            "| {bp} | {bm} | {ph} | {pe} |".format(
                sc=row.get("scenario"), en=row.get("engine"), kv=row.get("kv"),
                w=row.get("weights", "dense"),
                ly=row.get("weight_layout", "—"),
                mb=row.get("max_batch"), t=row.get("tokens_per_s", "?"),
                sp=f"{sp}x" if sp else "—", tm=row.get("ttft_mean_ms", "?"),
                tp=row.get("ttft_p90_ms", "?"),
                bp=fmt(row.get("kv_bytes_per_token_pred")),
                bm=fmt(row.get("kv_bytes_per_token_meas")),
                ph=row.get("prefix_hit_tokens", "?"),
                pe=row.get("preemptions", "?"),
            )
        )
    bursty = doc.get("bursty", [])
    if bursty:
        lines += [
            "",
            "**Bursty trace (Poisson-burst arrivals, long-tail prompts, "
            "per-request deadlines — identical seeded trace per scheduler):**",
            "",
            "| scheduler | req | tok/s | ttft p50 | ttft p99 | miss rate "
            "| completed | resumed | shed | missed |",
            "|---|---|---|---|---|---|---|---|---|---|",
        ]
        for row in bursty:
            lines.append(
                "| {s} | {n} | {t} | {p50}ms | {p99}ms | {mr} | {c} | {r} "
                "| {sh} | {dm} |".format(
                    s=row.get("scheduler"), n=row.get("n_requests"),
                    t=row.get("tokens_per_s", "?"),
                    p50=row.get("ttft_p50_ms", "?"),
                    p99=row.get("ttft_p99_ms", "?"),
                    mr=row.get("deadline_miss_rate", "?"),
                    c=row.get("n_completed", "?"),
                    r=row.get("n_preempted_resumed", "?"),
                    sh=row.get("n_shed", "?"), dm=row.get("n_deadline_missed", "?"),
                )
            )
    elif schema == 2:
        lines += [
            "",
            "_schema-2 artifact (pre SLO upgrade): no bursty-trace / "
            "deadline-miss cells — regenerate with benchmarks/bench_serve.py_",
        ]
    spec = doc.get("spec", [])
    if spec:
        lines += [
            "",
            "**Speculative decoding (q4 target, truncated self-drafts, "
            "equal page budget — output token-identical to non-spec "
            "greedy):**",
            "",
            "| draft | γ | tok/s | vs non-spec | acceptance | rounds "
            "| identical |",
            "|---|---|---|---|---|---|---|",
        ]
        for row in spec:
            acc = row.get("acceptance_rate")
            lines.append(
                "| {d} | {g} | {t} | {sp}x | {a} | {r} | {ok} |".format(
                    d=row.get("draft"), g=row.get("gamma"),
                    t=row.get("tokens_per_s", "?"),
                    sp=row.get("speedup_vs_baseline", "?"),
                    a="—" if acc is None else acc,
                    r=row.get("n_spec_rounds", "?"),
                    ok=row.get("token_identical"),
                )
            )
    elif schema == 3:
        lines += [
            "",
            "_schema-3 artifact (pre speculative-decoding upgrade): no "
            "acceptance-rate cells — regenerate with "
            "benchmarks/bench_serve.py_",
        ]
    return "\n".join(lines)


def eval_bench_table(doc):
    dense = doc.get("dense", {}) or {}
    data = doc.get("data", {}) or {}
    lines = [
        f"### BENCH_eval (schema {doc.get('schema')}, backend {doc.get('backend')})",
        "",
        f"dense ppl **{dense.get('ppl', '?')}** "
        f"(entropy floor {data.get('entropy_floor_ppl', '?')}), "
        f"top1 {dense.get('top1', '?')}, choice {dense.get('choice_acc', '?')}",
        "",
        "| method | bits | ppl | top1 | top5 | choice acc | layer err |",
        "|---|---|---|---|---|---|---|",
    ]
    for row in doc.get("grid", []):
        lines.append(
            f"| {row.get('method')} | {row.get('bits')} | {row.get('ppl', '?')} "
            f"| {row.get('top1', '?')} | {row.get('top5', '?')} "
            f"| {row.get('choice_acc', '?')} | {row.get('mean_layer_err', '?')} |"
        )
    par = doc.get("parity")
    if isinstance(par, dict):
        lines += [
            "",
            f"parity ({par.get('cell', 'dense')}): scorer vs contiguous "
            f"{par.get('max_abs_diff_contiguous', '?')}, vs paged "
            f"{par.get('max_abs_diff_paged', '?')} (tol {par.get('tol', '?')}); "
            f"paged bitwise = {par.get('paged_bitwise_contiguous', '?')}",
        ]
    return "\n".join(lines)


def tune_bench_table(doc):
    uniform = doc.get("uniform", {}) or {}
    lines = [
        f"### BENCH_tune (schema {doc.get('schema')}, backend {doc.get('backend')})",
        "",
        f"budget **{doc.get('budget_avg_bits', '?')} avg bits/weight** "
        f"over widths {doc.get('bits_candidates', '?')}; "
        f"uniform baseline ppl {uniform.get('ppl', '?')}",
        "",
        "| candidate | kind | avg bits | ppl | bits histogram | outlier layers |",
        "|---|---|---|---|---|---|",
    ]
    best_label = (doc.get("best") or {}).get("label")
    for row in doc.get("candidates", []):
        label = row.get("label", "?")
        if label == best_label:
            label = f"**{label}**"
        lines.append(
            f"| {label} | {row.get('kind', '?')} | {row.get('avg_bits', '?')} "
            f"| {row.get('ppl', '?')} | {row.get('bits_histogram', '—')} "
            f"| {row.get('n_outlier_layers', '—')} |"
        )
    par = doc.get("parity")
    if isinstance(par, dict):
        lines += [
            "",
            f"mixed-artifact parity (widths {doc.get('parity_bits_histogram', '?')}): "
            f"scorer vs contiguous {par.get('max_abs_diff_contiguous', '?')}, "
            f"vs paged {par.get('max_abs_diff_paged', '?')} "
            f"(tol {par.get('tol', '?')}); "
            f"paged bitwise = {par.get('paged_bitwise_contiguous', '?')}",
        ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/dryrun_results")
    ap.add_argument("--bench-dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."))
    args = ap.parse_args()
    for mesh in ("single", "multi"):
        cells = load(os.path.join(args.dir, mesh))
        if not cells:
            continue
        print(dryrun_table(cells, mesh))
        print()
        print(roofline_table(cells, mesh))
        print()
    for name, render in (
        ("BENCH_solver.json", solver_bench_table),
        ("BENCH_serve.json", serve_bench_table),
        ("BENCH_eval.json", eval_bench_table),
        ("BENCH_tune.json", tune_bench_table),
    ):
        doc, prob = _load_json(os.path.normpath(os.path.join(args.bench_dir, name)))
        if doc is None:
            print(f"### {name}\n\n_{prob}_\n")
            continue
        if doc.get("schema") not in _KNOWN_SCHEMAS[name]:
            # Unknown (likely newer) schema: render best-effort rather than
            # crash — field lookups below all degrade to '?'.
            print(f"_{name}: unknown schema {doc.get('schema')!r} "
                  f"(known: {_KNOWN_SCHEMAS[name]}); rendering best-effort_\n")
        try:
            print(render(doc))
        except Exception as e:  # malformed rows: note, keep the report alive
            print(f"_{name}: render failed ({type(e).__name__}: {e})_")
        print()


if __name__ == "__main__":
    main()
