"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Heavier benches share one cached
trained model (benchmarks/common.py); budget can be trimmed with
BENCH_TRAIN_STEPS / BENCH_FAST=1 (skips the slowest tables).
"""

import os
import sys
import time
import traceback


def main() -> None:
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import Csv

    from benchmarks import (
        bench_eval,
        bench_serve,
        bench_solver,
        bench_tune,
        fig2_layer_error,
        fig3_iterations,
        runtime,
        table4_outliers,
        table5_extreme,
        table123_perplexity,
    )

    fast = os.environ.get("BENCH_FAST", "0") == "1"
    modules = [table123_perplexity, fig2_layer_error, table4_outliers,
               table5_extreme, runtime, bench_solver, bench_serve, bench_eval,
               bench_tune]
    if not fast:
        modules.insert(2, fig3_iterations)

    csv = Csv()
    for mod in modules:
        t0 = time.time()
        try:
            mod.run(csv)
            print(f"# {mod.__name__}: {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
    csv.print()


if __name__ == "__main__":
    main()
