"""BENCH_serve — throughput / latency trajectory of the serving engines.

Serves batches of greedy-decode requests on a small random-init decoder
(serving perf is weight-value independent) and measures, per (scenario,
engine, kv-dtype, weights) cell:

  * **tokens/s** — generated tokens over wall-clock from first submit to
    batch completion (prefill + decode + scheduling, everything included),
  * **TTFT** — per-request time-to-first-token (mean + p90), which is where
    chunked prefill and wider paged admission show up,
  * engine counters: prefill chunks/tokens, prefix-cache hit tokens,
    preemptions,
  * **bytes/token** — roofline-*predicted* decode KV traffic
    (roofline/analysis.paged_kv_bytes_per_token at the workload's mean
    context) next to the *measured* value from the engine's page-read
    counter (engine.kv_read_bytes), the schema-2 packed-decode story.

Every engine gets the **same KV byte budget** (serve/kv_cache.page_nbytes):
the contiguous engine spends it on whole-sequence slots; paged engines get
per-dtype page counts — int8 pages cost less than bf16 and packed int4
pages cost less again, so the sub-4-bit cells hold more pages, admit wider,
and preempt less at identical memory.  That is the headline
``mixed/paged/int4 + q3-outlier weights`` vs ``mixed/paged/bf16 dense``
comparison: the whole sub-4-bit artifact (3-bit outlier-aware weights,
int4-packed KV pages) against the bf16 baseline at equal bytes.

Weights cells: ``dense`` bf16; ``q3_outlier`` — 3-bit RTN with a COO
outlier correction (the QuantEase Algorithm-3 artifact *layout*; serving
perf is weight-value independent so RTN stands in for the solver);
``q4`` — packed 4-bit, run through the roofline weight-layout decision
(serve/qparams.prepack_params_for_serving; the chosen label is recorded
per cell).

Schema 3 adds the **bursty SLO trace** section (``doc["bursty"]``): a
seeded trace of Poisson-burst arrivals with long-tail (lognormal) prompt
lengths, per-request deadlines calibrated against the engine's own
measured step costs, and mixed priorities, driven through the paged engine
under real pool pressure once per scheduler (``fifo`` — the legacy
arrival-order/preempt-newest baseline — and ``slo``).  Each row records
p50/p99 TTFT (from the engine's own request timestamps) and the
**deadline-miss rate**: the fraction of requests that did not deliver
their full output within deadline, counting shed / expired requests and
late completions alike, so the two schedulers are scored by the identical
rule.

Schema 4 adds the **speculative decoding** section (``doc["spec"]``,
DESIGN.md §Speculative-serving): a deep q4 target served at max_batch=1
(the latency regime speculation exists for) with truncated-layer
self-drafts (serve/spec.truncate_draft — the first k periods of the *same*
quantized artifact, zero extra weight memory) at several γ, next to a
non-speculative baseline run at the **identical page count** (equal KV
byte budget — draft pages come out of the same pool).  Each row records
the draft acceptance rate, tokens/s, the paired baseline tokens/s, and
``token_identical`` — whether the speculative outputs matched the
baseline outputs token-for-token, the §Speculative-serving invariant.
The bench weights are synthetic (random init), which is *adversarial* to
truncated-layer drafting — real trained transformers concentrate their
function in early layers and contribute decaying residual updates later
— so the spec model applies a per-period decay λ^i to each period's
output projections before quantization, the same
synthetic-stands-in-for-trained modeling choice as RTN standing in for
the solver elsewhere in this bench.

Emits ``BENCH_serve.json``; ``--smoke`` runs a seconds-scale subset with
the same schema (CI guards the file shape, not the numbers);
``--validate`` checks an existing file and exits non-zero on
malformed/missing — on full (non-smoke) documents it also enforces the
acceptance orderings: the int4+quantized-weights cell beats the bf16
paged baseline on tokens/s with TTFT no worse (5% jitter allowance), the
SLO scheduler's deadline-miss rate is no worse than FIFO's on the same
trace, every speculative row is token-identical to its baseline, and at
least one speculative cell reaches acceptance ≥ 0.6 with tokens/s at or
above its equal-byte-budget baseline.  Mirrors benchmarks/bench_solver.py
conventions.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

SCHEMA = 4
_SERVE_KEYS = {
    "scenario", "engine", "kv", "weights", "weight_layout", "max_batch",
    "kv_budget_tokens", "kv_budget_bytes", "n_pages", "n_requests",
    "new_tokens", "wall_s", "tokens_per_s", "ttft_mean_ms", "ttft_p90_ms",
    "prefill_tokens", "prefix_hit_tokens", "preemptions",
    "kv_bytes_per_token_pred", "kv_bytes_per_token_meas",
}
_BURSTY_KEYS = {
    "scenario", "engine", "kv", "weights", "scheduler", "max_batch",
    "n_pages", "n_requests", "new_tokens", "wall_s", "tokens_per_s",
    "ttft_p50_ms", "ttft_p99_ms", "deadline_miss_rate", "n_completed",
    "n_preempted_resumed", "n_shed", "n_deadline_missed", "n_preemptions",
}
_SPEC_KEYS = {
    "scenario", "engine", "kv", "weights", "draft", "gamma", "max_batch",
    "n_pages", "n_requests", "new_tokens", "wall_s", "tokens_per_s",
    "acceptance_rate", "n_spec_rounds", "n_draft_tokens", "n_draft_accepted",
    "baseline_tokens_per_s", "speedup_vs_baseline", "token_identical",
}


def _bench_model(smoke: bool):
    import jax

    from repro.configs import get_config
    from repro.launch.train import reduced
    from repro.models import init_params, make_plan

    cfg = reduced(get_config("stablelm_12b"))
    if smoke:
        import dataclasses

        cfg = dataclasses.replace(cfg, d_model=64, head_dim=16, d_ff=128)
    plans = {
        "bf16": make_plan(cfg, 1),
        "int8": make_plan(cfg, 1, kv_cache_dtype="int8"),
        "int4": make_plan(cfg, 1, kv_cache_dtype="int4"),
    }
    params = init_params(plans["bf16"], jax.random.PRNGKey(0))
    return cfg, plans, params


def _quantize_weights(plan, params, *, bits, outlier_frac=0.0):
    """RTN artifact in the serving QT layout (moved to serve/qparams).

    Serving perf is weight-value independent, so direct per-channel RTN
    (:func:`repro.serve.qparams.rtn_quantize_for_serving`) stands in for
    the PTQ solver; the bench only needs the artifact's byte layout.
    Returns ``(params, layout_label)``.
    """
    from repro.serve.qparams import rtn_quantize_for_serving

    return rtn_quantize_for_serving(plan, params, bits=bits,
                                    outlier_frac=outlier_frac)


def _spec_bench_model(smoke: bool, lam: float = 0.3):
    """Deep decayed-residual target for the speculative cells.

    Truncated-layer self-drafting bets that a prefix of the stack already
    predicts the full stack's argmax most of the time.  Random-init weights
    are *adversarial* to that bet — every layer contributes an equal-scale
    i.i.d. residual update, so dropping half the stack decorrelates the
    logits — whereas trained transformers concentrate their function early
    and contribute decaying residual updates later (the reason
    layer-skip/early-exit drafting works at all).  To make the synthetic
    bench model that shape rather than the adversarial one, each period
    i's *output* projections (attention ``wo``, MLP ``wd`` — the writes
    into the residual stream) are scaled by ``lam**i`` before
    quantization.  Same modeling spirit as RTN standing in for the solver:
    the bench measures the serving machinery, not model quality.
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch.train import reduced
    from repro.models import init_params, make_plan

    cfg = reduced(get_config("stablelm_12b"))
    cfg = dataclasses.replace(cfg, n_periods=2 if smoke else 4)
    if smoke:
        cfg = dataclasses.replace(cfg, d_model=64, head_dim=16, d_ff=128)
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    scale = (lam ** np.arange(cfg.n_periods)).astype(np.float32)
    dec = {}
    for key, blk in params["dec"].items():
        blk = dict(blk)
        for name in ("wo", "wd"):
            if name in blk:
                w = np.asarray(blk[name])
                blk[name] = jax.numpy.asarray(
                    w * scale.reshape((-1,) + (1,) * (w.ndim - 1))
                )
        dec[key] = blk
    return cfg, plan, dict(params, dec=dec)


def _collect_spec(smoke: bool) -> list:
    """The ``doc["spec"]`` rows: q4 target at max_batch=1, truncated
    self-drafts vs a non-speculative baseline at the identical page count
    (equal KV byte budget — draft pages live in the same pool)."""
    import numpy as np

    from repro.serve.engine import PagedServingEngine, Request
    from repro.serve.spec import SpecConfig, truncate_draft

    cfg, plan, params = _spec_bench_model(smoke)
    q4_params, _ = _quantize_weights(plan, params, bits=4)
    if smoke:
        max_seq, page_size, chunk, n_req, max_new = 64, 8, 16, 2, 6
        cells = [("trunc1", 1, 2)]
    else:
        max_seq, page_size, chunk, n_req, max_new = 256, 16, 32, 6, 32
        cells = [("trunc2", 2, 2), ("trunc2", 2, 3), ("trunc1", 1, 3)]
    # Draft pages come from the same pool, so the budget is set once and
    # shared: room for every lane's target pages plus the transient draft
    # lookahead (§Speculative-serving degradation keeps it honest anyway).
    n_pages = 1 + 2 * (max_seq // page_size)

    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(8, max(9, max_seq - max_new - 8),
                                     size=n_req)]

    def serve(spec):
        eng = PagedServingEngine(
            plan, q4_params, max_batch=1, max_seq=max_seq,
            page_size=page_size, prefill_chunk=chunk, n_pages=n_pages,
            spec=spec,
        )
        # Warm every executable on this instance: normal rounds, the
        # zero-budget legacy single-decode branch (a max_new=1 request),
        # and the COW guard-copy path (duplicate prompts share
        # prefix-cache pages).  Warm prompts come from a disjoint seed.
        wrng = np.random.default_rng(10_001)
        warm = [wrng.integers(1, cfg.vocab, size=40 + i).astype(np.int32)
                for i in range(3)]
        for i, p in enumerate(warm):
            eng.submit(Request(rid=-1 - i, prompt=p[: max_seq - 16],
                               max_new_tokens=min(12, max_new)))
        eng.submit(Request(rid=-8, prompt=warm[0][: page_size + 4].copy(),
                           max_new_tokens=1))
        dup = warm[1][: 2 * page_size + 1].copy()  # ≥1 full page to share
        eng.submit(Request(rid=-9, prompt=dup, max_new_tokens=2))
        eng.submit(Request(rid=-10, prompt=dup.copy(), max_new_tokens=2))
        eng.run()
        eng.finished.clear()
        eng.n_spec_rounds = eng.n_draft_tokens = eng.n_draft_accepted = 0
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        outs = {r.rid: list(r.output) for r in eng.finished if r.rid >= 0}
        return eng, wall, outs

    def row(name, gamma, eng, wall, identical, base_tps=None):
        new_tokens = sum(len(r.output) for r in eng.finished if r.rid >= 0)
        tps = round(new_tokens / wall, 1)
        acc = eng.acceptance_rate()
        return {
            "scenario": "latency",
            "engine": "paged",
            "kv": "bf16",
            "weights": "q4_decayed",
            "draft": name,
            "gamma": gamma,
            "max_batch": 1,
            "n_pages": n_pages,
            "n_requests": len(prompts),
            "new_tokens": new_tokens,
            "wall_s": round(wall, 4),
            "tokens_per_s": tps,
            "acceptance_rate": None if acc is None else round(acc, 4),
            "n_spec_rounds": eng.n_spec_rounds,
            "n_draft_tokens": eng.n_draft_tokens,
            "n_draft_accepted": eng.n_draft_accepted,
            "baseline_tokens_per_s": base_tps if base_tps is not None else tps,
            "speedup_vs_baseline": round(tps / base_tps, 2)
            if base_tps is not None else 1.0,
            "token_identical": identical,
        }

    base_eng, base_wall, base_outs = serve(None)
    rows = [row("none", 0, base_eng, base_wall, True)]
    base_tps = rows[0]["tokens_per_s"]
    for name, k, gamma in cells:
        dplan, dparams = truncate_draft(plan, q4_params, k)
        eng, wall, outs = serve(
            SpecConfig(draft_plan=dplan, draft_params=dparams, gamma=gamma)
        )
        rows.append(row(name, gamma, eng, wall, outs == base_outs, base_tps))
    return rows


def _requests(cfg, scenario: str, n: int, max_prompt: int, max_new: int):
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(7)
    reqs = []
    sys_prompt = rng.integers(0, cfg.vocab, max_prompt // 2).astype(np.int32)
    for i in range(n):
        if scenario == "shared_prefix":
            tail = rng.integers(0, cfg.vocab, rng.integers(4, max_prompt // 4))
            prompt = np.concatenate([sys_prompt, tail.astype(np.int32)])
        else:  # mixed prompt lengths
            prompt = rng.integers(0, cfg.vocab, rng.integers(8, max_prompt)).astype(
                np.int32
            )
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drive(eng, reqs, max_steps=100_000):
    """Submit everything up front, step to completion, record per-request
    time-to-first-token against the common start instant."""
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    ttft = {}
    steps = 0
    while (eng.queue or any(s is not None for s in _lanes(eng))) and steps < max_steps:
        if not eng.step():
            break
        now = time.perf_counter()
        for r in reqs:
            if r.rid not in ttft and r.output:
                ttft[r.rid] = now - t0
        steps += 1
    wall = time.perf_counter() - t0
    return wall, [ttft.get(r.rid, wall) for r in reqs]


def _lanes(eng):
    return getattr(eng, "lanes", None) or getattr(eng, "slot_req")


def _bursty_trace(cfg, n, max_prompt, max_new, chunk, costs, seed=11):
    """Seeded bursty SLO trace: ``[(arrival_s, request_kwargs), ...]``.

    Arrival process: exponential inter-burst gaps with geometric burst
    sizes (Poisson bursts); prompt lengths are lognormal (long-tail,
    clipped to the engine bounds).  Deadlines are *calibrated*: each
    request's optimistic service estimate (its own prefill chunks + decode
    steps at the warmed engine's measured per-step costs ``costs =
    (chunk_s, decode_s)``) is multiplied by a sampled tightness factor —
    the tight tail is infeasible under queueing, the loose tail is safe —
    so the trace stresses the scheduler identically on any host speed.
    Returns kwargs (not Request objects): each scheduler run materializes
    its own fresh requests from the same trace.
    """
    import numpy as np

    chunk_s, decode_s = costs
    rng = np.random.default_rng(seed)
    trace = []
    t, i = 0.0, 0
    # Mean inter-burst gap ≈ half a typical request's service time: bursts
    # overlap enough to contend for the pool without unbounded backlog.
    typical = max(2, 16 // chunk + 1) * chunk_s + max_new * decode_s
    while i < n:
        t += float(rng.exponential(typical * 0.5))
        burst = 1 + int(rng.geometric(0.45))
        for _ in range(min(burst, n - i)):
            ln = int(np.clip(rng.lognormal(np.log(16.0), 0.9), 4, max_prompt))
            est = (-(-ln // chunk)) * chunk_s + max_new * decode_s
            tightness = float(rng.choice([1.2, 2.5, 6.0, 15.0],
                                         p=[0.2, 0.35, 0.3, 0.15]))
            trace.append((t, dict(
                rid=i,
                prompt=rng.integers(0, cfg.vocab, ln).astype(np.int32),
                max_new_tokens=max_new,
                deadline_ms=est * tightness * 1e3,
                priority=int(rng.choice([0, 0, 0, 1, 2])),
            )))
            i += 1
    return trace


def _drive_trace(eng, trace, max_steps=200_000):
    """Submit requests at their trace arrival instants (engine wall clock)
    and step to completion.  Returns ``(wall_s, requests)``; per-request
    latency comes from the engine's own submit/first-token/finish
    timestamps, not from this loop."""
    from repro.serve.engine import Request

    reqs = [Request(**kw) for _, kw in trace]
    pending = list(zip([a for a, _ in trace], reqs))
    t0 = time.perf_counter()
    steps = 0
    while pending or eng.queue or any(s is not None for s in _lanes(eng)):
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending.pop(0)[1])
        if not (eng.queue or any(s is not None for s in _lanes(eng))):
            time.sleep(max(0.0, pending[0][0] - now))
            continue
        eng.step()
        steps += 1
        if steps >= max_steps:
            break
    return time.perf_counter() - t0, reqs


def _bursty_row(scheduler, eng, reqs, wall):
    """Score one scheduler run.  A request *missed* its deadline when it
    did not deliver its full output in time — shed and expired requests
    by definition, plus any completion that landed after the deadline —
    the same rule for both schedulers (FIFO ignores deadlines at run
    time, so all its misses are late/unfinished completions)."""
    import numpy as np

    ttfts = [r.first_token_t - r.submit_t for r in reqs
             if r.first_token_t is not None and r.submit_t is not None]
    missed = 0
    for r in reqs:
        if r.status in ("shed", "deadline_missed"):
            missed += 1
        elif r.deadline_ms is not None and (
            r.finish_t is None
            or r.finish_t - r.submit_t > r.deadline_ms / 1e3
        ):
            missed += 1
    new_tokens = sum(len(r.output or []) for r in reqs)
    return {
        "scenario": "bursty",
        "engine": "paged",
        "kv": "bf16",
        "weights": "dense",
        "scheduler": scheduler,
        "max_batch": eng.max_batch,
        "n_pages": eng.n_pages,
        "n_requests": len(reqs),
        "new_tokens": new_tokens,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(new_tokens / wall, 1),
        "ttft_p50_ms": round(float(np.percentile(ttfts, 50)) * 1e3, 1)
        if ttfts else None,
        "ttft_p99_ms": round(float(np.percentile(ttfts, 99)) * 1e3, 1)
        if ttfts else None,
        "deadline_miss_rate": round(missed / len(reqs), 4),
        "n_completed": sum(r.status == "completed" for r in reqs),
        "n_preempted_resumed": sum(r.status == "preempted_resumed" for r in reqs),
        "n_shed": sum(r.status == "shed" for r in reqs),
        "n_deadline_missed": sum(r.status == "deadline_missed" for r in reqs),
        "n_preemptions": eng.n_preemptions,
    }


def _row(scenario, engine_name, kv, weights, layout, eng, reqs, wall, ttfts,
         budget, budget_bytes, kv_pred):
    import numpy as np

    new_tokens = sum(len(r.output) for r in reqs)
    meas = None
    if hasattr(eng, "kv_read_bytes") and new_tokens:
        meas = round(eng.kv_read_bytes() / new_tokens, 1)
    return {
        "scenario": scenario,
        "engine": engine_name,
        "kv": kv,
        "weights": weights,
        "weight_layout": layout,
        "max_batch": eng.max_batch,
        "kv_budget_tokens": budget,
        "kv_budget_bytes": budget_bytes,
        "n_pages": getattr(eng, "n_pages", 0),
        "n_requests": len(reqs),
        "new_tokens": new_tokens,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(new_tokens / wall, 1),
        "ttft_mean_ms": round(float(np.mean(ttfts)) * 1e3, 1),
        "ttft_p90_ms": round(float(np.percentile(ttfts, 90)) * 1e3, 1),
        "prefill_tokens": getattr(eng, "n_prefill_tokens", 0),
        "prefix_hit_tokens": getattr(eng, "n_prefix_hit_tokens", 0),
        "preemptions": getattr(eng, "n_preemptions", 0),
        "kv_bytes_per_token_pred": kv_pred,
        "kv_bytes_per_token_meas": meas,
    }


def collect(smoke: bool) -> dict:
    import jax

    from repro.roofline.analysis import paged_kv_bytes_per_token
    from repro.serve.engine import PagedServingEngine, ServingEngine
    from repro.serve.kv_cache import page_nbytes

    cfg, plans, params = _bench_model(smoke)
    if smoke:
        max_seq, page_size, chunk = 64, 8, 16
        contig_batch, paged_batch = 2, 4
        n_req, max_prompt, max_new = 4, 24, 4
    else:
        # contig_batch=2 sets the shared byte budget at 512 KV tokens — about
        # 30% of the 16-lane paged working set (~116 bf16-equivalent pages),
        # so the bf16 pool visibly thrashes while the int4 pool (~3x the
        # pages at equal bytes) holds nearly the whole workload: the
        # memory-capacity story the sub-4-bit cells exist for.
        max_seq, page_size, chunk = 256, 16, 64
        contig_batch, paged_batch = 2, 16
        n_req, max_prompt, max_new = 32, 160, 32
    hp = plans["bf16"].heads
    budget = contig_batch * max_seq  # KV tokens the bf16 baseline may hold
    # Equal-BYTE budget across kv dtypes: the bf16 pool's allocatable bytes,
    # re-divided by each dtype's true page cost (serve/kv_cache.page_nbytes)
    # — cheaper pages ⇒ more pages at identical memory, which is the entire
    # sub-4-bit serving story.
    budget_bytes = (budget // page_size) * page_nbytes(
        page_size, hp.kv_pad, hp.head_dim, cfg.n_periods, "bf16"
    )
    n_pages = {
        kv: 1 + budget_bytes // page_nbytes(
            page_size, hp.kv_pad, hp.head_dim, cfg.n_periods, kv
        )
        for kv in ("bf16", "int8", "int4")
    }

    q3_params, q3_layout = _quantize_weights(
        plans["bf16"], params, bits=3, outlier_frac=0.01
    )
    q4_params, q4_layout = _quantize_weights(plans["bf16"], params, bits=4)
    weight_sets = {
        "dense": (params, "dense"),
        "q3_outlier": (q3_params, q3_layout),
        "q4": (q4_params, q4_layout),
    }

    def contiguous(kv, weights):
        return ServingEngine(
            plans[kv], weight_sets[weights][0], max_batch=contig_batch,
            max_seq=max_seq, prefill_pad=chunk,
        )

    def paged(kv, weights):
        return PagedServingEngine(
            plans[kv], weight_sets[weights][0], max_batch=paged_batch,
            max_seq=max_seq, page_size=page_size, n_pages=n_pages[kv],
            prefill_chunk=chunk,
        )

    cells = [
        ("mixed", "contiguous", "bf16", "dense"),
        ("mixed", "paged", "bf16", "dense"),
        ("mixed", "paged", "int8", "dense"),
        ("mixed", "paged", "int4", "dense"),
        ("mixed", "paged", "int4", "q3_outlier"),  # the sub-4-bit headline
        ("mixed", "paged", "bf16", "q4"),  # roofline-selected weight layout
        ("shared_prefix", "contiguous", "bf16", "dense"),
        ("shared_prefix", "paged", "bf16", "dense"),
    ]
    def warm_engine(eng):
        # Warm every executable on the SAME instance (jit caches live on the
        # engine's jitted closures): prompts long enough to cross chunk and
        # page boundaries, then drain so the engine returns to idle.  Warmup
        # prompts are drawn from a disjoint seed so they never seed the
        # prefix cache for the measured workload.
        import numpy as np

        from repro.serve.engine import Request

        wrng = np.random.default_rng(10_001)
        warm = [
            Request(rid=-1 - i,
                    prompt=wrng.integers(cfg.vocab // 2, cfg.vocab,
                                         max_prompt - 1 - i).astype(np.int32),
                    max_new_tokens=2)
            for i in range(2)
        ]
        _drive(eng, warm)
        eng.finished.clear()
        for attr in ("n_decode_steps", "n_prefills", "n_prefill_chunks",
                     "n_prefill_tokens", "n_prefix_hit_tokens", "n_cow_hits",
                     "n_guard_copies", "n_preemptions", "n_kv_page_reads",
                     "n_shed", "n_deadline_missed"):
            if hasattr(eng, attr):
                setattr(eng, attr, 0)

    rows = []
    for scenario, name, kv, weights in cells:
        import numpy as np

        eng = contiguous(kv, weights) if name == "contiguous" else paged(kv, weights)
        warm_engine(eng)
        reqs = _requests(cfg, scenario, n_req, max_prompt, max_new)
        # Roofline prediction at the workload's mean decode context: prompt
        # plus half the generation, in pages (the gather reads whole pages).
        ctx = float(np.mean([len(r.prompt) + max_new / 2 for r in reqs]))
        kv_pred = round(paged_kv_bytes_per_token(
            page_size, hp.kv_pad, hp.head_dim, cfg.n_periods,
            kv_dtype=kv, context_pages=-(-ctx // page_size),
        ), 1) if name == "paged" else None
        wall, ttfts = _drive(eng, reqs)
        rows.append(_row(scenario, name, kv, weights,
                         weight_sets[weights][1], eng, reqs, wall, ttfts,
                         budget, budget_bytes, kv_pred))
    by = {(r["scenario"], r["engine"], r["kv"], r["weights"]): r for r in rows}
    for r in rows:
        if r["engine"] == "paged":
            base = by.get((r["scenario"], "contiguous", "bf16", "dense"))
            if base:
                r["speedup_vs_contiguous"] = round(
                    r["tokens_per_s"] / base["tokens_per_s"], 2
                )

    # Bursty SLO trace: the identical seeded trace driven once per
    # scheduler through a deliberately tight pool (bursts contend for
    # pages, so preemption/shedding policy decides who makes the deadline).
    if smoke:
        b_req, b_new, b_batch, b_pages = 6, 4, 4, 1 + 8
    else:
        b_req, b_new, b_batch, b_pages = 40, 24, 8, 1 + 28
    bursty_rows = []
    trace = None
    for scheduler in ("fifo", "slo"):
        eng = PagedServingEngine(
            plans["bf16"], params, max_batch=b_batch, max_seq=max_seq,
            page_size=page_size, n_pages=b_pages, prefill_chunk=chunk,
            scheduler=scheduler,
        )
        warm_engine(eng)
        if trace is None:
            # Deadlines calibrated against this host's measured step costs
            # (populated by the warm run) — identical trace for both rows.
            costs = (eng._min_chunk_s or 1e-4, eng._min_decode_s or 1e-4)
            trace = _bursty_trace(cfg, b_req, max_prompt, b_new, chunk, costs)
        wall, treqs = _drive_trace(eng, trace)
        bursty_rows.append(_bursty_row(scheduler, eng, treqs, wall))

    return {
        "schema": SCHEMA,
        "smoke": smoke,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "serve": rows,
        "bursty": bursty_rows,
        "spec": _collect_spec(smoke),
    }


def validate(path: str) -> list[str]:
    """Returns a list of problems; empty means the file is well-formed."""
    if not os.path.exists(path):
        return [f"{path}: missing"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    probs = []
    if doc.get("schema") != SCHEMA:
        probs.append(f"schema != {SCHEMA}")
    rows = doc.get("serve")
    if not isinstance(rows, list) or not rows:
        probs.append("serve: missing/empty")
        return probs
    for i, row in enumerate(rows):
        missing = _SERVE_KEYS - set(row)
        if missing:
            probs.append(f"serve[{i}]: missing keys {sorted(missing)}")
    engines = {r.get("engine") for r in rows}
    if not {"contiguous", "paged"} <= engines:
        probs.append("serve: needs both contiguous and paged rows")
    by = {(r.get("scenario"), r.get("engine"), r.get("kv"), r.get("weights")): r
          for r in rows}
    if not any(r.get("kv") == "int4" for r in rows):
        probs.append("serve: missing int4-KV cell")
    if not any(r.get("weights") not in (None, "dense") for r in rows):
        probs.append("serve: missing packed-weight cell")
    bursty = doc.get("bursty")
    if not isinstance(bursty, list) or not bursty:
        probs.append("bursty: missing/empty")
        bursty = []
    for i, row in enumerate(bursty):
        missing = _BURSTY_KEYS - set(row)
        if missing:
            probs.append(f"bursty[{i}]: missing keys {sorted(missing)}")
    scheds = {r.get("scheduler") for r in bursty}
    if bursty and not {"fifo", "slo"} <= scheds:
        probs.append("bursty: needs both fifo and slo scheduler rows")
    spec = doc.get("spec")
    if not isinstance(spec, list) or not spec:
        probs.append("spec: missing/empty")
        spec = []
    for i, row in enumerate(spec):
        missing = _SPEC_KEYS - set(row)
        if missing:
            probs.append(f"spec[{i}]: missing keys {sorted(missing)}")
    spec_rows = [r for r in spec if r.get("draft") not in (None, "none")]
    if spec and not spec_rows:
        probs.append("spec: needs at least one speculative (draft != none) row")
    for r in spec_rows:
        # Token identity is the §Speculative-serving invariant — it holds
        # on every row (smoke included), not just the fast ones.
        if r.get("token_identical") is not True:
            probs.append(
                f"spec {r.get('draft')}/γ={r.get('gamma')}: output not "
                "token-identical to the non-speculative baseline"
            )
    if not doc.get("smoke"):
        # Acceptance ordering on the committed full trajectory: the whole
        # sub-4-bit artifact beats the bf16 paged baseline on tokens/s at
        # equal KV bytes, with TTFT no worse (5% timer-jitter allowance).
        base = by.get(("mixed", "paged", "bf16", "dense"))
        head = by.get(("mixed", "paged", "int4", "q3_outlier"))
        if base is None or head is None:
            probs.append("serve: missing mixed/paged bf16-dense or "
                         "int4-q3_outlier cell")
        else:
            if head["tokens_per_s"] < base["tokens_per_s"]:
                probs.append(
                    f"int4+q3_outlier tokens/s ({head['tokens_per_s']}) below "
                    f"bf16 paged baseline ({base['tokens_per_s']})"
                )
            if head["ttft_mean_ms"] > 1.05 * base["ttft_mean_ms"]:
                probs.append(
                    f"int4+q3_outlier ttft ({head['ttft_mean_ms']}ms) worse "
                    f"than bf16 baseline ({base['ttft_mean_ms']}ms)"
                )
        # SLO acceptance: on the identical bursty trace, the SLO scheduler
        # must not miss more deadlines than the FIFO baseline.
        b_by = {r.get("scheduler"): r for r in bursty}
        fifo, slo = b_by.get("fifo"), b_by.get("slo")
        if fifo is None or slo is None:
            probs.append("bursty: missing fifo or slo row")
        elif slo["deadline_miss_rate"] > fifo["deadline_miss_rate"]:
            probs.append(
                f"slo deadline-miss rate ({slo['deadline_miss_rate']}) worse "
                f"than fifo baseline ({fifo['deadline_miss_rate']})"
            )
        # Speculative acceptance: some committed cell must show speculation
        # actually paying — acceptance ≥ 0.6 AND tokens/s at or above the
        # non-speculative baseline at the identical page budget.
        if not any(
            (r.get("acceptance_rate") or 0.0) >= 0.6
            and r.get("tokens_per_s", 0) >= r.get("baseline_tokens_per_s", 1e9)
            for r in spec_rows
        ):
            probs.append(
                "spec: no cell with acceptance >= 0.6 and tokens/s >= the "
                "non-speculative baseline at equal KV byte budget"
            )
    return probs


def run(csv):
    """benchmarks/run.py entry point: measure, write BENCH_serve.json, and
    mirror the headline numbers into the shared CSV.

    Under BENCH_FAST=1 the smoke subset is measured and written to
    ``BENCH_serve_smoke.json`` instead — the committed full trajectory
    must only ever be overwritten by full-budget runs.
    """
    smoke = os.environ.get("BENCH_FAST", "0") == "1"
    doc = collect(smoke=smoke)
    name = "BENCH_serve_smoke.json" if smoke else "BENCH_serve.json"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", name)
    with open(os.path.normpath(out), "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["serve"]:
        csv.add(
            f"serve_{row['scenario']}_{row['engine']}_{row['kv']}",
            us=round(1e6 / max(row["tokens_per_s"], 1e-9), 1),
            tokens_per_s=row["tokens_per_s"],
            ttft_ms=row["ttft_mean_ms"],
        )
    for row in doc["bursty"]:
        csv.add(
            f"serve_bursty_{row['scheduler']}",
            us=round(1e6 / max(row["tokens_per_s"], 1e-9), 1),
            tokens_per_s=row["tokens_per_s"],
            ttft_ms=row["ttft_p50_ms"],
            miss_rate=row["deadline_miss_rate"],
        )
    for row in doc["spec"]:
        csv.add(
            f"serve_spec_{row['draft']}_g{row['gamma']}",
            us=round(1e6 / max(row["tokens_per_s"], 1e-9), 1),
            tokens_per_s=row["tokens_per_s"],
            acceptance=row["acceptance_rate"],
            speedup=row["speedup_vs_baseline"],
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="seconds-scale subset")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--validate", metavar="PATH", help="check an existing file")
    args = ap.parse_args()
    if args.validate:
        probs = validate(args.validate)
        for pr in probs:
            print(f"INVALID: {pr}", file=sys.stderr)
        print(f"{args.validate}: {'FAIL' if probs else 'ok'}")
        sys.exit(1 if probs else 0)
    doc = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["serve"]:
        extra = (
            f" ({row['speedup_vs_contiguous']}x vs contiguous)"
            if "speedup_vs_contiguous" in row
            else ""
        )
        bpt = (
            f", kv B/tok {row['kv_bytes_per_token_pred']} pred"
            f" / {row['kv_bytes_per_token_meas']} meas"
            if row["kv_bytes_per_token_pred"] is not None
            else ""
        )
        print(
            f"{row['scenario']:>14} {row['engine']:>10} {row['kv']}"
            f"/{row['weights']}[{row['weight_layout']}]: "
            f"{row['tokens_per_s']} tok/s, ttft {row['ttft_mean_ms']}ms "
            f"(p90 {row['ttft_p90_ms']}ms), prefill {row['prefill_tokens']} tok, "
            f"prefix-hit {row['prefix_hit_tokens']}{bpt}{extra}"
        )
    for row in doc["bursty"]:
        print(
            f"{'bursty':>14} {'paged':>10} [{row['scheduler']:>4}]: "
            f"{row['tokens_per_s']} tok/s, ttft p50 {row['ttft_p50_ms']}ms "
            f"p99 {row['ttft_p99_ms']}ms, miss-rate "
            f"{row['deadline_miss_rate']} ({row['n_completed']} completed, "
            f"{row['n_preempted_resumed']} resumed, {row['n_shed']} shed, "
            f"{row['n_deadline_missed']} expired, "
            f"{row['n_preemptions']} preemptions)"
        )
    for row in doc["spec"]:
        acc = row["acceptance_rate"]
        print(
            f"{'spec':>14} {'paged':>10} [{row['draft']:>6} γ={row['gamma']}]: "
            f"{row['tokens_per_s']} tok/s "
            f"({row['speedup_vs_baseline']}x vs non-spec), acceptance "
            f"{'-' if acc is None else acc}, identical={row['token_identical']}"
        )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    main()
