"""BENCH_serve — throughput / latency trajectory of the serving engines.

Serves batches of greedy-decode requests on a small random-init decoder
(serving perf is weight-value independent) and measures, per (scenario,
engine, kv-dtype) cell:

  * **tokens/s** — generated tokens over wall-clock from first submit to
    batch completion (prefill + decode + scheduling, everything included),
  * **TTFT** — per-request time-to-first-token (mean + p90), which is where
    chunked prefill and wider paged admission show up,
  * engine counters: prefill chunks/tokens, prefix-cache hit tokens,
    preemptions.

The paged and contiguous engines get the **same KV token budget**; the
contiguous engine spends it on ``budget / max_seq`` whole-sequence slots
while the paged engine spends it on pages — more concurrent lanes for the
same memory, which is the paged throughput story (plus prefix-cache prefill
savings in the shared-prefix scenario).

Scenarios: ``mixed`` (uniform random prompt lengths — the acceptance
workload: paged ≥ 1.5× contiguous tokens/s), ``shared_prefix`` (a common
system prompt + unique tails) and a ``mixed`` int8-KV variant.

Emits ``BENCH_serve.json``; ``--smoke`` runs a seconds-scale subset with
the same schema (CI guards the file shape, not the numbers);
``--validate`` checks an existing file and exits non-zero on
malformed/missing.  Mirrors benchmarks/bench_solver.py conventions.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

SCHEMA = 1
_SERVE_KEYS = {
    "scenario", "engine", "kv", "max_batch", "kv_budget_tokens", "n_requests",
    "new_tokens", "wall_s", "tokens_per_s", "ttft_mean_ms", "ttft_p90_ms",
    "prefill_tokens", "prefix_hit_tokens", "preemptions",
}


def _bench_model(smoke: bool):
    import jax

    from repro.configs import get_config
    from repro.launch.train import reduced
    from repro.models import init_params, make_plan

    cfg = reduced(get_config("stablelm_12b"))
    if smoke:
        import dataclasses

        cfg = dataclasses.replace(cfg, d_model=64, head_dim=16, d_ff=128)
    plans = {
        "bf16": make_plan(cfg, 1),
        "int8": make_plan(cfg, 1, kv_cache_dtype="int8"),
    }
    params = init_params(plans["bf16"], jax.random.PRNGKey(0))
    return cfg, plans, params


def _requests(cfg, scenario: str, n: int, max_prompt: int, max_new: int):
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(7)
    reqs = []
    sys_prompt = rng.integers(0, cfg.vocab, max_prompt // 2).astype(np.int32)
    for i in range(n):
        if scenario == "shared_prefix":
            tail = rng.integers(0, cfg.vocab, rng.integers(4, max_prompt // 4))
            prompt = np.concatenate([sys_prompt, tail.astype(np.int32)])
        else:  # mixed prompt lengths
            prompt = rng.integers(0, cfg.vocab, rng.integers(8, max_prompt)).astype(
                np.int32
            )
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drive(eng, reqs, max_steps=100_000):
    """Submit everything up front, step to completion, record per-request
    time-to-first-token against the common start instant."""
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    ttft = {}
    steps = 0
    while (eng.queue or any(s is not None for s in _lanes(eng))) and steps < max_steps:
        if not eng.step():
            break
        now = time.perf_counter()
        for r in reqs:
            if r.rid not in ttft and r.output:
                ttft[r.rid] = now - t0
        steps += 1
    wall = time.perf_counter() - t0
    return wall, [ttft.get(r.rid, wall) for r in reqs]


def _lanes(eng):
    return getattr(eng, "lanes", None) or getattr(eng, "slot_req")


def _row(scenario, engine_name, kv, eng, reqs, wall, ttfts, budget):
    import numpy as np

    new_tokens = sum(len(r.output) for r in reqs)
    return {
        "scenario": scenario,
        "engine": engine_name,
        "kv": kv,
        "max_batch": eng.max_batch,
        "kv_budget_tokens": budget,
        "n_requests": len(reqs),
        "new_tokens": new_tokens,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(new_tokens / wall, 1),
        "ttft_mean_ms": round(float(np.mean(ttfts)) * 1e3, 1),
        "ttft_p90_ms": round(float(np.percentile(ttfts, 90)) * 1e3, 1),
        "prefill_tokens": getattr(eng, "n_prefill_tokens", 0),
        "prefix_hit_tokens": getattr(eng, "n_prefix_hit_tokens", 0),
        "preemptions": getattr(eng, "n_preemptions", 0),
    }


def collect(smoke: bool) -> dict:
    import jax

    from repro.serve.engine import PagedServingEngine, ServingEngine

    cfg, plans, params = _bench_model(smoke)
    if smoke:
        max_seq, page_size, chunk = 64, 8, 16
        contig_batch, paged_batch = 2, 4
        n_req, max_prompt, max_new = 4, 24, 4
    else:
        max_seq, page_size, chunk = 256, 16, 64
        contig_batch, paged_batch = 4, 16
        n_req, max_prompt, max_new = 32, 160, 32
    budget = contig_batch * max_seq  # KV tokens both engines may hold
    n_pages = 1 + budget // page_size

    def contiguous(plan):
        return ServingEngine(
            plan, params, max_batch=contig_batch, max_seq=max_seq,
            prefill_pad=chunk,
        )

    def paged(plan, prefix_cache=True):
        return PagedServingEngine(
            plan, params, max_batch=paged_batch, max_seq=max_seq,
            page_size=page_size, n_pages=n_pages, prefill_chunk=chunk,
            prefix_cache=prefix_cache,
        )

    cells = [
        ("mixed", "contiguous", "bf16", lambda: contiguous(plans["bf16"])),
        ("mixed", "paged", "bf16", lambda: paged(plans["bf16"])),
        ("mixed", "paged", "int8", lambda: paged(plans["int8"])),
        ("shared_prefix", "contiguous", "bf16", lambda: contiguous(plans["bf16"])),
        ("shared_prefix", "paged", "bf16", lambda: paged(plans["bf16"])),
    ]
    rows = []
    for scenario, name, kv, mk in cells:
        import numpy as np

        from repro.serve.engine import Request

        eng = mk()
        # Warm every executable on the SAME instance (jit caches live on the
        # engine's jitted closures): prompts long enough to cross chunk and
        # page boundaries, then drain so the engine returns to idle.  Warmup
        # prompts are drawn from a disjoint seed so they never seed the
        # prefix cache for the measured workload.
        wrng = np.random.default_rng(10_001)
        warm = [
            Request(rid=-1 - i,
                    prompt=wrng.integers(cfg.vocab // 2, cfg.vocab,
                                         max_prompt - 1 - i).astype(np.int32),
                    max_new_tokens=2)
            for i in range(2)
        ]
        _drive(eng, warm)
        eng.finished.clear()
        for attr in ("n_decode_steps", "n_prefills", "n_prefill_chunks",
                     "n_prefill_tokens", "n_prefix_hit_tokens", "n_cow_hits",
                     "n_guard_copies", "n_preemptions"):
            if hasattr(eng, attr):
                setattr(eng, attr, 0)
        reqs = _requests(cfg, scenario, n_req, max_prompt, max_new)
        wall, ttfts = _drive(eng, reqs)
        rows.append(_row(scenario, name, kv, eng, reqs, wall, ttfts, budget))
    by = {(r["scenario"], r["engine"], r["kv"]): r for r in rows}
    for r in rows:
        if r["engine"] == "paged":
            base = by.get((r["scenario"], "contiguous", "bf16"))
            if base:
                r["speedup_vs_contiguous"] = round(
                    r["tokens_per_s"] / base["tokens_per_s"], 2
                )
    return {
        "schema": SCHEMA,
        "smoke": smoke,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "serve": rows,
    }


def validate(path: str) -> list[str]:
    """Returns a list of problems; empty means the file is well-formed."""
    if not os.path.exists(path):
        return [f"{path}: missing"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    probs = []
    if doc.get("schema") != SCHEMA:
        probs.append(f"schema != {SCHEMA}")
    rows = doc.get("serve")
    if not isinstance(rows, list) or not rows:
        probs.append("serve: missing/empty")
        return probs
    for i, row in enumerate(rows):
        missing = _SERVE_KEYS - set(row)
        if missing:
            probs.append(f"serve[{i}]: missing keys {sorted(missing)}")
    engines = {r.get("engine") for r in rows}
    if not {"contiguous", "paged"} <= engines:
        probs.append("serve: needs both contiguous and paged rows")
    return probs


def run(csv):
    """benchmarks/run.py entry point: measure, write BENCH_serve.json, and
    mirror the headline numbers into the shared CSV.

    Under BENCH_FAST=1 the smoke subset is measured and written to
    ``BENCH_serve_smoke.json`` instead — the committed full trajectory
    must only ever be overwritten by full-budget runs.
    """
    smoke = os.environ.get("BENCH_FAST", "0") == "1"
    doc = collect(smoke=smoke)
    name = "BENCH_serve_smoke.json" if smoke else "BENCH_serve.json"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", name)
    with open(os.path.normpath(out), "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["serve"]:
        csv.add(
            f"serve_{row['scenario']}_{row['engine']}_{row['kv']}",
            us=round(1e6 / max(row["tokens_per_s"], 1e-9), 1),
            tokens_per_s=row["tokens_per_s"],
            ttft_ms=row["ttft_mean_ms"],
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="seconds-scale subset")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--validate", metavar="PATH", help="check an existing file")
    args = ap.parse_args()
    if args.validate:
        probs = validate(args.validate)
        for pr in probs:
            print(f"INVALID: {pr}", file=sys.stderr)
        print(f"{args.validate}: {'FAIL' if probs else 'ok'}")
        sys.exit(1 if probs else 0)
    doc = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["serve"]:
        extra = (
            f" ({row['speedup_vs_contiguous']}x vs contiguous)"
            if "speedup_vs_contiguous" in row
            else ""
        )
        print(
            f"{row['scenario']:>14} {row['engine']:>10} {row['kv']}: "
            f"{row['tokens_per_s']} tok/s, ttft {row['ttft_mean_ms']}ms "
            f"(p90 {row['ttft_p90_ms']}ms), prefill {row['prefill_tokens']} tok, "
            f"prefix-hit {row['prefix_hit_tokens']}{extra}"
        )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    main()
