"""Table 4 — outlier-aware 3-bit quantization.

Paper claim: QuantEase 0.5% outliers < SpQR 1% < plain QuantEase (ppl);
1% does even better; structured (column) outliers sit between plain and
unstructured.
"""

from __future__ import annotations

from benchmarks.common import Csv, calib_batches, perplexity, trained_model
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec


def run(csv: Csv):
    plan, params, batch_fn, _ = trained_model()
    calib = calib_batches(batch_fn)
    spec = GridSpec(bits=3)
    runs = [
        ("plain", PTQConfig(method="quantease", spec=spec, iterations=20)),
        ("spqr_1pct", PTQConfig(method="spqr", spec=spec, outlier_frac=0.01)),
        ("qe_outlier_0.5pct", PTQConfig(method="qe_outlier", spec=spec, iterations=20, outlier_frac=0.005)),
        ("qe_outlier_1pct", PTQConfig(method="qe_outlier", spec=spec, iterations=20, outlier_frac=0.01)),
        ("qe_struct_1pct", PTQConfig(method="qe_outlier_struct", spec=spec, iterations=20, outlier_frac=0.01)),
    ]
    for name, pcfg in runs:
        qp, _ = ptq_quantize_model(plan, params, calib, pcfg)
        csv.add(f"table4_{name}", ppl=round(perplexity(plan, qp, batch_fn), 4))


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.print()
