"""Fig. 3 — effect of the number of QuantEase iterations on perplexity.

Paper claim: more iterations lower perplexity, with diminishing returns;
the 4-bit curve is flatter than the 3-bit curve; ~25 iterations is the
accuracy/runtime sweet spot.
"""

from __future__ import annotations

from benchmarks.common import Csv, calib_batches, perplexity, trained_model
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec


def run(csv: Csv):
    plan, params, batch_fn, _ = trained_model()
    calib = calib_batches(batch_fn)
    for bits in (3, 4):
        for iters in (1, 5, 10, 25):
            qp, rep = ptq_quantize_model(
                plan, params, calib,
                PTQConfig(method="quantease", spec=GridSpec(bits=bits), iterations=iters),
            )
            ppl = perplexity(plan, qp, batch_fn)
            csv.add(f"fig3_bits{bits}_iters{iters}", ppl=round(ppl, 4))


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.print()
