"""Fig. 2 — per-layer relative quantization error, QuantEase vs GPTQ.

Paper claim: QuantEase achieves lower calibration error than GPTQ on almost
every layer, up to 30% relative improvement, median ≈ 12% (3-bit), and
3-bit errors exceed 4-bit errors.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, calib_batches, trained_model
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.quant import GridSpec


def run(csv: Csv):
    plan, params, batch_fn, _ = trained_model()
    calib = calib_batches(batch_fn)
    for bits in (4, 3):
        _, rep_g = ptq_quantize_model(
            plan, params, calib, PTQConfig(method="gptq", spec=GridSpec(bits=bits))
        )
        _, rep_q = ptq_quantize_model(
            plan, params, calib,
            PTQConfig(method="quantease", spec=GridSpec(bits=bits), iterations=20),
        )
        keys = sorted(rep_g)
        g = np.array([rep_g[k] for k in keys])
        q = np.array([rep_q[k] for k in keys])
        imp = (g - q) / np.maximum(g, 1e-12)
        csv.add(
            f"fig2_bits{bits}",
            derived_median_improvement=round(float(np.median(imp)), 4),
            max_improvement=round(float(imp.max()), 4),
            frac_layers_improved=round(float((imp > 0).mean()), 3),
            mean_err_quantease=round(float(q.mean()), 5),
            mean_err_gptq=round(float(g.mean()), 5),
        )


if __name__ == "__main__":
    c = Csv()
    run(c)
    c.print()
