"""BENCH_tune — auto-tuned mixed precision vs uniform at equal average bits.

The committed trajectory for the accuracy-driven per-layer tuner
(repro/tune): probe per-layer sensitivity on the shared benchmark model,
race the greedy budgeted allocations against the uniform baseline at the
same average-bits budget, and score every candidate on the eval split as
the restacked **serving** artifact.  The headline claim — the tuned winner
is never worse than uniform at equal average bits — holds by construction
(uniform is always candidate 0 and the winner is the perplexity argmin), so
``--validate`` enforces it on smoke documents too, alongside the budget
bound and the mixed-precision parity bridge: a genuinely heterogeneous
artifact (every candidate width in one stack, COO outliers attached to a
subset of layers) must pass scorer↔engine logit parity within the
documented 0.05 tolerance with paged ≡ contiguous bitwise.

``--smoke`` runs a seconds-scale random-init subset with the same schema;
the full run shares bench_eval's trained model cache.  Mirrors the
bench_solver/bench_serve/bench_eval conventions.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

TUNE_SCHEMA = 1

_CAND_KEYS = {"label", "kind", "avg_bits", "ppl", "nll", "mean_layer_err"}
_PARITY_KEYS = {"max_abs_diff_contiguous", "max_abs_diff_paged",
                "paged_bitwise_contiguous", "tol"}


def _parity_mixed_artifact(plan, params, calib, layer_keys, tcfg, *, frac):
    """Quantize a deliberately heterogeneous artifact for the parity bridge.

    The greedy winner can legitimately collapse to one width (smoke budgets
    often do), so the parity claim — mixed-precision serving bytes match the
    scorer — gets its own construction: candidate widths cycle across layers
    and every ``1/4``-th layer carries a COO outlier budget.  This is the
    worst case the harmonized restack must handle: every width in one stack,
    outlier planes padded across periods.
    """
    from repro.core.solver import LayerSpec, PTQConfig, ptq_quantize_model
    from repro.quant import GridSpec
    from repro.serve.qparams import quantize_params_for_serving

    bc = tcfg.bits_candidates
    specs, hist = {}, {}
    for i, key in enumerate(sorted(layer_keys)):
        b = bc[i % len(bc)]
        if i % 4 == 3:
            specs[key] = LayerSpec(bits=b, outlier_frac=frac, method="qe_outlier")
        else:
            specs[key] = LayerSpec(bits=b, method="quantease")
        hist[b] = hist.get(b, 0) + 1
    cfg = PTQConfig(
        method="quantease",
        spec=GridSpec(bits=bc[-1], group_size=tcfg.group_size),
        iterations=tcfg.iterations,
        emit="qt",
        layer_specs=specs,
    )
    qp, _ = ptq_quantize_model(plan, params, calib, cfg)
    return quantize_params_for_serving(plan, params, qp["dec"]), {
        str(k): v for k, v in sorted(hist.items())
    }


def collect(smoke: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.pipeline import DataConfig, make_batch_fn
    from repro.eval.harness import engine_parity
    from repro.tune import TuneConfig, probe_layer_stats, tune_model

    if smoke:
        import dataclasses as dc

        import benchmarks.common as C
        from repro.models import init_params, make_plan

        cfg = dc.replace(C.BENCH_CFG, d_model=64, head_dim=16, d_ff=128,
                         n_periods=2)
        plan = make_plan(cfg, 1)
        params = init_params(plan, jax.random.PRNGKey(0))
        tcfg = TuneConfig(
            budget_avg_bits=3.0, bits_candidates=(2, 3, 4),
            outlier_frac_candidates=(0.02,), iterations=2,
            n_ppl_batches=1, chunk=32, probe_outlier_iterations=2,
        )
        seq, n_calib = 64, 1
    else:
        from benchmarks.common import trained_model

        # Same longer-trained model as bench_eval (shared /tmp cache): near
        # the entropy floor, allocation quality differences rise above model
        # noise.
        plan, params, _, _ = trained_model(
            steps=int(os.environ.get("BENCH_EVAL_TRAIN_STEPS", "1600"))
        )
        cfg = plan.cfg
        tcfg = TuneConfig(
            budget_avg_bits=3.0, bits_candidates=(2, 3, 4, 8),
            outlier_frac_candidates=(0.02,), iterations=10,
            n_ppl_batches=12, probe_outlier_iterations=6,
        )
        seq, n_calib = 96, 8

    dcfg = DataConfig(vocab=cfg.vocab, seed=0)
    calib_fn, _ = make_batch_fn(dcfg, cfg, batch=4, seq=seq, split="calib")
    eval_fn, corpus = make_batch_fn(dcfg, cfg, batch=4, seq=seq, split="eval")
    calib = [
        {k: jnp.asarray(v) for k, v in calib_fn(i).items()} for i in range(n_calib)
    ]

    stats = probe_layer_stats(
        plan, params, calib,
        bits_candidates=tcfg.bits_candidates,
        outlier_cells=tuple(
            (tcfg.bits_candidates[0], f) for f in tcfg.outlier_frac_candidates
        ),
        outlier_iterations=tcfg.probe_outlier_iterations,
        progress_cb=lambda r: print(f"# {r}", file=sys.stderr),
    )
    tuned = tune_model(
        plan, params, calib, eval_fn, tcfg, stats=stats,
        progress_cb=lambda r: print(f"# {r}", file=sys.stderr),
    )

    qp_mixed, hist = _parity_mixed_artifact(
        plan, params, calib, list(stats), tcfg,
        frac=(tcfg.outlier_frac_candidates or (0.02,))[0],
    )
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (5, 13, 29)]
    parity = engine_parity(plan, qp_mixed, prompts, max_seq=64, page_size=8,
                           prefill_chunk=16)

    doc = {
        "schema": TUNE_SCHEMA,
        "smoke": smoke,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "arch": cfg.name,
        "data": {
            "vocab": cfg.vocab, "seq": seq,
            "eval_split": "eval", "calib_split": "calib",
            "entropy_floor_ppl": round(float(np.exp(corpus.entropy_floor())), 4),
        },
        "parity": parity,
        "parity_bits_histogram": hist,
    }
    doc.update(tuned)
    return doc


def validate(path: str) -> list:
    """Schema + invariant problems; empty means well-formed.

    The tuned ≤ uniform and budget invariants hold by construction even on
    smoke documents, so they are always enforced."""
    if not os.path.exists(path):
        return [f"{path}: missing"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable/not JSON ({e})"]
    probs = []
    if doc.get("schema") != TUNE_SCHEMA:
        probs.append(f"schema != {TUNE_SCHEMA}")
    cands = doc.get("candidates")
    if not isinstance(cands, list) or not cands:
        probs.append("candidates: missing/empty")
        return probs
    for i, row in enumerate(cands):
        missing = _CAND_KEYS - set(row)
        if missing:
            probs.append(f"candidates[{i}]: missing keys {sorted(missing)}")
    uniform, best = doc.get("uniform"), doc.get("best")
    if not isinstance(uniform, dict) or not isinstance(best, dict):
        probs.append("uniform/best: missing")
        return probs
    if not any(r.get("kind") == "uniform" for r in cands):
        probs.append("no uniform baseline candidate")
    budget = doc.get("budget_avg_bits")
    if best.get("ppl") is None or uniform.get("ppl") is None:
        probs.append("uniform/best: missing ppl")
    elif best["ppl"] > uniform["ppl"] + 1e-9:
        probs.append(
            f"tuned ppl {best['ppl']} worse than uniform {uniform['ppl']} "
            "at equal average bits"
        )
    for row in cands:
        if isinstance(budget, (int, float)) and row.get("avg_bits", 0) > budget + 1e-6:
            probs.append(f"{row.get('label')}: avg_bits {row['avg_bits']} "
                         f"over budget {budget}")
    par = doc.get("parity")
    if not isinstance(par, dict) or _PARITY_KEYS - set(par):
        probs.append("parity: missing/incomplete")
    else:
        if par["max_abs_diff_contiguous"] > par["tol"]:
            probs.append("parity: contiguous diff exceeds tol")
        if par["max_abs_diff_paged"] > par["tol"]:
            probs.append("parity: paged diff exceeds tol")
        if not par["paged_bitwise_contiguous"]:
            probs.append("parity: paged != contiguous bitwise")
    hist = doc.get("parity_bits_histogram")
    if not isinstance(hist, dict) or len(hist) < 2:
        probs.append(
            "parity_bits_histogram: parity artifact not heterogeneous "
            "(need ≥2 distinct widths in one stack)"
        )
    return probs


def run(csv):
    """benchmarks/run.py entry point.  Under BENCH_FAST=1 the smoke subset
    writes ``BENCH_tune_smoke.json`` — the committed trajectory is only
    overwritten by full-budget runs."""
    smoke = os.environ.get("BENCH_FAST", "0") == "1"
    doc = collect(smoke=smoke)
    name = "BENCH_tune_smoke.json" if smoke else "BENCH_tune.json"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", name)
    with open(os.path.normpath(out), "w") as f:
        json.dump(doc, f, indent=1)
    csv.add("tune_uniform", ppl=doc["uniform"]["ppl"],
            avg_bits=doc["uniform"]["avg_bits"])
    csv.add("tune_best", ppl=doc["best"]["ppl"], avg_bits=doc["best"]["avg_bits"])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="seconds-scale subset")
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_tune.json, or "
                         "BENCH_tune_smoke.json under --smoke so a smoke run "
                         "never clobbers the committed trajectory)")
    ap.add_argument("--validate", metavar="PATH", help="check an existing file")
    args = ap.parse_args()
    if args.out is None:
        args.out = "BENCH_tune_smoke.json" if args.smoke else "BENCH_tune.json"
    if args.validate:
        probs = validate(args.validate)
        for pr in probs:
            print(f"INVALID: {pr}", file=sys.stderr)
        print(f"{args.validate}: {'FAIL' if probs else 'ok'}")
        sys.exit(1 if probs else 0)
    doc = collect(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    for row in doc["candidates"]:
        extra = ""
        if row["kind"] == "mixed":
            extra = f"  bits={row.get('bits_histogram')}  " \
                    f"outlier_layers={row.get('n_outlier_layers')}"
        print(f"{row['label']:>20}: ppl {row['ppl']:.4f}  "
              f"avg_bits {row['avg_bits']}{extra}")
    print(f"best: {doc['best']['label']}  (uniform ppl {doc['uniform']['ppl']:.4f})")
    print(f"parity: {doc['parity']}  mixed artifact widths: "
          f"{doc['parity_bits_histogram']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    main()
