"""Whole-model PTQ solver + quantized serving integration tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.solver import LayerSpec, PTQConfig, ptq_quantize_model
from repro.models import init_cache, init_params, make_plan, prefill, train_loss
from repro.quant import GridSpec
from repro.serve.engine import Request, ServingEngine
from tests.conftest import reduce_cfg


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_cfg(get_config("stablelm_12b"), d_model=96, head_dim=24, d_ff=192, n_periods=3)
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    calib = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 96)).astype(np.int32))}
        for _ in range(2)
    ]
    return plan, params, calib


def test_solver_error_ordering(small_model):
    plan, params, calib = small_model
    errs = {}
    for method in ("rtn", "gptq", "quantease"):
        _, rep = ptq_quantize_model(
            plan, params, calib,
            PTQConfig(method=method, spec=GridSpec(bits=3), iterations=10),
        )
        errs[method] = np.mean(list(rep.values()))
    assert errs["quantease"] < errs["gptq"] < errs["rtn"]


def test_solver_covers_all_linears(small_model):
    plan, params, calib = small_model
    _, rep = ptq_quantize_model(
        plan, params, calib, PTQConfig(method="rtn", spec=GridSpec(bits=4))
    )
    # stablelm block: wq wk wv wo wg wu wd = 7 linears × 3 periods
    assert len(rep) == 21


def test_fake_quant_model_runs(small_model):
    plan, params, calib = small_model
    qp, _ = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=6),
    )
    loss = train_loss(plan, qp, calib[0])
    assert bool(jnp.isfinite(loss))


def test_moe_per_expert_quantization():
    cfg = reduce_cfg(get_config("olmoe_1b_7b"))
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    calib = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)).astype(np.int32))}]
    qp, rep = ptq_quantize_model(
        plan, params, calib, PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=4)
    )
    expert_keys = [k for k in rep if ".e" in k]
    assert len(expert_keys) >= cfg.n_experts  # per-expert entries exist
    assert bool(jnp.isfinite(train_loss(plan, qp, calib[0])))


def test_mixed_precision_fake_quant_end_to_end(small_model):
    """Bare-name layer_specs: every wq solves at 2 bits, every wd at 8 —
    the fake-quant model still runs and the split shows in the error report
    (2-bit wq strictly worse than the 8-bit wd on average)."""
    plan, params, calib = small_model
    qp, rep = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=4,
                  layer_specs={"wq": LayerSpec(bits=2),
                               "wd": LayerSpec(bits=8)}),
    )
    wq_err = np.mean([v for k, v in rep.items() if k.endswith("/wq")])
    wd_err = np.mean([v for k, v in rep.items() if k.endswith("/wd")])
    assert wq_err > wd_err
    assert bool(jnp.isfinite(train_loss(plan, qp, calib[0])))


def test_engine_quantized_vs_dense(small_model):
    plan, params, calib = small_model
    qp, _ = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=6),
    )
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (6, 11, 17)]

    def serve(p):
        eng = ServingEngine(plan, p, max_batch=2, max_seq=128, prefill_pad=8)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new_tokens=5))
        return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]

    dense = serve(params)
    quant = serve(qp)
    agree = np.mean([a == b for d, q in zip(dense, quant) for a, b in zip(d, q)])
    assert agree > 0.5  # 4-bit greedy mostly tracks dense on a random model
