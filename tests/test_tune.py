"""Mixed-precision auto-tuning tests: the heterogeneous-bits round-trip
battery (per-layer QT stacks → harmonized restack → both serving engines),
solver ``layer_specs`` resolution, the raw per-layer sensitivity signal, and
the budgeted allocator."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.solver import LayerSpec, PTQConfig, ptq_quantize_model
from repro.models import init_params, make_plan
from repro.quant import GridSpec, QuantizedTensor, quantize_tensor
from repro.quant.pack import pack_codes
from repro.serve.engine import PagedServingEngine, Request, ServingEngine
from repro.serve.qparams import harmonize_qt_stack, quantize_params_for_serving
from repro.tune import (
    AllocConfig,
    LayerStat,
    TuneConfig,
    allocate,
    allocation_layer_specs,
    build_candidates,
    probe_layer_stats,
    tune_model,
)
from tests.conftest import reduce_cfg


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_cfg(get_config("stablelm_12b"), d_model=96, head_dim=24,
                     d_ff=192, n_periods=3)
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    calib = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 96)).astype(np.int32))}
    ]
    return plan, params, calib


# ---------------------------------------------------------------------------
# harmonize_qt_stack: heterogeneous QT stacks → one treedef, same weights
# ---------------------------------------------------------------------------


def _qt(w, bits, *, outliers=0, group_size=None, packed=False, seed=0):
    qt = quantize_tensor(jnp.asarray(w), GridSpec(bits=bits, group_size=group_size))
    if packed:
        qt = dataclasses.replace(
            qt, codes=pack_codes(qt.codes, bits), packed=True
        )
    if outliers:
        rng = np.random.default_rng(seed)
        q, p = w.shape
        idx = rng.choice(q * p, size=outliers, replace=False).astype(np.int32)
        vals = rng.standard_normal(outliers).astype(np.float16)
        qt = dataclasses.replace(
            qt,
            outlier_values=jnp.asarray(vals),
            outlier_idx=jnp.asarray(np.sort(idx)),
        )
    return qt


def test_harmonize_homogeneous_passthrough():
    w = np.random.default_rng(1).standard_normal((8, 16)).astype(np.float32)
    leaves = [_qt(w, 4, packed=True), _qt(w + 1, 4, packed=True)]
    out = harmonize_qt_stack(leaves)
    assert out is leaves  # untouched: packed 4-bit stays packed


def test_harmonize_mixed_bits_preserves_dequant():
    rng = np.random.default_rng(2)
    ws = [rng.standard_normal((8, 16)).astype(np.float32) for _ in range(4)]
    leaves = [
        _qt(ws[0], 2),
        _qt(ws[1], 3, outliers=5, seed=3),
        _qt(ws[2], 4, packed=True),
        _qt(ws[3], 8, outliers=2, seed=4),
    ]
    before = [np.asarray(l.dequantize()) for l in leaves]
    out = harmonize_qt_stack(leaves)
    metas = {(l.bits, l.packed, l.group_size) for l in out}
    assert metas == {(8, False, None)}  # one treedef: max bits, unpacked
    s = {l.outlier_values.shape[-1] for l in out}
    assert s == {5}  # COO planes padded to the stack max
    for l, b in zip(out, before):
        np.testing.assert_array_equal(np.asarray(l.dequantize()), b)
    # and the stack itself now works leaf-for-leaf
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *out)
    assert stacked.codes.shape == (4, 8, 16)


def test_harmonize_rejects_heterogeneous_group_size():
    w = np.random.default_rng(5).standard_normal((8, 16)).astype(np.float32)
    with pytest.raises(ValueError, match="group_size"):
        harmonize_qt_stack([_qt(w, 2, group_size=8), _qt(w, 4)])


def test_harmonize_rejects_mismatched_column_outliers():
    w = np.random.default_rng(6).standard_normal((8, 16)).astype(np.float32)
    a = _qt(w, 2)
    b = dataclasses.replace(
        _qt(w, 4),
        outlier_col_idx=jnp.asarray([3], jnp.int32),
        outlier_col_vals=jnp.asarray(w[:, 3:4]),
    )
    with pytest.raises(ValueError, match="column outliers"):
        harmonize_qt_stack([a, b])


# ---------------------------------------------------------------------------
# Round-trip battery: mixed bits through the driver → restack → both engines
# ---------------------------------------------------------------------------


def _mixed_specs(report_keys):
    """Exact-path specs cycling every candidate width across layers, COO
    outliers on every fourth — per-period heterogeneity for the same leaf
    name, the case the naive stack cannot represent."""
    widths = (2, 3, 4, 8)
    specs = {}
    for i, key in enumerate(sorted(report_keys)):
        b = widths[i % 4]
        if i % 4 == 3:
            specs[key] = LayerSpec(bits=b, outlier_frac=0.02, method="qe_outlier")
        else:
            specs[key] = LayerSpec(bits=b)
    return specs


@pytest.fixture(scope="module")
def mixed_artifact(small_model):
    plan, params, calib = small_model
    _, probe_rep = ptq_quantize_model(
        plan, params, calib, PTQConfig(method="rtn", spec=GridSpec(bits=4))
    )
    specs = _mixed_specs(probe_rep)
    qp, rep = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=8), iterations=3,
                  emit="qt", layer_specs=specs),
    )
    return plan, params, qp, rep, specs


def test_mixed_emit_respects_layer_specs(mixed_artifact):
    plan, params, qp, rep, specs = mixed_artifact
    seen_bits = set()
    for period, blocks in enumerate(qp["dec"]):
        for bkey, blk in blocks.items():
            for name, leaf in blk.items():
                if not isinstance(leaf, QuantizedTensor):
                    continue
                key = f"dec.p{period}.{bkey}/{name}"
                assert leaf.bits == specs[key].bits, key
                if specs[key].outlier_frac:
                    assert leaf.outlier_values is not None, key
                seen_bits.add(leaf.bits)
    assert seen_bits == {2, 3, 4, 8}


def test_mixed_restack_token_identity_and_parity(mixed_artifact):
    from repro.eval.harness import engine_parity

    plan, params, qp, _, _ = mixed_artifact
    serving = quantize_params_for_serving(plan, params, qp["dec"])

    # every width lives in one stacked artifact
    wq = serving["dec"]["b0"]["wq"]
    assert wq.codes.shape[0] == plan.cfg.n_periods and not wq.packed

    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, plan.cfg.vocab, n).astype(np.int32)
               for n in (5, 11, 23)]

    def generate(engine_cls, **kw):
        eng = engine_cls(plan, serving, max_batch=2, max_seq=96, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
        return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]

    contig = generate(ServingEngine, prefill_pad=8)
    paged = generate(PagedServingEngine, page_size=8, prefill_chunk=16)
    assert contig == paged  # token identity across engines

    parity = engine_parity(plan, serving, prompts, max_seq=64, page_size=8,
                           prefill_chunk=16)
    assert parity["max_abs_diff_contiguous"] <= parity["tol"] == 0.05
    assert parity["max_abs_diff_paged"] <= parity["tol"]
    assert parity["paged_bitwise_contiguous"]


# ---------------------------------------------------------------------------
# Solver layer_specs resolution + grouped-solve splitting
# ---------------------------------------------------------------------------


def test_for_layer_resolution_order():
    base = PTQConfig(
        method="quantease", spec=GridSpec(bits=4, group_size=16),
        layer_specs={
            "dec.p0.b0/wq": LayerSpec(bits=2),
            "wq": LayerSpec(bits=3, method="rtn"),
        },
    )
    exact = base.for_layer("dec.p0.b0/wq")
    assert exact.spec.bits == 2 and exact.method == "quantease"
    assert exact.spec.group_size == 16  # inherited, not clobbered
    bare = base.for_layer("dec.p2.b0/wq")
    assert bare.spec.bits == 3 and bare.method == "rtn"
    none = base.for_layer("dec.p0.b0/wk")
    assert none.spec.bits == 4 and none.layer_specs is None


def test_for_layer_explicit_none_group_size():
    base = PTQConfig(spec=GridSpec(bits=4, group_size=16),
                     layer_specs={"wq": LayerSpec(group_size=None)})
    assert base.for_layer("dec.p0.b0/wq").spec.group_size is None


def test_group_key_splits_mixed_groups():
    a = PTQConfig(spec=GridSpec(bits=4), layer_specs={"wq": LayerSpec(bits=2)})
    assert (a.for_layer("x/wq")._group_key()
            != a.for_layer("x/wk")._group_key())
    assert (a.for_layer("x/wk")._group_key()
            == a.for_layer("x/wv")._group_key())


# ---------------------------------------------------------------------------
# Raw sensitivity signal: progress layer_errors are never rounded
# ---------------------------------------------------------------------------


def test_progress_layer_errors_full_precision(small_model):
    plan, params, calib = small_model
    records = []
    _, rep = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="rtn", spec=GridSpec(bits=3)),
        progress_cb=records.append,
    )
    errs = {}
    for rec in records:
        errs.update(rec["layer_errors"])
    assert set(errs) == set(rep)
    for k, v in errs.items():
        assert v == float(rep[k])  # bit-exact, straight from the solve
    # the regression this pins: eval/harness's *display* aggregate rounds to
    # 6 digits; the tuner's signal must not go through that path
    assert any(v != round(v, 6) for v in errs.values())


def test_collect_sensitivity_lambda_max(small_model):
    plan, params, calib = small_model
    records = []
    ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="rtn", spec=GridSpec(bits=4), collect_sensitivity=True),
        progress_cb=records.append,
    )
    lams = {}
    for rec in records:
        lams.update(rec.get("lambda_max", {}))
    assert lams and all(v > 0 for v in lams.values())


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


def _stats(rows):
    """rows: (key, n, lam, {bits_or_cell: err})"""
    return {k: LayerStat(key=k, n_weights=n, lambda_max=lam, err=dict(errs))
            for k, n, lam, errs in rows}


def test_allocate_prefers_high_gain_density():
    # A's 2→3 upgrade removes 10× the error of B's at the same cost.
    stats = _stats([
        ("A", 100, 1.0, {2: 1.0, 3: 0.0, 4: 0.0}),
        ("B", 100, 1.0, {2: 0.1, 3: 0.0, 4: 0.0}),
    ])
    cfg = AllocConfig(budget_avg_bits=2.5, bits_candidates=(2, 3, 4),
                      policy="error")
    alloc = allocate(stats, cfg)
    assert alloc.bits == {"A": 3, "B": 2}
    assert alloc.avg_bits == 2.5


def test_allocate_never_exceeds_floor_or_budget():
    stats = _stats([("A", 64, 1.0, {2: 1.0, 3: 0.5, 4: 0.1})])
    with pytest.raises(ValueError, match="floor"):
        allocate(stats, AllocConfig(budget_avg_bits=1.5, bits_candidates=(2, 3, 4)))
    alloc = allocate(stats, AllocConfig(budget_avg_bits=3.7,
                                        bits_candidates=(2, 3, 4)))
    assert alloc.bits == {"A": 3}  # 4 would cost 4.0 avg — over budget
    assert alloc.avg_bits <= 3.7


def test_allocate_outlier_pricing():
    # One layer, outliers at 1% remove all remaining error: cost is
    # 0.01·48 = 0.48 avg bits on top of the floor width.
    stats = _stats([("A", 1000, 1.0,
                     {2: 1.0, 3: 0.9, (2, 0.01): 0.0})])
    cfg = AllocConfig(budget_avg_bits=2.5, bits_candidates=(2, 3),
                      outlier_frac_candidates=(0.01,), policy="error")
    alloc = allocate(stats, cfg)
    assert alloc.outlier_frac == {"A": 0.01}
    assert alloc.avg_bits == pytest.approx(2.48)


def test_allocation_layer_specs_mapping():
    stats = _stats([
        ("dec.p0.b0/wq", 64, 1.0, {2: 1.0, 3: 0.0, (2, 0.01): 0.2}),
        ("dec.p0.b0/wk", 64, 1.0, {2: 0.5, 3: 0.4, (2, 0.01): 0.0}),
    ])
    cfg = AllocConfig(budget_avg_bits=3.0, bits_candidates=(2, 3),
                      outlier_frac_candidates=(0.01,), policy="error")
    specs = allocation_layer_specs(allocate(stats, cfg))
    assert set(specs) == set(stats)
    for sp in specs.values():
        assert (sp.method == "qe_outlier") == (sp.outlier_frac is not None)


def test_sensitivity_policy_uses_lambda_max():
    # Identical error tables; only λ_max separates the layers.  Budget fits
    # exactly one upgrade: the sensitivity policy must take the hot layer.
    rows = {2: 1.0, 3: 0.0}
    stats = _stats([("cold", 100, 0.1, rows), ("hot", 100, 5.0, rows)])
    cfg = AllocConfig(budget_avg_bits=2.5, bits_candidates=(2, 3),
                      policy="sensitivity")
    assert allocate(stats, cfg).bits == {"cold": 2, "hot": 3}


# ---------------------------------------------------------------------------
# Probe + search loop on a real (tiny) model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_probe(small_model):
    plan, params, calib = small_model
    stats = probe_layer_stats(plan, params, calib, bits_candidates=(2, 4))
    return plan, params, calib, stats


def test_probe_layer_stats_shape(tiny_probe):
    plan, _, _, stats = tiny_probe
    assert len(stats) == 21  # 7 linears × 3 periods
    for st in stats.values():
        assert st.n_weights > 0 and st.lambda_max > 0
        assert st.err[2] >= st.err[4]  # wider grid never probes worse here


def test_tune_model_uniform_bound_and_resume(tiny_probe):
    plan, params, calib, stats = tiny_probe
    rng = np.random.default_rng(9)
    cfg = plan.cfg

    def batch_fn(i):
        r = np.random.default_rng(100 + i)
        return {"tokens": r.integers(0, cfg.vocab, (2, 64)).astype(np.int32)}

    tcfg = TuneConfig(budget_avg_bits=3.0, bits_candidates=(2, 4),
                      policies=("error",), method="rtn", n_ppl_batches=1,
                      chunk=32)
    doc = tune_model(plan, params, calib, batch_fn, tcfg, stats=stats)
    labels = [c["label"] for c in doc["candidates"]]
    assert labels[0].startswith("uniform@2b")  # widest ≤ budget is 2 here
    assert doc["best"]["ppl"] <= doc["uniform"]["ppl"]
    assert all(c["avg_bits"] <= tcfg.budget_avg_bits + 1e-6
               for c in doc["candidates"])

    # resume: feed the first result back, only the remainder re-evaluates
    evaluated = []
    doc2 = tune_model(plan, params, calib, batch_fn, tcfg, stats=stats,
                      prior_results=doc["candidates"][:1],
                      result_cb=lambda r: evaluated.append(r["label"]))
    assert evaluated == labels[1:]
    assert [c["label"] for c in doc2["candidates"]] == labels


def test_tune_model_retries_through_runner(tiny_probe):
    from repro.dist.elastic import RetryingRunner

    plan, params, calib, stats = tiny_probe
    cfg = plan.cfg

    def batch_fn(i):
        r = np.random.default_rng(200 + i)
        return {"tokens": r.integers(0, cfg.vocab, (2, 64)).astype(np.int32)}

    tcfg = TuneConfig(budget_avg_bits=2.0, bits_candidates=(2, 4),
                      policies=("error",), method="rtn", n_ppl_batches=1,
                      chunk=32)
    boom = {"armed": True}

    def fault(step):
        if step == 1 and boom.pop("armed", False):
            raise RuntimeError("simulated preemption")

    doc = tune_model(
        plan, params, calib, batch_fn, tcfg, stats=stats,
        runner_factory=lambda s, r: RetryingRunner(s, r, fault_hook=fault),
    )
    assert len(doc["candidates"]) == 2  # crash recovered, loop completed


def test_build_candidates_uniform_first():
    stats = _stats([("A", 64, 1.0, {2: 1.0, 3: 0.5, 4: 0.2})])
    tcfg = TuneConfig(budget_avg_bits=3.0, bits_candidates=(2, 3, 4),
                      policies=("error", "sensitivity"))
    cands = build_candidates(stats, tcfg)
    assert cands[0]["kind"] == "uniform" and cands[0]["bits"] == 3
    assert [c["label"] for c in cands[1:]] == ["greedy-error",
                                               "greedy-sensitivity"]
    with pytest.raises(ValueError, match="below every candidate"):
        TuneConfig(budget_avg_bits=1.0).uniform_bits()
