"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.quant import pack_codes


def _sweep_problem(seed, q, bsz):
    r = np.random.default_rng(seed)
    x = r.standard_normal((bsz, 4 * bsz)).astype(np.float32)
    s = x @ x.T
    sn = s / np.diag(s)[None, :]
    np.fill_diagonal(sn, 0.0)
    return (
        jnp.asarray(r.standard_normal((q, bsz)).astype(np.float32)),
        jnp.asarray(sn.astype(np.float32)),
        jnp.asarray(r.standard_normal((q, bsz)).astype(np.float32)),
        jnp.asarray((r.random((q, bsz)) * 0.2 + 0.05).astype(np.float32)),
        jnp.asarray(r.integers(0, 15, (q, bsz)).astype(np.float32)),
    )


@pytest.mark.parametrize("q,bsz", [(8, 16), (64, 32), (130, 64), (96, 128)])
@pytest.mark.parametrize("quantize", [True, False])
@pytest.mark.parametrize("n_levels", [4, 16])
def test_cd_sweep_matches_ref(q, bsz, quantize, n_levels):
    args = _sweep_problem(q * bsz, q, bsz)
    wk, dk = ops.quantease_block_sweep(
        *args, n_levels=n_levels, quantize=quantize, interpret=True
    )
    wr, dr = ref.quantease_block_sweep_ref(*args, n_levels=n_levels, quantize=quantize)
    scale = float(jnp.max(jnp.abs(wr))) + 1e-9
    assert float(jnp.max(jnp.abs(wk - wr))) / scale < 1e-5
    assert float(jnp.max(jnp.abs(dk - dr))) / scale < 1e-5


def test_cd_sweep_batched_matches_loop():
    """Leading group dim (grouped-block solver path) == per-slice sweeps."""
    G = 3
    probs = [_sweep_problem(11 + g, 24, 16) for g in range(G)]
    stacked = [jnp.stack([p[j] for p in probs]) for j in range(5)]
    wb, db = ops.quantease_block_sweep(
        *stacked, n_levels=16, quantize=True, interpret=True
    )
    assert wb.shape == (G, 24, 16)
    for g in range(G):
        wg, dg = ops.quantease_block_sweep(
            *probs[g], n_levels=16, quantize=True, interpret=True
        )
        np.testing.assert_allclose(np.asarray(wb[g]), np.asarray(wg), atol=1e-6)
        np.testing.assert_allclose(np.asarray(db[g]), np.asarray(dg), atol=1e-6)


@pytest.mark.parametrize(
    "m,p,q,xdt",
    [
        (4, 64, 16, jnp.float32),
        (33, 130, 50, jnp.bfloat16),
        (128, 512, 128, jnp.bfloat16),
        (1, 256, 64, jnp.float32),
    ],
)
def test_dequant_matmul_matches_ref(m, p, q, xdt):
    r = np.random.default_rng(m * p + q)
    x = jnp.asarray(r.standard_normal((m, p)), xdt)
    codes = jnp.asarray(r.integers(0, 16, (q, p)).astype(np.uint8))
    scale = jnp.asarray((r.random(q) * 0.1 + 0.01).astype(np.float32))
    zero = jnp.asarray(r.integers(0, 16, q).astype(np.float32))
    y_k = ops.dequant_matmul(x, codes, scale, zero, out_dtype=jnp.float32, interpret=True)
    y_r = ref.dequant_matmul_ref(x, codes, scale, zero)
    rel = float(jnp.max(jnp.abs(y_k - y_r)) / (jnp.max(jnp.abs(y_r)) + 1e-9))
    assert rel < 2e-6


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    p=st.sampled_from([32, 64, 128, 320]),
    q=st.integers(2, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_matmul_packed4_property(m, p, q, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, p)).astype(np.float32))
    codes = jnp.asarray(r.integers(0, 16, (q, p)).astype(np.uint8))
    scale = jnp.asarray((r.random(q) * 0.1 + 0.01).astype(np.float32))
    zero = jnp.asarray(r.integers(0, 16, q).astype(np.float32))
    packed = pack_codes(codes, 4)
    y_k = ops.dequant_matmul(
        x, packed, scale, zero, packed4=True, out_dtype=jnp.float32, interpret=True
    )
    y_r = ref.dequant_matmul_ref(x, codes, scale, zero)
    rel = float(jnp.max(jnp.abs(y_k - y_r)) / (jnp.max(jnp.abs(y_r)) + 1e-9))
    assert rel < 2e-6


def test_quantease_kernel_path_equals_xla(layer_problem):
    from repro.core import quantease_quantize
    from repro.quant import GridSpec

    w, sigma = layer_problem
    wx, _ = quantease_quantize(
        w, sigma, GridSpec(bits=4), iterations=3, block_size=32, use_kernel="xla"
    )
    wp, _ = quantease_quantize(
        w, sigma, GridSpec(bits=4), iterations=3, block_size=32, use_kernel="pallas"
    )
    np.testing.assert_allclose(np.asarray(wx), np.asarray(wp), atol=1e-5)


def test_dequant_matmul_tile_layout_bit_exact(rng):
    """The tile-native prepacked GEMM returns bit-identical results to the
    linear-packed dispatch — the reorder is a pure column permutation the
    kernel (or the un-prepacking ref) undoes exactly."""
    from repro.kernels.dequant_matmul import select_tile_k
    from repro.quant.pack import prepack_codes

    m, p, q = 4, 1024, 64
    codes = rng.integers(0, 16, (q, p)).astype(np.uint8)
    scale = jnp.asarray((rng.random((q, 1)) * 0.1 + 0.01).astype(np.float32))
    zero = jnp.asarray(rng.integers(0, 16, (q, 1)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, p)).astype(np.float32))
    y_lin = ops.dequant_matmul(
        x, pack_codes(jnp.asarray(codes), 4), scale, zero,
        packed4=True, out_dtype=jnp.float32, interpret=True,
    )
    tk = select_tile_k(p, None)
    pre = prepack_codes(jnp.asarray(codes), 4, tk)
    y_tile = ops.dequant_matmul(
        x, pre, scale, zero, packed4=True, pack_layout="tile", pack_tile=tk,
        out_dtype=jnp.float32, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(y_lin), np.asarray(y_tile))


def test_dequant_matmul_tile_layout_grouped(rng):
    """Tile layout under a grouped grid (whole-groups tiling: tk snaps to a
    group multiple) still matches the linear dispatch bit-for-bit."""
    from repro.kernels.dequant_matmul import select_tile_k
    from repro.quant.pack import prepack_codes

    m, p, q, gsz = 3, 1024, 32, 256
    n_groups = p // gsz
    codes = rng.integers(0, 16, (q, p)).astype(np.uint8)
    scale = jnp.asarray((rng.random((q, n_groups)) * 0.1 + 0.01).astype(np.float32))
    zero = jnp.asarray(rng.integers(0, 16, (q, n_groups)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, p)).astype(np.float32))
    y_lin = ops.dequant_matmul(
        x, pack_codes(jnp.asarray(codes), 4), scale, zero,
        packed4=True, group_size=gsz, out_dtype=jnp.float32, interpret=True,
    )
    tk = select_tile_k(p, gsz)
    assert tk % gsz == 0  # whole-groups tiling for this shape
    pre = prepack_codes(jnp.asarray(codes), 4, tk)
    y_tile = ops.dequant_matmul(
        x, pre, scale, zero, packed4=True, group_size=gsz,
        pack_layout="tile", pack_tile=tk, out_dtype=jnp.float32, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(y_lin), np.asarray(y_tile))
