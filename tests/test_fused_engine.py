"""Fused-iteration CD engine: parity, triangular scheduling, bf16 Σ̃, and
the grouped-scale Pallas serving GEMM (DESIGN.md §Fused-iteration)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantease
from repro.core.quantease import (
    layer_objective,
    quantease_quantize,
    quantease_reference,
    relative_error,
)
from repro.kernels import ops, ref
from repro.quant import GridSpec, compute_grid, dequantize_codes, pack_codes, quantize_codes

SPEC3 = GridSpec(bits=3)


def _problem(seed, q, p, n):
    r = np.random.default_rng(seed)
    x = r.standard_normal((p, n)).astype(np.float32)
    w = r.standard_normal((q, p)).astype(np.float32)
    w[r.random((q, p)) < 0.003] *= 10.0
    return jnp.asarray(w), jnp.asarray(x @ x.T)


# ---------------------------------------------------------------------------
# Engine parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bsz", [32, 64, 128])
def test_fused_matches_reference(layer_problem, bsz):
    """Fused engine reproduces Algorithm 1 (same iterates, any block size)."""
    w, sigma = layer_problem
    w_ref = quantease_reference(w, sigma, SPEC3, iterations=3)
    w_fused, _ = quantease_quantize(
        w, sigma, SPEC3, iterations=3, block_size=bsz,
        unquantized_heuristic=False, engine="fused", use_kernel="xla",
    )
    np.testing.assert_allclose(np.asarray(w_ref), np.asarray(w_fused), rtol=0, atol=2e-4)


@pytest.mark.parametrize("heuristic", [False, True])
@pytest.mark.parametrize("bsz", [32, 128])
def test_fused_matches_legacy(layer_problem, bsz, heuristic):
    """Triangular-correction equivalence: the rolling-Δ fused schedule and
    the legacy full-recompute schedule apply updates in the same order, so
    iterates agree (bit-level drift only from fp reassociation, absorbed by
    the grid snap on quantized iterations)."""
    w, sigma = layer_problem
    kw = dict(iterations=4, block_size=bsz, unquantized_heuristic=heuristic)
    w_leg, _ = quantease_quantize(w, sigma, SPEC3, engine="legacy", **kw)
    w_fus, _ = quantease_quantize(w, sigma, SPEC3, engine="fused", use_kernel="xla", **kw)
    np.testing.assert_allclose(np.asarray(w_leg), np.asarray(w_fus), rtol=0, atol=2e-4)


def test_fused_objective_matches_legacy(layer_problem):
    w, sigma = layer_problem
    kw = dict(iterations=5, unquantized_heuristic=False, track_objective=True)
    _, o_leg = quantease_quantize(w, sigma, SPEC3, engine="legacy", **kw)
    _, o_fus = quantease_quantize(w, sigma, SPEC3, engine="fused", **kw)
    np.testing.assert_allclose(np.asarray(o_leg), np.asarray(o_fus), rtol=1e-5)


def test_objective_opt_out_returns_none(layer_problem):
    w, sigma = layer_problem
    _, objs = quantease_quantize(w, sigma, SPEC3, iterations=2)
    assert objs is None


def test_bf16_sigma_within_tolerance(layer_problem):
    """bf16 Σ̃ correction operands: solution quality stays at the fp32 level
    (β/quantize path is fp32 — only correction matmul rounding differs)."""
    w, sigma = layer_problem
    kw = dict(iterations=8, unquantized_heuristic=False)
    w32, _ = quantease_quantize(w, sigma, SPEC3, matmul_dtype="float32", **kw)
    wbf, _ = quantease_quantize(w, sigma, SPEC3, matmul_dtype="bfloat16", **kw)
    e32 = float(relative_error(w, w32, sigma))
    ebf = float(relative_error(w, wbf, sigma))
    assert ebf <= e32 * 1.05 + 1e-6
    # and the bf16 iterate is still a descent vs RTN-style starting error
    f0 = float(layer_objective(w, quantease.quantease_reference(
        w, sigma, SPEC3, iterations=1), sigma))
    assert float(layer_objective(w, wbf, sigma)) <= f0 * 1.05 + 1e-6


# ---------------------------------------------------------------------------
# Single fused kernel vs per-block sweeps (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,p,bsz", [(96, 128, 32), (64, 96, 48), (130, 64, 64)])
def test_fused_kernel_matches_fused_xla(q, p, bsz):
    w, sigma = _problem(q * p, q, p, 2 * p)
    kw = dict(iterations=3, block_size=bsz, unquantized_heuristic=True)
    wx, _ = quantease_quantize(w, sigma, SPEC3, use_kernel="xla", **kw)
    wp, _ = quantease_quantize(w, sigma, SPEC3, use_kernel="pallas", **kw)
    np.testing.assert_allclose(np.asarray(wx), np.asarray(wp), atol=1e-5)


def test_fused_kernel_single_launch_per_iteration():
    """The fused Pallas path launches one kernel per iteration — not one per
    column block (the pre-fused schedule's launch pattern)."""
    w, sigma = _problem(7, 64, 128, 256)
    n_calls = 0
    orig = ops.quantease_fused_iteration

    def counting(*a, **k):
        nonlocal n_calls
        n_calls += 1
        return orig(*a, **k)

    ops.quantease_fused_iteration, saved = counting, orig
    try:
        # under jit the wrapper traces once per distinct quantize flag; run
        # untraced via the internal 2-D path to count real invocations
        quantease._quantease_2d(
            w, sigma, spec=SPEC3, iterations=4, block_size=32, percdamp=0.01,
            unquantized_heuristic=False, w_init=None, grid=None,
            use_kernel="pallas", matmul_dtype="float32",
            track_objective=False, engine="fused",
        )
    finally:
        ops.quantease_fused_iteration = saved
    assert n_calls == 4  # one per iteration, though p/32 = 4 blocks each


def test_fused_kernel_batched_matches_per_slice():
    """Leading group dim through the fused kernel == per-slice solves."""
    G = 3
    probs = [_problem(11 + g, 48, 64, 128) for g in range(G)]
    w3 = jnp.stack([pr[0] for pr in probs])
    sig3 = jnp.stack([pr[1] for pr in probs])
    kw = dict(iterations=2, block_size=32, unquantized_heuristic=False,
              use_kernel="pallas")
    wb, _ = quantease_quantize(w3, sig3, SPEC3, **kw)
    for g in range(G):
        wg, _ = quantease_quantize(w3[g], sig3[g], SPEC3, **kw)
        np.testing.assert_allclose(np.asarray(wb[g]), np.asarray(wg), atol=1e-5)


def test_use_kernel_auto_resolves():
    assert quantease._resolve_use_kernel("auto") in ("xla", "pallas_hw")
    if not ops.on_tpu():
        assert quantease._resolve_use_kernel("auto") == "xla"
    with pytest.raises(ValueError):
        quantease._resolve_use_kernel("mosaic")
    w, sigma = _problem(3, 32, 48, 96)
    wa, _ = quantease_quantize(w, sigma, SPEC3, iterations=2, use_kernel="auto")
    wx, _ = quantease_quantize(w, sigma, SPEC3, iterations=2, use_kernel="xla")
    if not ops.on_tpu():
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wx))


# ---------------------------------------------------------------------------
# Grouped-scale Pallas serving GEMM
# ---------------------------------------------------------------------------


def _gemm_problem(seed, m, q, p, n_groups):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, p)).astype(np.float32))
    codes = jnp.asarray(r.integers(0, 16, (q, p)).astype(np.uint8))
    scale = jnp.asarray((r.random((q, n_groups)) * 0.1 + 0.01).astype(np.float32))
    zero = jnp.asarray(r.integers(0, 16, (q, n_groups)).astype(np.float32))
    return x, codes, scale, zero


@pytest.mark.parametrize(
    "m,q,p,n_groups",
    [
        (4, 16, 64, 4),  # gsz=16 < tk: whole groups per tile
        (8, 32, 128, 2),  # gsz=64
        (5, 24, 640, 5),  # gsz=128, tk snaps to a multiple of gsz
        (3, 16, 1536, 2),  # gsz=768 > tk=512: tile inside one group
    ],
)
def test_grouped_dequant_matmul_pallas_matches_ref(m, q, p, n_groups):
    x, codes, scale, zero = _gemm_problem(m * p, m, q, p, n_groups)
    y_k = ops.dequant_matmul(
        x, codes, scale, zero, out_dtype=jnp.float32, interpret=True
    )
    y_r = ref.dequant_matmul_ref(x, codes, scale, zero)
    rel = float(jnp.max(jnp.abs(y_k - y_r)) / (jnp.max(jnp.abs(y_r)) + 1e-9))
    assert rel < 2e-6


def test_grouped_dequant_matmul_packed4_pallas_matches_ref():
    x, codes, scale, zero = _gemm_problem(0, 6, 24, 256, 4)
    packed = pack_codes(codes, 4)
    y_k = ops.dequant_matmul(
        x, packed, scale, zero, packed4=True, out_dtype=jnp.float32, interpret=True
    )
    y_r = ref.dequant_matmul_ref(x, codes, scale, zero)
    rel = float(jnp.max(jnp.abs(y_k - y_r)) / (jnp.max(jnp.abs(y_r)) + 1e-9))
    assert rel < 2e-6


def test_grouped_packed4_cpu_dispatch_unpacks():
    """Regression (kernels/ops.py): the grouped-scale CPU path used to hand
    *packed* int4 codes to the reference GEMM, which reads them as raw uint8
    codes — silently wrong results for every group_size spec with packed
    weights."""
    x, codes, scale, zero = _gemm_problem(1, 4, 8, 64, 4)
    packed = pack_codes(codes, 4)
    y = ops.dequant_matmul(x, packed, scale, zero, packed4=True, out_dtype=jnp.float32)
    y_r = ref.dequant_matmul_ref(x, codes, scale, zero)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), rtol=1e-6, atol=1e-5)


def test_grouped_ragged_falls_back_to_ref():
    # p=60 with 8 groups: ragged last group — must still be correct (ref path).
    x, codes, scale, zero = _gemm_problem(2, 3, 8, 60, 8)
    y = ops.dequant_matmul(x, codes, scale, zero, out_dtype=jnp.float32, interpret=True)
    y_r = ref.dequant_matmul_ref(x, codes, scale, zero)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), rtol=1e-6, atol=1e-5)


def test_ragged_group_size_threads_true_boundaries():
    """Regression: a ragged grid whose group *count* happens to divide p
    (p=384, group_size=256 → groups of 256+128, n_groups=2) must dequantize
    with the grid's true boundaries — without the threaded ``group_size``
    both the uniform check (384 % 2 == 0) and ceil inference (gsz=192) get
    it wrong."""
    r = np.random.default_rng(5)
    q, p, gsz = 8, 384, 256
    w = jnp.asarray(r.standard_normal((q, p)).astype(np.float32))
    spec = GridSpec(bits=4, group_size=gsz)
    grid = compute_grid(w, spec)
    codes = quantize_codes(w, grid)
    x = jnp.asarray(r.standard_normal((3, p)).astype(np.float32))
    scale_pc, zero_pc = grid.per_column(p)
    w_true = (codes.astype(jnp.float32) - zero_pc) * scale_pc
    y_true = x @ w_true.T
    y = ops.dequant_matmul(
        x, codes, grid.scale, grid.zero,
        out_dtype=jnp.float32, group_size=gsz,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_true), rtol=1e-6, atol=1e-5)


# ---------------------------------------------------------------------------
# Solver grid threading: codes round-trip the solve exactly
# ---------------------------------------------------------------------------


def test_codes_roundtrip_solver_grid(layer_problem):
    """quantize_codes on the *solver's* grid inverts exactly: dequantizing
    the emitted codes reproduces Ŵ bit-for-bit (satellite: thread Grid
    through _emit_leaf instead of recomputing on Ŵ)."""
    w, sigma = layer_problem
    grid = compute_grid(w, SPEC3)
    w_hat, _ = quantease_quantize(w, sigma, SPEC3, iterations=3, grid=grid)
    codes = quantize_codes(w_hat, grid)
    np.testing.assert_array_equal(
        np.asarray(dequantize_codes(codes, grid)), np.asarray(w_hat)
    )


def test_emit_qt_roundtrips_model_solve():
    """End-to-end: emit='qt' QuantizedTensor leaves dequantize back to the
    solver's Ŵ exactly (error report == dequantized-leaf error)."""
    from repro.configs import get_config
    from repro.core.solver import PTQConfig, ptq_quantize_model
    from repro.models import init_params, make_plan
    from repro.quant import unpack_codes
    from tests.conftest import reduce_cfg

    cfg = reduce_cfg(get_config("stablelm_12b"), n_periods=1)
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    calib = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)).astype(np.int32))}]

    pcfg = PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=3, emit="qt")
    qt_params, report = ptq_quantize_model(plan, params, calib, pcfg)
    qt_periods = qt_params["dec"]

    # Re-run with emit='fake' (same solves, same grids) and compare a leaf.
    fcfg = PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=3, emit="fake")
    fake, _ = ptq_quantize_model(plan, params, calib, fcfg)

    qt = qt_periods[0]["b0"]["wq"]
    codes = qt.codes
    if qt.packed:
        codes = unpack_codes(codes, 4, codes.shape[-1] * 2)
    deq = (codes.astype(jnp.float32) - qt.zero) * qt.scale  # (out_f, d_in)
    w_fake = fake["dec"]["b0"]["wq"][0]  # original leaf layout, period 0
    d_in = deq.shape[1]
    w2 = w_fake.reshape(d_in, -1).T  # (out_f, d_in), fake-emit dtype (bf16)
    # The fake leaf is Ŵ cast to the param dtype; an exact codes round-trip
    # means dequantizing the QT leaf and casting reproduces it bit-for-bit.
    np.testing.assert_array_equal(
        np.asarray(deq.astype(w2.dtype)), np.asarray(w2)
    )
