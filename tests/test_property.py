"""Hypothesis property tests on the system's invariants.

Imports through tests/_hypothesis_compat: without hypothesis installed
(optional dev dependency) each @given test collects as one skipped test
instead of the module vanishing wholesale."""

import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import quantease_quantize, rtn_quantize
from repro.core.calib import damp_sigma
from repro.core.quantease import layer_objective
from repro.quant import GridSpec, compute_grid, quantize_dequantize


def _problem(seed, q, p, n):
    r = np.random.default_rng(seed)
    x = r.standard_normal((p, n)).astype(np.float32)
    w = r.standard_normal((q, p)).astype(np.float32)
    if seed % 3 == 0:
        w[r.random((q, p)) < 0.01] *= 8.0
    return jnp.asarray(w), jnp.asarray(x @ x.T)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    q=st.integers(4, 24),
    p=st.integers(4, 48),
    bits=st.sampled_from([2, 3, 4]),
)
def test_quantease_never_worse_than_rtn(seed, q, p, bits):
    """CD starting feasible can only descend ⇒ ≤ RTN error always (the RTN
    point is one feasible point; QuantEase's first sweep min-s over each
    coordinate, which includes the RTN choice)."""
    w, sigma = _problem(seed, q, p, max(2 * p, 16))
    spec = GridSpec(bits=bits)
    sigma_d = damp_sigma(sigma)
    w_rtn = rtn_quantize(w, spec)
    w_qe, _ = quantease_quantize(
        w, sigma, spec, iterations=6, unquantized_heuristic=False, w_init=w_rtn
    )
    f_rtn = float(layer_objective(w, w_rtn, sigma_d))
    f_qe = float(layer_objective(w, w_qe, sigma_d))
    assert f_qe <= f_rtn * (1 + 1e-5) + 1e-6


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    q=st.integers(4, 16),
    p=st.integers(4, 32),
)
def test_objective_monotone_property(seed, q, p):
    w, sigma = _problem(seed, q, p, max(2 * p, 16))
    _, objs = quantease_quantize(
        w, sigma, GridSpec(bits=3), iterations=8, unquantized_heuristic=False,
        track_objective=True,
    )
    objs = np.asarray(objs)
    assert np.all(np.diff(objs) <= np.abs(objs[:-1]) * 1e-4 + 1e-3)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    bits=st.sampled_from([2, 3, 4, 8]),
    symmetric=st.booleans(),
)
def test_grid_projection_is_nearest(seed, bits, symmetric):
    """q_i(x) is the closest grid point: |x − q(x)| ≤ |x − any grid value|
    (checked against a dense enumeration of the grid)."""
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((3, 17)).astype(np.float32) * 3)
    spec = GridSpec(bits=bits, symmetric=symmetric)
    grid = compute_grid(w, spec)
    wq = np.asarray(quantize_dequantize(w, grid))
    scale, zero = grid.per_column(w.shape[1])
    levels = np.arange(2**bits)[None, None, :]
    vals = (levels - np.asarray(zero)[..., None]) * np.asarray(scale)[..., None]
    dmin = np.abs(vals - np.asarray(w)[..., None]).min(-1)
    # distance-based check: exact .5-step ties may legally go either way
    np.testing.assert_allclose(
        np.abs(wq - np.asarray(w)), dmin, rtol=1e-4, atol=1e-5
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_cw_minimum(seed):
    """After convergence, no single-coordinate move improves the objective
    (Definition 1, CW-minimum — checked on a coordinate sample)."""
    w, sigma = _problem(seed, 6, 10, 64)
    spec = GridSpec(bits=3)
    sigma_d = damp_sigma(sigma)
    w_hat, _ = quantease_quantize(
        w, sigma, spec, iterations=30, unquantized_heuristic=False
    )
    f0 = float(layer_objective(w, w_hat, sigma_d))
    grid = compute_grid(w, spec)
    scale, zero = grid.per_column(w.shape[1])
    r = np.random.default_rng(seed)
    wh = np.asarray(w_hat).copy()
    for _ in range(12):
        i = r.integers(0, w.shape[0])
        j = r.integers(0, w.shape[1])
        for lvl in range(2**3):
            cand = wh.copy()
            cand[i, j] = (lvl - float(zero[i, j])) * float(scale[i, j])
            f = float(layer_objective(w, jnp.asarray(cand), sigma_d))
            assert f >= f0 - abs(f0) * 1e-4 - 1e-3


# ---------------------------------------------------------------------------
# Budgeted mixed-precision allocator invariants (repro/tune/allocate.py)
# ---------------------------------------------------------------------------

from repro.tune import AllocConfig, LayerStat, allocate  # noqa: E402

_ALLOC_BITS = (2, 3, 4, 8)
_ALLOC_FRACS = (0.01,)


if HAVE_HYPOTHESIS:
    @st.composite
    def _alloc_stats(draw):
        n = draw(st.integers(1, 5))
        err_f = st.floats(0.0, 2.0, allow_nan=False, allow_infinity=False)
        stats = {}
        for i in range(n):
            errs = {b: draw(err_f) for b in _ALLOC_BITS}
            for frac in _ALLOC_FRACS:
                errs[(_ALLOC_BITS[0], frac)] = draw(err_f)
            stats[f"L{i}"] = LayerStat(
                key=f"L{i}",
                n_weights=draw(st.integers(16, 4096)),
                lambda_max=draw(st.floats(0.0, 10.0, allow_nan=False)),
                err=errs,
            )
        return stats
else:  # stub strategy: @given skips these tests anyway
    def _alloc_stats():
        return None


@settings(max_examples=40, deadline=None)
@given(
    stats=_alloc_stats(),
    budget=st.floats(2.0, 9.0, allow_nan=False),
    policy=st.sampled_from(["error", "sensitivity"]),
)
def test_allocation_never_exceeds_budget(stats, budget, policy):
    cfg = AllocConfig(budget_avg_bits=budget, bits_candidates=_ALLOC_BITS,
                      outlier_frac_candidates=_ALLOC_FRACS, policy=policy)
    alloc = allocate(stats, cfg)
    assert alloc.avg_bits <= budget + 1e-9
    total_n = sum(s.n_weights for s in stats.values())
    # avg_bits accounting matches the per-layer assignment exactly
    recomputed = sum(
        (alloc.bits[k] + alloc.outlier_frac.get(k, 0.0) * 48) * s.n_weights
        for k, s in stats.items()
    ) / total_n
    assert alloc.avg_bits == pytest.approx(recomputed)


@settings(max_examples=25, deadline=None)
@given(
    stats=_alloc_stats(),
    budget=st.floats(2.0, 9.0, allow_nan=False),
    policy=st.sampled_from(["error", "sensitivity"]),
)
def test_allocation_deterministic_under_iteration_order(stats, budget, policy):
    cfg = AllocConfig(budget_avg_bits=budget, bits_candidates=_ALLOC_BITS,
                      outlier_frac_candidates=_ALLOC_FRACS, policy=policy)
    a = allocate(stats, cfg)
    reversed_stats = dict(reversed(list(stats.items())))
    b = allocate(reversed_stats, cfg)
    assert a.bits == b.bits
    assert a.outlier_frac == b.outlier_frac
    assert a.trace == b.trace


@settings(max_examples=25, deadline=None)
@given(
    stats=_alloc_stats(),
    b1=st.floats(2.0, 9.0, allow_nan=False),
    b2=st.floats(2.0, 9.0, allow_nan=False),
    policy=st.sampled_from(["error", "sensitivity"]),
)
def test_allocation_monotone_in_budget(b1, b2, stats, policy):
    """Prefix semantics: a larger budget spends a superset of the upgrade
    sequence, so total assigned bits never decreases."""
    lo, hi = sorted((b1, b2))
    mk = lambda b: allocate(stats, AllocConfig(
        budget_avg_bits=b, bits_candidates=_ALLOC_BITS,
        outlier_frac_candidates=_ALLOC_FRACS, policy=policy))
    a_lo, a_hi = mk(lo), mk(hi)
    assert a_hi.total_bits >= a_lo.total_bits - 1e-9
    assert a_hi.trace[: len(a_lo.trace)] == a_lo.trace  # literal prefix
    for k in stats:
        assert a_hi.bits[k] >= a_lo.bits[k]
