"""Grid, packing, QuantizedTensor unit tests (+ hypothesis roundtrips)."""

import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.quant import (
    GridSpec,
    compute_grid,
    compute_grid_excluding_outliers,
    dequantize_codes,
    pack_codes,
    quantize_codes,
    quantize_dequantize,
    quantize_tensor,
    unpack_codes,
)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("symmetric", [False, True])
def test_grid_covers_range(bits, symmetric, rng):
    w = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    spec = GridSpec(bits=bits, symmetric=symmetric)
    wq = quantize_dequantize(w, compute_grid(w, spec))
    # quantization error bounded by half a grid step
    grid = compute_grid(w, spec)
    step = np.asarray(grid.scale).max()
    assert float(jnp.max(jnp.abs(w - wq))) <= step * 0.5 + 1e-6


def test_grid_idempotent(rng):
    w = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    grid = compute_grid(w, GridSpec(bits=3))
    w1 = quantize_dequantize(w, grid)
    w2 = quantize_dequantize(w1, grid)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=0, atol=0)


def test_group_size(rng):
    w = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    spec = GridSpec(bits=4, group_size=16)
    grid = compute_grid(w, spec)
    assert grid.scale.shape == (4, 4)
    err_grouped = float(jnp.abs(w - quantize_dequantize(w, grid)).mean())
    err_channel = float(
        jnp.abs(w - quantize_dequantize(w, compute_grid(w, GridSpec(bits=4)))).mean()
    )
    assert err_grouped <= err_channel + 1e-7  # finer grids can't be worse


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    p=st.integers(1, 70),
    q=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_roundtrip(bits, p, q, seed):
    r = np.random.default_rng(seed)
    codes = jnp.asarray(r.integers(0, 2**bits, (q, p)).astype(np.uint8))
    packed = pack_codes(codes, bits)
    assert packed.shape[-1] == -(-p * bits // 8)
    out = unpack_codes(packed, bits, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_outlier_grid_shrinks_range(rng):
    w = rng.standard_normal((8, 64)).astype(np.float32)
    w[0, 0] = 100.0
    w = jnp.asarray(w)
    mask = jnp.zeros((8, 64), bool).at[0, 0].set(True)
    g_full = compute_grid(w, GridSpec(bits=3))
    g_shrunk = compute_grid_excluding_outliers(w, GridSpec(bits=3), mask)
    assert float(g_shrunk.scale[0, 0]) < float(g_full.scale[0, 0]) / 5


def test_quantized_tensor_roundtrip(rng):
    w = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    qt = quantize_tensor(w, GridSpec(bits=8))
    err = float(jnp.max(jnp.abs(qt.dequantize() - w)))
    assert err < 0.02
    assert 8.0 <= qt.bits_per_weight() < 12.0


def test_packed_quantized_tensor(rng):
    """Packed int4 QT dequantizes identically to unpacked (§Perf H1)."""
    import dataclasses as dc

    from repro.quant import GridSpec, quantize_tensor

    w = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    qt = quantize_tensor(w, GridSpec(bits=4))
    packed = dc.replace(qt, codes=pack_codes(qt.codes, 4), packed=True)
    assert packed.shape == qt.shape
    np.testing.assert_array_equal(
        np.asarray(packed.unpacked_codes()), np.asarray(qt.codes)
    )
    np.testing.assert_allclose(
        np.asarray(packed.dequantize()), np.asarray(qt.dequantize())
    )


# ---------------------------------------------------------------------------
# Ragged group grids through the quantization-side path (PR-2's serving-side
# ceil-inference bug had no quantization-side twin — these pin that down).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_ragged_group_roundtrip(bits, rng):
    """compute_grid → quantize_codes → pack → unpack → dequantize at
    p=384 / group_size=256 (ragged last group of 128) is bit-exact against
    the unpacked quantize-dequantize operator, for every code width."""
    w = jnp.asarray(rng.standard_normal((8, 384)).astype(np.float32))
    spec = GridSpec(bits=bits, group_size=256)
    grid = compute_grid(w, spec)
    assert grid.scale.shape == (8, 2)  # ceil(384 / 256)
    codes = quantize_codes(w, grid)
    unpacked = unpack_codes(pack_codes(codes, bits), bits, 384)
    assert np.array_equal(np.asarray(codes), np.asarray(unpacked))
    deq = dequantize_codes(unpacked, grid)
    np.testing.assert_array_equal(
        np.asarray(deq), np.asarray(quantize_dequantize(w, grid))
    )


def test_ragged_group_scales_match_sliced_reference(rng):
    """Group (scale, zero) at a ragged boundary equal per-slice grids: the
    128-wide tail group must use only its own columns (edge-padding in
    _group_reduce must never widen a range)."""
    w = np.asarray(rng.standard_normal((8, 384)), np.float32)
    # Make the global extremes live in the tail group so leakage would show.
    w[:, 300] = 9.0
    w[:, 301] = -9.0
    grid = compute_grid(jnp.asarray(w), GridSpec(bits=4, group_size=256))
    for g, (lo, hi) in enumerate([(0, 256), (256, 384)]):
        blk = w[:, lo:hi]
        wmin = np.minimum(blk.min(1), 0.0)
        wmax = np.maximum(blk.max(1), 0.0)
        np.testing.assert_allclose(
            np.asarray(grid.scale)[:, g], np.maximum((wmax - wmin) / 15, 1e-12),
            rtol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(grid.zero)[:, g], np.round(-wmin / np.asarray(grid.scale)[:, g]),
            rtol=0, atol=0,
        )


def test_ragged_group_excluding_outliers(rng):
    """Outlier-shrunk grids honor ragged group boundaries: a huge outlier in
    the tail group must not widen either group's range."""
    w = np.asarray(rng.standard_normal((4, 384)), np.float32)
    w[:, 380] = 50.0
    mask = np.zeros((4, 384), bool)
    mask[:, 380] = True
    grid = compute_grid_excluding_outliers(
        jnp.asarray(w), GridSpec(bits=3, group_size=256), jnp.asarray(mask)
    )
    kept = np.where(mask, 0.0, w)
    for g, (lo, hi) in enumerate([(0, 256), (256, 384)]):
        blk = np.where(mask[:, lo:hi], np.nan, w[:, lo:hi])
        wmin = np.minimum(np.nanmin(blk, 1), 0.0)
        wmax = np.maximum(np.nanmax(blk, 1), 0.0)
        np.testing.assert_allclose(
            np.asarray(grid.scale)[:, g], np.maximum((wmax - wmin) / 7, 1e-12),
            rtol=1e-6,
        )
    assert bool(np.isfinite(np.asarray(grid.scale)).all())


def test_ragged_group_quantized_tensor_dequant(rng):
    """QuantizedTensor round-trip (incl. packed int4) on a ragged grid
    dequantizes on the true 256-column boundary, not ceil(p/n_groups)."""
    w = jnp.asarray(rng.standard_normal((8, 384)).astype(np.float32))
    qt = quantize_tensor(w, GridSpec(bits=4, group_size=256))
    ref = quantize_dequantize(w, compute_grid(w, GridSpec(bits=4, group_size=256)))
    np.testing.assert_array_equal(np.asarray(qt.dequantize()), np.asarray(ref))


# ---------------------------------------------------------------------------
# Tile-native prepack + int4 KV packing (DESIGN.md §Packed-serving)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("p,tile_k", [(1024, 512), (640, 128), (384, 128),
                                      (300, 128), (128, 128), (96, 128)])
def test_prepack_roundtrip(bits, p, tile_k, rng):
    """prepack → unprepack is the identity for every code width, including
    ragged tails past the last full tile and p < tile_k (no full tile)."""
    from repro.quant.pack import prepack_codes, unprepack_codes

    codes = jnp.asarray(
        rng.integers(0, 2 ** bits, (5, p)).astype(np.uint8)
    )
    pre = prepack_codes(codes, bits, tile_k)
    assert pre.shape[-1] == -(-p * bits // 8)
    out = unprepack_codes(pre, bits, p, tile_k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_prepack_is_pure_permutation(rng):
    """The tile-native transform only reorders columns: byte i of a 4-bit
    full tile holds columns (i, i + tile_k/2) in its (lo, hi) nibbles."""
    from repro.quant.pack import tile_native_perm

    p, tk = 256, 128
    perm = tile_native_perm(p, 4, tk)
    assert sorted(perm.tolist()) == list(range(p))
    # first storage byte of tile 0 packs columns (0, tk//2)
    assert perm[0] == 0 and perm[1] == tk // 2
    # ragged tail (p=300) keeps linear order past the last full tile
    tail = tile_native_perm(300, 4, 128)[256:]
    np.testing.assert_array_equal(tail, np.arange(256, 300))


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_prepacked_qt_dequant_bit_exact(bits, rng):
    """Dequantizing through the tile-native layout is bit-exact vs linear
    (it is a pure column permutation of the same codes)."""
    from repro.quant.pack import prepack_codes, unprepack_codes

    w = jnp.asarray(rng.standard_normal((8, 384)).astype(np.float32))
    qt = quantize_tensor(w, GridSpec(bits=bits, group_size=128))
    pre = prepack_codes(qt.codes, bits, 128)
    back = unprepack_codes(pre, bits, 384, 128)
    import dataclasses as dc

    deq = dc.replace(qt, codes=back).dequantize()
    np.testing.assert_array_equal(np.asarray(deq), np.asarray(qt.dequantize()))


@pytest.mark.parametrize("shape", [(3, 7, 2, 16), (1, 5, 30), (4, 64)])
def test_kv_pack_int4_roundtrip(shape, rng):
    """Fold-in-half int4 KV packing round-trips signed codes in [-7, 7]
    at odd page/slot counts; packed plane is half the head dim."""
    from repro.quant.pack import kv_pack_int4, kv_unpack_int4

    codes = jnp.asarray(rng.integers(-7, 8, shape).astype(np.int8))
    packed = kv_pack_int4(codes)
    assert packed.dtype == jnp.uint8
    assert packed.shape == shape[:-1] + (shape[-1] // 2,)
    np.testing.assert_array_equal(
        np.asarray(kv_unpack_int4(packed)), np.asarray(codes)
    )


def test_kv_pack_int4_rejects_odd_head_dim(rng):
    from repro.quant.pack import kv_pack_int4

    with pytest.raises(ValueError, match="even head dim"):
        kv_pack_int4(jnp.zeros((2, 15), jnp.int8))
