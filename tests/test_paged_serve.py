"""Paged-KV serving: kernel parity, engine edge cases, prefix cache / COW /
preemption determinism (DESIGN.md §Paged-serving)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.models import (
    init_paged_cache,
    init_params,
    make_plan,
    paged_cache_shapes,
)
from repro.serve.engine import PagedServingEngine, Request, ServingEngine
from repro.serve.kv_cache import NULL_PAGE, PagePool
from tests.conftest import reduce_cfg


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


def _rand_paged(rng, *, B=3, KVp=2, G=2, hd=16, psz=8, P=9, npg=4, int8=False):
    q = jnp.asarray(rng.standard_normal((B, KVp, G, hd)), jnp.bfloat16)
    if int8:
        kp = jnp.asarray(rng.integers(-127, 128, (P, psz, KVp, hd)).astype(np.int8))
        vp = jnp.asarray(rng.integers(-127, 128, (P, psz, KVp, hd)).astype(np.int8))
        ks = jnp.asarray((rng.random((P, psz, KVp, 1)) * 0.02 + 1e-3).astype(np.float32))
        vs = jnp.asarray((rng.random((P, psz, KVp, 1)) * 0.02 + 1e-3).astype(np.float32))
    else:
        kp = jnp.asarray(rng.standard_normal((P, psz, KVp, hd)), jnp.bfloat16)
        vp = jnp.asarray(rng.standard_normal((P, psz, KVp, hd)), jnp.bfloat16)
        ks = vs = None
    pt = jnp.asarray(rng.integers(0, P, (B, npg)).astype(np.int32))
    ln = jnp.asarray(rng.integers(1, npg * psz + 1, (B,)).astype(np.int32))
    return q, kp, vp, pt, ln, ks, vs


@pytest.mark.parametrize("window,softcap", [(None, None), (9, None), (None, 30.0)])
def test_paged_kernel_matches_ref_bf16(rng, window, softcap):
    q, kp, vp, pt, ln, _, _ = _rand_paged(rng)
    o_ref = ref.paged_attention_ref(q, kp, vp, pt, ln, window=window, attn_softcap=softcap)
    o_k = paged_attention_pallas(
        q, kp, vp, pt, ln, window=window, attn_softcap=softcap, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_k, np.float32), atol=2e-2
    )


def test_paged_kernel_matches_ref_int8(rng):
    q, kp, vp, pt, ln, ks, vs = _rand_paged(rng, int8=True)
    o_ref = ref.paged_attention_ref(q, kp, vp, pt, ln, k_scale_pages=ks, v_scale_pages=vs)
    o_k = paged_attention_pallas(
        q, kp, vp, pt, ln, k_scale_pages=ks, v_scale_pages=vs, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_k, np.float32), atol=2e-2
    )


def test_paged_ref_matches_contiguous_decode_attention(rng):
    """A paged read over the same KV values is bit-identical to the
    contiguous decode_attention read (the engine-parity cornerstone)."""
    from repro.models.common import decode_attention

    q, kp, vp, pt, ln, _, _ = _rand_paged(rng)
    B, KVp, G, hd = q.shape
    psz = kp.shape[1]
    S = pt.shape[1] * psz
    kc = kp[pt].reshape(B, S, KVp, hd)
    vc = vp[pt].reshape(B, S, KVp, hd)
    o_pg = ref.paged_attention_ref(q, kp, vp, pt, ln)
    o_ct = decode_attention(q[:, None], kc, vc, ln)[:, 0]
    assert np.array_equal(np.asarray(o_pg, np.float32), np.asarray(o_ct, np.float32))


def test_paged_dispatch_guards_int8_without_scales(rng):
    q, kp, vp, pt, ln, ks, vs = _rand_paged(rng, int8=True)
    with pytest.raises(ValueError):
        ops.paged_attention(q, kp, vp, pt, ln)  # int8 pages need scale planes
    with pytest.raises(ValueError):
        ops.paged_attention(q, kp, vp, pt, ln, k_scale_pages=ks)  # both or none
    out = ops.paged_attention(q, kp, vp, pt, ln, k_scale_pages=ks, v_scale_pages=vs)
    assert out.shape == q.shape


def test_paged_vmem_gate():
    assert ops.paged_attention_fits_vmem(16, 8, 4, 128)
    assert not ops.paged_attention_fits_vmem(4096, 64, 8, 128)


# ---------------------------------------------------------------------------
# Page pool
# ---------------------------------------------------------------------------


def test_page_pool_alloc_release_refcount():
    pool = PagePool(6, 8)  # pages 1..5 allocatable
    got = pool.alloc(5)
    assert sorted(got) == [1, 2, 3, 4, 5] and pool.alloc(1) is None
    pool.incref(got[0])
    pool.release(got[0])
    assert pool.n_free == 0  # still referenced once
    for p in got:
        pool.release(p)
    assert pool.n_free == 5


def test_page_pool_prefix_cache_park_revive_evict():
    pool = PagePool(4, 2)
    (a,) = pool.alloc(1)
    pool.register(a, (7, 8))
    pool.release(a)
    assert pool.n_free == 3  # parked but evictable
    pages, n = pool.match_full((7, 8, 9))
    assert pages == [a] and n == 2  # revived + increfed
    pool.release(a)
    # exhaust the pool: the parked page is evicted last and unregistered
    got = pool.alloc(3)
    assert a in got and pool.n_evictions == 1
    assert pool.match_full((7, 8)) == ([], 0)


def test_page_pool_partial_match():
    pool = PagePool(4, 4)
    a, b = pool.alloc(2)
    pool.register(a, (1, 2, 3, 4))
    pool.register(b, (1, 2, 3, 4, 5, 6, 7, 8))
    # full-page prefix (1,2,3,4) matched; tail (5,6) continues into b
    pages, n = pool.match_full((1, 2, 3, 4, 5, 6))
    assert pages == [a] and n == 4
    assert pool.match_partial((1, 2, 3, 4, 5, 6), 4) == b
    assert pool.match_partial((1, 2, 3, 4, 9, 9), 4) is None
    for p in pages:
        pool.release(p)


def test_page_pool_match_partial_cow_siblings():
    """COW lookup among several children of one matched prefix: the source
    is the sibling whose leading tokens equal the tail, full-page and empty
    tails never COW, and the lookup transfers no ownership."""
    pool = PagePool(6, 4)
    a, b, c = pool.alloc(3)
    pool.register(a, (1, 2, 3, 4))
    # Two siblings continue the same parent prefix with different tokens.
    pool.register(b, (1, 2, 3, 4, 5, 6, 7, 8))
    pool.register(c, (1, 2, 3, 4, 9, 9, 9, 9))
    assert pool.match_partial((1, 2, 3, 4, 5, 6), 4) == b
    assert pool.match_partial((1, 2, 3, 4, 9, 9, 9), 4) == c
    # Tail diverges from every sibling -> no COW source.
    assert pool.match_partial((1, 2, 3, 4, 5, 9), 4) is None
    # A full-page tail is match_full territory, never a COW copy...
    assert pool.match_partial((1, 2, 3, 4, 5, 6, 7, 8), 4) is None
    # ... and an empty tail has nothing to copy.
    assert pool.match_partial((1, 2, 3, 4), 4) is None
    # match_partial does not incref: the caller copies synchronously and
    # the source page keeps exactly its pre-lookup ownership.
    assert pool.ref[b] == 1 and pool.ref[c] == 1


def test_page_pool_evict_under_park_lru_order():
    """Parked (registered, refcount-0) pages are evicted in park order,
    eviction unregisters, and an incref revival removes the page from
    eviction candidacy while keeping its registration."""
    pool = PagePool(4, 2)
    a, b, c = pool.alloc(3)
    pool.register(a, (1, 2))
    pool.register(b, (3, 4))
    pool.register(c, (5, 6))
    # Park in order b, a, c — that order is the LRU eviction order.
    pool.release(b)
    pool.release(a)
    pool.release(c)
    assert pool.n_free == 3 and pool.free == []
    # Revive a: it leaves the parked list and cannot be evicted.
    pool.incref(a)
    assert pool.n_free == 2
    (first,) = pool.alloc(1)
    assert first == b and pool.n_evictions == 1  # earliest-parked goes first
    assert pool.match_full((3, 4)) == ([], 0)  # eviction unregistered b
    (second,) = pool.alloc(1)
    assert second == c and pool.n_evictions == 2
    # a survived park-and-revive with its registration intact.
    pages, n = pool.match_full((1, 2))
    assert pages == [a] and n == 2 and pool.ref[a] == 2


# ---------------------------------------------------------------------------
# Engine behaviour
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_model():
    cfg = reduce_cfg(
        get_config("stablelm_12b"), d_model=96, head_dim=24, d_ff=192, n_periods=2
    )
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (6, 21, 47, 11, 33)]
    return plan, params, prompts


def _serve(eng, prompts, max_new=7):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]


def test_paged_engine_token_identical_to_contiguous(served_model):
    plan, params, prompts = served_model
    contig = _serve(
        ServingEngine(plan, params, max_batch=2, max_seq=128, prefill_pad=8), prompts
    )
    paged = _serve(
        PagedServingEngine(
            plan, params, max_batch=2, max_seq=128, page_size=8, prefill_chunk=16
        ),
        prompts,
    )
    assert contig == paged


def test_paged_long_prompt_spans_many_chunks(served_model):
    """A prompt far longer than one prefill chunk streams in chunked; the
    47-token prompt above needs ceil(47/16)=3 chunks and still matches."""
    plan, params, prompts = served_model
    eng = PagedServingEngine(
        plan, params, max_batch=1, max_seq=128, page_size=8, prefill_chunk=16
    )
    out = _serve(eng, [prompts[2]])
    assert eng.n_prefill_chunks == 3
    big = PagedServingEngine(
        plan, params, max_batch=1, max_seq=128, page_size=8, prefill_chunk=64
    )
    assert out == _serve(big, [prompts[2]])


def test_paged_max_new_tokens_zero(served_model):
    plan, params, prompts = served_model
    for eng in (
        ServingEngine(plan, params, max_batch=2, max_seq=64),
        PagedServingEngine(plan, params, max_batch=2, max_seq=64, page_size=8),
    ):
        eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=0))
        fin = eng.run()
        assert fin[0].done and fin[0].output == []
    assert eng.pool.n_free == eng.n_pages - 1  # no pages leaked


def test_paged_page_refill_mid_decode(served_model):
    """page_size=4 with 11+7 tokens forces fresh page allocation mid-decode;
    outputs still match the contiguous engine."""
    plan, params, prompts = served_model
    contig = _serve(
        ServingEngine(plan, params, max_batch=2, max_seq=64, prefill_pad=8),
        prompts[:2],
    )
    eng = PagedServingEngine(
        plan, params, max_batch=2, max_seq=64, page_size=4, prefill_chunk=8
    )
    assert _serve(eng, prompts[:2]) == contig


def test_paged_unaligned_max_seq_pad_overflow(served_model):
    """max_seq not page-aligned: the final chunk's pad window extends past
    the page table; pad writes must hit the null page, not clamp onto the
    last real page and clobber valid prompt KV (regression)."""
    plan, params, _ = served_model
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, 250, 50).astype(np.int32)
    contig = _serve(
        ServingEngine(plan, params, max_batch=1, max_seq=64, prefill_pad=8),
        [prompt], max_new=4,
    )
    paged = _serve(
        PagedServingEngine(
            plan, params, max_batch=1, max_seq=55, page_size=8, prefill_chunk=16
        ),
        [prompt], max_new=4,
    )
    assert contig == paged


def test_prefix_cache_hit_bit_identical(served_model):
    plan, params, prompts = served_model
    eng = PagedServingEngine(
        plan, params, max_batch=1, max_seq=128, page_size=8,
        prefill_chunk=16, record_logits=True,
    )
    eng.submit(Request(rid=0, prompt=prompts[2], max_new_tokens=5))
    eng.run()
    warm_before = eng.n_prefill_tokens
    eng.submit(Request(rid=1, prompt=prompts[2], max_new_tokens=5))
    eng.run()
    o0, o1 = (r.output for r in sorted(eng.finished, key=lambda r: r.rid))
    assert o0 == o1
    # 47-token prompt → 5 full pages (40 tokens) reused; only the 7-token
    # tail re-prefills
    assert eng.n_prefix_hit_tokens == 40
    assert eng.n_prefill_tokens - warm_before == 7
    assert all(
        np.array_equal(a, b)
        for a, b in zip(eng.logit_trace[0], eng.logit_trace[1])
    )


def test_prefix_cache_cow_partial_page(served_model):
    """A prompt that diverges mid-page from a cached sequence copies the
    shared page (COW) and produces the same outputs as a cold run."""
    plan, params, prompts = served_model
    rng = np.random.default_rng(11)
    A = rng.integers(0, 250, 48).astype(np.int32)  # 6 full pages of 8
    eng = PagedServingEngine(
        plan, params, max_batch=1, max_seq=128, page_size=8, prefill_chunk=16
    )
    eng.submit(Request(rid=0, prompt=A, max_new_tokens=4))
    eng.run()
    eng.submit(Request(rid=1, prompt=A[:43], max_new_tokens=4))
    eng.run()
    assert eng.n_cow_hits == 1
    warm = [r for r in eng.finished if r.rid == 1][0].output
    cold = PagedServingEngine(
        plan, params, max_batch=1, max_seq=128, page_size=8,
        prefill_chunk=16, prefix_cache=False,
    )
    cold.submit(Request(rid=1, prompt=A[:43], max_new_tokens=4))
    assert warm == cold.run()[0].output


def test_full_prefix_hit_never_writes_live_shared_page(served_model):
    """A full-coverage prefix hit arms a replay decode at a position inside
    the last matched page; replay bytes are decode-path (≈1 ulp from the
    prefill-path bytes), so the engine must COW that page instead of
    writing through the share (regression: live sharer mutation)."""
    plan, params, _ = served_model
    rng = np.random.default_rng(17)
    A = rng.integers(0, 250, 48).astype(np.int32)  # 6 full pages of 8

    def run(with_b):
        eng = PagedServingEngine(
            plan, params, max_batch=2, max_seq=128, page_size=8, prefill_chunk=16
        )
        eng.submit(Request(rid=0, prompt=A, max_new_tokens=12))
        for _ in range(8):  # A prefilled + registered, mid-decode
            eng.step()
        snap = None
        if with_b:
            # the page B's replay would write without COW: A's 2nd page
            # (B = A[:16] → replay position 15 lives in page index 1)
            shared = eng.lanes[0].pages[1]
            snap = np.asarray(eng.cache["b0"]["k"][:, shared])
            eng.submit(Request(rid=1, prompt=A[:16], max_new_tokens=4))
        eng.run()
        if with_b:
            # B full-hit pages 0-1 of A's prompt; A's pages stay untouched.
            # A's own page-aligned prompt also guard-copied its registered
            # final page before the replay wrote it.
            assert eng.n_cow_hits == 1
            assert eng.n_guard_copies == 1
            after = np.asarray(eng.cache["b0"]["k"][:, shared])
            assert np.array_equal(snap, after)
        return [r.output for r in sorted(eng.finished, key=lambda r: r.rid)]

    solo = run(False)[0]
    both = run(True)
    assert both[0] == solo  # the live sharer is unperturbed by B's arrival
    cold = PagedServingEngine(
        plan, params, max_batch=1, max_seq=128, page_size=8,
        prefill_chunk=16, prefix_cache=False,
    )
    cold.submit(Request(rid=1, prompt=A[:16], max_new_tokens=4))
    assert both[1] == cold.run()[0].output  # warm B ≡ cold B


def test_eviction_then_resume_deterministic(served_model):
    """A pool too small for the full batch forces preemption; resumed
    sequences re-prefill (prompt + generated) and finish with outputs
    identical to an ample-pool run."""
    plan, params, prompts = served_model
    ample = _serve(
        PagedServingEngine(
            plan, params, max_batch=3, max_seq=128, page_size=8, prefill_chunk=16
        ),
        prompts,
    )
    tight = PagedServingEngine(
        plan, params, max_batch=3, max_seq=128, page_size=8, n_pages=13,
        prefill_chunk=16, prefix_cache=False,
    )
    assert _serve(tight, prompts) == ample
    assert tight.n_preemptions >= 1
    assert tight.pool.n_free == tight.n_pages - 1  # all pages returned


def test_paged_int8_kv_tracks_contiguous(served_model):
    plan_bf, params, prompts = served_model
    plan8 = make_plan(plan_bf.cfg, 1, kv_cache_dtype="int8")
    contig = _serve(
        ServingEngine(plan8, params, max_batch=2, max_seq=128, prefill_pad=8),
        prompts[:3], max_new=5,
    )
    paged = _serve(
        PagedServingEngine(
            plan8, params, max_batch=2, max_seq=128, page_size=8, prefill_chunk=16
        ),
        prompts[:3], max_new=5,
    )
    # Chunked prefill attends the *dequantized pages* while the contiguous
    # engine attends fresh bf16 k/v — a near-tie token flip then compounds
    # greedily, so int8 asserts agreement, not identity (same threshold as
    # the quantized-vs-dense engine test).
    agree = np.mean([a == b for x, y in zip(paged, contig) for a, b in zip(x, y)])
    assert agree > 0.5


def test_paged_cache_rejects_unsupported_archs():
    cfg = reduce_cfg(get_config("jamba_1_5_large"))
    plan = make_plan(cfg, 1)
    with pytest.raises(ValueError):
        paged_cache_shapes(plan, 8, 8)


def test_submit_rejects_oversized_request(served_model):
    plan, params, _ = served_model
    eng = PagedServingEngine(plan, params, max_batch=1, max_seq=64, page_size=8)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(60, np.int32), max_new_tokens=16))


# ---------------------------------------------------------------------------
# Window-boundary regressions: prompts that exactly fill (or overflow) the
# sequence window must be handled identically — and cleanly — by both
# engines.  A full-window prompt used to finish silently with zero output on
# the contiguous engine (and an over-long one crashed prefill with an opaque
# numpy broadcast error mid-run).
# ---------------------------------------------------------------------------


def test_window_filling_prompt_rejected_both_engines(served_model):
    """len(prompt) == max_seq with max_new > 0: decode of token 0 has no
    position left to advance into — both engines reject at submit."""
    plan, params, _ = served_model
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 250, 64).astype(np.int32)
    for eng in (
        ServingEngine(plan, params, max_batch=1, max_seq=64, prefill_pad=8),
        PagedServingEngine(plan, params, max_batch=1, max_seq=64, page_size=8),
    ):
        with pytest.raises(ValueError):
            eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))


def test_overlong_prompt_rejected_at_submit(served_model):
    """len(prompt) > max_seq is rejected at submit (contiguous engine used
    to crash later, inside prefill, with a broadcast error)."""
    plan, params, _ = served_model
    eng = ServingEngine(plan, params, max_batch=1, max_seq=64, prefill_pad=8)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(65, np.int32), max_new_tokens=0))


def test_window_filling_prompt_max_new_zero_ok(served_model):
    """len(prompt) == max_seq with max_new == 0 is valid on both engines:
    prefill stays in-bounds and the request retires with empty output."""
    plan, params, _ = served_model
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 250, 64).astype(np.int32)
    for eng in (
        ServingEngine(plan, params, max_batch=1, max_seq=64, prefill_pad=8),
        PagedServingEngine(plan, params, max_batch=1, max_seq=64, page_size=8),
    ):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=0))
        fin = eng.run()
        assert [r.output for r in fin] == [[]] and fin[0].done


def test_exact_fit_generates_all_tokens_both_engines(served_model):
    """prompt + max_new == max_seq (== pages_per_seq · page_size for the
    paged engine) generates every requested token, decode never writes past
    the table, and the engines stay token-identical."""
    plan, params, _ = served_model
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, 250, 60).astype(np.int32)  # 60 + 4 == 64 == 8*8
    outs = []
    for eng in (
        ServingEngine(plan, params, max_batch=1, max_seq=64, prefill_pad=8),
        PagedServingEngine(plan, params, max_batch=1, max_seq=64, page_size=8,
                           prefill_chunk=16),
    ):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        fin = eng.run()
        assert len(fin) == 1 and len(fin[0].output) == 4
        outs.append(fin[0].output)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# int4-packed KV pages
# ---------------------------------------------------------------------------


def _rand_paged_int4(rng, *, B=3, KVp=2, G=2, hd=16, psz=8, P=9, npg=4):
    from repro.quant.pack import kv_pack_int4

    q = jnp.asarray(rng.standard_normal((B, KVp, G, hd)), jnp.bfloat16)
    kc = jnp.asarray(rng.integers(-7, 8, (P, psz, KVp, hd)).astype(np.int8))
    vc = jnp.asarray(rng.integers(-7, 8, (P, psz, KVp, hd)).astype(np.int8))
    ks = jnp.asarray((rng.random((P, psz, KVp, 1)) * 0.02 + 1e-3).astype(np.float32))
    vs = jnp.asarray((rng.random((P, psz, KVp, 1)) * 0.02 + 1e-3).astype(np.float32))
    pt = jnp.asarray(rng.integers(0, P, (B, npg)).astype(np.int32))
    ln = jnp.asarray(rng.integers(1, npg * psz + 1, (B,)).astype(np.int32))
    return q, kv_pack_int4(kc), kv_pack_int4(vc), pt, ln, ks, vs


def test_paged_kernel_matches_ref_int4(rng):
    """int4-packed pages (uint8, 2 codes/byte, fold-in-half) unpack
    in-kernel and match the XLA oracle within the bf16 tolerance."""
    q, kp, vp, pt, ln, ks, vs = _rand_paged_int4(rng)
    assert kp.dtype == jnp.uint8 and kp.shape[-1] == 8  # hd // 2
    o_ref = ref.paged_attention_ref(q, kp, vp, pt, ln, k_scale_pages=ks, v_scale_pages=vs)
    o_k = paged_attention_pallas(
        q, kp, vp, pt, ln, k_scale_pages=ks, v_scale_pages=vs, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_k, np.float32), atol=2e-2
    )


def test_paged_dispatch_guards_int4_without_scales(rng):
    q, kp, vp, pt, ln, ks, vs = _rand_paged_int4(rng)
    with pytest.raises(ValueError):
        ops.paged_attention(q, kp, vp, pt, ln)  # packed pages need scales
    out = ops.paged_attention(q, kp, vp, pt, ln, k_scale_pages=ks, v_scale_pages=vs)
    assert out.shape == q.shape


def test_paged_int4_kv_bounded_perturbation(served_model):
    """int4 KV quantize-on-write perturbs the first decode logits boundedly
    (observed ~0.06 on a ~0.6 logit scale at this shape; asserted at 4x
    margin), int8 perturbs strictly less (finer grid), and the page-read
    counter prices int4 traffic at 0.5 B/elem via page_nbytes.

    Token-agreement vs bf16 is NOT asserted: random-init logits are
    near-uniform, so 4-bit KV noise legitimately flips greedy near-ties.
    """
    from repro.serve.kv_cache import page_nbytes

    plan_bf, params, prompts = served_model

    def first_logits(plan):
        eng = PagedServingEngine(
            plan, params, max_batch=2, max_seq=128, page_size=8,
            prefill_chunk=16, record_logits=True,
        )
        for i, p in enumerate(prompts[:3]):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        eng.run()
        return eng, {i: np.asarray(tr[0]) for i, tr in eng.logit_trace.items()}

    _, lg_bf = first_logits(plan_bf)
    eng4, lg4 = first_logits(make_plan(plan_bf.cfg, 1, kv_cache_dtype="int4"))
    eng8, lg8 = first_logits(make_plan(plan_bf.cfg, 1, kv_cache_dtype="int8"))
    d4 = max(float(np.abs(lg4[i] - lg_bf[i]).max()) for i in lg_bf)
    d8 = max(float(np.abs(lg8[i] - lg_bf[i]).max()) for i in lg_bf)
    assert 0 < d4 < 0.25
    assert d8 < d4
    plan4, hp = eng4.plan, eng4.plan.heads
    assert eng4.n_kv_page_reads > 0
    assert eng4.kv_read_bytes() == eng4.n_kv_page_reads * page_nbytes(
        8, hp.kv_pad, hp.head_dim, plan4.cfg.n_periods, "int4"
    )
    # packed pages really are half-width: int4 page bytes < int8 page bytes
    assert page_nbytes(8, hp.kv_pad, hp.head_dim, plan4.cfg.n_periods, "int4") < \
        page_nbytes(8, hp.kv_pad, hp.head_dim, plan4.cfg.n_periods, "int8")


def test_contiguous_cache_rejects_int4(served_model):
    """int4 KV is paged-only: the fold-in-half pages live in the paged pool;
    the contiguous reservation has no packed layout."""
    plan_bf, params, _ = served_model
    plan4 = make_plan(plan_bf.cfg, 1, kv_cache_dtype="int4")
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(plan4, params, max_batch=2, max_seq=64)


def test_paged_int4_cache_shapes(served_model):
    """int4 page planes are uint8 at hd/2 with f32 scale planes alongside."""
    plan_bf, _, _ = served_model
    plan4 = make_plan(plan_bf.cfg, 1, kv_cache_dtype="int4")
    shapes = paged_cache_shapes(plan4, 8, 8)
    hd = plan4.heads.head_dim
    blk = shapes["b0"]
    assert blk["k"].dtype == jnp.uint8 and blk["k"].shape[-1] == hd // 2
    assert blk["ks"].dtype == jnp.float32 and blk["ks"].shape[-1] == 1


def test_int4_eviction_then_resume_deterministic(served_model):
    """Preemption + re-prefill resume under int4 KV: resumed KV is written
    by the prefill path where the original was quantized on decode-write,
    so exact identity vs an ample run is not guaranteed (same near-tie
    compounding as the int8-vs-contiguous test) — but the tight run itself
    is fully deterministic, agrees with the ample run well above chance,
    and returns every page."""
    plan_bf, params, prompts = served_model
    plan4 = make_plan(plan_bf.cfg, 1, kv_cache_dtype="int4")
    kw = dict(max_batch=3, max_seq=128, page_size=8, prefill_chunk=16,
              prefix_cache=False)
    ample = _serve(PagedServingEngine(plan4, params, **kw), prompts)
    tight1 = PagedServingEngine(plan4, params, n_pages=13, **kw)
    out1 = _serve(tight1, prompts)
    tight2 = PagedServingEngine(plan4, params, n_pages=13, **kw)
    assert _serve(tight2, prompts) == out1  # deterministic under preemption
    assert tight1.n_preemptions >= 1
    agree = np.mean([a == b for x, y in zip(out1, ample) for a, b in zip(x, y)])
    assert agree > 0.5
    assert tight1.pool.n_free == tight1.n_pages - 1


def test_admission_livelock_regression(served_model):
    """Regression: a zero-generation request whose prompt fully hits the
    prefix cache used to livelock admission when the matched pages plus the
    one replay COW page exceeded the whole pool — every step re-matched the
    pages, failed the COW alloc, released, and retried forever (run()
    returned with the request still pending).  Such requests now complete
    at admission without touching the pool."""
    plan, params, _ = served_model
    rng = np.random.default_rng(9)
    A = rng.integers(0, 250, 40).astype(np.int32)
    eng = PagedServingEngine(plan, params, max_batch=2, max_seq=128,
                             page_size=8, n_pages=6, prefill_chunk=16)
    # Seed the prefix cache by hand: 5 registered pages covering all of A —
    # exactly n_pages - 1, so a full-coverage hit leaves no room for the
    # +1 replay copy-on-write page.
    pages = eng.pool.alloc(5)
    for j, p in enumerate(pages):
        eng.pool.register(p, tuple(int(t) for t in A[: 8 * (j + 1)]))
        eng.pool.release(p)
    assert eng.pool.n_free == eng.n_pages - 1  # all cached-free
    req = Request(rid=0, prompt=A, max_new_tokens=0)
    eng.submit(req)
    fin = eng.run(max_steps=50)
    assert fin == [req] and req.done
    assert req.status == "completed" and req.output == []
    assert eng.pool.n_free == eng.n_pages - 1  # pool never touched
