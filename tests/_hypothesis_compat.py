"""Import hypothesis, or stub it so only @given tests skip.

Mixed test modules (kernel sweeps + property tests) import from here instead
of hypothesis directly: when hypothesis is missing (it is an optional dev
dependency — requirements-dev.txt), the plain parametrized tests still run
and each @given test collects as a single skipped test instead of killing
the whole module at import.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without dev deps
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
            def _skipped():
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
