"""Reduced-mesh dry-run smoke: lower+compile on forged host devices.

Runs in a SUBPROCESS because xla_force_host_platform_device_count must be
set before jax initializes (the main pytest process keeps 1 device).
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses, json, sys
sys.path.insert(0, "src"); sys.path.insert(0, "tests")
import jax, jax.numpy as jnp
from conftest import reduce_cfg
from repro.configs import get_config
import repro.configs.base as base
from repro.launch.specs import build_cell, CELLS
import repro.launch.specs as specs

mesh = jax.make_mesh((4, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
CELLS["tiny_train"] = dict(seq=64, batch=16, kind="train")
CELLS["tiny_decode"] = dict(seq=64, batch=16, kind="decode")
out = {}
for arch in json.loads(sys.argv[1]):
    cfg = reduce_cfg(get_config(arch), n_kv_heads=min(get_config(arch).n_kv_heads, 2), vocab=256)
    base._REGISTRY[cfg.name] = cfg  # reduced config under the same name
    for shape in ("tiny_train", "tiny_decode"):
        if arch == "whisper_large_v3" and shape == "tiny_decode":
            pass
        spec = build_cell(arch, shape, mesh)
        lowered = jax.jit(spec.fn, donate_argnums=spec.donate).lower(*spec.args)
        compiled = lowered.compile()
        m = compiled.memory_analysis()
        out[f"{arch}/{shape}"] = m.temp_size_in_bytes
print(json.dumps(out))
"""


@pytest.mark.parametrize(
    "archs",
    [["stablelm_12b", "mamba2_2_7b"], ["olmoe_1b_7b", "whisper_large_v3"]],
)
def test_reduced_mesh_dryrun(archs):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT, json.dumps(archs)],
        capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env, timeout=560,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out) == 2 * len(archs)
