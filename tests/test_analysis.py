"""The static-analysis framework itself: rules, suppressions, CLI, and the
repo-cleanliness + fault-plan-validation contracts (DESIGN.md
§Static-analysis)."""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis import run_analysis
from repro.faults import FaultPlan

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _rules(findings):
    return {f.rule for f in findings}


def _run(*names, **kw):
    kw.setdefault("runtime_checks", False)
    paths = [os.path.join(FIXTURES, n) for n in names]
    return run_analysis(paths, **kw)


# ---------------------------------------------------------------------------
# Rules on fixtures
# ---------------------------------------------------------------------------


def test_donation_bad_fixture():
    findings, _ = _run("donation_bad.py")
    assert "donation-use-after-donate" in _rules(findings)
    assert "donation-unbound-result" in _rules(findings)
    # Both hazards are inside the class; check line attribution is sane.
    lines = {f.rule: f.line for f in findings}
    assert lines["donation-use-after-donate"] > lines["donation-unbound-result"] - 20


def test_donation_good_fixture():
    findings, _ = _run("donation_good.py")
    assert not findings


def test_retrace_bad_fixture():
    findings, _ = _run("retrace_bad.py")
    got = _rules(findings)
    assert "retrace-jit-in-loop" in got
    assert "retrace-jit-per-call" in got
    assert "retrace-closure-capture" in got
    assert "retrace-nonhashable-static" in got


def test_retrace_good_fixture():
    findings, _ = _run("retrace_good.py")
    assert not findings


def test_vmem_bad_fixture():
    findings, _ = _run("vmem_bad")
    assert _rules(findings) == {"vmem-ungated-pallas-call"}


def test_vmem_good_fixture():
    findings, _ = _run("vmem_good")
    assert not findings


def test_dtype_bad_fixture():
    findings, _ = _run("dtype_bad.py")
    got = _rules(findings)
    assert "dtype-bf16-accum" in got
    assert "dtype-int-code-arith" in got
    # Both the binop and the reduction form fire.
    assert sum(f.rule == "dtype-int-code-arith" for f in findings) == 2


def test_dtype_good_fixture():
    findings, _ = _run("dtype_good.py")
    assert not findings


def test_faultsite_bad_fixture():
    findings, _ = _run("faultsite_bad")
    got = _rules(findings)
    assert "fault-site-unregistered" in got
    assert "fault-site-unwired" in got
    unwired = [f for f in findings if f.rule == "fault-site-unwired"]
    assert "ghost.site" in unwired[0].message


def test_faultsite_good_fixture():
    findings, _ = _run("faultsite_good")
    assert not findings


def test_suppression_waives_with_rationale_only():
    findings, suppressed = _run("suppressed.py")
    # The rationaled waiver is honored; the bare one surfaces as
    # bad-suppression (and its underlying finding stays waived).
    assert _rules(findings) == {"bad-suppression"}
    assert len(suppressed) == 2
    assert all(s.rule == "retrace-jit-per-call" for s in suppressed)


# ---------------------------------------------------------------------------
# Repo cleanliness (the S1 negative regression: serve/ donation + retrace)
# ---------------------------------------------------------------------------


def test_serve_engine_donation_and_retrace_clean():
    """serve/engine.py + serve/spec.py carry the pool-donation pattern the
    donation pass was built for; pin that they analyze clean so any future
    use-after-donate or per-call re-jit is a test failure, not a review
    catch."""
    findings, suppressed = run_analysis(
        [os.path.join(SRC, "serve", "engine.py"), os.path.join(SRC, "serve", "spec.py")],
        runtime_checks=False,
    )
    assert not findings, [str(f.__dict__) for f in findings]
    assert not suppressed  # clean outright, not waived


def test_whole_repo_analyzes_clean():
    """The headline contract: `python -m repro.analysis` exits 0 — every
    real finding is fixed or carries a written rationale."""
    findings, _ = run_analysis([SRC], runtime_checks=False)
    assert not findings, [str(f.__dict__) for f in findings]


def test_vmem_gate_formulas_hold_for_all_configs():
    """Runtime half of the VMEM pass: every fit gate's byte formula is
    self-consistent across every shipped arch shape (approve ⇒ fits,
    decline ⇒ minimum tile overflows)."""
    from repro.analysis.vmem import check_gate_formulas

    assert check_gate_formulas() == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(SRC, os.pardir))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env,
    )


def test_cli_exit_nonzero_on_each_bad_fixture():
    for bad in ("donation_bad.py", "retrace_bad.py", "vmem_bad",
                "dtype_bad.py", "faultsite_bad"):
        r = _cli(os.path.join(FIXTURES, bad), "--no-runtime")
        assert r.returncode == 1, (bad, r.stdout, r.stderr)


def test_cli_exit_zero_on_good_fixtures_and_json():
    goods = [os.path.join(FIXTURES, g) for g in
             ("donation_good.py", "retrace_good.py", "vmem_good",
              "dtype_good.py", "faultsite_good")]
    r = _cli(*goods, "--no-runtime", "--format", "json")
    assert r.returncode == 0, (r.stdout, r.stderr)
    doc = json.loads(r.stdout)
    assert doc["ok"] and doc["findings"] == []


def test_cli_fix_suggestions_and_usage_error():
    r = _cli(os.path.join(FIXTURES, "retrace_bad.py"), "--no-runtime",
             "--fix-suggestions")
    assert r.returncode == 1
    assert "fix:" in r.stdout
    assert _cli("no/such/path.py").returncode == 2


# ---------------------------------------------------------------------------
# FaultPlan.from_spec hardening (same registry as the parity pass)
# ---------------------------------------------------------------------------


def test_from_spec_rejects_unknown_site_with_pointed_error():
    with pytest.raises(ValueError) as e:
        FaultPlan.from_spec(
            {"faults": [{"site": "engine.stpe", "kind": "transient"}]}
        )
    msg = str(e.value)
    assert "faults[0]" in msg and "engine.stpe" in msg and "engine.step" in msg


def test_from_spec_rejects_unknown_keys_and_missing_required():
    with pytest.raises(ValueError, match=r"faults\[0\].*unknown key.*'stie'"):
        FaultPlan.from_spec({"faults": [{"stie": "engine.step", "kind": "deny",
                                         "site": "engine.step"}]})
    with pytest.raises(ValueError, match=r"faults\[1\].*missing required.*'kind'"):
        FaultPlan.from_spec({"faults": [
            {"site": "engine.step", "kind": "deny"},
            {"site": "engine.step"},
        ]})
    with pytest.raises(ValueError, match="unknown fault-plan key"):
        FaultPlan.from_spec({"seed": 1, "fautls": []})
    with pytest.raises(ValueError, match="JSON object"):
        FaultPlan.from_spec([{"site": "engine.step", "kind": "deny"}])


def test_from_spec_still_accepts_valid_plans():
    plan = FaultPlan.from_spec(
        {"seed": 7, "faults": [
            {"site": "engine.step", "kind": "transient", "at": [1]},
            {"site": "pool.alloc", "kind": "deny", "window": [0, 2],
             "p": 0.5, "max_fires": 1},
        ]}
    )
    assert len(plan.specs) == 2 and plan.seed == 7
