"""Known-bad donation fixture: reads after donate, discarded result."""

import jax


class Engine:
    def __init__(self, step_fn):
        self._decode = jax.jit(
            lambda params, tokens, cache: step_fn(params, tokens, cache),
            donate_argnums=(2,),
        )

    def step_use_after_donate(self, params, tokens):
        out = self._decode(params, tokens, self.cache)
        return out, self.cache.mean()  # BAD: cache was donated above

    def step_discarded(self, params, tokens):
        self._decode(params, tokens, self.cache)  # BAD: result discarded
        return None
