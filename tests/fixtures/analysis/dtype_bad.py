"""Known-bad dtype-flow fixture: bf16 accumulation, raw-code arithmetic."""

import jax.numpy as jnp


def bf16_accum(a, b, matmul_dtype=jnp.bfloat16):
    # BAD: bf16 operands with no preferred_element_type — the accumulator
    # inherits bf16.
    return jnp.dot(a.astype(matmul_dtype), b.astype(matmul_dtype))


def code_arith(codes, scale):
    return codes * scale  # BAD: arithmetic on packed codes before dequant


def code_reduce(codes):
    return jnp.sum(codes)  # BAD: reduction over raw code indices
