SITES = (
    "engine.step",
    "pool.alloc",
)


def fault_point(site):
    return "ok"
