from ..faults.plan import fault_point


def step():
    fault_point("engine.step")
    return True


def alloc():
    if fault_point("pool.alloc") == "deny":
        return None
    return 1
