"""Suppression fixture: one waived finding, one rationale-less waiver."""

import jax


def deliberate_per_call(x):
    # repro: allow[retrace-jit-per-call] -- one-shot AOT probe, wrapper reuse is irrelevant here
    return jax.jit(lambda a: a * 2)(x)


def bare_suppression(x):
    # repro: allow[retrace-jit-per-call]
    return jax.jit(lambda a: a * 3)(x)
