"""Known-good VMEM fixture: pallas_call dominated by a fit gate."""

from jax.experimental import pallas as pl

_BUDGET = 12 * 1024 * 1024


def my_kernel_fits_vmem(n: int) -> bool:
    return n * 4 <= _BUDGET


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def gated_kernel(x):
    return pl.pallas_call(_body, out_shape=x)(x)


def dispatcher(x):
    if not my_kernel_fits_vmem(x.size):
        return x * 2  # XLA fallback
    return gated_kernel(x)
