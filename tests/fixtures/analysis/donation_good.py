"""Known-good donation fixture: the reassign-from-result idiom."""

import jax


class Engine:
    def __init__(self, step_fn):
        self._decode = jax.jit(
            lambda params, tokens, cache: step_fn(params, tokens, cache),
            donate_argnums=(2,),
        )

    def step(self, params, tokens):
        # Same-statement rebind: the attribute tracks the donated-output
        # buffer, so later reads are of the fresh buffer.
        tokens, self.cache = self._decode(params, tokens, self.cache)
        return tokens, self.cache.shape

    def step_rebind_then_read(self, params, tokens):
        out = self._decode(params, tokens, self.cache)
        self.cache = out[1]  # rebind kills the taint
        return self.cache.mean()
