"""Known-good retrace fixture: every blessed wrapper-caching pattern."""

import functools

import jax

module_step = jax.jit(lambda x: x + 1)  # module level: built once


class Engine:
    def __init__(self, fn):
        # Bound once per object construction.
        self._decode = jax.jit(lambda p, t, c: fn(p, t, c), donate_argnums=(2,))

    def run(self, p, t):
        t, self.cache = self._decode(p, t, self.cache)
        return t


@functools.lru_cache(maxsize=None)
def cached_factory(chunk):
    # lru_cache'd factory: one wrapper per chunk value, reused forever.
    return jax.jit(lambda x: x.reshape(chunk, -1))


def returning_factory(plan):
    # Returns the wrapper — the caller binds and reuses it.
    return jax.jit(functools.partial(_score, plan))


def _score(plan, x):
    return x * plan
