"""Known-bad VMEM fixture: pallas_call reachable with no fit gate."""

from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def ungated_kernel(x):
    # BAD: no *_tq / *_fits_vmem gate anywhere on the call path.
    return pl.pallas_call(_body, out_shape=x)(x)


def dispatcher(x):
    return ungated_kernel(x)
