"""Known-good dtype-flow fixture: fp32 accumulation, dequant idioms."""

import jax.numpy as jnp


def bf16_mm_fp32_accum(a, b, matmul_dtype=jnp.bfloat16):
    return jnp.dot(
        a.astype(matmul_dtype),
        b.astype(matmul_dtype),
        preferred_element_type=jnp.float32,
    )


def dequant(codes, scale, zero):
    # The blessed idiom: cast before arithmetic.
    return (codes.astype(jnp.float32) - zero) * scale


def unpack(codes):
    # Bitwise unpacking is exempt.
    lo = codes & 0xF
    hi = codes >> 4
    return lo, hi


def quantize(beta, sc, zc, n_levels):
    # `codes` here is a *float* tensor (round/clip output) that merely
    # shares the name — the float-domain exemption must apply.
    codes = jnp.clip(jnp.round(beta / sc) + zc, 0, n_levels - 1)
    return (codes - zc) * sc
