"""Known-bad retrace fixture: wrapper churn and trace-constant capture."""

import jax
import jax.numpy as jnp

step = jax.jit(lambda x, n: x * n, static_argnums=(1,))


def jit_in_loop(xs):
    out = []
    for x in xs:
        f = jax.jit(lambda a: a + 1)  # BAD: fresh wrapper per iteration
        out.append(f(x))
    return out


def jit_per_call(x):
    return jax.jit(lambda a: a * 2)(x)  # BAD: cache discarded per call


def closure_capture(xs):
    out = []
    for i, x in enumerate(xs):
        # BAD: jitted lambda bakes the loop variable in as a constant.
        g = jax.jit(lambda a: a + i)
        out.append(g(x))
    return out


def nonhashable_static(x):
    return step(x, [1, 2, 3])  # BAD: list in a static position
