from ..faults.plan import fault_point


def step():
    fault_point("engine.step")
    fault_point("engine.stpe")  # BAD: typo — not in SITES
    return True
