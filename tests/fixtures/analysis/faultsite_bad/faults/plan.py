SITES = (
    "engine.step",
    "ghost.site",  # BAD: registered but never instrumented
)


def fault_point(site):
    return "ok"
