"""Train substrate + distribution: optimizer, checkpoints, elasticity, rules."""

import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.trainer import Trainer, TrainerConfig
from tests.conftest import reduce_cfg


def test_adamw_int8_tracks_fp32(rng):
    params = {
        "w": jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal(64).astype(np.float32)),
    }
    grads = jax.tree.map(lambda p: jnp.asarray(
        np.random.default_rng(1).standard_normal(p.shape).astype(np.float32)), params)
    outs = {}
    for moments in ("fp32", "int8"):
        cfg = AdamWConfig(lr=1e-2, moments=moments, warmup_steps=0)
        state = adamw_init(params, cfg)
        p = params
        for _ in range(5):
            p, state, _ = adamw_update(p, grads, state, cfg)
        outs[moments] = p
    diff = float(jnp.max(jnp.abs(outs["fp32"]["w"] - outs["int8"]["w"])))
    step = float(jnp.max(jnp.abs(outs["fp32"]["w"] - params["w"])))
    upd_fp = np.asarray(outs["fp32"]["w"] - params["w"]).ravel()
    upd_q8 = np.asarray(outs["int8"]["w"] - params["w"]).ravel()
    corr = float(np.corrcoef(upd_fp, upd_q8)[0, 1])
    assert corr > 0.99  # quantized moments track fp32 update directions
    assert diff < 0.6 * step  # and never explode (log-domain v)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
        "n": {"b": jnp.asarray(np.random.default_rng(0).standard_normal((5,)))},
        "c": jnp.asarray([3], jnp.int32),
    }
    ckpt.save_checkpoint(str(tmp_path), 7, tree, meta={"data_step": 9})
    out, manifest = ckpt.load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 7 and manifest["meta"]["data_step"] == 9
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    ckpt.save_checkpoint(str(tmp_path), 1, tree)
    # a crashed half-write leaves only .tmp → ignored and cleaned
    os.makedirs(tmp_path / "step_2.tmp")
    ckpt.cleanup_tmp(str(tmp_path))
    assert ckpt.latest_step(str(tmp_path)) == 1
    assert not (tmp_path / "step_2.tmp").exists()


def test_trainer_recovers_from_failure(tmp_path):
    cfg = reduce_cfg(get_config("stablelm_12b"), vocab=128)
    t = Trainer(
        cfg,
        AdamWConfig(lr=1e-3, total_steps=30),
        TrainerConfig(steps=30, batch=4, seq=32, ckpt_every=10,
                      ckpt_dir=str(tmp_path), log_every=10),
    )
    died = []

    def fault(step):
        if step == 15 and not died:
            died.append(1)
            raise RuntimeError("boom")

    out = t.run(fault_hook=fault)
    assert out["recoveries"] == 1
    assert out["log"][-1]["loss"] < out["log"][0]["loss"]


def test_trainer_deterministic_resume(tmp_path):
    """Stop at 20 of 40, resume in a fresh Trainer → same final params as an
    uninterrupted run (exact-step data replay)."""
    cfg = reduce_cfg(get_config("stablelm_12b"), vocab=64, n_periods=1)
    opt = AdamWConfig(lr=1e-3, total_steps=40)

    def mk(steps, d):
        return Trainer(cfg, opt, TrainerConfig(
            steps=steps, batch=4, seq=16, ckpt_every=20, ckpt_dir=d, log_every=40))

    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    t_full = mk(40, d1)
    t_full.run()
    t_half = mk(20, d2)
    t_half.run()
    t_resume = mk(40, d2)  # picks up at step 20 from d2
    t_resume.run()
    for a, b in zip(jax.tree.leaves(t_full.params), jax.tree.leaves(t_resume.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
        )


def test_rules_divisibility_fallbacks():
    import jax as j

    from repro.dist.sharding import make_rules

    if len(j.devices()) != 1:
        pytest.skip("single-device test")
    mesh = j.make_mesh((1, 1), ("data", "model"),
                       axis_types=(j.sharding.AxisType.Auto,) * 2)
    r = make_rules(mesh, n_heads=40, n_kv_heads=8, d_ff=1024, n_experts=8,
                   vocab=50280, d_model=512)
    # axis size 1 ⇒ everything "fits"; fallback logic exercised via spec dedup
    spec = r.spec(("embed", "ffn", "ffn"))  # duplicate mesh axis → later None
    assert spec[2] is None


def test_data_pipeline_deterministic():
    from repro.data.pipeline import DataConfig, make_batch_fn

    cfg = get_config("stablelm_12b")
    f1, _ = make_batch_fn(DataConfig(vocab=256, seed=7), cfg, 4, 32)
    f2, _ = make_batch_fn(DataConfig(vocab=256, seed=7), cfg, 4, 32)
    np.testing.assert_array_equal(f1(123)["tokens"], f2(123)["tokens"])
    assert not np.array_equal(f1(123)["tokens"], f1(124)["tokens"])
