"""Core PTQ algorithm tests: the paper's claims at layer level."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    awq_quantize,
    gptq_quantize,
    layer_objective,
    outlier_quantease,
    quantease_quantize,
    quantease_reference,
    relative_error,
    rtn_quantize,
    spqr_quantize,
)
from repro.quant import GridSpec, compute_grid, quantize_dequantize

SPEC3 = GridSpec(bits=3)


def _err(w, w_hat, sigma):
    return float(relative_error(w, w_hat, sigma))


def test_method_ordering(layer_problem):
    """QuantEase ≤ GPTQ ≤ RTN (paper §3.4) and AWQ ≤ RTN."""
    w, sigma = layer_problem
    e_rtn = _err(w, rtn_quantize(w, SPEC3), sigma)
    e_awq = _err(w, awq_quantize(w, sigma, SPEC3), sigma)
    e_gptq = _err(w, gptq_quantize(w, sigma, SPEC3), sigma)
    e_qe = _err(w, quantease_quantize(w, sigma, SPEC3, iterations=20)[0], sigma)
    assert e_qe < e_gptq < e_rtn
    assert e_awq <= e_rtn + 1e-6


def test_alg1_equals_alg2(layer_problem):
    """Blocked Algorithm 2 reproduces Algorithm 1 exactly (same iterates)."""
    w, sigma = layer_problem
    w_ref = quantease_reference(w, sigma, SPEC3, iterations=3)
    for bsz in (32, 128):
        w_blk, _ = quantease_quantize(
            w, sigma, SPEC3, iterations=3, block_size=bsz, unquantized_heuristic=False
        )
        np.testing.assert_allclose(
            np.asarray(w_ref), np.asarray(w_blk), rtol=0, atol=2e-4
        )


def test_feasibility(layer_problem):
    """Output lies exactly on the per-channel grid (Lemma 2 prerequisite)."""
    w, sigma = layer_problem
    w_hat, _ = quantease_quantize(w, sigma, SPEC3, iterations=4)
    grid = compute_grid(w, SPEC3)
    snapped = quantize_dequantize(w_hat, grid)
    np.testing.assert_allclose(np.asarray(w_hat), np.asarray(snapped), atol=1e-5)


def test_objective_monotone(layer_problem):
    """Non-increasing damped objective from the first feasible iterate."""
    w, sigma = layer_problem
    _, objs = quantease_quantize(
        w, sigma, SPEC3, iterations=10, unquantized_heuristic=False,
        track_objective=True,
    )
    objs = np.asarray(objs)
    assert np.all(np.diff(objs) <= objs[:-1] * 1e-5 + 1e-3)


def test_gptq_init_improves(layer_problem):
    """QuantEase initialized from GPTQ only improves on it (paper §3.1)."""
    w, sigma = layer_problem
    w_g = gptq_quantize(w, sigma, SPEC3)
    w_qg, _ = quantease_quantize(
        w, sigma, SPEC3, iterations=10, w_init=w_g, unquantized_heuristic=False
    )
    assert _err(w, w_qg, sigma) <= _err(w, w_g, sigma) + 1e-7


def test_unquantized_heuristic_helps_or_ties(layer_problem):
    w, sigma = layer_problem
    e_with = _err(w, quantease_quantize(w, sigma, SPEC3, iterations=24)[0], sigma)
    e_without = _err(
        w,
        quantease_quantize(
            w, sigma, SPEC3, iterations=24, unquantized_heuristic=False
        )[0],
        sigma,
    )
    assert e_with <= e_without * 1.05  # heuristic never catastrophically worse


def test_outlier_budget_and_gain(layer_problem):
    w, sigma = layer_problem
    s = int(0.01 * w.size)
    res = outlier_quantease(w, sigma, SPEC3, s=s, iterations=10)
    assert int((np.asarray(res.h) != 0).sum()) <= s
    e_plain = _err(w, quantease_quantize(w, sigma, SPEC3, iterations=10)[0], sigma)
    assert _err(w, res.w_eff, sigma) < e_plain


def test_outlier_structured_columns(layer_problem):
    w, sigma = layer_problem
    s = int(0.02 * w.size)
    res = outlier_quantease(w, sigma, SPEC3, s=s, iterations=8, structured=True)
    h = np.asarray(res.h)
    nz_cols = np.nonzero(np.abs(h).sum(0))[0]
    assert len(nz_cols) <= max(s // w.shape[0], 1)


def test_qe_outliers_beat_spqr(layer_problem):
    """Paper §5.4: QuantEase-outlier beats SpQR at equal budget."""
    w, sigma = layer_problem
    s = int(0.01 * w.size)
    e_spqr = _err(w, spqr_quantize(w, sigma, SPEC3, s=s)[0], sigma)
    e_qe = _err(
        w, outlier_quantease(w, sigma, SPEC3, s=s, iterations=12).w_eff, sigma
    )
    assert e_qe < e_spqr


def test_2bit_needs_outliers(layer_problem):
    """Paper §5.4.1: plain 2-bit collapses; 2% outliers rescue it."""
    w, sigma = layer_problem
    spec2 = GridSpec(bits=2)
    e_plain = _err(w, quantease_quantize(w, sigma, spec2, iterations=12)[0], sigma)
    e_out = _err(
        w,
        outlier_quantease(w, sigma, spec2, s=int(0.02 * w.size), iterations=12).w_eff,
        sigma,
    )
    assert e_out < 0.6 * e_plain


def test_gptq_keep_mask(layer_problem):
    """Kept (outlier) entries stay full precision — they absorb OBS
    corrections (SpQR semantics) but are never rounded — and pinning them
    lowers the total error."""
    w, sigma = layer_problem
    mask = np.zeros(w.shape, bool)
    mask[::7, ::11] = True
    w_hat = gptq_quantize(w, sigma, SPEC3, keep_mask=jnp.asarray(mask))
    grid = compute_grid(w, SPEC3)
    snapped = np.asarray(quantize_dequantize(w_hat, grid))
    off_grid = np.abs(np.asarray(w_hat)[mask] - snapped[mask]) > 1e-6
    assert off_grid.mean() > 0.5  # kept entries are genuinely unquantized
    e_masked = _err(w, w_hat, sigma)
    e_plain = _err(w, gptq_quantize(w, sigma, SPEC3), sigma)
    assert e_masked < e_plain


def test_awq_plus_quantease_improves(layer_problem):
    """Paper §6 conjecture: AWQ scaling + QuantEase ≤ QuantEase alone on
    layers with per-channel activation-scale structure."""
    import numpy as np

    from repro.core.awq import awq_then_quantease

    rng = np.random.default_rng(1)
    q, p = 64, 96
    x = rng.standard_normal((p, 384)).astype(np.float32) * (
        rng.random(p)[:, None] * 3 + 0.2
    )
    w = jnp.asarray(rng.standard_normal((q, p)).astype(np.float32))
    sigma = jnp.asarray(x @ x.T)
    e_qe = _err(w, quantease_quantize(w, sigma, SPEC3, iterations=12)[0], sigma)
    e_combo = _err(w, awq_then_quantease(w, sigma, SPEC3, iterations=12), sigma)
    assert e_combo <= e_qe * 1.02


def test_opt_family_configs():
    from repro.configs import get_config

    for name, tgt in [("opt_125m", 0.125), ("opt_1_3b", 1.315), ("opt_66b", 65.7)]:
        n = get_config(name).param_count() / 1e9
        assert abs(n - tgt) / tgt < 0.05
