"""Chaos suite: seeded fault injection end to end (DESIGN.md §Resilience).

The two invariants this file exists to pin:

1. **Serving**: under any injected fault schedule (engine-step transients,
   page-pool exhaustion spikes, kernel-dispatch denials), every request
   that finishes ``completed`` or ``preempted_resumed`` has tokens
   identical to the fault-free run, and the pool leaks nothing.
2. **Pipelines**: a quantize run killed mid-flight by an injected
   permanent fault and then re-run with ``--resume`` emits a
   **bit-identical** artifact to an uninterrupted run (the whole pipeline
   is deterministic, so restart-from-scratch is exact); corrupted
   checkpoint shards are detected by checksum and degrade to the last
   good step instead of restoring garbage.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.faults import (
    FaultPlan,
    FaultSpec,
    PermanentFault,
    TransientFault,
    active_plan,
    corrupt_bytes,
    fault_plan,
    fault_point,
)
from repro.models import init_params, make_plan
from repro.serve.engine import PagedServingEngine, Request
from tests.conftest import reduce_cfg

# ---------------------------------------------------------------------------
# FaultPlan determinism & mechanics
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="no.such.site", kind="transient")
    with pytest.raises(ValueError):
        FaultSpec(site="engine.step", kind="flaky")
    with pytest.raises(ValueError):
        FaultSpec(site="engine.step", kind="transient", p=1.5)


def _drive_plan(plan, site, n):
    """Call ``check(site)`` n times, recording the action per invocation."""
    out = []
    for _ in range(n):
        try:
            out.append(plan.check(site))
        except TransientFault:
            out.append("transient")
        except PermanentFault:
            out.append("permanent")
    return out


def test_fault_plan_at_window_and_max_fires():
    specs = [
        FaultSpec(site="pool.alloc", kind="deny", at=(1,), window=(4, 6)),
        FaultSpec(site="engine.step", kind="transient", window=(0, 100),
                  max_fires=2),
    ]
    plan = FaultPlan(specs, seed=0)
    assert _drive_plan(plan, "pool.alloc", 7) == [
        "ok", "deny", "ok", "ok", "deny", "deny", "ok"
    ]
    # max_fires caps the unbounded window at 2 fires
    assert _drive_plan(plan, "engine.step", 5) == [
        "transient", "transient", "ok", "ok", "ok"
    ]
    assert plan.fired == [
        ("pool.alloc", 1, "deny"), ("pool.alloc", 4, "deny"),
        ("pool.alloc", 5, "deny"), ("engine.step", 0, "transient"),
        ("engine.step", 1, "transient"),
    ]


def test_fault_plan_probabilistic_fires_are_deterministic():
    mk = lambda: FaultPlan(
        [FaultSpec(site="data.fetch", kind="transient", p=0.3)], seed=7
    )
    a = _drive_plan(mk(), "data.fetch", 50)
    b = _drive_plan(mk(), "data.fetch", 50)
    assert a == b and "transient" in a and "ok" in a
    # a different seed produces a different (but equally deterministic) draw
    c = _drive_plan(
        FaultPlan([FaultSpec(site="data.fetch", kind="transient", p=0.3)],
                  seed=8),
        "data.fetch", 50,
    )
    assert c != a


def test_fault_plan_from_spec_dict_string_and_path(tmp_path):
    doc = {"seed": 5, "faults": [
        {"site": "ckpt.write", "kind": "corrupt", "at": [0]},
        {"site": "engine.step", "kind": "transient", "window": [2, 4],
         "p": 0.1, "max_fires": 3},
    ]}
    for src in (doc, json.dumps(doc)):
        plan = FaultPlan.from_spec(src)
        assert plan.seed == 5 and len(plan.specs) == 2
        assert plan.specs[0].kind == "corrupt" and plan.specs[1].window == (2, 4)
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(doc))
    assert FaultPlan.from_spec(str(p)).seed == 5


def test_fault_point_inactive_is_noop_and_scoping_nests():
    assert active_plan() is None
    assert fault_point("engine.step") == "ok"
    outer = FaultPlan([FaultSpec(site="pool.alloc", kind="deny", at=(0,))])
    inner = FaultPlan([])
    with fault_plan(outer):
        assert active_plan() is outer
        with fault_plan(inner):  # innermost wins
            assert fault_point("pool.alloc") == "ok"
        assert fault_point("pool.alloc") == "deny"
    assert active_plan() is None
    with fault_plan(None):  # None-tolerant threading
        assert fault_point("pool.alloc") == "ok"
    with pytest.raises(ValueError):
        outer.check("not.a.site")


def test_corrupt_bytes_flips_exactly_one_seeded_byte():
    plan = FaultPlan([], seed=3)
    data = bytes(range(64))
    out = corrupt_bytes(plan, data)
    diff = [i for i in range(64) if out[i] != data[i]]
    assert len(diff) == 1 and out[diff[0]] == data[diff[0]] ^ 0xFF
    # same seed, fresh plan → same byte; the corruption is reproducible
    assert corrupt_bytes(FaultPlan([], seed=3), data) == out
    assert corrupt_bytes(plan, b"") == b""


# ---------------------------------------------------------------------------
# RetryingRunner: backoff, budget, permanent classification
# ---------------------------------------------------------------------------


def _flaky_counter(fail_at, exc=RuntimeError):
    calls = []

    def step(state, i):
        calls.append(i)
        if (i, len([c for c in calls if c == i])) in fail_at:
            raise exc(f"boom at {i}")
        return state + [i]

    return step, calls


def test_retrying_runner_backoff_and_recovery():
    from repro.dist.elastic import RetryingRunner

    step, _ = _flaky_counter({(2, 1), (2, 2)})  # step 2 fails twice
    slept = []
    runner = RetryingRunner(
        step, lambda: ([0, 1], 2), max_retries=3,
        backoff_base_s=0.01, backoff_mult=2.0, jitter=0.5,
        sleep_fn=slept.append, seed=0,
    )
    state, end = runner.run([], 0, 5)
    assert state == [0, 1, 2, 3, 4] and end == 5
    assert runner.recoveries == 2 and slept == runner.delays
    # exponential base with seeded jitter in [0.5x, 1.5x]
    assert 0.005 <= runner.delays[0] <= 0.015
    assert 0.01 <= runner.delays[1] <= 0.03
    # seeded jitter replays exactly
    step2, _ = _flaky_counter({(2, 1), (2, 2)})
    rerun = RetryingRunner(
        step2, lambda: ([0, 1], 2), max_retries=3,
        backoff_base_s=0.01, backoff_mult=2.0, jitter=0.5,
        sleep_fn=lambda s: None, seed=0,
    )
    rerun.run([], 0, 5)
    assert rerun.delays == runner.delays


def test_retrying_runner_budget_exhaustion_reraises():
    from repro.dist.elastic import RetryingRunner

    step, _ = _flaky_counter({(1, k) for k in range(1, 10)})
    runner = RetryingRunner(step, lambda: ([0], 1), max_retries=2,
                            sleep_fn=lambda s: None)
    with pytest.raises(RuntimeError):
        runner.run([], 0, 3)
    assert runner.recoveries == 2  # budget fully spent before the re-raise


def test_retrying_runner_permanent_never_retried():
    from repro.dist.elastic import RetryingRunner

    step, calls = _flaky_counter({(1, 1)}, exc=lambda m: PermanentFault("data.fetch", 1))
    restores = []
    runner = RetryingRunner(step, lambda: restores.append(1) or ([], 0),
                            sleep_fn=lambda s: None)
    with pytest.raises(PermanentFault):
        runner.run([], 0, 3)
    assert restores == [] and runner.recoveries == 0
    # caller-supplied permanent types behave identically
    step2, _ = _flaky_counter({(0, 1)}, exc=KeyboardInterrupt)
    runner2 = RetryingRunner(step2, lambda: ([], 0),
                             permanent=(KeyboardInterrupt,),
                             sleep_fn=lambda s: None)
    with pytest.raises(KeyboardInterrupt):
        runner2.run([], 0, 1)


# ---------------------------------------------------------------------------
# Checkpoint corruption: checksum detection + last-good fallback
# ---------------------------------------------------------------------------


def _tree(seed, shape=(4, 3)):
    r = np.random.default_rng(seed)
    return {"w": r.standard_normal(shape).astype(np.float32),
            "b": r.standard_normal(shape[0]).astype(np.float32)}


def test_injected_write_corruption_detected_on_read(tmp_path):
    from repro.dist import checkpoint as ckpt

    tree = _tree(0)
    plan = FaultPlan([FaultSpec(site="ckpt.write", kind="corrupt", at=(0,))])
    with fault_plan(plan):
        ckpt.save_checkpoint(str(tmp_path), 1, tree)
    assert plan.fired  # the corruption really was injected
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.load_checkpoint(str(tmp_path), tree)


def test_load_last_good_skips_damaged_steps(tmp_path):
    from repro.dist import checkpoint as ckpt

    good = _tree(1)
    ckpt.save_checkpoint(str(tmp_path), 1, good)
    bad = _tree(2)
    plan = FaultPlan([FaultSpec(site="ckpt.write", kind="corrupt", at=(0,))])
    with fault_plan(plan):
        ckpt.save_checkpoint(str(tmp_path), 2, bad)
    # latest (step 2) is damaged → degrade to step 1, reporting the skip
    tree, manifest, skipped = ckpt.load_last_good(str(tmp_path), good)
    assert manifest["step"] == 1
    assert [s for s, _ in skipped] == [2]
    assert "checksum" in skipped[0][1]
    np.testing.assert_array_equal(np.asarray(tree["w"]), good["w"])


def test_load_last_good_all_damaged_raises(tmp_path):
    from repro.dist import checkpoint as ckpt

    tree = _tree(3)
    plan = FaultPlan([FaultSpec(site="ckpt.write", kind="corrupt",
                                window=(0, 10_000))])
    with fault_plan(plan):
        ckpt.save_checkpoint(str(tmp_path), 1, tree)
        ckpt.save_checkpoint(str(tmp_path), 2, tree)
    with pytest.raises(ckpt.CheckpointCorrupt, match="all 2 step"):
        ckpt.load_last_good(str(tmp_path), tree)
    with pytest.raises(FileNotFoundError):
        ckpt.load_last_good(str(tmp_path / "empty"), tree)


def test_pre_checksum_manifests_still_load(tmp_path):
    """Manifests written before CRC-32 existed have no ``crc32`` field —
    they must load unverified, not crash."""
    from repro.dist import checkpoint as ckpt

    tree = _tree(4)
    ckpt.save_checkpoint(str(tmp_path), 1, tree)
    mpath = tmp_path / "step_1" / "manifest.json"
    doc = json.loads(mpath.read_text())
    for rec in doc["leaves"]:
        del rec["crc32"]
    mpath.write_text(json.dumps(doc))
    out, manifest = ckpt.load_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


def test_transient_read_fault_raises_through(tmp_path):
    from repro.dist import checkpoint as ckpt

    tree = _tree(5)
    ckpt.save_checkpoint(str(tmp_path), 1, tree)
    plan = FaultPlan([FaultSpec(site="ckpt.read", kind="transient", at=(0,))])
    with fault_plan(plan):
        with pytest.raises(TransientFault):
            ckpt.load_checkpoint(str(tmp_path), tree)
        out, _ = ckpt.load_checkpoint(str(tmp_path), tree)  # retry succeeds
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


# ---------------------------------------------------------------------------
# Data pipeline: retried fetch reproduces the batch bit-identically
# ---------------------------------------------------------------------------


def test_data_fetch_retry_is_bit_identical():
    from repro.data.pipeline import DataConfig, make_batch_fn

    cfg = reduce_cfg(get_config("stablelm_12b"))
    get, _ = make_batch_fn(DataConfig(vocab=cfg.vocab, seed=0), cfg,
                           batch=2, seq=16, split="calib")
    clean = get(3)
    plan = FaultPlan([FaultSpec(site="data.fetch", kind="transient", at=(0,))])
    with fault_plan(plan):
        with pytest.raises(TransientFault):
            get(3)
        retried = get(3)  # the retry the RetryingRunner would perform
    np.testing.assert_array_equal(retried["tokens"], clean["tokens"])


# ---------------------------------------------------------------------------
# Serving chaos invariant
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def chaos_model():
    cfg = reduce_cfg(
        get_config("stablelm_12b"), d_model=96, head_dim=24, d_ff=192,
        n_periods=2,
    )
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (6, 21, 47, 11, 33)]
    return plan, params, prompts


def _serve_outputs(plan, params, prompts, fplan=None, **eng_kw):
    eng = PagedServingEngine(plan, params, **eng_kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=7))
    with fault_plan(fplan):
        eng.run(max_steps=2_000)
    return eng, {r.rid: r for r in eng.finished}


def test_chaos_serving_invariant(chaos_model):
    """Under injected engine-step transients, pool-exhaustion spikes, and
    kernel-dispatch denials, every completed/preempted_resumed request is
    token-identical to the fault-free run and no page leaks."""
    plan, params, prompts = chaos_model
    kw = dict(max_batch=3, max_seq=128, page_size=8, n_pages=13,
              prefill_chunk=16, prefix_cache=False)
    _, clean = _serve_outputs(plan, params, prompts, **kw)
    assert len(clean) == len(prompts)
    fplan = FaultPlan([
        FaultSpec(site="engine.step", kind="transient", at=(0, 3, 7),
                  window=(11, 14)),
        FaultSpec(site="pool.alloc", kind="deny", at=(2, 5, 9),
                  window=(12, 15), p=0.05, max_fires=12),
        FaultSpec(site="kernel.dispatch", kind="deny", window=(0, 10_000)),
    ], seed=42)
    eng, chaotic = _serve_outputs(plan, params, prompts, fplan=fplan, **kw)
    assert fplan.fired  # the schedule really exercised the engine
    assert eng.n_transient_faults >= 3
    assert len(chaotic) == len(prompts)  # nothing stuck, nothing lost
    for rid, req in chaotic.items():
        assert req.status in ("completed", "preempted_resumed")
        assert req.output == clean[rid].output  # the tentpole invariant
    assert eng.pool.n_free == eng.n_pages - 1  # every page returned


def test_chaos_alloc_denial_storm_self_preempts(chaos_model):
    """A denial spike while a single sequence needs to grow must not crash
    with 'pool too small' — the engine self-preempts and resumes once the
    spike passes, with identical output."""
    plan, params, prompts = chaos_model
    kw = dict(max_batch=1, max_seq=128, page_size=8, prefill_chunk=16,
              prefix_cache=False)
    _, clean = _serve_outputs(plan, params, [prompts[1]], **kw)
    fplan = FaultPlan([
        FaultSpec(site="pool.alloc", kind="deny", window=(2, 8)),
    ])
    eng, chaotic = _serve_outputs(plan, params, [prompts[1]], fplan=fplan, **kw)
    assert chaotic[0].output == clean[0].output
    assert chaotic[0].status in ("completed", "preempted_resumed")
    assert eng.pool.n_free == eng.n_pages - 1


def test_engine_step_transient_is_pure_noop(chaos_model):
    """A transient at the very first step must not lose queued requests or
    report a dead engine (step() returns True, nothing mutates)."""
    plan, params, prompts = chaos_model
    eng = PagedServingEngine(plan, params, max_batch=2, max_seq=128,
                             page_size=8, prefill_chunk=16)
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=3))
    fplan = FaultPlan([FaultSpec(site="engine.step", kind="transient", at=(0,))])
    with fault_plan(fplan):
        assert eng.step() is True  # no-op retry, not a dead engine
        assert eng.n_transient_faults == 1
        assert eng.lanes == [None, None] and len(eng.queue) == 1
        fin = eng.run()
    assert len(fin) == 1 and fin[0].status == "completed"


# ---------------------------------------------------------------------------
# Quantize pipeline: fault-interrupted run resumes bit-identically
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def quantize_env(tmp_path_factory):
    """A tiny trained-checkpoint directory + the config monkeypatch args."""
    import jax.numpy as jnp

    from repro.dist import checkpoint as ckpt
    from repro.models import param_shapes
    from repro.train.optimizer import AdamWConfig, adamw_init

    cfg = reduce_cfg(
        get_config("stablelm_12b"), d_model=32, head_dim=8, d_ff=64,
        max_seq=64,
    )
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(1))
    like = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), param_shapes(plan))
    # param_shapes and init_params agree on structure; store a real state
    state = {"params": params, "opt": adamw_init(like, AdamWConfig())}
    ckpt_dir = tmp_path_factory.mktemp("train_ckpt")
    ckpt.save_checkpoint(str(ckpt_dir), 7, state)
    return cfg, str(ckpt_dir)


def _run_quantize(monkeypatch, cfg, ckpt_dir, out_dir, extra=()):
    import repro.configs as configs
    from repro.launch import quantize

    monkeypatch.setattr(configs, "get_config", lambda name: cfg)
    argv = ["quantize", "--arch", "tiny", "--ckpt-dir", ckpt_dir,
            "--out-dir", out_dir, "--method", "quantease", "--bits", "3",
            "--iterations", "2", "--calib-batches", "2", "--seq", "32",
            *extra]
    monkeypatch.setattr("sys.argv", argv)
    quantize.main()


def _artifact_bytes(out_dir):
    d = [p for p in os.listdir(out_dir) if p.startswith("step_")]
    assert len(d) == 1
    step = os.path.join(out_dir, d[0])
    return {
        name: open(os.path.join(step, name), "rb").read()
        for name in sorted(os.listdir(step))
        if name.endswith(".bin")
    }


def test_quantize_fault_then_resume_bit_identical(
    quantize_env, tmp_path, monkeypatch
):
    cfg, ckpt_dir = quantize_env
    # 1) uninterrupted reference run
    ref_dir = str(tmp_path / "ref")
    _run_quantize(monkeypatch, cfg, ckpt_dir, ref_dir)
    ref = _artifact_bytes(ref_dir)
    assert ref  # produced leaf shards

    # 2) fault-interrupted run: a permanent storage fault mid-calibration
    #    kills the run (RetryingRunner classifies it — no retry burn)
    out_dir = str(tmp_path / "chaotic")
    fp = json.dumps({"faults": [
        {"site": "data.fetch", "kind": "permanent", "at": [1]},
    ]})
    with pytest.raises(PermanentFault):
        _run_quantize(monkeypatch, cfg, ckpt_dir, out_dir,
                      extra=("--fault-plan", fp))
    assert not os.path.exists(os.path.join(out_dir, "step_7"))

    # 3) --resume after the crash: deterministic restart → identical bytes
    _run_quantize(monkeypatch, cfg, ckpt_dir, out_dir, extra=("--resume",))
    assert _artifact_bytes(out_dir) == ref


def test_quantize_transient_fetch_fault_recovers_in_run(
    quantize_env, tmp_path, monkeypatch, capsys
):
    """A *transient* calibration-fetch fault is absorbed by the retry loop
    inside one run — same artifact, no restart needed."""
    cfg, ckpt_dir = quantize_env
    ref_dir = str(tmp_path / "ref")
    _run_quantize(monkeypatch, cfg, ckpt_dir, ref_dir)
    out_dir = str(tmp_path / "retried")
    fp = json.dumps({"faults": [
        {"site": "data.fetch", "kind": "transient", "at": [1]},
    ]})
    _run_quantize(monkeypatch, cfg, ckpt_dir, out_dir,
                  extra=("--fault-plan", fp))
    assert "recovered from 1 transient fault" in capsys.readouterr().out
    assert _artifact_bytes(out_dir) == _artifact_bytes(ref_dir)


def test_quantize_corrupt_source_falls_back_to_last_good(
    quantize_env, tmp_path, monkeypatch, capsys
):
    """A corrupted newest train checkpoint degrades to the previous good
    step (with a loud warning) instead of wedging the quantize run."""
    import shutil

    from repro.dist import checkpoint as ckpt

    cfg, ckpt_dir = quantize_env
    work = str(tmp_path / "ckpts")
    shutil.copytree(ckpt_dir, work)
    # forge a newer step, then flip one byte of one of its shards
    src = os.path.join(work, "step_7")
    dst = os.path.join(work, "step_9")
    shutil.copytree(src, dst)
    man = json.loads(open(os.path.join(dst, "manifest.json")).read())
    man["step"] = 9
    with open(os.path.join(dst, "manifest.json"), "w") as f:
        json.dump(man, f)
    shard = os.path.join(dst, "leaf_0.bin")
    raw = bytearray(open(shard, "rb").read())
    raw[0] ^= 0xFF
    open(shard, "wb").write(bytes(raw))
    assert ckpt.latest_step(work) == 9

    out_dir = str(tmp_path / "out")
    _run_quantize(monkeypatch, cfg, work, out_dir)
    captured = capsys.readouterr()
    assert "skipped damaged checkpoint step_9" in captured.err
    assert "loaded checkpoint step 7" in captured.out
