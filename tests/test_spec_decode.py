"""Speculative decoding: token identity, accounting, page hygiene, and the
acceptance-rule properties (DESIGN.md §Speculative-serving).

The headline invariant — speculative greedy output is **token-identical**
to non-speculative greedy decode of the same target artifact — is pinned
here end-to-end (engine runs across γ, budget edges, preemption, SLO
interplay) and at the model layer (the batched virtual-lane verify is
*bitwise* equal to sequential decode steps, logits and KV bytes alike).
The stochastic acceptance rule kept as a host-side reference
(serve/spec.rejection_sample_commit) is pinned by property tests: it never
commits a token the target gives zero probability, and with one-hot
target rows it collapses to longest-prefix + argmax — the integer rule the
engine implements.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    init_paged_cache,
    init_params,
    make_plan,
    paged_decode_step,
    paged_prefill_chunk,
    paged_verify_tokens,
)
from repro.serve.engine import PagedServingEngine, Request
from repro.serve.spec import (
    SpecConfig,
    greedy_accept_len,
    rejection_sample_commit,
    truncate_draft,
)
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from tests.conftest import reduce_cfg


@pytest.fixture(scope="module")
def spec_model():
    cfg = reduce_cfg(
        get_config("stablelm_12b"), d_model=96, head_dim=24, d_ff=192, n_periods=2
    )
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    draft_plan, draft_params = truncate_draft(plan, params, 1)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (6, 21, 47, 11)]
    return plan, params, draft_plan, draft_params, prompts


def _spec(plan, draft_plan, draft_params, gamma):
    return SpecConfig(draft_plan=draft_plan, draft_params=draft_params, gamma=gamma)


def _serve(eng, prompts, max_new=7):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]


def _engine(plan, params, *, spec=None, max_batch=2, max_seq=128, page_size=8,
            **kw):
    # Generous pool: target pages + draft pages live in the same pool, so
    # identity tests get headroom (degradation under pressure is its own
    # test below).
    pages_per_seq = -(-max_seq // page_size)
    kw.setdefault("n_pages", 1 + 2 * max_batch * pages_per_seq)
    return PagedServingEngine(
        plan, params, max_batch=max_batch, max_seq=max_seq,
        page_size=page_size, prefill_chunk=16, spec=spec, **kw,
    )


# ---------------------------------------------------------------------------
# Token identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gamma", [1, 2, 4])
def test_spec_token_identical_to_plain_greedy(spec_model, gamma):
    plan, params, dplan, dparams, prompts = spec_model
    base = _serve(_engine(plan, params), prompts)
    eng = _engine(plan, params, spec=_spec(plan, dplan, dparams, gamma))
    assert _serve(eng, prompts) == base
    assert eng.n_spec_rounds > 0  # speculation actually ran


def test_spec_gamma_overruns_max_new(spec_model):
    """γ larger than the remaining token budget: proposals clamp so a
    verify round never overshoots max_new, and outputs stay identical —
    including max_new=1, where the budget is 0 every round and the engine
    runs the legacy single-decode branch throughout."""
    plan, params, dplan, dparams, prompts = spec_model
    for max_new in (1, 3):
        base = _serve(_engine(plan, params), prompts[:2], max_new=max_new)
        eng = _engine(plan, params, spec=_spec(plan, dplan, dparams, 4))
        assert _serve(eng, prompts[:2], max_new=max_new) == base
        assert all(len(o) == max_new for o in base)
        if max_new == 1:
            # One token per request with zero proposals — the all-empty
            # round is the legacy path, so no draft tokens exist.
            assert eng.n_draft_tokens == 0 and eng.acceptance_rate() is None


def test_spec_window_edge_prompt(spec_model):
    """Prompt + max_new exactly fills max_seq: the last speculative rounds
    run against the window edge where the per-lane budget clamps to the
    remaining positions; outputs must still be identical and complete."""
    plan, params, dplan, dparams, _ = spec_model
    rng = np.random.default_rng(23)
    max_seq, max_new = 64, 6
    prompt = rng.integers(0, 250, max_seq - max_new).astype(np.int32)
    base = _serve(_engine(plan, params, max_seq=max_seq), [prompt],
                  max_new=max_new)
    eng = _engine(plan, params, max_seq=max_seq,
                  spec=_spec(plan, dplan, dparams, 4))
    out = _serve(eng, [prompt], max_new=max_new)
    assert out == base and len(out[0]) == max_new


# ---------------------------------------------------------------------------
# Accounting and page hygiene
# ---------------------------------------------------------------------------


def test_spec_acceptance_accounting_exact(spec_model):
    """Every spec-engine round commits accepted + 1 tokens, so
    ``len(output) == n_draft_accepted + n_spec_rounds`` holds *exactly*
    per request, and the engine totals are the per-request sums."""
    plan, params, dplan, dparams, prompts = spec_model
    eng = _engine(plan, params, spec=_spec(plan, dplan, dparams, 3))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=9))
    fin = sorted(eng.run(), key=lambda r: r.rid)
    for r in fin:
        assert len(r.output) == r.n_draft_accepted + r.n_spec_rounds
        assert 0 <= r.n_draft_accepted <= r.n_draft_tokens
    # Engine-level n_spec_rounds counts fused verify *dispatches* (shared
    # by every active lane), so it is bounded by the per-lane commit-round
    # sum; the draft-token totals are exact per-request sums.
    assert 0 < eng.n_spec_rounds <= sum(r.n_spec_rounds for r in fin)
    assert eng.n_draft_tokens == sum(r.n_draft_tokens for r in fin)
    assert eng.n_draft_accepted == sum(r.n_draft_accepted for r in fin)
    assert eng.acceptance_rate() == eng.n_draft_accepted / eng.n_draft_tokens


def test_spec_zero_page_leaks(spec_model):
    """Draft pages roll back after every verify and release with the lane:
    a refcount audit after the run sees every allocatable page free (the
    null page stays reserved), with or without prefix caching."""
    plan, params, dplan, dparams, prompts = spec_model
    for prefix_cache in (True, False):
        eng = _engine(plan, params, spec=_spec(plan, dplan, dparams, 3),
                      prefix_cache=prefix_cache)
        _serve(eng, prompts, max_new=9)
        assert eng.pool.n_free == eng.n_pages - 1
        assert all(not pgs for pgs in eng.spec_mgr.pages)
        assert not any(eng.spec_mgr.table.ravel())  # NULL_PAGE == 0


def test_spec_preemption_resume_deterministic(spec_model):
    """A pool too small for the batch forces preemption mid-speculation;
    draft allocation degrades (never preempts) and resumed sequences
    finish with outputs identical to the ample-pool run."""
    plan, params, dplan, dparams, prompts = spec_model
    sp = _spec(plan, dplan, dparams, 3)
    ample = _serve(_engine(plan, params, max_batch=3, spec=sp), prompts)
    tight = PagedServingEngine(
        plan, params, max_batch=3, max_seq=128, page_size=8, n_pages=13,
        prefill_chunk=16, prefix_cache=False, spec=sp,
    )
    assert _serve(tight, prompts) == ample
    assert tight.n_preemptions >= 1
    assert tight.pool.n_free == tight.n_pages - 1  # target AND draft pages


def test_spec_slo_shed_and_expire(spec_model):
    """Speculation under the SLO scheduler: an impossible deadline sheds,
    an overdue request expires mid-generation, and the surviving default
    request's tokens are identical to the non-speculative run — spec
    rounds never bypass deadline checks or leak the victims' pages."""
    from tests.test_slo_serve import StepClock

    plan, params, dplan, dparams, prompts = spec_model
    sp = _spec(plan, dplan, dparams, 3)

    def run(spec):
        eng = PagedServingEngine(
            plan, params, max_batch=2, max_seq=128, page_size=8,
            prefill_chunk=16, n_pages=1 + 4 * 16, clock=StepClock(),
            spec=spec,
        )
        eng.submit(Request(rid=0, prompt=prompts[1], max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=prompts[2], max_new_tokens=30,
                           deadline_ms=20_000))  # expires mid-generation
        eng.run()
        # 30 decode positions at the engine's own observed per-step floor
        # can never fit in 3 virtual seconds — provably unmeetable: shed.
        eng.submit(Request(rid=2, prompt=prompts[0], max_new_tokens=30,
                           deadline_ms=3_000))
        fin = {r.rid: r for r in eng.run()}
        assert eng.pool.n_free == eng.n_pages - 1
        return eng, fin

    base_eng, base = run(None)
    eng, fin = run(sp)
    assert fin[2].status == "shed" and base[2].status == "shed"
    assert fin[2].output == [] and "provably unmeetable" in fin[2].error
    assert fin[1].status == base[1].status == "deadline_missed"
    assert 0 < len(fin[1].output) < 30  # partial output survives expiry
    assert fin[0].status == "completed"
    assert fin[0].output == base[0].output


def test_spec_disabled_is_legacy_bit_for_bit(spec_model):
    """spec=None runs the legacy single-decode branch every round; with a
    SpecConfig the committed positions go through the batched verify.
    Both record the same trace *bitwise* — the strongest form of the
    identity invariant (argmax equality would survive logit drift)."""
    plan, params, dplan, dparams, prompts = spec_model
    def trace(spec):
        eng = _engine(plan, params, max_batch=1, record_logits=True,
                      spec=spec)
        _serve(eng, prompts[:2], max_new=6)
        return {
            rid: np.stack([np.asarray(v) for v in vs])
            for rid, vs in eng.logit_trace.items()
        }

    legacy = trace(None)
    spec = trace(_spec(plan, dplan, dparams, 3))
    assert legacy.keys() == spec.keys()
    for rid in legacy:
        assert np.array_equal(legacy[rid], spec[rid])


# ---------------------------------------------------------------------------
# Model layer: batched virtual-lane verify ≡ sequential decode, bitwise
# ---------------------------------------------------------------------------


def test_batched_verify_bitwise_equals_sequential(spec_model):
    """paged_verify_tokens runs L positions as B·L virtual lanes of ONE
    decode step; this is the bitwise pin (logits and cache bytes) against
    L separate paged_decode_step calls that makes the engine's token
    identity exact rather than tolerance-based."""
    plan, params, _, _, prompts = spec_model
    page_size, n_pages, L = 8, 12, 4
    prompt = prompts[2]  # 47 tokens: positions 46..49 cross a page boundary
    pt = np.full((1, 8), 0, np.int32)
    pt[0, :7] = [1, 2, 3, 4, 5, 6, 7]
    cache = init_paged_cache(plan, n_pages, page_size)
    buf = np.zeros((1, 48), np.int32)
    buf[0, : len(prompt)] = prompt
    cache = paged_prefill_chunk(
        plan, params, jnp.asarray(buf), cache, jnp.asarray(pt), np.int32(0)
    )
    pos0 = len(prompt) - 1
    toks = np.asarray([[int(prompt[-1]), 7, 11, 13]], np.int32)
    wp = np.asarray([[pt[0, (pos0 + j) // page_size] for j in range(L)]],
                    np.int32)

    batched, cache_b = paged_verify_tokens(
        plan, params, jnp.asarray(toks), cache, jnp.asarray([pos0]),
        jnp.asarray(pt), jnp.asarray(wp),
    )
    seq_logits, cache_s = [], cache
    for j in range(L):
        lg, cache_s = paged_decode_step(
            plan, params, jnp.asarray(toks[:, j : j + 1]), cache_s,
            jnp.asarray([pos0 + j]), jnp.asarray(pt), jnp.asarray(wp[:, j]),
        )
        seq_logits.append(np.asarray(lg.astype(jnp.float32)))
    assert np.array_equal(
        np.asarray(batched.astype(jnp.float32))[0], np.stack([l[0] for l in seq_logits])
    )
    for a, b in zip(jax.tree.leaves(cache_b), jax.tree.leaves(cache_s)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Acceptance-rule properties (hypothesis-optional)
# ---------------------------------------------------------------------------


def _random_spec_case(seed):
    """Draft/target distributions with deliberate zero-mass tokens, plus
    the rule's random draws, all from one integer seed."""
    rng = np.random.default_rng(seed)
    V = int(rng.integers(3, 9))
    n = int(rng.integers(1, 5))

    def dist(support_bias):
        p = rng.random(V) ** 3  # skewed so near-ties and zeros both occur
        p[rng.random(V) < support_bias] = 0.0
        if p.sum() <= 0:
            p[int(rng.integers(V))] = 1.0
        return p / p.sum()

    draft_probs = [dist(0.3) for _ in range(n)]
    target_probs = [dist(0.4) for _ in range(n + 1)]
    # Proposals must come from the draft's own support (the rule rejects a
    # zero-draft-probability proposal as a caller bug).
    draft_tokens = [int(rng.choice(V, p=d)) for d in draft_probs]
    u = rng.random(n)
    v = rng.random(n + 1)
    return draft_tokens, draft_probs, target_probs, u, v


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_rejection_sampling_never_commits_zero_target_prob(seed):
    draft_tokens, dp, tp, u, v = _random_spec_case(seed)
    committed = rejection_sample_commit(draft_tokens, dp, tp, u, v)
    assert 1 <= len(committed) <= len(draft_tokens) + 1
    for j, t in enumerate(committed):
        assert tp[j][t] > 0.0, "committed a token the target excludes"
    # Accepted prefix (all but the last committed token) is verbatim draft.
    assert committed[:-1] == draft_tokens[: len(committed) - 1]


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_rejection_sampling_one_hot_reduces_to_greedy_rule(seed):
    """With one-hot (greedy) target rows the stochastic rule collapses to
    longest-prefix acceptance + the target argmax at the stop position —
    exactly greedy_accept_len + bonus, independent of the random draws."""
    draft_tokens, dp, tp, u, v = _random_spec_case(seed)
    greedy = [int(np.argmax(t)) for t in tp]
    one_hot = [np.eye(len(t))[g] for t, g in zip(tp, greedy)]
    committed = rejection_sample_commit(draft_tokens, dp, one_hot, u, v)
    a = greedy_accept_len(draft_tokens, greedy)
    assert committed == draft_tokens[:a] + [greedy[a]]


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_greedy_accept_len_is_longest_agreeing_prefix(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, 6))
    draft = rng.integers(0, 4, n).tolist()
    target = rng.integers(0, 4, n).tolist()
    a = greedy_accept_len(draft, target)
    assert 0 <= a <= n
    assert draft[:a] == target[:a]
    if a < n:
        assert draft[a] != target[a]


def test_rejection_sampling_rejects_malformed_inputs():
    with pytest.raises(ValueError):
        rejection_sample_commit([0], [[1.0]], [[1.0]], [0.5], [0.5])  # short v
    with pytest.raises(ValueError):
        # Draft proposing outside its own support is a caller bug.
        rejection_sample_commit(
            [1], [np.array([1.0, 0.0])], [np.array([0.5, 0.5])] * 2,
            [0.5], [0.5, 0.5],
        )


# ---------------------------------------------------------------------------
# Launcher flag validation (subprocess argparse smoke)
# ---------------------------------------------------------------------------


def test_launcher_rejects_nonpositive_counts():
    """The serve launcher refuses zero/negative counts at argparse time
    (exit code 2, pointed message) before touching jax or checkpoints."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    for flag, val in [("--gamma", "0"), ("--page-size", "-4"),
                      ("--max-new", "0")]:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve",
             "--arch", "stablelm_12b", flag, val],
            capture_output=True, text=True, cwd=repo, env=env, timeout=120,
        )
        assert proc.returncode == 2, proc.stderr
        assert f"{flag} must be >= 1, got" in proc.stderr
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "stablelm_12b", "--gamma", "two"],
        capture_output=True, text=True, cwd=repo, env=env, timeout=120,
    )
    assert proc.returncode == 2
    assert "--gamma expects a positive integer, got 'two'" in proc.stderr
