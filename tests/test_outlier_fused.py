"""Fused outlier-aware CD engine (DESIGN.md §Outlier-aware-fused): engine
parity, scanned outer loop, single-launch kernel, sparse-Ĥ COO artifact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import outlier
from repro.core.outlier import outlier_quantease, power_lambda_max
from repro.core.quantease import relative_error
from repro.kernels import ops, ref
from repro.quant import GridSpec, compute_grid

SPEC3 = GridSpec(bits=3)


def _problem(seed, q, p, n):
    r = np.random.default_rng(seed)
    x = r.standard_normal((p, n)).astype(np.float32)
    w = r.standard_normal((q, p)).astype(np.float32)
    w[r.random((q, p)) < 0.003] *= 10.0
    return jnp.asarray(w), jnp.asarray(x @ x.T)


# ---------------------------------------------------------------------------
# Engine parity (fused vs legacy schedule)
# ---------------------------------------------------------------------------


def test_fused_matches_legacy_unstructured(layer_problem):
    """Same update order ⇒ same iterates: the fused engine reproduces the
    legacy schedule exactly up to fp reassociation (the top-s support can
    only differ on near-ties, absorbed by the error-level bound)."""
    w, sigma = layer_problem
    s = int(0.01 * w.size)
    kw = dict(s=s, iterations=8, use_kernel="xla")
    rl = outlier_quantease(w, sigma, SPEC3, engine="legacy", **kw)
    rf = outlier_quantease(w, sigma, SPEC3, engine="fused", **kw)
    el = float(relative_error(w, rl.w_eff, sigma))
    ef = float(relative_error(w, rf.w_eff, sigma))
    assert ef <= el * 1.01 + 1e-7
    assert int((np.asarray(rf.h) != 0).sum()) <= s
    # Generic data has no projection ties, so the iterates agree tightly.
    np.testing.assert_allclose(
        np.asarray(rl.w_hat), np.asarray(rf.w_hat), rtol=0, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(rl.h), np.asarray(rf.h), rtol=0, atol=2e-4
    )


def test_fused_matches_legacy_structured(layer_problem):
    w, sigma = layer_problem
    q = w.shape[0]
    s = int(0.02 * w.size)
    kw = dict(s=s, iterations=6, structured=True, use_kernel="xla")
    rl = outlier_quantease(w, sigma, SPEC3, engine="legacy", **kw)
    rf = outlier_quantease(w, sigma, SPEC3, engine="fused", **kw)
    el = float(relative_error(w, rl.w_eff, sigma))
    ef = float(relative_error(w, rf.w_eff, sigma))
    assert ef <= el * 1.01 + 1e-7
    nz_cols = np.nonzero(np.abs(np.asarray(rf.h)).sum(0))[0]
    assert len(nz_cols) <= max(s // q, 1)
    np.testing.assert_allclose(
        np.asarray(rl.w_hat), np.asarray(rf.w_hat), rtol=0, atol=2e-4
    )


def test_fused_bf16_within_tolerance(layer_problem):
    """bf16 Σ̃ correction/residual operands keep solution quality at the fp32
    level (the bf16-tolerance contract of tests/test_fused_engine.py)."""
    w, sigma = layer_problem
    s = int(0.01 * w.size)
    kw = dict(s=s, iterations=8, use_kernel="xla", engine="fused")
    e32 = float(relative_error(
        w, outlier_quantease(w, sigma, SPEC3, matmul_dtype="float32", **kw).w_eff,
        sigma))
    ebf = float(relative_error(
        w, outlier_quantease(w, sigma, SPEC3, matmul_dtype="bfloat16", **kw).w_eff,
        sigma))
    assert ebf <= e32 * 1.05 + 1e-6


def test_fused_padding_non_multiple_block(layer_problem):
    """p not a multiple of the sweep block: padded columns quantize to
    isolated zeros and never enter the outlier budget."""
    r = np.random.default_rng(3)
    q, p = 48, 100  # pads to 128
    w = jnp.asarray(r.standard_normal((q, p)).astype(np.float32))
    x = r.standard_normal((p, 300)).astype(np.float32)
    sigma = jnp.asarray(x @ x.T)
    s = 50
    rl = outlier_quantease(w, sigma, SPEC3, s=s, iterations=5, engine="legacy",
                           use_kernel="xla")
    rf = outlier_quantease(w, sigma, SPEC3, s=s, iterations=5, engine="fused",
                           use_kernel="xla")
    np.testing.assert_allclose(
        np.asarray(rl.w_hat), np.asarray(rf.w_hat), rtol=0, atol=2e-4
    )
    assert rf.h.shape == (q, p)
    assert int((np.asarray(rf.h) != 0).sum()) <= s


def test_objective_optin_and_matches_legacy(layer_problem):
    """Objective history is opt-in (None by default) and, when tracked, the
    fused engine's resident-state evaluation equals the legacy einsum."""
    w, sigma = layer_problem
    s = int(0.01 * w.size)
    assert outlier_quantease(w, sigma, SPEC3, s=s, iterations=2).objective is None
    kw = dict(s=s, iterations=5, use_kernel="xla", track_objective=True)
    ol = outlier_quantease(w, sigma, SPEC3, engine="legacy", **kw).objective
    of = outlier_quantease(w, sigma, SPEC3, engine="fused", **kw).objective
    assert of.shape == (5,)
    np.testing.assert_allclose(np.asarray(ol), np.asarray(of), rtol=1e-4)


# ---------------------------------------------------------------------------
# Single-launch kernel + scanned outer loop
# ---------------------------------------------------------------------------


def test_fused_kernel_matches_xla():
    w, sigma = _problem(5, 96, 128, 256)
    s = int(0.01 * w.size)
    kw = dict(s=s, iterations=4, engine="fused")
    rx = outlier_quantease(w, sigma, SPEC3, use_kernel="xla", **kw)
    rp = outlier_quantease(w, sigma, SPEC3, use_kernel="pallas", **kw)
    np.testing.assert_allclose(
        np.asarray(rx.w_hat), np.asarray(rp.w_hat), rtol=0, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(rx.h), np.asarray(rp.h), rtol=0, atol=1e-4
    )


def test_outlier_kernel_matches_ref():
    """The single-launch kernel reproduces the pure-jnp oracle: sweep,
    base/Δ bookkeeping with the lazy dĤ fold, and the exact residual."""
    r = np.random.default_rng(7)
    q, p, bsz = 32, 64, 32
    w = jnp.asarray(r.standard_normal((q, p)).astype(np.float32))
    x = r.standard_normal((p, 200)).astype(np.float32)
    from repro.core.calib import damp_sigma

    sk = damp_sigma(jnp.asarray(x @ x.T), 0.01)
    diag = jnp.diag(sk)
    st = sk / diag[None, :] - jnp.eye(p)
    g = compute_grid(w, SPEC3)
    sc, zc = g.per_column(p)
    dprev = jnp.asarray(r.standard_normal((q, p)).astype(np.float32)) * 0.01
    dhp = jnp.asarray(r.standard_normal((q, p)).astype(np.float32)) * 0.01
    kw = dict(n_levels=SPEC3.n_levels, quantize=True, bsz=bsz)
    outs_k = ops.quantease_outlier_iteration(
        w, st, w, sc, zc, dprev, dhp, interpret=True, **kw
    )
    outs_r = ref.quantease_outlier_iteration_ref(w, st, w, sc, zc, dprev, dhp, **kw)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_single_launch_per_outer_iteration_and_scanned_loop():
    """The fused Pallas path issues ONE kernel dispatch per scan body — and
    because the outer loop is a lax.scan, the dispatcher is *traced* exactly
    once regardless of `iterations` (the pre-PR loop traced 25 copies) and
    never falls back to per-block sweep launches."""
    w, sigma = _problem(11, 64, 128, 256)
    s = int(0.01 * w.size)
    n_outlier = n_block = 0
    orig_o = ops.quantease_outlier_iteration_t
    orig_b = ops.quantease_block_sweep

    def count_o(*a, **k):
        nonlocal n_outlier
        n_outlier += 1
        return orig_o(*a, **k)

    def count_b(*a, **k):
        nonlocal n_block
        n_block += 1
        return orig_b(*a, **k)

    ops.quantease_outlier_iteration_t = count_o
    ops.quantease_block_sweep = count_b
    try:
        # eager internal entry point: tracing happens here, uncached
        outlier._outlier_2d(
            w, sigma, spec=SPEC3, s=s, iterations=6, structured=False,
            percdamp=0.01, cd_block_size=128, use_kernel="pallas",
            matmul_dtype="float32", track_objective=False, engine="fused",
            lam_iters=64,
        )
    finally:
        ops.quantease_outlier_iteration_t = orig_o
        ops.quantease_block_sweep = orig_b
    assert n_outlier == 1  # one traced dispatch inside the scan body
    assert n_block == 0  # no per-block launches anywhere


def test_vmem_overflow_falls_back_to_xla():
    """Layers whose single-launch kernel can't fit VMEM must take the XLA
    schedule (same iterates) instead of raising — the base engine's
    fallback contract."""
    w, sigma = _problem(29, 48, 64, 128)
    s = 30
    orig = ops.outlier_iteration_tq
    ops.outlier_iteration_tq = lambda *a, **k: None  # force "doesn't fit"
    try:
        r_fb = outlier._outlier_2d(
            w, sigma, spec=SPEC3, s=s, iterations=3, structured=False,
            percdamp=0.01, cd_block_size=64, use_kernel="pallas",
            matmul_dtype="float32", track_objective=False, engine="fused",
            lam_iters=64,
        )
    finally:
        ops.outlier_iteration_tq = orig
    r_x = outlier_quantease(w, sigma, SPEC3, s=s, iterations=3,
                            use_kernel="xla")
    np.testing.assert_allclose(
        np.asarray(r_fb.w_hat), np.asarray(r_x.w_hat), atol=1e-5
    )


def test_eta_computed_once_outside_scanned_loop():
    """Regression: η = 1/(2λ_max) is computed once per solve, not per outer
    iteration (power_lambda_max must sit outside the scanned loop)."""
    w, sigma = _problem(13, 48, 64, 128)
    n_calls = 0
    orig = outlier.power_lambda_max

    def counting(*a, **k):
        nonlocal n_calls
        n_calls += 1
        return orig(*a, **k)

    outlier.power_lambda_max = counting
    try:
        for engine in ("fused", "legacy"):
            n_calls = 0
            outlier._outlier_2d(
                w, sigma, spec=SPEC3, s=30, iterations=7, structured=False,
                percdamp=0.01, cd_block_size=64, use_kernel="xla",
                matmul_dtype="float32", track_objective=False, engine=engine,
                lam_iters=64,
            )
            assert n_calls == 1, engine
    finally:
        outlier.power_lambda_max = orig


def test_power_lambda_max_iters_and_tol():
    r = np.random.default_rng(17)
    a = r.standard_normal((48, 96)).astype(np.float32)
    sigma = jnp.asarray(a @ a.T)
    lam_true = float(np.linalg.eigvalsh(np.asarray(sigma)).max())
    lam = float(power_lambda_max(sigma))
    assert abs(lam - lam_true) / lam_true < 1e-3
    # iters is configurable and a tight cap still lands in the ballpark
    lam8 = float(power_lambda_max(sigma, iters=8))
    assert abs(lam8 - lam_true) / lam_true < 0.2
    # a loose tol early-outs without leaving the ballpark
    lam_loose = float(power_lambda_max(sigma, tol=1e-2))
    assert abs(lam_loose - lam_true) / lam_true < 0.2


# ---------------------------------------------------------------------------
# Batched (vmapped) solves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", ["xla", "pallas"])
def test_batched_vmap_matches_per_slice(use_kernel):
    G = 3
    probs = [_problem(19 + g, 48, 64, 128) for g in range(G)]
    w3 = jnp.stack([pr[0] for pr in probs])
    sig3 = jnp.stack([pr[1] for pr in probs])
    s = int(0.01 * w3[0].size)
    kw = dict(s=s, iterations=3, engine="fused", use_kernel=use_kernel)
    rb = outlier_quantease(w3, sig3, SPEC3, **kw)
    for g in range(G):
        rg = outlier_quantease(w3[g], sig3[g], SPEC3, **kw)
        np.testing.assert_allclose(
            np.asarray(rb.w_hat[g]), np.asarray(rg.w_hat), atol=1e-5
        )
        np.testing.assert_allclose(np.asarray(rb.h[g]), np.asarray(rg.h), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(jax.tree.map(lambda x: x[g], rb.grid).scale),
            np.asarray(rg.grid.scale),
        )


# ---------------------------------------------------------------------------
# Sparse-Ĥ COO artifact + serving parity
# ---------------------------------------------------------------------------


def test_emit_qt_coo_roundtrip(layer_problem):
    """emit='qt' stores Ĥ as int32 flat indices + fp16 values; dequantizing
    codes + COO reproduces Ŵ exactly and Ĥ to fp16 rounding."""
    from repro.core.solver import PTQConfig, _emit_leaf
    from repro.quant import dequantize_tensor

    w, sigma = layer_problem
    s = int(0.01 * w.size)
    res = outlier_quantease(w, sigma, SPEC3, s=s, iterations=6, use_kernel="xla")
    cfg = PTQConfig(method="qe_outlier", spec=SPEC3, outlier_frac=0.01, emit="qt")
    qt = _emit_leaf(res.w_hat, res.h, w, cfg, grid=res.grid)
    assert qt.outlier_idx.dtype == jnp.int32
    assert qt.outlier_values.dtype == jnp.float16
    assert qt.outlier_idx.shape == (s,)
    deq = dequantize_tensor(qt)
    h16 = np.asarray(res.h).astype(np.float16).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(deq), np.asarray(res.w_hat) + h16, rtol=0, atol=1e-5
    )


def test_apply_linear_coo_matches_dense_ref(layer_problem):
    """Serving: apply_linear's post-GEMM COO correction equals the dense
    (dequant + Ĥ) matmul."""
    from repro.core.solver import PTQConfig, _emit_leaf
    from repro.models.common import apply_linear
    from repro.quant import dequantize_tensor

    w, sigma = layer_problem
    q, p = w.shape
    s = int(0.01 * w.size)
    res = outlier_quantease(w, sigma, SPEC3, s=s, iterations=4, use_kernel="xla")
    cfg = PTQConfig(method="qe_outlier", spec=SPEC3, outlier_frac=0.01, emit="qt")
    qt = _emit_leaf(res.w_hat, res.h, w, cfg, grid=res.grid)
    r = np.random.default_rng(23)
    x = jnp.asarray(r.standard_normal((5, p)).astype(np.float32))
    y = apply_linear(qt, x)
    w_eff = dequantize_tensor(qt)  # codes + fp16 COO, the artifact's truth
    y_ref = x @ w_eff.T
    np.testing.assert_allclose(
        np.asarray(y.astype(jnp.float32)), np.asarray(y_ref),
        rtol=1e-4, atol=1e-3,
    )


def test_solver_groups_outlier_layers(layer_problem):
    """The grouped solver batches same-shape outlier layers through one
    vmapped fused solve and scatters per-layer grids/h back."""
    from repro.core.solver import PTQConfig, _solve_group

    w, sigma = layer_problem
    G = 2
    w3 = jnp.stack([w, w * 1.2])
    sig3 = jnp.stack([sigma, sigma])
    cfg = PTQConfig(method="qe_outlier", spec=SPEC3, iterations=3,
                    outlier_frac=0.01)
    w_hat3, hs, grids = _solve_group(w3, sig3, cfg, mesh=None)
    assert w_hat3.shape == w3.shape
    assert len(hs) == G and all(h is not None for h in hs)
    assert len(grids) == G and all(g is not None for g in grids)
    s = max(int(cfg.outlier_frac * w.size), 1)
    for g in range(G):
        assert int((np.asarray(hs[g]) != 0).sum()) <= s
        e = float(relative_error(w3[g], w_hat3[g] + hs[g], sig3[g]))
        assert np.isfinite(e) and e < 1.0
