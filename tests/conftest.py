import dataclasses
import os
import sys

# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forges 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


def reduce_cfg(cfg, **over):
    kw = dict(
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        n_periods=2,
        max_seq=512,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2),
        moe_d_ff=128 if cfg.n_experts else 0,
        ssm_state=16,
        ssm_headdim=8,
        ssm_expand=2,
        n_enc_periods=2 if cfg.n_enc_periods else 0,
        n_frames=32 if cfg.family == "encdec" else 1500,
        n_prefix=8 if cfg.n_prefix else 0,
    )
    kw.update(over)
    return dataclasses.replace(cfg, **kw)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def layer_problem():
    """A realistic (W, Σ) layer-quantization problem."""
    import jax.numpy as jnp

    r = np.random.default_rng(42)
    q, p, n = 96, 128, 512
    x = r.standard_normal((p, n)).astype(np.float32)
    w = r.standard_normal((q, p)).astype(np.float32)
    w[r.random((q, p)) < 0.003] *= 10.0
    return jnp.asarray(w), jnp.asarray(x @ x.T)
