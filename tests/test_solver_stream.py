"""Streaming/batched/sharded PTQ engine: parity against the record-based oracle.

The seed engine captured raw activation lists per linear and quantized
layers one-by-one in Python loops.  The streaming engine accumulates
CalibStats (Σ only) during capture and solves same-shape groups in batched
vmapped calls.  These tests pin the refactor to the old semantics:

* streaming Σ == Σ rebuilt from raw records (fp32 tolerance),
* grouped/vmapped solves == sequential per-layer solves,
* whole-model relative-error reports match a record-based reference engine
  within 1e-4 (ISSUE 1 acceptance bar),
* sharded paths == local paths (psum gram fallback on 1 device; the
  2-device shard_map run is skip-guarded on jax.device_count()).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.calib import CalibStats, sharded_gram
from repro.core.solver import (
    PTQConfig,
    QUANTIZABLE,
    _MOE_NAMES,
    _quantize_one,
    ptq_quantize_model,
)
from repro.core.quantease import quantease_quantize, relative_error
from repro.core.gptq import gptq_quantize
from repro.models import init_params, make_plan, train_loss
from repro.models import model as M
from repro.models.common import (
    capture_gram_stats,
    capture_linear_inputs,
    capture_scope,
)
from repro.quant import GridSpec
from tests.conftest import reduce_cfg


def _small(arch="stablelm_12b", **over):
    cfg = reduce_cfg(get_config(arch), **over)
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    calib = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 48)).astype(np.int32))}
        for _ in range(2)
    ]
    return plan, params, calib


def _capture_both(plan, params, calib):
    """One block's capture pass under both mechanisms at once."""
    mcfg = plan.cfg
    xs = [M._embed_tokens(plan, params, b["tokens"]) for b in calib]
    p_blk = jax.tree.map(lambda a: a[0], params["dec"])["b0"]
    records, stats = {}, {}
    with capture_linear_inputs(records), capture_gram_stats(stats), capture_scope("s"):
        for x in xs:
            M._block_apply(
                mcfg, plan.heads, mcfg.pattern[0], p_blk, x,
                mode="train", pos_ids=jnp.arange(x.shape[1]),
            )
    return p_blk, records, stats


def _sigma_from_records(xs_list):
    p = xs_list[0].shape[-1]
    sigma = jnp.zeros((p, p), jnp.float32)
    for x in xs_list:
        x32 = x.astype(jnp.float32)
        sigma = sigma + x32.T @ x32
    return sigma


def test_streaming_sigma_matches_records():
    plan, params, calib = _small()
    _, records, stats = _capture_both(plan, params, calib)
    assert set(records) == set(stats)
    assert records, "no linears captured"
    for key, xs_list in records.items():
        ref = _sigma_from_records(xs_list)
        got = stats[key].sigma
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        assert float(jnp.max(jnp.abs(got - ref))) / scale < 1e-5, key
        assert stats[key].n == sum(x.shape[0] for x in xs_list)


def test_streaming_sigma_matches_records_moe():
    plan, params, calib = _small("olmoe_1b_7b")
    _, records, stats = _capture_both(plan, params, calib)
    moe_keys = [k for k in stats if k.split("/")[-1] in _MOE_NAMES]
    assert moe_keys, "no MoE linears captured"
    for key in moe_keys:
        sig = stats[key].sigma
        E = sig.shape[0]
        assert sig.ndim == 3
        for e in range(E):
            ref = _sigma_from_records([x[e] for x in records[key]])
            scale = float(jnp.max(jnp.abs(ref))) + 1e-9
            assert float(jnp.max(jnp.abs(sig[e] - ref))) / scale < 1e-5, (key, e)


@pytest.mark.parametrize("method", ["gptq", "quantease"])
def test_batched_solve_matches_sequential(layer_problem, method):
    w, sigma = layer_problem
    r = np.random.default_rng(1)
    # Three distinct layers of one shape: perturb w and sigma per group slot.
    w3 = jnp.stack([w, w * 0.5, w + 0.1])
    x2 = jnp.asarray(r.standard_normal((w.shape[1], 300)).astype(np.float32))
    sig3 = jnp.stack([sigma, sigma * 2.0, x2 @ x2.T])
    spec = GridSpec(bits=4)
    if method == "gptq":
        batched = gptq_quantize(w3, sig3, spec)
        seq = [gptq_quantize(w3[g], sig3[g], spec) for g in range(3)]
    else:
        batched, objs = quantease_quantize(
            w3, sig3, spec, iterations=4, track_objective=True
        )
        assert objs.shape == (3, 4)
        seq = [quantease_quantize(w3[g], sig3[g], spec, iterations=4)[0] for g in range(3)]
    for g in range(3):
        np.testing.assert_allclose(
            np.asarray(batched[g]), np.asarray(seq[g]), atol=2e-5
        )


def test_moe_vmapped_experts_match_per_expert_loop():
    plan, params, calib = _small("olmoe_1b_7b")
    cfg = PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=4)
    _, report = ptq_quantize_model(plan, params, calib, cfg)
    # Reference: per-expert sequential solves from the same streaming stats.
    _, _, stats = _capture_both(plan, params, calib)
    p_blk = jax.tree.map(lambda a: a[0], params["dec"])["b0"]
    checked = 0
    for name in sorted(_MOE_NAMES & set(p_blk)):
        st = stats[f"s/{name}"]
        w = p_blk[name]
        for e in range(w.shape[0]):
            w2d = w[e].reshape(w.shape[1], -1).T.astype(jnp.float32)
            w_hat, _, _ = _quantize_one(w2d, st.sigma[e], cfg)
            ref = float(relative_error(w2d, w_hat, st.sigma[e]))
            got = report[f"dec.p0.b0/{name}.e{e}"]
            assert abs(got - ref) < 1e-4, (name, e)
            checked += 1
    assert checked >= plan.cfg.n_experts


def test_engine_report_matches_record_based_reference():
    """ISSUE 1 acceptance: streaming+batched engine reports == a record-based
    sequential engine within 1e-4 on a reduced config."""
    plan, params, calib = _small(d_model=96, head_dim=24, d_ff=192, n_periods=2)
    cfg = PTQConfig(method="quantease", spec=GridSpec(bits=3), iterations=6)
    _, report = ptq_quantize_model(plan, params, calib, cfg)

    # Reference engine: raw records → per-layer Σ → sequential solves, with
    # the same quantized-prefix propagation structure.
    mcfg = plan.cfg
    xs = [M._embed_tokens(plan, params, b["tokens"]) for b in calib]
    ref_report = {}
    stack = params["dec"]
    for period in range(mcfg.n_periods):
        p_period = jax.tree.map(lambda a: a[period], stack)
        for i, b in enumerate(mcfg.pattern):
            scope = f"dec.p{period}.b{i}"
            records = {}
            with capture_linear_inputs(records), capture_scope(scope):
                for x in xs:
                    M._block_apply(
                        mcfg, plan.heads, b, p_period[f"b{i}"], x,
                        mode="train", pos_ids=jnp.arange(x.shape[1]),
                    )
            new_blk = dict(p_period[f"b{i}"])
            for name, w in p_period[f"b{i}"].items():
                key = f"{scope}/{name}"
                if name not in QUANTIZABLE or key not in records:
                    continue
                sigma = _sigma_from_records(records[key])
                w2d = w.reshape(sigma.shape[0], -1).T.astype(jnp.float32)
                w_hat, _, _ = _quantize_one(w2d, sigma, cfg)
                ref_report[key] = float(relative_error(w2d, w_hat, sigma))
                new_blk[name] = w_hat.T.reshape(w.shape).astype(w.dtype)
            xs = [
                M._block_apply(
                    mcfg, plan.heads, b, new_blk, x,
                    mode="train", pos_ids=jnp.arange(x.shape[1]),
                )[0]
                for x in xs
            ]
    assert set(ref_report) == set(report)
    for key in ref_report:
        assert abs(report[key] - ref_report[key]) < 1e-4, key


def test_stream_chunking_changes_nothing():
    plan, params, calib = _small()
    cfg_whole = PTQConfig(method="gptq", spec=GridSpec(bits=4))
    cfg_chunk = PTQConfig(method="gptq", spec=GridSpec(bits=4), stream_chunk=1)
    _, rep_whole = ptq_quantize_model(plan, params, calib, cfg_whole)
    _, rep_chunk = ptq_quantize_model(plan, params, calib, cfg_chunk)
    assert set(rep_whole) == set(rep_chunk)
    for k in rep_whole:
        assert abs(rep_whole[k] - rep_chunk[k]) < 1e-5, k


def test_progress_callback_reports_every_block():
    plan, params, calib = _small()
    seen = []
    cfg = PTQConfig(method="rtn", spec=GridSpec(bits=4))
    _, report = ptq_quantize_model(
        plan, params, calib, cfg, progress_cb=seen.append
    )
    total = plan.cfg.n_periods * len(plan.cfg.pattern)
    assert len(seen) == total
    assert seen[-1]["done_blocks"] == seen[-1]["total_blocks"] == total
    assert sum(r["n_linears"] for r in seen) == len(report)


def test_sharded_gram_fallback_matches_local(rng):
    x = jnp.asarray(rng.standard_normal((64, 24)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(sharded_gram(x, None)), np.asarray(x.T @ x), rtol=1e-6
    )


@pytest.mark.skipif(jax.device_count() < 2, reason="needs ≥2 devices")
def test_sharded_engine_matches_single_device():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    plan, params, calib = _small()
    cfg = PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=4)
    _, rep_local = ptq_quantize_model(plan, params, calib, cfg)
    cfg_sh = PTQConfig(
        method="quantease", spec=GridSpec(bits=4), iterations=4, shard=True
    )
    _, rep_shard = ptq_quantize_model(plan, params, calib, cfg_sh, mesh=mesh)
    assert set(rep_local) == set(rep_shard)
    for k in rep_local:
        assert abs(rep_local[k] - rep_shard[k]) < 1e-4, k


def test_sharded_engine_parity_subprocess():
    """Run the 2-device parity check on forged host devices.

    Subprocess because xla_force_host_platform_device_count must be set
    before jax initializes (same pattern as test_dryrun_small)."""
    import os
    import subprocess
    import sys

    script = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=2';"
        "import sys; sys.path.insert(0,'src'); sys.path.insert(0,'.');"
        "from tests.test_solver_stream import test_sharded_engine_matches_single_device as t;"
        "t(); print('OK')"
    )
    root = os.path.dirname(os.path.dirname(__file__))
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, cwd=root,
        env=dict(os.environ, PYTHONPATH="src"), timeout=560,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_quantized_model_still_runs():
    plan, params, calib = _small()
    qp, _ = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=4,
                  stream_chunk=1),
    )
    assert bool(jnp.isfinite(train_loss(plan, qp, calib[0])))
