"""Retrace-count regression: the paged engine's executable set is pinned.

The serving SLO assumes the step loop reaches a compile fixed point: after
warm-up every prefill chunk / decode step / spec round hits the jit cache.
Accidental shape polymorphism (a stray Python int in a traced position, a
bucket boundary that drifts, a weak-type flip) shows up here as a count
diff long before it shows up as a latency regression.

Method (see repro/analysis/sanitize.py): run the full trace once on a
warm-up engine — this compiles the module-level helper ops (jnp.ones,
gather/scatter fragments, …) into JAX's global cache — then run the
identical trace on a *fresh identical* engine under the monitor.  The
fresh engine re-jits its own wrappers (new lambda objects ⇒ new cache
keys), while the helpers stay cached, so the monitored count is exactly
the engine's own executable set.  The numbers pinned below are therefore
a contract: "the paged engine compiles N distinct executables for this
workload".  If a change legitimately alters the engine's jit surface
(new wrapper, different bucketing), update the pin with the new count and
say why in the commit.
"""

import jax
import numpy as np
import pytest

from repro.analysis.sanitize import CompilationMonitor
from repro.configs import get_config
from repro.models import init_params, make_plan
from repro.serve.engine import PagedServingEngine, Request
from repro.serve.spec import SpecConfig, truncate_draft
from tests.conftest import reduce_cfg

# One trace per (wrapper, shape-signature).  The plain engine's whole
# workload — chunked prefill, decode, preemption + resume — stabilizes at
# TWO signatures: every prefill chunk is padded to prefill_chunk and every
# decode batch to max_batch, so one chunk executable + one decode
# executable serve the entire trace (page-copy never fires with the
# prefix cache off).  Speculation adds the draft proposer and the verify
# step at its two trailing widths (γ+1 mid-stream, 1 at the tail).
PLAIN_ENGINE_EXECUTABLES = 2
SPEC_ENGINE_EXECUTABLES = 5


@pytest.fixture(scope="module")
def served():
    cfg = reduce_cfg(
        get_config("stablelm_12b"), d_model=96, head_dim=24, d_ff=192, n_periods=2
    )
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    draft_plan, draft_params = truncate_draft(plan, params, 1)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (6, 21, 47, 11, 33)]
    return plan, params, draft_plan, draft_params, prompts


def _drive(eng, prompts, max_new=7):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=np.asarray(p), max_new_tokens=max_new))
    return [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]


def _plain_engine(plan, params):
    # n_pages=13 with this workload forces ≥1 preemption + resume
    # (tests/test_paged_serve.py pins that behaviour).
    return PagedServingEngine(
        plan, params, max_batch=3, max_seq=128, page_size=8,
        prefill_chunk=16, n_pages=13, prefix_cache=False,
    )


def _spec_engine(plan, params, draft_plan, draft_params):
    spec = SpecConfig(draft_plan=draft_plan, draft_params=draft_params, gamma=3)
    return PagedServingEngine(
        plan, params, max_batch=2, max_seq=128, page_size=8,
        prefill_chunk=16, n_pages=65, spec=spec,
    )


def test_plain_engine_executable_count_pinned(served):
    plan, params, _, _, prompts = served
    warm = _plain_engine(plan, params)
    out_warm = _drive(warm, prompts)
    assert warm.n_preemptions >= 1  # the trace really covers resume

    fresh = _plain_engine(plan, params)
    with CompilationMonitor() as mon:
        out = _drive(fresh, prompts)
    assert out == out_warm  # fixed point is also a correctness fixed point

    n = mon.count()
    assert n == PLAIN_ENGINE_EXECUTABLES, (
        f"paged engine traced {n} executables "
        f"(expected {PLAIN_ENGINE_EXECUTABLES}):\n  "
        + "\n  ".join(e.detail.splitlines()[0] for e in mon.events)
    )

    # Stability: more work with the same shape vocabulary compiles nothing.
    with CompilationMonitor() as mon2:
        _drive(fresh, [prompts[0], prompts[3]])
    mon2.assert_bounded(0)


def test_spec_engine_executable_count_pinned(served):
    plan, params, dplan, dparams, prompts = served
    warm = _spec_engine(plan, params, dplan, dparams)
    out_warm = _drive(warm, prompts[:4])
    assert warm.n_spec_rounds > 0  # speculation actually ran

    fresh = _spec_engine(plan, params, dplan, dparams)
    with CompilationMonitor() as mon:
        out = _drive(fresh, prompts[:4])
    assert out == out_warm

    n = mon.count()
    assert n == SPEC_ENGINE_EXECUTABLES, (
        f"spec engine traced {n} executables "
        f"(expected {SPEC_ENGINE_EXECUTABLES}):\n  "
        + "\n  ".join(e.detail.splitlines()[0] for e in mon.events)
    )

    with CompilationMonitor() as mon2:
        _drive(fresh, [prompts[0], prompts[3]])
    mon2.assert_bounded(0)
