"""Model zoo: per-arch smoke (reduced configs) + decode≡prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    make_plan,
    prefill,
    train_loss,
)
from tests.conftest import reduce_cfg


def _batch(cfg, rng, B, S):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frames, cfg.d_model)), jnp.bfloat16
        )
    if cfg.n_prefix:
        out["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix, cfg.d_model)), jnp.bfloat16
        )
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, rng):
    """One forward/train step on CPU: output shapes + no NaNs (+grad)."""
    cfg = reduce_cfg(get_config(arch))
    plan = make_plan(cfg, axis_n=1)
    params = init_params(plan, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng, 2, 48)
    loss, grads = jax.value_and_grad(lambda p: train_loss(plan, p, batch))(params)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm))


@pytest.mark.parametrize(
    "arch,window",
    [
        ("stablelm_12b", None),  # GQA + rope
        ("gemma2_27b", 24),  # local/global + softcaps + post-norms + tied
        ("qwen15_32b", None),  # MHA + qkv bias
        ("mamba2_2_7b", None),  # pure SSD recurrence
        ("jamba_1_5_large", None),  # hybrid + MoE
        ("whisper_large_v3", None),  # enc-dec + cross cache + learned pos
        ("mixtral_8x22b", 24),  # MoE + SWA ring cache
        ("llava_next_34b", None),  # prefix stub
    ],
)
def test_decode_matches_prefill(arch, window, rng):
    cfg = reduce_cfg(get_config(arch))
    if window is not None:
        cfg = dataclasses.replace(
            cfg,
            pattern=tuple(
                dataclasses.replace(b, window=window if b.window else None)
                for b in cfg.pattern
            ),
        )
    plan = make_plan(cfg, axis_n=1)
    params = init_params(plan, jax.random.PRNGKey(1))
    B, S = 2, 40
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    batch_s = _batch(cfg, np.random.default_rng(5), B, S)
    batch_s["tokens"] = jnp.asarray(toks[:, :S])
    batch_s1 = dict(batch_s, tokens=jnp.asarray(toks))

    npre = cfg.n_prefix or 0
    cache = init_cache(plan, B, 128)
    _, cache = prefill(plan, params, batch_s, cache)
    lg_dec, _ = decode_step(
        plan, params, jnp.asarray(toks[:, S : S + 1]), cache, jnp.int32(S + npre)
    )
    lg_ref, _ = prefill(plan, params, batch_s1, init_cache(plan, B, 128))
    diff = float(jnp.max(jnp.abs(lg_dec.astype(jnp.float32) - lg_ref.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(lg_ref.astype(jnp.float32)))) + 1e-9
    assert diff / scale < 0.05, f"{arch}: decode diverges from prefill ({diff})"


def test_flash_attention_matches_naive(rng):
    from repro.models.common import flash_attention

    B, S, KV, G, hd = 2, 50, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)

    def naive(q, k, v, window=None):
        s = jnp.einsum("bskgd,btkd->bkgst", q, k) / np.sqrt(hd)
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        if window is not None:
            mask &= jnp.arange(S)[:, None] - jnp.arange(S)[None, :] < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, -1)
        return jnp.einsum("bkgst,btkd->bskgd", p, v)

    for window, qc, kc in [(None, 16, 16), (13, 8, 16), (None, 64, 64)]:
        out = flash_attention(q, k, v, causal=True, window=window, q_chunk=qc, kv_chunk=kc)
        expect = naive(q, k, v, window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-3)


def test_head_plan_cases():
    from repro.models.common import make_head_plan

    hp = make_head_plan(32, 8, 160, 16)  # stablelm GQA
    assert (hp.dup, hp.kv_pad, hp.g_pad, hp.h_pad) == (2, 16, 2, 32)
    hp = make_head_plan(40, 40, 128, 16)  # qwen MHA → zero-pad 48
    assert (hp.dup, hp.kv_pad, hp.g_pad) == (1, 48, 1)
    hp = make_head_plan(56, 8, 128, 16)  # llava ragged GQA
    assert (hp.dup, hp.kv_pad) == (2, 16) and hp.h_pad >= 56
    hp = make_head_plan(20, 20, 64, 16)  # whisper MHA → 32
    assert hp.kv_pad == 32 and hp.dup == 1
    hp = make_head_plan(32, 8, 128, 1)  # no mesh: untouched
    assert (hp.dup, hp.kv_pad, hp.g_pad) == (1, 8, 4)


def test_param_counts_match_targets():
    targets = {
        "stablelm_12b": 12.1, "gemma2_27b": 27.2, "qwen15_32b": 35.2,
        "phi3_mini_3_8b": 3.8, "jamba_1_5_large": 398, "olmoe_1b_7b": 6.9,
        "mixtral_8x22b": 141, "mamba2_2_7b": 2.7, "llava_next_34b": 34.4,
    }
    for arch, tgt in targets.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - tgt) / tgt < 0.06, f"{arch}: {n:.2f}B vs {tgt}B"


def test_int8_kv_cache_decode_matches(rng):
    """§Perf H1: int8 KV cache decode tracks the bf16 path closely."""
    import dataclasses as dc

    import jax

    cfg = reduce_cfg(get_config("stablelm_12b"))
    params = init_params(make_plan(cfg, 1), jax.random.PRNGKey(1))
    B, S = 2, 40
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    outs = {}
    for kvd in ("bf16", "int8"):
        plan = make_plan(cfg, 1, kv_cache_dtype=kvd)
        cache = init_cache(plan, B, 128)
        _, cache = prefill(plan, params, {"tokens": jnp.asarray(toks[:, :S])}, cache)
        lg, _ = decode_step(plan, params, jnp.asarray(toks[:, S:S+1]), cache, jnp.int32(S))
        outs[kvd] = lg.astype(jnp.float32)
    diff = float(jnp.max(jnp.abs(outs["bf16"] - outs["int8"])))
    scale = float(jnp.max(jnp.abs(outs["bf16"]))) + 1e-9
    assert diff / scale < 0.05


def test_moe_dispatch_groups_equivalent(rng):
    """§Perf H2: grouped dispatch changes only the (rare) drop pattern."""
    import jax

    cfg = reduce_cfg(get_config("olmoe_1b_7b"))
    params = init_params(make_plan(cfg, 1), jax.random.PRNGKey(2))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32))}
    losses = []
    for g in (1, 4):
        plan = make_plan(cfg, 1, dispatch_groups=g)
        losses.append(float(train_loss(plan, params, batch)))
    assert abs(losses[0] - losses[1]) < 0.02  # capacity-local drops only
