"""Roofline HLO collective parser unit tests."""

from repro.roofline.analysis import collective_bytes, _shape_bytes


HLO = """
ENTRY main {
  %ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = (f32[8,16]{1,0}) reduce-scatter(f32[128,16]{1,0} %z), replica_groups=[16,16]<=[256], dimensions={0}
  %cp-start = bf16[64]{0} collective-permute-start(bf16[64]{0} %w), source_target_pairs={{0,1}}
  %done = bf16[64]{0} collective-permute-done(bf16[64]{0} %cp-start)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
    assert _shape_bytes("(f32[8,16])") == 8 * 16 * 4
    assert _shape_bytes("u8[3]") == 3


def test_collective_bytes_kinds():
    out = collective_bytes(HLO, 256)
    g = 16
    # all-gather: global result bytes × (g−1)/g
    assert abs(out["all-gather"] - 16 * 4096 * 2 * (g - 1) / g) < 1
    # all-reduce: 2 × bytes × (g−1)/g
    assert abs(out["all-reduce"] - 2 * 128 * 4 * (g - 1) / g) < 1
    # reduce-scatter: shard bytes × (g−1)
    assert abs(out["reduce-scatter"] - 8 * 16 * 4 * (g - 1)) < 1
    # collective-permute counted once (start only)
    assert out["collective-permute"] == 64 * 2
    assert out["counts"]["all-gather"] == 1
