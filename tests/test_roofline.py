"""Roofline HLO collective parser + pack-decision unit tests."""

from repro.roofline.analysis import (
    choose_weight_layout,
    collective_bytes,
    paged_kv_bytes_per_token,
    weight_bytes,
    _shape_bytes,
)


HLO = """
ENTRY main {
  %ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = (f32[8,16]{1,0}) reduce-scatter(f32[128,16]{1,0} %z), replica_groups=[16,16]<=[256], dimensions={0}
  %cp-start = bf16[64]{0} collective-permute-start(bf16[64]{0} %w), source_target_pairs={{0,1}}
  %done = bf16[64]{0} collective-permute-done(bf16[64]{0} %cp-start)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
    assert _shape_bytes("(f32[8,16])") == 8 * 16 * 4
    assert _shape_bytes("u8[3]") == 3


def test_shape_bytes_packed_sub_byte():
    """s4/u4 are packed 2/byte in HBM: 0.5 B/elem, ragged rows round up.
    (The pre-fix 1 B/elem made every packed memory term 2× too high.)"""
    assert _shape_bytes("s4[128,256]") == 128 * 256 // 2
    assert _shape_bytes("u4[128,256]") == 128 * 256 // 2
    assert _shape_bytes("u4[7]") == 4  # last half-filled byte still occupied
    assert _shape_bytes("s8[128,256]") == 128 * 256  # int8 untouched


def test_weight_bytes_packed_halves_codes():
    dense = weight_bytes(128, 512, bits=4, n_groups=4, packed=False)
    packed = weight_bytes(128, 512, bits=4, n_groups=4, packed=True)
    assert dense - packed == 128 * 512 * 0.5  # codes halve, metadata constant


def test_choose_weight_layout_prefers_tile_native_on_tpu():
    d = choose_weight_layout(256, 1024, bits=4, group_size=256, tile_k=512,
                             backend="tpu")
    assert d.kind == "tile" and d.packed and d.tile_k == 512
    assert d.tiling == "whole-groups"
    # tile-native reads the packed bytes at full bandwidth; the interleaved
    # linear-packed layout reads the same bytes slower, linear-unpacked
    # reads twice the bytes — both lose on the memory term.
    assert d.memory_s < choose_weight_layout(
        256, 1024, bits=4, group_size=256, tile_k=None, backend="tpu"
    ).memory_s


def test_choose_weight_layout_degrades_off_tpu_and_off_4bit():
    assert choose_weight_layout(256, 1024, bits=3, tile_k=512).kind == "linear"
    d = choose_weight_layout(256, 1024, bits=4, tile_k=512, backend="cpu")
    assert d.kind == "linear"  # XLA ref un-prepacks: tile buys nothing
    # odd p cannot pack at all
    assert not choose_weight_layout(256, 1023, bits=4, tile_k=None).packed


def test_paged_kv_bytes_per_token_ordering():
    kw = dict(page_size=16, kvp=4, hd=64, n_periods=2, context_pages=3.0)
    b16 = paged_kv_bytes_per_token(kv_dtype="bf16", **kw)
    i8 = paged_kv_bytes_per_token(kv_dtype="int8", **kw)
    i4 = paged_kv_bytes_per_token(kv_dtype="int4", **kw)
    assert b16 > i8 > i4
    # int4 codes alone are 4× smaller than bf16; with scale planes the
    # total still lands well under half of bf16 at hd=64.
    assert i4 < 0.5 * b16


def test_collective_bytes_kinds():
    out = collective_bytes(HLO, 256)
    g = 16
    # all-gather: global result bytes × (g−1)/g
    assert abs(out["all-gather"] - 16 * 4096 * 2 * (g - 1) / g) < 1
    # all-reduce: 2 × bytes × (g−1)/g
    assert abs(out["all-reduce"] - 2 * 128 * 4 * (g - 1) / g) < 1
    # reduce-scatter: shard bytes × (g−1)
    assert abs(out["reduce-scatter"] - 8 * 16 * 4 * (g - 1)) < 1
    # collective-permute counted once (start only)
    assert out["collective-permute"] == 64 * 2
    assert out["counts"]["all-gather"] == 1
