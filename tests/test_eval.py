"""Eval subsystem tests: split disjointness, scorer parity (train-loss, QT
artifact, serving engines), synthetic tasks, schema validation — plus the
satellite CLI fixes (resume-tolerant progress parse, degradable report)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.solver import PTQConfig, ptq_quantize_model
from repro.data.pipeline import SPLITS, DataConfig, make_batch_fn
from repro.eval import (
    engine_parity,
    eval_model,
    next_token_logits,
    perplexity_on_stream,
    validate_doc,
)
from repro.eval.harness import EvalBudget
from repro.eval.scorer import make_scorer, token_scores
from repro.eval.tasks import build_choice_items, cloze_accuracy, continuation_choice
from repro.models import init_params, make_plan, train_loss
from repro.quant import GridSpec
from repro.serve.qparams import quantize_params_for_serving
from tests.conftest import reduce_cfg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def eval_model_fixture():
    cfg = reduce_cfg(
        get_config("stablelm_12b"), d_model=96, head_dim=24, d_ff=192, n_periods=2
    )
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    dc = DataConfig(vocab=cfg.vocab)
    calib_fn, _ = make_batch_fn(dc, cfg, batch=2, seq=48, split="calib")
    eval_fn, corpus = make_batch_fn(dc, cfg, batch=2, seq=48, split="eval")
    calib = [{k: jnp.asarray(v) for k, v in calib_fn(0).items()}]
    return plan, params, calib, eval_fn, corpus


# ---------------------------------------------------------------------------
# Split disjointness (no calibration leakage)
# ---------------------------------------------------------------------------


def test_splits_are_disjoint_streams():
    dc = DataConfig(vocab=256)
    cfg = get_config("stablelm_12b")
    fns = {
        s: make_batch_fn(dc, cfg, batch=4, seq=64, split=s)[0]
        for s in ("train", "calib", "eval")
    }
    # Across a window of steps, no sequence of one split reappears in any
    # other split (row-level check — the streams use distinct SeedSequence
    # entropy tuples, so a collision would be a keying bug).
    rows = {
        s: {tuple(r) for i in range(6) for r in np.asarray(fn(i)["tokens"])}
        for s, fn in fns.items()
    }
    assert not rows["eval"] & rows["calib"]
    assert not rows["eval"] & rows["train"]
    assert not rows["calib"] & rows["train"]


def test_train_split_keeps_historical_keying():
    """split="train" must replay existing checkpoints: batch i keyed by
    (seed, i) exactly as before the split parameter existed."""
    dc = DataConfig(vocab=256)
    cfg = get_config("stablelm_12b")
    fn, corpus = make_batch_fn(dc, cfg, batch=2, seq=32, split="train")
    rng = np.random.default_rng((dc.seed, 7))
    np.testing.assert_array_equal(fn(7)["tokens"], corpus.sample(rng, 2, 32))


def test_unknown_split_rejected():
    dc = DataConfig(vocab=256)
    with pytest.raises(ValueError):
        make_batch_fn(dc, get_config("stablelm_12b"), 2, 32, split="test")
    assert set(SPLITS) == {"train", "calib", "eval"}


# ---------------------------------------------------------------------------
# Scorer
# ---------------------------------------------------------------------------


def test_scorer_nll_matches_train_loss(eval_model_fixture):
    plan, params, _, eval_fn, _ = eval_model_fixture
    batch = {k: jnp.asarray(v) for k, v in eval_fn(0).items()}
    out = perplexity_on_stream(plan, params, eval_fn, n_batches=1)
    ref = float(train_loss(plan, params, batch))
    assert abs(out["nll"] - ref) < 1e-5
    assert out["ppl"] == pytest.approx(np.exp(ref), rel=1e-5)


def test_scorer_logprobs_are_normalized(eval_model_fixture):
    plan, params, _, eval_fn, _ = eval_model_fixture
    tokens = jnp.asarray(eval_fn(0)["tokens"])
    lp, rank = token_scores(plan, params, tokens)
    assert lp.shape == rank.shape == (tokens.shape[0], tokens.shape[1] - 1)
    assert float(lp.max()) <= 0.0
    assert int(rank.min()) >= 0 and int(rank.max()) < plan.cfg.vocab
    # chunking must not change scores
    lp32, _ = token_scores(plan, params, tokens, chunk=16)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp32), atol=1e-5)


def test_scorer_qt_artifact_matches_fake_quant(eval_model_fixture):
    """Scoring the restacked QuantizedTensor serving artifact agrees with
    the fake-quant tree of the same solve (same codes — only the bf16
    weight cast vs in-GEMM dequant differs)."""
    plan, params, calib, eval_fn, _ = eval_model_fixture
    pc = dict(method="quantease", spec=GridSpec(bits=4), iterations=3)
    qp_fake, _ = ptq_quantize_model(plan, params, calib, PTQConfig(**pc, emit="fake"))
    qp_qt, _ = ptq_quantize_model(plan, params, calib, PTQConfig(**pc, emit="qt"))
    qt_params = quantize_params_for_serving(plan, params, qp_qt["dec"])
    nll_fake = perplexity_on_stream(plan, qp_fake, eval_fn, n_batches=1)["nll"]
    nll_qt = perplexity_on_stream(plan, qt_params, eval_fn, n_batches=1)["nll"]
    assert np.isfinite(nll_qt)
    assert abs(nll_fake - nll_qt) < 0.02


# ---------------------------------------------------------------------------
# Parity bridge: scorer vs serving engines
# ---------------------------------------------------------------------------


def test_scorer_parity_with_engines_dense(eval_model_fixture):
    plan, params, _, _, _ = eval_model_fixture
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (5, 17, 26)]
    par = engine_parity(plan, params, prompts, max_seq=64, page_size=8,
                        prefill_chunk=8)
    # Documented tolerance: the engines' first decode replays the last
    # prompt token through the decode path (KV bytes ≈1 bf16 ulp off the
    # prefill path), so scorer-vs-engine is tolerance-bounded while
    # paged-vs-contiguous — same decode path — stays bitwise.
    assert par["max_abs_diff_contiguous"] <= par["tol"]
    assert par["max_abs_diff_paged"] <= par["tol"]
    assert par["paged_bitwise_contiguous"]


def test_scorer_parity_with_engines_quantized(eval_model_fixture):
    """Same bridge on the QuantizedTensor artifact: quality numbers are
    measured on the exact bytes the engines serve."""
    plan, params, calib, _, _ = eval_model_fixture
    qp, _ = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=3,
                  emit="qt"),
    )
    qt_params = quantize_params_for_serving(plan, params, qp["dec"])
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (7, 19)]
    par = engine_parity(plan, qt_params, prompts, max_seq=64, page_size=8,
                        prefill_chunk=8)
    assert par["max_abs_diff_contiguous"] <= par["tol"]
    assert par["max_abs_diff_paged"] <= par["tol"]
    assert par["paged_bitwise_contiguous"]


def test_next_token_logits_teacher_forced_consistency(eval_model_fixture):
    """The parity anchor and the teacher-forced scorer agree: scoring
    [prompt + x] puts logprob(x | prompt) at the last position, which must
    match log_softmax of the prefill-path next-token logits."""
    plan, params, _, _, _ = eval_model_fixture
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, 250, 13).astype(np.int32)
    logits = next_token_logits(plan, params, prompt)
    x = int(np.argmax(logits))
    lp_ref = float(jax.nn.log_softmax(jnp.asarray(logits))[x])
    lp, _ = token_scores(
        plan, params, jnp.asarray(np.concatenate([prompt, [x]])[None])
    )
    assert float(lp[0, -1]) == pytest.approx(lp_ref, abs=5e-3)


# ---------------------------------------------------------------------------
# Tasks
# ---------------------------------------------------------------------------


def test_choice_items_shapes_and_gold(eval_model_fixture):
    _, _, _, eval_fn, _ = eval_model_fixture
    tokens, gold = build_choice_items(
        eval_fn, n_items=6, n_choices=4, prompt_len=16, cont_len=8
    )
    assert tokens.shape == (6, 4, 24)
    assert gold.shape == (6,) and set(gold) <= {0, 1, 2, 3}
    # every choice of an item shares the prompt; gold continuation differs
    # from at least one distractor
    for i in range(6):
        for c in range(4):
            np.testing.assert_array_equal(tokens[i, c, :16], tokens[i, 0, :16])


def test_tasks_run_and_bound(eval_model_fixture):
    plan, params, _, eval_fn, _ = eval_model_fixture
    cl = cloze_accuracy(plan, params, eval_fn, n_batches=1, ks=(1, 5))
    assert 0.0 <= cl["top1"] <= cl["top5"] <= 1.0
    ch = continuation_choice(
        plan, params, eval_fn, n_items=8, prompt_len=16, cont_len=8
    )
    assert 0.0 <= ch["acc"] <= 1.0 and np.isfinite(ch["margin"])


def test_eval_model_smoke_budget(eval_model_fixture):
    plan, params, _, eval_fn, _ = eval_model_fixture
    out = eval_model(plan, params, eval_fn, budget=EvalBudget.smoke())
    for k in ("ppl", "nll", "top1", "top5", "choice_acc", "choice_margin"):
        assert k in out and np.isfinite(out[k])


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------


def _min_doc(smoke=True):
    row = {
        "method": "rtn", "bits": 4, "outlier_frac": None, "group_size": None,
        "mean_layer_err": 0.01, "ppl": 10.0, "nll": 2.3, "top1": 0.5,
        "top5": 0.9, "choice_acc": 0.5, "choice_margin": 1.0,
    }
    return {
        "schema": 1, "smoke": smoke, "dense": {"ppl": 9.0},
        "grid": [row],
        "parity": {
            "n_prompts": 3, "max_abs_diff_contiguous": 0.001,
            "max_abs_diff_paged": 0.001, "paged_bitwise_contiguous": True,
            "tol": 0.05,
        },
    }


def test_validate_doc_accepts_minimal_smoke():
    assert validate_doc(_min_doc()) == []


def test_validate_doc_flags_problems():
    doc = _min_doc()
    doc["schema"] = 99
    del doc["grid"][0]["ppl"]
    doc["parity"]["max_abs_diff_paged"] = 1.0
    probs = validate_doc(doc)
    assert any("schema" in p for p in probs)
    assert any("grid[0]" in p for p in probs)
    assert any("paged diff" in p for p in probs)


def test_validate_doc_full_run_orderings():
    doc = _min_doc(smoke=False)

    def row(method, bits, ppl):
        r = dict(doc["grid"][0])
        r.update(method=method, bits=bits, ppl=ppl)
        return r

    doc["grid"] = [
        row("rtn", 4, 10.2), row("gptq", 4, 10.1), row("quantease", 4, 10.0),
        row("rtn", 3, 14.0), row("gptq", 3, 12.0), row("quantease", 3, 11.0),
        row("qe_outlier", 3, 10.5),
    ]
    assert validate_doc(doc) == []
    doc["grid"][5]["ppl"] = 13.0  # quantease@3 > gptq@3 → ordering violated
    assert any("ordering violated at 3 bits" in p for p in validate_doc(doc))
    doc["grid"][5]["ppl"] = 11.0
    doc["grid"][6]["ppl"] = 11.5  # outlier not better than plain
    assert any("outlier" in p for p in validate_doc(doc))


# ---------------------------------------------------------------------------
# Satellite: shared progress.jsonl parser tolerates torn tails
# ---------------------------------------------------------------------------


def test_load_progress_tolerates_truncation(tmp_path):
    from repro.launch.progress import append_record, load_progress

    # the historical import site re-exports the one shared implementation
    import repro.launch.quantize as q

    assert q.load_progress is load_progress
    assert q.append_record is append_record

    p = tmp_path / "progress.jsonl"
    assert load_progress(str(p)) == []  # absent
    p.write_text("")
    assert load_progress(str(p)) == []  # empty (killed before first record)
    rec1 = {"done_blocks": 1, "total_blocks": 4}
    rec2 = {"done_blocks": 2, "total_blocks": 4}
    p.write_text(json.dumps(rec1) + "\n" + json.dumps(rec2) + "\n")
    assert load_progress(str(p)) == [rec1, rec2]
    # torn last line (killed mid-write): parse up to the last complete record
    p.write_text(json.dumps(rec1) + "\n" + json.dumps(rec2)[:9])
    assert load_progress(str(p)) == [rec1]
    # torn line *followed by* records = corruption, not truncation
    p.write_text('{"bad": \n' + json.dumps(rec2) + "\n")
    with pytest.raises(ValueError):
        load_progress(str(p))
    # append_record round-trips through the tolerant parser
    p.write_text("")
    append_record(str(p), rec1)
    append_record(str(p), rec2)
    assert load_progress(str(p)) == [rec1, rec2]


# ---------------------------------------------------------------------------
# Satellite: benchmarks/report.py degrades gracefully
# ---------------------------------------------------------------------------


def _run_report(bench_dir):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.report",
         "--dir", os.path.join(str(bench_dir), "no_dryrun"),
         "--bench-dir", str(bench_dir)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120,
    )


def test_report_survives_missing_artifacts(tmp_path):
    r = _run_report(tmp_path)
    assert r.returncode == 0, r.stderr
    assert r.stdout.count("missing — regenerate") == 4


def test_report_survives_unknown_schema_and_garbage(tmp_path):
    (tmp_path / "BENCH_solver.json").write_text(json.dumps(
        {"schema": 42, "backend": "cpu", "cd": [{"q": 1}]}
    ))
    (tmp_path / "BENCH_serve.json").write_text("{not json")
    (tmp_path / "BENCH_eval.json").write_text(json.dumps(
        {"schema": 1, "backend": "cpu", "dense": {"ppl": 1.0},
         "grid": [{"method": "rtn", "bits": 4}], "parity": None}
    ))
    (tmp_path / "BENCH_tune.json").write_text(json.dumps(
        {"schema": 1, "backend": "cpu", "budget_avg_bits": 3.0,
         "candidates": [{"label": "uniform@3b", "kind": "uniform"}],
         "best": {"label": "uniform@3b"}, "parity": None}
    ))
    r = _run_report(tmp_path)
    assert r.returncode == 0, r.stderr
    assert "unknown schema 42" in r.stdout      # renders best-effort
    assert "unreadable/not JSON" in r.stdout    # garbage noted, not fatal
    assert "| rtn | 4 |" in r.stdout            # partial eval doc renders
    assert "| **uniform@3b** | uniform |" in r.stdout  # partial tune doc renders


def test_scorer_parity_with_engines_prepacked(eval_model_fixture):
    """Parity bridge on the *packed* artifact (DESIGN.md §Packed-serving):
    the tile-native weight reorder is a pure column permutation, so the
    scorer-vs-engine tolerance and paged-vs-contiguous bitwise claims must
    survive prepacking unchanged.  backend="tpu" forces the tile decision
    even though this host serves through the XLA ref path."""
    from repro.serve.qparams import prepack_params_for_serving

    plan, params, calib, _, _ = eval_model_fixture
    qp, _ = ptq_quantize_model(
        plan, params, calib,
        PTQConfig(method="quantease", spec=GridSpec(bits=4), iterations=3,
                  emit="qt"),
    )
    qt_params = quantize_params_for_serving(plan, params, qp["dec"])
    qt_params, decisions = prepack_params_for_serving(
        plan, qt_params, backend="tpu"
    )
    assert decisions and any(v.startswith("tile") for v in decisions.values())
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (7, 19)]
    par = engine_parity(plan, qt_params, prompts, max_seq=64, page_size=8,
                        prefill_chunk=8)
    assert par["max_abs_diff_contiguous"] <= par["tol"]
    assert par["max_abs_diff_paged"] <= par["tol"]
    assert par["paged_bitwise_contiguous"]
