"""SLO-aware scheduling: admission shedding, deadlines, priorities.

The paged engine's ``slo`` scheduler (the default) adds per-request
deadlines and priorities on top of the legacy paged machinery:

* **provable shed** — admission rejects a deadline the engine can prove
  unmeetable even under the *optimistic* cost bound (fastest observed
  step costs, zero queueing); it never sheds cold (no cost evidence).
* **deadline_missed** — overdue work is terminated at the next step
  boundary, keeping partial output and freeing its pages immediately.
* **priority** — the queue admits highest-priority-first (low-priority
  work parks, holding no pages) and preemption evicts the
  lowest-priority / most-slack / newest lane.
* **degeneracy** — for default requests (no deadline, priority 0) the
  ``slo`` policy is bit-identical to the legacy ``fifo`` policy; the
  whole legacy test suite pins this implicitly by running on the
  default scheduler.

Tests drive a virtual clock (one tick per ``clock()`` call) so deadline
arithmetic is exact and host-speed independent.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, make_plan
from repro.serve.engine import PagedServingEngine, Request
from tests.conftest import reduce_cfg


class StepClock:
    """Deterministic engine clock: each call advances one virtual second."""

    def __init__(self, dt: float = 1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


@pytest.fixture(scope="module")
def slo_model():
    cfg = reduce_cfg(get_config("stablelm_12b"))
    plan = make_plan(cfg, 1)
    params = init_params(plan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 250, n).astype(np.int32) for n in (6, 10, 10, 9)]
    return plan, params, prompts


_KW = dict(max_batch=2, max_seq=128, page_size=8, prefill_chunk=16,
           prefix_cache=False)


def test_scheduler_name_validated(slo_model):
    plan, params, _ = slo_model
    with pytest.raises(ValueError, match="unknown scheduler"):
        PagedServingEngine(plan, params, scheduler="edf", **_KW)


def test_queue_pick_priority_then_deadline_then_arrival(slo_model):
    plan, params, prompts = slo_model
    clk = StepClock()
    eng = PagedServingEngine(plan, params, clock=clk, **_KW)
    r0 = Request(rid=0, prompt=prompts[0], max_new_tokens=2)
    r1 = Request(rid=1, prompt=prompts[0], max_new_tokens=2, priority=1,
                 deadline_ms=5_000)
    r2 = Request(rid=2, prompt=prompts[0], max_new_tokens=2, priority=1,
                 deadline_ms=2_000)
    r3 = Request(rid=3, prompt=prompts[0], max_new_tokens=2, priority=2)
    for r in (r0, r1, r2, r3):
        eng.submit(r)
    # highest priority first ...
    assert eng.queue[eng._queue_pick()] is r3
    eng.queue.remove(r3)
    # ... then earliest absolute deadline within the priority class ...
    assert eng.queue[eng._queue_pick()] is r2
    eng.queue.remove(r2)
    # ... then arrival order (no deadline sorts last: deadline_at() = inf)
    assert eng.queue[eng._queue_pick()] is r1
    eng.queue.remove(r1)
    assert eng.queue[eng._queue_pick()] is r0
    # fifo ignores all of it
    fifo = PagedServingEngine(plan, params, scheduler="fifo", clock=StepClock(),
                              **_KW)
    for r in (Request(rid=0, prompt=prompts[0], max_new_tokens=2),
              Request(rid=1, prompt=prompts[0], max_new_tokens=2, priority=9)):
        fifo.submit(r)
    assert fifo._queue_pick() == 0


def test_provably_unmeetable_deadline_is_shed(slo_model):
    plan, params, prompts = slo_model
    clk = StepClock()
    eng = PagedServingEngine(plan, params, clock=clk, **_KW)
    # Cold engine: no cost evidence, nothing is provable — a hopeless
    # deadline still admits (and will expire instead; see below).
    hopeless = Request(rid=9, prompt=prompts[0], max_new_tokens=1,
                       deadline_ms=0.001)
    assert eng._provably_unmeetable(hopeless) is None
    # Warm up: one plain request populates the min-observed step costs.
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=2))
    eng.run()
    assert eng._min_decode_s is not None and eng._min_chunk_s is not None
    # 20 decode steps at ≥1 virtual second each can never fit in 3s.
    doomed = Request(rid=1, prompt=prompts[0], max_new_tokens=20,
                     deadline_ms=3_000)
    eng.submit(doomed)
    eng.run()
    assert doomed.status == "shed" and doomed.done
    assert "provably unmeetable" in doomed.error
    assert doomed.output == []  # shed at admission: no work was burned
    assert eng.n_shed == 1
    # A generous deadline sails through the same admission check.
    fine = Request(rid=2, prompt=prompts[0], max_new_tokens=20,
                   deadline_ms=10_000_000)
    eng.submit(fine)
    eng.run()
    assert fine.status == "completed" and len(fine.output) == 20
    assert eng.pool.n_free == eng.n_pages - 1


def test_deadline_missed_mid_generation_keeps_partial_output(slo_model):
    plan, params, prompts = slo_model
    eng = PagedServingEngine(plan, params, clock=StepClock(), **_KW)
    req = Request(rid=0, prompt=prompts[0], max_new_tokens=20,
                  deadline_ms=20_000)  # ~4-5 decode steps of virtual time
    eng.submit(req)
    fin = eng.run()
    assert fin == [req] and req.status == "deadline_missed"
    assert 0 < len(req.output) < 20  # partial output survives
    assert req.first_token_t is not None
    assert eng.n_deadline_missed == 1
    assert eng.pool.n_free == eng.n_pages - 1  # pages freed at expiry


def test_fifo_scheduler_matches_slo_for_default_requests(slo_model):
    """With no deadlines and uniform priorities the two policies coincide —
    same preemptions, token-identical outputs, both equal to an ample run."""
    plan, params, prompts = slo_model

    def serve(**kw):
        eng = PagedServingEngine(plan, params, **_KW | kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        eng.run()
        return eng, [r.output for r in sorted(eng.finished, key=lambda r: r.rid)]

    _, ample = serve()
    slo_eng, slo_out = serve(n_pages=7, scheduler="slo")
    fifo_eng, fifo_out = serve(n_pages=7, scheduler="fifo")
    assert slo_out == ample and fifo_out == ample
    assert slo_eng.n_preemptions == fifo_eng.n_preemptions
    assert slo_eng.pool.n_free == slo_eng.n_pages - 1


def test_priority_preemption_evicts_low_priority_lane(slo_model):
    """Under pool pressure the slo victim is the low-priority lane: the
    urgent request runs uninterrupted, the background one resumes later
    with deterministic (ample-identical) output."""
    plan, params, prompts = slo_model
    kw = dict(max_batch=2, max_seq=64, page_size=4, prefill_chunk=16,
              prefix_cache=False)

    def serve(**over):
        eng = PagedServingEngine(plan, params, **kw | over)
        back = Request(rid=0, prompt=prompts[1], max_new_tokens=8)
        urgent = Request(rid=1, prompt=prompts[2], max_new_tokens=8, priority=5)
        eng.submit(back)
        eng.submit(urgent)
        eng.run()
        return eng, back, urgent

    _, back_a, urgent_a = serve()  # ample pool: no preemption
    # 6 allocatable pages: both admit at 3 pages each, the first growth
    # starves the pool and must evict someone.
    eng, back, urgent = serve(n_pages=7)
    assert eng.n_preemptions >= 1
    assert urgent.status == "completed" and urgent.n_preemptions == 0
    assert back.status == "preempted_resumed" and back.n_preemptions >= 1
    assert urgent.output == urgent_a.output
    assert back.output == back_a.output
    assert eng.pool.n_free == eng.n_pages - 1


def test_low_priority_parks_until_urgent_work_drains(slo_model):
    """A parked request holds no pages and finishes last; under fifo the
    same workload completes in arrival order."""
    plan, params, prompts = slo_model

    def serve(scheduler):
        eng = PagedServingEngine(plan, params, scheduler=scheduler,
                                 **_KW | {"max_batch": 1})
        reqs = [Request(rid=0, prompt=prompts[1], max_new_tokens=3),
                Request(rid=1, prompt=prompts[2], max_new_tokens=3, priority=5),
                Request(rid=2, prompt=prompts[3], max_new_tokens=3, priority=5)]
        for r in reqs:
            eng.submit(r)
        return [r.rid for r in eng.run()]  # finished[] is completion order

    assert serve("slo") == [1, 2, 0]  # urgent first, background parked
    assert serve("fifo") == [0, 1, 2]  # legacy arrival order
