"""End-to-end quantized-model evaluation (perplexity + synthetic task accuracy).

The paper's headline artifacts are quality tables — perplexity (Tables 1-3,
5) and zero-shot task accuracy (§5.3) — measured on full models, not layer
errors.  This package scores any parameter tree the repo can produce (dense
bf16, fake-quant, or stacked :class:`~repro.quant.QuantizedTensor` serving
params) end to end on the synthetic Markov corpus:

* :mod:`repro.eval.scorer` — batched teacher-forced log-likelihood, chunked
  over the sequence so logits never materialize at (B, S, V); plus the
  prefill-path next-token logits used by the serving parity bridge,
* :mod:`repro.eval.tasks` — synthetic zero-shot-style tasks (cloze
  next-token top-k, multi-choice continuation scoring) so both of the
  paper's metric families exist offline,
* :mod:`repro.eval.harness` — the method × bits × outlier grid sweep behind
  ``launch/eval.py`` / ``benchmarks/bench_eval.py`` (``BENCH_eval.json``),
  schema validation, and the scorer-vs-serving-engine logit parity check.

Eval batches come from ``data/pipeline.py``'s ``split="eval"`` stream,
disjoint from the ``calib`` stream by construction (no calibration leakage).
"""

from repro.eval.harness import (
    EVAL_SCHEMA,
    engine_parity,
    eval_model,
    quantized_parity,
    run_grid,
    validate_doc,
)
from repro.eval.scorer import make_scorer, next_token_logits, perplexity_on_stream
from repro.eval.tasks import cloze_accuracy, continuation_choice

__all__ = [
    "EVAL_SCHEMA",
    "make_scorer",
    "next_token_logits",
    "perplexity_on_stream",
    "cloze_accuracy",
    "continuation_choice",
    "eval_model",
    "run_grid",
    "engine_parity",
    "quantized_parity",
    "validate_doc",
]
