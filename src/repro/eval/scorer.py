"""Batched teacher-forced scorer — full-model log-likelihood, bounded memory.

Scores token streams against any parameter tree the model stack accepts:
dense bf16, fake-quant (``emit="fake"``), or the serving artifact itself —
stacked :class:`~repro.quant.QuantizedTensor` leaves from
``serve.qparams.quantize_params_for_serving`` (the scan in
``models._run_stack`` slices QT pytrees exactly like dense leaves, and
``apply_linear`` dispatches them through the dequant GEMM).  Scoring the
serving artifact rather than a dequantized copy is what ties the quality
numbers to the bytes serving actually executes.

Memory: the forward keeps the usual (B, S, d) activations; the head is
evaluated in sequence chunks (mirroring ``models.chunked_cross_entropy``)
so logits never materialize at (B, S, V) — per-chunk peak is (B, C, V).
Beyond the gold logprob, each chunk also emits gold-token *ranks* (count of
strictly-larger logits), from which any top-k accuracy is derived for free.

Scope: token-only decoder stacks (the same gate as paged serving) — encoder-
decoder and prefix models raise.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.common import apply_norm, softcap

__all__ = [
    "token_scores",
    "make_scorer",
    "next_token_logits",
    "perplexity_on_stream",
]


def _check_family(cfg):
    if cfg.family == "encdec" or cfg.n_prefix:
        raise ValueError("eval scorer supports token-only decoder models only")


def _hidden_states(plan, params, tokens: jax.Array) -> jax.Array:
    """(B, S) int32 → (B, S, d) final-norm hidden states, teacher-forced."""
    cfg = plan.cfg
    _check_family(cfg)
    B, S = tokens.shape
    x = M._embed_tokens(plan, params, tokens)
    pos = jnp.arange(S)
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice(
            params["pos_emb"], (0, 0), (S, cfg.d_model)
        )[None].astype(plan.dtype)
    x, _, _ = M._run_stack(
        plan, params["dec"], cfg.pattern, x, mode="train", pos_ids=pos
    )
    return apply_norm(params["final_norm"], x, cfg.norm)


def token_scores(plan, params, tokens: jax.Array, *, chunk: int = 128):
    """Per-token teacher-forced scores.

    Returns ``(logprob, rank)``, both (B, S-1) fp32/int32: position ``t``
    scores token ``t+1`` given the prefix — ``logprob`` is the gold-token
    log-probability, ``rank`` the number of strictly-larger logits (0 ⇒ the
    gold token is the greedy argmax; ``rank < k`` ⇒ a top-k hit).
    """
    cfg = plan.cfg
    if tokens.shape[1] < 2:
        raise ValueError("token_scores needs sequences of at least 2 tokens")
    x = _hidden_states(plan, params, tokens)
    B, S, d = x.shape
    head = M._logit_head(plan, params)
    labels = tokens[:, 1:]  # (B, S-1)
    x = x[:, :-1]  # position t predicts token t+1
    Sm = S - 1
    chunk = min(chunk, Sm)
    n = -(-Sm // chunk)
    pad = n * chunk - Sm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    xs = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def step(_, inp):
        xc, lc = inp
        logits = M._head_logits(xc, head)  # (B, C, Vp) fp32
        logits = softcap(logits, cfg.logit_softcap)
        vp = logits.shape[-1]
        if vp > cfg.vocab:
            bias = jnp.where(jnp.arange(vp) < cfg.vocab, 0.0, -jnp.inf)
            logits = logits + bias
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        rank = (logits > gold[..., None]).sum(-1)
        return (), (gold - lse, rank.astype(jnp.int32))

    _, (lp, rank) = jax.lax.scan(step, (), (xs, ls))
    lp = lp.transpose(1, 0, 2).reshape(B, n * chunk)[:, :Sm]
    rank = rank.transpose(1, 0, 2).reshape(B, n * chunk)[:, :Sm]
    return lp, rank


def make_scorer(plan, *, chunk: int = 128):
    """Jitted ``(params, tokens) → (logprob, rank)`` closure — one compiled
    executable reused across the whole eval stream (params are an argument,
    not a baked constant, so the same scorer serves every grid cell of a
    given params layout)."""
    return jax.jit(
        functools.partial(_token_scores_flat, plan, chunk)
    )


def _token_scores_flat(plan, chunk, params, tokens):
    return token_scores(plan, params, tokens, chunk=chunk)


def next_token_logits(plan, params, prompt: np.ndarray) -> np.ndarray:
    """Prefill-path logits predicting the token after ``prompt``.

    Runs the model's own :func:`repro.models.prefill` on the *unpadded*
    prompt (B=1, cache sized to the prompt), so the returned vector is
    byte-for-byte the prefill path the serving engines execute — the anchor
    of the parity bridge (:func:`repro.eval.harness.engine_parity`).
    """
    _check_family(plan.cfg)
    n = int(len(prompt))
    cache = M.init_cache(plan, 1, n)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, _ = M.prefill(plan, params, {"tokens": toks}, cache)
    return np.asarray(logits[0].astype(jnp.float32))


def perplexity_on_stream(
    plan,
    params,
    batch_fn,
    *,
    n_batches: int = 4,
    step0: int = 0,
    chunk: int = 128,
    scorer=None,
) -> dict:
    """Mean NLL / perplexity / top-k hits over ``batch_fn(step0 + i)``.

    ``batch_fn`` should come from ``data.pipeline.make_batch_fn(...,
    split="eval")`` so the stream is disjoint from calibration.  Returns
    ``{"nll", "ppl", "top1", "top5", "n_tokens"}`` (fp means over all scored
    positions of all batches).
    """
    score = scorer if scorer is not None else make_scorer(plan, chunk=chunk)
    tot_lp = 0.0
    tot_t1 = 0
    tot_t5 = 0
    n_tok = 0
    for i in range(n_batches):
        tokens = jnp.asarray(batch_fn(step0 + i)["tokens"])
        lp, rank = score(params, tokens)
        lp = np.asarray(lp, np.float64)
        rank = np.asarray(rank)
        tot_lp += lp.sum()
        tot_t1 += int((rank < 1).sum())
        tot_t5 += int((rank < 5).sum())
        n_tok += lp.size
    nll = -tot_lp / max(n_tok, 1)
    return {
        "nll": float(nll),
        "ppl": float(np.exp(nll)),
        "top1": tot_t1 / max(n_tok, 1),
        "top5": tot_t5 / max(n_tok, 1),
        "n_tokens": n_tok,
    }
