"""Eval grid harness: method × bits × outlier sweep, parity bridge, schema.

Drives the paper's Tables 1-3 shape end to end: quantize the model with
each (method, bits[, outlier budget]) cell via the whole-model PTQ driver
(``core/solver.py``), restack the ``emit="qt"`` artifact into serving
layout (``serve/qparams.py``), and score perplexity + task accuracy on the
``split="eval"`` stream — the same QuantizedTensor bytes the serving
engines execute.  ``launch/eval.py`` and ``benchmarks/bench_eval.py`` are
thin frontends over :func:`run_grid`; ``BENCH_eval.json`` is the committed
artifact (``validate_doc`` is the CI schema guard, and on full — non-smoke
— documents it also asserts the paper's orderings: QuantEase ≤ GPTQ ≤ RTN
perplexity at 3 and 4 bits, outlier-aware 3-bit < plain 3-bit).

The **parity bridge** (:func:`engine_parity`) ties the scorer to serving:
for a set of prompts it compares the scorer's prefill-path next-token
logits against the first decode logits of both serving engines on the same
params.  Documented tolerance: the engines' first decode *replays* the
last prompt token through the decode path, whose KV bytes differ from the
prefill path by ≈1 bf16 ulp, so scorer-vs-engine agrees to ~1e-2 absolute
on O(10)-magnitude logits — while paged-vs-contiguous stays **bitwise**
(the engines share the decode path; tests/test_paged_serve.py pins it).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.eval.scorer import make_scorer, next_token_logits, perplexity_on_stream
from repro.eval.tasks import continuation_choice

__all__ = [
    "EVAL_SCHEMA",
    "EvalBudget",
    "eval_model",
    "run_grid",
    "engine_parity",
    "validate_doc",
]

EVAL_SCHEMA = 1

_GRID_KEYS = {
    "method", "bits", "outlier_frac", "group_size", "mean_layer_err",
    "ppl", "nll", "top1", "top5", "choice_acc", "choice_margin",
}
_PARITY_KEYS = {
    "n_prompts", "max_abs_diff_contiguous", "max_abs_diff_paged",
    "paged_bitwise_contiguous", "tol",
}


@dataclasses.dataclass(frozen=True)
class EvalBudget:
    """How much eval to run per cell (smoke shrinks everything)."""

    n_ppl_batches: int = 4
    n_choice_items: int = 32
    choice_prompt_len: int = 32
    choice_cont_len: int = 8
    chunk: int = 128

    @classmethod
    def smoke(cls) -> "EvalBudget":
        return cls(
            n_ppl_batches=1, n_choice_items=8,
            choice_prompt_len=8, choice_cont_len=4, chunk=32,
        )


def eval_model(plan, params, batch_fn, *, budget: EvalBudget, scorer=None) -> dict:
    """All metrics for one parameter tree on the eval stream.

    The cloze top-1/top-5 come from the perplexity pass itself (the scorer
    emits gold ranks alongside logprobs), so the task accuracies carry the
    full ``n_ppl_batches`` statistics with no second scoring pass."""
    scorer = scorer if scorer is not None else make_scorer(plan, chunk=budget.chunk)
    out = perplexity_on_stream(
        plan, params, batch_fn, n_batches=budget.n_ppl_batches, scorer=scorer
    )
    choice = continuation_choice(
        plan, params, batch_fn,
        n_items=budget.n_choice_items,
        prompt_len=budget.choice_prompt_len,
        cont_len=budget.choice_cont_len,
        step0=budget.n_ppl_batches,  # fresh eval steps, still split="eval"
        scorer=scorer,
    )
    out["choice_acc"] = choice["acc"]
    out["choice_margin"] = choice["margin"]
    return out


def _quantize_cell(plan, params, calib, cell: dict, *, iterations: int, emit: str):
    """One PTQ run for a grid cell; returns (scored-params, mean layer err)."""
    from repro.core.solver import PTQConfig, ptq_quantize_model
    from repro.quant import GridSpec

    frac = cell.get("outlier_frac")
    cfg = PTQConfig(
        method=cell["method"],
        spec=GridSpec(bits=cell["bits"], group_size=cell.get("group_size")),
        iterations=cell.get("iterations", iterations),
        outlier_frac=0.01 if frac is None else frac,
        emit=emit,
    )
    qp, rep = ptq_quantize_model(plan, params, calib, cfg)
    if emit == "qt":
        from repro.serve.qparams import quantize_params_for_serving

        qp = quantize_params_for_serving(plan, params, qp["dec"])
    return qp, float(np.mean(list(rep.values())))


def run_grid(
    plan,
    params,
    calib: list,
    batch_fn,
    cells: list,
    *,
    iterations: int = 20,
    emit: str = "qt",
    budget: Optional[EvalBudget] = None,
    progress_cb=None,
) -> dict:
    """Evaluate dense params + every quantized cell; returns the doc body.

    ``cells``: list of ``{"method", "bits"[, "outlier_frac", "group_size",
    "iterations"]}``.  ``emit="qt"`` (default) scores the restacked
    QuantizedTensor serving artifact; ``emit="fake"`` scores dequantized
    bf16 (faster, identical up to the bf16 cast — tests pin the parity).
    """
    budget = budget or EvalBudget()
    scorer = make_scorer(plan, chunk=budget.chunk)
    dense = {
        k: (round(v, 6) if isinstance(v, float) else v)
        for k, v in eval_model(plan, params, batch_fn, budget=budget,
                               scorer=scorer).items()
    }
    if progress_cb:
        progress_cb({"cell": "dense", **dense})
    rows = []
    for cell in cells:
        qp, err = _quantize_cell(
            plan, params, calib, cell, iterations=iterations, emit=emit
        )
        row = {
            "method": cell["method"],
            "bits": cell["bits"],
            "outlier_frac": cell.get("outlier_frac"),
            "group_size": cell.get("group_size"),
            "mean_layer_err": round(err, 6),
        }
        row.update(
            {
                k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in eval_model(
                    plan, qp, batch_fn, budget=budget, scorer=scorer
                ).items()
            }
        )
        rows.append(row)
        if progress_cb:
            progress_cb({"cell": f"{cell['method']}@{cell['bits']}", **row})
    return {"dense": dense, "grid": rows}


def engine_parity(
    plan,
    params,
    prompts: list,
    *,
    max_seq: int = 128,
    page_size: int = 16,
    prefill_chunk: int = 32,
    max_batch: int = 4,
) -> dict:
    """Scorer-vs-serving logit parity on the same params.

    For each prompt: the scorer's prefill-path next-token logits
    (:func:`~repro.eval.scorer.next_token_logits`) vs both engines' first
    decode logits (``record_logits=True``).  Returns max abs diffs and
    whether paged matched contiguous bitwise.  See the module docstring for
    the tolerance story.
    """
    from repro.serve.engine import PagedServingEngine, Request, ServingEngine

    ref = {i: next_token_logits(plan, params, p) for i, p in enumerate(prompts)}

    def first_logits(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=np.asarray(p, np.int32),
                               max_new_tokens=1))
        eng.run()
        return {rid: tr[0] for rid, tr in eng.logit_trace.items()}

    contig = first_logits(
        ServingEngine(plan, params, max_batch=max_batch, max_seq=max_seq,
                      prefill_pad=prefill_chunk, record_logits=True)
    )
    paged = first_logits(
        PagedServingEngine(plan, params, max_batch=max_batch, max_seq=max_seq,
                           page_size=page_size, prefill_chunk=prefill_chunk,
                           record_logits=True)
    )
    d_contig = max(
        float(np.abs(ref[i] - contig[i]).max()) for i in range(len(prompts))
    )
    d_paged = max(
        float(np.abs(ref[i] - paged[i]).max()) for i in range(len(prompts))
    )
    bitwise = all(
        np.array_equal(contig[i], paged[i]) for i in range(len(prompts))
    )
    return {
        "n_prompts": len(prompts),
        "max_abs_diff_contiguous": round(d_contig, 6),
        "max_abs_diff_paged": round(d_paged, 6),
        "paged_bitwise_contiguous": bool(bitwise),
        "tol": 0.05,
    }


def quantized_parity(
    plan, params, calib, prompts, *, cell=None, iterations: int = 6,
    prepack_backend=None, **kw
) -> dict:
    """Quantize one grid cell (default: quantease 4-bit, ``emit="qt"``) and
    run :func:`engine_parity` on the resulting serving artifact — the
    issue-level claim is parity on the *quantized* checkpoint, i.e. that
    the quality numbers describe the bytes serving executes.

    ``prepack_backend`` additionally pushes the artifact through the
    roofline weight-layout decision (serve/qparams.
    prepack_params_for_serving) for that backend before serving, so the
    parity bridge holds on the *packed* bytes — pass ``"tpu"`` to force the
    tile-native reorder even when the test host serves through the XLA ref
    path (which un-permutes exactly; DESIGN.md §Packed-serving)."""
    cell = cell or {"method": "quantease", "bits": 4}
    qp, _ = _quantize_cell(plan, params, calib, cell, iterations=iterations,
                           emit="qt")
    out = {}
    if prepack_backend is not None:
        from repro.serve.qparams import prepack_params_for_serving

        qp, decisions = prepack_params_for_serving(
            plan, qp, backend=prepack_backend
        )
        out["pack_layouts"] = sorted(set(decisions.values()))
    out.update(engine_parity(plan, qp, prompts, **kw))
    out["cell"] = f"{cell['method']}@{cell['bits']}"
    return out


def _ppl(doc, method, bits):
    for row in doc.get("grid", []):
        if row.get("method") == method and row.get("bits") == bits:
            return row.get("ppl")
    return None


def validate_doc(doc: dict) -> list:
    """Schema (and, for full runs, ordering) problems; empty ⇒ valid."""
    probs = []
    if doc.get("schema") != EVAL_SCHEMA:
        probs.append(f"schema != {EVAL_SCHEMA}")
    if not isinstance(doc.get("dense"), dict) or "ppl" not in doc.get("dense", {}):
        probs.append("dense: missing/incomplete")
    rows = doc.get("grid")
    if not isinstance(rows, list) or not rows:
        probs.append("grid: missing/empty")
        return probs
    for i, row in enumerate(rows):
        missing = _GRID_KEYS - set(row)
        if missing:
            probs.append(f"grid[{i}]: missing keys {sorted(missing)}")
    par = doc.get("parity")
    if not isinstance(par, dict) or _PARITY_KEYS - set(par):
        probs.append("parity: missing/incomplete")
    else:
        if par["max_abs_diff_contiguous"] > par["tol"]:
            probs.append("parity: contiguous diff exceeds tol")
        if par["max_abs_diff_paged"] > par["tol"]:
            probs.append("parity: paged diff exceeds tol")
        if not par["paged_bitwise_contiguous"]:
            probs.append("parity: paged != contiguous bitwise")
    if not doc.get("smoke"):
        # Full runs must reproduce the paper's orderings.
        for bits in (3, 4):
            qe, g, r = (_ppl(doc, m, bits) for m in ("quantease", "gptq", "rtn"))
            if None in (qe, g, r):
                probs.append(f"grid: missing method row at {bits} bits")
            elif not (qe <= g <= r):
                probs.append(
                    f"ordering violated at {bits} bits: "
                    f"quantease={qe} gptq={g} rtn={r}"
                )
        qe3, out3 = _ppl(doc, "quantease", 3), _ppl(doc, "qe_outlier", 3)
        if out3 is None:
            probs.append("grid: missing qe_outlier 3-bit row")
        elif qe3 is not None and not (out3 < qe3):
            probs.append(f"outlier 3-bit ({out3}) not better than plain ({qe3})")
    return probs
