"""Synthetic zero-shot-style tasks over the Markov corpus.

The paper's second metric family is zero-shot task accuracy (LAMBADA, PIQA,
…).  Offline we build the two task shapes those benchmarks reduce to, on the
synthetic corpus itself:

* **cloze / next-token top-k** (:func:`cloze_accuracy`) — LAMBADA-style:
  given the prefix, is the true next token in the model's top-k?  The
  corpus' limited branching (``DataConfig.branching`` plausible successors)
  makes top-1/top-5 meaningful rather than saturated.
* **multi-choice continuation scoring** (:func:`continuation_choice`) —
  PIQA/HellaSwag-style: a prompt plus N candidate continuations (the true
  one and N−1 continuations lifted from *other* eval sequences at the same
  position); the model picks the candidate with the highest teacher-forced
  log-likelihood.  Distractors are real chain samples, so the task probes
  whether the model tracks *this* prefix's transitions, not just marginal
  plausibility.

Both consume the ``split="eval"`` stream and score through
:mod:`repro.eval.scorer`, so every number is attributable to the exact
parameter bytes being evaluated (dense or QuantizedTensor).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.eval.scorer import make_scorer

__all__ = ["cloze_accuracy", "continuation_choice", "build_choice_items"]


def cloze_accuracy(
    plan, params, batch_fn, *, n_batches: int = 2, step0: int = 0,
    ks=(1, 5), chunk: int = 128, scorer=None,
) -> dict:
    """Top-k next-token accuracy over eval batches: ``{"top{k}": acc}``."""
    score = scorer if scorer is not None else make_scorer(plan, chunk=chunk)
    hits = {k: 0 for k in ks}
    n_tok = 0
    for i in range(n_batches):
        tokens = jnp.asarray(batch_fn(step0 + i)["tokens"])
        _, rank = score(params, tokens)
        rank = np.asarray(rank)
        for k in ks:
            hits[k] += int((rank < k).sum())
        n_tok += rank.size
    return {f"top{k}": hits[k] / max(n_tok, 1) for k in ks}


def build_choice_items(
    batch_fn, *, n_items: int, n_choices: int = 4, prompt_len: int = 32,
    cont_len: int = 8, step0: int = 0, seed: int = 0,
):
    """Assemble (n_items, n_choices, prompt_len + cont_len) token arrays.

    Item ``i`` uses eval-stream sequence ``i``'s prefix as the prompt; the
    true continuation is that sequence's actual next ``cont_len`` tokens,
    distractors are the same-position continuations of ``n_choices - 1``
    *other* sequences.  Returns ``(tokens, gold)`` with ``gold[i]`` the true
    choice index (position randomized per item).
    """
    rng = np.random.default_rng(seed)
    seqs = []
    step = step0
    while sum(s.shape[0] for s in seqs) < n_items + n_choices:
        b = np.asarray(batch_fn(step)["tokens"])
        if b.shape[1] < prompt_len + cont_len:
            raise ValueError(
                f"eval seq len {b.shape[1]} < prompt_len+cont_len "
                f"{prompt_len + cont_len}"
            )
        seqs.append(b)
        step += 1
    pool = np.concatenate(seqs, axis=0)
    L = prompt_len + cont_len
    tokens = np.zeros((n_items, n_choices, L), np.int32)
    gold = rng.integers(0, n_choices, n_items)
    n_pool = pool.shape[0]
    for i in range(n_items):
        prompt = pool[i, :prompt_len]
        # distractor sources: other pool rows, offset so none equals i
        others = [(i + 1 + j) % n_pool for j in range(n_choices - 1)]
        conts = []
        for c in range(n_choices):
            if c == gold[i]:
                conts.append(pool[i, prompt_len:L])
            else:
                src = others.pop()
                conts.append(pool[src, prompt_len:L])
        for c in range(n_choices):
            tokens[i, c, :prompt_len] = prompt
            tokens[i, c, prompt_len:] = conts[c]
    return tokens, gold


def continuation_choice(
    plan, params, batch_fn, *, n_items: int = 32, n_choices: int = 4,
    prompt_len: int = 32, cont_len: int = 8, step0: int = 0,
    chunk: int = 128, scorer=None, batch: int = 32,
) -> dict:
    """Multi-choice continuation accuracy: ``{"acc", "margin"}``.

    ``margin`` is the mean (gold − best-distractor) total log-likelihood —
    a sharper quantization-degradation signal than the 0/1 accuracy.
    """
    tokens, gold = build_choice_items(
        batch_fn, n_items=n_items, n_choices=n_choices,
        prompt_len=prompt_len, cont_len=cont_len, step0=step0,
    )
    flat = tokens.reshape(-1, tokens.shape[-1])
    score = scorer if scorer is not None else make_scorer(plan, chunk=chunk)
    lps = []
    for i in range(0, flat.shape[0], batch):
        chunk_toks = flat[i : i + batch]
        padded = chunk_toks
        if padded.shape[0] < batch:  # keep one executable: pad the tail batch
            padded = np.concatenate(
                [padded, np.repeat(padded[-1:], batch - padded.shape[0], 0)]
            )
        lp, _ = score(params, jnp.asarray(padded))
        lps.append(np.asarray(lp)[: chunk_toks.shape[0]])
    lp = np.concatenate(lps, axis=0)  # (n_items*n_choices, L-1)
    # positions [prompt_len-1, prompt_len+cont_len-1) score the continuation
    cont_lp = lp[:, prompt_len - 1 : prompt_len + cont_len - 1].sum(-1)
    cont_lp = cont_lp.reshape(n_items, n_choices)
    pred = cont_lp.argmax(-1)
    acc = float((pred == gold).mean())
    gold_lp = cont_lp[np.arange(n_items), gold]
    masked = cont_lp.copy()
    masked[np.arange(n_items), gold] = -np.inf
    margin = float((gold_lp - masked.max(-1)).mean())
    return {"acc": acc, "margin": margin, "n_items": n_items}
