"""repro.analysis — static-correctness pass for the JAX/Pallas codebase.

``python -m repro.analysis`` runs five rule families over ``src/``:
donation safety, retrace hazards, VMEM gate coverage (static domination +
runtime re-evaluation of the gate byte formulas against every shipped
config shape), dtype flow, and fault-site registry parity.  See
DESIGN.md §Static-analysis for the rule catalog and suppression syntax.
"""

from repro.analysis.framework import (
    Finding,
    RULES,
    load_project,
    render_json,
    render_text,
    run_analysis,
)
from repro.analysis.sanitize import CompilationEvent, CompilationMonitor

__all__ = [
    "Finding",
    "RULES",
    "load_project",
    "render_json",
    "render_text",
    "run_analysis",
    "CompilationEvent",
    "CompilationMonitor",
]
