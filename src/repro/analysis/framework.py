"""Static-analysis framework: rule registry, suppressions, runner, output.

The pass (DESIGN.md §Static-analysis) machine-checks correctness invariants
that previously lived only in review conventions: donated buffers must not
be read after the jitted call that consumed them, jit wrappers must be
bound once (not rebuilt per call), every Pallas kernel must sit behind a
VMEM fit gate, bf16 matmuls must accumulate in fp32, and the fault-site
registry must match the instrumented production call sites exactly.

Rules are AST visitors over a :class:`Project` — the parsed file set plus a
lightweight call-graph index — registered with :func:`rule`.  Each rule
yields :class:`Finding` records; line-scoped suppression comments

    # repro: allow[rule-id] -- rationale

(on the flagged line or the line above; ``allow[*]`` matches every rule)
waive a finding **only with a written rationale** — a bare suppression is
itself reported (``bad-suppression``), so every waiver in the tree carries
its justification next to the code it excuses.

Entry points: ``python -m repro.analysis`` (CLI, exit-nonzero on findings)
and :func:`run_analysis` (tests, CI).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Iterable, Optional

__all__ = [
    "Finding",
    "Suppression",
    "FileCtx",
    "Project",
    "RULES",
    "rule",
    "load_project",
    "run_analysis",
    "render_text",
    "render_json",
]

# `# repro: allow[rule-a,rule-b] -- why this is safe`
_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([\w\-\*,\s]+)\]\s*(?:--\s*(.*))?$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str  # project-relative path
    line: int
    message: str
    suggestion: str = ""  # rendered under --fix-suggestions
    severity: str = "error"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Suppression:
    line: int
    rules: tuple  # rule ids, or ("*",)
    rationale: str

    def matches(self, finding: Finding) -> bool:
        if finding.line not in (self.line, self.line + 1):
            return False
        return "*" in self.rules or finding.rule in self.rules


@dataclasses.dataclass
class FunctionInfo:
    """Call-graph record for one function/method definition."""

    qualname: str  # "path::Class.name"
    name: str
    path: str
    line: int
    node: ast.AST
    calls: set  # simple names (last attribute segment) this body calls


class FileCtx:
    """One parsed source file: AST, suppression table, parent links."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # Parent links let rules walk up from any node (loop/function scope).
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._repro_parent = parent  # type: ignore[attr-defined]
        self.suppressions: list[Suppression] = []
        for i, ln in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(ln)
            if m:
                ids = tuple(s.strip() for s in m.group(1).split(",") if s.strip())
                self.suppressions.append(
                    Suppression(i, ids, (m.group(2) or "").strip())
                )

    def parents(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = getattr(node, "_repro_parent", None)
        while cur is not None:
            yield cur
            cur = getattr(cur, "_repro_parent", None)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        for p in self.parents(node):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return p
        return None


class Project:
    """The full parsed file set plus a simple-name call-graph index."""

    def __init__(self, root: str, files: list, runtime_checks: bool = True):
        self.root = root
        self.files = files
        self.runtime_checks = runtime_checks
        self.functions: list[FunctionInfo] = []
        self._by_name: dict[str, list] = {}
        for ctx in files:
            self._index_file(ctx)

    def _index_file(self, ctx: FileCtx):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            qual = [node.name]
            for p in ctx.parents(node):
                if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                    qual.append(p.name)
            calls = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    calls.add(call_name(sub))
                    # functools.partial(f, ...) / jax.vmap(f) forward to f:
                    # count the wrapped callable as called so gate
                    # domination sees through the indirection.
                    if call_name(sub) in ("partial", "vmap", "jit", "shard_map"):
                        for a in sub.args[:1]:
                            nm = dotted_name(a)
                            if nm:
                                calls.add(nm.split(".")[-1])
            info = FunctionInfo(
                qualname=f"{ctx.rel}::" + ".".join(reversed(qual)),
                name=node.name,
                path=ctx.rel,
                line=node.lineno,
                node=node,
                calls={c for c in calls if c},
            )
            self.functions.append(info)
            self._by_name.setdefault(node.name, []).append(info)

    def callers_of(self, name: str) -> list:
        """Functions whose body calls ``name`` (matched by simple name)."""
        return [f for f in self.functions if name in f.calls]

    def transitive_callers(self, name: str, depth: int = 4) -> list:
        """All functions reaching ``name`` through ≤ ``depth`` call edges."""
        seen: dict[str, FunctionInfo] = {}
        frontier = [name]
        for _ in range(depth):
            nxt = []
            for n in frontier:
                for f in self.callers_of(n):
                    if f.qualname not in seen:
                        seen[f.qualname] = f
                        nxt.append(f.name)
            frontier = nxt
            if not frontier:
                break
        return list(seen.values())


def call_name(call: ast.Call) -> str:
    """Simple name of a call target: ``f(...)`` → "f", ``a.b.f(...)`` → "f"."""
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def dotted_name(node: ast.AST) -> str:
    """Dotted rep of a Name/Attribute chain ("self.cache"), or "" if the
    expression is not a plain chain (calls, subscripts, literals)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def is_jax_jit(call: ast.Call) -> bool:
    """Matches ``jax.jit(...)`` and bare ``jit(...)``."""
    name = dotted_name(call.func)
    return name in ("jax.jit", "jit")


# --------------------------- registry ---------------------------------------

RULES: dict[str, "Rule"] = {}


@dataclasses.dataclass
class Rule:
    name: str
    doc: str
    fn: Callable


def rule(name: str, doc: str):
    def deco(fn):
        RULES[name] = Rule(name=name, doc=doc, fn=fn)
        return fn

    return deco


# --------------------------- runner -----------------------------------------

_SKIP_DIRS = {"__pycache__", ".git"}


def load_project(paths, runtime_checks: bool = True) -> Project:
    """Parse every .py file under ``paths`` (files or directories)."""
    roots = [os.path.abspath(p) for p in paths]
    root = os.path.commonpath(roots) if roots else os.getcwd()
    if os.path.isfile(root):
        root = os.path.dirname(root)
    files = []
    seen = set()
    for p in roots:
        if os.path.isfile(p):
            cand = [p]
        else:
            cand = []
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
                cand.extend(
                    os.path.join(dirpath, f)
                    for f in sorted(filenames)
                    if f.endswith(".py")
                )
        for f in cand:
            if f in seen:
                continue
            seen.add(f)
            with open(f, encoding="utf-8") as fh:
                src = fh.read()
            files.append(FileCtx(f, os.path.relpath(f, root), src))
    return Project(root, files, runtime_checks=runtime_checks)


def run_analysis(
    paths, *, runtime_checks: bool = True, rules: Optional[Iterable[str]] = None
):
    """Run the registered rules; returns ``(findings, suppressed)`` — both
    lists of :class:`Finding`, the second the ones waived by a suppression
    comment (kept for the JSON audit trail)."""
    from repro.analysis import passes  # noqa: F401 — registers the rules

    project = load_project(paths, runtime_checks=runtime_checks)
    raw: list[Finding] = []
    for name, r in sorted(RULES.items()):
        if rules is not None and name not in rules:
            continue
        raw.extend(r.fn(project))

    by_file = {ctx.rel: ctx for ctx in project.files}
    findings, suppressed = [], []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        ctx = by_file.get(f.path)
        sup = None
        if ctx is not None:
            sup = next((s for s in ctx.suppressions if s.matches(f)), None)
        if sup is None:
            findings.append(f)
        elif not sup.rationale:
            suppressed.append(f)
            findings.append(
                Finding(
                    rule="bad-suppression",
                    path=f.path,
                    line=sup.line,
                    message=(
                        f"suppression of [{f.rule}] has no rationale — write "
                        "`# repro: allow[...] -- why this is safe`"
                    ),
                    suggestion="append `-- <reason>` to the suppression comment",
                )
            )
        else:
            suppressed.append(f)
    return findings, suppressed


def render_text(findings, suppressed, *, fix_suggestions: bool = False) -> str:
    out = []
    for f in findings:
        out.append(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
        if fix_suggestions and f.suggestion:
            out.append(f"    fix: {f.suggestion}")
    out.append(
        f"{len(findings)} finding(s), {len(suppressed)} suppressed"
        + (" — see `# repro: allow[...]` comments" if suppressed else "")
    )
    return "\n".join(out)


def render_json(findings, suppressed) -> str:
    return json.dumps(
        {
            "findings": [f.to_json() for f in findings],
            "suppressed": [f.to_json() for f in suppressed],
            "ok": not findings,
        },
        indent=2,
    )
