"""Dtype-flow pass: bf16 accumulation and premature int-code arithmetic.

QuantEase's CD updates tolerate bf16 only in the Σ̃ correction matmuls —
the β/quantize path and every accumulator must stay fp32 (paper §3;
ablation in BENCH_solver.json).  Packed int4/int8 code arrays are storage,
not numbers: arithmetic on them before dequantization silently computes on
code indices.

``dtype-bf16-accum``
    A ``dot``/``matmul``/``einsum`` call with an operand cast to bf16
    (``.astype(jnp.bfloat16)`` or a conventional dtype variable:
    ``matmul_dtype`` / ``corr_dtype`` / ``cdt``) that does not pin fp32
    accumulation via ``preferred_element_type=jnp.float32``.  On MXU
    hardware the default accumulates in bf16 and the CD trajectory drifts.

``dtype-int-code-arith``
    A variable whose name marks it as a packed-code array (``codes``,
    ``*_codes``, ``packed*``, ``q_idx``) appearing as a bare operand of
    ``+ - * / @`` before any dequant call.  Bitwise ops (``& >> << ^ |``)
    are the unpacking idiom and exempt; so are arguments to functions whose
    names contain ``pack`` / ``unpack`` / ``dequant`` / ``quant``.  The
    taint is shallow (name-based, per-expression) by design: deep taint
    over jnp ops produced false positives on every kernel that rounds
    float codes (``_sweep_kernel``) — precision over recall here, the
    runtime tests own recall.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.framework import Finding, Project, call_name, dotted_name, rule

__all__ = ["check_dtype_flow"]

_MATMUL_FNS = {"dot", "matmul", "einsum", "dot_general", "tensordot"}
_BF16_DTYPE_NAMES = {"bfloat16", "matmul_dtype", "corr_dtype", "cdt"}
_CODE_NAME_RE = re.compile(r"(^|_)(codes?|packed\w*|q_idx)$")
_EXEMPT_CALL_RE = re.compile(r"pack|unpack|dequant|quant|bitcast")


def _is_bf16_cast(node: ast.AST) -> bool:
    """``x.astype(jnp.bfloat16)`` / ``x.astype(matmul_dtype)`` etc."""
    if not (isinstance(node, ast.Call) and call_name(node) == "astype"):
        return False
    if not node.args:
        return False
    nm = dotted_name(node.args[0])
    leaf = nm.split(".")[-1] if nm else ""
    return leaf in _BF16_DTYPE_NAMES


def _has_fp32_accum(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "preferred_element_type":
            nm = dotted_name(kw.value)
            return nm.split(".")[-1] in ("float32", "f32")
    return False


@rule(
    "dtype-bf16-accum",
    "bf16-cast matmul without preferred_element_type=float32 — MXU "
    "accumulates in bf16 and the CD/β path drifts",
)
def check_dtype_flow(project: Project):
    findings = []
    for ctx in project.files:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if call_name(node) in _MATMUL_FNS:
                operands = list(node.args)
                if any(_is_bf16_cast(a) for a in operands) and not _has_fp32_accum(
                    node
                ):
                    findings.append(
                        Finding(
                            rule="dtype-bf16-accum",
                            path=ctx.rel,
                            line=node.lineno,
                            message=(
                                "matmul with a bf16-cast operand has no "
                                "preferred_element_type=jnp.float32; the "
                                "accumulator inherits bf16 and quantize/β "
                                "inputs lose ~8 bits of mantissa"
                            ),
                            suggestion=(
                                "add preferred_element_type=jnp.float32 to "
                                "the dot/matmul call"
                            ),
                        )
                    )
            findings.extend(_int_code_arith(ctx, node))
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp):
                findings.extend(_int_code_binop(ctx, node))
    return findings


def _int_code_arith(ctx, call: ast.Call):
    # jnp.sum(codes), jnp.mean(codes) etc. — reductions over raw codes.
    if call_name(call) not in ("sum", "mean", "cumsum", "prod", "var", "std"):
        return
    if _EXEMPT_CALL_RE.search(call_name(call)):
        return
    for a in call.args:
        nm = dotted_name(a)
        leaf = nm.split(".")[-1] if nm else ""
        if leaf and _CODE_NAME_RE.search(leaf):
            yield Finding(
                rule="dtype-int-code-arith",
                path=ctx.rel,
                line=call.lineno,
                message=(
                    f"reduction over packed-code array `{nm}` before "
                    "dequantization — this aggregates code indices, not "
                    "values"
                ),
                suggestion="dequantize first, or operate on the fp tensor",
            )


_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.MatMult)


def _exempt_context(ctx, node) -> bool:
    """Inside a pack/unpack/dequant-named call or function: exempt."""
    for p in ctx.parents(node):
        if isinstance(p, ast.Call):
            if _EXEMPT_CALL_RE.search(call_name(p) or ""):
                return True
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _EXEMPT_CALL_RE.search(p.name):
                return True
            break
    return False


def _float_domain_names(ctx, scope) -> set:
    """Names assigned (in ``scope``) from a float-producing expression —
    ``jnp.round``/``clip`` output or an ``.astype(float*)`` — are fp
    tensors that merely *look* like code arrays (the quantize step's
    pre-cast codes) and are exempt."""
    out = set()
    for sub in ast.walk(scope):
        if not isinstance(sub, ast.Assign):
            continue
        float_src = False
        for c in ast.walk(sub.value):
            if isinstance(c, ast.Call):
                nm = call_name(c)
                if nm in ("round", "clip", "rint", "floor", "ceil"):
                    float_src = True
                if nm == "astype" and c.args:
                    dt = dotted_name(c.args[0]).split(".")[-1]
                    if dt.startswith(("float", "bfloat", "f32", "f16")):
                        float_src = True
        if float_src:
            for tgt in sub.targets:
                nm = dotted_name(tgt)
                if nm:
                    out.add(nm.split(".")[-1])
    return out


def _int_code_binop(ctx, node: ast.BinOp):
    if not isinstance(node.op, _ARITH_OPS):
        return
    scope = ctx.enclosing_function(node) or ctx.tree
    float_names = _float_domain_names(ctx, scope)
    for side in (node.left, node.right):
        # Bare name only: `codes * scale` flags, but
        # `(codes.astype(f32) - zero) * scale` (the dequant idiom) and
        # `codes & 0xF` (unpacking) do not.
        if not isinstance(side, (ast.Name, ast.Attribute)):
            continue
        nm = dotted_name(side)
        leaf = nm.split(".")[-1] if nm else ""
        if not leaf or not _CODE_NAME_RE.search(leaf):
            continue
        if leaf in float_names or _exempt_context(ctx, node):
            continue
        yield Finding(
            rule="dtype-int-code-arith",
            path=ctx.rel,
            line=node.lineno,
            message=(
                f"arithmetic on packed-code array `{nm}` before "
                "dequantization — code indices are storage, not values"
            ),
            suggestion=(
                "unpack/dequantize first (`(codes.astype(f32) - zero) * "
                "scale`), or use bitwise ops for unpacking"
            ),
        )


@rule(
    "dtype-int-code-arith",
    "packed integer-code array used in arithmetic before dequantization",
)
def _r2(project):
    return []
