"""Imports every rule module so the registry is populated.

``framework.run_analysis`` imports this lazily; adding a rule = writing a
module with an ``@rule(...)``-decorated checker and importing it here.
"""

from repro.analysis import donation  # noqa: F401
from repro.analysis import dtypeflow  # noqa: F401
from repro.analysis import faultsites  # noqa: F401
from repro.analysis import retrace  # noqa: F401
from repro.analysis import vmem  # noqa: F401
