"""Tracer sanitizer: count real XLA compilations at runtime.

The static retrace pass (``analysis/retrace.py``) catches the lexical
hazards; this module catches the semantic ones — shape polymorphism, a
weak-typed scalar flipping promotion, a config object that stops hashing —
by counting what actually compiles.  The serving SLO depends on the step
loop reaching a *fixed point*: after warm-up, every decode step must hit
the jit cache, so the number of distinct compiled executables is a small
constant determined by the engine's bucketing, not by trace length.

Mechanism: ``jax_log_compiles`` makes JAX's dispatch layer log one
``"Compiling <name> ..."`` record per backend compilation, with the jitted
function's name and the argument shapes — enough to attribute each compile
to its wrapper.  :class:`CompilationMonitor` attaches a logging handler for
the duration of a ``with`` block and exposes the captured events.

Usage (see tests/test_retrace_count.py):

    with CompilationMonitor() as mon:
        run_trace(engine_a)          # warm-up: helper ops + engine jits
    with CompilationMonitor() as mon:
        run_trace(engine_b)          # fresh identical engine
    assert mon.count() == EXPECTED   # engine-owned executables only

A fresh engine re-jits its own wrappers (new lambda objects ⇒ new cache
keys) while module-level helper ops (``jnp.ones`` etc.) stay cached from
the warm-up — so the second block counts exactly the engine's executable
set.  ``assert_bounded`` wraps the common "run more of the same work, no
new executables" stability assertion.
"""

from __future__ import annotations

import logging
import re
from typing import List, Optional

__all__ = ["CompilationEvent", "CompilationMonitor"]

# Loggers that emit the "Compiling ..." line across recent jax versions.
_COMPILE_LOGGERS = (
    "jax._src.interpreters.pxla",
    "jax._src.dispatch",
    "jax.interpreters.pxla",
)

_COMPILE_RE = re.compile(r"^(?:Compiling|Finished tracing \+ transforming)\s+(\S+)")


class CompilationEvent:
    """One trace or backend compilation: the jitted function's name, the
    event kind, and the raw log line (which carries the argument
    shapes/dtypes for diffing).

    ``kind="trace"`` (one per fresh (wrapper, shape-signature) pair) is
    the stable executable-set metric: JAX dedups *backend* compiles of
    identical HLO modules through an in-memory cache, so ``kind=
    "compile"`` counts depend on what else ran in the process, while
    trace counts are a pure function of the monitored code's jit surface.
    """

    def __init__(self, name: str, detail: str, kind: str = "compile"):
        self.name = name
        self.detail = detail
        self.kind = kind

    def __repr__(self):
        return f"CompilationEvent({self.name!r}, kind={self.kind!r})"


class _Capture(logging.Handler):
    def __init__(self, events):
        super().__init__(level=logging.DEBUG)
        self.events = events

    def emit(self, record):
        msg = record.getMessage()
        m = _COMPILE_RE.match(msg)
        if not m:
            return
        kind = "compile" if msg.startswith("Compiling") else "trace"
        self.events.append(CompilationEvent(m.group(1), msg, kind))


class CompilationMonitor:
    """Context manager that records every XLA compilation inside the block.

    Enables ``jax_log_compiles`` on entry and restores the previous value
    on exit; nesting is safe (each instance restores what it saw)."""

    def __init__(self):
        self.events: List[CompilationEvent] = []
        self._handler = _Capture(self.events)
        self._prev: Optional[bool] = None
        self._levels = {}

    def __enter__(self):
        import jax

        self._prev = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        for name in _COMPILE_LOGGERS:
            lg = logging.getLogger(name)
            self._levels[name] = lg.level
            # The compile line is logged at WARNING when jax_log_compiles
            # is on; keep the logger open at least that far.
            if lg.level > logging.WARNING:
                lg.setLevel(logging.WARNING)
            lg.addHandler(self._handler)
        return self

    def __exit__(self, *exc):
        import jax

        for name in _COMPILE_LOGGERS:
            lg = logging.getLogger(name)
            lg.removeHandler(self._handler)
            lg.setLevel(self._levels[name])
        jax.config.update("jax_log_compiles", False if self._prev is None else self._prev)
        return False

    # ------------------------------------------------------------------
    def count(
        self, name_filter: Optional[str] = None, kind: str = "trace"
    ) -> int:
        """Number of events of ``kind`` ("trace" — the stable
        executable-set metric — or "compile"), optionally only those whose
        jitted-function name contains ``name_filter``."""
        return sum(
            1
            for e in self.events
            if e.kind == kind and (name_filter is None or name_filter in e.name)
        )

    def names(self, kind: str = "trace") -> list:
        return [e.name for e in self.events if e.kind == kind]

    def assert_bounded(
        self, limit: int, name_filter: Optional[str] = None, kind: str = "trace"
    ):
        """Assert at most ``limit`` events of ``kind`` happened; on
        failure the message lists every event line so the offending shape
        is visible in the test output."""
        n = self.count(name_filter, kind=kind)
        if n > limit:
            lines = "\n  ".join(e.detail for e in self.events if e.kind == kind)
            raise AssertionError(
                f"{kind} count {n} exceeds bound {limit}"
                + (f" (filter: {name_filter!r})" if name_filter else "")
                + f"; events:\n  {lines}"
            )
