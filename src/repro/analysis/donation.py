"""Donation-safety pass: no reads of a donated buffer after the call.

``jax.jit(..., donate_argnums=...)`` lets XLA reuse the donated buffer's
memory for the output — the Python reference still exists but points at a
deleted buffer, and touching it raises (or silently aliases, on some
backends).  The paged serving engine leans on this for the KV page pool:
every ``self._decode(...)`` donates ``self.cache`` and the safe idiom is to
immediately reassign the attribute from the result.

Two rules:

``donation-use-after-donate``
    Within one function body, a plain-name/attribute argument passed in a
    donated position is *consumed* at the call statement; any later read of
    the same dotted name in that body is flagged, unless a store to the
    name (e.g. ``self.cache = self._decode(...)``) kills the taint first.
    Statement order is the linear source order — good enough for the
    straight-line step loops this repo writes; branches are walked in
    order, which over-approximates (both arms seen) and never misses a
    straight-line use.

``donation-unbound-result``
    A donating call whose result is discarded (bare ``Expr`` statement):
    the donated buffer is gone and nothing took its place.

The pass resolves donating callables in two steps: ``jax.jit`` calls with
``donate_argnums`` assigned to a name in the same module (including
``self._fn = jax.jit(lambda ...)`` in ``__init__``), then every call to
those names module-wide.  Direct ``jax.jit(f, donate_argnums=...)(args)``
call expressions are handled too.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.framework import (
    Finding,
    Project,
    dotted_name,
    is_jax_jit,
    rule,
)

__all__ = ["check_donation"]


def _donated_positions(call: ast.Call) -> Optional[tuple]:
    """``donate_argnums`` of a jax.jit call as a tuple of ints, else None."""
    if not is_jax_jit(call):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, int):
                        out.append(e.value)
                return tuple(out)
            return ()  # dynamic expression — can't resolve, treat as none
    return None


def _collect_donating_names(ctx) -> dict:
    """Map of local callable name ("self._decode", "step_fn") → donated
    argnum tuple, from ``<name> = jax.jit(..., donate_argnums=...)``."""
    out = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            pos = _donated_positions(node.value)
            if pos:
                for tgt in node.targets:
                    nm = dotted_name(tgt)
                    if nm:
                        out[nm] = pos
    return out


def _reads(node: ast.AST):
    """Dotted names read (Load context) anywhere inside ``node``."""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)) and isinstance(
            getattr(sub, "ctx", None), ast.Load
        ):
            nm = dotted_name(sub)
            if nm:
                yield nm, sub


def _stores(stmt: ast.stmt):
    """Dotted names assigned at the top level of this statement."""
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for tgt in targets:
        if isinstance(tgt, ast.Tuple):
            for e in tgt.elts:
                nm = dotted_name(e)
                if nm:
                    yield nm
        else:
            nm = dotted_name(tgt)
            if nm:
                yield nm


def _donating_calls_in(stmt: ast.stmt, donating: dict):
    """(call node, donated dotted-name args, result-bound?) for each
    donating call inside ``stmt``."""
    for sub in ast.walk(stmt):
        if not isinstance(sub, ast.Call):
            continue
        pos = None
        callee = dotted_name(sub.func)
        if callee in donating:
            pos = donating[callee]
        elif isinstance(sub.func, ast.Call):
            # jax.jit(f, donate_argnums=...)(args)
            pos = _donated_positions(sub.func)
        if not pos:
            continue
        donated = []
        for i in pos:
            if i < len(sub.args):
                nm = dotted_name(sub.args[i])
                if nm:
                    donated.append(nm)
        yield sub, donated


def _flatten(body):
    """Statements of a body in linear source order, descending into
    compound statements (if/for/while/with/try)."""
    for stmt in body:
        yield stmt
        for attr in ("body", "orelse", "finalbody"):
            if hasattr(stmt, attr):
                yield from _flatten(getattr(stmt, attr))
        for h in getattr(stmt, "handlers", []):
            yield from _flatten(h.body)


@rule(
    "donation-use-after-donate",
    "a buffer passed in a donate_argnums position is read after the call "
    "without being reassigned from the result",
)
def check_donation(project: Project):
    findings = []
    for ctx in project.files:
        donating = _collect_donating_names(ctx)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # taint: dotted name → line of the consuming call
            tainted: dict[str, int] = {}
            for stmt in _flatten(fn.body):
                # Reads before this statement's own stores/donations fire.
                consumed_here = set()
                for call, donated in _donating_calls_in(stmt, donating):
                    consumed_here.update(donated)
                for nm, node in _reads(stmt):
                    if nm in tainted and nm not in consumed_here:
                        findings.append(
                            Finding(
                                rule="donation-use-after-donate",
                                path=ctx.rel,
                                line=node.lineno,
                                message=(
                                    f"`{nm}` was donated to a jitted call at "
                                    f"line {tainted[nm]} and read again here; "
                                    "the buffer may already be freed"
                                ),
                                suggestion=(
                                    f"reassign `{nm} = <jitted call>(...)` so the "
                                    "reference tracks the donated-output buffer"
                                ),
                            )
                        )
                        del tainted[nm]  # report once per donation
                bound_names = set(_stores(stmt))
                for call, donated in _donating_calls_in(stmt, donating):
                    is_bare = isinstance(stmt, ast.Expr) and stmt.value is call
                    if is_bare:
                        findings.append(
                            Finding(
                                rule="donation-unbound-result",
                                path=ctx.rel,
                                line=call.lineno,
                                message=(
                                    "result of a donating jitted call is "
                                    "discarded; the donated buffer is gone and "
                                    "nothing replaces it"
                                ),
                                suggestion="bind the result: `x = fn(...)`",
                            )
                        )
                    for nm in donated:
                        # `self.cache = self._decode(..., self.cache, ...)`
                        # re-binds in the same statement: taint never lands.
                        if nm not in bound_names:
                            tainted[nm] = call.lineno
                # Any other store kills taint (fresh buffer bound).
                for nm in bound_names:
                    tainted.pop(nm, None)
    return findings


@rule(
    "donation-unbound-result",
    "a donating jitted call whose result is discarded",
)
def _check_donation_unbound(project: Project):
    # Emitted by check_donation's single walk; registered for --list/--rule
    # selection symmetry.
    return []
