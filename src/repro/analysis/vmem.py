"""VMEM-gate pass: every Pallas kernel behind a fit gate, gates re-checked.

TPU Pallas kernels pull their whole working set into VMEM (~16 MiB/core);
a shape that overflows it fails at compile time in the middle of a serving
run.  The repo's convention is that ``pl.pallas_call`` is never reached
except through a dispatcher that first consults a *fit gate* — a pure
byte-formula function named ``*_tq`` (returns a tile size or None) or
``*_fits_vmem`` (returns bool) in ``kernels/ops.py`` — and falls back to
the XLA reference path otherwise.

Two rules:

``vmem-ungated-pallas-call``
    A ``pl.pallas_call`` whose enclosing function is not *dominated* by a
    gate: neither the function itself nor any transitive caller (≤ 4 call
    edges, simple-name call graph) calls a recognized gate.  Kernel-body
    functions (taken as first argument by ``pallas_call``) inherit their
    dispatcher's gate through the caller walk.

``vmem-gate-overflow`` (runtime check, needs jax importable)
    Each gate's byte formula is re-evaluated against every shipped
    ``configs/*`` architecture shape — all (p, bsz, dtype) combinations the
    solvers can produce, and all (page_size, kv_pages, groups, head_dim)
    the serving engine ships.  The check asserts *consistency*, not fit:
    when a gate approves (returns a tile / True) the formula's bytes must
    be ≤ budget, and when it declines the minimum-tile bytes must exceed
    budget — a gate that approves an overflowing shape, or that can never
    decline, is a bug in the formula.  mixtral-scale d_ff legitimately
    makes ``fused_iteration_tq`` return None; that is a *decision*, not a
    finding.
"""

from __future__ import annotations

import ast

from repro.analysis.framework import Finding, Project, call_name, dotted_name, rule

__all__ = ["check_vmem_gates", "check_gate_formulas"]

# VMEM budget the gates enforce (kernels/ops.py leaves ~4 MiB headroom
# under the ~16 MiB/core VMEM).
_BUDGET = 12 * 1024 * 1024


def _is_gate_name(name: str) -> bool:
    return name.endswith("_tq") or name.endswith("_fits_vmem")


def _pallas_calls(ctx):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            nm = dotted_name(node.func)
            if nm.endswith("pallas_call") or call_name(node) == "pallas_call":
                yield node


@rule(
    "vmem-ungated-pallas-call",
    "pl.pallas_call not dominated by a *_tq / *_fits_vmem fit gate",
)
def check_vmem_gates(project: Project):
    findings = []
    for ctx in project.files:
        if "kernels" not in ctx.rel.split("/"):
            continue
        for node in _pallas_calls(ctx):
            fn = ctx.enclosing_function(node)
            while isinstance(fn, ast.Lambda):
                fn = ctx.enclosing_function(fn)
            if fn is None:
                findings.append(
                    Finding(
                        rule="vmem-ungated-pallas-call",
                        path=ctx.rel,
                        line=node.lineno,
                        message="pl.pallas_call at module level cannot be gated",
                        suggestion="wrap in a dispatcher that checks a fit gate",
                    )
                )
                continue
            if _dominated_by_gate(project, fn.name):
                continue
            findings.append(
                Finding(
                    rule="vmem-ungated-pallas-call",
                    path=ctx.rel,
                    line=node.lineno,
                    message=(
                        f"`{fn.name}` reaches pl.pallas_call but neither it "
                        "nor any caller (≤4 edges) consults a *_tq/"
                        "*_fits_vmem gate; an oversized shape will fail at "
                        "compile time instead of falling back"
                    ),
                    suggestion=(
                        "route the call through a dispatcher in kernels/ops.py "
                        "that checks a fit gate and falls back to the XLA "
                        "reference path"
                    ),
                )
            )
    return findings


def _dominated_by_gate(project: Project, fn_name: str) -> bool:
    """``fn_name`` or any transitive caller calls a recognized gate."""
    infos = [f for f in project.functions if f.name == fn_name]
    for info in infos:
        if any(_is_gate_name(c) for c in info.calls):
            return True
    for caller in project.transitive_callers(fn_name, depth=4):
        if any(_is_gate_name(c) for c in caller.calls):
            return True
    return False


# --------------------------- formula re-evaluation ---------------------------

def _iter_solver_shapes():
    """(p, bsz, dtype) combinations the quantization solvers can produce:
    every weight-matrix row count across shipped archs × the default and
    max block sizes × both matmul dtypes."""
    from repro.configs import base as cfgs

    ps = set()
    for arch in cfgs.list_configs():
        c = cfgs.get_config(arch)
        ps.update(
            x
            for x in (
                c.d_model,
                getattr(c, "d_ff", 0),
                getattr(c, "moe_ff", 0) or 0,
                getattr(c, "d_inner", 0) or 0,
            )
            if x
        )
    # Pallas pads p to a multiple of 8 lanes; gates see the padded value.
    ps = {(-(-p // 8)) * 8 for p in ps}
    for p in sorted(ps):
        for bsz in (128, 256):  # solver/outlier and quantease defaults
            for dtype in ("float32", "bfloat16"):
                yield p, bsz, dtype


def _iter_attention_shapes():
    """(page_size, kv_pages, groups, head_dim, kv_bytes, quantized) combos
    the paged serving engine ships."""
    from repro.configs import base as cfgs

    for arch in cfgs.list_configs():
        c = cfgs.get_config(arch)
        g = max(1, c.n_heads // max(1, c.n_kv_heads))
        for psz in (16, 32):
            for kvp in (16, 64, 256):
                for kv_bytes, quantized in ((2, False), (2, True), (4, False)):
                    yield psz, kvp, g, c.hd, kv_bytes, quantized


def check_gate_formulas() -> list:
    """Re-evaluate every fit gate against all shipped config shapes.

    Returns findings (empty when all gates are self-consistent).  Needs a
    working jax/repro import; the CLI runs it unless --no-runtime.
    """
    from repro.kernels import ops

    findings = []

    def flag(gate, msg):
        findings.append(
            Finding(
                rule="vmem-gate-overflow",
                path="src/repro/kernels/ops.py",
                line=1,
                message=f"{gate}: {msg}",
                suggestion="fix the gate's byte formula in kernels/ops.py",
            )
        )

    def fused_bytes(p_pad, bsz, dtype, tq):
        sig = bsz * p_pad * (2 if dtype == "bfloat16" else 4)
        return p_pad * tq * 4 + sig + 7 * bsz * tq * 4

    def outlier_bytes(p_pad, bsz, dtype, tq):
        cd = 2 if dtype == "bfloat16" else 4
        return 2 * p_pad * tq * 4 + 2 * bsz * p_pad * cd + 8 * bsz * tq * 4

    for p, bsz, dtype in _iter_solver_shapes():
        for gate_name, bytes_fn in (
            ("fused_iteration_tq", fused_bytes),
            ("outlier_iteration_tq", outlier_bytes),
        ):
            gate = getattr(ops, gate_name, None)
            if gate is None:
                flag(gate_name, "gate missing from kernels/ops.py")
                continue
            tq = gate(p, bsz, matmul_dtype=dtype)
            shape = f"p={p} bsz={bsz} dtype={dtype}"
            if tq is not None:
                if bytes_fn(p, bsz, dtype, tq) > _BUDGET:
                    flag(
                        gate_name,
                        f"approved tq={tq} at {shape} but the working set "
                        f"is {bytes_fn(p, bsz, dtype, tq)} B > {_BUDGET} B",
                    )
                if tq < 128 or tq & (tq - 1):
                    flag(gate_name, f"returned non-power-of-two tile {tq} at {shape}")
            else:
                if bytes_fn(p, bsz, dtype, 128) <= _BUDGET:
                    flag(
                        gate_name,
                        f"declined {shape} although the minimum tile (128) "
                        "fits the budget — fallback taken needlessly",
                    )

    sweep_gate = getattr(ops, "block_sweep_tq", None)
    if sweep_gate is None:
        flag("block_sweep_tq", "gate missing from kernels/ops.py")
    else:
        # The sweep tiles q, so evaluate every shipped q (row count) too —
        # and the gate must approve every realistic block size (the sweep
        # working set is tiny; a decline means the formula broke).
        for q, bsz, _ in _iter_solver_shapes():
            tq = sweep_gate(q, bsz)
            # 6 (bsz × tq) fp32 tiles + the (bsz × bsz) Σ̃ block.
            if tq is not None:
                got = 6 * bsz * tq * 4 + bsz * bsz * 4
                if got > _BUDGET:
                    flag(
                        "block_sweep_tq",
                        f"approved tq={tq} at q={q} bsz={bsz} but working "
                        f"set is {got} B > {_BUDGET} B",
                    )
            elif 6 * bsz * 128 * 4 + bsz * bsz * 4 <= _BUDGET:
                flag(
                    "block_sweep_tq",
                    f"declined q={q} bsz={bsz} although the minimum tile fits",
                )

    dm_gate = getattr(ops, "dequant_matmul_fits_vmem", None)
    if dm_gate is None:
        flag("dequant_matmul_fits_vmem", "gate missing from kernels/ops.py")
    else:
        for p, _, _ in _iter_solver_shapes():
            for m in (1, 8, 128, 1024):
                for q in (1024, 4096, 16384):
                    ok = dm_gate(m, q, p)
                    tm, tq, tk = min(128, m), min(128, q), min(512, p)
                    tile = tm * tk * 4 + tq * tk + 2 * tq * tk * 4 + tm * tq * 4
                    if ok and tile > _BUDGET:
                        flag(
                            "dequant_matmul_fits_vmem",
                            f"approved m={m} q={q} p={p} but tile working "
                            f"set is {tile} B > {_BUDGET} B",
                        )
                    if not ok and tile <= _BUDGET:
                        flag(
                            "dequant_matmul_fits_vmem",
                            f"declined m={m} q={q} p={p} although {tile} B fits",
                        )

    pa_gate = getattr(ops, "paged_attention_fits_vmem", None)
    if pa_gate is None:
        flag("paged_attention_fits_vmem", "gate missing from kernels/ops.py")
    else:
        for psz, kvp, g, hd, kv_bytes, quantized in _iter_attention_shapes():
            ok = pa_gate(psz, kvp, g, hd, kv_bytes=kv_bytes, quantized=quantized)
            pages = 2 * 2 * psz * kvp * hd * kv_bytes
            if quantized:
                pages += 2 * 2 * psz * kvp * 4
            fixed = kvp * g * hd * 4 * 3 + kvp * g * 4 * 2
            total = pages + fixed
            if ok and total > _BUDGET:
                flag(
                    "paged_attention_fits_vmem",
                    f"approved page_size={psz} kv_pages={kvp} g={g} hd={hd} "
                    f"kv_bytes={kv_bytes} quantized={quantized} but working "
                    f"set is {total} B > {_BUDGET} B",
                )
            if not ok and total <= _BUDGET:
                flag(
                    "paged_attention_fits_vmem",
                    f"declined page_size={psz} kv_pages={kvp} g={g} hd={hd} "
                    f"although {total} B fits the budget",
                )
    return findings


@rule(
    "vmem-gate-overflow",
    "fit-gate byte formula inconsistent with shipped configs/* shapes "
    "(runtime check; skipped under --no-runtime)",
)
def _check_formulas_rule(project: Project):
    if not project.runtime_checks:
        return []
    # Only meaningful when analyzing this repo (the gates must be importable).
    if not any(c.rel.endswith("kernels/ops.py") for c in project.files):
        return []
    try:
        return check_gate_formulas()
    except ImportError:
        return []
