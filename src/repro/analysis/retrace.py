"""Retrace-hazard pass: jit wrappers that defeat JAX's compilation cache.

``jax.jit`` caches compiled executables on the *wrapper object*; build a
fresh wrapper per call and every call retraces and recompiles.  The paged
step loop runs thousands of decode steps — one stray re-jit turns an
~μs dispatch into a multi-second compile.  Three rules:

``retrace-jit-in-loop``
    A ``jax.jit(...)`` call expression lexically inside a for/while body.
    Each iteration builds a new wrapper with an empty cache.

``retrace-jit-per-call``
    A jitted wrapper built and immediately called / lowered in the same
    expression (``jax.jit(f)(x)``, ``jax.jit(f).lower(...)``) inside a
    function body that is not a recognized factory.  A *factory* caches the
    wrapper for reuse: the jit call is in a ``return`` statement, the
    enclosing function is decorated with ``lru_cache``/``cache``, or the
    wrapper is stored on ``self`` inside ``__init__`` — those are the
    blessed patterns (`core/calib.py`, `eval/scorer.py`, engine
    constructors).

``retrace-nonhashable-static``
    ``static_argnums``/``static_argnames`` combined with a literal list /
    dict / set argument at a call site of the same wrapper in the same
    module — unhashable statics raise; mutable ones that are rebuilt per
    call retrace every time.

The static pass is paired with a runtime check — ``analysis/sanitize.py``
counts real compilations under ``jax_log_compiles`` and the retrace-count
regression test pins the engine's executable count — so anything that
slips through the lexical net still shows up as a count diff.
"""

from __future__ import annotations

import ast

from repro.analysis.framework import (
    Finding,
    Project,
    dotted_name,
    is_jax_jit,
    rule,
)

__all__ = ["check_retrace"]

_FACTORY_DECORATORS = {"lru_cache", "cache", "cached_property"}


def _in_loop(ctx, node) -> bool:
    for p in ctx.parents(node):
        if isinstance(p, (ast.For, ast.While, ast.AsyncFor)):
            return True
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            # A nested def inside a loop body is built per iteration too,
            # but jit-wrapping it is only hazardous if also called there —
            # covered by retrace-jit-per-call. Stop at the function wall.
            return False
    return False


def _enclosing_defs(ctx, node):
    return [
        p
        for p in ctx.parents(node)
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]


def _is_factory(ctx, jit_call: ast.Call) -> bool:
    """True when the jit wrapper is being cached for reuse, not rebuilt."""
    defs = _enclosing_defs(ctx, jit_call)
    if not defs:
        return True  # module level: built once at import
    fn = defs[0]
    for deco in fn.decorator_list:
        name = dotted_name(deco)
        if not name and isinstance(deco, ast.Call):
            name = dotted_name(deco.func)
        if name and name.split(".")[-1] in _FACTORY_DECORATORS:
            return True
    if any(f.name == "__init__" for f in defs):
        return True  # bound once per object construction
    # `return jax.jit(...)` hands the wrapper to the caller, and
    # `self._fn = jax.jit(...)` caches it on the object — but only when the
    # *wrapper itself* escapes.  A Call/Attribute between the jit node and
    # the Return/Assign means the wrapper is consumed in-expression
    # (`return jax.jit(f)(x)`) and only its result escapes.
    for p in ctx.parents(jit_call):
        if isinstance(p, ast.Return):
            return True
        if isinstance(p, ast.Assign):
            return True
        if isinstance(p, (ast.Call, ast.Attribute)):
            break
        if p is fn:
            break
    return False


def _static_argnames(call: ast.Call):
    for kw in call.keywords:
        if kw.arg in ("static_argnums", "static_argnames"):
            return kw
    return None


@rule(
    "retrace-jit-in-loop",
    "jax.jit called inside a loop body — a fresh wrapper (empty compile "
    "cache) per iteration",
)
def check_retrace(project: Project):
    findings = []
    for ctx in project.files:
        jit_names = {}  # name → jit call (for static-arg checks)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                if is_jax_jit(node.value):
                    for tgt in node.targets:
                        nm = dotted_name(tgt)
                        if nm:
                            jit_names[nm] = node.value
            if not (isinstance(node, ast.Call) and is_jax_jit(node)):
                continue
            # Closure capture: jitted lambda reading a loop variable.
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Lambda):
                    findings.extend(_lambda_loop_captures(ctx, node, arg))
            if _in_loop(ctx, node):
                findings.append(
                    Finding(
                        rule="retrace-jit-in-loop",
                        path=ctx.rel,
                        line=node.lineno,
                        message=(
                            "jax.jit inside a loop builds a fresh wrapper "
                            "every iteration; each call retraces and "
                            "recompiles"
                        ),
                        suggestion=(
                            "hoist the jit out of the loop (bind once in "
                            "__init__ or at module level)"
                        ),
                    )
                )
                continue
            parent = getattr(node, "_repro_parent", None)
            immediately_used = (
                isinstance(parent, ast.Call)
                and parent.func is node
            ) or (
                isinstance(parent, ast.Attribute) and parent.value is node
            )
            if immediately_used and not _is_factory(ctx, node):
                findings.append(
                    Finding(
                        rule="retrace-jit-per-call",
                        path=ctx.rel,
                        line=node.lineno,
                        message=(
                            "jit wrapper built and used in the same "
                            "expression inside a per-call path; the compile "
                            "cache is discarded after every call"
                        ),
                        suggestion=(
                            "bind the wrapper once (module level, __init__, "
                            "or an lru_cache'd factory) and call the bound "
                            "name"
                        ),
                    )
                )

        # Unhashable static args at call sites of known jitted names.
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            nm = dotted_name(node.func)
            jit_call = jit_names.get(nm)
            if jit_call is None or _static_argnames(jit_call) is None:
                continue
            statics = _static_positions(jit_call)
            for i, arg in enumerate(node.args):
                if i in statics and isinstance(
                    arg, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp)
                ):
                    findings.append(
                        Finding(
                            rule="retrace-nonhashable-static",
                            path=ctx.rel,
                            line=arg.lineno,
                            message=(
                                f"argument {i} of `{nm}` is static but a "
                                "literal list/dict/set is passed — unhashable "
                                "statics raise, and per-call rebuilds retrace"
                            ),
                            suggestion="pass a tuple / frozen value instead",
                        )
                    )
    return findings


def _static_positions(jit_call: ast.Call) -> set:
    kw = _static_argnames(jit_call)
    out = set()
    if kw is not None and kw.arg == "static_argnums":
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            out.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List)):
            for e in v.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    out.add(e.value)
    return out


def _lambda_loop_captures(ctx, jit_call: ast.Call, lam: ast.Lambda):
    """Flag a jitted lambda closing over the induction variable of an
    enclosing loop — each captured value traces as a fresh constant."""
    loop_vars = set()
    for p in ctx.parents(jit_call):
        if isinstance(p, (ast.For, ast.AsyncFor)):
            for t in ast.walk(p.target):  # handles `for i, x in ...` tuples
                if isinstance(t, ast.Name):
                    loop_vars.add(t.id)
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            break
    if not loop_vars:
        return
    params = {a.arg for a in lam.args.args}
    for sub in ast.walk(lam.body):
        if (
            isinstance(sub, ast.Name)
            and isinstance(sub.ctx, ast.Load)
            and sub.id in loop_vars
            and sub.id not in params
        ):
            yield Finding(
                rule="retrace-closure-capture",
                path=ctx.rel,
                line=sub.lineno,
                message=(
                    f"jitted lambda closes over loop variable `{sub.id}`; "
                    "each iteration bakes a different constant into the "
                    "trace, forcing a recompile"
                ),
                suggestion=(
                    f"pass `{sub.id}` as a (possibly static) argument "
                    "instead of capturing it"
                ),
            )


@rule(
    "retrace-jit-per-call",
    "jit wrapper built and invoked in the same expression on a per-call path",
)
def _r2(project):
    return []


@rule(
    "retrace-closure-capture",
    "jitted lambda capturing an enclosing loop variable",
)
def _r3(project):
    return []


@rule(
    "retrace-nonhashable-static",
    "literal list/dict/set passed in a static_argnums position",
)
def _r4(project):
    return []
