"""CLI: ``python -m repro.analysis [paths...]``.

Exit status: 0 when no findings, 1 when any rule fires, 2 on usage error.
Defaults to analyzing the ``src/repro`` tree this module was imported
from, so CI can run it with no arguments from the repo root.
"""

from __future__ import annotations

import argparse
import os
import sys

import repro
from repro.analysis.framework import (
    RULES,
    render_json,
    render_text,
    run_analysis,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas static-correctness pass (see DESIGN.md)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/directories to analyze (default: the installed src/repro tree)",
    )
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument(
        "--fix-suggestions",
        action="store_true",
        help="print a suggested fix under each finding (text format)",
    )
    ap.add_argument(
        "--no-runtime",
        action="store_true",
        help="skip runtime checks (VMEM gate formula re-evaluation needs "
        "jax + repro importable)",
    )
    ap.add_argument(
        "--rule",
        action="append",
        help="run only this rule id (repeatable)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        from repro.analysis import passes  # noqa: F401

        for name, r in sorted(RULES.items()):
            print(f"{name:32s} {r.doc}")
        return 0

    paths = args.paths or [os.path.dirname(os.path.abspath(repro.__file__))]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    findings, suppressed = run_analysis(
        paths,
        runtime_checks=not args.no_runtime,
        rules=set(args.rule) if args.rule else None,
    )
    if args.format == "json":
        print(render_json(findings, suppressed))
    else:
        print(render_text(findings, suppressed, fix_suggestions=args.fix_suggestions))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
