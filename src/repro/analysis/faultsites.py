"""Fault-site parity pass: faults/ registry ↔ production instrumentation.

The chaos harness (``faults/plan.py``) names its injection sites in a
``SITES`` tuple; production code arms each with a ``fault_point("<site>")``
call.  Drift in either direction is silent breakage: a registered site with
no call is a chaos test that can never fire (coverage theater), and a call
with an unregistered name is a hook no plan can target (and, after the
``from_spec`` hardening, a name its JSON validation would reject).

``fault-site-unwired``
    A name in ``SITES`` with no ``fault_point(...)`` call anywhere in
    production code (``faults/`` itself and ``analysis/`` excluded).

``fault-site-unregistered``
    A ``fault_point("<name>")`` call whose literal name is not in
    ``SITES``.  Non-literal arguments are flagged too — the registry
    can't vouch for a dynamic name.

The registry is read from the AST of ``faults/plan.py`` (no import
needed), so the pass works on fixture trees as well as the real repo.
"""

from __future__ import annotations

import ast

from repro.analysis.framework import Finding, Project, call_name, rule

__all__ = ["check_fault_sites"]

_HOOK_NAMES = ("fault_point", "fault_site")


def _registry_sites(project: Project):
    """(sites, path, line) parsed from SITES = (...) in faults/plan.py."""
    for ctx in project.files:
        if not ctx.rel.replace("\\", "/").endswith("faults/plan.py"):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Assign):
                continue
            if not any(
                isinstance(t, ast.Name) and t.id == "SITES" for t in node.targets
            ):
                continue
            if isinstance(node.value, (ast.Tuple, ast.List)):
                sites = [
                    e.value
                    for e in node.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                ]
                return sites, ctx.rel, node.lineno
    return None, None, None


@rule(
    "fault-site-parity",
    "faults/ SITES registry and production fault_point(...) calls must "
    "match exactly in both directions",
)
def check_fault_sites(project: Project):
    sites, reg_path, reg_line = _registry_sites(project)
    if sites is None:
        return []  # tree has no fault registry — nothing to check

    findings = []
    called = {}  # site name → first (path, line)
    for ctx in project.files:
        parts = ctx.rel.replace("\\", "/").split("/")
        if "faults" in parts or "analysis" in parts:
            continue  # the registry and this checker aren't production arms
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and call_name(node) in _HOOK_NAMES):
                continue
            if not node.args:
                continue
            arg = node.args[0]
            if not (isinstance(arg, ast.Constant) and isinstance(arg.value, str)):
                findings.append(
                    Finding(
                        rule="fault-site-unregistered",
                        path=ctx.rel,
                        line=node.lineno,
                        message=(
                            "fault_point called with a non-literal site name; "
                            "the registry cannot vouch for it"
                        ),
                        suggestion="pass a string literal from faults.SITES",
                    )
                )
                continue
            name = arg.value
            called.setdefault(name, (ctx.rel, node.lineno))
            if name not in sites:
                findings.append(
                    Finding(
                        rule="fault-site-unregistered",
                        path=ctx.rel,
                        line=node.lineno,
                        message=(
                            f"fault_point site `{name}` is not in faults/"
                            f"plan.py SITES — no fault plan can target it; "
                            f"valid: {', '.join(sites)}"
                        ),
                        suggestion=f"add `{name}` to SITES or fix the name",
                    )
                )
    for name in sites:
        if name not in called:
            findings.append(
                Finding(
                    rule="fault-site-unwired",
                    path=reg_path,
                    line=reg_line,
                    message=(
                        f"registered fault site `{name}` has no "
                        "fault_point call in production code — chaos plans "
                        "targeting it silently never fire"
                    ),
                    suggestion=(
                        f"instrument the owning subsystem with "
                        f'`fault_point("{name}")` or drop it from SITES'
                    ),
                )
            )
    return findings


@rule(
    "fault-site-unregistered",
    "fault_point call whose site name is absent from the SITES registry",
)
def _r2(project):
    return []


@rule(
    "fault-site-unwired",
    "SITES entry with no production fault_point call",
)
def _r3(project):
    return []
