"""Distribution layer: logical-axis sharding rules, checkpoints, elasticity.

Submodules:

* :mod:`repro.dist.sharding` — logical axis names → mesh ``PartitionSpec``
  rules engine with divisibility fallbacks; the ambient ``axis_rules``
  context that makes ``logical_constraint`` calls in model code resolve.
* :mod:`repro.dist.checkpoint` — atomic step-directory checkpoints
  (``step_N.tmp`` → rename), dtype-exact round-trips including bf16,
  per-leaf CRC-32 content checksums (corrupted shards raise
  ``CheckpointCorrupt``), and ``load_last_good`` degradation to the
  newest step that verifies.
* :mod:`repro.dist.elastic` — ``RetryingRunner`` restart-from-checkpoint
  loop (jittered exponential backoff, total retry budget,
  permanent-error classification — ``repro.faults.PermanentFault`` is
  never retried) and degraded-capacity mesh rebuilding.
* :mod:`repro.dist.qgather` — int8-quantized FSDP gather transform
  (§Perf H3; kept out of default configs, see launch/specs.py).
"""
