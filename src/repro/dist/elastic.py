"""Elastic training: retry-from-checkpoint loop + degraded-capacity meshes.

``RetryingRunner`` is deliberately dumb: any exception inside a step rolls
the loop back to the last checkpoint via ``restore_fn`` and keeps going, up
to ``max_retries`` total recoveries.  Determinism comes from the caller's
exact-step data replay (``data_step`` in the checkpoint meta), not from
anything here — see trainer tests for the contract.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax

__all__ = ["RetryingRunner", "elastic_mesh"]


class RetryingRunner:
    """Run ``step_fn(state, step)`` for a span of steps with crash recovery.

    ``restore_fn() -> (state, step)`` must rebuild state from the latest
    checkpoint and report the step to resume at.  ``fault_hook(step)`` is a
    test seam: it runs before each step and may raise to simulate a failure.
    """

    def __init__(
        self,
        step_fn: Callable,
        restore_fn: Callable,
        fault_hook: Optional[Callable] = None,
        max_retries: int = 3,
    ):
        self.step_fn = step_fn
        self.restore_fn = restore_fn
        self.fault_hook = fault_hook
        self.max_retries = max_retries
        self.recoveries = 0

    def run(self, state, start: int, n_steps: int):
        step, end = start, start + n_steps
        while step < end:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state = self.step_fn(state, step)
                step += 1
            except Exception:
                if self.recoveries >= self.max_retries:
                    raise
                self.recoveries += 1
                state, step = self.restore_fn()
        return state, step


def elastic_mesh(model_axis: int = 1, devices=None):
    """Largest ("data", "model") mesh the *currently alive* devices support.

    On a restart after losing hosts, the surviving device count may no
    longer fill the original mesh; this trims the data axis to the largest
    multiple of ``model_axis`` that fits (dropping remainder devices) so
    training resumes at degraded capacity instead of wedging.
    """
    devs = list(devices if devices is not None else jax.devices())
    if model_axis <= 0 or len(devs) < model_axis:
        raise ValueError(
            f"{len(devs)} device(s) cannot host model_axis={model_axis}"
        )
    data = len(devs) // model_axis
    keep = devs[: data * model_axis]
    return jax.make_mesh((data, model_axis), ("data", "model"), devices=keep)
