"""Elastic execution: retry-from-checkpoint loop + degraded-capacity meshes.

``RetryingRunner`` rolls any *recoverable* exception inside a step back to
the last checkpoint via ``restore_fn`` and keeps going, up to a total
retry budget, sleeping a jittered exponential backoff between recoveries
(thundering-herd hygiene for multi-host restarts; the jitter stream is
seeded so tests replay the exact delays).  Exceptions classified as
**permanent** — :class:`repro.faults.PermanentFault` always, plus any
caller-supplied types — are re-raised immediately: retrying an
unrecoverable error only burns the budget that a later transient will
need.  Determinism comes from the caller's exact-step data replay
(``data_step`` in the checkpoint meta), not from anything here — see
trainer tests for the contract.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.faults import PermanentFault

__all__ = ["RetryingRunner", "elastic_mesh"]


class RetryingRunner:
    """Run ``step_fn(state, step)`` for a span of steps with crash recovery.

    ``restore_fn() -> (state, step)`` must rebuild state from the latest
    checkpoint and report the step to resume at.  ``fault_hook(step)`` is a
    test seam: it runs before each step and may raise to simulate a failure.

    Retry policy: up to ``max_retries`` total recoveries across the run
    (a *budget*, not per-step), with delay
    ``min(backoff_max_s, backoff_base_s · backoff_mult^k)`` before the
    k-th recovery, multiplied by a seeded uniform jitter in
    ``[1−jitter, 1+jitter]``.  ``sleep_fn`` is injectable (tests pass a
    recorder); ``self.delays`` keeps the slept values for audit.
    ``permanent`` lists extra exception types that must never be retried.
    """

    def __init__(
        self,
        step_fn: Callable,
        restore_fn: Callable,
        fault_hook: Optional[Callable] = None,
        max_retries: int = 3,
        *,
        backoff_base_s: float = 0.01,
        backoff_mult: float = 2.0,
        backoff_max_s: float = 2.0,
        jitter: float = 0.5,
        permanent: tuple = (),
        sleep_fn: Callable[[float], None] = time.sleep,
        seed: int = 0,
    ):
        self.step_fn = step_fn
        self.restore_fn = restore_fn
        self.fault_hook = fault_hook
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_mult = backoff_mult
        self.backoff_max_s = backoff_max_s
        self.jitter = jitter
        self.permanent = tuple(permanent) + (PermanentFault,)
        self.sleep_fn = sleep_fn
        self.recoveries = 0
        self.delays: list[float] = []
        self._rng = np.random.default_rng(seed)

    def _backoff(self) -> float:
        delay = min(
            self.backoff_max_s,
            self.backoff_base_s * self.backoff_mult ** self.recoveries,
        )
        if self.jitter:
            delay *= 1.0 + self.jitter * (2.0 * float(self._rng.random()) - 1.0)
        return delay

    def run(self, state, start: int, n_steps: int):
        step, end = start, start + n_steps
        while step < end:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state = self.step_fn(state, step)
                step += 1
            except self.permanent:
                raise
            except Exception:
                if self.recoveries >= self.max_retries:
                    raise
                delay = self._backoff()
                self.delays.append(delay)
                self.sleep_fn(delay)
                self.recoveries += 1
                state, step = self.restore_fn()
        return state, step


def elastic_mesh(model_axis: int = 1, devices=None):
    """Largest ("data", "model") mesh the *currently alive* devices support.

    On a restart after losing hosts, the surviving device count may no
    longer fill the original mesh; this trims the data axis to the largest
    multiple of ``model_axis`` that fits (dropping remainder devices) so
    training resumes at degraded capacity instead of wedging.
    """
    devs = list(devices if devices is not None else jax.devices())
    if model_axis <= 0 or len(devs) < model_axis:
        raise ValueError(
            f"{len(devs)} device(s) cannot host model_axis={model_axis}"
        )
    data = len(devs) // model_axis
    keep = devs[: data * model_axis]
    return jax.make_mesh((data, model_axis), ("data", "model"), devices=keep)
