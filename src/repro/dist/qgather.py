"""Int8-quantized FSDP gather (§Perf H3) — kept out of default configs.

Under FSDP the scan body must all-gather each period's weights before use.
Gathering bf16 costs 2 bytes/param of interconnect; quantizing shards to
int8 (per-row scale) before the gather and dequantizing after halves that.
XLA's convert-pair elimination defeats the narrow dtype when expressed as
plain ``convert → all-gather → convert`` (see launch/specs.py note), so the
transform pins the gathered layout with explicit sharding constraints on
the int8 codes + fp32 scales.

``make_period_transform`` returns a function applied to one period's param
tree inside the scan body (ModelPlan.param_transform), mapping
FSDP-sharded leaves (``rules`` layout) to replicated leaves (``rep_rules``
layout).  Non-float and small (<2-D) leaves gather unquantized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import Rules

__all__ = ["make_period_transform"]

_QUANT_DTYPES = (jnp.bfloat16, jnp.float32, jnp.float16)


def _gather_int8(x: jax.Array, sharded, replicated) -> jax.Array:
    """Quantize per leading-row, gather codes+scales, dequantize."""
    x32 = x.astype(jnp.float32)
    red = tuple(range(1, x.ndim))
    scale = jnp.max(jnp.abs(x32), axis=red, keepdims=True) / 127.0 + 1e-12
    codes = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    codes = jax.lax.with_sharding_constraint(codes, sharded)
    codes = jax.lax.with_sharding_constraint(codes, replicated)
    scale = jax.lax.with_sharding_constraint(scale, replicated)
    return (codes.astype(jnp.float32) * scale).astype(x.dtype)


def make_period_transform(period_axes, rules: Rules, rep_rules: Rules):
    """Build the per-period transform: FSDP layout → replicated layout.

    ``period_axes``: logical-axes tree matching one period's params (the
    stacked "layers" axis already stripped by the caller).
    """
    flat_ax = jax.tree.flatten(
        period_axes, is_leaf=lambda x: isinstance(x, tuple)
    )[0]

    def transform(p_period):
        flat_p, tdef = jax.tree.flatten(p_period)
        out = []
        for leaf, ax in zip(flat_p, flat_ax):
            ax = tuple(ax)
            rep = rep_rules.sharding(ax)
            if leaf.ndim >= 2 and leaf.dtype in _QUANT_DTYPES:
                out.append(_gather_int8(leaf, rules.sharding(ax), rep))
            else:
                out.append(jax.lax.with_sharding_constraint(leaf, rep))
        return jax.tree.unflatten(tdef, out)

    return transform
