"""Atomic step-directory checkpoints with dtype-exact, checksummed round-trips.

Layout: ``<dir>/step_<N>/`` holding one raw-bytes blob per pytree leaf (in
flatten order) plus ``manifest.json`` (step, user meta, per-leaf shape,
dtype, and CRC-32 content checksum).  Writes go to ``step_<N>.tmp`` and are
renamed into place only after the manifest lands, so a crashed half-write
can never be mistaken for a checkpoint — :func:`cleanup_tmp` sweeps
orphaned ``.tmp`` dirs at restart.

Corruption detection: every leaf's CRC-32 is computed over the bytes the
writer *intended* (before any injected corruption) and verified on read —
a flipped bit anywhere in a shard raises :class:`CheckpointCorrupt` instead
of silently restoring garbage weights.  :func:`load_last_good` walks the
step directories newest-first, skipping corrupt/unreadable steps, so a
damaged latest checkpoint degrades to the last good one rather than
wedging a resume (manifests written before checksums existed load
unverified — there is nothing to verify against).

Leaves are stored as raw buffers (``tobytes``), not ``np.save``: numpy can't
round-trip ml_dtypes extension dtypes (bf16) through ``.npy`` without
pickling, while ``np.frombuffer(..., np.dtype("bfloat16"))`` is exact.

Fault injection (DESIGN.md §Resilience): each shard write consults
``fault_point("ckpt.write")`` (``corrupt`` → one seeded byte of the
on-disk shard is flipped; transient/permanent raise) and each shard read
consults ``fault_point("ckpt.read")``.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401 — registers bfloat16 & friends with np.dtype
import numpy as np

from repro.faults import active_plan, corrupt_bytes, fault_point

__all__ = [
    "CheckpointCorrupt",
    "save_checkpoint",
    "load_checkpoint",
    "load_last_good",
    "latest_step",
    "list_steps",
    "cleanup_tmp",
]

_MANIFEST = "manifest.json"


class CheckpointCorrupt(Exception):
    """A shard's bytes do not match its manifest checksum (or the step is
    otherwise unreadable in a way that indicates damage, not absence)."""


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}")


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None):
    """Write ``tree`` as ``step_<step>`` atomically (tmp dir + rename)."""
    leaves, _ = jax.tree.flatten(tree)
    final = _step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    records = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        data = np.ascontiguousarray(arr).tobytes()
        # Checksum the intended bytes BEFORE any injected corruption: the
        # read side must be able to prove what landed on disk is wrong.
        crc = zlib.crc32(data)
        if fault_point("ckpt.write") == "corrupt":
            data = corrupt_bytes(active_plan(), data)
        with open(os.path.join(tmp, f"leaf_{i}.bin"), "wb") as f:
            f.write(data)
        records.append({"shape": list(arr.shape), "dtype": str(arr.dtype), "crc32": crc})
    manifest = {"step": step, "meta": meta or {}, "leaves": records}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    # Re-saving an existing step (elastic retry rewrites the recovery step):
    # move the old dir aside first so there is never a moment where neither
    # a valid old nor new step_<N> exists; the .old copy dies only after the
    # replace lands (and cleanup_tmp sweeps any crash leftovers).
    old = final + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(final):
        os.replace(final, old)
    os.replace(tmp, final)
    shutil.rmtree(old, ignore_errors=True)


def load_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None):
    """Restore the pytree saved at ``step`` (default: latest).

    ``like`` supplies the tree structure; leaf dtypes/shapes come from the
    manifest (and are checked against ``like`` where it carries them).
    Shard bytes are verified against the manifest CRC-32 when present;
    mismatches raise :class:`CheckpointCorrupt`.  Returns
    ``(tree, manifest)``.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_like, tdef = jax.tree.flatten(like)
    recs = manifest["leaves"]
    if len(recs) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(recs)} leaves, template has {len(flat_like)}"
        )
    out = []
    for i, rec in enumerate(recs):
        like_leaf = flat_like[i]
        if hasattr(like_leaf, "shape") and tuple(like_leaf.shape) != tuple(rec["shape"]):
            raise ValueError(
                f"leaf {i}: checkpoint shape {rec['shape']} != template "
                f"shape {tuple(like_leaf.shape)}"
            )
        if hasattr(like_leaf, "dtype") and str(np.dtype(like_leaf.dtype)) != rec["dtype"]:
            raise ValueError(
                f"leaf {i}: checkpoint dtype {rec['dtype']} != template "
                f"dtype {np.dtype(like_leaf.dtype)}"
            )
        fault_point("ckpt.read")
        with open(os.path.join(d, f"leaf_{i}.bin"), "rb") as f:
            raw = f.read()
        if "crc32" in rec and zlib.crc32(raw) != rec["crc32"]:
            raise CheckpointCorrupt(
                f"{d}/leaf_{i}.bin: content checksum mismatch "
                f"(crc32 {zlib.crc32(raw)} != manifest {rec['crc32']}) — "
                "shard corrupted on disk"
            )
        arr = np.frombuffer(raw, dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(tdef, out), manifest


def load_last_good(ckpt_dir: str, like: Any):
    """Restore the newest checkpoint that verifies, skipping damaged steps.

    Walks steps newest-first; corrupt or unreadable steps (checksum
    mismatch, missing shard, undecodable manifest, template mismatch) are
    recorded and skipped.  Returns ``(tree, manifest, skipped)`` where
    ``skipped`` is ``[(step, reason), ...]``.  Raises
    :class:`FileNotFoundError` when no step exists at all, and
    :class:`CheckpointCorrupt` when steps exist but none verifies.
    """
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    skipped: list[tuple] = []
    for step in reversed(steps):
        try:
            tree, manifest = load_checkpoint(ckpt_dir, like, step=step)
            return tree, manifest, skipped
        except (CheckpointCorrupt, ValueError, OSError, json.JSONDecodeError) as e:
            skipped.append((step, f"{type(e).__name__}: {e}"))
    raise CheckpointCorrupt(
        f"{ckpt_dir}: no loadable checkpoint — all {len(steps)} step(s) "
        f"damaged: {[s for s, _ in skipped]}"
    )


def list_steps(ckpt_dir: str) -> list:
    """All complete checkpoint steps under ``ckpt_dir``, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith((".tmp", ".old")):
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                try:
                    steps.append(int(name[len("step_"):]))
                except ValueError:
                    continue
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Highest complete checkpoint step under ``ckpt_dir`` (None if none)."""
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def cleanup_tmp(ckpt_dir: str):
    """Remove orphaned ``step_*.tmp``/``step_*.old`` dirs from crashed writers.

    A ``step_N.old`` whose ``step_N`` is missing means the crash hit between
    the two renames in :func:`save_checkpoint` — restore it instead of
    deleting (the .tmp replacement is unproven; the .old was a committed
    checkpoint)."""
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        path = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and name.endswith(".tmp"):
            shutil.rmtree(path, ignore_errors=True)
        elif name.startswith("step_") and name.endswith(".old"):
            final = path[: -len(".old")]
            if not os.path.exists(final):
                os.replace(path, final)
            else:
                shutil.rmtree(path, ignore_errors=True)
