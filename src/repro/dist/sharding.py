"""Logical-axis sharding rules (t5x-style), with divisibility fallbacks.

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "ffn", ...; see models/model.py ``param_axes``).  This
module owns the single mapping from those names to mesh axes:

* :func:`make_rules` builds a :class:`Rules` table for one mesh, checking
  divisibility of every dimension it knows the size of and falling back to
  replication (or to an alternative axis — e.g. ``head_dim`` when
  ``kv_heads`` doesn't divide the model axis) when a dim doesn't fit.
* :class:`Rules` resolves logical-axes tuples to ``PartitionSpec`` /
  ``NamedSharding``.  A mesh axis may appear at most once per spec (GSPMD
  rule); duplicate uses degrade to ``None`` — this is what lets a leaf like
  ``("embed", "ffn", "ffn")`` stay lowerable instead of erroring.
* :func:`axis_rules` installs a Rules as the ambient context;
  :func:`logical_constraint` is the model-side entry point: identity when no
  rules are active (CPU tests), ``with_sharding_constraint`` otherwise.

Nothing here touches jax device state at import time.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

__all__ = [
    "Rules",
    "make_rules",
    "axis_rules",
    "current_rules",
    "logical_constraint",
    "mesh_axis_size",
]

# A table value: one mesh axis, a tuple of mesh axes (e.g. batch over
# ("pod", "data")), or None (replicated).
_Entry = Union[str, tuple, None]


def mesh_axis_size(mesh, axes) -> int:
    """Product of the sizes of the named mesh axes (missing axes count 1)."""
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


@dataclasses.dataclass(frozen=True)
class Rules:
    """Resolved logical-axis → mesh-axis table for one mesh."""

    mesh: jax.sharding.Mesh
    table: dict

    def spec(self, axes: tuple) -> PartitionSpec:
        """Resolve a logical-axes tuple to a PartitionSpec.

        Each mesh axis is used at most once; later logical axes that map to
        an already-used mesh axis resolve to None (replicated on that dim).
        """
        used: set = set()
        out = []
        for name in axes:
            entry: _Entry = self.table.get(name) if name is not None else None
            if entry is None:
                out.append(None)
                continue
            members = (entry,) if isinstance(entry, str) else tuple(entry)
            free = tuple(m for m in members if m not in used)
            used.update(free)
            if not free:
                out.append(None)
            elif len(free) == 1:
                out.append(free[0])
            else:
                out.append(free)
        return PartitionSpec(*out)

    def sharding(self, axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))


def make_rules(
    mesh,
    *,
    n_heads: int = 0,
    n_kv_heads: int = 0,
    head_dim: int = 0,
    d_ff: int = 0,
    n_experts: int = 0,
    vocab: int = 0,
    d_model: int = 0,
    moe_ff: int = 0,
    ssm_heads: int = 0,
    fsdp: bool = False,
    seq_sharded_cache: bool = False,
    extra: Optional[dict] = None,
) -> Rules:
    """Build the rules table for ``mesh``.

    Sizes are the *global* (padded) dimension carried under each logical
    name; 0 means "unknown" and maps to replicated.  ``extra`` entries
    (e.g. the serve path's fused-dim names from qparams.qt_rules_extra)
    override/extend the base table verbatim.
    """
    model_n = mesh.shape.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_n = mesh.shape.get("data", 1)

    def fits(n: int) -> bool:
        return n > 0 and n % model_n == 0

    kv_on_model = fits(n_kv_heads)
    experts_on_model = fits(n_experts)
    table: dict = {
        "batch": data_axes or None,
        "layers": None,
        # Attention: kv_pad is padded to a model-axis multiple by HeadPlan,
        # so "heads" (and the fused h_pad passed as n_heads) always fits.
        "heads": "model" if fits(n_heads) else None,
        "kv_heads": "model" if kv_on_model else None,
        # Fallback: when true kv heads don't divide (GQA with few kv heads),
        # shard the head_dim instead so wk/wv aren't replicated.
        "head_dim": "model" if (not kv_on_model and fits(head_dim)) else None,
        "ffn": "model" if fits(d_ff) else None,
        "experts": "model" if experts_on_model else None,
        # EP when experts divide (OLMoE 64, Jamba 16), else TP on the
        # per-expert ffn axis (Mixtral 8 on a 16-wide model axis).
        "expert_ffn": None
        if experts_on_model
        else ("model" if (moe_ff == 0 or fits(moe_ff)) else None),
        "vocab": "model" if fits(vocab) else None,
        "ssm_heads": "model" if fits(ssm_heads) else None,
        # FSDP: parameters sharded over the data axis on their embed dim.
        "embed": ("data" if (fsdp and d_model and d_model % data_n == 0) else None),
        # Sequence parallelism for the pre-stack activation region.
        "seq_sp": "model",
        "cache_seq": "model" if seq_sharded_cache else None,
    }
    if extra:
        table.update(extra)
    return Rules(mesh=mesh, table=table)


# ---------------------------------------------------------------------------
# Ambient rules context
# ---------------------------------------------------------------------------

_state = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Rules]):
    """Install ``rules`` as the ambient table (None → constraints no-op)."""
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def logical_constraint(x: jax.Array, axes: tuple) -> jax.Array:
    """``with_sharding_constraint`` under the ambient rules; identity when
    no rules are installed (single-device tests and examples)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes))
