"""Shared model components: norms, RoPE, blockwise attention, linears.

TPU-adaptation conventions (DESIGN.md §3/§4):

* **Grouped head layout.**  Attention heads are carried as
  ``(kv_heads_padded, q_per_kv, head_dim)`` so that sharding the leading
  kv-slot axis over the "model" mesh axis keeps *all* attention math local.
  ``HeadPlan`` computes the padding: KV heads are *duplicated* (GQA, exact)
  and/or q-head slots zero-padded (MHA / ragged groups) up to divisibility
  by the model-axis size.  With no mesh (CPU tests) every pad degenerates
  to the true architecture.
* **Blockwise (flash) attention.**  Scores never materialize at (S, S);
  a kv-chunk scan carries running (max, sum, acc).  Sliding windows and
  softcaps are applied inside the chunk mask.
* **Quantized linears.**  Any weight leaf may be a
  :class:`repro.quant.QuantizedTensor` (serve path); `apply_linear`
  dispatches to the fused dequant-matmul.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.quant import QuantizedTensor

__all__ = [
    "HeadPlan",
    "make_head_plan",
    "rmsnorm",
    "layernorm",
    "apply_norm",
    "rope",
    "softcap",
    "apply_linear",
    "HoistedDequant",
    "hoist_dequant",
    "flash_attention",
    "decode_attention",
    "activation",
]


# --------------------------------------------------------------------------
# Head padding plan
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeadPlan:
    """Padded grouped-head layout for one (config, mesh-axis) pair.

    true q heads H, true kv heads KV  →  layout (kv_pad, g_pad, head_dim):
      * ``dup``: each true kv head duplicated ``dup`` times (exact for GQA),
      * ``kv_pad = KV * dup`` (multiple of the model-axis size),
      * ``g_pad = ceil(H / (KV*dup))``; q slots beyond H are structural pads.
    """

    n_heads: int
    n_kv: int
    head_dim: int
    axis_n: int
    dup: int
    kv_pad: int
    g_pad: int

    @property
    def h_pad(self) -> int:
        return self.kv_pad * self.g_pad


def make_head_plan(n_heads: int, n_kv: int, head_dim: int, axis_n: int = 1) -> HeadPlan:
    if axis_n <= 1 or n_kv == 0:
        g = max(n_heads // max(n_kv, 1), 1)
        return HeadPlan(n_heads, n_kv, head_dim, 1, 1, max(n_kv, 1), g)
    if n_kv == n_heads:
        # MHA: zero-pad kv slots to the axis multiple (padded q slots have
        # zero wq/wo ⇒ exact).  Duplication would pay lcm(kv,16)/kv ×; e.g.
        # qwen's 40 heads would balloon to 80 slots instead of 48.
        kv_pad = -(-n_kv // axis_n) * axis_n
        return HeadPlan(n_heads, n_kv, head_dim, axis_n, 1, kv_pad, 1)
    dup = math.lcm(n_kv, axis_n) // n_kv
    kv_pad = n_kv * dup
    g_pad = -(-n_heads // kv_pad)
    return HeadPlan(n_heads, n_kv, head_dim, axis_n, dup, kv_pad, g_pad)


# --------------------------------------------------------------------------
# Norms / activations / positional
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, ..., head_dim); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None]  # (1, S) broadcasting over batch
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]  # broadcast over head dims
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(
        x.dtype
    )


# --------------------------------------------------------------------------
# Linears (dense or quantized) + PTQ calibration capture
# --------------------------------------------------------------------------

import contextlib
import threading

_capture_state = threading.local()


@contextlib.contextmanager
def capture_scope(name: str):
    """Inside a capture context, tags subsequent apply_linear calls."""
    prev = getattr(_capture_state, "scope", None)
    _capture_state.scope = name
    try:
        yield
    finally:
        _capture_state.scope = prev


@contextlib.contextmanager
def capture_linear_inputs(records: dict):
    """Collect {scope/name: [x2d, ...]} for every linear applied within —
    RAW activations, O(n·p) memory per layer.  Kept as the numerical oracle
    for the streaming path (tests); the whole-model solver uses
    :func:`capture_gram_stats` instead and never materializes these lists.
    Eager-only; never active under jit."""
    prev = getattr(_capture_state, "records", None)
    _capture_state.records = records
    try:
        yield records
    finally:
        _capture_state.records = prev


@contextlib.contextmanager
def capture_gram_stats(stats: dict, mesh=None):
    """Accumulate {scope/name: CalibStats} streaming for every linear applied
    within: each call folds its activations into the layer's Σ = XXᵀ on the
    spot (``p²`` fp32 per linear, DESIGN.md §Streaming-solver) — raw
    activations are never retained.  Under a mesh, row contraction happens
    shard-locally with a psum (calib.sharded_gram).  Eager-only."""
    prev = getattr(_capture_state, "stats", None)
    prev_mesh = getattr(_capture_state, "stats_mesh", None)
    _capture_state.stats = stats
    _capture_state.stats_mesh = mesh
    try:
        yield stats
    finally:
        _capture_state.stats = prev
        _capture_state.stats_mesh = prev_mesh


def _record_linear(name, x, expert_stacked: bool = False):
    if name is None:
        return
    records = getattr(_capture_state, "records", None)
    stats = getattr(_capture_state, "stats", None)
    if records is None and stats is None:
        return
    scope = getattr(_capture_state, "scope", None)
    key = f"{scope}/{name}" if scope else name
    if records is not None:
        records.setdefault(key, []).append(
            x if expert_stacked else x.reshape(-1, x.shape[-1])
        )
    if stats is not None:
        from repro.core.calib import CalibStats

        p = x.shape[-1]
        if key not in stats:
            stats[key] = CalibStats.zeros(p, experts=x.shape[0] if expert_stacked else 0)
        if expert_stacked:
            stats[key] = stats[key].update_expert_tokens(x)
        else:
            stats[key] = stats[key].update_tokens(
                x, mesh=getattr(_capture_state, "stats_mesh", None)
            )


def apply_linear(w, x: jax.Array, out_shape: tuple = (), name: str = None) -> jax.Array:
    """y = x @ W, where W is (d_in, *out_dims) dense or a QuantizedTensor
    with codes (prod(out_dims), d_in).  x: (..., d_in)."""
    _record_linear(name, x)
    if isinstance(w, QuantizedTensor):
        from repro.kernels import ops as kops

        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y2 = kops.dequant_matmul(
            x2, w.codes, w.scale, w.zero, packed4=w.packed and w.bits == 4,
            out_dtype=x.dtype, interpret=None, group_size=w.group_size,
            pack_layout=w.pack_layout, pack_tile=w.pack_tile,
        )
        if w.outlier_values is not None:
            # Rank-s unstructured COO correction (fp16 values, flat int32
            # indices): y += x[:, cols] ⋅ vals → rows, after the dequant-GEMM.
            p_in = w.shape[1]
            rows = w.outlier_idx // p_in
            cols = w.outlier_idx % p_in
            contrib = x2[:, cols].astype(jnp.float32) * w.outlier_values.astype(
                jnp.float32
            )
            y2 = (
                y2.astype(jnp.float32)
                .at[:, rows]
                .add(contrib)
                .astype(x.dtype)
            )
        if w.outlier_col_idx is not None:
            y2 = (
                y2.astype(jnp.float32)
                + x2[:, w.outlier_col_idx].astype(jnp.float32)
                @ w.outlier_col_vals.T
            ).astype(x.dtype)
        out = out_shape or (w.shape[0],)
        return y2.reshape(*lead, *out)
    if isinstance(w, HoistedDequant):
        # Pre-dequantized QT (see HoistedDequant): same contraction shape,
        # same fp32 weight bytes, same post-GEMM outlier adds as the
        # QuantizedTensor reference path — bitwise-equal results.
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y2 = (x2.astype(jnp.float32) @ w.w.T).astype(x.dtype)
        if w.outlier_values is not None:
            p_in = w.shape[1]
            rows = w.outlier_idx // p_in
            cols = w.outlier_idx % p_in
            contrib = x2[:, cols].astype(jnp.float32) * w.outlier_values.astype(
                jnp.float32
            )
            y2 = y2.astype(jnp.float32).at[:, rows].add(contrib).astype(x.dtype)
        if w.outlier_col_idx is not None:
            y2 = (
                y2.astype(jnp.float32)
                + x2[:, w.outlier_col_idx].astype(jnp.float32)
                @ w.outlier_col_vals.T
            ).astype(x.dtype)
        out = out_shape or (w.shape[0],)
        return y2.reshape(*lead, *out)
    d_in = x.shape[-1]
    w2 = w.reshape(d_in, -1)
    y = jnp.einsum("...d,df->...f", x, w2)
    if out_shape:
        y = y.reshape(*y.shape[:-1], *out_shape)
    elif w.ndim > 2 and w.shape[0] == d_in:
        # (d_in, *out_dims) weights unfold naturally; weights whose *input*
        # spans several leading dims (e.g. mamba out_proj (nh, hd, d)) keep
        # the flat output.
        y = y.reshape(*y.shape[:-1], *w.shape[1:])
    return y


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HoistedDequant:
    """A QuantizedTensor whose dequantization has been hoisted out of the
    consuming computation: ``w`` holds byte-for-byte the fp32 matrix the
    XLA reference GEMM (kernels/ref.dequant_matmul_ref) would rebuild on
    every call — ``(codes - zero) * scale`` over the unpacked codes —
    alongside the original outlier planes, which stay *post-GEMM*
    corrections exactly as in the QuantizedTensor path.

    Purpose (DESIGN.md §Speculative-serving): inside a multi-position
    ``lax.scan`` (speculative verify / draft rollout) XLA re-dequantizes
    loop-invariant quantized weights at every scan position, which on the
    CPU reference path makes a γ+1-position verify cost γ+1 dequants.
    Hoisting pays the dequant once per *call* instead of once per
    *position*; because the per-position dot then consumes bit-identical
    weight bytes through the same ``x_f32 @ w.T → out_dtype`` contraction
    and the same post-GEMM outlier adds, results stay bitwise equal to
    the un-hoisted path — the token-identity invariant survives.  Only
    meaningful where dequant_matmul would take the XLA reference anyway
    (off-TPU); the Pallas kernel already fuses dequant in-kernel.

    Leaves may carry a leading period-stack axis like every other ``dec``
    leaf — slicing through jax.tree.map yields per-period views."""

    w: jax.Array  # (..., q, p) fp32 — exact reference dequant bytes
    outlier_values: Optional[jax.Array] = None  # (..., s) fp16
    outlier_idx: Optional[jax.Array] = None  # (..., s) int32, row·p + col
    outlier_col_idx: Optional[jax.Array] = None  # (..., c) int32
    outlier_col_vals: Optional[jax.Array] = None  # (..., q, c) fp32

    @property
    def shape(self):
        return self.w.shape


def hoist_dequant(tree):
    """Map a params tree, replacing every QuantizedTensor leaf with a
    :class:`HoistedDequant` holding the reference-path dequantized fp32
    matrix (packed codes are unpacked with the same helper the GEMM
    dispatch uses, so tile-prepacked layouts are transparent).  Dense
    leaves pass through untouched.  Roughly ``32 / bits`` × the quantized
    footprint in extra memory — a serve-time scratch copy the speculative
    engine holds only when hoisting is enabled."""
    from repro.kernels.ops import _unpacked

    def _one(leaf):
        if not isinstance(leaf, QuantizedTensor):
            return leaf
        codes = _unpacked(
            leaf.codes, leaf.packed and leaf.bits == 4,
            leaf.pack_layout, leaf.pack_tile,
        )
        p = codes.shape[-1]
        scale, zero = leaf.scale, leaf.zero
        if scale.ndim == codes.ndim - 1:  # per-channel grid stored flat
            scale, zero = scale[..., None], zero[..., None]
        n_groups = scale.shape[-1]
        gsz = leaf.group_size or -(-p // n_groups)
        idx = jnp.arange(p) // gsz
        w = (codes.astype(jnp.float32) - jnp.take(zero, idx, axis=-1)) * jnp.take(
            scale, idx, axis=-1
        )
        return HoistedDequant(
            w=w,
            outlier_values=leaf.outlier_values,
            outlier_idx=leaf.outlier_idx,
            outlier_col_idx=leaf.outlier_col_idx,
            outlier_col_vals=leaf.outlier_col_vals,
        )

    return jax.tree.map(
        _one, tree, is_leaf=lambda l: isinstance(l, QuantizedTensor)
    )


# --------------------------------------------------------------------------
# Blockwise (flash) attention — pure XLA, TPU-fusable
# --------------------------------------------------------------------------


def _chunk_mask(
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,)
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention(
    q: jax.Array,  # (B, Sq, KVp, G, hd)
    k: jax.Array,  # (B, Sk, KVp, hd)
    v: jax.Array,  # (B, Sk, KVp, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax blockwise attention in grouped-head layout.

    Returns (B, Sq, KVp, G, hd).  ``q_offset`` shifts query positions
    (used when queries are a suffix of the kv sequence).
    For *local* (windowed) layers only the kv chunks intersecting the window
    of each q chunk are visited (static slice — the sub-quadratic path that
    makes gemma2/mixtral long-context layers affordable).
    """
    B, Sq, KVp, G, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = -(-Sq // q_chunk)
    n_kv = -(-Sk // kv_chunk)
    pad_q = n_q * q_chunk - Sq
    pad_kv = n_kv * kv_chunk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    # Windowed layers: only kv chunks within [q_start − window, q_end] matter.
    if window is not None and causal:
        kv_band = min(n_kv, (window + q_chunk) // kv_chunk + 2)
    else:
        kv_band = n_kv

    q = q.reshape(B, n_q, q_chunk, KVp, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        qb = (q_blk * scale).astype(q.dtype)

        # First kv chunk index to visit (static band for windowed layers).
        if kv_band == n_kv:
            kv_start = 0
        else:
            # q chunk [qi*qc, qi*qc+qc); window reaches back `window` tokens.
            kv_start = jnp.maximum(
                0, (q_offset + qi * q_chunk - (window or 0)) // kv_chunk
            )
            kv_start = jnp.minimum(kv_start, n_kv - kv_band)

        def kv_step(carry, j):
            m_run, l_run, acc = carry
            kj = kv_start + j
            k_blk = jax.lax.dynamic_slice(
                k, (0, kj * kv_chunk, 0, 0), (B, kv_chunk, KVp, hd)
            )
            v_blk = jax.lax.dynamic_slice(
                v, (0, kj * kv_chunk, 0, 0), (B, kv_chunk, KVp, hd)
            )
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqkgd,btkd->bkgqt", qb, k_blk, preferred_element_type=jnp.float32
            )
            s = softcap(s, attn_softcap)
            mask = _chunk_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < Sk)[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_run = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_run, acc), None

        init = (
            jnp.full((B, KVp, G, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, KVp, G, q_chunk), jnp.float32),
            jnp.zeros((B, KVp, G, q_chunk, hd), jnp.float32),
        )
        (m_run, l_run, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(kv_band))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (B, q_chunk, KVp, G, hd)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(n_q), q))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_q * q_chunk, KVp, G, hd)
    return out[:, :Sq].astype(k.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, KVp, G, hd)
    k_cache: jax.Array,  # (B, S, KVp, hd) bf16 or int8
    v_cache: jax.Array,  # (B, S, KVp, hd)
    cache_len: jax.Array,  # (B,) or scalar — valid prefix length
    *,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,  # (B, S, KVp, 1) fp32 (int8 cache)
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffered) KV cache.

    int8 caches: per-(token, head) scales fold algebraically —
    q·(s·k₈) = s·(q·k₈) and Σ p·(s·v₈) = Σ (p·s)·v₈ — so the bf16 cache is
    never materialized; HBM reads stay 1 byte/element (§Perf H1).
    """
    B, S, KVp, hd = k_cache.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bokgd,btkd->bkgot", (q * scale).astype(q.dtype),
        k_cache.astype(q.dtype),
        preferred_element_type=jnp.float32,
    )
    if k_scale is not None:
        # (B,S,KVp,1) → (B,KVp,1,1,S) broadcast over (B,KVp,G,o,S)
        s = s * k_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
    s = softcap(s, attn_softcap)
    pos = jnp.arange(S)[None, :]  # (1, S)
    clen = jnp.asarray(cache_len).reshape(-1, 1)  # (B or 1, 1)
    valid = pos < clen
    if window is not None:
        valid &= pos >= (clen - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum(
        "bkgot,btkd->bokgd", p.astype(q.dtype), v_cache.astype(q.dtype),
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)
