"""Mixture-of-Experts layer: top-k routing with sort-based static dispatch.

Dropless-ish token-choice MoE that lowers to static shapes (GSPMD-friendly):

  1. router (fp32) → top-k expert ids + weights per token,
  2. the N·k routed copies are assigned slots in a (E, C) table
     (C = capacity = ceil(N·k/E · capacity_factor); overflow drops, the
     standard Switch/GShard behavior),
  3. gather → (E, C, D), grouped GEMMs over stacked expert weights
     (E, D, F) — *one* einsum per projection, MXU-dense,
  4. weighted scatter-add back to (N, D).

Sharding: expert-stacked weights shard on the expert axis over "model" when
E is divisible (EP: OLMoE 64, Jamba 16), else on the per-expert ffn axis
(TP: Mixtral 8) — resolved by the rules engine.  Slots shard over "data"
with the tokens.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.models.common import _record_linear, activation

__all__ = ["moe_apply", "router_aux_loss"]


def _expert_matmul(w, xs: jax.Array, name: str) -> jax.Array:
    """xs: (E, C, d_in) × stacked expert weights → (E, C, d_out).

    ``w`` is dense (E, d_in, d_out) or a QuantizedTensor with codes
    (E, d_out, d_in) (per-expert grids stacked on the leading axis).
    """
    _record_linear(name, xs, expert_stacked=True)  # (E, C, d_in): per-expert Σ
    if hasattr(w, "codes"):
        from repro.kernels.ref import dequant_matmul_ref

        return jax.vmap(
            lambda x_e, c_e, s_e, z_e: dequant_matmul_ref(
                x_e, c_e, s_e, z_e, out_dtype=xs.dtype
            )
        )(xs, w.unpacked_codes(), w.scale, w.zero)
    if hasattr(w, "w"):  # HoistedDequant: per-expert pre-dequantized (E, d_out, d_in)
        return jax.vmap(
            lambda x_e, w_e: (x_e.astype(jnp.float32) @ w_e.T).astype(xs.dtype)
        )(xs, w.w)
    return jnp.einsum("ecd,edf->ecf", xs, w)


def _dispatch_table(expert_ids: jax.Array, n_experts: int, capacity: int):
    """expert_ids: (R,) routed-copy expert assignment → (token-slot table
    (E*C,) int32 with -1 empty, per-copy slot position or -1 if dropped)."""
    r = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)  # stable: groups copies by expert
    sorted_e = expert_ids[order]
    # Position of each routed copy within its expert group.
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_in_e = jnp.arange(r) - starts[sorted_e]
    keep = pos_in_e < capacity
    slot_sorted = jnp.where(keep, sorted_e * capacity + pos_in_e, n_experts * capacity)
    # Invert the sort to get each copy's slot.
    slot = jnp.zeros((r,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    # slot → copy index (overflow bucket at the end, trimmed after scatter).
    copy_for_slot = (
        jnp.full((n_experts * capacity + 1,), -1, jnp.int32)
        .at[slot]
        .set(jnp.arange(r, dtype=jnp.int32))[:-1]
    )
    return copy_for_slot, slot


def moe_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    n_experts: int,
    top_k: int,
    act: str,
    gated: bool,
    norm_topk: bool,
    capacity_factor: float = 1.25,
    return_aux: bool = False,
    dispatch_groups: int = 1,
):
    """Returns (y, router_probs or None).

    ``dispatch_groups`` (§Perf H2): dispatch/combine are computed within
    ``dispatch_groups`` independent token groups aligned with the
    data-parallel sharding.  With groups == data-axis size the gather and
    scatter-add never cross data shards — GSPMD otherwise all-gathers the
    whole (N, D) token array per MoE layer (measured: 131 GB/device/layer
    on mixtral prefill_32k).  Capacity is per (group, expert); the drop
    criterion becomes group-local, which is exactly what per-host routing
    does on real fleets.
    """
    B, S, D = x.shape
    n = B * S
    g = dispatch_groups if n % dispatch_groups == 0 else 1
    ng = n // g  # tokens per group
    xf = x.reshape(n, D)
    logits = jnp.einsum(
        "nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)  # (n, k)
    if norm_topk:
        top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9, None)

    capacity = max(int(ng * top_k / n_experts * capacity_factor), 8)
    copy_for_slot, _ = jax.vmap(
        lambda e: _dispatch_table(e, n_experts, capacity)
    )(top_e.reshape(g, ng * top_k))  # (g, E·C)

    token_for_slot = jnp.where(copy_for_slot >= 0, copy_for_slot // top_k, 0)
    w_for_slot = jnp.where(
        copy_for_slot >= 0,
        jnp.take_along_axis(
            top_w.reshape(g, -1), jnp.clip(copy_for_slot, 0), axis=1
        ),
        0.0,
    )  # (g, E·C)

    # Constraints pin the dispatch group axis to the data shards at every
    # hop; without them GSPMD replicates the gather/scatter (and their
    # transposes in backward) and all-reduces full (N, D) fp32 tensors over
    # the entire mesh — measured at ~7 TB/device/step on jamba train_4k.
    xg = xf.reshape(g, ng, D)
    xg = logical_constraint(xg, ("batch", None, None))
    xs = jnp.take_along_axis(xg, token_for_slot[..., None], axis=1)
    xs = logical_constraint(xs, ("batch", None, None))
    xs = xs.reshape(g, n_experts, capacity, D).transpose(1, 0, 2, 3)
    xs = xs.reshape(n_experts, g * capacity, D)
    xs = logical_constraint(xs, ("experts", "batch", None))
    h = _expert_matmul(p["w_gate"], xs, "w_gate")
    h = activation(h, act)
    if gated:
        h = h * _expert_matmul(p["w_up"], xs, "w_up")
    h = logical_constraint(h, ("experts", "batch", "expert_ffn"))
    ys = _expert_matmul(p["w_down"], h, "w_down")  # (E, g·C, D)
    ys = ys.reshape(n_experts, g, capacity, D).transpose(1, 0, 2, 3)
    ys = ys.reshape(g, n_experts * capacity, D)
    ys = logical_constraint(ys, ("batch", None, None))
    ys = ys * w_for_slot[..., None].astype(ys.dtype)

    yg = jnp.zeros((g, ng, D), jnp.float32)
    yg = yg.at[jnp.arange(g)[:, None], token_for_slot].add(
        jnp.where((copy_for_slot >= 0)[..., None], ys.astype(jnp.float32), 0.0)
    )
    yg = logical_constraint(yg, ("batch", None, None))
    y = yg.reshape(B, S, D).astype(x.dtype)
    return (y, probs if return_aux else None)


def router_aux_loss(probs: jax.Array, top_e: Optional[jax.Array] = None) -> jax.Array:
    """Switch-style load-balancing loss: E · Σ_e f_e · P_e."""
    n, e = probs.shape
    pe = probs.mean(0)
    fe = (probs == probs.max(-1, keepdims=True)).astype(jnp.float32).mean(0)
    return e * jnp.sum(fe * pe)
