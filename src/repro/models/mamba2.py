"""Mamba-2 SSD (state-space duality) block — chunked, MXU-friendly.

TPU adaptation (DESIGN.md §3): we implement the *SSD chunked* formulation
(arXiv:2405.21060 §6) rather than Mamba-1's sequential selective scan — the
chunked form is a handful of batched matmuls (intra-chunk "attention-like"
term + inter-chunk state recurrence over L/Q steps) which map onto the MXU,
with only an O(L/Q)-step `lax.scan` of (B, nh, hd, N) states.

Layout: SSD heads shard over the "model" mesh axis (nh % 16 == 0 for all
assigned archs); B/C group projections are replicated (G=1).  The depthwise
conv is split into an x-part (head-sharded) and a BC-part (replicated) so
its channels never straddle shards.

Decode is the O(1) recurrence: S ← exp(dtA)·S + dt·(B ⊗ x), y = C·S + D·x,
with a rolling (conv_w−1)-deep conv state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.models.common import apply_linear, rmsnorm

__all__ = ["MambaCache", "mamba_params_shape", "mamba_apply", "mamba_decode"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MambaCache:
    conv_x: jax.Array  # (B, convw-1, nh, hd)
    conv_bc: jax.Array  # (B, convw-1, 2*G*N)
    ssm: jax.Array  # (B, nh, hd, N) fp32


def _dw_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv along axis 1.  x: (B, L, *ch), w: (*ch, K)."""
    k = w.shape[-1]
    x = jnp.pad(x, [(0, 0), (k - 1, 0)] + [(0, 0)] * (x.ndim - 2))
    out = sum(
        x[:, i : i + x.shape[1] - k + 1] * w[..., i] for i in range(k)
    )
    return out + b


def _ssd_chunked(
    x: jax.Array,  # (B, L, nh, hd)
    dt: jax.Array,  # (B, L, nh) — post-softplus
    a: jax.Array,  # (nh,) negative
    b: jax.Array,  # (B, L, G, N)
    c: jax.Array,  # (B, L, G, N)
    *,
    chunk: int = 128,
    h0: Optional[jax.Array] = None,  # (B, nh, hd, N) initial state
):
    """Returns (y: (B, L, nh, hd), final state (B, nh, hd, N))."""
    B, L, nh, hd = x.shape
    G, N = b.shape[2], b.shape[3]
    hpg = nh // G  # heads per group
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (L + pad) // Q

    xc = x.reshape(B, nc, Q, nh, hd)
    dtc = dt.reshape(B, nc, Q, nh).astype(jnp.float32)
    bc = b.reshape(B, nc, Q, G, N)
    cc = c.reshape(B, nc, Q, G, N)

    da = dtc * a.astype(jnp.float32)[None, None, None, :]  # (B,nc,Q,nh) ≤ 0
    da_cs = jnp.cumsum(da, axis=2)  # inclusive cumsum
    da_tot = da_cs[:, :, -1]  # (B,nc,nh)

    # ---- intra-chunk (quadratic in Q, attention-like) ----
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cc, bc, preferred_element_type=jnp.float32)
    # decay L[h, i, j] = exp(da_cs[i] − da_cs[j]) for i ≥ j
    seg = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]  # (B,nc,Q,Q,nh) i,j
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    # Mask INSIDE the exp: exp(seg) overflows for i<j (positive seg) and a
    # where() around an inf poisons the backward pass (0·inf = NaN).
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))  # (B,nc,Q,Q,nh)
    scores = (
        cb.reshape(B, nc, G, 1, Q, Q)
        .repeat(hpg, axis=3)
        .reshape(B, nc, nh, Q, Q)
        .transpose(0, 1, 3, 4, 2)
        * decay
        * dtc[:, :, None, :, :]  # dt_j on the source index
    )  # (B,nc,Q,Q,nh)
    y_intra = jnp.einsum(
        "bcijh,bcjhd->bcihd", scores, xc.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    # ---- chunk summary states ----
    # S_c = Σ_j exp(da_tot − da_cs[j]) dt_j B_j ⊗ x_j   (B,nc,nh,hd,N)
    w_state = jnp.exp(da_tot[:, :, None, :] - da_cs) * dtc  # (B,nc,Q,nh)
    if G == 1:
        bx = jnp.einsum(
            "bcqgn,bcqhd,bcqh->bchdn",
            bc,
            xc.astype(jnp.float32),
            w_state,
            preferred_element_type=jnp.float32,
        )
    else:
        bx = jnp.einsum(
            "bcqgn,bcqghd,bcqgh->bcghdn",
            bc,
            xc.astype(jnp.float32).reshape(B, nc, Q, G, hpg, hd),
            w_state.reshape(B, nc, Q, G, hpg),
            preferred_element_type=jnp.float32,
        ).reshape(B, nc, nh, hd, N)

    # ---- inter-chunk recurrence over nc steps ----
    def step(h, inputs):
        bx_c, da_tot_c = inputs  # (B,nh,hd,N), (B,nh)
        h_new = h * jnp.exp(da_tot_c)[:, :, None, None] + bx_c
        return h_new, h  # emit state *before* the chunk

    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    h_final, h_before = jax.lax.scan(
        step,
        h0,
        (bx.transpose(1, 0, 2, 3, 4), da_tot.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # (B,nc,nh,hd,N)

    # ---- inter-chunk contribution: y_i += exp(da_cs[i]) C_i · H_before ----
    cfac = jnp.exp(da_cs)  # (B,nc,Q,nh)
    if G == 1:
        y_inter = jnp.einsum(
            "bcqgn,bchdn,bcqh->bcqhd", cc, h_before, cfac,
            preferred_element_type=jnp.float32,
        )
    else:
        y_inter = jnp.einsum(
            "bcqgn,bcghdn,bcqgh->bcqghd",
            cc,
            h_before.reshape(B, nc, G, hpg, hd, N),
            cfac.reshape(B, nc, Q, G, hpg),
            preferred_element_type=jnp.float32,
        ).reshape(B, nc, Q, nh, hd)

    y = (y_intra + y_inter).reshape(B, nc * Q, nh, hd)
    return y[:, :L], h_final


def mamba_apply(
    p: dict,
    x: jax.Array,  # (B, L, D) — post-norm input
    cfg,
    *,
    chunk: int = 128,
    cache: Optional[MambaCache] = None,
    return_cache: bool = False,
):
    """Full-sequence SSD block (train / prefill)."""
    B, L, D = x.shape
    nh, hd = cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state

    z = apply_linear(p["wz"], x, out_shape=(nh, hd), name="wz")  # gate
    xin_pre = apply_linear(p["wx"], x, out_shape=(nh, hd), name="wx")  # pre-conv
    bc_pre = apply_linear(p["wbc"], x, name="wbc")  # (B,L,2GN)
    dt_raw = apply_linear(p["wdt"], x, name="wdt")  # (B,L,nh)

    xin_pre = logical_constraint(xin_pre, ("batch", None, "ssm_heads", None))
    xin = jax.nn.silu(_dw_conv(xin_pre, p["conv_x_w"], p["conv_x_b"]))
    bcv = jax.nn.silu(_dw_conv(bc_pre, p["conv_bc_w"], p["conv_bc_b"]))
    b, c = jnp.split(bcv.reshape(B, L, 2 * G, N), 2, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, h_final = _ssd_chunked(xin, dt, a, b, c, chunk=chunk)
    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = (y.astype(x.dtype) * jax.nn.silu(z)).reshape(B, L, nh * hd)
    y = rmsnorm(y, p["norm_scale"].reshape(-1))
    out = apply_linear(p["out_proj"], y, name="out_proj")
    if not return_cache:
        return out, None
    k = cfg.ssm_conv
    new_cache = MambaCache(
        conv_x=_last_k(xin_pre, k - 1),
        conv_bc=_last_k(bc_pre, k - 1),
        ssm=h_final,
    )
    return out, new_cache


def _last_k(x: jax.Array, k: int) -> jax.Array:
    return x[:, x.shape[1] - k :]


def mamba_decode(p: dict, x: jax.Array, cfg, cache: MambaCache):
    """One-token recurrent step.  x: (B, 1, D)."""
    B, _, D = x.shape
    nh, hd = cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    xt = x[:, 0]

    z = apply_linear(p["wz"], xt, out_shape=(nh, hd), name="wz")
    xin_new = apply_linear(p["wx"], xt, out_shape=(nh, hd), name="wx")  # pre-conv
    bc_new = apply_linear(p["wbc"], xt, name="wbc")
    dt_raw = apply_linear(p["wdt"], xt, name="wdt")

    # Depthwise conv via rolling buffers (width k: k−1 past + current).
    k = cfg.ssm_conv
    conv_x_hist = jnp.concatenate([cache.conv_x, xin_new[:, None]], axis=1)
    conv_bc_hist = jnp.concatenate([cache.conv_bc, bc_new[:, None]], axis=1)
    xin = jax.nn.silu(
        jnp.einsum("bthd,hdt->bhd", conv_x_hist, p["conv_x_w"]) + p["conv_x_b"]
    )
    bc = jax.nn.silu(
        jnp.einsum("btn,nt->bn", conv_bc_hist, p["conv_bc_w"]) + p["conv_bc_b"]
    )
    b, c = jnp.split(bc.reshape(B, 2 * G, N), 2, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None, :])  # (B, nh)

    xin32 = xin.astype(jnp.float32)
    bh = b.reshape(B, G, N).repeat(nh // G, axis=1)  # (B, nh, N)
    ch = c.reshape(B, G, N).repeat(nh // G, axis=1)
    ssm = cache.ssm * da[:, :, None, None] + (
        dt[:, :, None, None] * xin32[:, :, :, None] * bh[:, :, None, :]
    )
    y = jnp.einsum("bhdn,bhn->bhd", ssm, ch)
    y = y + xin32 * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = (y.astype(x.dtype) * jax.nn.silu(z)).reshape(B, nh * hd)
    y = rmsnorm(y, p["norm_scale"].reshape(-1))
    out = apply_linear(p["out_proj"], y, name="out_proj")[:, None]
    new_cache = MambaCache(
        conv_x=conv_x_hist[:, 1:], conv_bc=conv_bc_hist[:, 1:], ssm=ssm
    )
    return out, new_cache
