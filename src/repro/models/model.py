"""Model assembly: params, train loss, prefill and decode for all families.

One generic stack covers the 10 assigned architectures (configs/base.py):
``lax.scan`` over *periods* of blocks (attn / mamba × dense / MoE / none),
optional encoder stack (whisper), optional embedding prefix stub (llava).

Param tree (all leaves bf16 unless noted):

  embed        (vocab_pad, d)
  pos_emb      (max_seq, d)            [pos == "learned"]
  enc_pos_emb  (n_frames, d)           [encdec]
  lm_head      (d, vocab_pad)          [unless tied]
  final_norm   {scale[, bias]}
  dec / enc    per-period stacks: {"b0": {...}, "b1": {...}, ...}
               every leaf has leading dim n_periods (scan axis)

Each leaf carries *logical axes* (see dist/sharding.py) via the parallel
tree from :func:`param_axes`; the dry-run and trainer map these to mesh
PartitionSpecs.  Quantized serving swaps linear leaves for
:class:`QuantizedTensor`s (serve/quantize_model.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDef, ModelConfig
from repro.dist.sharding import logical_constraint
from repro.models import mamba2
from repro.models.common import (
    HeadPlan,
    HoistedDequant,
    activation,
    apply_linear,
    apply_norm,
    decode_attention,
    flash_attention,
    make_head_plan,
    rope,
    softcap,
)
from repro.models.mamba2 import MambaCache
from repro.models.moe import moe_apply, router_aux_loss

__all__ = [
    "ModelPlan",
    "make_plan",
    "param_shapes",
    "param_axes",
    "init_params",
    "init_cache",
    "cache_axes",
    "train_loss",
    "prefill",
    "decode_step",
    "paged_cache_shapes",
    "init_paged_cache",
    "paged_prefill_chunk",
    "paged_decode_step",
    "paged_verify_tokens",
    "paged_draft_tokens",
]


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """Static lowering plan: config + mesh-derived paddings."""

    cfg: ModelConfig
    axis_n: int  # model-axis size (1 on CPU)
    heads: HeadPlan
    vocab_pad: int
    # "bf16" | "int8" | "int4" (§Perf H1 lever).  int4 is paged-engine only:
    # pages store two codes/byte (quant/pack.kv_pack_int4 fold-in-half) and
    # the contiguous cache path rejects it.
    kv_cache_dtype: str = "bf16"
    dispatch_groups: int = 1  # MoE data-local dispatch groups (§Perf H2)
    # Optional per-period param transform (e.g. int8-quantized FSDP gather,
    # dist/qgather.py — §Perf H3); applied inside the scan body so gathered
    # weights stay transient.  compare=False keeps the plan hashable-free.
    param_transform: Optional[Any] = dataclasses.field(default=None, compare=False)

    @property
    def dtype(self):
        return self.cfg.dtype


def make_plan(
    cfg: ModelConfig,
    axis_n: int = 1,
    kv_cache_dtype: str = "bf16",
    dispatch_groups: int = 1,
    param_transform=None,
) -> ModelPlan:
    plan_heads = make_head_plan(cfg.n_heads, cfg.n_kv_heads, cfg.hd, axis_n)
    vocab_pad = -(-cfg.vocab // max(axis_n, 1)) * max(axis_n, 1)
    return ModelPlan(
        cfg=cfg, axis_n=axis_n, heads=plan_heads, vocab_pad=vocab_pad,
        kv_cache_dtype=kv_cache_dtype, dispatch_groups=dispatch_groups,
        param_transform=param_transform,
    )


# ---------------------------------------------------------------------------
# Parameter definitions: (shape, logical axes, init scale) per leaf.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _P:
    shape: tuple
    axes: tuple
    init: str = "normal"  # normal | zeros | ones | small_normal | conv | dt | alog

    @property
    def dtype_override(self):
        # SSM dynamics params are numerically sensitive → fp32 (DESIGN.md §5).
        return jnp.float32 if self.init in ("dt", "alog") else None


def _norm_def(cfg, d) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": _P((d,), (None,), "ones"), "bias": _P((d,), (None,), "zeros")}
    return {"scale": _P((d,), (None,), "zeros")}  # (1+scale) convention


def _attn_defs(cfg: ModelConfig, hp: HeadPlan, suffix="") -> dict:
    d, hd = cfg.d_model, cfg.hd
    defs = {
        f"wq{suffix}": _P((d, hp.kv_pad, hp.g_pad, hd), ("embed", "heads", None, None)),
        f"wk{suffix}": _P((d, hp.n_kv, hd), ("embed", "kv_heads", "head_dim")),
        f"wv{suffix}": _P((d, hp.n_kv, hd), ("embed", "kv_heads", "head_dim")),
        f"wo{suffix}": _P((hp.kv_pad, hp.g_pad, hd, d), ("heads", None, None, "embed")),
    }
    if cfg.qkv_bias and not suffix:
        defs["bq"] = _P((hp.kv_pad, hp.g_pad, hd), ("heads", None, None), "zeros")
        defs["bk"] = _P((hp.n_kv, hd), ("kv_heads", "head_dim"), "zeros")
        defs["bv"] = _P((hp.n_kv, hd), ("kv_heads", "head_dim"), "zeros")
    return defs


def _mlp_defs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "wg": _P((d, f), ("embed", "ffn")),
        "wd": _P((f, d), ("ffn", "embed"), "small_normal"),
    }
    if cfg.gated_mlp:
        defs["wu"] = _P((d, f), ("embed", "ffn"))
    return defs


def _moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.n_experts
    defs = {
        "router": _P((d, e), (None, None)),
        "w_gate": _P((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_down": _P((e, f, d), ("experts", "expert_ffn", "embed"), "small_normal"),
    }
    if cfg.gated_mlp:
        defs["w_up"] = _P((e, d, f), ("experts", "embed", "expert_ffn"))
    return defs


def _mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh, hd = cfg.ssm_nheads, cfg.ssm_headdim
    gn2 = 2 * cfg.ssm_ngroups * cfg.ssm_state
    k = cfg.ssm_conv
    return {
        "wz": _P((d, nh, hd), ("embed", "ssm_heads", None)),
        "wx": _P((d, nh, hd), ("embed", "ssm_heads", None)),
        "wbc": _P((d, gn2), ("embed", None)),
        "wdt": _P((d, nh), ("embed", "ssm_heads"), "small_normal"),
        "conv_x_w": _P((nh, hd, k), ("ssm_heads", None, None), "conv"),
        "conv_x_b": _P((nh, hd), ("ssm_heads", None), "zeros"),
        "conv_bc_w": _P((gn2, k), (None, None), "conv"),
        "conv_bc_b": _P((gn2,), (None,), "zeros"),
        "a_log": _P((nh,), (None,), "alog"),
        "d_skip": _P((nh,), (None,), "ones"),
        "dt_bias": _P((nh,), (None,), "dt"),
        "norm_scale": _P((nh, hd), ("ssm_heads", None), "zeros"),
        "out_proj": _P((nh, hd, d), ("ssm_heads", None, "embed"), "small_normal"),
    }


def _block_defs(cfg: ModelConfig, hp: HeadPlan, b: BlockDef) -> dict:
    d = cfg.d_model
    defs: dict = {"ln": _norm_def(cfg, d)}
    if b.kind == "attn":
        defs.update(_attn_defs(cfg, hp))
        if b.cross:
            defs["ln_c"] = _norm_def(cfg, d)
            defs.update(_attn_defs(cfg, hp, suffix="_c"))
        if cfg.post_norms:
            defs["post_ln"] = _norm_def(cfg, d)
    else:
        defs.update(_mamba_defs(cfg))
    if b.mlp != "none":
        defs["ln2"] = _norm_def(cfg, d)
        defs.update(_moe_defs(cfg) if b.mlp == "moe" else _mlp_defs(cfg))
        if cfg.post_norms:
            defs["post_ln2"] = _norm_def(cfg, d)
    return defs


def _stack_defs(cfg: ModelConfig, hp: HeadPlan, pattern, n_periods) -> dict:
    out = {}
    for i, b in enumerate(pattern):
        blk = _block_defs(cfg, hp, b)
        out[f"b{i}"] = jax.tree.map(
            lambda pd: _P((n_periods, *pd.shape), ("layers", *pd.axes), pd.init),
            blk,
            is_leaf=lambda x: isinstance(x, _P),
        )
    return out


def model_defs(plan: ModelPlan) -> dict:
    cfg, hp = plan.cfg, plan.heads
    d = cfg.d_model
    defs: dict = {
        "embed": _P((plan.vocab_pad, d), ("vocab", "embed")),
        "final_norm": _norm_def(cfg, d),
        "dec": _stack_defs(cfg, hp, cfg.pattern, cfg.n_periods),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = _P((d, plan.vocab_pad), ("embed", "vocab"))
    if cfg.pos == "learned":
        defs["pos_emb"] = _P((cfg.max_seq, d), (None, "embed"), "small_normal")
    if cfg.family == "encdec":
        defs["enc"] = _stack_defs(cfg, hp, cfg.enc_pattern, cfg.n_enc_periods)
        defs["enc_pos_emb"] = _P((cfg.n_frames, d), (None, "embed"), "small_normal")
        defs["enc_final_norm"] = _norm_def(cfg, d)
    if cfg.n_prefix:
        # llava stub: learned projection bias marker (patches arrive projected).
        defs["prefix_ln"] = _norm_def(cfg, d)
    return defs


def _is_pdef(x):
    return isinstance(x, _P)


def param_shapes(plan: ModelPlan) -> Any:
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype_override or plan.dtype),
        model_defs(plan),
        is_leaf=_is_pdef,
    )


def param_axes(plan: ModelPlan) -> Any:
    return jax.tree.map(lambda pd: pd.axes, model_defs(plan), is_leaf=_is_pdef)


def _init_leaf(key, pd: _P, dtype, n_layers_total: int):
    shape = pd.shape
    if pd.init == "zeros":
        return jnp.zeros(shape, dtype)
    if pd.init == "ones":
        return jnp.ones(shape, dtype)
    if pd.init == "normal":
        return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
    if pd.init == "small_normal":
        s = 0.02 / math.sqrt(max(2 * n_layers_total, 1))
        return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    if pd.init == "conv":
        fan = shape[-1]
        return (
            jax.random.uniform(key, shape, jnp.float32, -1, 1) / math.sqrt(fan)
        ).astype(dtype)
    if pd.init == "dt":
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 0.1)
        return jnp.log(jnp.expm1(u)).astype(jnp.float32)  # fp32 (sensitive)
    if pd.init == "alog":
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)
    raise ValueError(pd.init)


def init_params(plan: ModelPlan, key: jax.Array) -> Any:
    defs = model_defs(plan)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_pdef)
    keys = jax.random.split(key, len(leaves))
    n_layers = plan.cfg.n_layers + plan.cfg.n_enc_periods * len(plan.cfg.enc_pattern)
    out = [
        _init_leaf(k, pd, plan.dtype, n_layers) for k, pd in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Forward blocks
# ---------------------------------------------------------------------------


def _qkv(cfg, hp: HeadPlan, p, h, suffix=""):
    # out_shape matters on the QuantizedTensor path (codes are 2-D fused).
    q = apply_linear(
        p[f"wq{suffix}"], h, out_shape=(hp.kv_pad, hp.g_pad, hp.head_dim),
        name=f"wq{suffix}",
    )  # (B,S,KVp,Gp,hd)
    k = apply_linear(
        p[f"wk{suffix}"], h, out_shape=(hp.n_kv, hp.head_dim), name=f"wk{suffix}"
    )  # (B,S,KV,hd)
    v = apply_linear(
        p[f"wv{suffix}"], h, out_shape=(hp.n_kv, hp.head_dim), name=f"wv{suffix}"
    )
    if cfg.qkv_bias and not suffix:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, _expand_kv(hp, k), _expand_kv(hp, v)


def _expand_kv(hp, k):
    if hp.dup > 1:  # GQA: duplicate true kv heads into padded slots (exact)
        # take-with-iota instead of repeat: GSPMD turns the repeat's
        # split-dim reshape into an "involuntary full rematerialization";
        # a constant gather from a replicated operand slices locally.
        return jnp.take(k, jnp.arange(hp.kv_pad) // hp.dup, axis=2)
    if hp.kv_pad > hp.n_kv:  # MHA: zero-pad (padded q slots have wo ≡ 0)
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, hp.kv_pad - hp.n_kv)
        return jnp.pad(k, pad)
    return k


def _kv_quantize(x: jax.Array):
    """Per-(token, head) symmetric int8: (…, hd) → codes int8, scale fp32."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), -1, keepdims=True) / 127.0 + 1e-12
    codes = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def _kv_quantize4(x: jax.Array):
    """Per-(token, head) symmetric int4, fold-in-half packed: (…, hd) →
    packed uint8 (…, hd/2), scale fp32 (…, 1).  Codes live in [-7, 7] so the
    4-bit two's-complement range is symmetric (−8 unused)."""
    from repro.quant.pack import kv_pack_int4

    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), -1, keepdims=True) / 7.0 + 1e-12
    codes = jnp.clip(jnp.round(x32 / scale), -7, 7).astype(jnp.int8)
    return kv_pack_int4(codes), scale


def _attn_sublayer(
    cfg,
    hp,
    b: BlockDef,
    p,
    x,
    *,
    pos_ids,
    mode: str,
    cache=None,
    enc_out=None,
    decode_pos=None,
    kv_dtype: str = "bf16",
    page_table=None,
    page_write=None,
):
    """Self-attention (+ optional cross) sublayer.  Returns (x, new_cache).

    With ``page_table`` set the KV cache is block-paged (DESIGN.md
    §Paged-serving): decode writes the new token into
    ``(page_write, pos % page_size)`` and attends through
    ``ops.paged_attention``; prefill scatter-writes the chunk into its
    pages and attends the gathered context with ``flash_attention`` at
    ``q_offset = pos_ids[0]`` (chunked prefill).
    """
    h = apply_norm(p["ln"], x, cfg.norm)
    q, k, v = _qkv(cfg, hp, p, h)
    if cfg.pos == "rope":
        q = rope(q, pos_ids, cfg.rope_theta)
        k = rope(k, pos_ids, cfg.rope_theta)
    q = logical_constraint(q, ("batch", None, "heads", None, None))
    k = logical_constraint(k, ("batch", None, "heads", None))
    v = logical_constraint(v, ("batch", None, "heads", None))

    new_cache = {}
    if page_table is not None:
        if b.cross:
            raise ValueError("paged KV serving does not support cross-attention")
        from repro.kernels import ops as kops

        kc, vc = cache["k"], cache["v"]  # (n_pages, psz, KVp, hd)
        psz = kc.shape[1]
        if mode == "decode":
            B = q.shape[0]
            pos_b = jnp.broadcast_to(jnp.asarray(decode_pos, jnp.int32), (B,))
            slot = pos_b % psz
            if kv_dtype in ("int8", "int4"):
                quantize = _kv_quantize4 if kv_dtype == "int4" else _kv_quantize
                k8, ks_new = quantize(k[:, 0])
                v8, vs_new = quantize(v[:, 0])
                kc = kc.at[page_write, slot].set(k8)
                vc = vc.at[page_write, slot].set(v8)
                ksc = cache["ks"].at[page_write, slot].set(ks_new)
                vsc = cache["vs"].at[page_write, slot].set(vs_new)
                new_cache = {"k": kc, "v": vc, "ks": ksc, "vs": vsc}
                ksp, vsp = ksc, vsc
            else:
                kc = kc.at[page_write, slot].set(k[:, 0].astype(kc.dtype))
                vc = vc.at[page_write, slot].set(v[:, 0].astype(vc.dtype))
                new_cache = {"k": kc, "v": vc}
                ksp = vsp = None
            o = kops.paged_attention(
                q[:, 0], kc, vc, page_table, pos_b + 1,
                window=b.window, attn_softcap=cfg.attn_softcap,
                k_scale_pages=ksp, v_scale_pages=vsp,
            )[:, None]
        else:  # chunked paged prefill, one sequence at a time (B == 1)
            S = k.shape[1]
            pos = jnp.asarray(pos_ids, jnp.int32).reshape(-1)  # (S,) absolute
            row = page_table[0]  # (n_pgs,)
            # Pad positions beyond the table must hit the null page (page 0)
            # explicitly — the default gather clamp would alias them onto the
            # last real page and clobber valid prompt KV.
            pg = pos // psz
            pidx = jnp.where(
                pg < row.shape[0], row[jnp.minimum(pg, row.shape[0] - 1)], 0
            )
            slot = pos % psz
            if kv_dtype in ("int8", "int4"):
                quantize = _kv_quantize4 if kv_dtype == "int4" else _kv_quantize
                k8, ks_new = quantize(k[0])
                v8, vs_new = quantize(v[0])
                kc = kc.at[pidx, slot].set(k8)
                vc = vc.at[pidx, slot].set(v8)
                ksc = cache["ks"].at[pidx, slot].set(ks_new)
                vsc = cache["vs"].at[pidx, slot].set(vs_new)
                new_cache = {"k": kc, "v": vc, "ks": ksc, "vs": vsc}
            else:
                kc = kc.at[pidx, slot].set(k[0].astype(kc.dtype))
                vc = vc.at[pidx, slot].set(v[0].astype(vc.dtype))
                new_cache = {"k": kc, "v": vc}
            n_ctx = row.shape[0] * psz
            kctx = kc[row].reshape(1, n_ctx, *kc.shape[2:])
            vctx = vc[row].reshape(1, n_ctx, *vc.shape[2:])
            if kv_dtype == "int4":
                from repro.quant.pack import kv_unpack_int4

                kctx = kv_unpack_int4(kctx)
                vctx = kv_unpack_int4(vctx)
            if kv_dtype in ("int8", "int4"):
                ksg = new_cache["ks"][row].reshape(1, n_ctx, -1, 1)
                vsg = new_cache["vs"][row].reshape(1, n_ctx, -1, 1)
                kctx = (kctx.astype(jnp.float32) * ksg).astype(q.dtype)
                vctx = (vctx.astype(jnp.float32) * vsg).astype(q.dtype)
            o = flash_attention(
                q, kctx, vctx,
                causal=True, window=b.window, attn_softcap=cfg.attn_softcap,
                q_offset=pos[0],
            )
        out = _apply_out_proj(p["wo"], o, name="wo")
        if cfg.post_norms:
            out = apply_norm(p["post_ln"], out, cfg.norm)
        return x + out, new_cache
    if mode == "decode":
        kc, vc = cache["k"], cache["v"]
        B = kc.shape[0]
        w = b.window
        pos_b = jnp.broadcast_to(jnp.asarray(decode_pos, jnp.int32), (B,))
        slot = pos_b % kc.shape[1] if w is not None else pos_b
        bidx = jnp.arange(B)
        if kv_dtype == "int8":
            k8, ks = _kv_quantize(k[:, 0])
            v8, vs = _kv_quantize(v[:, 0])
            kc = kc.at[bidx, slot].set(k8)
            vc = vc.at[bidx, slot].set(v8)
            ksc = cache["ks"].at[bidx, slot].set(ks)
            vsc = cache["vs"].at[bidx, slot].set(vs)
            new_cache = {"k": kc, "v": vc, "ks": ksc, "vs": vsc}
        else:
            kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
            ksc = vsc = None
            new_cache = {"k": kc, "v": vc}
        # Ring buffers make the window implicit; valid prefix is per-slot.
        valid_len = jnp.minimum(pos_b + 1, kc.shape[1])
        o = decode_attention(q, kc, vc, valid_len, window=None,
                             attn_softcap=cfg.attn_softcap,
                             k_scale=ksc, v_scale=vsc)
    else:
        o = flash_attention(
            q, k, v,
            causal=b.causal,
            window=b.window,
            attn_softcap=cfg.attn_softcap,
        )
        if mode == "prefill":
            new_cache = _fill_cache(cache, k, v, b.window, pos_ids, kv_dtype)

    out = _apply_out_proj(p["wo"], o, name="wo")
    if cfg.post_norms:
        out = apply_norm(p["post_ln"], out, cfg.norm)
    x = x + out

    if b.cross:
        h = apply_norm(p["ln_c"], x, cfg.norm)
        qc = apply_linear(
            p["wq_c"], h, out_shape=(hp.kv_pad, hp.g_pad, hp.head_dim), name="wq_c"
        )
        if mode == "decode":
            kcx, vcx = cache["ck"], cache["cv"]
            new_cache.update({"ck": kcx, "cv": vcx})
        else:
            kcx = _expand_kv(hp, apply_linear(
                p["wk_c"], enc_out, out_shape=(hp.n_kv, hp.head_dim), name="wk_c"
            ))
            vcx = _expand_kv(hp, apply_linear(
                p["wv_c"], enc_out, out_shape=(hp.n_kv, hp.head_dim), name="wv_c"
            ))
            if mode == "prefill":
                new_cache.update({"ck": kcx.astype(jnp.bfloat16),
                                  "cv": vcx.astype(jnp.bfloat16)})
        if mode == "decode":
            oc = decode_attention(qc, kcx, vcx, kcx.shape[1], window=None)
        else:
            oc = flash_attention(qc, kcx, vcx, causal=False)
        x = x + _apply_out_proj(p["wo_c"], oc, name="wo_c")
    return x, new_cache


def _apply_out_proj(w, o, name=None):
    """o: (B, S, KVp, Gp, hd) → (B, S, d); dense 4-D weight or QuantizedTensor
    with codes (d, KVp·Gp·hd)."""
    if hasattr(w, "codes") or isinstance(w, HoistedDequant):
        return apply_linear(w, o.reshape(*o.shape[:2], -1), name=name)
    from repro.models.common import _record_linear

    _record_linear(name, o.reshape(*o.shape[:2], -1))
    return jnp.einsum("bskgd,kgdm->bsm", o, w)


def _fill_cache(cache, k, v, window, pos_ids, kv_dtype="bf16"):
    """Prefill: write the (ring-buffered for windowed layers) cache."""
    if cache is None:
        return {}
    kc, vc = cache["k"], cache["v"]
    cap = kc.shape[1]
    S = k.shape[1]
    if kv_dtype == "int8":
        k, ks = _kv_quantize(k)
        v, vs = _kv_quantize(v)
    if window is not None and cap < S:
        # keep last `cap` positions at slots pos % cap
        slots = (jnp.arange(S - cap, S)) % cap
        kc = kc.at[:, slots].set(k[:, S - cap :].astype(kc.dtype))
        vc = vc.at[:, slots].set(v[:, S - cap :].astype(vc.dtype))
        out = {"k": kc, "v": vc}
        if kv_dtype == "int8":
            out["ks"] = cache["ks"].at[:, slots].set(ks[:, S - cap :])
            out["vs"] = cache["vs"].at[:, slots].set(vs[:, S - cap :])
    else:
        zi = (0, 0, 0, 0)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), zi)
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), zi)
        out = {"k": kc, "v": vc}
        if kv_dtype == "int8":
            out["ks"] = jax.lax.dynamic_update_slice(cache["ks"], ks, zi)
            out["vs"] = jax.lax.dynamic_update_slice(cache["vs"], vs, zi)
    return out


def _mlp_sublayer(cfg, b: BlockDef, p, x, aux, dispatch_groups=1):
    if b.mlp == "none":
        return x, aux
    h = apply_norm(p["ln2"], x, cfg.norm)
    if b.mlp == "moe":
        y, probs = moe_apply(
            p,
            h,
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            act=cfg.act,
            gated=cfg.gated_mlp,
            norm_topk=cfg.router_norm_topk,
            return_aux=aux is not None,
            dispatch_groups=dispatch_groups,
        )
        if aux is not None and probs is not None:
            aux = aux + router_aux_loss(probs)
    else:
        g = apply_linear(p["wg"], h, name="wg")
        u = activation(g, cfg.act)
        if cfg.gated_mlp:
            u = u * apply_linear(p["wu"], h, name="wu")
        u = logical_constraint(u, ("batch", None, "ffn"))
        y = apply_linear(p["wd"], u, name="wd")
    if cfg.post_norms:
        y = apply_norm(p["post_ln2"], y, cfg.norm)
    return x + y, aux


def _block_apply(cfg, hp, b, p, x, *, mode, pos_ids, cache=None, enc_out=None,
                 decode_pos=None, aux=None, kv_dtype="bf16", dispatch_groups=1,
                 page_table=None, page_write=None):
    if b.kind == "attn":
        x, new_cache = _attn_sublayer(
            cfg, hp, b, p, x,
            pos_ids=pos_ids, mode=mode, cache=cache, enc_out=enc_out,
            decode_pos=decode_pos, kv_dtype=kv_dtype,
            page_table=page_table, page_write=page_write,
        )
    else:
        h = apply_norm(p["ln"], x, cfg.norm)
        if mode == "decode":
            y, new_cache = mamba2.mamba_decode(p, h, cfg, cache)
        else:
            y, new_cache = mamba2.mamba_apply(
                p, h, cfg, cache=cache, return_cache=(mode == "prefill")
            )
        x = x + y
    x, aux = _mlp_sublayer(cfg, b, p, x, aux, dispatch_groups)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stacks (scan over periods)
# ---------------------------------------------------------------------------


def _run_stack(
    plan: ModelPlan,
    stack_params: dict,
    pattern,
    x,
    *,
    mode: str,
    pos_ids,
    caches=None,
    enc_out=None,
    decode_pos=None,
    aux=None,
    remat: bool = True,
    page_table=None,
    page_write=None,
):
    """Scan over periods.  caches: pytree stacked on leading period axis.
    ``page_table``/``page_write`` (shared across periods) switch attention
    layers to the paged KV path."""
    cfg, hp = plan.cfg, plan.heads
    have_aux = aux is not None

    def period_fn(carry, xs):
        x, aux = carry
        p_period, cache_period = xs
        if plan.param_transform is not None and mode == "train":
            p_period = plan.param_transform(p_period)
        new_caches = {}
        for i, b in enumerate(pattern):
            c_i = cache_period.get(f"b{i}") if cache_period else None
            x, nc, aux = _block_apply(
                cfg, hp, b, p_period[f"b{i}"], x,
                mode=mode, pos_ids=pos_ids, cache=c_i, enc_out=enc_out,
                decode_pos=decode_pos, aux=aux, kv_dtype=plan.kv_cache_dtype,
                dispatch_groups=plan.dispatch_groups,
                page_table=page_table, page_write=page_write,
            )
            new_caches[f"b{i}"] = nc
        return (x, aux), new_caches

    body = period_fn
    if remat and mode == "train":
        body = jax.checkpoint(period_fn, prevent_cse=False)

    if aux is None:
        aux = jnp.zeros((), jnp.float32)
    xs = (stack_params, caches if caches is not None else _empty_caches(pattern, plan))
    (x, aux), new_caches = jax.lax.scan(body, (x, aux), xs)
    return x, new_caches, (aux if have_aux else None)


def _empty_caches(pattern, plan):
    n = plan.cfg.n_periods
    return {f"b{i}": None for i in range(len(pattern))} if False else {
        f"b{i}": jnp.zeros((n, 0), jnp.float32) for i in range(len(pattern))
    }


# ---------------------------------------------------------------------------
# Losses / entry points
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    x: jax.Array,  # (B, S, d)
    head,  # (d, vocab_pad) dense / QuantizedTensor, or ("tied", embed)
    labels: jax.Array,  # (B, S) int32
    mask: jax.Array,  # (B, S) bool/float
    *,
    real_vocab: int,
    chunk: int = 512,
    logit_softcap: Optional[float] = None,
):
    """LM cross-entropy without materializing (B, S, V) logits: scan over
    sequence chunks (beyond-paper memory optimization, DESIGN.md §4)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xs = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def step(acc, inp):
        xc, lc, mc = inp
        logits = _head_logits(xc, head)  # (B, C, Vp) fp32
        logits = softcap(logits, logit_softcap)
        logits = logical_constraint(logits, ("batch", None, "vocab"))
        vp = logits.shape[-1]
        if vp > real_vocab:
            bias = jnp.where(jnp.arange(vp) < real_vocab, 0.0, -1e30)
            logits = logits + bias
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),) * 2, (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def _head_logits(xc, head):
    if isinstance(head, tuple) and head[0] == "tied":
        return jnp.einsum(
            "bcd,vd->bcv", xc, head[1], preferred_element_type=jnp.float32
        )
    if hasattr(head, "codes") or isinstance(head, HoistedDequant):
        # QuantizedTensor (or its hoisted-dequant serving view)
        y = apply_linear(head, xc)
        return y.astype(jnp.float32)
    return jnp.einsum("bcd,dv->bcv", xc, head, preferred_element_type=jnp.float32)


def _logit_head(plan, params):
    if plan.cfg.tie_embeddings:
        return ("tied", params["embed"])
    return params["lm_head"]


def _embed_tokens(plan, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(plan.dtype)
    if plan.cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(plan.cfg.d_model), plan.dtype)
    return x


def _encoder(plan, params, frames):
    cfg = plan.cfg
    x = frames.astype(plan.dtype) + params["enc_pos_emb"][None].astype(plan.dtype)
    pos = jnp.arange(frames.shape[1])
    x, _, _ = _run_stack(
        plan, params["enc"], cfg.enc_pattern, x, mode="train", pos_ids=pos
    )
    return apply_norm(params["enc_final_norm"], x, cfg.norm)


def train_loss(plan: ModelPlan, params, batch: dict) -> jax.Array:
    """batch: tokens (B,S) [+ frames (B,F,d) | patches (B,P,d)] → scalar loss."""
    cfg = plan.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(plan, params, tokens)
    loss_mask = jnp.ones((B, S), jnp.float32)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encoder(plan, params, batch["frames"])
    if cfg.n_prefix:
        pre = batch["patches"].astype(plan.dtype)
        pre = apply_norm(params["prefix_ln"], pre, cfg.norm)
        x = jnp.concatenate([pre, x], axis=1)
        loss_mask = jnp.concatenate(
            [jnp.zeros((B, cfg.n_prefix), jnp.float32), loss_mask], axis=1
        )
        tokens = jnp.concatenate(
            [jnp.zeros((B, cfg.n_prefix), tokens.dtype), tokens], axis=1
        )
        S = S + cfg.n_prefix

    x = logical_constraint(x, ("batch", "seq_sp", None))
    pos = jnp.arange(S)
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice(
            params["pos_emb"], (0, 0), (S, cfg.d_model)
        )[None].astype(plan.dtype)

    aux0 = jnp.zeros((), jnp.float32) if _has_moe(cfg) else None
    x, _, aux = _run_stack(
        plan, params["dec"], cfg.pattern, x,
        mode="train", pos_ids=pos, enc_out=enc_out, aux=aux0,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)

    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    loss_mask = loss_mask.at[:, -1].set(0.0)
    loss = chunked_cross_entropy(
        x,
        _logit_head(plan, params),
        labels,
        loss_mask,
        real_vocab=cfg.vocab,
        logit_softcap=cfg.logit_softcap,
    )
    if aux is not None:
        loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return loss


def _has_moe(cfg) -> bool:
    return any(b.mlp == "moe" for b in cfg.pattern)


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------


def _block_cache_shape(plan: ModelPlan, b: BlockDef, B: int, cap: int):
    cfg, hp = plan.cfg, plan.heads
    if b.kind == "attn":
        c = min(cap, b.window) if b.window is not None else cap
        if plan.kv_cache_dtype == "int4":
            raise ValueError(
                "kv_cache_dtype='int4' is paged-engine only (packed pages, "
                "quant/pack.kv_pack_int4); the contiguous cache supports "
                "bf16 and int8 — use --engine paged or drop to int8"
            )
        if plan.kv_cache_dtype == "int8":
            sh = {
                "k": jax.ShapeDtypeStruct((B, c, hp.kv_pad, hp.head_dim), jnp.int8),
                "v": jax.ShapeDtypeStruct((B, c, hp.kv_pad, hp.head_dim), jnp.int8),
                "ks": jax.ShapeDtypeStruct((B, c, hp.kv_pad, 1), jnp.float32),
                "vs": jax.ShapeDtypeStruct((B, c, hp.kv_pad, 1), jnp.float32),
            }
        else:
            sh = {
                "k": jax.ShapeDtypeStruct((B, c, hp.kv_pad, hp.head_dim), jnp.bfloat16),
                "v": jax.ShapeDtypeStruct((B, c, hp.kv_pad, hp.head_dim), jnp.bfloat16),
            }
        if b.cross:
            sh["ck"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, hp.kv_pad, hp.head_dim), jnp.bfloat16
            )
            sh["cv"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, hp.kv_pad, hp.head_dim), jnp.bfloat16
            )
        return sh
    k = cfg.ssm_conv
    return MambaCache(
        conv_x=jax.ShapeDtypeStruct(
            (B, k - 1, cfg.ssm_nheads, cfg.ssm_headdim), jnp.bfloat16
        ),
        conv_bc=jax.ShapeDtypeStruct(
            (B, k - 1, 2 * cfg.ssm_ngroups * cfg.ssm_state), jnp.bfloat16
        ),
        ssm=jax.ShapeDtypeStruct(
            (B, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
    )


def cache_shapes(plan: ModelPlan, B: int, cap: int):
    """ShapeDtypeStruct pytree of the decode cache (stacked over periods)."""
    cfg = plan.cfg

    def stack(sds):
        return jax.ShapeDtypeStruct((cfg.n_periods, *sds.shape), sds.dtype)

    out = {}
    for i, b in enumerate(cfg.pattern):
        out[f"b{i}"] = jax.tree.map(stack, _block_cache_shape(plan, b, B, cap))
    return out


def cache_axes(plan: ModelPlan, seq_shard: bool = False):
    """Logical axes mirroring cache_shapes."""
    cfg = plan.cfg
    seq_ax = "cache_seq" if seq_shard else None

    def attn_axes(b):
        ax = {
            "k": ("layers", "batch", seq_ax, "heads", None),
            "v": ("layers", "batch", seq_ax, "heads", None),
        }
        if plan.kv_cache_dtype == "int8":
            ax["ks"] = ("layers", "batch", seq_ax, "heads", None)
            ax["vs"] = ("layers", "batch", seq_ax, "heads", None)
        if b.cross:
            ax["ck"] = ("layers", "batch", None, "heads", None)
            ax["cv"] = ("layers", "batch", None, "heads", None)
        return ax

    out = {}
    for i, b in enumerate(cfg.pattern):
        if b.kind == "attn":
            out[f"b{i}"] = attn_axes(b)
        else:
            out[f"b{i}"] = MambaCache(
                conv_x=("layers", "batch", None, "ssm_heads", None),
                conv_bc=("layers", "batch", None, None),
                ssm=("layers", "batch", "ssm_heads", None, None),
            )
    return out


def init_cache(plan: ModelPlan, B: int, cap: int):
    return jax.tree.map(
        lambda sds: jnp.zeros(sds.shape, sds.dtype), cache_shapes(plan, B, cap)
    )


def paged_cache_shapes(plan: ModelPlan, n_pages: int, page_size: int):
    """ShapeDtypeStruct pytree of the block-paged decode cache.

    Per attention layer: ``k``/``v`` pages ``(n_pages, page_size, KVp, hd)``
    (int8 adds fp32 ``ks``/``vs`` scale planes; int4 packs two codes/byte —
    uint8 pages of width ``hd/2`` plus the same scale planes) with a leading
    period axis,
    exactly like :func:`cache_shapes` — page id ``p`` addresses slot ``p``
    of every layer's array, so page accounting is in shared token slots.
    There is no batch axis: the pool is shared by all sequences; ownership
    lives in the page tables (serve/kv_cache.py).  Windowed layers keep
    full pages and mask in attention (no ring buffer).  Only
    self-attention decoder stacks page — cross-attention and Mamba state
    stay on the contiguous engine.
    """
    cfg, hp = plan.cfg, plan.heads
    for b in cfg.pattern:
        if b.kind != "attn" or b.cross:
            raise ValueError(
                "paged KV serving supports self-attention decoder stacks only"
            )
    if cfg.family == "encdec" or cfg.n_prefix:
        raise ValueError("paged KV serving: decoder-only models only")
    kv_dt = plan.kv_cache_dtype
    if kv_dt == "int4":
        if hp.head_dim % 2:
            raise ValueError(
                f"int4 KV pages need an even head dim (fold-in-half packing), "
                f"got hd={hp.head_dim}"
            )
        kdt, page_hd = jnp.uint8, hp.head_dim // 2
    elif kv_dt == "int8":
        kdt, page_hd = jnp.int8, hp.head_dim
    else:
        kdt, page_hd = jnp.bfloat16, hp.head_dim
    page = jax.ShapeDtypeStruct((n_pages, page_size, hp.kv_pad, page_hd), kdt)
    sh = {"k": page, "v": page}
    if kv_dt in ("int8", "int4"):
        sp = jax.ShapeDtypeStruct((n_pages, page_size, hp.kv_pad, 1), jnp.float32)
        sh["ks"] = sp
        sh["vs"] = sp

    def stack(sds):
        return jax.ShapeDtypeStruct((cfg.n_periods, *sds.shape), sds.dtype)

    return {f"b{i}": jax.tree.map(stack, sh) for i in range(len(cfg.pattern))}


def init_paged_cache(plan: ModelPlan, n_pages: int, page_size: int):
    return jax.tree.map(
        lambda sds: jnp.zeros(sds.shape, sds.dtype),
        paged_cache_shapes(plan, n_pages, page_size),
    )


def prefill(plan: ModelPlan, params, batch: dict, cache):
    """Full-sequence forward filling `cache`; returns (last_logits, cache)."""
    cfg = plan.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(plan, params, tokens)
    enc_out = _encoder(plan, params, batch["frames"]) if cfg.family == "encdec" else None
    if cfg.n_prefix:
        pre = apply_norm(params["prefix_ln"], batch["patches"].astype(plan.dtype), cfg.norm)
        x = jnp.concatenate([pre, x], axis=1)
        S = S + cfg.n_prefix
    pos = jnp.arange(S)
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice(params["pos_emb"], (0, 0), (S, cfg.d_model))[
            None
        ].astype(plan.dtype)
    x, new_cache, _ = _run_stack(
        plan, params["dec"], cfg.pattern, x,
        mode="prefill", pos_ids=pos, caches=cache, enc_out=enc_out,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _head_logits(x[:, -1:], _logit_head(plan, params))[:, 0]
    logits = softcap(logits, cfg.logit_softcap)
    return logits, new_cache


def decode_step(plan: ModelPlan, params, tokens: jax.Array, cache, pos):
    """One decode step.  tokens: (B, 1); pos: scalar or (B,) int32 position
    (per-slot positions enable continuous batching — serve/engine.py)."""
    cfg = plan.cfg
    B = tokens.shape[0]
    x = _embed_tokens(plan, params, tokens)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    pos_ids = pos_b[:, None]  # (B, 1)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_emb"], pos_b, axis=0)[:, None].astype(
            plan.dtype
        )
    x, new_cache, _ = _run_stack(
        plan, params["dec"], cfg.pattern, x,
        mode="decode", pos_ids=pos_ids, caches=cache, decode_pos=pos,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _head_logits(x, _logit_head(plan, params))[:, 0]
    logits = softcap(logits, cfg.logit_softcap)
    return logits, new_cache


def paged_prefill_chunk(
    plan: ModelPlan, params, tokens: jax.Array, cache, page_table, offset
):
    """One chunked-prefill step for a single sequence (DESIGN.md
    §Paged-serving).

    ``tokens``: (1, C) — chunk ``[offset, offset + C)`` of the prompt
    (right-padded; pad positions scatter into the null page or into
    not-yet-valid slots that decode overwrites before they enter any
    length mask, so no masking of the writes is needed).  ``page_table``:
    (1, n_pgs) — the sequence's page row; ``offset``: traced scalar, the
    absolute position of ``tokens[:, 0]``.  Writes the chunk's KV into its
    pages and attends queries against the gathered context
    ``[0, offset + C)``, so long prompts stream through in O(C) steps
    without ever holding a contiguous cache.  Returns the updated cache
    (no logits — the engine replays the last prompt token as the first
    decode, exactly like the contiguous engine).
    """
    cfg = plan.cfg
    B, S = tokens.shape
    if B != 1:
        raise ValueError("paged prefill processes one sequence per call")
    x = _embed_tokens(plan, params, tokens)
    pos = jnp.asarray(offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_emb"], pos, axis=0)[None].astype(plan.dtype)
    _, new_cache, _ = _run_stack(
        plan, params["dec"], cfg.pattern, x,
        mode="prefill", pos_ids=pos, caches=cache, page_table=page_table,
    )
    return new_cache


def paged_decode_step(
    plan: ModelPlan, params, tokens: jax.Array, cache, pos, page_table,
    page_write,
):
    """One decode step over the paged KV pool.

    ``tokens``: (B, 1); ``pos``: (B,) int32 positions; ``page_table``:
    (B, n_pgs) int32 (padded entries → null page); ``page_write``: (B,)
    int32 — the page holding position ``pos[b]`` (the host scheduler knows
    the page tables, so the write target arrives precomputed; inactive
    lanes point at the null page).  Writes each lane's new KV into
    ``(page_write, pos % page_size)`` and attends via
    ``ops.paged_attention`` with per-lane lengths ``pos + 1``.
    """
    cfg = plan.cfg
    B = tokens.shape[0]
    x = _embed_tokens(plan, params, tokens)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    pos_ids = pos_b[:, None]  # (B, 1)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_emb"], pos_b, axis=0)[:, None].astype(
            plan.dtype
        )
    x, new_cache, _ = _run_stack(
        plan, params["dec"], cfg.pattern, x,
        mode="decode", pos_ids=pos_ids, caches=cache, decode_pos=pos,
        page_table=page_table, page_write=page_write,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _head_logits(x, _logit_head(plan, params))[:, 0]
    logits = softcap(logits, cfg.logit_softcap)
    return logits, new_cache


def paged_verify_tokens(
    plan: ModelPlan, params, tokens: jax.Array, cache, pos0, page_table,
    write_pages,
):
    """Multi-token speculative *verify* forward (DESIGN.md
    §Speculative-serving).

    ``tokens``: (B, L) — per lane, the replayed last committed token
    followed by the draft proposal (right-padded for lanes with shorter
    proposals); ``pos0``: (B,) int32 position of ``tokens[:, 0]``;
    ``write_pages``: (B, L) int32 — the page holding position
    ``pos0[b] + j`` (null page for pad columns and inactive lanes).
    Returns ``(logits (B, L, V), cache)`` where ``logits[:, j]`` scores
    the token *after* ``tokens[:, j]``.

    Deliberately **not** the flash-attention chunk path: the chunk path
    writes KV through prefill-path quantize/round code whose bytes
    differ from the decode path by ~1 ulp — enough to flip a near-tie
    argmax.  Instead the L positions run through **one**
    :func:`paged_decode_step` call as ``B·L`` *virtual lanes*: lane
    ``(b, j)`` decodes token ``tokens[b, j]`` at position ``pos0[b] +
    j`` against lane b's page table.  Inside every layer the decode step
    scatters all lanes' K/V into the pages *before* the attention
    gather, so virtual lane ``(b, j)`` reads the in-flight keys of
    ``(b, 0..j-1)`` from the pages it shares with them, and its causal
    length mask (``pos + 1``) hides ``(b, j+1..)`` — sequencing by
    masking instead of by a ``lax.scan``.  Every position therefore goes
    through *the same arithmetic* the non-speculative loop would have
    used, and the per-position GEMMs are row-blocks of one batched
    GEMM; tests pin that logits and KV bytes match L separate decode
    calls exactly, which is what makes the engine's token-identity
    invariant (speculative greedy ≡ plain greedy) bitwise rather than
    tolerance-based.  Unlike a scan of decode bodies, the weights — and
    on the quantized serving path, their dequantization
    (models/common.HoistedDequant) — are read once for all L positions:
    that amortization is what speculative decoding buys on the serving
    hot path.  Pad columns write to the null scratch page and their
    logits are ignored by the engine's commit rule.
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    B, L = tokens.shape
    pos0 = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32), (B,))
    pos = (pos0[:, None] + jnp.arange(L, dtype=jnp.int32)[None, :]).reshape(-1)
    logits, cache = paged_decode_step(
        plan, params, tokens.reshape(B * L, 1), cache, pos,
        jnp.repeat(jnp.asarray(page_table), L, axis=0),
        jnp.asarray(write_pages, jnp.int32).reshape(-1),
    )
    return logits.reshape(B, L, -1), cache


def paged_draft_tokens(
    plan: ModelPlan, params, forced: jax.Array, n_forced, cache, pos0,
    page_table, write_pages,
):
    """Fused greedy draft proposal: S decode steps of the *draft* stack
    with the argmax feedback loop inside one ``lax.scan`` (DESIGN.md
    §Speculative-serving).

    Step ``j`` runs at position ``pos0[b] + j``: for ``j <
    n_forced[b]`` it is *teacher-forced* with ``forced[b, j]`` — already
    committed tokens replayed so the draft KV catches up to the target's
    committed frontier (after a fully-accepted round the bonus token
    never passed through the draft, so the catch-up is 2 tokens; 1 is
    the steady state) — and for later steps it feeds back its own
    previous argmax, producing draft proposals.  ``forced``: (B, S);
    ``n_forced``: (B,); ``pos0``: (B,) position of step 0;
    ``write_pages``: (B, S) — page of position ``pos0[b] + j``, null
    once the lane's step budget is exhausted.  Returns ``(tokens (B, S),
    cache)`` where ``tokens[b, j]`` is step j's argmax — the host slices
    proposals out of columns ``[n_forced-1, n_forced-1+d)``.  One
    dispatch per whole proposal is what lets speculation pay for itself
    when per-call overhead rivals the draft matmuls; ``jnp.argmax``
    breaks ties toward the lowest index, matching the engine's host-side
    ``np.argmax`` commit rule.
    """
    forced = jnp.asarray(forced, jnp.int32)
    B, S = forced.shape
    n_forced = jnp.asarray(n_forced, jnp.int32)
    pos0 = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32), (B,))

    def body(carry, xs):
        tok, c = carry
        frc, wp, j = xs
        inp = jnp.where(j < n_forced, frc, tok)
        logits, c = paged_decode_step(
            plan, params, inp[:, None], c, pos0 + j, page_table, wp,
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, c), nxt

    xs = (
        jnp.transpose(forced),
        jnp.transpose(jnp.asarray(write_pages, jnp.int32)),
        jnp.arange(S, dtype=jnp.int32),
    )
    (_, cache), drafts = jax.lax.scan(
        body, (jnp.zeros((B,), jnp.int32), cache), xs
    )
    return jnp.transpose(drafts), cache
