"""Model zoo: one generic period-scanned stack covering all 10 assigned archs."""

from repro.models.model import (
    ModelPlan,
    make_plan,
    init_params,
    init_cache,
    cache_shapes,
    cache_axes,
    param_shapes,
    param_axes,
    train_loss,
    prefill,
    decode_step,
    paged_cache_shapes,
    init_paged_cache,
    paged_prefill_chunk,
    paged_decode_step,
    paged_verify_tokens,
    paged_draft_tokens,
)
from repro.models.common import HoistedDequant, hoist_dequant

__all__ = [
    "ModelPlan",
    "make_plan",
    "init_params",
    "init_cache",
    "cache_shapes",
    "cache_axes",
    "param_shapes",
    "param_axes",
    "train_loss",
    "prefill",
    "decode_step",
    "paged_cache_shapes",
    "init_paged_cache",
    "paged_prefill_chunk",
    "paged_decode_step",
    "paged_verify_tokens",
    "paged_draft_tokens",
    "HoistedDequant",
    "hoist_dequant",
]
