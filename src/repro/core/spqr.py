"""SpQR-style baseline (Dettmers et al., 2023) as described in QuantEase §4.2.

Sensitivity-based outlier selection + GPTQ:

  1. ω_{ij} = (W_{ij} − q(W_{ij}))² / [H⁻¹]_{jj}  (OBS saliency, Eq. 15),
  2. outliers = { (i,j) : ω_{ij} > τ }, τ chosen as the quantile hitting the
     requested outlier fraction,
  3. GPTQ column sweep keeping outliers at full precision (they still absorb
     OBS corrections; grid range shrinks by excluding them).

Unlike outlier-aware QuantEase, the outlier *set is fixed* after step 2 —
this is exactly the structural difference the paper credits for QuantEase's
2×+ improvement (§4.3 last paragraph).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.calib import damp_sigma
from repro.core.gptq import gptq_quantize, obs_sensitivity
from repro.quant import GridSpec, compute_grid, compute_grid_excluding_outliers, quantize_dequantize

__all__ = ["spqr_quantize"]


@functools.partial(jax.jit, static_argnames=("spec", "s", "block_size"))
def spqr_quantize(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    s: int,
    percdamp: float = 0.01,
    block_size: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Returns (Ŵ_eff fp32, outlier_mask bool).  ``s`` = number of outliers."""
    q, p = w.shape
    w = w.astype(jnp.float32)

    # Step 1–2: saliency w.r.t. the plain grid, top-s as outliers.
    base_grid = compute_grid(w, spec)
    w_rtn = quantize_dequantize(w, base_grid)
    omega = obs_sensitivity(w, sigma, w_rtn, percdamp=percdamp)
    _, idx = jax.lax.top_k(omega.reshape(-1), s)
    mask = jnp.zeros((q * p,), jnp.bool_).at[idx].set(True).reshape(q, p)

    # Step 3: GPTQ with outliers pinned at full precision + shrunk grid.
    grid = compute_grid_excluding_outliers(w, spec, mask)
    w_hat = gptq_quantize(
        w,
        sigma,
        spec,
        percdamp=percdamp,
        block_size=block_size,
        keep_mask=mask,
        grid=grid,
    )
    return w_hat, mask
