"""QuantEase — cyclic coordinate descent layer-wise quantization (the paper).

Math (Lemma 1):  with Σ = XXᵀ, the optimal quantized value of coordinate
(i, j), all others fixed, is ``q_i(β̃)`` where::

    β̃ = −[ Σ_{k≠j} Σ_{j,k} Ŵ_{i,k} − (WΣ)_{i,j} ] / Σ_{j,j}

Updates are applied one *column* at a time (rows are independent given j).

Two implementations:

* :func:`quantease_reference` — Algorithm 1 verbatim (rank-1 maintenance of
  ŴΣ).  O(p²q) per iteration with p sequential HBM-bound steps; used as the
  numerical oracle in tests.
* :func:`quantease_quantize` — the production path: Algorithm 2's
  "accelerated partial updates" (Eq. 13) restructured into **column blocks**
  (DESIGN.md §3).  Per block of B columns, the cross-block correction is one
  MXU matmul (``ΔŴ @ Σ̃[:, blk]``); the strictly-sequential intra-block sweep
  touches only a (q_tile × B) weight tile and a (B × B) Σ̃ tile — VMEM
  resident on TPU, where :mod:`repro.kernels.quantease_cd` implements it as a
  Pallas kernel.  The XLA fallback below is bit-equivalent (same update
  order ⇒ same iterates, Algorithm 1 ≡ Algorithm 2 ≡ blocked).

Both support the paper's "every third iteration unquantized" heuristic
(§3.2 Initialization) and initialization from any Ŵ (e.g. GPTQ's output,
§3.1 last paragraph).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.calib import damp_sigma
from repro.quant import GridSpec, compute_grid
from repro.quant.grid import Grid

__all__ = [
    "QuantEaseConfig",
    "quantease_quantize",
    "quantease_reference",
    "layer_objective",
    "relative_error",
]


@dataclasses.dataclass(frozen=True)
class QuantEaseConfig:
    """Hyper-parameters of the CD solver (paper defaults)."""

    iterations: int = 25  # paper §5.1: 25 strikes the accuracy/runtime balance
    block_size: int = 256  # column block B for the two-level sweep
    percdamp: float = 0.01  # Σ damping (same role as in GPTQ)
    unquantized_heuristic: bool = True  # every 3rd iteration keeps β̃ raw
    use_kernel: str = "auto"  # "auto" | "pallas" | "xla"


def layer_objective(w: jax.Array, w_hat: jax.Array, sigma: jax.Array) -> jax.Array:
    """f(Ŵ) = ‖WX − ŴX‖²_F = Tr((W−Ŵ) Σ (W−Ŵ)ᵀ).

    Accepts leading batch dims (w: (..., q, p), sigma: (..., p, p)) and
    reduces per-matrix — the grouped solver scores a whole vmap batch at
    once.
    """
    e = (w - w_hat).astype(jnp.float32)
    return jnp.einsum("...ij,...jk,...ik->...", e, sigma.astype(jnp.float32), e)


def relative_error(w: jax.Array, w_hat: jax.Array, sigma: jax.Array) -> jax.Array:
    """Error(Ŵ) = ‖WX−ŴX‖²_F / ‖WX‖²_F (paper §3.4 / Fig. 2 metric).

    Batched like :func:`layer_objective`."""
    w = w.astype(jnp.float32)
    denom = jnp.einsum("...ij,...jk,...ik->...", w, sigma.astype(jnp.float32), w)
    return layer_objective(w, w_hat, sigma) / jnp.clip(denom, 1e-30, None)


def _prep(w, sigma, spec, percdamp, grid: Optional[Grid]):
    q, p = w.shape
    w = w.astype(jnp.float32)
    sigma = damp_sigma(sigma.astype(jnp.float32), percdamp)
    if grid is None:
        grid = compute_grid(w, spec)
    scale_pc, zero_pc = grid.per_column(p)  # (q, p)
    diag = jnp.diag(sigma)
    sig_norm = sigma / diag[None, :]  # column-normalized, diag = 1
    sig_tilde = sig_norm - jnp.eye(p, dtype=jnp.float32)  # zero diag
    pmat = w @ sig_norm  # P = WΣ^norm (full diag — see Alg. 2 ordering)
    return w, sigma, scale_pc, zero_pc, sig_tilde, pmat, grid


def _quant_cols(x, scale, zero, n_levels):
    codes = jnp.clip(jnp.round(x / scale) + zero, 0, n_levels - 1)
    return (codes - zero) * scale


# ---------------------------------------------------------------------------
# Reference: Algorithm 1 (rank-1 maintenance), the oracle.
# ---------------------------------------------------------------------------


def quantease_reference(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    iterations: int = 3,
    percdamp: float = 0.01,
    unquantized_heuristic: bool = False,
    w_init: Optional[jax.Array] = None,
) -> jax.Array:
    """Algorithm 1, column-at-a-time with rank-1 ŴΣ updates.  Slow; tests only."""
    q, p = w.shape
    w32, sigma, scale_pc, zero_pc, _, _, spec_grid = _prep(
        w, sigma, spec, percdamp, None
    )
    n_levels = spec.n_levels
    w_hat = w32 if w_init is None else w_init.astype(jnp.float32)
    wsig = w32 @ sigma  # (WΣ), fixed
    what_sig = w_hat @ sigma  # maintained by rank-1 updates
    diag = jnp.diag(sigma)

    def col_update(carry, j, quantize):
        w_hat, what_sig = carry
        wcol = jax.lax.dynamic_slice(w_hat, (0, j), (q, 1))[:, 0]
        ws_col = jax.lax.dynamic_slice(what_sig, (0, j), (q, 1))[:, 0]
        wsig_col = jax.lax.dynamic_slice(wsig, (0, j), (q, 1))[:, 0]
        sjj = diag[j]
        # β̃ = −[ (ŴΣ)_{:,j} − Σ_jj Ŵ_{:,j} − (WΣ)_{:,j} ] / Σ_jj
        beta = -(ws_col - sjj * wcol - wsig_col) / sjj
        sc = jax.lax.dynamic_slice(scale_pc, (0, j), (q, 1))[:, 0]
        zc = jax.lax.dynamic_slice(zero_pc, (0, j), (q, 1))[:, 0]
        new = _quant_cols(beta, sc, zc, n_levels) if quantize else beta
        # Rank-1 update of ŴΣ (Eq. 12).
        sig_row = sigma[j]  # (p,)
        what_sig = what_sig + jnp.outer(new - wcol, sig_row)
        w_hat = jax.lax.dynamic_update_slice(w_hat, new[:, None], (0, j))
        return (w_hat, what_sig), None

    for it in range(iterations):
        quantize = not (
            unquantized_heuristic and (it + 1) % 3 == 0 and it != iterations - 1
        )
        step = functools.partial(col_update, quantize=quantize)
        (w_hat, what_sig), _ = jax.lax.scan(step, (w_hat, what_sig), jnp.arange(p))
    return w_hat


# ---------------------------------------------------------------------------
# Production: blocked Algorithm 2.
# ---------------------------------------------------------------------------


def _xla_block_sweep(beta0, sig_blk, w_old_blk, scale_blk, zero_blk, n_levels, quantize):
    """Sequential CD sweep inside one column block (XLA fallback).

    beta0:  (q, B) = P_blk − P̂_blk + (cross-block ΔŴ correction)
    sig_blk: (B, B) Σ̃ block (zero diag)
    Returns (w_new_blk, delta_blk) with delta = old − new.
    """
    q, bsz = beta0.shape

    def col(carry, i):
        delta_blk = carry
        # Intra-block correction: ΔŴ_blk (zero in cols ≥ i) @ Σ̃_blk[:, i].
        corr = delta_blk @ jax.lax.dynamic_slice(sig_blk, (0, i), (bsz, 1))[:, 0]
        beta = jax.lax.dynamic_slice(beta0, (0, i), (q, 1))[:, 0] + corr
        if quantize:
            sc = jax.lax.dynamic_slice(scale_blk, (0, i), (q, 1))[:, 0]
            zc = jax.lax.dynamic_slice(zero_blk, (0, i), (q, 1))[:, 0]
            new = _quant_cols(beta, sc, zc, n_levels)
        else:
            new = beta
        old = jax.lax.dynamic_slice(w_old_blk, (0, i), (q, 1))[:, 0]
        delta_blk = jax.lax.dynamic_update_slice(
            delta_blk, (old - new)[:, None], (0, i)
        )
        return delta_blk, new

    delta_blk, new_cols = jax.lax.scan(
        col, jnp.zeros((q, bsz), jnp.float32), jnp.arange(bsz)
    )
    return new_cols.T, delta_blk  # scan stacks (B, q) → transpose


def _block_sweep(beta0, sig_blk, w_old_blk, scale_blk, zero_blk, n_levels, quantize, use_kernel):
    if use_kernel == "xla":
        return _xla_block_sweep(
            beta0, sig_blk, w_old_blk, scale_blk, zero_blk, n_levels, quantize
        )
    # Pallas path (TPU, or interpret-mode on CPU when forced).
    from repro.kernels import ops as kops

    return kops.quantease_block_sweep(
        beta0,
        sig_blk,
        w_old_blk,
        scale_blk,
        zero_blk,
        n_levels=n_levels,
        quantize=quantize,
        interpret=(use_kernel != "pallas_hw"),
    )


@functools.partial(
    jax.jit,
    static_argnames=("spec", "iterations", "block_size", "unquantized_heuristic", "use_kernel"),
)
def quantease_quantize(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    iterations: int = 25,
    block_size: int = 256,
    percdamp: float = 0.01,
    unquantized_heuristic: bool = True,
    w_init: Optional[jax.Array] = None,
    grid: Optional[Grid] = None,
    use_kernel: str = "xla",
) -> tuple[jax.Array, jax.Array]:
    """Blocked Algorithm 2.  Returns (Ŵ fp32, per-iteration damped objective).

    The objective history (length ``iterations``) is evaluated *after* each
    iteration against the damped Σ; from the first fully-quantized iterate
    onward it is non-increasing on quantized iterations (Lemma 2) — this is
    asserted by tests/test_property.py.

    **Batched:** ``w: (G, q, p)`` with ``sigma: (G, p, p)`` solves G
    independent layers in one vmapped call — the whole-model solver groups
    same-shape linears of a block (and all E experts of an MoE matrix) this
    way; ``_prep``/``iteration`` and the Pallas sweep all carry the leading
    dim.  Returns (Ŵ (G, q, p), objectives (G, iterations)).  ``grid`` must
    be None on the batched path (per-layer grids are computed inside).
    """
    if w.ndim == 3:
        if grid is not None:
            raise ValueError("explicit grid unsupported on the batched path")
        solve = functools.partial(
            _quantease_2d,
            spec=spec,
            iterations=iterations,
            block_size=block_size,
            percdamp=percdamp,
            unquantized_heuristic=unquantized_heuristic,
            grid=None,
            use_kernel=use_kernel,
        )
        if w_init is None:
            return jax.vmap(lambda wi, si: solve(wi, si, w_init=None))(w, sigma)
        return jax.vmap(lambda wi, si, ii: solve(wi, si, w_init=ii))(w, sigma, w_init)
    return _quantease_2d(
        w,
        sigma,
        spec=spec,
        iterations=iterations,
        block_size=block_size,
        percdamp=percdamp,
        unquantized_heuristic=unquantized_heuristic,
        w_init=w_init,
        grid=grid,
        use_kernel=use_kernel,
    )


def _quantease_2d(
    w: jax.Array,
    sigma: jax.Array,
    *,
    spec: GridSpec,
    iterations: int,
    block_size: int,
    percdamp: float,
    unquantized_heuristic: bool,
    w_init: Optional[jax.Array],
    grid: Optional[Grid],
    use_kernel: str,
) -> tuple[jax.Array, jax.Array]:
    q, p = w.shape
    w32, sigma_d, scale_pc, zero_pc, sig_tilde, pmat, _ = _prep(
        w, sigma, spec, percdamp, grid
    )
    n_levels = spec.n_levels
    w_hat = w32 if w_init is None else w_init.astype(jnp.float32)

    bsz = min(block_size, p)
    n_blocks = -(-p // bsz)
    pad = n_blocks * bsz - p
    if pad:
        # Padded columns: zero Σ̃ coupling, unit scale ⇒ they quantize to an
        # isolated 0 and never influence real columns.
        w32 = jnp.pad(w32, ((0, 0), (0, pad)))
        w_hat = jnp.pad(w_hat, ((0, 0), (0, pad)))
        scale_pc = jnp.pad(scale_pc, ((0, 0), (0, pad)), constant_values=1.0)
        zero_pc = jnp.pad(zero_pc, ((0, 0), (0, pad)))
        sig_tilde = jnp.pad(sig_tilde, ((0, pad), (0, pad)))
        pmat = jnp.pad(pmat, ((0, 0), (0, pad)))
    p_pad = p + pad

    def iteration(w_hat, quantize):
        p_hat = w_hat @ sig_tilde  # P̂ (zero-diag Σ̃) — one qp² matmul
        base = pmat - p_hat

        def block(carry, b):
            w_new, delta = carry  # delta: (q, p_pad), old−new, zero if unprocessed
            col0 = b * bsz
            # Cross-block correction: ΔŴ @ Σ̃[:, blk].  Unprocessed columns of
            # ΔŴ are zero, so the full matmul is exact.
            sig_cols = jax.lax.dynamic_slice(sig_tilde, (0, col0), (p_pad, bsz))
            beta0 = (
                jax.lax.dynamic_slice(base, (0, col0), (q, bsz)) + delta @ sig_cols
            )
            sig_blk = jax.lax.dynamic_slice(sig_tilde, (col0, col0), (bsz, bsz))
            w_old_blk = jax.lax.dynamic_slice(w_hat, (0, col0), (q, bsz))
            s_blk = jax.lax.dynamic_slice(scale_pc, (0, col0), (q, bsz))
            z_blk = jax.lax.dynamic_slice(zero_pc, (0, col0), (q, bsz))
            new_blk, delta_blk = _block_sweep(
                beta0, sig_blk, w_old_blk, s_blk, z_blk, n_levels, quantize, use_kernel
            )
            w_new = jax.lax.dynamic_update_slice(w_new, new_blk, (0, col0))
            delta = jax.lax.dynamic_update_slice(delta, delta_blk, (0, col0))
            return (w_new, delta), None

        (w_new, _), _ = jax.lax.scan(
            block, (w_hat, jnp.zeros((q, p_pad), jnp.float32)), jnp.arange(n_blocks)
        )
        return w_new

    sigma_pad = jnp.pad(sigma_d, ((0, pad), (0, pad))) if pad else sigma_d
    objs = []
    for it in range(iterations):
        quantize = not (
            unquantized_heuristic and (it + 1) % 3 == 0 and it != iterations - 1
        )
        w_hat = iteration(w_hat, quantize)
        e = w32 - w_hat
        objs.append(jnp.einsum("ij,jk,ik->", e, sigma_pad, e))
    return w_hat[:, :p], jnp.stack(objs)
