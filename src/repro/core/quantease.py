"""QuantEase — cyclic coordinate descent layer-wise quantization (the paper).

Math (Lemma 1):  with Σ = XXᵀ, the optimal quantized value of coordinate
(i, j), all others fixed, is ``q_i(β̃)`` where::

    β̃ = −[ Σ_{k≠j} Σ_{j,k} Ŵ_{i,k} − (WΣ)_{i,j} ] / Σ_{j,j}

Updates are applied one *column* at a time (rows are independent given j).

Three implementations:

* :func:`quantease_reference` — Algorithm 1 verbatim (rank-1 maintenance of
  ŴΣ).  O(p²q) per iteration with p sequential HBM-bound steps; used as the
  numerical oracle in tests.
* ``engine="legacy"`` — the pre-fused production path: Algorithm 2's
  "accelerated partial updates" (Eq. 13) restructured into column blocks,
  with a full ``Ŵ @ Σ̃`` recompute per iteration plus full-width ``Δ @ Σ̃``
  cross-block corrections.  ~2·qp² matmul FLOPs per iteration (3·qp² with
  the objective history).  Kept as the baseline for BENCH_solver.json and
  the equivalence tests.
* ``engine="fused"`` (default) — the **fused-iteration engine**
  (DESIGN.md §Fused-iteration): ``base = P − P̂`` is maintained
  *incrementally* across iterations via a rolling Δ buffer, so each block's
  single full-width correction matmul simultaneously (a) applies the
  triangular prefix of the *current* iteration's Δ and (b) amortises the
  previous iteration's Δ over ``base`` — one qp² matmul per iteration
  total, a 2× FLOP cut.  The correction matmuls optionally run with bf16
  operands and fp32 accumulation (``matmul_dtype="bfloat16"``); the
  β/quantize path stays fp32.  On TPU the whole iteration is a single
  Pallas kernel (:mod:`repro.kernels.quantease_cd`), grid
  ``(q-tiles × blocks)`` with the Δ accumulator resident in VMEM scratch
  across block steps; the XLA fallback is restructured to match (same
  update order ⇒ same iterates up to fp reassociation).

All paths support the paper's "every third iteration unquantized" heuristic
(§3.2 Initialization) and initialization from any Ŵ (e.g. GPTQ's output,
§3.1 last paragraph).  The per-iteration objective history costs an extra
qp² einsum per iteration and is **opt-in** (``track_objective=True``).

The outlier-aware solver (:mod:`repro.core.outlier`, DESIGN.md
§Outlier-aware-fused) builds its Algorithm-3 loop on the same
``base = P − P̂`` / rolling-Δ invariant, sharing it across the Ŵ-block/
Ĥ-block boundary instead of re-entering :func:`quantease_quantize` per
outer iteration.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calib import damp_sigma
from repro.quant import GridSpec, compute_grid
from repro.quant.grid import Grid

__all__ = [
    "QuantEaseConfig",
    "quantease_quantize",
    "quantease_reference",
    "layer_objective",
    "relative_error",
]


@dataclasses.dataclass(frozen=True)
class QuantEaseConfig:
    """Hyper-parameters of the CD solver (paper defaults).

    ``use_kernel`` selects the execution engine: ``"auto"`` resolves to the
    compiled Pallas kernel on TPU and pure XLA elsewhere; ``"pallas"``
    forces Pallas interpret mode (tests), ``"pallas_hw"`` compiled Mosaic,
    ``"xla"`` the jnp fallback.  ``matmul_dtype`` applies to the Σ̃
    correction matmuls only (fp32 accumulation; the β/quantize path is
    always fp32).  The whole-model solver threads this config through
    :func:`quantease_quantize` via :meth:`solve_kwargs`.
    """

    iterations: int = 25  # paper §5.1: 25 strikes the accuracy/runtime balance
    block_size: int = 256  # column block B for the two-level sweep
    percdamp: float = 0.01  # Σ damping (same role as in GPTQ)
    unquantized_heuristic: bool = True  # every 3rd iteration keeps β̃ raw
    use_kernel: str = "auto"  # "auto" | "pallas" | "pallas_hw" | "xla"
    matmul_dtype: str = "float32"  # "float32" | "bfloat16" — Σ̃ corrections
    track_objective: bool = False  # per-iteration objective history (qp²/iter)
    engine: str = "fused"  # "fused" | "legacy"

    def solve_kwargs(self) -> dict:
        """Keyword arguments for :func:`quantease_quantize`."""
        return dict(
            iterations=self.iterations,
            block_size=self.block_size,
            percdamp=self.percdamp,
            unquantized_heuristic=self.unquantized_heuristic,
            use_kernel=self.use_kernel,
            matmul_dtype=self.matmul_dtype,
            track_objective=self.track_objective,
            engine=self.engine,
        )


def _resolve_use_kernel(use_kernel: str) -> str:
    if use_kernel == "auto":
        from repro.kernels import ops as kops

        return "pallas_hw" if kops.on_tpu() else "xla"
    if use_kernel not in ("pallas", "pallas_hw", "xla"):
        raise ValueError(f"unknown use_kernel {use_kernel!r}")
    return use_kernel


def layer_objective(w: jax.Array, w_hat: jax.Array, sigma: jax.Array) -> jax.Array:
    """f(Ŵ) = ‖WX − ŴX‖²_F = Tr((W−Ŵ) Σ (W−Ŵ)ᵀ).

    Accepts leading batch dims (w: (..., q, p), sigma: (..., p, p)) and
    reduces per-matrix — the grouped solver scores a whole vmap batch at
    once.
    """
    e = (w - w_hat).astype(jnp.float32)
    return jnp.einsum("...ij,...jk,...ik->...", e, sigma.astype(jnp.float32), e)


def relative_error(w: jax.Array, w_hat: jax.Array, sigma: jax.Array) -> jax.Array:
    """Error(Ŵ) = ‖WX−ŴX‖²_F / ‖WX‖²_F (paper §3.4 / Fig. 2 metric).

    Batched like :func:`layer_objective`."""
    w = w.astype(jnp.float32)
    denom = jnp.einsum("...ij,...jk,...ik->...", w, sigma.astype(jnp.float32), w)
    return layer_objective(w, w_hat, sigma) / jnp.clip(denom, 1e-30, None)


def _prep(w, sigma, spec, percdamp, grid: Optional[Grid]):
    q, p = w.shape
    w = w.astype(jnp.float32)
    sigma = damp_sigma(sigma.astype(jnp.float32), percdamp)
    if grid is None:
        grid = compute_grid(w, spec)
    scale_pc, zero_pc = grid.per_column(p)  # (q, p)
    diag = jnp.diag(sigma)
    sig_norm = sigma / diag[None, :]  # column-normalized, diag = 1
    sig_tilde = sig_norm - jnp.eye(p, dtype=jnp.float32)  # zero diag
    pmat = w @ sig_norm  # P = WΣ^norm (full diag — see Alg. 2 ordering)
    return w, sigma, scale_pc, zero_pc, sig_tilde, pmat, grid


def _quant_cols(x, scale, zero, n_levels):
    codes = jnp.clip(jnp.round(x / scale) + zero, 0, n_levels - 1)
    return (codes - zero) * scale


# ---------------------------------------------------------------------------
# Reference: Algorithm 1 (rank-1 maintenance), the oracle.
# ---------------------------------------------------------------------------


def quantease_reference(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    iterations: int = 3,
    percdamp: float = 0.01,
    unquantized_heuristic: bool = False,
    w_init: Optional[jax.Array] = None,
) -> jax.Array:
    """Algorithm 1, column-at-a-time with rank-1 ŴΣ updates.  Slow; tests only."""
    q, p = w.shape
    w32, sigma, scale_pc, zero_pc, _, _, spec_grid = _prep(
        w, sigma, spec, percdamp, None
    )
    n_levels = spec.n_levels
    w_hat = w32 if w_init is None else w_init.astype(jnp.float32)
    wsig = w32 @ sigma  # (WΣ), fixed
    what_sig = w_hat @ sigma  # maintained by rank-1 updates
    diag = jnp.diag(sigma)

    def col_update(carry, j, quantize):
        w_hat, what_sig = carry
        wcol = jax.lax.dynamic_slice(w_hat, (0, j), (q, 1))[:, 0]
        ws_col = jax.lax.dynamic_slice(what_sig, (0, j), (q, 1))[:, 0]
        wsig_col = jax.lax.dynamic_slice(wsig, (0, j), (q, 1))[:, 0]
        sjj = diag[j]
        # β̃ = −[ (ŴΣ)_{:,j} − Σ_jj Ŵ_{:,j} − (WΣ)_{:,j} ] / Σ_jj
        beta = -(ws_col - sjj * wcol - wsig_col) / sjj
        sc = jax.lax.dynamic_slice(scale_pc, (0, j), (q, 1))[:, 0]
        zc = jax.lax.dynamic_slice(zero_pc, (0, j), (q, 1))[:, 0]
        new = _quant_cols(beta, sc, zc, n_levels) if quantize else beta
        # Rank-1 update of ŴΣ (Eq. 12).
        sig_row = sigma[j]  # (p,)
        what_sig = what_sig + jnp.outer(new - wcol, sig_row)
        w_hat = jax.lax.dynamic_update_slice(w_hat, new[:, None], (0, j))
        return (w_hat, what_sig), None

    for it in range(iterations):
        quantize = not (
            unquantized_heuristic and (it + 1) % 3 == 0 and it != iterations - 1
        )
        step = functools.partial(col_update, quantize=quantize)
        (w_hat, what_sig), _ = jax.lax.scan(step, (w_hat, what_sig), jnp.arange(p))
    return w_hat


# ---------------------------------------------------------------------------
# Production: blocked Algorithm 2 (legacy + fused engines).
# ---------------------------------------------------------------------------


def _xla_block_sweep(beta0, sig_blk, w_old_blk, scale_blk, zero_blk, n_levels, quantize):
    """Sequential CD sweep inside one column block (XLA fallback).

    beta0:  (q, B) = P_blk − P̂_blk + (cross-block ΔŴ correction)
    sig_blk: (B, B) Σ̃ block (zero diag)
    Returns (w_new_blk, delta_blk) with delta = old − new.
    """
    q, bsz = beta0.shape

    def col(carry, i):
        delta_blk = carry
        # Intra-block correction: ΔŴ_blk (zero in cols ≥ i) @ Σ̃_blk[:, i].
        corr = delta_blk @ jax.lax.dynamic_slice(sig_blk, (0, i), (bsz, 1))[:, 0]
        beta = jax.lax.dynamic_slice(beta0, (0, i), (q, 1))[:, 0] + corr
        if quantize:
            sc = jax.lax.dynamic_slice(scale_blk, (0, i), (q, 1))[:, 0]
            zc = jax.lax.dynamic_slice(zero_blk, (0, i), (q, 1))[:, 0]
            new = _quant_cols(beta, sc, zc, n_levels)
        else:
            new = beta
        old = jax.lax.dynamic_slice(w_old_blk, (0, i), (q, 1))[:, 0]
        delta_blk = jax.lax.dynamic_update_slice(
            delta_blk, (old - new)[:, None], (0, i)
        )
        return delta_blk, new

    delta_blk, new_cols = jax.lax.scan(
        col, jnp.zeros((q, bsz), jnp.float32), jnp.arange(bsz)
    )
    return new_cols.T, delta_blk  # scan stacks (B, q) → transpose


def _block_sweep(beta0, sig_blk, w_old_blk, scale_blk, zero_blk, n_levels, quantize, use_kernel):
    if use_kernel == "xla":
        return _xla_block_sweep(
            beta0, sig_blk, w_old_blk, scale_blk, zero_blk, n_levels, quantize
        )
    # Pallas path (TPU, or interpret-mode on CPU when forced).
    from repro.kernels import ops as kops

    return kops.quantease_block_sweep(
        beta0,
        sig_blk,
        w_old_blk,
        scale_blk,
        zero_blk,
        n_levels=n_levels,
        quantize=quantize,
        interpret=(use_kernel != "pallas_hw"),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "iterations", "block_size", "unquantized_heuristic",
        "use_kernel", "matmul_dtype", "track_objective", "engine",
    ),
)
def quantease_quantize(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    iterations: int = 25,
    block_size: int = 256,
    percdamp: float = 0.01,
    unquantized_heuristic: bool = True,
    w_init: Optional[jax.Array] = None,
    grid: Optional[Grid] = None,
    use_kernel: str = "auto",
    matmul_dtype: str = "float32",
    track_objective: bool = False,
    engine: str = "fused",
) -> tuple[jax.Array, Optional[jax.Array]]:
    """Blocked Algorithm 2.  Returns (Ŵ fp32, objective history or None).

    The objective history is **opt-in** (``track_objective=True`` — it costs
    an extra qp² einsum per iteration): length ``iterations``, evaluated
    *after* each iteration against the damped Σ; from the first
    fully-quantized iterate onward it is non-increasing on quantized
    iterations (Lemma 2) — asserted by tests/test_property.py.  With
    ``track_objective=False`` (the default) the second element is ``None``.

    ``engine="fused"`` (default) runs the fused-iteration engine — one qp²
    correction matmul per iteration via incremental ``base = P − P̂``
    maintenance; ``engine="legacy"`` keeps the pre-fused schedule (full
    ``Ŵ @ Σ̃`` recompute + full-width corrections) for benchmarking and
    equivalence tests.  Both apply updates in the same order, so iterates
    agree up to fp reassociation.

    **Batched:** ``w: (G, q, p)`` with ``sigma: (G, p, p)`` solves G
    independent layers in one vmapped call — the whole-model solver groups
    same-shape linears of a block (and all E experts of an MoE matrix) this
    way; ``_prep``/iteration and the Pallas kernels all carry the leading
    dim.  ``grid``/``w_init`` may be batched too (Grid leaves
    ``(G, q, n_groups)``) — the solver threads its precomputed grids
    through so emitted codes round-trip the solve exactly.
    """
    if w.ndim == 3:
        solve = functools.partial(
            _quantease_2d,
            spec=spec,
            iterations=iterations,
            block_size=block_size,
            percdamp=percdamp,
            unquantized_heuristic=unquantized_heuristic,
            use_kernel=use_kernel,
            matmul_dtype=matmul_dtype,
            track_objective=track_objective,
            engine=engine,
        )
        if w_init is None and grid is None:
            return jax.vmap(lambda wi, si: solve(wi, si, w_init=None, grid=None))(
                w, sigma
            )
        if w_init is None:
            return jax.vmap(lambda wi, si, gi: solve(wi, si, w_init=None, grid=gi))(
                w, sigma, grid
            )
        if grid is None:
            return jax.vmap(lambda wi, si, ii: solve(wi, si, w_init=ii, grid=None))(
                w, sigma, w_init
            )
        return jax.vmap(
            lambda wi, si, ii, gi: solve(wi, si, w_init=ii, grid=gi)
        )(w, sigma, w_init, grid)
    return _quantease_2d(
        w,
        sigma,
        spec=spec,
        iterations=iterations,
        block_size=block_size,
        percdamp=percdamp,
        unquantized_heuristic=unquantized_heuristic,
        w_init=w_init,
        grid=grid,
        use_kernel=use_kernel,
        matmul_dtype=matmul_dtype,
        track_objective=track_objective,
        engine=engine,
    )


def _quantease_2d(
    w: jax.Array,
    sigma: jax.Array,
    *,
    spec: GridSpec,
    iterations: int,
    block_size: int,
    percdamp: float,
    unquantized_heuristic: bool,
    w_init: Optional[jax.Array],
    grid: Optional[Grid],
    use_kernel: str,
    matmul_dtype: str,
    track_objective: bool,
    engine: str,
) -> tuple[jax.Array, Optional[jax.Array]]:
    use_kernel = _resolve_use_kernel(use_kernel)
    if matmul_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unknown matmul_dtype {matmul_dtype!r}")
    q, p = w.shape
    w32, sigma_d, scale_pc, zero_pc, sig_tilde, pmat, _ = _prep(
        w, sigma, spec, percdamp, grid
    )
    n_levels = spec.n_levels
    w_hat = w32 if w_init is None else w_init.astype(jnp.float32)

    bsz = min(block_size, p)
    n_blocks = -(-p // bsz)
    pad = n_blocks * bsz - p
    if pad:
        # Padded columns: zero Σ̃ coupling, unit scale ⇒ they quantize to an
        # isolated 0 and never influence real columns.
        w32 = jnp.pad(w32, ((0, 0), (0, pad)))
        w_hat = jnp.pad(w_hat, ((0, 0), (0, pad)))
        scale_pc = jnp.pad(scale_pc, ((0, 0), (0, pad)), constant_values=1.0)
        zero_pc = jnp.pad(zero_pc, ((0, 0), (0, pad)))
        sig_tilde = jnp.pad(sig_tilde, ((0, pad), (0, pad)))
        pmat = jnp.pad(pmat, ((0, 0), (0, pad)))
    p_pad = p + pad
    cdt = jnp.bfloat16 if matmul_dtype == "bfloat16" else jnp.float32

    quant_flags = [
        not (unquantized_heuristic and (it + 1) % 3 == 0 and it != iterations - 1)
        for it in range(iterations)
    ]

    if engine == "legacy":
        step = _legacy_iteration_step(
            sig_tilde, pmat, scale_pc, zero_pc, n_levels, bsz, n_blocks, use_kernel
        )
        w_hat, objs = _drive(step, w_hat, w32, sigma_d, pad, quant_flags, track_objective)
    elif engine == "fused":
        kernel_fits = True
        if use_kernel != "xla":
            from repro.kernels import ops as kops

            kernel_fits = kops.fused_iteration_tq(p_pad, bsz, matmul_dtype) is not None
        if use_kernel == "xla" or not kernel_fits:
            # XLA schedule — also the fallback when the single-kernel
            # iteration's VMEM-resident slabs (Δ accumulator + Σ̃ᵀ rows)
            # can't fit for very wide layers.  Same update order, same
            # iterates.
            step = _fused_xla_iteration_step(
                sig_tilde, scale_pc, zero_pc, n_levels, bsz, n_blocks, cdt
            )
        else:
            step = _fused_pallas_iteration_step(
                sig_tilde, scale_pc, zero_pc, n_levels, bsz, matmul_dtype,
                interpret=(use_kernel != "pallas_hw"),
            )
        # Incremental-state init: one qp² matmul for base = P − Ŵ₀Σ̃ (fp32
        # regardless of matmul_dtype — one-time cost), rolling Δ = 0.
        base = pmat - w_hat @ sig_tilde
        delta = jnp.zeros_like(base)

        def fused_step(w_hat_and_state, quantize):
            w_cur, base_c, delta_c = w_hat_and_state
            return step(w_cur, base_c, delta_c, quantize)

        sigma_pad = jnp.pad(sigma_d, ((0, pad), (0, pad))) if pad else sigma_d
        state = (w_hat, base, delta)
        objs = []
        for quantize in quant_flags:
            state = fused_step(state, quantize)
            if track_objective:
                e = w32 - state[0]
                objs.append(jnp.einsum("ij,jk,ik->", e, sigma_pad, e))
        w_hat = state[0]
    else:
        raise ValueError(f"unknown engine {engine!r}")

    return w_hat[:, :p], (jnp.stack(objs) if track_objective else None)


def _drive(step, w_hat, w32, sigma_d, pad, quant_flags, track_objective):
    sigma_pad = jnp.pad(sigma_d, ((0, pad), (0, pad))) if pad else sigma_d
    objs = []
    for quantize in quant_flags:
        w_hat = step(w_hat, quantize)
        if track_objective:
            e = w32 - w_hat
            objs.append(jnp.einsum("ij,jk,ik->", e, sigma_pad, e))
    return w_hat, objs


def _legacy_iteration_step(
    sig_tilde, pmat, scale_pc, zero_pc, n_levels, bsz, n_blocks, use_kernel
):
    """Pre-fused schedule: full P̂ recompute + full-width Δ corrections."""
    q = pmat.shape[0]
    p_pad = sig_tilde.shape[0]

    def iteration(w_hat, quantize):
        p_hat = w_hat @ sig_tilde  # P̂ (zero-diag Σ̃) — one qp² matmul
        base = pmat - p_hat

        def block(carry, b):
            w_new, delta = carry  # delta: (q, p_pad), old−new, zero if unprocessed
            col0 = b * bsz
            # Cross-block correction: ΔŴ @ Σ̃[:, blk].  Unprocessed columns of
            # ΔŴ are zero, so the full matmul is exact.
            sig_cols = jax.lax.dynamic_slice(sig_tilde, (0, col0), (p_pad, bsz))
            beta0 = (
                jax.lax.dynamic_slice(base, (0, col0), (q, bsz)) + delta @ sig_cols
            )
            sig_blk = jax.lax.dynamic_slice(sig_tilde, (col0, col0), (bsz, bsz))
            w_old_blk = jax.lax.dynamic_slice(w_hat, (0, col0), (q, bsz))
            s_blk = jax.lax.dynamic_slice(scale_pc, (0, col0), (q, bsz))
            z_blk = jax.lax.dynamic_slice(zero_pc, (0, col0), (q, bsz))
            new_blk, delta_blk = _block_sweep(
                beta0, sig_blk, w_old_blk, s_blk, z_blk, n_levels, quantize, use_kernel
            )
            w_new = jax.lax.dynamic_update_slice(w_new, new_blk, (0, col0))
            delta = jax.lax.dynamic_update_slice(delta, delta_blk, (0, col0))
            return (w_new, delta), None

        (w_new, _), _ = jax.lax.scan(
            block, (w_hat, jnp.zeros((q, p_pad), jnp.float32)), jnp.arange(n_blocks)
        )
        return w_new

    return iteration


def _xla_block_sweep_t(beta0_t, sig_t, w_old_t, scale_t, zero_t, n_levels, quantize):
    """Transposed, xs-fed intra-block sweep (fused-engine XLA path).

    Same update order as :func:`_xla_block_sweep` — identical iterates —
    but every per-column operand arrives as a scan ``xs`` row and the Δ
    accumulator is carried transposed (B, q), so each step is one
    contiguous-row gemv + one contiguous-row store instead of five strided
    (q, 1) column slices.  On CPU XLA this roughly halves the sequential
    sweep's per-column cost (the floor the fused engine's matmul savings
    sit on top of).
    """
    bsz, q = beta0_t.shape

    def col(delta_t, xs):
        i, sig_row, b0, ws, sc, zc = xs
        beta = b0 + sig_row @ delta_t  # Σ̃[:, i] · Δ — rows ≥ i still zero
        if quantize:
            new = (jnp.clip(jnp.round(beta / sc) + zc, 0, n_levels - 1) - zc) * sc
        else:
            new = beta
        delta_t = jax.lax.dynamic_update_slice(delta_t, (ws - new)[None], (i, 0))
        return delta_t, new

    delta_t, new_t = jax.lax.scan(
        col,
        jnp.zeros((bsz, q), jnp.float32),
        (jnp.arange(bsz), sig_t, beta0_t, w_old_t, scale_t, zero_t),
    )
    return new_t, delta_t  # both (B, q)


def _fused_xla_iteration_step(
    sig_tilde, scale_pc, zero_pc, n_levels, bsz, n_blocks, cdt
):
    """Fused engine, XLA path: rolling-Δ incremental base maintenance.

    The rolling Δ buffer holds, when block b is processed, the *current*
    iteration's Δ for blocks < b (triangular prefix) and the *previous*
    iteration's Δ for blocks ≥ b — so one full-width correction matmul per
    block both applies the triangular correction and amortises the
    incremental ``base = P − P̂`` update.  qp² FLOPs per iteration total
    (the legacy schedule pays 2·qp² plus another qp² for its always-on
    objective).  ``cdt`` casts the correction operands (bf16 Σ̃ option);
    accumulation and the sweep stay fp32.

    Per-block operands are pre-stacked once and fed through scan ``xs``;
    per-block results come back as stacked ``ys`` (blocks partition the
    columns, so reassembly is a transpose+reshape) — the only carry is the
    rolling Δ, which each block's correction genuinely reads in full.
    """
    q = scale_pc.shape[0]
    p_pad = sig_tilde.shape[0]

    def stack_cols(a):  # (q, p_pad) → (n_blocks, B, q): block-major, transposed
        return a.reshape(q, n_blocks, bsz).transpose(1, 2, 0)

    # Σ̃ᵀ split row-blocks: slab b = Σ̃[:, blk_b]ᵀ, and its cols [blk_b] are
    # the transposed diagonal block the intra-sweep needs.
    sig_rows = sig_tilde.T.reshape(n_blocks, bsz, p_pad)
    sig_rows_c = sig_rows.astype(cdt)
    sig_diag_t = jnp.stack(
        [sig_rows[b, :, b * bsz : (b + 1) * bsz] for b in range(n_blocks)]
    )  # (n_blocks, B, B), row i = Σ̃_blk[:, i]
    scale_t = stack_cols(scale_pc)
    zero_t = stack_cols(zero_pc)

    def unstack(ys_t):  # (n_blocks, B, q) → (q, p_pad)
        return ys_t.transpose(2, 0, 1).reshape(q, p_pad)

    def iteration(w_hat, base, delta, quantize):
        base_b = stack_cols(base)
        w_old_b = stack_cols(w_hat)

        def block(delta_ct, xs):
            b, sg_rows, sg_t, base_t, w_old_t, s_t, z_t = xs
            corr = jnp.dot(
                sg_rows, delta_ct.astype(cdt), preferred_element_type=jnp.float32
            )  # (B, q) — full-width rolling-Δ correction, transposed
            beta0_t = base_t + corr
            # beta0 is exactly P_blk − (Ŵ entering this block) Σ̃ — it is
            # this block's base invariant for the *next* iteration.
            new_t, delta_t = _xla_block_sweep_t(
                beta0_t, sg_t, w_old_t, s_t, z_t, n_levels, quantize
            )
            delta_ct = jax.lax.dynamic_update_slice(delta_ct, delta_t, (b * bsz, 0))
            return delta_ct, (new_t, beta0_t, delta_t)

        _, (new_b, beta0_b, delta_b) = jax.lax.scan(
            block,
            delta.T,  # rolling Δ carried transposed (p_pad, q): contiguous updates
            (jnp.arange(n_blocks), sig_rows_c, sig_diag_t, base_b, w_old_b,
             scale_t, zero_t),
        )
        return unstack(new_b), unstack(beta0_b), unstack(delta_b)

    return iteration


def _fused_pallas_iteration_step(
    sig_tilde, scale_pc, zero_pc, n_levels, bsz, matmul_dtype, interpret
):
    """Fused engine, Pallas path: one kernel launch per iteration."""
    from repro.kernels import ops as kops

    def iteration(w_hat, base, delta, quantize):
        return kops.quantease_fused_iteration(
            base,
            sig_tilde,
            w_hat,
            scale_pc,
            zero_pc,
            delta,
            n_levels=n_levels,
            quantize=quantize,
            bsz=bsz,
            matmul_dtype=matmul_dtype,
            interpret=interpret,
        )

    return iteration
