"""QuantEase core: the paper's layer-wise PTQ algorithms + baselines."""

from repro.core.calib import CalibStats, damp_sigma, gram
from repro.core.quantease import (
    QuantEaseConfig,
    quantease_quantize,
    quantease_reference,
    layer_objective,
    relative_error,
)
from repro.core.outlier import OutlierResult, outlier_quantease, top_s_mask
from repro.core.rtn import rtn_quantize
from repro.core.gptq import gptq_quantize, obs_sensitivity
from repro.core.awq import awq_quantize
from repro.core.spqr import spqr_quantize

__all__ = [
    "CalibStats",
    "damp_sigma",
    "gram",
    "QuantEaseConfig",
    "quantease_quantize",
    "quantease_reference",
    "layer_objective",
    "relative_error",
    "OutlierResult",
    "outlier_quantease",
    "top_s_mask",
    "rtn_quantize",
    "gptq_quantize",
    "obs_sensitivity",
    "awq_quantize",
    "spqr_quantize",
]
