"""GPTQ baseline (Frantar et al., 2023) — OBS column sweep with lazy batching.

Implements the reference algorithm faithfully (§2.2.1 of the QuantEase paper):
one pass over columns j = 1..p; quantize column j, then propagate the OBS
correction to the not-yet-quantized columns using the upper-Cholesky factor of
``H⁻¹`` (H = damped Σ).  Corrections inside the active block of size
``block_size`` are applied column-by-column; corrections to the remaining
columns are batched into one matmul per block ("lazy batch", the trick that
makes GPTQ fast — and the same trick our blocked QuantEase kernel reuses).

This is the component QuantEase's experiments initialize-from / compare-to,
and it is *required infrastructure* for the SpQR baseline (sensitivities are
OBS saliencies computed from the same Cholesky factor).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calib import damp_sigma
from repro.quant import GridSpec, compute_grid
from repro.quant.grid import Grid

__all__ = ["gptq_quantize", "obs_sensitivity"]


def _quant_dequant_cols(w_cols: jax.Array, scale: jax.Array, zero: jax.Array, n_levels: int):
    codes = jnp.clip(jnp.round(w_cols / scale) + zero, 0, n_levels - 1)
    return (codes - zero) * scale


def _cholesky_inv_upper(h: jax.Array) -> jax.Array:
    """Upper-triangular U with H⁻¹ = Uᵀ U (GPTQ's factor)."""
    hinv = jnp.linalg.inv(h)
    # jnp.linalg.cholesky returns lower L with Hinv = L Lᵀ = (Lᵀ)ᵀ (Lᵀ).
    return jnp.linalg.cholesky(hinv).T


@functools.partial(jax.jit, static_argnames=("spec", "block_size", "act_order"))
def gptq_quantize(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    percdamp: float = 0.01,
    block_size: int = 128,
    act_order: bool = False,
    keep_mask: Optional[jax.Array] = None,
    grid: Optional[Grid] = None,
) -> jax.Array:
    """Quantize W: (q, p) against Σ: (p, p).  Returns dequantized Ŵ (fp32).

    ``keep_mask``: optional (q, p) bool — True entries are *kept at full
    precision* (used by the SpQR baseline's outliers); they still absorb OBS
    corrections but are never rounded.
    ``grid``: optional explicit grid (e.g. SpQR's outlier-shrunk ranges).

    **Batched:** ``w: (G, q, p)`` / ``sigma: (G, p, p)`` solves G layers in
    one vmapped call (grouped-block solver; ``grid`` may be batched too —
    Grid leaves ``(G, q, n_groups)`` — so the whole-model solver can thread
    its precomputed grids through; ``keep_mask`` must be None on this
    path).
    """
    if w.ndim == 3:
        if keep_mask is not None:
            raise ValueError("keep_mask unsupported on the batched path")
        solve = functools.partial(
            _gptq_2d,
            spec=spec,
            percdamp=percdamp,
            block_size=block_size,
            act_order=act_order,
            keep_mask=None,
        )
        if grid is None:
            return jax.vmap(lambda wi, si: solve(wi, si, grid=None))(w, sigma)
        return jax.vmap(lambda wi, si, gi: solve(wi, si, grid=gi))(w, sigma, grid)
    return _gptq_2d(
        w, sigma, spec=spec, percdamp=percdamp, block_size=block_size,
        act_order=act_order, keep_mask=keep_mask, grid=grid,
    )


def _gptq_2d(
    w: jax.Array,
    sigma: jax.Array,
    *,
    spec: GridSpec,
    percdamp: float,
    block_size: int,
    act_order: bool,
    keep_mask: Optional[jax.Array],
    grid: Optional[Grid],
) -> jax.Array:
    q, p = w.shape
    w = w.astype(jnp.float32)
    sigma = damp_sigma(sigma.astype(jnp.float32), percdamp)

    perm = None
    if act_order:
        perm = jnp.argsort(-jnp.diag(sigma))
        w = w[:, perm]
        sigma = sigma[perm][:, perm]
        if keep_mask is not None:
            keep_mask = keep_mask[:, perm]

    if grid is None:
        grid = compute_grid(w, spec)  # from (possibly permuted) w: aligned
        scale_pc, zero_pc = grid.per_column(p)  # (q, p)
    else:
        scale_pc, zero_pc = grid.per_column(p)  # original column order
        if act_order:
            scale_pc, zero_pc = scale_pc[:, perm], zero_pc[:, perm]
    n_levels = spec.n_levels
    u = _cholesky_inv_upper(sigma)  # (p, p) upper
    if keep_mask is None:
        keep_mask = jnp.zeros((q, p), jnp.bool_)

    n_blocks = -(-p // block_size)
    pad = n_blocks * block_size - p
    if pad:
        # Pad with identity-ish tail: extra columns have zero weight, unit diag.
        w = jnp.pad(w, ((0, 0), (0, pad)))
        scale_pc = jnp.pad(scale_pc, ((0, 0), (0, pad)), constant_values=1.0)
        zero_pc = jnp.pad(zero_pc, ((0, 0), (0, pad)))
        keep_mask = jnp.pad(keep_mask, ((0, 0), (0, pad)))
        u = jnp.pad(u, ((0, pad), (0, pad)))
        u = u.at[jnp.arange(p, p + pad), jnp.arange(p, p + pad)].set(1.0)
    p_pad = p + pad
    bsz = block_size

    def block_step(wb, b):
        """Process columns [b*bsz, (b+1)*bsz)."""
        col0 = b * bsz
        w_blk = jax.lax.dynamic_slice(wb, (0, col0), (q, bsz))
        s_blk = jax.lax.dynamic_slice(scale_pc, (0, col0), (q, bsz))
        z_blk = jax.lax.dynamic_slice(zero_pc, (0, col0), (q, bsz))
        k_blk = jax.lax.dynamic_slice(keep_mask, (0, col0), (q, bsz))
        u_blk = jax.lax.dynamic_slice(u, (col0, col0), (bsz, bsz))

        def col_step(carry, i):
            w_blk, err_blk = carry
            wc = jax.lax.dynamic_slice(w_blk, (0, i), (q, 1))[:, 0]
            sc = jax.lax.dynamic_slice(s_blk, (0, i), (q, 1))[:, 0]
            zc = jax.lax.dynamic_slice(z_blk, (0, i), (q, 1))[:, 0]
            kc = jax.lax.dynamic_slice(k_blk, (0, i), (q, 1))[:, 0]
            qc = jnp.where(kc, wc, _quant_dequant_cols(wc, sc, zc, n_levels))
            d = u_blk[i, i]
            err = (wc - qc) / d
            # Propagate inside the block (columns > i; row i of U is zero
            # left of the diagonal so a full-row update is safe, but we must
            # not touch already-quantized cols — mask by position.
            row = u_blk[i]  # (bsz,)
            pos_mask = (jnp.arange(bsz) > i).astype(w_blk.dtype)
            w_blk = w_blk - jnp.outer(err, row * pos_mask)
            w_blk = jax.lax.dynamic_update_slice(w_blk, qc[:, None], (0, i))
            err_blk = jax.lax.dynamic_update_slice(err_blk, err[:, None], (0, i))
            return (w_blk, err_blk), None

        (w_blk, err_blk), _ = jax.lax.scan(
            col_step, (w_blk, jnp.zeros((q, bsz), jnp.float32)), jnp.arange(bsz)
        )
        wb = jax.lax.dynamic_update_slice(wb, w_blk, (0, col0))
        # Lazy-batch correction of all trailing columns: one matmul.
        u_rest = jax.lax.dynamic_slice(u, (col0, 0), (bsz, p_pad))
        tail_mask = (jnp.arange(p_pad) >= col0 + bsz).astype(wb.dtype)
        wb = wb - (err_blk @ u_rest) * tail_mask[None, :]
        return wb, None

    w_out, _ = jax.lax.scan(block_step, w, jnp.arange(n_blocks))
    w_out = w_out[:, :p]
    if act_order:
        inv = jnp.argsort(perm)
        w_out = w_out[:, inv]
    return w_out


@functools.partial(jax.jit, static_argnames=())
def obs_sensitivity(w: jax.Array, sigma: jax.Array, w_rtn: jax.Array, *, percdamp: float = 0.01) -> jax.Array:
    """OBS saliency ω_{ij} = (W_{ij} − q(W_{ij}))² / [H⁻¹]_{jj} (SpQR Eq. 15)."""
    sigma = damp_sigma(sigma.astype(jnp.float32), percdamp)
    hinv_diag = jnp.diag(jnp.linalg.inv(sigma))  # (p,)
    return (w.astype(jnp.float32) - w_rtn) ** 2 / hinv_diag[None, :]
