"""Outlier-aware QuantEase (paper §4, Algorithm 3).

Solves  min ‖WX − (Ŵ+Ĥ)X‖²  s.t.  Ŵ on-grid, ‖Ĥ‖₀ ≤ s
by block coordinate descent:

  * Ŵ-block: one cyclic-CD sweep of QuantEase on the surrogate target
    ``W − Ĥ`` (identical math, WΣ ← (W−Ĥ)Σ),
  * Ĥ-block: one iterative-hard-thresholding (IHT) step
    ``Ĥ ← P_s(Ĥ − η ∇_H g)`` with ``η = 1/(2 λ_max(Σ))`` (Lemma 3 descent).

Grid-range shrink: the per-channel grids are computed once, from W with the
top-s magnitude entries excluded (§4.3) — outliers live in Ĥ, so the grid
need not cover them.

Structured variant (§4.3 "Structured Outliers"): ``P_s`` selects the
⌊s/q⌋ columns of largest ℓ2 norm instead of the s largest entries.

Initialization: Ĥ = P_s(W), Ŵ = W − Ĥ (infeasible until the first sweep,
like basic QuantEase).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calib import damp_sigma
from repro.core.quantease import quantease_quantize
from repro.quant import GridSpec, compute_grid_excluding_outliers

__all__ = ["OutlierResult", "outlier_quantease", "top_s_mask", "power_lambda_max"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OutlierResult:
    w_hat: jax.Array  # (q, p) quantized part (on-grid, fp32)
    h: jax.Array  # (q, p) dense sparse-correction (‖H‖₀ ≤ s)
    objective: jax.Array  # per-outer-iteration damped objective
    # Range-shrunk grid the CD sweeps quantized against — threaded to the
    # solver's emit path so codes round-trip the solve exactly.
    grid: object = None

    @property
    def w_eff(self) -> jax.Array:
        return self.w_hat + self.h


def power_lambda_max(sigma: jax.Array, iters: int = 64) -> jax.Array:
    """Largest eigenvalue of PSD Σ by power iteration (matrix-vector only —
    the paper's point: no decompositions anywhere in the pipeline)."""
    p = sigma.shape[0]
    v = jnp.ones((p,), jnp.float32) / jnp.sqrt(p)

    def body(_, v):
        v = sigma @ v
        return v / jnp.clip(jnp.linalg.norm(v), 1e-30, None)

    v = jax.lax.fori_loop(0, iters, body, v)
    return v @ (sigma @ v)


def top_s_mask(a: jax.Array, s: int) -> jax.Array:
    """Boolean mask of the s largest |entries| (exact, via top_k on flat)."""
    flat = jnp.abs(a).reshape(-1)
    _, idx = jax.lax.top_k(flat, s)
    mask = jnp.zeros(flat.shape, jnp.bool_).at[idx].set(True)
    return mask.reshape(a.shape)


def _project_s(a: jax.Array, s: int) -> jax.Array:
    """P_s: keep the s largest-|value| entries, zero the rest."""
    return jnp.where(top_s_mask(a, s), a, 0.0)


def _project_columns(a: jax.Array, n_cols: int) -> jax.Array:
    """Structured P_s: keep the n_cols columns of largest ℓ2 norm."""
    norms = jnp.linalg.norm(a, axis=0)
    _, idx = jax.lax.top_k(norms, n_cols)
    mask = jnp.zeros((a.shape[1],), jnp.bool_).at[idx].set(True)
    return jnp.where(mask[None, :], a, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "s", "iterations", "structured", "cd_block_size", "use_kernel"),
)
def outlier_quantease(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    s: int,
    iterations: int = 25,
    structured: bool = False,
    percdamp: float = 0.01,
    cd_block_size: int = 256,
    use_kernel: str = "xla",
) -> OutlierResult:
    """Algorithm 3.  ``s`` = total outlier budget (entries; for the structured
    variant ⌊s/q⌋ columns are kept)."""
    q, p = w.shape
    w32 = w.astype(jnp.float32)
    sigma_d = damp_sigma(sigma.astype(jnp.float32), percdamp)
    eta = 1.0 / (2.0 * power_lambda_max(sigma_d))

    n_cols = max(s // q, 1)
    project = (
        functools.partial(_project_columns, n_cols=n_cols)
        if structured
        else functools.partial(_project_s, s=s)
    )

    # Range-shrunk grids (outliers excluded from the quantization pool).
    # The exclusion mask must match the *structure* of H: entries for the
    # unstructured variant, whole columns for the structured one.
    if structured:
        _, col_idx = jax.lax.top_k(jnp.linalg.norm(w32, axis=0), n_cols)
        excl = jnp.zeros((p,), jnp.bool_).at[col_idx].set(True)
        excl = jnp.broadcast_to(excl[None, :], (q, p))
    else:
        excl = top_s_mask(w32, s)
    grid = compute_grid_excluding_outliers(w32, spec, excl)

    # Init: Ĥ = P_s(W), Ŵ = W − Ĥ.
    h = project(w32)
    w_hat = w32 - h

    objs = []
    for _ in range(iterations):
        # Ŵ-block: one QuantEase sweep on target (W − Ĥ).
        w_hat, _ = quantease_quantize(
            w32 - h,
            sigma_d,
            spec,
            iterations=1,
            block_size=cd_block_size,
            percdamp=0.0,  # sigma_d is already damped
            unquantized_heuristic=False,
            w_init=w_hat,
            grid=grid,
            use_kernel=use_kernel,
        )
        # Ĥ-block: IHT step.  ∇_H g = 2 (Ŵ + Ĥ − W) Σ.
        grad = 2.0 * ((w_hat + h - w32) @ sigma_d)
        h = project(h - eta * grad)
        e = w32 - w_hat - h
        objs.append(jnp.einsum("ij,jk,ik->", e, sigma_d, e))
    return OutlierResult(w_hat=w_hat, h=h, objective=jnp.stack(objs), grid=grid)
