"""Outlier-aware QuantEase (paper §4, Algorithm 3) — fused engine.

Solves  min ‖WX − (Ŵ+Ĥ)X‖²  s.t.  Ŵ on-grid, ‖Ĥ‖₀ ≤ s
by block coordinate descent:

  * Ŵ-block: one cyclic-CD sweep of QuantEase on the surrogate target
    ``W − Ĥ`` (identical math, WΣ ← (W−Ĥ)Σ),
  * Ĥ-block: one iterative-hard-thresholding (IHT) step
    ``Ĥ ← P_s(Ĥ − η ∇_H g)`` with ``η = 1/(2 λ_max(Σ))`` (Lemma 3 descent).

Two engines (DESIGN.md §Outlier-aware-fused):

* ``engine="fused"`` (default) — one ``lax.scan`` over outer iterations whose
  state is the CD engine's resident residual product.  With
  ``σ_norm = Σ/diag`` and ``Σ̃ = σ_norm − I``, the invariant
  ``base = P − ŴΣ̃`` (``P = (W−Ĥ)σ_norm``) is maintained *incrementally*:

    - the Ŵ-sweep is the rolling-Δ fused iteration (one qp² correction
      matmul, PR 2's schedule) carried natively transposed ``(p, q)`` so the
      per-iteration state never transposes,
    - the **exact** post-sweep residual ``R = P − ŴΣ̃`` — shared across the
      Ŵ/Ĥ boundary — is recovered from the same state by one block-suffix
      product ``R = base + Σ_{c≥b} Δ_c Σ̃[c, b]`` (triangular: computed as
      ``min(4, n_blocks)`` column chunks, the diagonal chunks masked, so it
      costs ~0.6·qp² instead of the dense 2(Ŵ+Ĥ−W)Σ matmul the legacy
      schedule pays),
    - the IHT gradient is then free: ``∇_H g = −2 (R − Ŵ) ⊙ diag(Σ)``, and
      the objective (opt-in) is one matmul,
    - the Ĥ-step's effect on the target, ``P ← P − ĤσΔ``, is **never** a
      dense matmul: the ``−dĤ Σ̃`` part rides the rolling Δ buffer (the
      sweep's w_old is folded to ``Ŵ − dĤ`` so every published block delta
      carries the correction to later blocks for free), and the ``−dĤ``
      identity part is one fused elementwise subtract.

  On TPU each outer iteration is a **single Pallas launch**
  (:func:`repro.kernels.ops.quantease_outlier_iteration`): the fused CD
  sweep and the suffix-residual accumulation share one kernel, with the
  rolling Δ and the R accumulator resident in VMEM across block steps.  The
  XLA fallback applies updates in the same order (iterates agree up to fp
  reassociation; the top-s support may differ only on near-ties).

* ``engine="legacy"`` — the pre-fused schedule, kept verbatim for
  equivalence tests and BENCH_solver.json: every outer iteration re-enters
  :func:`quantease_quantize` (a fresh ``_prep`` with its qp² WΣ matmul),
  pays a dense qp² matmul for the IHT gradient and (when
  ``track_objective``) another for the objective, inside an unrolled
  Python loop.

Grid-range shrink: the per-channel grids are computed once, from W with the
top-s magnitude entries excluded (§4.3) — outliers live in Ĥ, so the grid
need not cover them.

Structured variant (§4.3 "Structured Outliers"): ``P_s`` selects the
⌊s/q⌋ columns of largest ℓ2 norm instead of the s largest entries.

Initialization: Ĥ = P_s(W), Ŵ = W − Ĥ (infeasible until the first sweep,
like basic QuantEase).

**Batched:** ``w: (G, q, p)`` with ``sigma: (G, p, p)`` solves G independent
layers in one vmapped call — the whole-model solver stacks same-shape
outlier layers exactly like the base engine (``OutlierResult`` leaves and
the Grid gain a leading G dim).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.calib import damp_sigma
from repro.core.quantease import _quant_cols, quantease_quantize
from repro.quant import GridSpec, compute_grid_excluding_outliers

__all__ = ["OutlierResult", "outlier_quantease", "top_s_mask", "power_lambda_max"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OutlierResult:
    w_hat: jax.Array  # (q, p) quantized part (on-grid, fp32)
    h: jax.Array  # (q, p) dense sparse-correction (‖H‖₀ ≤ s)
    # Per-outer-iteration damped objective — **opt-in** via
    # ``track_objective=True`` (matches the base engine's PR 2 convention);
    # None by default.
    objective: Optional[jax.Array] = None
    # Range-shrunk grid the CD sweeps quantized against — threaded to the
    # solver's emit path so codes round-trip the solve exactly.
    grid: object = None

    @property
    def w_eff(self) -> jax.Array:
        return self.w_hat + self.h


def power_lambda_max(
    sigma: jax.Array, iters: int = 64, tol: float = 0.0
) -> jax.Array:
    """Largest eigenvalue of PSD Σ by power iteration (matrix-vector only —
    the paper's point: no decompositions anywhere in the pipeline).

    ``iters`` caps the iteration count.  ``tol > 0`` additionally early-outs
    once the Rayleigh quotient is stable to that relative tolerance — an
    *optimistic* stop: quotient stagnation is necessary but not sufficient
    for convergence (a clustered top of the spectrum can plateau near a
    sub-dominant eigenvalue), and an under-estimated λ_max makes the IHT
    step ``η = 1/(2λ_max)`` exceed the Lemma-3 bound.  The default
    ``tol=0.0`` therefore always runs the full ``iters`` matvecs; opt into
    the early-out only when the calibration spectrum is known to be
    well-separated.  One matvec per iteration: λ is read off as ``v·(Σv)``
    for the *unit* v entering the step, and the same product is reused for
    the next iterate.
    """
    p = sigma.shape[0]
    v0 = jnp.ones((p,), jnp.float32) / jnp.sqrt(p)

    def cond(state):
        i, _, lam, lam_prev = state
        if tol <= 0.0:
            return i < iters
        resolved = jnp.abs(lam - lam_prev) <= tol * jnp.maximum(jnp.abs(lam), 1e-30)
        return (i < iters) & ~resolved

    def body(state):
        i, v, lam, _ = state
        sv = sigma @ v
        lam_new = v @ sv  # Rayleigh quotient of the unit vector v
        v_new = sv / jnp.clip(jnp.linalg.norm(sv), 1e-30, None)
        return i + 1, v_new, lam_new, lam

    _, v, lam, _ = jax.lax.while_loop(
        cond, body, (0, v0, jnp.float32(0.0), jnp.float32(3.4e38))
    )
    # One final exact quotient on the converged direction.
    return v @ (sigma @ v)


def top_s_mask(a: jax.Array, s: int) -> jax.Array:
    """Boolean mask of the s largest |entries| (exact, via top_k on flat)."""
    flat = jnp.abs(a).reshape(-1)
    _, idx = jax.lax.top_k(flat, s)
    mask = jnp.zeros(flat.shape, jnp.bool_).at[idx].set(True)
    return mask.reshape(a.shape)


def _project_s(a: jax.Array, s: int) -> jax.Array:
    """P_s: keep the s largest-|value| entries, zero the rest."""
    return jnp.where(top_s_mask(a, s), a, 0.0)


def _project_columns(a: jax.Array, n_cols: int) -> jax.Array:
    """Structured P_s: keep the n_cols columns of largest ℓ2 norm."""
    norms = jnp.linalg.norm(a, axis=0)
    _, idx = jax.lax.top_k(norms, n_cols)
    mask = jnp.zeros((a.shape[1],), jnp.bool_).at[idx].set(True)
    return jnp.where(mask[None, :], a, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "s", "iterations", "structured", "cd_block_size",
        "use_kernel", "matmul_dtype", "track_objective", "engine", "lam_iters",
    ),
)
def outlier_quantease(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    s: int,
    iterations: int = 25,
    structured: bool = False,
    percdamp: float = 0.01,
    cd_block_size: int = 128,
    use_kernel: str = "auto",
    matmul_dtype: str = "float32",
    track_objective: bool = False,
    engine: str = "fused",
    lam_iters: int = 64,
) -> OutlierResult:
    """Algorithm 3.  ``s`` = total outlier budget (entries; for the structured
    variant ⌊s/q⌋ columns are kept).

    ``use_kernel``/``matmul_dtype`` follow the base engine's contract
    (threaded from ``PTQConfig`` by the whole-model solver): ``"auto"``
    resolves to the compiled Pallas kernel on TPU and XLA elsewhere;
    ``matmul_dtype="bfloat16"`` runs the Σ̃ correction/residual matmuls with
    bf16 operands (fp32 accumulation; β/quantize/IHT stay fp32).

    Batched: ``w: (G, q, p)`` + ``sigma: (G, p, p)`` vmaps G independent
    solves in one call.
    """
    kw = dict(
        spec=spec, s=s, iterations=iterations, structured=structured,
        percdamp=percdamp, cd_block_size=cd_block_size, use_kernel=use_kernel,
        matmul_dtype=matmul_dtype, track_objective=track_objective,
        engine=engine, lam_iters=lam_iters,
    )
    if w.ndim == 3:
        return jax.vmap(lambda wi, si: _outlier_2d(wi, si, **kw))(w, sigma)
    return _outlier_2d(w, sigma, **kw)


def _outlier_2d(
    w, sigma, *, spec, s, iterations, structured, percdamp, cd_block_size,
    use_kernel, matmul_dtype, track_objective, engine, lam_iters,
) -> OutlierResult:
    q, p = w.shape
    w32 = w.astype(jnp.float32)
    sigma_d = damp_sigma(sigma.astype(jnp.float32), percdamp)
    eta = 1.0 / (2.0 * power_lambda_max(sigma_d, iters=lam_iters))

    n_cols = max(s // q, 1)
    # Range-shrunk grids (outliers excluded from the quantization pool).
    # The exclusion mask must match the *structure* of H: entries for the
    # unstructured variant, whole columns for the structured one.
    if structured:
        _, col_idx = jax.lax.top_k(jnp.linalg.norm(w32, axis=0), n_cols)
        excl = jnp.zeros((p,), jnp.bool_).at[col_idx].set(True)
        excl = jnp.broadcast_to(excl[None, :], (q, p))
    else:
        excl = top_s_mask(w32, s)
    grid = compute_grid_excluding_outliers(w32, spec, excl)

    if engine == "legacy":
        return _outlier_legacy_2d(
            w32, sigma_d, spec, grid, excl, eta,
            s=s, iterations=iterations, structured=structured,
            cd_block_size=cd_block_size, use_kernel=use_kernel,
            track_objective=track_objective, n_cols=n_cols,
        )
    if engine != "fused":
        raise ValueError(f"unknown engine {engine!r}")
    return _outlier_fused_2d(
        w32, sigma_d, spec, grid, excl, eta,
        s=s, iterations=iterations, structured=structured,
        cd_block_size=cd_block_size, use_kernel=use_kernel,
        matmul_dtype=matmul_dtype, track_objective=track_objective,
        n_cols=n_cols,
    )


# ---------------------------------------------------------------------------
# Legacy engine: the pre-fused schedule, verbatim (bench + equivalence tests).
# ---------------------------------------------------------------------------


def _outlier_legacy_2d(
    w32, sigma_d, spec, grid, excl, eta, *,
    s, iterations, structured, cd_block_size, use_kernel, track_objective,
    n_cols,
):
    project = (
        functools.partial(_project_columns, n_cols=n_cols)
        if structured
        else functools.partial(_project_s, s=s)
    )
    # Init: Ĥ = P_s(W), Ŵ = W − Ĥ.
    h = jnp.where(excl, w32, 0.0)
    w_hat = w32 - h

    objs = []
    for _ in range(iterations):
        # Ŵ-block: one QuantEase sweep on target (W − Ĥ).
        w_hat, _ = quantease_quantize(
            w32 - h,
            sigma_d,
            spec,
            iterations=1,
            block_size=cd_block_size,
            percdamp=0.0,  # sigma_d is already damped
            unquantized_heuristic=False,
            w_init=w_hat,
            grid=grid,
            use_kernel=use_kernel,
        )
        # Ĥ-block: IHT step.  ∇_H g = 2 (Ŵ + Ĥ − W) Σ.
        grad = 2.0 * ((w_hat + h - w32) @ sigma_d)
        h = project(h - eta * grad)
        if track_objective:
            e = w32 - w_hat - h
            objs.append(jnp.einsum("ij,jk,ik->", e, sigma_d, e))
    return OutlierResult(
        w_hat=w_hat,
        h=h,
        objective=jnp.stack(objs) if track_objective else None,
        grid=grid,
    )


# ---------------------------------------------------------------------------
# Fused engine: scanned outer loop on the resident (base, Δ) state.
# ---------------------------------------------------------------------------

_SWEEP_CHUNK = 8  # columns per unrolled sweep step (static intra-chunk tiles)


def _suffix_corr(delta_t, sig_t, bsz, cdt):
    """Exact block-suffix product ``U[:, blk b] = Σ_{c≥b} Δ_c Σ̃[c, blk b]``
    in transposed layout: ``U_t = (Σ̃ ⊙ M)ᵀ Δ_t`` with ``M[r, c] = 1`` iff
    ``block(r) ≥ block(c)``.

    The mask is block-lower-triangular, so the product is computed in
    ``min(4, n_blocks)`` column chunks — diagonal chunks masked at block
    granularity, below-diagonal crosses dense — ~0.6·qp² FLOPs instead of
    the dense qp².  ``cdt`` casts the matmul operands (bf16 option; fp32
    accumulation).
    """
    p_pad, _ = delta_t.shape
    nb = p_pad // bsz
    nchunk = next(c for c in (4, 3, 2, 1) if nb % c == 0)
    cs = p_pad // nchunk
    blk = jnp.arange(cs) // bsz
    mask = blk[:, None] <= blk[None, :]  # within-chunk: row-block ≤ col-block
    outs = []
    for i in range(nchunk):
        sl = slice(i * cs, (i + 1) * cs)
        sig_diag = jnp.where(mask, sig_t[sl, sl], 0.0).astype(cdt)
        u = jnp.dot(
            sig_diag, delta_t[sl].astype(cdt), preferred_element_type=jnp.float32
        )
        for j in range(i + 1, nchunk):
            sj = slice(j * cs, (j + 1) * cs)
            u = u + jnp.dot(
                sig_t[sl, sj].astype(cdt),
                delta_t[sj].astype(cdt),
                preferred_element_type=jnp.float32,
            )
        outs.append(u)
    return outs[0] if nchunk == 1 else jnp.concatenate(outs, 0)


def _sweep_block_t(beta0, sg_diag, wo, sc, zc, n_levels, q):
    """Transposed intra-block CD sweep: scan over K-column groups, each group
    one (K, B)·(B, q) correction matmul plus statically-unrolled rank-1
    fixups for the intra-group recurrence.  Same update order as the
    per-column reference sweep — identical iterates up to fp reassociation.
    """
    bsz = beta0.shape[0]
    K = _SWEEP_CHUNK
    ng = bsz // K
    sgr = sg_diag.reshape(ng, K, bsz)
    sgi = jnp.stack([sgr[g][:, g * K : (g + 1) * K] for g in range(ng)])
    xs = (
        jnp.arange(ng), sgr, sgi, beta0.reshape(ng, K, q),
        wo.reshape(ng, K, q), sc.reshape(ng, K, q), zc.reshape(ng, K, q),
    )

    def grp(dloc, x):
        g, sg_rows_g, sg_in, b0g, wog, scg, zcg = x
        corr = sg_rows_g @ dloc  # vs groups < g of this block (rows ≥ gK are 0)
        fresh, news = [], []
        for j in range(K):
            b = b0g[j] + corr[j]
            for jj in range(j):  # intra-group recurrence, static indices
                b = b + fresh[jj] * sg_in[j, jj]
            new = _quant_cols(b, scg[j], zcg[j], n_levels)
            fresh.append(wog[j] - new)
            news.append(new)
        dloc = jax.lax.dynamic_update_slice(dloc, jnp.stack(fresh), (g * K, 0))
        return dloc, jnp.stack(news)

    dloc, new_g = jax.lax.scan(grp, jnp.zeros((bsz, q), jnp.float32), xs)
    return new_g.reshape(bsz, q), dloc


def _outlier_fused_2d(
    w32, sigma_d, spec, grid, excl, eta, *,
    s, iterations, structured, cd_block_size, use_kernel, matmul_dtype,
    track_objective, n_cols,
):
    from repro.core.quantease import _resolve_use_kernel

    use_kernel = _resolve_use_kernel(use_kernel)
    if matmul_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unknown matmul_dtype {matmul_dtype!r}")
    cdt = jnp.bfloat16 if matmul_dtype == "bfloat16" else jnp.float32
    q, p = w32.shape
    n_levels = spec.n_levels

    bsz = max(_SWEEP_CHUNK, min(cd_block_size, p))
    bsz = -(-bsz // _SWEEP_CHUNK) * _SWEEP_CHUNK  # multiple of the sweep chunk
    nb = -(-p // bsz)
    p_pad = nb * bsz
    pad = p_pad - p

    scale_pc, zero_pc = grid.per_column(p)
    diag = jnp.diag(sigma_d)
    sig_norm = sigma_d / diag[None, :]
    sig_tilde = sig_norm - jnp.eye(p, dtype=jnp.float32)
    if pad:
        # Padded columns: zero Σ̃ coupling, unit scale, zero diag ⇒ they
        # quantize to an isolated 0, their IHT candidates are exactly 0, and
        # they never influence real columns.
        sig_tilde = jnp.pad(sig_tilde, ((0, pad), (0, pad)))
        diag = jnp.pad(diag, (0, pad))
        scale_pc = jnp.pad(scale_pc, ((0, 0), (0, pad)), constant_values=1.0)
        zero_pc = jnp.pad(zero_pc, ((0, 0), (0, pad)))
    w_p = jnp.pad(w32, ((0, 0), (0, pad))) if pad else w32
    excl_p = jnp.pad(excl, ((0, 0), (0, pad))) if pad else excl

    # Engine selection: the single-launch Pallas kernel when requested AND
    # its VMEM budget fits; otherwise the XLA schedule — same update order,
    # same iterates (the base engine's fallback contract).
    kernel_tq = None
    if use_kernel != "xla":
        from repro.kernels import ops as kops

        kernel_tq = kops.outlier_iteration_tq(p_pad, bsz, matmul_dtype)
    use_pallas = kernel_tq is not None
    # The kernel tiles q: pad the resident state's q axis once, outside the
    # scan (the XLA path needs no q padding).
    tq = min(kernel_tq, q) if use_pallas else 0
    pad_q = (-q) % tq if use_pallas else 0
    qq = q + pad_q

    # Everything below lives natively transposed: state is (p_pad, qq).
    sig_t = sig_tilde.T  # row j = Σ̃[:, j]
    sig_rows = sig_t.reshape(nb, bsz, p_pad)
    sig_diag_t = jnp.stack(
        [sig_rows[b][:, b * bsz : (b + 1) * bsz] for b in range(nb)]
    )
    sig_rows_c = sig_rows.astype(cdt)
    diag_t = diag[:, None]

    def prep_t(a, fill=0.0):  # (q, p_pad) → (p_pad, qq), q-padded, once
        if pad_q:
            a = jnp.pad(a, ((0, pad_q), (0, 0)), constant_values=fill)
        return a.T

    scale_tp = prep_t(jnp.maximum(scale_pc, 1e-12), fill=1.0)
    zero_tp = prep_t(zero_pc)
    w_t = prep_t(w_p)
    excl_t = prep_t(excl_p)

    # Init: Ĥ = P_s(W), Ŵ = W − Ĥ.  The base invariant collapses at init:
    # base = P − Ŵ₀Σ̃ = target(σ_norm − Σ̃) = target, since Ŵ₀ = target = W − Ĥ
    # and σ_norm − Σ̃ = I — no init matmul at all.
    h_t = jnp.where(excl_t, w_t, 0.0)
    w_hat_t = w_t - h_t
    base_t = w_hat_t

    if not use_pallas:
        scale_tb = scale_tp.reshape(nb, bsz, qq)
        zero_tb = zero_tp.reshape(nb, bsz, qq)

        def iteration(w_old_t, base_in, delta_in, dh_t):
            """One fused CD iteration; returns (Ŵ_new, base_out, Δ_pure, R)."""
            xs = (
                jnp.arange(nb), sig_rows_c, sig_diag_t,
                base_in.reshape(nb, bsz, qq), w_old_t.reshape(nb, bsz, qq),
                scale_tb, zero_tb, dh_t.reshape(nb, bsz, qq),
            )

            def block(delta_buf, x):
                b, sgr, sgd, b0, wo, sc, zc, dhp = x
                corr = jnp.dot(
                    sgr, delta_buf.astype(cdt), preferred_element_type=jnp.float32
                )
                # −dhp: the identity part of the Ĥ-step's target move,
                # absorbed into the read (base carry stays un-folded).
                beta0 = b0 - dhp + corr
                new_t, dblk = _sweep_block_t(beta0, sgd, wo, sc, zc, n_levels, qq)
                # Publish δŴ − dĤ_prev: later blocks' corrections then carry
                # the −dĤΣ̃ part of the Ĥ-step's target move for free.  The
                # pure δŴ goes out for the suffix residual and the next
                # iteration's rolling state.
                delta_buf = jax.lax.dynamic_update_slice(
                    delta_buf, dblk - dhp, (b * bsz, 0)
                )
                return delta_buf, (new_t, beta0, dblk)

            _, (new_b, beta0_b, dpure_b) = jax.lax.scan(block, delta_in, xs)
            new_t = new_b.reshape(p_pad, qq)
            base_out = beta0_b.reshape(p_pad, qq)
            dpure = dpure_b.reshape(p_pad, qq)
            r_t = base_out + _suffix_corr(dpure, sig_t, bsz, cdt)
            return new_t, base_out, dpure, r_t
    else:
        interpret = use_kernel != "pallas_hw"
        sig_corr_c = sig_t.astype(cdt)

        def iteration(w_old_t, base_in, delta_in, dh_t):
            # Single kernel launch per outer iteration, straight on the
            # resident transposed state — loop-invariant Σ̃/scale/zero slabs
            # prepped once above, no per-iteration transposes.
            return kops.quantease_outlier_iteration_t(
                base_in,
                sig_corr=sig_corr_c, sig_t=sig_t,
                w_old_t=w_old_t, scale_t=scale_tp, zero_t=zero_tp,
                dh_prev_t=dh_t, delta_prev_t=delta_in,
                n_levels=n_levels, quantize=True, bsz=bsz, tq=tq,
                matmul_dtype=matmul_dtype, interpret=interpret,
            )

    delta0 = jnp.zeros((p_pad, qq), jnp.float32)

    def project_t(cand_t):
        """P_s in transposed layout.  Returns the new Ĥᵀ."""
        if structured:
            # columns of W = rows of the transposed state
            norms = jnp.sum(cand_t * cand_t, axis=1)
            _, ridx = jax.lax.top_k(norms, n_cols)
            mask = jnp.zeros((p_pad,), jnp.bool_).at[ridx].set(True)
            return jnp.where(mask[:, None], cand_t, 0.0)
        cf = cand_t.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(cf), s)
        return jnp.zeros_like(cf).at[idx].set(cf[idx]).reshape(cand_t.shape)

    def body(state, _):
        w_cur, h_cur, base_cur, delta_cur, dh_prev = state
        new_t, base_out, dpure, r_t = iteration(w_cur, base_cur, delta_cur, dh_prev)
        # IHT step from the exact residual: ∇_H g = −2 (R − Ŵ) ⊙ diag.
        cand_t = h_cur + (2.0 * eta) * ((r_t - new_t) * diag_t)
        h_new = project_t(cand_t)
        dh = h_new - h_cur
        if track_objective:
            e_t = w_t - h_new - new_t
            obj = jnp.sum(e_t * (sigma_d_pad @ e_t))
        else:
            obj = jnp.float32(0.0)
        # The Ĥ-step moves the target by −dĤσ_norm: its −dĤΣ̃ part rides the
        # rolling Δ (dh_prev is re-subtracted at each block's publish next
        # iteration) and its −dĤ identity part is absorbed when base is read
        # (the −dhp term in beta0) — no dense matmul anywhere.
        return (new_t, h_new, base_out, dpure - dh, dh), obj

    sigma_d_pad = (
        jnp.pad(sigma_d, ((0, pad), (0, pad))) if (track_objective and pad)
        else sigma_d
    )
    state = (w_hat_t, h_t, base_t, delta0, jnp.zeros_like(h_t))
    (w_hat_t, h_t, _, _, _), objs = jax.lax.scan(
        body, state, None, length=iterations, unroll=min(2, iterations)
    )
    return OutlierResult(
        w_hat=w_hat_t.T[:q, :p],
        h=h_t.T[:q, :p],
        objective=objs if track_objective else None,
        grid=grid,
    )
