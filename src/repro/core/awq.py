"""AWQ baseline (Lin et al., 2023) as described in QuantEase §2.2.2.

AWQ searches a per-input-channel scaling ``s ∈ R^p`` minimizing

    ‖WX − q(s⊙W)(X⊙s⁻¹)‖²_F,

with the parametric family ``s = s_X^α · s_W^{−β}``, α, β grid-searched over
[0, 1]; ``s_X`` / ``s_W`` are per-channel mean magnitudes of activations and
weights.  The effective dequantized weight is ``Ŵ = q(s⊙W) ⊙ s⁻¹`` (column j
scaled by 1/s_j), so the reconstruction error is computable from Σ alone:
``‖(W−Ŵ)X‖² = Tr(EΣEᵀ)`` — no raw activations needed.

``s_X`` is derived from Σ's diagonal (E[x_j²]^{1/2}), which is the statistic
our calibration pipeline already carries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.quant import GridSpec, compute_grid, quantize_dequantize

__all__ = ["awq_quantize"]


def _candidate_error(w, sigma, spec, s):
    """Error of quantizing with column scaling s (p,)."""
    ws = w * s[None, :]
    grid = compute_grid(ws, spec)
    wq = quantize_dequantize(ws, grid) / s[None, :]
    e = w - wq
    return jnp.einsum("ij,jk,ik->", e, sigma, e), wq


@functools.partial(jax.jit, static_argnames=("spec", "n_grid", "search_beta"))
def awq_quantize(
    w: jax.Array,
    sigma: jax.Array,
    spec: GridSpec,
    *,
    n_grid: int = 20,
    search_beta: bool = False,
) -> jax.Array:
    """Grid-search α (and optionally β) and return the best dequantized Ŵ.

    With ``search_beta=False`` (AWQ's published default) s = s_X^α only.
    """
    q, p = w.shape
    w = w.astype(jnp.float32)
    sigma = sigma.astype(jnp.float32)
    sx = jnp.sqrt(jnp.clip(jnp.diag(sigma), 1e-12, None))  # per-channel act scale
    sx = sx / jnp.exp(jnp.mean(jnp.log(sx)))  # geo-mean normalize (AWQ impl.)
    sw = jnp.mean(jnp.abs(w), axis=0)
    sw = sw / jnp.exp(jnp.mean(jnp.log(jnp.clip(sw, 1e-12, None))))

    alphas = jnp.linspace(0.0, 1.0, n_grid)
    betas = jnp.linspace(0.0, 1.0, n_grid) if search_beta else jnp.zeros((1,))

    def eval_ab(ab):
        a, b = ab
        s = jnp.clip(sx**a * sw ** (-b), 1e-6, 1e6)
        err, _ = _candidate_error(w, sigma, spec, s)
        return err

    grid_ab = jnp.stack(
        [jnp.repeat(alphas, betas.shape[0]), jnp.tile(betas, alphas.shape[0])], axis=1
    )
    errs = jax.lax.map(eval_ab, grid_ab)
    best = grid_ab[jnp.argmin(errs)]
    s = jnp.clip(sx ** best[0] * sw ** (-best[1]), 1e-6, 1e6)
    _, wq = _candidate_error(w, sigma, spec, s)
    return wq


def awq_then_quantease(
    w, sigma, spec, *, n_grid: int = 20, iterations: int = 20, percdamp: float = 0.01
):
    """AWQ + QuantEase (paper §6: "we would expect AWQ+QuantEase would lead
    to even further improvements"): grid-search the AWQ per-channel scaling,
    then run QuantEase CD on the *scaled* problem.

    With column scaling s, min ‖WX − (Ŵs⊙s⁻¹)X‖² over on-grid Ŵs is the
    QuantEase problem with W' = s⊙W and Σ' = diag(1/s) Σ diag(1/s).
    """
    import jax.numpy as jnp

    from repro.core import quantease

    w = w.astype(jnp.float32)
    sigma = sigma.astype(jnp.float32)
    sx = jnp.sqrt(jnp.clip(jnp.diag(sigma), 1e-12, None))
    sx = sx / jnp.exp(jnp.mean(jnp.log(sx)))
    alphas = jnp.linspace(0.0, 1.0, n_grid)

    def eval_a(a):
        s = jnp.clip(sx**a, 1e-6, 1e6)
        err, _ = _candidate_error(w, sigma, spec, s)
        return err

    errs = jax.lax.map(eval_a, alphas)
    s = jnp.clip(sx ** alphas[jnp.argmin(errs)], 1e-6, 1e6)
    ws = w * s[None, :]
    sigma_s = sigma / s[:, None] / s[None, :]
    ws_hat, _ = quantease.quantease_quantize(
        ws, sigma_s, spec, iterations=iterations, percdamp=percdamp
    )
    return ws_hat / s[None, :]
