"""RTN (round-to-nearest) baseline — Dettmers et al. 2022 / Yao et al. 2022.

Quantizes each weight independently to its nearest grid point; no use of
calibration data.  This is the weakest baseline in the paper's tables and the
initializer sanity floor for everything else.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import GridSpec, compute_grid, quantize_dequantize

__all__ = ["rtn_quantize"]


def rtn_quantize(w: jax.Array, spec: GridSpec) -> jax.Array:
    """W: (q, p) → nearest-grid Ŵ (fp32)."""
    grid = compute_grid(w, spec)
    return quantize_dequantize(w.astype(jnp.float32), grid)
