"""Calibration statistics for layer-wise PTQ.

Every algorithm in this package consumes only second-order statistics of the
calibration activations — ``Σ = X Xᵀ`` (p×p) and optionally ``W Σ`` — never
the raw ``X`` (n ≫ p, so this is the memory win the paper highlights:
``p² + O(pq)`` footprint).  ``CalibStats`` supports *streaming* accumulation
over calibration batches (fp32 accumulators), which is how the whole-model
solver feeds it, and sharded accumulation under a mesh (each data shard
accumulates its local Gram matrix; a psum at the end makes it global).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["CalibStats", "gram", "damp_sigma"]


def gram(x: jax.Array) -> jax.Array:
    """Σ = X Xᵀ for X: (p, n) — fp32 accumulation regardless of input dtype."""
    x = x.astype(jnp.float32)
    return x @ x.T


@dataclasses.dataclass
class CalibStats:
    """Streaming Σ accumulator for one linear layer.

    ``sigma`` is the *unnormalized* Gram matrix; ``n`` counts samples.  The
    algorithms are scale-invariant in Σ (β̃ in Lemma 1 uses only ratios
    Σ_{j,k}/Σ_{j,j}), so no normalization by n is required.
    """

    sigma: jax.Array  # (p, p) fp32
    n: int = 0

    @classmethod
    def zeros(cls, p: int) -> "CalibStats":
        return cls(sigma=jnp.zeros((p, p), jnp.float32), n=0)

    def update(self, x: jax.Array) -> "CalibStats":
        """x: (p, n_batch) activations feeding the layer (paper layout)."""
        return CalibStats(sigma=self.sigma + gram(x), n=self.n + x.shape[1])

    def update_tokens(self, x_tokens: jax.Array) -> "CalibStats":
        """x_tokens: (..., p) activation tensor in model layout."""
        x2 = x_tokens.reshape(-1, x_tokens.shape[-1]).astype(jnp.float32)
        return CalibStats(sigma=self.sigma + x2.T @ x2, n=self.n + x2.shape[0])


def damp_sigma(sigma: jax.Array, percdamp: float = 0.01) -> jax.Array:
    """λ-damping: Σ + λI with λ = percdamp · mean(diag Σ).

    Identical to GPTQ's stabilization.  For QuantEase it additionally
    guarantees Σ_{j,j} > 0 (Lemma 1 footnote: dead input features would
    otherwise make the CD update ill-defined).  Columns with Σ_{j,j}=0 before
    damping are untouched by the objective, so damping them towards
    round-to-nearest is exactly the right behavior.
    """
    p = sigma.shape[0]
    mean_diag = jnp.clip(jnp.mean(jnp.diag(sigma)), 1e-8, None)
    return sigma + (percdamp * mean_diag) * jnp.eye(p, dtype=sigma.dtype)
