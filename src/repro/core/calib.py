"""Calibration statistics for layer-wise PTQ.

Every algorithm in this package consumes only second-order statistics of the
calibration activations — ``Σ = X Xᵀ`` (p×p) and optionally ``W Σ`` — never
the raw ``X`` (n ≫ p, so this is the memory win the paper highlights:
``p² + O(pq)`` footprint).  ``CalibStats`` supports *streaming* accumulation
over calibration batches (fp32 accumulators) — the whole-model solver
(core/solver.py) feeds it batch-by-batch during the capture pass — and
sharded accumulation under a mesh: each data shard accumulates its local
Gram matrix inside a ``shard_map`` and a ``psum`` makes it global
(:func:`sharded_gram`); with one device or no mesh the same call degrades
to the plain local matmul.

MoE layers carry one Σ per expert: a ``CalibStats`` whose ``sigma`` has a
leading expert axis ``(E, p, p)``, updated from dispatch-table activations
``(E, C, p)`` in one einsum (see DESIGN.md §Streaming-solver).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

__all__ = ["CalibStats", "gram", "sharded_gram", "shard_axis", "damp_sigma"]


def gram(x: jax.Array) -> jax.Array:
    """Σ = X Xᵀ for X: (p, n) — fp32 accumulation regardless of input dtype."""
    x = x.astype(jnp.float32)
    return x @ x.T


@functools.lru_cache(maxsize=None)
def _sharded_gram_fn(mesh, axis: str):
    """One cached shard_mapped executable per (mesh, axis) — the capture
    pass calls this per linear per chunk, so a fresh wrapper per call would
    retrace every time."""
    from jax.experimental.shard_map import shard_map

    def local(xl):
        return jax.lax.psum(xl.T @ xl, axis)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=PartitionSpec(axis, None),
            out_specs=PartitionSpec(None, None),
        )
    )


def shard_axis(mesh) -> Optional[str]:
    """The mesh axis PTQ shards over: "data" if present, else the first
    axis.  Single source of truth for Gram accumulation and the row-sharded
    CD solve, so they always engage (or fall back) together."""
    if mesh is None:
        return None
    return "data" if "data" in mesh.shape else next(iter(mesh.shape))


def sharded_gram(x2d: jax.Array, mesh=None, axis: Optional[str] = None) -> jax.Array:
    """Σ = XᵀX for X: (n, p) token-major, data-sharded over ``axis``
    (default: :func:`shard_axis`).

    Each shard contracts its local rows; a ``psum`` over the data axis
    produces the global Gram matrix without ever gathering activations.
    Rows pad internally with zeros up to the axis size (zero rows
    contribute nothing to Σ).  Falls back to the single-device matmul when
    ``mesh`` is None or the axis has size 1 (the result is bit-identical
    up to fp32 reduction order).
    """
    x2d = x2d.astype(jnp.float32)
    axis = axis or shard_axis(mesh)
    n_shards = 1 if mesh is None else mesh.shape.get(axis, 1)
    if n_shards <= 1:
        return x2d.T @ x2d
    pad = (-x2d.shape[0]) % n_shards
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return _sharded_gram_fn(mesh, axis)(x2d)


@dataclasses.dataclass
class CalibStats:
    """Streaming Σ accumulator for one linear layer.

    ``sigma`` is the *unnormalized* Gram matrix — ``(p, p)``, or ``(E, p, p)``
    for expert-stacked MoE linears; ``n`` counts samples.  The algorithms are
    scale-invariant in Σ (β̃ in Lemma 1 uses only ratios Σ_{j,k}/Σ_{j,j}),
    so no normalization by n is required.
    """

    sigma: jax.Array  # (p, p) or (E, p, p) fp32
    n: int = 0

    @classmethod
    def zeros(cls, p: int, experts: int = 0) -> "CalibStats":
        shape = (experts, p, p) if experts else (p, p)
        return cls(sigma=jnp.zeros(shape, jnp.float32), n=0)

    @property
    def p(self) -> int:
        return self.sigma.shape[-1]

    def update(self, x: jax.Array) -> "CalibStats":
        """x: (p, n_batch) activations feeding the layer (paper layout)."""
        return CalibStats(sigma=self.sigma + gram(x), n=self.n + x.shape[1])

    def update_tokens(self, x_tokens: jax.Array, mesh=None) -> "CalibStats":
        """x_tokens: (..., p) activation tensor in model layout.

        With a mesh, the flattened token rows accumulate via
        :func:`sharded_gram` (local matmul + psum); otherwise locally.
        """
        x2 = x_tokens.reshape(-1, x_tokens.shape[-1])
        return CalibStats(
            sigma=self.sigma + sharded_gram(x2, mesh), n=self.n + x2.shape[0]
        )

    def update_expert_tokens(self, x_experts: jax.Array) -> "CalibStats":
        """x_experts: (E, C, p) dispatch-table activations (MoE path).

        One einsum accumulates all per-expert Gram matrices; dropped slots
        are zero rows and contribute nothing.
        """
        x32 = x_experts.astype(jnp.float32)
        return CalibStats(
            sigma=self.sigma + jnp.einsum("ecd,ecf->edf", x32, x32),
            n=self.n + x_experts.shape[1],
        )


def damp_sigma(sigma: jax.Array, percdamp: float = 0.01) -> jax.Array:
    """λ-damping: Σ + λI with λ = percdamp · mean(diag Σ).

    Identical to GPTQ's stabilization.  For QuantEase it additionally
    guarantees Σ_{j,j} > 0 (Lemma 1 footnote: dead input features would
    otherwise make the CD update ill-defined).  Columns with Σ_{j,j}=0 before
    damping are untouched by the objective, so damping them towards
    round-to-nearest is exactly the right behavior.  Batched Σ (leading
    dims) damp per-matrix.
    """
    p = sigma.shape[-1]
    diag = jnp.diagonal(sigma, axis1=-2, axis2=-1)
    mean_diag = jnp.clip(jnp.mean(diag, axis=-1), 1e-8, None)
    eye = jnp.eye(p, dtype=sigma.dtype)
    return sigma + (percdamp * mean_diag)[..., None, None] * eye
