"""Whole-model PTQ: streaming, sharded, batched — the paper's pipeline at scale.

Mirrors the reference GPTQ/QuantEase flow (paper §5 setup), engineered per
DESIGN.md §Streaming-solver:

  * run calibration batches through the model **block by block**; the inputs
    feeding each block are the outputs of the *already-quantized* prefix
    (error propagation across blocks, as all layer-wise PTQ codebases do),
  * **streaming Σ capture**: per linear, a :class:`~repro.core.calib.CalibStats`
    accumulator folds each batch into Σ = XXᵀ the moment it is computed
    (fp32, the only statistic any method needs — ``p² + O(pq)`` memory,
    paper §3.2).  Raw per-layer activation lists are never materialized;
    peak capture memory per layer is O(p²), not O(n_calib·seq·p),
  * **batched solves**: same-shape captured linears of a block — and all E
    experts of an MoE matrix — are stacked and solved by a single vmapped
    ``quantease_quantize``/``gptq_quantize`` call instead of sequential
    Python loops (layer independence, as CDQuant exploits for parallel CD),
  * **mesh sharding** (``ptq_quantize_model(..., mesh=...)``): calibration
    Gram accumulation is data-sharded with a psum (calib.sharded_gram), and
    the CD solve shard_maps over the independent q (output-row) dimension;
    with one device or no mesh everything degrades to the local path,
  * record per-layer relative errors — the data behind the paper's Fig. 2 —
    and report per-block progress through an optional callback.

Quantized leaf set: every matmul the model zoo routes through
``apply_linear`` except numerically-critical small tensors (mamba Δ
projection ``wdt``; norms; biases; MoE router) — see DESIGN.md
§Arch-applicability.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.core import awq, gptq, outlier, quantease, rtn, spqr
from repro.core.calib import CalibStats
from repro.core.quantease import relative_error
from repro.models import model as M
from repro.models.common import capture_gram_stats, capture_scope
from repro.quant import (
    GridSpec,
    QuantizedTensor,
    compute_grid,
    quantize_codes,
    quantize_dequantize,
)

__all__ = ["LayerSpec", "PTQConfig", "ptq_quantize_model", "QUANTIZABLE"]

QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "wq_c", "wk_c", "wv_c", "wo_c",
    "wg", "wu", "wd",
    "wz", "wx", "wbc", "out_proj",
    "w_gate", "w_up", "w_down",
}
_MOE_NAMES = {"w_gate", "w_up", "w_down"}

# Methods batchable with a single vmapped call *and* row-shardable under a
# mesh.  qe_outlier/qe_outlier_struct also batch (one vmapped fused-engine
# call per same-shape group — see _solve_group) but never row-shard: the
# top-s projection is global across output rows.  The remainder (awq, spqr)
# fall back to a per-layer loop inside the same grouped interface.
_BATCHED_METHODS = {"rtn", "gptq", "quantease"}

# Sentinel distinguishing "inherit from the base config" from an explicit
# ``None`` (per-channel) group_size in a LayerSpec override.
_INHERIT = "__inherit__"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Per-layer override of the global PTQConfig (mixed-precision PTQ).

    Any field left at its default inherits the base config; ``group_size``
    uses the ``_INHERIT`` sentinel because ``None`` is itself a meaningful
    value (one group spanning the whole row).  Keys into
    ``PTQConfig.layer_specs`` are solver layer paths — ``"dec.p0.b1/wq"`` —
    or bare leaf names (``"wq"``) as a fallback matched when no exact path
    entry exists.
    """

    bits: Optional[int] = None
    group_size: object = _INHERIT
    outlier_frac: Optional[float] = None
    method: Optional[str] = None
    iterations: Optional[int] = None


@dataclasses.dataclass
class PTQConfig:
    method: str = "quantease"  # rtn|gptq|awq|quantease|awq_qe|spqr|qe_outlier|qe_outlier_struct
    spec: GridSpec = dataclasses.field(default_factory=lambda: GridSpec(bits=4))
    iterations: int = 25
    outlier_frac: float = 0.01  # for outlier-aware methods
    percdamp: float = 0.01
    block_size: int = 128
    emit: str = "fake"  # "fake" (dequantized bf16) | "qt" (QuantizedTensor)
    init_from_gptq: bool = False  # QuantEase warm start (paper §3.1)
    # Streaming capture: feed calibration batches through the capture pass in
    # chunks of this many sequences (0 = whole batch at once) so transient
    # activation memory is bounded independently of the calibration set size.
    # Dense Σ is chunk-invariant; MoE dispatch capacity is per-forward, so
    # chunking can shift overflow drops and perturb per-expert Σ slightly.
    stream_chunk: int = 0
    # Shard the CD solve over output rows (and Gram accumulation over data)
    # when a mesh is passed to ptq_quantize_model.
    shard: bool = False
    # QuantEase engine knobs, threaded through QuantEaseConfig.solve_kwargs:
    # "auto" resolves to the compiled Pallas kernel on TPU, XLA elsewhere;
    # matmul_dtype="bfloat16" runs the Σ̃ correction matmuls with bf16
    # operands (fp32 accumulation — the β/quantize path stays fp32).
    use_kernel: str = "auto"
    matmul_dtype: str = "float32"
    # Mixed-precision: per-layer overrides keyed by solver layer path
    # ("dec.p0.b1/wq") or bare leaf name ("wq").  Same-shape batching splits
    # groups by the *effective* per-layer config, so layers assigned
    # different bits never share a vmapped solve.
    layer_specs: Optional[dict] = None
    # Auto-tuning sensitivity signal: when True, each progress_cb record
    # additionally carries per-layer λ_max(Σ) (power iteration — the IHT
    # step-size spectrum the tuner ranks on) under "lambda_max".
    collect_sensitivity: bool = False

    def qe_config(self) -> "quantease.QuantEaseConfig":
        """The CD-solver config this PTQ run resolves to (wired end-to-end)."""
        return quantease.QuantEaseConfig(
            iterations=self.iterations,
            percdamp=self.percdamp,
            use_kernel=self.use_kernel,
            matmul_dtype=self.matmul_dtype,
        )

    def for_layer(self, key: str) -> "PTQConfig":
        """Resolve the effective config for one layer path.

        Exact-path entries win over bare-name fallbacks; a layer with no
        entry uses the base config unchanged.  The returned config has
        ``layer_specs=None`` — it is fully resolved.
        """
        if not self.layer_specs:
            return self
        ov = self.layer_specs.get(key)
        if ov is None:
            ov = self.layer_specs.get(key.rsplit("/", 1)[-1])
        if ov is None:
            return dataclasses.replace(self, layer_specs=None)
        spec = dataclasses.replace(
            self.spec,
            bits=self.spec.bits if ov.bits is None else ov.bits,
            group_size=self.spec.group_size
            if ov.group_size is _INHERIT
            else ov.group_size,
        )
        return dataclasses.replace(
            self,
            layer_specs=None,
            spec=spec,
            method=self.method if ov.method is None else ov.method,
            outlier_frac=self.outlier_frac
            if ov.outlier_frac is None
            else ov.outlier_frac,
            iterations=self.iterations
            if ov.iterations is None
            else ov.iterations,
        )

    def _group_key(self) -> tuple:
        """Hashable identity of everything that changes a grouped solve."""
        return (
            self.method, self.spec, self.outlier_frac, self.iterations,
            self.init_from_gptq,
        )


# ---------------------------------------------------------------------------
# Single-layer and grouped solves
# ---------------------------------------------------------------------------


def _quantize_one(w2d: jax.Array, sigma: jax.Array, cfg: PTQConfig):
    """Single (q, p) solve.  Returns (w_hat fp32, h or None, grid or None).

    ``grid`` is the quantization grid the solve actually used, threaded to
    the emit path so stored codes round-trip the solve exactly; methods
    whose emitted tensor is not on a single known grid (AWQ's rescaled
    grids, SpQR's full-precision kept outliers) return None and the emit
    path falls back to re-deriving a grid from Ŵ.
    """
    spec = cfg.spec
    if cfg.method == "rtn":
        grid = compute_grid(w2d, spec)
        return quantize_dequantize(w2d, grid), None, grid
    if cfg.method == "gptq":
        grid = compute_grid(w2d, spec)
        return (
            gptq.gptq_quantize(
                w2d, sigma, spec,
                percdamp=cfg.percdamp, block_size=cfg.block_size, grid=grid,
            ),
            None,
            grid,
        )
    if cfg.method == "awq":
        return awq.awq_quantize(w2d, sigma, spec), None, None
    if cfg.method == "awq_qe":
        # AWQ auto-alpha rescale pre-pass + QuantEase CD on the scaled
        # problem (paper §6; the tuner's optional pre-pass).  The effective
        # weight is off any single uniform grid (column j is rescaled by
        # 1/s_j), so — like awq/spqr — no grid is returned and emit="qt"
        # falls back to a re-derived (lossy) grid.
        w_hat = awq.awq_then_quantease(
            w2d, sigma, spec,
            iterations=cfg.iterations, percdamp=cfg.percdamp,
        )
        return w_hat, None, None
    if cfg.method == "quantease":
        grid = compute_grid(w2d, spec)
        w_init = None
        if cfg.init_from_gptq:
            w_init = gptq.gptq_quantize(
                w2d, sigma, spec,
                percdamp=cfg.percdamp, block_size=cfg.block_size, grid=grid,
            )
        w_hat, _ = quantease.quantease_quantize(
            w2d, sigma, spec,
            w_init=w_init, grid=grid, **cfg.qe_config().solve_kwargs(),
        )
        return w_hat, None, grid
    if cfg.method == "spqr":
        s = max(int(cfg.outlier_frac * w2d.size), 1)
        w_hat, _ = spqr.spqr_quantize(
            w2d, sigma, spec, s=s, percdamp=cfg.percdamp, block_size=cfg.block_size
        )
        return w_hat, None, None
    if cfg.method in ("qe_outlier", "qe_outlier_struct"):
        s = max(int(cfg.outlier_frac * w2d.size), 1)
        res = outlier.outlier_quantease(
            w2d,
            sigma,
            spec,
            s=s,
            iterations=cfg.iterations,
            structured=cfg.method.endswith("struct"),
            percdamp=cfg.percdamp,
            use_kernel=cfg.use_kernel,
            matmul_dtype=cfg.matmul_dtype,
        )
        return res.w_hat, res.h, res.grid
    raise ValueError(cfg.method)


def _solve_batched(w3: jax.Array, sig3: jax.Array, cfg: PTQConfig, grid3):
    """Grouped solve: (G, q, p) × (G, p, p) → (G, q, p) in one vmapped call.

    ``grid3``: batched Grid (leaves (G, q, n_groups)) computed from the
    original weights — the same grid every method here quantizes onto, so
    the emit path can reuse it verbatim.
    """
    spec = cfg.spec
    if cfg.method == "rtn":
        return jax.vmap(quantize_dequantize)(w3, grid3)
    if cfg.method == "gptq":
        return gptq.gptq_quantize(
            w3, sig3, spec,
            percdamp=cfg.percdamp, block_size=cfg.block_size, grid=grid3,
        )
    w_init = None
    if cfg.init_from_gptq:
        w_init = gptq.gptq_quantize(
            w3, sig3, spec,
            percdamp=cfg.percdamp, block_size=cfg.block_size, grid=grid3,
        )
    w_hat, _ = quantease.quantease_quantize(
        w3, sig3, spec,
        w_init=w_init, grid=grid3, **cfg.qe_config().solve_kwargs(),
    )
    return w_hat


def _solve_group(w3: jax.Array, sig3: jax.Array, cfg: PTQConfig, mesh):
    """Solve G stacked same-shape layers; returns (w_hat (G,q,p), hs, grids).

    Batchable methods go through one vmapped (optionally row-sharded) call;
    outlier-aware methods run per-layer inside the same interface so the
    grouped driver upstream stays method-agnostic.  ``grids`` is a per-slice
    list of the Grid each solve quantized onto (None where unavailable).
    """
    G = w3.shape[0]
    if cfg.method in _BATCHED_METHODS:
        grid3 = jax.vmap(lambda wi: compute_grid(wi, cfg.spec))(w3)
        solve = lambda w, s, g: _solve_batched(w, s, cfg, g)
        if mesh is not None and cfg.shard:
            w_hat = _shard_rows(solve, w3, sig3, grid3, mesh)
        else:
            w_hat = solve(w3, sig3, grid3)
        grids = [jax.tree.map(lambda a: a[g], grid3) for g in range(G)]
        return w_hat, [None] * G, grids
    if cfg.method in ("qe_outlier", "qe_outlier_struct"):
        # Fused outlier engine batches like everything else: one vmapped
        # solve per same-shape group.  (Never row-sharded: the unstructured
        # top-s projection is global across output rows, so splitting q
        # would change the solve.)
        s = max(int(cfg.outlier_frac * int(w3[0].size)), 1)
        res = outlier.outlier_quantease(
            w3,
            sig3,
            cfg.spec,
            s=s,
            iterations=cfg.iterations,
            structured=cfg.method.endswith("struct"),
            percdamp=cfg.percdamp,
            use_kernel=cfg.use_kernel,
            matmul_dtype=cfg.matmul_dtype,
        )
        grids = [jax.tree.map(lambda a: a[g], res.grid) for g in range(G)]
        return res.w_hat, [res.h[g] for g in range(G)], grids
    outs, hs, grids = [], [], []
    for g in range(G):
        w_hat, h, grid = _quantize_one(w3[g], sig3[g], cfg)
        outs.append(w_hat)
        hs.append(h)
        grids.append(grid)
    return jnp.stack(outs), hs, grids


def _shard_rows(solve: Callable, w3: jax.Array, sig3: jax.Array, grid3, mesh):
    """shard_map a grouped solve over the independent q (output-row) dim.

    Rows are independent in every column-sweep method (the CD update of row
    i never reads row j), so splitting q across devices is exact; the
    per-row grid shards along with the rows.  Rows pad up to the axis size;
    padded zero rows quantize in isolation (unit pad scale) and are
    stripped.  Single-device meshes skip the wrapper entirely.
    """
    from repro.core.calib import shard_axis

    axis = shard_axis(mesh)
    n = mesh.shape[axis]
    if n <= 1:
        return solve(w3, sig3, grid3)
    from jax.experimental.shard_map import shard_map

    G, q, p = w3.shape
    pad = (-q) % n
    if pad:
        w3 = jnp.pad(w3, ((0, 0), (0, pad), (0, 0)))
        grid3 = dataclasses.replace(
            grid3,
            scale=jnp.pad(
                grid3.scale, ((0, 0), (0, pad), (0, 0)), constant_values=1.0
            ),
            zero=jnp.pad(grid3.zero, ((0, 0), (0, pad), (0, 0))),
        )

    sharded = shard_map(
        solve,
        mesh=mesh,
        in_specs=(
            PartitionSpec(None, axis, None),
            PartitionSpec(None, None, None),
            PartitionSpec(None, axis, None),
        ),
        out_specs=PartitionSpec(None, axis, None),
        check_rep=False,
    )
    return sharded(w3, sig3, grid3)[:, :q]


# ---------------------------------------------------------------------------
# Leaf marshalling
# ---------------------------------------------------------------------------


def _to_2d(w: jax.Array, d_in: int) -> jax.Array:
    return w.reshape(d_in, -1).T.astype(jnp.float32)  # (out, in)


def _from_2d(w2d: jax.Array, like: jax.Array) -> jax.Array:
    d_in = like.shape[0] if like.ndim == 2 else int(np.prod(like.shape) // w2d.shape[0])
    return w2d.T.reshape(like.shape).astype(like.dtype)


def _emit_leaf(w_hat, h, like, cfg: PTQConfig, grid=None):
    if cfg.emit == "fake":
        w_eff = w_hat if h is None else w_hat + h
        return _from_2d(w_eff, like)
    if grid is None:
        # Fallback for methods that don't expose their grid (AWQ/SpQR):
        # re-derive from Ŵ — lossy if Ŵ doesn't attain its grid extremes.
        grid = compute_grid(w_hat, cfg.spec)
    codes = quantize_codes(w_hat, grid)
    packed = cfg.spec.bits == 4 and codes.shape[-1] % 2 == 0
    if packed:
        from repro.quant import pack_codes

        codes = pack_codes(codes, 4)
    qt = QuantizedTensor(
        codes=codes,
        scale=grid.scale,
        zero=grid.zero,
        bits=cfg.spec.bits,
        group_size=cfg.spec.group_size,
        packed=packed,
    )
    if h is not None:
        # Sparse-Ĥ artifact: COO with flat int32 indices + fp16 values
        # (48 bits/outlier — §5.4 accounting) instead of a dense (q, p)
        # fp32 array.  ‖Ĥ‖₀ ≤ s, so top-s by |value| captures the support
        # exactly; pad entries carry (idx 0, value 0) — additive no-ops.
        s = max(int(cfg.outlier_frac * w_hat.size), 1)
        flat = h.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(flat), s)
        qt = dataclasses.replace(
            qt,
            outlier_values=flat[idx].astype(jnp.float16),
            outlier_idx=idx.astype(jnp.int32),
        )
    return qt


# ---------------------------------------------------------------------------
# Block quantization: group → batched solve → scatter back
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Item:
    """One captured linear flattened to solver layout."""

    name: str  # leaf name in the block param dict
    key: str  # report key (scope/name[, .e{i} appended per expert])
    w3: jax.Array  # (G, q, p) — G=1 for dense linears, G=E for MoE
    sig3: jax.Array  # (G, p, p)
    like: jax.Array  # original leaf (or one expert's leaf) for reshaping
    moe: bool


def _collect_items(p_blk: dict, stats: dict, scope: str) -> list[_Item]:
    items = []
    for name, w in p_blk.items():
        key = f"{scope}/{name}"
        if name not in QUANTIZABLE or key not in stats:
            continue
        st: CalibStats = stats[key]
        if name in _MOE_NAMES:
            # w: (E, d_in, d_out); st.sigma: (E, p, p) — already stacked.
            E = w.shape[0]
            w3 = jax.vmap(lambda we: we.reshape(w.shape[1], -1).T)(w).astype(
                jnp.float32
            )
            items.append(_Item(name, key, w3, st.sigma, w[0], True))
        else:
            p = st.p
            items.append(
                _Item(name, key, _to_2d(w, p)[None], st.sigma[None], w, False)
            )
    return items


def _quantize_block(
    p_blk: dict, stats: dict, scope: str, cfg: PTQConfig, report: dict, mesh,
    sens: Optional[dict] = None,
) -> dict:
    """Quantize every captured linear of one block (returns a new dict).

    Items are grouped by (solver shape, effective per-layer config): each
    group — e.g. wq/wk/wv sharing d_model inputs, or wg/wu, or the E
    experts of one MoE matrix — is solved by a single batched call.
    ``cfg.layer_specs`` splits otherwise-identical shapes into separate
    groups whenever their assigned bits/method/outlier budget differ, so
    mixed-precision never shares a vmapped solve across specs.

    ``sens``: optional dict filled with per-layer λ_max(Σ) (same keys as
    ``report``) when ``cfg.collect_sensitivity`` is set.
    """
    items = _collect_items(p_blk, stats, scope)
    groups: dict[tuple, tuple[PTQConfig, list[_Item]]] = {}
    for it in items:
        eff = cfg.for_layer(it.key)
        gk = (it.w3.shape[1:], eff._group_key())
        groups.setdefault(gk, (eff, []))[1].append(it)

    new = dict(p_blk)
    for (shape, _), (eff, group) in groups.items():
        w3 = jnp.concatenate([it.w3 for it in group], axis=0)
        sig3 = jnp.concatenate([it.sig3 for it in group], axis=0)
        w_hat3, hs, grids = _solve_group(w3, sig3, eff, mesh)
        errs = relative_error(w3, _effective(w_hat3, hs), sig3)
        if cfg.collect_sensitivity and sens is not None:
            for it in group:
                lam = jax.vmap(outlier.power_lambda_max)(it.sig3)
                if it.moe:
                    for e in range(it.sig3.shape[0]):
                        sens[f"{it.key}.e{e}"] = float(lam[e])
                else:
                    sens[it.key] = float(lam[0])
        off = 0
        for it in group:
            G = it.w3.shape[0]
            sl = slice(off, off + G)
            _scatter_item(
                it, w_hat3[sl], hs[sl], errs[sl], new, eff, report, grids[sl]
            )
            off += G
    return new


def _effective(w_hat3, hs):
    if all(h is None for h in hs):
        return w_hat3
    return jnp.stack(
        [w if h is None else w + h for w, h in zip(w_hat3, hs)]
    )


def _scatter_item(
    it: _Item, w_hat, hs, errs, new: dict, cfg: PTQConfig, report: dict, grids
):
    if it.moe:
        for e in range(w_hat.shape[0]):
            report[f"{it.key}.e{e}"] = float(errs[e])
        if cfg.emit == "fake":
            new[it.name] = jnp.stack(
                [
                    _from_2d(w if h is None else w + h, it.like)
                    for w, h in zip(w_hat, hs)
                ]
            ).astype(new[it.name].dtype)
        else:
            qts = [
                _emit_leaf(w, h, it.like, cfg, grid)
                for w, h, grid in zip(w_hat, hs, grids)
            ]
            new[it.name] = jax.tree.map(lambda *ls: jnp.stack(ls), *qts)
    else:
        report[it.key] = float(errs[0])
        new[it.name] = _emit_leaf(w_hat[0], hs[0], it.like, cfg, grids[0])


# ---------------------------------------------------------------------------
# Whole-model driver
# ---------------------------------------------------------------------------


def _slice_period(stack, i):
    return jax.tree.map(lambda a: a[i], stack)


def _set_period(stack, i, new_period):
    return jax.tree.map(
        lambda a, n: a.at[i].set(n.astype(a.dtype))
        if not hasattr(n, "codes")
        else n,
        stack,
        new_period,
    )


def _capture_chunks(x: jax.Array, chunk: int):
    """Split a (B, S, d) batch along B into ≤chunk-sequence slices."""
    if chunk <= 0 or x.shape[0] <= chunk:
        return [x]
    return [x[i : i + chunk] for i in range(0, x.shape[0], chunk)]


def _apply_chunked(mcfg, plan, b, blk, x, enc_out, chunk: int) -> jax.Array:
    """Forward one block over ≤chunk-sequence slices (batch dim independent)."""
    x_chunks = _capture_chunks(x, chunk)
    eo_chunks = (
        [None] * len(x_chunks) if enc_out is None else _capture_chunks(enc_out, chunk)
    )
    outs = [
        M._block_apply(
            mcfg, plan.heads, b, blk, xc,
            mode="train", pos_ids=jnp.arange(xc.shape[1]), enc_out=ec,
        )[0]
        for xc, ec in zip(x_chunks, eo_chunks)
    ]
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)


def ptq_quantize_model(
    plan: M.ModelPlan,
    params,
    calib_batches: list[dict],
    cfg: PTQConfig,
    mesh=None,
    progress_cb: Optional[Callable[[dict], None]] = None,
):
    """Quantize a model's decoder (+ encoder) stacks.

    Returns (new_params, report) where report maps layer path → relative
    reconstruction error (paper Fig. 2 metric).

    ``emit="fake"`` keeps the stacked-scan param layout (dequantized values)
    — usable by train_loss/prefill/decode directly.  ``emit="qt"`` returns
    per-period *lists* of blocks with QuantizedTensor leaves (the serving
    engine consumes this unrolled layout).

    ``mesh`` (+ ``cfg.shard``): data-shard Gram accumulation and row-shard
    the CD solves; identical results on one device.  ``progress_cb``
    receives one dict per quantized block — the launcher renders these as
    progress lines and a block-level progress file (an audit trail for
    post-hoc/restart inspection; quantization itself restarts from scratch).
    """
    mcfg = plan.cfg
    report: dict[str, float] = {}
    calib_mesh = mesh if (mesh is not None and cfg.shard) else None

    # --- embed calibration batches once ---
    xs, enc_outs = [], []
    for batch in calib_batches:
        tokens = batch["tokens"]
        x = M._embed_tokens(plan, params, tokens)
        if mcfg.n_prefix:
            pre = M.apply_norm(params["prefix_ln"], batch["patches"].astype(plan.dtype), mcfg.norm)
            x = jnp.concatenate([pre, x], axis=1)
        if mcfg.pos == "learned":
            S = x.shape[1]
            x = x + jax.lax.dynamic_slice(
                params["pos_emb"], (0, 0), (S, mcfg.d_model)
            )[None].astype(plan.dtype)
        xs.append(x)
        enc_outs.append(None)

    new_params = dict(params)

    # --- encoder first (whisper): quantize, then freeze its outputs ---
    if mcfg.family == "encdec":
        enc_inputs = [
            batch["frames"].astype(plan.dtype)
            + params["enc_pos_emb"][None].astype(plan.dtype)
            for batch in calib_batches
        ]
        new_params["enc"], enc_inputs = _quantize_stack(
            plan, params["enc"], mcfg.enc_pattern, mcfg.n_enc_periods,
            enc_inputs, "enc", cfg, report, enc_outs=None,
            mesh=calib_mesh, progress_cb=progress_cb,
        )
        enc_outs = [
            M.apply_norm(params["enc_final_norm"], e, mcfg.norm) for e in enc_inputs
        ]

    new_params["dec"], _ = _quantize_stack(
        plan, params["dec"], mcfg.pattern, mcfg.n_periods, xs, "dec", cfg, report,
        enc_outs=enc_outs, mesh=calib_mesh, progress_cb=progress_cb,
    )
    return new_params, report


def _quantize_stack(
    plan, stack, pattern, n_periods, xs, stack_name, cfg, report, enc_outs,
    mesh=None, progress_cb=None,
):
    mcfg = plan.cfg
    quantized_periods = []  # for emit="qt": list of {bi: block params}
    stack_out = stack
    n_blocks_total = n_periods * len(pattern)
    for period in range(n_periods):
        p_period = _slice_period(stack, period)
        new_period = {}
        for i, b in enumerate(pattern):
            t0 = time.monotonic()
            scope = f"{stack_name}.p{period}.b{i}"
            stats: dict[str, CalibStats] = {}
            # Capture pass: current block, current (quantized-prefix) inputs.
            # Each chunk's activations fold into Σ immediately — nothing but
            # the p×p accumulators survives this loop.
            with capture_gram_stats(stats, mesh=mesh), capture_scope(scope):
                for bi, x in enumerate(xs):
                    eo = None if enc_outs is None else enc_outs[bi]
                    x_chunks = _capture_chunks(x, cfg.stream_chunk)
                    eo_chunks = (
                        [None] * len(x_chunks)
                        if eo is None
                        else _capture_chunks(eo, cfg.stream_chunk)
                    )
                    for xc, ec in zip(x_chunks, eo_chunks):
                        pos = jnp.arange(xc.shape[1])
                        M._block_apply(
                            mcfg, plan.heads, b, p_period[f"b{i}"], xc,
                            mode="train", pos_ids=pos, enc_out=ec,
                        )
            n_before = len(report)
            sens: dict[str, float] = {}
            new_blk = _quantize_block(
                p_period[f"b{i}"], stats, scope, cfg, report, mesh, sens=sens
            )
            new_period[f"b{i}"] = new_blk
            # Recompute this block's outputs with quantized weights — chunked
            # like the capture pass, so stream_chunk bounds transient
            # activation memory in *both* passes (the stored block inputs xs
            # themselves are the pipeline's irreducible working set).
            xs = [
                _apply_chunked(
                    mcfg, plan, b, new_blk, x,
                    None if enc_outs is None else enc_outs[bi],
                    cfg.stream_chunk,
                )
                for bi, x in enumerate(xs)
            ]
            if progress_cb is not None:
                new_keys = list(report)[n_before:]
                errs = [report[k] for k in new_keys]
                rec = {
                    "stack": stack_name,
                    "period": period,
                    "block": i,
                    "done_blocks": period * len(pattern) + i + 1,
                    "total_blocks": n_blocks_total,
                    "n_linears": len(new_keys),
                    "mean_rel_error": float(np.mean(errs)) if errs else 0.0,
                    # Full-resolution per-layer errors, keyed by layer path.
                    # The auto-tuner ranks layers on these — never on any
                    # downstream-rounded aggregate (eval/harness.py rounds
                    # its reported mean to 6 digits; that rounding must not
                    # reach the sensitivity signal).
                    "layer_errors": {k: float(report[k]) for k in new_keys},
                    "seconds": round(time.monotonic() - t0, 3),
                }
                if sens:
                    rec["lambda_max"] = sens
                progress_cb(rec)
        quantized_periods.append(new_period)
        if cfg.emit == "fake":
            stack_out = _set_period(stack_out, period, new_period)
    if cfg.emit == "qt":
        return quantized_periods, xs
    return stack_out, xs
