"""Whole-model PTQ: the paper's pipeline, layer-by-layer over a real model.

Mirrors the reference GPTQ/QuantEase flow (paper §5 setup):

  * run calibration batches through the model **block by block**; the inputs
    feeding each block are the outputs of the *already-quantized* prefix
    (error propagation across blocks, as all layer-wise PTQ codebases do),
  * per linear, accumulate Σ = XXᵀ streaming over batches (fp32, the only
    statistic any method needs — ``p² + O(pq)`` memory, paper §3.2),
  * quantize with the chosen method, write back (fake-quant bf16 leaves or
    :class:`QuantizedTensor` leaves for real serving),
  * record per-layer relative errors — the data behind the paper's Fig. 2.

Quantized leaf set: every matmul the model zoo routes through
``apply_linear`` except numerically-critical small tensors (mamba Δ
projection ``wdt``; norms; biases; MoE router) — see DESIGN.md
§Arch-applicability.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import awq, gptq, outlier, quantease, rtn, spqr
from repro.core.quantease import relative_error
from repro.models import model as M
from repro.models.common import capture_linear_inputs, capture_scope
from repro.quant import GridSpec, QuantizedTensor, compute_grid, quantize_codes

__all__ = ["PTQConfig", "ptq_quantize_model", "QUANTIZABLE"]

QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "wq_c", "wk_c", "wv_c", "wo_c",
    "wg", "wu", "wd",
    "wz", "wx", "wbc", "out_proj",
    "w_gate", "w_up", "w_down",
}
_MOE_NAMES = {"w_gate", "w_up", "w_down"}


@dataclasses.dataclass
class PTQConfig:
    method: str = "quantease"  # rtn|gptq|awq|quantease|spqr|qe_outlier|qe_outlier_struct
    spec: GridSpec = dataclasses.field(default_factory=lambda: GridSpec(bits=4))
    iterations: int = 25
    outlier_frac: float = 0.01  # for outlier-aware methods
    percdamp: float = 0.01
    block_size: int = 128
    emit: str = "fake"  # "fake" (dequantized bf16) | "qt" (QuantizedTensor)
    init_from_gptq: bool = False  # QuantEase warm start (paper §3.1)


def _quantize_one(w2d: jax.Array, sigma: jax.Array, cfg: PTQConfig):
    """Returns (w_hat fp32, h or None)."""
    spec = cfg.spec
    if cfg.method == "rtn":
        return rtn.rtn_quantize(w2d, spec), None
    if cfg.method == "gptq":
        return (
            gptq.gptq_quantize(
                w2d, sigma, spec, percdamp=cfg.percdamp, block_size=cfg.block_size
            ),
            None,
        )
    if cfg.method == "awq":
        return awq.awq_quantize(w2d, sigma, spec), None
    if cfg.method == "quantease":
        w_init = None
        if cfg.init_from_gptq:
            w_init = gptq.gptq_quantize(
                w2d, sigma, spec, percdamp=cfg.percdamp, block_size=cfg.block_size
            )
        w_hat, _ = quantease.quantease_quantize(
            w2d,
            sigma,
            spec,
            iterations=cfg.iterations,
            percdamp=cfg.percdamp,
            w_init=w_init,
        )
        return w_hat, None
    if cfg.method == "spqr":
        s = max(int(cfg.outlier_frac * w2d.size), 1)
        w_hat, _ = spqr.spqr_quantize(
            w2d, sigma, spec, s=s, percdamp=cfg.percdamp, block_size=cfg.block_size
        )
        return w_hat, None
    if cfg.method in ("qe_outlier", "qe_outlier_struct"):
        s = max(int(cfg.outlier_frac * w2d.size), 1)
        res = outlier.outlier_quantease(
            w2d,
            sigma,
            spec,
            s=s,
            iterations=cfg.iterations,
            structured=cfg.method.endswith("struct"),
            percdamp=cfg.percdamp,
        )
        return res.w_hat, res.h
    raise ValueError(cfg.method)


def _to_2d(w: jax.Array, d_in: int) -> jax.Array:
    return w.reshape(d_in, -1).T.astype(jnp.float32)  # (out, in)


def _from_2d(w2d: jax.Array, like: jax.Array) -> jax.Array:
    d_in = like.shape[0] if like.ndim == 2 else int(np.prod(like.shape) // w2d.shape[0])
    return w2d.T.reshape(like.shape).astype(like.dtype)


def _emit_leaf(w_hat, h, like, cfg: PTQConfig):
    if cfg.emit == "fake":
        w_eff = w_hat if h is None else w_hat + h
        return _from_2d(w_eff, like)
    grid = compute_grid(w_hat, cfg.spec)
    codes = quantize_codes(w_hat, grid)
    packed = cfg.spec.bits == 4 and codes.shape[-1] % 2 == 0
    if packed:
        from repro.quant import pack_codes

        codes = pack_codes(codes, 4)
    qt = QuantizedTensor(
        codes=codes,
        scale=grid.scale,
        zero=grid.zero,
        bits=cfg.spec.bits,
        group_size=cfg.spec.group_size,
        packed=packed,
    )
    if h is not None:
        s = max(int(cfg.outlier_frac * w_hat.size), 1)
        flat = jnp.abs(h).reshape(-1)
        _, idx = jax.lax.top_k(flat, s)
        rows, cols = idx // h.shape[1], idx % h.shape[1]
        qt = dataclasses.replace(
            qt,
            outlier_values=h.reshape(-1)[idx],
            outlier_rows=rows.astype(jnp.int32),
            outlier_cols=cols.astype(jnp.int32),
        )
    return qt


def _sigma_from_records(xs: list[jax.Array]) -> jax.Array:
    p = xs[0].shape[-1]
    sigma = jnp.zeros((p, p), jnp.float32)
    for x in xs:
        x32 = x.astype(jnp.float32)
        sigma = sigma + x32.T @ x32
    return sigma


def _quantize_block(p_blk: dict, records: dict, scope: str, cfg: PTQConfig, report: dict):
    """Quantize every captured linear of one block, in place (returns copy)."""
    new = dict(p_blk)
    for name, w in p_blk.items():
        if name not in QUANTIZABLE or f"{scope}/{name}" not in records:
            continue
        xs = records[f"{scope}/{name}"]
        if name in _MOE_NAMES:
            # xs: list of (E, C, d_in); per-expert Σ and per-expert quantize.
            E = w.shape[0]
            outs, hs = [], []
            for e in range(E):
                sigma = _sigma_from_records([x[e] for x in xs])
                w2d = w[e].reshape(w.shape[1], -1).T.astype(jnp.float32)
                w_hat, h = _quantize_one(w2d, sigma, cfg)
                report[f"{scope}/{name}.e{e}"] = float(
                    relative_error(w2d, w_hat if h is None else w_hat + h, sigma)
                )
                outs.append(w_hat)
                hs.append(h)
            if cfg.emit == "fake":
                new[name] = jnp.stack(
                    [
                        _from_2d(o if h is None else o + h, w[0])
                        for o, h in zip(outs, hs)
                    ]
                ).astype(w.dtype)
            else:
                qts = [
                    _emit_leaf(o, h, w[0], cfg) for o, h in zip(outs, hs)
                ]
                new[name] = jax.tree.map(lambda *ls: jnp.stack(ls), *qts)
        else:
            sigma = _sigma_from_records(xs)
            d_in = xs[0].shape[-1]
            w2d = _to_2d(w, d_in)
            w_hat, h = _quantize_one(w2d, sigma, cfg)
            report[f"{scope}/{name}"] = float(
                relative_error(w2d, w_hat if h is None else w_hat + h, sigma)
            )
            new[name] = _emit_leaf(w_hat, h, w, cfg)
    return new


def _slice_period(stack, i):
    return jax.tree.map(lambda a: a[i], stack)


def _set_period(stack, i, new_period):
    return jax.tree.map(
        lambda a, n: a.at[i].set(n.astype(a.dtype))
        if not hasattr(n, "codes")
        else n,
        stack,
        new_period,
    )


def ptq_quantize_model(
    plan: M.ModelPlan,
    params,
    calib_batches: list[dict],
    cfg: PTQConfig,
):
    """Quantize a model's decoder (+ encoder) stacks.

    Returns (new_params, report) where report maps layer path → relative
    reconstruction error (paper Fig. 2 metric).

    ``emit="fake"`` keeps the stacked-scan param layout (dequantized values)
    — usable by train_loss/prefill/decode directly.  ``emit="qt"`` returns
    per-period *lists* of blocks with QuantizedTensor leaves (the serving
    engine consumes this unrolled layout).
    """
    mcfg = plan.cfg
    report: dict[str, float] = {}

    # --- embed calibration batches once ---
    xs, enc_outs = [], []
    for batch in calib_batches:
        tokens = batch["tokens"]
        x = M._embed_tokens(plan, params, tokens)
        if mcfg.n_prefix:
            pre = M.apply_norm(params["prefix_ln"], batch["patches"].astype(plan.dtype), mcfg.norm)
            x = jnp.concatenate([pre, x], axis=1)
        if mcfg.pos == "learned":
            S = x.shape[1]
            x = x + jax.lax.dynamic_slice(
                params["pos_emb"], (0, 0), (S, mcfg.d_model)
            )[None].astype(plan.dtype)
        xs.append(x)
        enc_outs.append(None)

    new_params = dict(params)

    # --- encoder first (whisper): quantize, then freeze its outputs ---
    if mcfg.family == "encdec":
        enc_inputs = [
            batch["frames"].astype(plan.dtype)
            + params["enc_pos_emb"][None].astype(plan.dtype)
            for batch in calib_batches
        ]
        new_params["enc"], enc_inputs = _quantize_stack(
            plan, params["enc"], mcfg.enc_pattern, mcfg.n_enc_periods,
            enc_inputs, "enc", cfg, report, enc_outs=None,
        )
        enc_outs = [
            M.apply_norm(params["enc_final_norm"], e, mcfg.norm) for e in enc_inputs
        ]

    new_params["dec"], _ = _quantize_stack(
        plan, params["dec"], mcfg.pattern, mcfg.n_periods, xs, "dec", cfg, report,
        enc_outs=enc_outs,
    )
    return new_params, report


def _quantize_stack(plan, stack, pattern, n_periods, xs, stack_name, cfg, report, enc_outs):
    mcfg = plan.cfg
    quantized_periods = []  # for emit="qt": list of {bi: block params}
    stack_out = stack
    for period in range(n_periods):
        p_period = _slice_period(stack, period)
        new_period = {}
        for i, b in enumerate(pattern):
            scope = f"{stack_name}.p{period}.b{i}"
            records: dict = {}
            # capture pass: current block, current (quantized-prefix) inputs
            with capture_linear_inputs(records), capture_scope(scope):
                for bi, x in enumerate(xs):
                    pos = jnp.arange(x.shape[1])
                    M._block_apply(
                        mcfg, plan.heads, b, p_period[f"b{i}"], x,
                        mode="train", pos_ids=pos,
                        enc_out=None if enc_outs is None else enc_outs[bi],
                    )
            new_blk = _quantize_block(p_period[f"b{i}"], records, scope, cfg, report)
            new_period[f"b{i}"] = new_blk
            # recompute this block's outputs with quantized weights
            blk_for_fwd = new_blk if cfg.emit == "fake" else new_blk
            xs = [
                M._block_apply(
                    mcfg, plan.heads, b, blk_for_fwd, x,
                    mode="train", pos_ids=jnp.arange(x.shape[1]),
                    enc_out=None if enc_outs is None else enc_outs[bi],
                )[0]
                for bi, x in enumerate(xs)
            ]
        quantized_periods.append(new_period)
        if cfg.emit == "fake":
            stack_out = _set_period(stack_out, period, new_period)
    if cfg.emit == "qt":
        return quantized_periods, xs
    return stack_out, xs
