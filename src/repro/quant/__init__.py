"""Quantization substrate: uniform grids, code packing, quantized tensors.

This package contains the *representation* layer shared by every PTQ
algorithm in :mod:`repro.core` and by the quantized serving path in
:mod:`repro.serve` / :mod:`repro.kernels`.
"""

from repro.quant.grid import (
    GridSpec,
    Grid,
    compute_grid,
    compute_grid_excluding_outliers,
    quantize_codes,
    dequantize_codes,
    quantize_dequantize,
)
from repro.quant.pack import (
    pack_codes,
    unpack_codes,
    packed_words_per_row,
    tile_native_perm,
    prepack_codes,
    unprepack_codes,
    kv_pack_int4,
    kv_unpack_int4,
)
from repro.quant.qtensor import QuantizedTensor, quantize_tensor, dequantize_tensor

__all__ = [
    "GridSpec",
    "Grid",
    "compute_grid",
    "compute_grid_excluding_outliers",
    "quantize_codes",
    "dequantize_codes",
    "quantize_dequantize",
    "pack_codes",
    "unpack_codes",
    "packed_words_per_row",
    "tile_native_perm",
    "prepack_codes",
    "unprepack_codes",
    "kv_pack_int4",
    "kv_unpack_int4",
    "QuantizedTensor",
    "quantize_tensor",
    "dequantize_tensor",
]
