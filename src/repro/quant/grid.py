"""Uniform per-channel / per-group quantization grids.

The paper (QuantEase §2.1) quantizes each output channel ``i`` of a weight
matrix ``W ∈ R^{q×p}`` onto a finite uniformly-spaced set ``Q_i``.  Following
GPTQ's convention we parameterize ``Q_i`` by an (asymmetric) affine grid::

    Q_i = { s_i * (c - z_i) : c ∈ {0, ..., 2^bits - 1} }

so the nearest-grid-point operator is ``q_i(x) = s_i * (clip(round(x/s_i) +
z_i, 0, 2^b-1) - z_i)``.  ``group_size`` generalizes to one (s, z) pair per
contiguous group of input columns (the paper doesn't use grouping for its
headline results but notes it is trivially compatible; we support it as a
first-class option).

All math is fp32; shapes use the paper's layout ``W: (q, p)`` = (out, in).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "GridSpec",
    "Grid",
    "compute_grid",
    "compute_grid_excluding_outliers",
    "quantize_codes",
    "dequantize_codes",
    "quantize_dequantize",
]


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static description of a quantization grid.

    Attributes:
      bits: code width (2, 3, 4 or 8).
      symmetric: if True, zero-point is fixed at the grid midpoint
        (``z = 2^{b-1}``) and scale is set from max(|W|); if False (default,
        matching GPTQ/QuantEase experiments), asymmetric min/max affine grid.
      group_size: columns per (scale, zero) group; ``None`` means one group
        spanning the whole row (per-channel, as in the paper).
    """

    bits: int = 4
    symmetric: bool = False
    group_size: Optional[int] = None

    def __post_init__(self):
        if self.bits not in (2, 3, 4, 8):
            raise ValueError(f"unsupported bit-width {self.bits}")
        if self.group_size is not None and self.group_size <= 0:
            raise ValueError("group_size must be positive")

    @property
    def n_levels(self) -> int:
        return 1 << self.bits

    def n_groups(self, p: int) -> int:
        g = self.group_size or p
        return -(-p // g)  # ceil


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Grid:
    """Concrete grid: per-(row, group) scales and zero-points.

    ``scale``/``zero``: fp32 arrays of shape ``(q, n_groups)``.
    ``zero`` is kept in fp32 (it is integral by construction but fp32 avoids
    dtype churn inside the CD inner loop).  Registered as a pytree with the
    spec static, so a Grid can cross jit boundaries.
    """

    spec: GridSpec = dataclasses.field(metadata=dict(static=True))
    scale: jax.Array = dataclasses.field(default=None)
    zero: jax.Array = dataclasses.field(default=None)

    def per_column(self, p: int) -> tuple[jax.Array, jax.Array]:
        """Expand (q, n_groups) → (q, p) per-column scale/zero views."""
        g = self.spec.group_size or p
        idx = jnp.arange(p) // g
        return self.scale[:, idx], self.zero[:, idx]


def _group_reduce(w: jax.Array, group_size: Optional[int], fn) -> jax.Array:
    """Reduce (q, p) → (q, n_groups) with `fn` over each column group.

    Ragged grids (``p % group_size != 0``): the tail group reduces over its
    true (narrower) column span — the edge-value padding below is range-
    neutral for min/max/absmax, and every consumer maps columns to groups
    by ``col // group_size`` (``Grid.per_column``), never by inferring a
    uniform ``ceil(p / n_groups)`` width.  The serving side had exactly
    that ceil-inference bug (fixed in PR 2); the quantization side is
    audited clean and pinned by tests/test_quant.py::test_ragged_group_*.
    """
    q, p = w.shape
    g = group_size or p
    n_groups = -(-p // g)
    pad = n_groups * g - p
    if pad:
        # Pad with edge values so padding never widens the range.
        w = jnp.concatenate([w, jnp.repeat(w[:, -1:], pad, axis=1)], axis=1)
    return fn(w.reshape(q, n_groups, g), axis=2)


def compute_grid(w: jax.Array, spec: GridSpec) -> Grid:
    """Min/max (or symmetric max-abs) grid from the weights themselves."""
    w = w.astype(jnp.float32)
    n = spec.n_levels - 1
    if spec.symmetric:
        amax = _group_reduce(jnp.abs(w), spec.group_size, jnp.max)
        scale = jnp.maximum(2.0 * amax / n, 1e-12)
        zero = jnp.full_like(scale, float(1 << (spec.bits - 1)))
    else:
        wmin = jnp.minimum(_group_reduce(w, spec.group_size, jnp.min), 0.0)
        wmax = jnp.maximum(_group_reduce(w, spec.group_size, jnp.max), 0.0)
        scale = jnp.maximum((wmax - wmin) / n, 1e-12)
        zero = jnp.round(-wmin / scale)
    return Grid(spec=spec, scale=scale, zero=zero)


def compute_grid_excluding_outliers(
    w: jax.Array, spec: GridSpec, outlier_mask: jax.Array
) -> Grid:
    """Grid over non-outlier weights only (QuantEase §4.3 range shrink).

    The outlier-aware formulation removes the top-s magnitude weights from the
    quantization pool before computing per-channel ranges; ``outlier_mask`` is
    a boolean (q, p) array, True where the weight is an outlier.
    """
    w = w.astype(jnp.float32)
    n = spec.n_levels - 1
    keep = ~outlier_mask
    if spec.symmetric:
        amax = _group_reduce(jnp.where(keep, jnp.abs(w), 0.0), spec.group_size, jnp.max)
        scale = jnp.maximum(2.0 * amax / n, 1e-12)
        zero = jnp.full_like(scale, float(1 << (spec.bits - 1)))
    else:
        big = jnp.float32(3.4e38)
        wmin = jnp.minimum(
            _group_reduce(jnp.where(keep, w, big), spec.group_size, jnp.min), 0.0
        )
        wmax = jnp.maximum(
            _group_reduce(jnp.where(keep, w, -big), spec.group_size, jnp.max), 0.0
        )
        scale = jnp.maximum((wmax - wmin) / n, 1e-12)
        zero = jnp.round(-wmin / scale)
    return Grid(spec=spec, scale=scale, zero=zero)


def quantize_codes(w: jax.Array, grid: Grid) -> jax.Array:
    """Nearest-grid-point codes: (q, p) fp → (q, p) uint8."""
    q, p = w.shape
    scale, zero = grid.per_column(p)
    n = grid.spec.n_levels - 1
    codes = jnp.clip(jnp.round(w.astype(jnp.float32) / scale) + zero, 0, n)
    return codes.astype(jnp.uint8)


def dequantize_codes(codes: jax.Array, grid: Grid, dtype=jnp.float32) -> jax.Array:
    q, p = codes.shape
    scale, zero = grid.per_column(p)
    return ((codes.astype(jnp.float32) - zero) * scale).astype(dtype)


def quantize_dequantize(w: jax.Array, grid: Grid) -> jax.Array:
    """The operator ``q_i(·)`` of the paper (Eq. 2), vectorized: fp32 → fp32
    nearest grid value.  This is the exact map used inside every CD update."""
    q, p = w.shape
    scale, zero = grid.per_column(p)
    n = grid.spec.n_levels - 1
    codes = jnp.clip(jnp.round(w.astype(jnp.float32) / scale) + zero, 0, n)
    return (codes - zero) * scale
