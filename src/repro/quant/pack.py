"""Bit-packing of quantization codes into dense uint8 storage.

Storage layout: codes (…, p) uint8 with values < 2^bits are packed along the
last axis into ``ceil(p * bits / 8)`` bytes, little-endian within each byte
(code k occupies bits ``[ (k*bits) % 8, ... )`` of byte ``(k*bits)//8``).
2-, 4- and 8-bit codes never straddle byte boundaries; 3-bit codes do, and
are handled by the generic bit-blit path (packed 3-bit is a *storage /
checkpoint* format — the serving kernels consume 2/4/8-bit packed planes or
raw uint8 codes; see DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pack_codes", "unpack_codes", "packed_words_per_row"]


def packed_words_per_row(p: int, bits: int) -> int:
    return -(-p * bits // 8)


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """(…, p) uint8 codes → (…, ceil(p*bits/8)) uint8 packed."""
    if codes.dtype != jnp.uint8:
        codes = codes.astype(jnp.uint8)
    p = codes.shape[-1]
    if bits == 8:
        return codes
    if bits in (2, 4):
        per_byte = 8 // bits
        pad = (-p) % per_byte
        if pad:
            codes = jnp.concatenate(
                [codes, jnp.zeros(codes.shape[:-1] + (pad,), jnp.uint8)], axis=-1
            )
        grouped = codes.reshape(codes.shape[:-1] + (-1, per_byte)).astype(jnp.uint32)
        shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits)
        packed = (grouped << shifts).sum(axis=-1, dtype=jnp.uint32)
        return packed.astype(jnp.uint8)
    if bits == 3:
        # Generic bit-blit via a (p, 3)-bit boolean plane.
        bitplane = (
            (codes[..., :, None].astype(jnp.uint32) >> jnp.arange(3, dtype=jnp.uint32))
            & 1
        ).reshape(codes.shape[:-1] + (p * 3,))
        nbytes = packed_words_per_row(p, 3)
        pad = nbytes * 8 - p * 3
        if pad:
            bitplane = jnp.concatenate(
                [bitplane, jnp.zeros(bitplane.shape[:-1] + (pad,), bitplane.dtype)],
                axis=-1,
            )
        by = bitplane.reshape(bitplane.shape[:-1] + (nbytes, 8))
        packed = (by << jnp.arange(8, dtype=jnp.uint32)).sum(axis=-1, dtype=jnp.uint32)
        return packed.astype(jnp.uint8)
    raise ValueError(f"unsupported bits={bits}")


def unpack_codes(packed: jax.Array, bits: int, p: int) -> jax.Array:
    """Inverse of :func:`pack_codes`; returns (…, p) uint8 codes."""
    if bits == 8:
        return packed[..., :p]
    if bits in (2, 4):
        per_byte = 8 // bits
        mask = jnp.uint8((1 << bits) - 1)
        shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits)
        codes = (packed[..., :, None].astype(jnp.uint32) >> shifts) & mask
        return codes.reshape(packed.shape[:-1] + (-1,))[..., :p].astype(jnp.uint8)
    if bits == 3:
        bitplane = (
            (packed[..., :, None].astype(jnp.uint32) >> jnp.arange(8, dtype=jnp.uint32))
            & 1
        ).reshape(packed.shape[:-1] + (-1,))[..., : p * 3]
        tri = bitplane.reshape(bitplane.shape[:-1] + (p, 3))
        codes = (tri << jnp.arange(3, dtype=jnp.uint32)).sum(axis=-1, dtype=jnp.uint32)
        return codes.astype(jnp.uint8)
    raise ValueError(f"unsupported bits={bits}")
