"""Bit-packing of quantization codes into dense uint8 storage.

Storage layout: codes (…, p) uint8 with values < 2^bits are packed along the
last axis into ``ceil(p * bits / 8)`` bytes, little-endian within each byte
(code k occupies bits ``[ (k*bits) % 8, ... )`` of byte ``(k*bits)//8``).
2-, 4- and 8-bit codes never straddle byte boundaries; 3-bit codes do, and
are handled by the generic bit-blit path (packed 3-bit is a *storage /
checkpoint* format — the serving kernels consume 2/4/8-bit packed planes or
raw uint8 codes; see DESIGN.md §3).

Two serving-oriented layouts ride on top of the linear format
(DESIGN.md §Packed-serving):

* **Tile-native prepack** (:func:`prepack_codes`): within each k-tile of
  ``tile_k`` columns, the columns are reordered *plane-wise* before packing
  — byte ``i`` of a 4-bit tile holds columns ``(i, i + tile_k/2)`` in its
  (lo, hi) nibbles — so the dequant-matmul kernel reconstructs the tile
  with two shifts and a **concatenate** (contiguous words) instead of the
  lane-scattering stack/reshape interleave the linear layout forces.  Any
  ragged tail (``p % tile_k``) stays linear; the transform is a pure column
  permutation, so dequantization is bit-exact vs the linear layout.

* **Fold-in-half int4 KV packing** (:func:`kv_pack_int4`): the paged KV
  pages store two signed int4 codes per byte with the *first half* of the
  head dim in low nibbles and the second half in high nibbles — the same
  concat-not-interleave property for the paged-attention kernel's unpack.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "pack_codes", "unpack_codes", "packed_words_per_row",
    "tile_native_perm", "prepack_codes", "unprepack_codes",
    "kv_pack_int4", "kv_unpack_int4",
]


def packed_words_per_row(p: int, bits: int) -> int:
    return -(-p * bits // 8)


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """(…, p) uint8 codes → (…, ceil(p*bits/8)) uint8 packed."""
    if codes.dtype != jnp.uint8:
        codes = codes.astype(jnp.uint8)
    p = codes.shape[-1]
    if bits == 8:
        return codes
    if bits in (2, 4):
        per_byte = 8 // bits
        pad = (-p) % per_byte
        if pad:
            codes = jnp.concatenate(
                [codes, jnp.zeros(codes.shape[:-1] + (pad,), jnp.uint8)], axis=-1
            )
        grouped = codes.reshape(codes.shape[:-1] + (-1, per_byte)).astype(jnp.uint32)
        shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits)
        packed = (grouped << shifts).sum(axis=-1, dtype=jnp.uint32)
        return packed.astype(jnp.uint8)
    if bits == 3:
        # Generic bit-blit via a (p, 3)-bit boolean plane.
        bitplane = (
            (codes[..., :, None].astype(jnp.uint32) >> jnp.arange(3, dtype=jnp.uint32))
            & 1
        ).reshape(codes.shape[:-1] + (p * 3,))
        nbytes = packed_words_per_row(p, 3)
        pad = nbytes * 8 - p * 3
        if pad:
            bitplane = jnp.concatenate(
                [bitplane, jnp.zeros(bitplane.shape[:-1] + (pad,), bitplane.dtype)],
                axis=-1,
            )
        by = bitplane.reshape(bitplane.shape[:-1] + (nbytes, 8))
        packed = (by << jnp.arange(8, dtype=jnp.uint32)).sum(axis=-1, dtype=jnp.uint32)
        return packed.astype(jnp.uint8)
    raise ValueError(f"unsupported bits={bits}")


def unpack_codes(packed: jax.Array, bits: int, p: int) -> jax.Array:
    """Inverse of :func:`pack_codes`; returns (…, p) uint8 codes."""
    if bits == 8:
        return packed[..., :p]
    if bits in (2, 4):
        per_byte = 8 // bits
        mask = jnp.uint8((1 << bits) - 1)
        shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits)
        codes = (packed[..., :, None].astype(jnp.uint32) >> shifts) & mask
        return codes.reshape(packed.shape[:-1] + (-1,))[..., :p].astype(jnp.uint8)
    if bits == 3:
        bitplane = (
            (packed[..., :, None].astype(jnp.uint32) >> jnp.arange(8, dtype=jnp.uint32))
            & 1
        ).reshape(packed.shape[:-1] + (-1,))[..., : p * 3]
        tri = bitplane.reshape(bitplane.shape[:-1] + (p, 3))
        codes = (tri << jnp.arange(3, dtype=jnp.uint32)).sum(axis=-1, dtype=jnp.uint32)
        return codes.astype(jnp.uint8)
    raise ValueError(f"unsupported bits={bits}")


# ---------------------------------------------------------------------------
# Tile-native (plane-wise) layout for the serving GEMM
# ---------------------------------------------------------------------------

# Codes per byte-aligned packing word: how many columns one storage byte
# interleaves in the linear layout (3-bit codes straddle bytes; their word
# is the 3-byte / 8-code block).
_PLANES = {2: 4, 3: 8, 4: 2, 8: 1}


def tile_native_perm(p: int, bits: int, tile_k: int) -> np.ndarray:
    """Column permutation putting each full k-tile in plane-wise order.

    With ``n = _PLANES[bits]`` planes, tile column ``j`` moves so that
    storage word ``i`` of the tile packs columns ``(i, i + tile_k/n, …,
    i + (n-1)·tile_k/n)`` — one column per plane.  Unpacking a tile is then
    ``concatenate([plane_0, …, plane_{n-1}], axis=-1)``, already in natural
    column order.  The ragged tail past the last full tile keeps the linear
    order (the kernel never sees it — the pack decision requires
    ``p % tile_k == 0`` for the Pallas path; refs un-permute exactly).
    """
    n = _PLANES[bits]
    cols = np.arange(p, dtype=np.int64)
    n_full = p // tile_k
    if n == 1 or tile_k % n or n_full == 0:
        return cols
    head = cols[: n_full * tile_k].reshape(n_full, n, tile_k // n)
    head = head.transpose(0, 2, 1).reshape(-1)
    return np.concatenate([head, cols[n_full * tile_k:]])


def prepack_codes(codes: jax.Array, bits: int, tile_k: int) -> jax.Array:
    """(…, p) uint8 linear codes → packed bytes in tile-native order."""
    perm = tile_native_perm(codes.shape[-1], bits, tile_k)
    return pack_codes(codes[..., perm], bits)


def unprepack_codes(packed: jax.Array, bits: int, p: int, tile_k: int) -> jax.Array:
    """Inverse of :func:`prepack_codes` — (…, p) uint8 codes, linear order."""
    perm = tile_native_perm(p, bits, tile_k)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(p, dtype=np.int64)
    return unpack_codes(packed, bits, p)[..., inv]


# ---------------------------------------------------------------------------
# int4 KV page packing (paged serving)
# ---------------------------------------------------------------------------


def kv_pack_int4(codes: jax.Array) -> jax.Array:
    """(…, hd) signed int codes in [-7, 7] → (…, hd/2) uint8, fold-in-half:
    byte d carries element d in its low nibble (two's complement) and
    element d + hd/2 in its high nibble."""
    hd = codes.shape[-1]
    if hd % 2:
        raise ValueError(f"int4 KV packing requires an even head dim, got {hd}")
    c = codes.astype(jnp.int32)
    lo = c[..., : hd // 2] & 0xF
    hi = c[..., hd // 2 :] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def kv_unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`kv_pack_int4` — (…, hd) int8 codes in [-8, 7]."""
    b = packed.astype(jnp.int32)
    lo = ((b & 0xF) ^ 8) - 8  # sign-extend the 4-bit two's complement
    hi = ((b >> 4) ^ 8) - 8
    return jnp.concatenate([lo, hi], axis=-1).astype(jnp.int8)
