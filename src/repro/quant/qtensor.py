"""QuantizedTensor — the pytree carried by quantized checkpoints & serving.

Layout convention matches the paper: a linear layer computes ``y = x @ W^T``
with ``W: (q, p)`` (out, in).  A ``QuantizedTensor`` stores:

  * ``codes``  — (q, p) uint8 quantization codes (kept *unpacked* in memory;
    :mod:`repro.quant.pack` provides the packed storage format used by
    checkpoints, and the Pallas dequant-matmul consumes either),
  * ``scale`` / ``zero`` — (q, n_groups) fp32 affine grid,
  * ``outlier_values`` / ``outlier_idx`` — optional COO rank-s correction
    ``H`` (QuantEase §4: W ≈ Ŵ + H, ‖H‖₀ ≤ s) stored as fp16 values plus
    flat row-major int32 indices (``idx = row·p + col`` — 48 bits/outlier
    total, the §5.4 accounting), padded to a static ``s`` so the pytree has
    static shapes (padding entries carry value 0 and index 0 — a zero-valued
    update is a no-op),
  * ``outlier_col_idx`` / ``outlier_col_vals`` — optional *structured* column
    outliers (whole fp columns; QuantEase §4.3 "Structured Outliers").

The effective weight is ``W_eff = dequant(codes) + H`` (element-wise H wins
over the quantized value only through addition — QuantEase's formulation is
additive, so no masking is required at serve time).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.grid import Grid, GridSpec, compute_grid, quantize_codes

__all__ = ["QuantizedTensor", "quantize_tensor", "dequantize_tensor"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedTensor:
    codes: jax.Array  # (q, p) uint8 — or (q, p/2) when packed (int4)
    scale: jax.Array  # (q, n_groups) fp32
    zero: jax.Array  # (q, n_groups) fp32
    bits: int = dataclasses.field(metadata=dict(static=True), default=4)
    group_size: Optional[int] = dataclasses.field(
        metadata=dict(static=True), default=None
    )
    packed: bool = dataclasses.field(metadata=dict(static=True), default=False)
    # Serving storage layout of `codes` (DESIGN.md §Packed-serving):
    # "linear" — pack.py's little-endian column order; "tile" — tile-native
    # plane-wise prepack (pack.prepack_codes with k-tile `pack_tile`), the
    # layout the Pallas dequant GEMM reads as contiguous words per tile.
    pack_layout: str = dataclasses.field(metadata=dict(static=True), default="linear")
    pack_tile: Optional[int] = dataclasses.field(
        metadata=dict(static=True), default=None
    )
    # Unstructured outliers (COO, statically padded): fp16 values + flat
    # row-major int32 indices into the (q, p) weight.
    outlier_values: Optional[jax.Array] = None  # (s,) fp16
    outlier_idx: Optional[jax.Array] = None  # (s,) int32, row·p + col
    # Structured (column) outliers.
    outlier_col_idx: Optional[jax.Array] = None  # (c,) int32
    outlier_col_vals: Optional[jax.Array] = None  # (q, c) fp32

    @property
    def shape(self) -> tuple[int, int]:
        if self.packed:
            return (*self.codes.shape[:-1], self.codes.shape[-1] * (8 // self.bits))
        return self.codes.shape

    def unpacked_codes(self) -> jax.Array:
        if not self.packed:
            return self.codes
        from repro.quant.pack import unpack_codes, unprepack_codes

        p = self.codes.shape[-1] * (8 // self.bits)
        if self.pack_layout == "tile":
            return unprepack_codes(self.codes, self.bits, p, self.pack_tile)
        return unpack_codes(self.codes, self.bits, p)

    @property
    def spec(self) -> GridSpec:
        return GridSpec(bits=self.bits, group_size=self.group_size)

    @property
    def grid(self) -> Grid:
        return Grid(spec=self.spec, scale=self.scale, zero=self.zero)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize_tensor(self, dtype=dtype)

    def bits_per_weight(self) -> float:
        """Average storage bits/weight incl. outlier overhead (paper §5.4
        accounting: each unstructured outlier ≈ 32 bits value + ~index)."""
        q, p = self.shape
        total = float(q * p * self.bits)
        n_groups = self.scale.shape[1]
        total += q * n_groups * 32 * 2  # scales + zeros
        if self.outlier_values is not None:
            total += self.outlier_values.shape[0] * (16 + 32)  # val fp16 + idx
        if self.outlier_col_idx is not None:
            total += self.outlier_col_vals.size * 16
        return total / (q * p)


def quantize_tensor(w: jax.Array, spec: GridSpec) -> QuantizedTensor:
    """RTN-style direct quantization into a QuantizedTensor (no outliers)."""
    grid = compute_grid(w, spec)
    return QuantizedTensor(
        codes=quantize_codes(w, grid),
        scale=grid.scale,
        zero=grid.zero,
        bits=spec.bits,
        group_size=spec.group_size,
    )


def dequantize_tensor(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    q, p = qt.shape
    scale, zero = qt.grid.per_column(p)
    w = (qt.unpacked_codes().astype(jnp.float32) - zero) * scale
    if qt.outlier_values is not None:
        rows, cols = qt.outlier_idx // p, qt.outlier_idx % p
        w = w.at[rows, cols].add(qt.outlier_values.astype(jnp.float32))
    if qt.outlier_col_idx is not None:
        w = w.at[:, qt.outlier_col_idx].set(qt.outlier_col_vals)
    return w.astype(dtype)
