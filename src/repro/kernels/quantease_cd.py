"""Pallas TPU kernel: QuantEase intra-block coordinate-descent sweep.

The blocked Algorithm 2 (see repro/core/quantease.py) reduces each iteration
to, per column-block of width B:

  1. one MXU matmul for the cross-block correction (done by XLA outside), and
  2. a strictly-sequential sweep over the B columns inside the block — this
     kernel.

Row independence makes the sweep embarrassingly parallel over the q
(output-channel) dimension, so the grid tiles q; each program keeps its
(B × TQ) working set plus the (B × B) Σ̃ tile entirely in VMEM and runs the
B-step recurrence with `jax.lax.fori_loop`:

    corr_i  = Σ̃_blkᵀ[i, :] @ Δ            (VPU/MXU (1,B)×(B,TQ))
    β_i     = β0[i] + corr_i
    new_i   = quantize(β_i)  (or β_i on "unquantized heuristic" iterations)
    Δ[i]    = old_i − new_i

All operands are carried *transposed* — (B, TQ) instead of (TQ, B) — so the
sequential index i addresses the sublane dimension (dynamic lane-dim slicing
is slow on TPU; sublane slicing is free).

VMEM budget per program (TQ=256, B=256, fp32):
6 × 256×256×4 B (β0, old, scale, zero, new, Δ) + 256²×4 B (Σ̃ᵀ) ≈ 1.8 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantease_block_sweep_pallas"]


def _sweep_kernel(
    beta0_t_ref,  # (B, TQ) f32
    sig_t_ref,  # (B, B) f32 — Σ̃_blkᵀ (row i = Σ̃[:, i])
    w_old_t_ref,  # (B, TQ) f32
    scale_t_ref,  # (B, TQ) f32
    zero_t_ref,  # (B, TQ) f32
    w_new_t_ref,  # (B, TQ) f32 out
    delta_t_ref,  # (B, TQ) f32 out — old − new, doubles as the Δ accumulator
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
):
    delta_t_ref[...] = jnp.zeros_like(delta_t_ref)

    def body(i, _):
        # corr = Σ̃[:, i] · Δ  — rows ≥ i of Δ are still zero, so no mask.
        sig_row = sig_t_ref[pl.ds(i, 1), :]  # (1, B)
        corr = jnp.dot(
            sig_row, delta_t_ref[...], preferred_element_type=jnp.float32
        )  # (1, TQ)
        beta = beta0_t_ref[pl.ds(i, 1), :] + corr
        if quantize:
            sc = scale_t_ref[pl.ds(i, 1), :]
            zc = zero_t_ref[pl.ds(i, 1), :]
            codes = jnp.clip(jnp.round(beta / sc) + zc, 0, n_levels - 1)
            new = (codes - zc) * sc
        else:
            new = beta
        w_new_t_ref[pl.ds(i, 1), :] = new
        delta_t_ref[pl.ds(i, 1), :] = w_old_t_ref[pl.ds(i, 1), :] - new
        return 0

    jax.lax.fori_loop(0, bsz, body, 0)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "quantize", "tq", "interpret")
)
def quantease_block_sweep_pallas(
    beta0: jax.Array,  # (q, B) f32
    sig_blk: jax.Array,  # (B, B) f32
    w_old_blk: jax.Array,  # (q, B) f32
    scale_blk: jax.Array,  # (q, B) f32
    zero_blk: jax.Array,  # (q, B) f32
    *,
    n_levels: int,
    quantize: bool,
    tq: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    q, bsz = beta0.shape
    tq = min(tq, q)
    pad_q = (-q) % tq
    qp = q + pad_q

    def prep(a):  # (q, B) → (B, qp) transposed + padded
        if pad_q:
            a = jnp.pad(a, ((0, pad_q), (0, 0)))
        return a.T

    beta0_t = prep(beta0)
    w_old_t = prep(w_old_blk)
    scale_t = prep(jnp.maximum(scale_blk, 1e-12))
    zero_t = prep(zero_blk)
    sig_t = sig_blk.T

    kernel = functools.partial(
        _sweep_kernel, n_levels=n_levels, quantize=quantize, bsz=bsz
    )
    grid = (qp // tq,)
    w_new_t, delta_t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, bsz), lambda i: (0, 0)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, qp), jnp.float32),
            jax.ShapeDtypeStruct((bsz, qp), jnp.float32),
        ],
        interpret=interpret,
    )(beta0_t, sig_t, w_old_t, scale_t, zero_t)
    return w_new_t.T[:q], delta_t.T[:q]
