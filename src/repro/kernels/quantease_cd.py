"""Pallas TPU kernels: QuantEase coordinate-descent sweeps.

Three kernels:

* :func:`quantease_block_sweep_pallas` — the intra-block sweep of one column
  block (the original per-block kernel; the legacy engine launches one of
  these per block per iteration).
* :func:`quantease_fused_iteration_pallas` — one **whole CD iteration** as a
  single kernel launch (DESIGN.md §Fused-iteration).  Grid
  ``(q-tiles, blocks)`` with the block dimension "arbitrary" (sequential):
  each program applies the full-width rolling-Δ correction for its block —
  ``corr = Σ̃ᵀ[blk, :] @ Δ`` with the (p × TQ) Δ accumulator resident in
  VMEM scratch across block steps — then runs the sequential intra-block
  sweep, then publishes its block's fresh Δ into the accumulator for the
  blocks that follow.  The rolling buffer holds current-iteration Δ for
  processed blocks and previous-iteration Δ for the rest, so the one matmul
  per block simultaneously applies the triangular cross-block correction
  and the incremental ``base = P − P̂`` maintenance (see
  repro/core/quantease.py).
* :func:`quantease_outlier_iteration_pallas` — the outlier-aware variant
  (DESIGN.md §Outlier-aware-fused): same rolling-Δ sweep plus, in the same
  launch, (a) the Ĥ-step's lazy target move (``−dĤ_prev`` absorbed at the
  base read, ``−dĤ_prevΣ̃`` folded into the published Δ) and (b) the exact
  post-sweep residual ``R = P − ŴΣ̃`` accumulated into a VMEM-resident
  output: each block adds its β0 tile plus its pure δŴ's suffix
  contribution ``Σ̃ᵀ[:, blk] δŴ_blk`` masked to the blocks already seeded.
  One launch per *outer* Algorithm-3 iteration.

Row independence makes everything embarrassingly parallel over the q
(output-channel) dimension, so the grid tiles q.  All operands are carried
*transposed* — (B, TQ) instead of (TQ, B) — so the sequential index
addresses the sublane dimension (dynamic lane-dim slicing is slow on TPU;
sublane slicing is free).

VMEM budget per fused-iteration program (TQ=256, B=256, fp32, p=4096):
Δ accumulator p×TQ×4 B = 4 MB + Σ̃ᵀ correction rows B×p×4 B = 4 MB
(2 MB at bf16) + 7 small (B × TQ) tiles ≈ 1.8 MB — fits the ~16 MB VMEM
with double-buffering headroom up to p ≈ 4–5k; shrink ``tq`` for wider
layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "quantease_block_sweep_pallas",
    "quantease_fused_iteration_pallas",
    "quantease_outlier_iteration_pallas",
    "quantease_outlier_iteration_t_pallas",
]


def _sweep_kernel(
    beta0_t_ref,  # (B, TQ) f32
    sig_t_ref,  # (B, B) f32 — Σ̃_blkᵀ (row i = Σ̃[:, i])
    w_old_t_ref,  # (B, TQ) f32
    scale_t_ref,  # (B, TQ) f32
    zero_t_ref,  # (B, TQ) f32
    w_new_t_ref,  # (B, TQ) f32 out
    delta_t_ref,  # (B, TQ) f32 out — old − new, doubles as the Δ accumulator
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
):
    delta_t_ref[...] = jnp.zeros_like(delta_t_ref)

    def body(i, _):
        # corr = Σ̃[:, i] · Δ  — rows ≥ i of Δ are still zero, so no mask.
        sig_row = sig_t_ref[pl.ds(i, 1), :]  # (1, B)
        corr = jnp.dot(
            sig_row, delta_t_ref[...], preferred_element_type=jnp.float32
        )  # (1, TQ)
        beta = beta0_t_ref[pl.ds(i, 1), :] + corr
        if quantize:
            sc = scale_t_ref[pl.ds(i, 1), :]
            zc = zero_t_ref[pl.ds(i, 1), :]
            codes = jnp.clip(jnp.round(beta / sc) + zc, 0, n_levels - 1)
            new = (codes - zc) * sc
        else:
            new = beta
        w_new_t_ref[pl.ds(i, 1), :] = new
        delta_t_ref[pl.ds(i, 1), :] = w_old_t_ref[pl.ds(i, 1), :] - new
        return 0

    jax.lax.fori_loop(0, bsz, body, 0)


@functools.partial(
    jax.jit, static_argnames=("n_levels", "quantize", "tq", "interpret")
)
def quantease_block_sweep_pallas(
    beta0: jax.Array,  # (q, B) f32
    sig_blk: jax.Array,  # (B, B) f32
    w_old_blk: jax.Array,  # (q, B) f32
    scale_blk: jax.Array,  # (q, B) f32
    zero_blk: jax.Array,  # (q, B) f32
    *,
    n_levels: int,
    quantize: bool,
    tq: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    q, bsz = beta0.shape
    tq = min(tq, q)
    pad_q = (-q) % tq
    qp = q + pad_q

    def prep(a):  # (q, B) → (B, qp) transposed + padded
        if pad_q:
            a = jnp.pad(a, ((0, pad_q), (0, 0)))
        return a.T

    beta0_t = prep(beta0)
    w_old_t = prep(w_old_blk)
    scale_t = prep(jnp.maximum(scale_blk, 1e-12))
    zero_t = prep(zero_blk)
    sig_t = sig_blk.T

    kernel = functools.partial(
        _sweep_kernel, n_levels=n_levels, quantize=quantize, bsz=bsz
    )
    grid = (qp // tq,)
    w_new_t, delta_t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, bsz), lambda i: (0, 0)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
            pl.BlockSpec((bsz, tq), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, qp), jnp.float32),
            jax.ShapeDtypeStruct((bsz, qp), jnp.float32),
        ],
        interpret=interpret,
    )(beta0_t, sig_t, w_old_t, scale_t, zero_t)
    return w_new_t.T[:q], delta_t.T[:q]


# ---------------------------------------------------------------------------
# Fused iteration: the whole blocked sweep as one kernel launch.
# ---------------------------------------------------------------------------


def _fused_iter_kernel(
    base_t_ref,  # (B, TQ) f32 — (P − P̂)ᵀ tile for this block
    sig_corr_ref,  # (B, p_pad) cdt — Σ̃ᵀ rows of this block (row i = Σ̃[:, col0+i])
    sig_diag_ref,  # (B, B) f32 — Σ̃ᵀ diagonal block (intra-block sweep)
    w_old_t_ref,  # (B, TQ) f32 — Ŵᵀ at iteration start
    scale_t_ref,  # (B, TQ) f32
    zero_t_ref,  # (B, TQ) f32
    delta_prev_t_ref,  # (p_pad, TQ) f32 — previous-iteration rolling Δᵀ
    w_new_t_ref,  # (B, TQ) f32 out
    base_out_t_ref,  # (B, TQ) f32 out — next iteration's base invariant
    delta_out_t_ref,  # (B, TQ) f32 out — this block's fresh Δ
    delta_acc,  # (p_pad, TQ) f32 VMEM scratch — rolling Δ, lives across blocks
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
    corr_dtype,
):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _seed():
        delta_acc[...] = delta_prev_t_ref[...]

    # Full-width rolling-Δ correction: rows < col0 of Δ hold *this*
    # iteration's deltas (triangular prefix), rows ≥ col0 the *previous*
    # iteration's (incremental base maintenance) — one matmul does both.
    corr = jnp.dot(
        sig_corr_ref[...],
        delta_acc[...].astype(corr_dtype),
        preferred_element_type=jnp.float32,
    )  # (B, TQ)
    beta0 = base_t_ref[...] + corr
    base_out_t_ref[...] = beta0

    # Intra-block sequential sweep (fp32 — the β/quantize path).
    delta_out_t_ref[...] = jnp.zeros_like(delta_out_t_ref)

    def body(i, _):
        sig_row = sig_diag_ref[pl.ds(i, 1), :]  # (1, B)
        c = jnp.dot(
            sig_row, delta_out_t_ref[...], preferred_element_type=jnp.float32
        )  # (1, TQ)
        beta = jax.lax.dynamic_slice(beta0, (i, 0), (1, beta0.shape[1])) + c
        if quantize:
            sc = scale_t_ref[pl.ds(i, 1), :]
            zc = zero_t_ref[pl.ds(i, 1), :]
            codes = jnp.clip(jnp.round(beta / sc) + zc, 0, n_levels - 1)
            new = (codes - zc) * sc
        else:
            new = beta
        w_new_t_ref[pl.ds(i, 1), :] = new
        delta_out_t_ref[pl.ds(i, 1), :] = w_old_t_ref[pl.ds(i, 1), :] - new
        return 0

    jax.lax.fori_loop(0, bsz, body, 0)
    # Publish this block's Δ so later blocks' corrections see it.
    delta_acc[pl.ds(b * bsz, bsz), :] = delta_out_t_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "quantize", "bsz", "tq", "matmul_dtype", "interpret"),
)
def quantease_fused_iteration_pallas(
    base: jax.Array,  # (q, p_pad) f32 — P − P̂ invariant entering this iteration
    sig_tilde: jax.Array,  # (p_pad, p_pad) f32 — zero diag, column-normalized
    w_hat: jax.Array,  # (q, p_pad) f32 — iterate entering this iteration
    scale_pc: jax.Array,  # (q, p_pad) f32
    zero_pc: jax.Array,  # (q, p_pad) f32
    delta_prev: jax.Array,  # (q, p_pad) f32 — previous iteration's rolling Δ
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
    tq: int = 256,
    matmul_dtype: str = "float32",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One full CD iteration in a single ``pallas_call``.

    Returns ``(w_new, base_new, delta_new)`` — feed them straight back in
    for the next iteration.  ``p_pad`` must be a multiple of ``bsz`` (the
    caller's column-block padding).
    """
    q, p_pad = base.shape
    assert p_pad % bsz == 0, (p_pad, bsz)
    n_blocks = p_pad // bsz
    tq = min(tq, q)
    pad_q = (-q) % tq
    qp = q + pad_q
    cdt = jnp.bfloat16 if matmul_dtype == "bfloat16" else jnp.float32

    def prep(a, fill=0.0):  # (q, p_pad) → (p_pad, qp) transposed + padded
        if pad_q:
            a = jnp.pad(a, ((0, pad_q), (0, 0)), constant_values=fill)
        return a.T

    base_t = prep(base)
    w_old_t = prep(w_hat)
    scale_t = prep(jnp.maximum(scale_pc, 1e-12), fill=1.0)
    zero_t = prep(zero_pc)
    delta_prev_t = prep(delta_prev)
    sig_t = sig_tilde.T  # row j = Σ̃[:, j]
    sig_corr = sig_t.astype(cdt)

    kernel = functools.partial(
        _fused_iter_kernel,
        n_levels=n_levels,
        quantize=quantize,
        bsz=bsz,
        corr_dtype=cdt,
    )
    grid = (qp // tq, n_blocks)
    out_spec = pl.BlockSpec((bsz, tq), lambda i, b: (b, i))
    w_new_t, base_out_t, delta_out_t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # base
            pl.BlockSpec((bsz, p_pad), lambda i, b: (b, 0)),  # Σ̃ᵀ corr rows
            pl.BlockSpec((bsz, bsz), lambda i, b: (b, b)),  # Σ̃ᵀ diag block
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # w_old
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # scale
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # zero
            pl.BlockSpec((p_pad, tq), lambda i, b: (0, i)),  # Δ_prev (resident)
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p_pad, tq), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        )
        if not interpret
        else None,
    )(base_t, sig_corr, sig_t, w_old_t, scale_t, zero_t, delta_prev_t)
    return w_new_t.T[:q], base_out_t.T[:q], delta_out_t.T[:q]


# ---------------------------------------------------------------------------
# Outlier-aware fused iteration: CD sweep + exact-residual accumulation in
# one launch (DESIGN.md §Outlier-aware-fused).
# ---------------------------------------------------------------------------


def _outlier_iter_kernel(
    base_t_ref,  # (B, TQ) f32 — base invariant tile for this block
    sig_corr_ref,  # (B, p_pad) cdt — Σ̃ᵀ rows of this block (full-width corr)
    sig_col_ref,  # (p_pad, B) cdt — Σ̃ᵀ columns of this block (suffix resid)
    sig_diag_ref,  # (B, B) f32 — Σ̃ᵀ diagonal block (intra-block sweep)
    w_old_t_ref,  # (B, TQ) f32 — Ŵᵀ at iteration start
    scale_t_ref,  # (B, TQ) f32
    zero_t_ref,  # (B, TQ) f32
    dh_prev_t_ref,  # (B, TQ) f32 — previous IHT step dĤᵀ tile
    delta_prev_t_ref,  # (p_pad, TQ) f32 — rolling Δᵀ entering the iteration
    w_new_t_ref,  # (B, TQ) f32 out
    base_out_t_ref,  # (B, TQ) f32 out — next iteration's base invariant
    dpure_t_ref,  # (B, TQ) f32 out — this block's *pure* δŴ
    r_t_ref,  # (p_pad, TQ) f32 out — exact residual R = P − ŴΣ̃, accumulated
    delta_acc,  # (p_pad, TQ) f32 VMEM scratch — rolling Δ across blocks
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
    corr_dtype,
):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _seed():
        delta_acc[...] = delta_prev_t_ref[...]
        r_t_ref[...] = jnp.zeros_like(r_t_ref)

    # Full-width rolling-Δ correction.  The buffer holds, for blocks < b,
    # this iteration's published (δŴ − dĤ_prev) deltas and, for blocks ≥ b,
    # the previous iteration's — so one matmul applies the triangular
    # cross-block correction, the incremental base maintenance, AND the
    # −dĤΣ̃ target move of the Ĥ-step.  The identity part of the target move
    # (−dĤ) is absorbed into the read below.
    corr = jnp.dot(
        sig_corr_ref[...],
        delta_acc[...].astype(corr_dtype),
        preferred_element_type=jnp.float32,
    )  # (B, TQ)
    beta0 = base_t_ref[...] - dh_prev_t_ref[...] + corr
    base_out_t_ref[...] = beta0
    r_t_ref[pl.ds(b * bsz, bsz), :] += beta0

    # Intra-block sequential sweep (fp32 — the β/quantize path).
    dpure_t_ref[...] = jnp.zeros_like(dpure_t_ref)

    def body(i, _):
        sig_row = sig_diag_ref[pl.ds(i, 1), :]  # (1, B)
        c = jnp.dot(
            sig_row, dpure_t_ref[...], preferred_element_type=jnp.float32
        )  # (1, TQ) — rows ≥ i still zero; dĤ_prev cancels in the difference
        beta = jax.lax.dynamic_slice(beta0, (i, 0), (1, beta0.shape[1])) + c
        if quantize:
            sc = scale_t_ref[pl.ds(i, 1), :]
            zc = zero_t_ref[pl.ds(i, 1), :]
            codes = jnp.clip(jnp.round(beta / sc) + zc, 0, n_levels - 1)
            new = (codes - zc) * sc
        else:
            new = beta
        w_new_t_ref[pl.ds(i, 1), :] = new
        dpure_t_ref[pl.ds(i, 1), :] = w_old_t_ref[pl.ds(i, 1), :] - new
        return 0

    jax.lax.fori_loop(0, bsz, body, 0)
    # Publish δŴ − dĤ_prev so later blocks' corrections also carry the Ĥ
    # step's −dĤΣ̃ target move; the pure δŴ stays in the output (suffix
    # residual + next iteration's rolling state).
    delta_acc[pl.ds(b * bsz, bsz), :] = (
        dpure_t_ref[...] - dh_prev_t_ref[...]
    )
    # Suffix-residual contribution: this block's pure δŴ corrects R of every
    # block ≤ b (row mask) — accumulated into the resident R output.
    contrib = jnp.dot(
        sig_col_ref[...],
        dpure_t_ref[...].astype(corr_dtype),
        preferred_element_type=jnp.float32,
    )  # (p_pad, TQ)
    row = jax.lax.broadcasted_iota(jnp.int32, contrib.shape, 0)
    r_t_ref[...] += jnp.where(row < (b + 1) * bsz, contrib, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "quantize", "bsz", "tq", "matmul_dtype", "interpret"),
)
def quantease_outlier_iteration_pallas(
    base: jax.Array,  # (q, p_pad) f32 — base invariant entering this iteration
    sig_tilde: jax.Array,  # (p_pad, p_pad) f32 — zero diag, column-normalized
    w_old: jax.Array,  # (q, p_pad) f32 — iterate Ŵ entering this iteration
    scale_pc: jax.Array,  # (q, p_pad) f32
    zero_pc: jax.Array,  # (q, p_pad) f32
    delta_prev: jax.Array,  # (q, p_pad) f32 — rolling Δ entering the iteration
    dh_prev: jax.Array,  # (q, p_pad) f32 — previous IHT step dĤ
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
    tq: int = 256,
    matmul_dtype: str = "float32",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One outlier-aware fused CD iteration in a single ``pallas_call``.

    Returns ``(w_new, base_new, delta_pure, r)``: the new iterate, the next
    iteration's base invariant, the pure δŴ (rolling-Δ state before the
    next dĤ fold), and the **exact residual** ``R = P − Ŵ_newΣ̃`` the IHT
    step consumes.  ``p_pad`` must be a multiple of ``bsz``.
    """
    q, p_pad = base.shape
    assert p_pad % bsz == 0, (p_pad, bsz)
    tq = min(tq, q)
    pad_q = (-q) % tq
    qp = q + pad_q

    def prep(a, fill=0.0):  # (q, p_pad) → (p_pad, qp) transposed + padded
        if pad_q:
            a = jnp.pad(a, ((0, pad_q), (0, 0)), constant_values=fill)
        return a.T

    cdt = jnp.bfloat16 if matmul_dtype == "bfloat16" else jnp.float32
    sig_t = sig_tilde.T  # row j = Σ̃[:, j]
    w_new_t, base_out_t, dpure_t, r_t = quantease_outlier_iteration_t_pallas(
        prep(base),
        sig_corr=sig_t.astype(cdt),
        sig_t=sig_t,
        w_old_t=prep(w_old),
        scale_t=prep(jnp.maximum(scale_pc, 1e-12), fill=1.0),
        zero_t=prep(zero_pc),
        dh_prev_t=prep(dh_prev),
        delta_prev_t=prep(delta_prev),
        n_levels=n_levels,
        quantize=quantize,
        bsz=bsz,
        tq=tq,
        matmul_dtype=matmul_dtype,
        interpret=interpret,
    )
    return w_new_t.T[:q], base_out_t.T[:q], dpure_t.T[:q], r_t.T[:q]


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "quantize", "bsz", "tq", "matmul_dtype", "interpret"),
)
def quantease_outlier_iteration_t_pallas(
    base_t: jax.Array,  # (p_pad, qp) f32 — transposed base invariant
    *,
    sig_corr: jax.Array,  # (p_pad, p_pad) cdt — Σ̃ᵀ cast for the matmuls
    sig_t: jax.Array,  # (p_pad, p_pad) f32 — Σ̃ᵀ (intra-block sweep)
    w_old_t: jax.Array,  # (p_pad, qp) f32
    scale_t: jax.Array,  # (p_pad, qp) f32 — clamped ≥ 1e-12, pad cols = 1
    zero_t: jax.Array,  # (p_pad, qp) f32
    dh_prev_t: jax.Array,  # (p_pad, qp) f32
    delta_prev_t: jax.Array,  # (p_pad, qp) f32
    n_levels: int,
    quantize: bool,
    bsz: int,
    tq: int,
    matmul_dtype: str = "float32",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Transposed-native entry: one outlier-aware fused CD iteration on
    operands already in the engine's resident (p_pad, qp) layout.

    The scanned outer loop in :mod:`repro.core.outlier` carries its state
    transposed and its Σ̃/scale/zero operands are loop-invariant — calling
    this entry directly (rather than the (q, p) wrapper above) means no
    per-iteration layout transposes cross the pallas_call boundary.
    ``p_pad % bsz == 0`` and ``qp % tq == 0`` are the caller's contract.
    """
    p_pad, qp = base_t.shape
    assert p_pad % bsz == 0 and qp % tq == 0, (p_pad, bsz, qp, tq)
    n_blocks = p_pad // bsz
    cdt = jnp.bfloat16 if matmul_dtype == "bfloat16" else jnp.float32

    kernel = functools.partial(
        _outlier_iter_kernel,
        n_levels=n_levels,
        quantize=quantize,
        bsz=bsz,
        corr_dtype=cdt,
    )
    grid = (qp // tq, n_blocks)
    out_spec = pl.BlockSpec((bsz, tq), lambda i, b: (b, i))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # base
            pl.BlockSpec((bsz, p_pad), lambda i, b: (b, 0)),  # Σ̃ᵀ corr rows
            pl.BlockSpec((p_pad, bsz), lambda i, b: (0, b)),  # Σ̃ᵀ suffix cols
            pl.BlockSpec((bsz, bsz), lambda i, b: (b, b)),  # Σ̃ᵀ diag block
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # w_old
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # scale
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # zero
            pl.BlockSpec((bsz, tq), lambda i, b: (b, i)),  # dh_prev
            pl.BlockSpec((p_pad, tq), lambda i, b: (0, i)),  # Δ_prev (resident)
        ],
        out_specs=[out_spec, out_spec, out_spec,
                   pl.BlockSpec((p_pad, tq), lambda i, b: (0, i))],  # R resident
        out_shape=[
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, qp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p_pad, tq), jnp.float32)],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        )
        if not interpret
        else None,
    )(base_t, sig_corr, sig_corr, sig_t, w_old_t, scale_t, zero_t,
      dh_prev_t, delta_prev_t)
