"""Paged-attention decode kernel: one query token per sequence against a
block-paged KV cache.

The serving engine (serve/engine.py PagedServingEngine) stores KV in
fixed-size pages shared by all sequences; each sequence owns a *page table*
(row of page indices).  This kernel computes single-token attention directly
against that layout — no contiguous (B, S, ...) cache is ever materialized:

  * grid ``(B, n_pages_per_seq)`` with the page dimension sequential; the
    page table and per-sequence lengths ride a
    :class:`~jax.experimental.pallas.tpu.PrefetchScalarGridSpec` scalar
    prefetch, so each program's BlockSpec index map resolves
    ``page_table[b, j]`` *before* the body runs and the pipeline DMAs
    exactly the page this (sequence, step) needs from HBM,
  * online-softmax accumulators (running max / sum / weighted value) live
    in VMEM scratch across the page steps of one sequence; the output is
    written once, at the last page step,
  * pages may be **bf16, int8, or int4-packed uint8**.  Quantized pages
    carry per-(token, head) fp32 scale planes; the scales fold
    algebraically after the dot — ``q·(s·k₈) = s·(q·k₈)`` and
    ``Σ p·(s·v₈) = Σ (p·s)·v₈`` — so the dequantized bf16 page is never
    materialized and HBM reads stay 1 byte/element for int8 and **0.5**
    for int4.  int4 pages are fold-in-half packed (quant/pack.kv_pack_int4:
    byte d of a slot holds head-dim elements d and d + hd/2 in its lo/hi
    nibbles), so the in-kernel unpack is two shift/mask sign-extends and a
    concatenate along the head dim — no lane interleave,
  * all score/softmax math accumulates in fp32 (`preferred_element_type`);
    only the final output casts back to the query dtype.

Pages past a sequence's length are masked, not skipped: the padded tail of
a page table points at the reserved null page (serve/kv_cache.py), so every
DMA is in-bounds and masked contributions are exactly zero (``exp(-1e30 −
m)`` underflows).  The oracle is :func:`repro.kernels.ref.paged_attention_ref`;
dispatch (VMEM fit gate + XLA gather fallback) lives in
:func:`repro.kernels.ops.paged_attention`.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_pallas"]


def _paged_attn_kernel(
    pt_ref,  # (B, n_pgs) i32 scalar-prefetch — page table
    len_ref,  # (B,) i32 scalar-prefetch — valid tokens per sequence
    q_ref,  # (1, KVp, G, hd) — query, pre-scaled by 1/sqrt(hd)
    k_ref,  # (1, psz, KVp, hd) — the page this program attends
    v_ref,  # (1, psz, KVp, hd)
    *rest,  # [ks_ref, vs_ref,] o_ref, m_s, l_s, acc_s
    psz: int,
    n_pgs: int,
    window: Optional[int],
    attn_softcap: Optional[float],
    quantized: bool,
    kv_packed4: bool,
):
    def _unpack(page):  # (psz, KVp, hd/2) uint8 → (psz, KVp, hd) f32
        b32 = page.astype(jnp.int32)
        lo = ((b32 & 0xF) ^ 8) - 8  # sign-extend 4-bit two's complement
        hi = ((b32 >> 4) ^ 8) - 8
        return jnp.concatenate([lo, hi], axis=-1).astype(jnp.float32)

    if quantized:
        ks_ref, vs_ref, o_ref, m_s, l_s, acc_s = rest
    else:
        o_ref, m_s, l_s, acc_s = rest
        ks_ref = vs_ref = None

    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[b]

    # Pages entirely past the valid prefix contribute nothing; skip the MXU
    # work (their DMA still targets a real page — the null page for padded
    # table entries — so it is always in-bounds).
    @pl.when(j * psz < length)
    def _():
        qv = q_ref[0].astype(jnp.float32)  # (KVp, G, hd)
        kb = _unpack(k_ref[0]) if kv_packed4 else k_ref[0].astype(jnp.float32)
        s = jnp.einsum(
            "kgd,tkd->kgt", qv, kb, preferred_element_type=jnp.float32
        )  # (KVp, G, psz)
        if ks_ref is not None:
            ks = ks_ref[0][:, :, 0]  # (psz, KVp)
            s = s * ks.T[:, None, :]
        if attn_softcap is not None:
            s = jnp.tanh(s / attn_softcap) * attn_softcap
        pos = j * psz + jax.lax.broadcasted_iota(jnp.int32, (1, 1, psz), 2)
        valid = pos < length
        if window is not None:
            valid &= pos >= length - window
        s = jnp.where(valid, s, -1e30)

        m_new = jnp.maximum(m_s[...], s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * corr + p.sum(-1)
        vb = _unpack(v_ref[0]) if kv_packed4 else v_ref[0].astype(jnp.float32)
        if vs_ref is not None:
            vs = vs_ref[0][:, :, 0]  # (psz, KVp)
            p = p * vs.T[:, None, :]
        acc_s[...] = acc_s[...] * corr[..., None] + jnp.einsum(
            "kgt,tkd->kgd", p, vb, preferred_element_type=jnp.float32
        )
        m_s[...] = m_new

    @pl.when(j == n_pgs - 1)
    def _():
        o_ref[0] = (
            acc_s[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
        ).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,  # (B, KVp, G, hd) — one decode token per sequence
    k_pages: jax.Array,  # (n_pages, psz, KVp, hd) bf16/f32/int8, or
    #                      (n_pages, psz, KVp, hd//2) uint8 int4-packed
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, n_pgs) int32 — padded entries → null page
    lengths: jax.Array,  # (B,) int32 — valid tokens per sequence
    *,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    k_scale_pages: Optional[jax.Array] = None,  # (n_pages, psz, KVp, 1) f32
    v_scale_pages: Optional[jax.Array] = None,
    interpret: bool = False,
) -> jax.Array:
    """Single decode-token attention over paged KV.  Returns (B, KVp, G, hd)."""
    B, KVp, G, hd = q.shape
    psz = k_pages.shape[1]
    n_pgs = page_table.shape[1]
    quantized = k_scale_pages is not None
    kv_packed4 = k_pages.dtype == jnp.uint8  # fold-in-half int4 pages
    page_hd = hd // 2 if kv_packed4 else hd

    # Mirror decode_attention's cast discipline: the 1/sqrt(hd) pre-scale is
    # applied in the query dtype, scores accumulate fp32.
    qs = (q * (1.0 / math.sqrt(hd))).astype(q.dtype)

    page_spec = pl.BlockSpec(
        (1, psz, KVp, page_hd), lambda b, j, pt, ln: (pt[b, j], 0, 0, 0)
    )
    in_specs = [
        pl.BlockSpec((1, KVp, G, hd), lambda b, j, pt, ln: (b, 0, 0, 0)),
        page_spec,
        page_spec,
    ]
    args = [qs, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, psz, KVp, 1), lambda b, j, pt, ln: (pt[b, j], 0, 0, 0)
        )
        in_specs += [scale_spec, scale_spec]
        args += [k_scale_pages, v_scale_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pgs),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, KVp, G, hd), lambda b, j, pt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KVp, G), jnp.float32),  # running max
            pltpu.VMEM((KVp, G), jnp.float32),  # running sum
            pltpu.VMEM((KVp, G, hd), jnp.float32),  # weighted-value acc
        ],
    )
    kernel = functools.partial(
        _paged_attn_kernel,
        psz=psz,
        n_pgs=n_pgs,
        window=window,
        attn_softcap=attn_softcap,
        quantized=quantized,
        kv_packed4=kv_packed4,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVp, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, *args)
