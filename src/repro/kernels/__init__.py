"""Pallas TPU kernels for QuantEase's compute hot-spots.

* quantease_cd.py — intra-block CD sweep (the PTQ-time hot loop).
* dequant_matmul.py — fused dequant+GEMM (the serve-time hot loop).
* ops.py — jit'd dispatchers (TPU Mosaic vs CPU interpret).
* ref.py — pure-jnp oracles, the contract for tests.
"""
