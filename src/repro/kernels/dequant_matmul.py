"""Pallas TPU kernel: fused dequantize + matmul for quantized serving.

Computes ``y = x @ Wᵀ`` where W is stored as uint8 quantization codes plus a
per-output-channel affine grid (scale, zero).  The codes tile is dequantized
*in VMEM* and fed straight to the MXU — W never materializes in HBM at full
precision, which is the entire inference-memory story of weight-only PTQ:
HBM traffic per weight is 1 byte (or 0.5 with the packed-int4 variant) vs 2
for bf16.

Grid: (m-tiles, q-tiles, k-tiles); k is the contraction dim, declared
"arbitrary" so the accumulator lives in the output tile across k steps.

Tiling defaults (TM=128, TQ=128, TK=512):
  x tile   128×512×2 B (bf16)        = 128 KiB
  codes    128×512×1 B               =  64 KiB
  out acc  128×128×4 B (fp32)        =  64 KiB
  total ≈ 0.26 MiB/program — leaves VMEM headroom for double-buffering.

The packed-int4 variant (``packed4=True``) takes codes packed two-per-byte
(p/2 bytes per row) and unpacks with shift/mask in-kernel, halving HBM
traffic — the lever that matters when decode is HBM-bandwidth-bound.

**Grouped grids** (``scale/zero: (q, n_groups)``, group_size = p/n_groups
columns per (s, z) pair) are first-class: the k-tile width ``tk`` is
snapped so every tile covers a whole number of groups (``tk % gsz == 0``,
tile carries a (TQ, tk//gsz) scale slab expanded in-VMEM) or sits inside
one group (``gsz % tk == 0``, tile carries a (TQ, 1) slab addressed by the
k→group index map) — group metadata HBM traffic stays O(q·n_groups), never
the O(q·p) a per-column pre-expansion would cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dequant_matmul_pallas", "select_tile_k"]


def select_tile_k(p: int, group_size=None, tk: int = 512):
    """The k-tile the kernel will run for a (·, p) GEMM — the same snapping
    :func:`dequant_matmul_pallas` applies, exposed so the pack-time layout
    decision (serve/qparams.py + roofline/analysis.py) can prepack codes
    into exactly the tile the kernel reads."""
    tk = min(tk, p)
    gsz = group_size if group_size else p
    if group_size and p // gsz > 1:
        if tk >= gsz:
            tk = (tk // gsz) * gsz
        elif gsz % tk:
            tk = gsz
    return tk


def _dequant_matmul_kernel(
    x_ref,  # (TM, TK) activations
    codes_ref,  # (TQ, TK) uint8 (or (TQ, TK//2) packed4)
    scale_ref,  # (TQ, groups_per_tile) f32
    zero_ref,  # (TQ, groups_per_tile) f32
    o_ref,  # (TM, TQ) f32 accumulator
    *,
    n_k: int,
    packed4: bool,
    tile_native: bool,
    expand: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...]
    if packed4:
        lo = codes & 0xF
        hi = codes >> 4
        if tile_native:
            # Prepacked plane-wise tile (pack.prepack_codes): lo nibbles are
            # the tile's first TK/2 columns, hi nibbles the rest — natural
            # column order falls out of a concat, no lane interleave.
            codes = jnp.concatenate([lo, hi], axis=-1)
        else:
            # Linear layout: packed byte b holds codes (2b, 2b+1) —
            # interleave back to (TQ, TK).
            codes = jnp.stack([lo, hi], axis=-1).reshape(codes.shape[0], -1)
    scale = scale_ref[...]
    zero = zero_ref[...]
    if expand > 1:
        # One (s, z) pair per contiguous group of `expand` columns.
        scale = jnp.repeat(scale, expand, axis=1)
        zero = jnp.repeat(zero, expand, axis=1)
    w = (codes.astype(jnp.float32) - zero) * scale  # (TQ, TK)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w.T, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("tm", "tq", "tk", "packed4", "pack_layout", "out_dtype",
                     "interpret"),
)
def dequant_matmul_pallas(
    x: jax.Array,  # (m, p)
    codes: jax.Array,  # (q, p) uint8, or (q, p//2) when packed4
    scale: jax.Array,  # (q,) or (q, n_groups) f32 — uniform groups (p % n_groups == 0)
    zero: jax.Array,  # same shape as scale
    *,
    tm: int = 128,
    tq: int = 128,
    tk: int = 512,
    packed4: bool = False,
    pack_layout: str = "linear",
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    m, p = x.shape
    q = codes.shape[0]
    if scale.ndim == 1:
        scale = scale[:, None]
        zero = zero[:, None]
    n_groups = scale.shape[1]
    gsz = p // n_groups if n_groups > 1 else p
    if n_groups > 1 and p % n_groups:
        raise ValueError("grouped Pallas GEMM requires uniform groups")
    tm = min(tm, m)
    tq = min(tq, q)
    tile_native = pack_layout == "tile"
    if tile_native:
        # Prepacked codes are committed to the caller's k-tile: consuming
        # them at any other tk would permute columns mid-tile.  The pack
        # decision (select_tile_k) guarantees divisibility and group fit.
        if not packed4:
            raise ValueError("pack_layout='tile' requires packed4 codes")
        if p % tk or (tk % gsz and gsz % tk):
            raise ValueError(
                f"tile-native layout needs p % tk == 0 and group-compatible "
                f"tk (p={p}, tk={tk}, group_size={gsz})"
            )
    else:
        tk = min(tk, p)
        if n_groups > 1:
            # Snap tk so each k-tile covers whole groups or sits inside one.
            if tk >= gsz:
                tk = (tk // gsz) * gsz
            elif gsz % tk:
                tk = gsz

    pad_m, pad_q, pad_k = (-m) % tm, (-q) % tq, (-p) % tk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_q or pad_k:
        kdim_pad = pad_k // 2 if packed4 else pad_k
        codes = jnp.pad(codes, ((0, pad_q), (0, kdim_pad)))
    if pad_q:
        scale = jnp.pad(scale, ((0, pad_q), (0, 0)))
        zero = jnp.pad(zero, ((0, pad_q), (0, 0)))
    if pad_k and tk % gsz == 0:
        # Whole-groups tiling addresses ceil(pp/gsz) groups; the k padding
        # may extend past the last real group — pad the metadata to match
        # (padded x columns are zero, so the values are never observed).
        pad_g = (p + pad_k) // gsz - n_groups
        if pad_g:
            scale = jnp.pad(scale, ((0, 0), (0, pad_g)), constant_values=1.0)
            zero = jnp.pad(zero, ((0, 0), (0, pad_g)))
    mp, qp, pp = m + pad_m, q + pad_q, p + pad_k
    n_k = pp // tk
    ck = tk // 2 if packed4 else tk  # codes tile width in stored bytes

    if tk % gsz == 0:  # k-tile covers whole groups → (TQ, tk/gsz) slab per tile
        g_tile = tk // gsz
        scale_spec = pl.BlockSpec((tq, g_tile), lambda i, j, k: (j, k))
        expand = gsz
    else:  # k-tile inside one group (gsz % tk == 0, and per-channel where
        # gsz = p): a (TQ, 1) slab addressed by the k-tile's group index.
        scale_spec = pl.BlockSpec((tq, 1), lambda i, j, k: (j, (k * tk) // gsz))
        expand = tk

    kernel = functools.partial(
        _dequant_matmul_kernel, n_k=n_k, packed4=packed4,
        tile_native=tile_native, expand=expand,
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // tm, qp // tq, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tq, ck), lambda i, j, k: (j, k)),
            scale_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec((tm, tq), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, qp), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        )
        if not interpret
        else None,
    )(x, codes, scale, zero)
    return out[:m, :q].astype(out_dtype)
