"""Pallas TPU kernel: fused dequantize + matmul for quantized serving.

Computes ``y = x @ Wᵀ`` where W is stored as uint8 quantization codes plus a
per-output-channel affine grid (scale, zero).  The codes tile is dequantized
*in VMEM* and fed straight to the MXU — W never materializes in HBM at full
precision, which is the entire inference-memory story of weight-only PTQ:
HBM traffic per weight is 1 byte (or 0.5 with the packed-int4 variant) vs 2
for bf16.

Grid: (m-tiles, q-tiles, k-tiles); k is the contraction dim, declared
"arbitrary" so the accumulator lives in the output tile across k steps.

Tiling defaults (TM=128, TQ=128, TK=512):
  x tile   128×512×2 B (bf16)        = 128 KiB
  codes    128×512×1 B               =  64 KiB
  out acc  128×128×4 B (fp32)        =  64 KiB
  total ≈ 0.26 MiB/program — leaves VMEM headroom for double-buffering.

The packed-int4 variant (``packed4=True``) takes codes packed two-per-byte
(p/2 bytes per row) and unpacks with shift/mask in-kernel, halving HBM
traffic — the lever that matters when decode is HBM-bandwidth-bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dequant_matmul_pallas"]


def _dequant_matmul_kernel(
    x_ref,  # (TM, TK) activations
    codes_ref,  # (TQ, TK) uint8 (or (TQ, TK//2) packed4)
    scale_ref,  # (TQ, 1) f32
    zero_ref,  # (TQ, 1) f32
    o_ref,  # (TM, TQ) f32 accumulator
    *,
    n_k: int,
    packed4: bool,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...]
    if packed4:
        lo = codes & 0xF
        hi = codes >> 4
        # Interleave back to (TQ, TK): packed byte b holds codes (2b, 2b+1).
        codes = jnp.stack([lo, hi], axis=-1).reshape(codes.shape[0], -1)
    w = (codes.astype(jnp.float32) - zero_ref[...]) * scale_ref[...]  # (TQ, TK)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w.T, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("tm", "tq", "tk", "packed4", "out_dtype", "interpret"),
)
def dequant_matmul_pallas(
    x: jax.Array,  # (m, p)
    codes: jax.Array,  # (q, p) uint8, or (q, p//2) when packed4
    scale: jax.Array,  # (q,) f32 (per-channel; groups go through the XLA path)
    zero: jax.Array,  # (q,) f32
    *,
    tm: int = 128,
    tq: int = 128,
    tk: int = 512,
    packed4: bool = False,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    m, p = x.shape
    q = codes.shape[0]
    tm = min(tm, m)
    tq = min(tq, q)
    tk = min(tk, p)

    pad_m, pad_q, pad_k = (-m) % tm, (-q) % tq, (-p) % tk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_q or pad_k:
        kdim_pad = pad_k // 2 if packed4 else pad_k
        codes = jnp.pad(codes, ((0, pad_q), (0, kdim_pad)))
    if pad_q:
        scale = jnp.pad(scale, (0, pad_q))
        zero = jnp.pad(zero, (0, pad_q))
    mp, qp, pp = m + pad_m, q + pad_q, p + pad_k
    n_k = pp // tk
    ck = tk // 2 if packed4 else tk  # codes tile width in stored bytes

    kernel = functools.partial(_dequant_matmul_kernel, n_k=n_k, packed4=packed4)
    out = pl.pallas_call(
        kernel,
        grid=(mp // tm, qp // tq, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tq, ck), lambda i, j, k: (j, k)),
            pl.BlockSpec((tq, 1), lambda i, j, k: (j, 0)),
            pl.BlockSpec((tq, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tq), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, qp), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        )
        if not interpret
        else None,
    )(x, codes, scale[:, None], zero[:, None])
    return out[:m, :q].astype(out_dtype)
