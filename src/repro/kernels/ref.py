"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantics* — kernels must match them bit-for-bit (up to fp
reassociation tolerances) across the shape/dtype sweeps in
tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantease_block_sweep_ref", "dequant_matmul_ref", "gram_ref"]


def _quant_cols(x, scale, zero, n_levels):
    codes = jnp.clip(jnp.round(x / scale) + zero, 0, n_levels - 1)
    return (codes - zero) * scale


def quantease_block_sweep_ref(
    beta0: jax.Array,  # (q, B) f32 — P_blk − P̂_blk + cross-block correction
    sig_blk: jax.Array,  # (B, B) f32 — Σ̃ block (zero diag, column-normalized)
    w_old_blk: jax.Array,  # (q, B) f32 — Ŵ block at iteration start
    scale_blk: jax.Array,  # (q, B) f32 — per-column scales
    zero_blk: jax.Array,  # (q, B) f32 — per-column zero points
    *,
    n_levels: int,
    quantize: bool,
) -> tuple[jax.Array, jax.Array]:
    """Sequential CD sweep over the B columns of one block (Eq. 13 intra-block
    term).  Returns (Ŵ_new block, Δ block = old − new)."""
    q, bsz = beta0.shape

    def col(delta, i):
        corr = delta @ jax.lax.dynamic_slice(sig_blk, (0, i), (bsz, 1))[:, 0]
        beta = jax.lax.dynamic_slice(beta0, (0, i), (q, 1))[:, 0] + corr
        if quantize:
            sc = jax.lax.dynamic_slice(scale_blk, (0, i), (q, 1))[:, 0]
            zc = jax.lax.dynamic_slice(zero_blk, (0, i), (q, 1))[:, 0]
            new = _quant_cols(beta, sc, zc, n_levels)
        else:
            new = beta
        old = jax.lax.dynamic_slice(w_old_blk, (0, i), (q, 1))[:, 0]
        delta = jax.lax.dynamic_update_slice(delta, (old - new)[:, None], (0, i))
        return delta, new

    delta, new_cols = jax.lax.scan(
        col, jnp.zeros((q, bsz), jnp.float32), jnp.arange(bsz)
    )
    return new_cols.T, delta


def dequant_matmul_ref(
    x: jax.Array,  # (m, p) activations
    codes: jax.Array,  # (q, p) uint8
    scale: jax.Array,  # (q,) or (q, n_groups) f32
    zero: jax.Array,  # same shape as scale
    *,
    out_dtype=jnp.float32,
    group_size=None,
) -> jax.Array:
    """y = x @ dequant(codes)ᵀ — the serving GEMM oracle.

    ``group_size``: columns per (scale, zero) pair — pass the grid's true
    group size for ragged layouts (last group narrower); when None it is
    inferred as ceil(p / n_groups), which matches Grid.per_column only for
    uniform groups.
    """
    q, p = codes.shape
    if scale.ndim == 1:
        scale = scale[:, None]
        zero = zero[:, None]
    n_groups = scale.shape[1]
    gsz = group_size or -(-p // n_groups)
    idx = jnp.arange(p) // gsz
    w = (codes.astype(jnp.float32) - zero[:, idx]) * scale[:, idx]
    return (x.astype(jnp.float32) @ w.T).astype(out_dtype)


def gram_ref(x: jax.Array) -> jax.Array:
    """Σ = X Xᵀ, fp32 accumulate (X: (p, n), any float dtype)."""
    x = x.astype(jnp.float32)
    return x @ x.T
