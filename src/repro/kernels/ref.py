"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantics* — kernels must match them bit-for-bit (up to fp
reassociation tolerances) across the shape/dtype sweeps in
tests/test_kernels.py.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "quantease_block_sweep_ref",
    "quantease_outlier_iteration_ref",
    "dequant_matmul_ref",
    "paged_attention_ref",
    "gram_ref",
]


def _quant_cols(x, scale, zero, n_levels):
    codes = jnp.clip(jnp.round(x / scale) + zero, 0, n_levels - 1)
    return (codes - zero) * scale


def quantease_block_sweep_ref(
    beta0: jax.Array,  # (q, B) f32 — P_blk − P̂_blk + cross-block correction
    sig_blk: jax.Array,  # (B, B) f32 — Σ̃ block (zero diag, column-normalized)
    w_old_blk: jax.Array,  # (q, B) f32 — Ŵ block at iteration start
    scale_blk: jax.Array,  # (q, B) f32 — per-column scales
    zero_blk: jax.Array,  # (q, B) f32 — per-column zero points
    *,
    n_levels: int,
    quantize: bool,
) -> tuple[jax.Array, jax.Array]:
    """Sequential CD sweep over the B columns of one block (Eq. 13 intra-block
    term).  Returns (Ŵ_new block, Δ block = old − new)."""
    q, bsz = beta0.shape

    def col(delta, i):
        corr = delta @ jax.lax.dynamic_slice(sig_blk, (0, i), (bsz, 1))[:, 0]
        beta = jax.lax.dynamic_slice(beta0, (0, i), (q, 1))[:, 0] + corr
        if quantize:
            sc = jax.lax.dynamic_slice(scale_blk, (0, i), (q, 1))[:, 0]
            zc = jax.lax.dynamic_slice(zero_blk, (0, i), (q, 1))[:, 0]
            new = _quant_cols(beta, sc, zc, n_levels)
        else:
            new = beta
        old = jax.lax.dynamic_slice(w_old_blk, (0, i), (q, 1))[:, 0]
        delta = jax.lax.dynamic_update_slice(delta, (old - new)[:, None], (0, i))
        return delta, new

    delta, new_cols = jax.lax.scan(
        col, jnp.zeros((q, bsz), jnp.float32), jnp.arange(bsz)
    )
    return new_cols.T, delta


def quantease_outlier_iteration_ref(
    base: jax.Array,  # (q, p) f32 — rolling base invariant entering the iter
    sig_tilde: jax.Array,  # (p, p) f32 — zero diag, column-normalized
    w_old: jax.Array,  # (q, p) f32 — Ŵ entering the iteration
    scale_pc: jax.Array,  # (q, p) f32
    zero_pc: jax.Array,  # (q, p) f32
    delta_prev: jax.Array,  # (q, p) f32 — rolling Δ (δŴ_prev − dĤ_prev)
    dh_prev: jax.Array,  # (q, p) f32 — previous IHT step dĤ
    *,
    n_levels: int,
    quantize: bool,
    bsz: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the outlier-aware fused iteration kernel: the blocked
    rolling-Δ sweep with the Ĥ-step's target move applied lazily, plus the
    exact post-sweep residual ``R = P − Ŵ_newΣ̃`` via the masked block-suffix
    product.  Returns ``(w_new, base_new, delta_pure, r)``.
    """
    q, p = base.shape
    n_blocks = p // bsz
    w_new = w_old
    delta_buf = delta_prev  # rolling: published (δŴ − dĤ_prev) rows
    base_out = jnp.zeros_like(base)
    dpure = jnp.zeros_like(base)
    for b in range(n_blocks):
        sl = slice(b * bsz, (b + 1) * bsz)
        corr = delta_buf @ sig_tilde[:, sl]
        beta0 = base[:, sl] - dh_prev[:, sl] + corr
        new_blk, dblk = quantease_block_sweep_ref(
            beta0, sig_tilde[sl, sl], w_old[:, sl], scale_pc[:, sl],
            zero_pc[:, sl], n_levels=n_levels, quantize=quantize,
        )
        w_new = w_new.at[:, sl].set(new_blk)
        base_out = base_out.at[:, sl].set(beta0)
        dpure = dpure.at[:, sl].set(dblk)
        delta_buf = delta_buf.at[:, sl].set(dblk - dh_prev[:, sl])
    blk = jnp.arange(p) // bsz
    sig_suffix = jnp.where(blk[:, None] >= blk[None, :], sig_tilde, 0.0)
    r = base_out + dpure @ sig_suffix
    return w_new, base_out, dpure, r


def dequant_matmul_ref(
    x: jax.Array,  # (m, p) activations
    codes: jax.Array,  # (q, p) uint8
    scale: jax.Array,  # (q,) or (q, n_groups) f32
    zero: jax.Array,  # same shape as scale
    *,
    out_dtype=jnp.float32,
    group_size=None,
) -> jax.Array:
    """y = x @ dequant(codes)ᵀ — the serving GEMM oracle.

    ``group_size``: columns per (scale, zero) pair — pass the grid's true
    group size for ragged layouts (last group narrower); when None it is
    inferred as ceil(p / n_groups), which matches Grid.per_column only for
    uniform groups.
    """
    q, p = codes.shape
    if scale.ndim == 1:
        scale = scale[:, None]
        zero = zero[:, None]
    n_groups = scale.shape[1]
    gsz = group_size or -(-p // n_groups)
    idx = jnp.arange(p) // gsz
    w = (codes.astype(jnp.float32) - zero[:, idx]) * scale[:, idx]
    return (x.astype(jnp.float32) @ w.T).astype(out_dtype)


def paged_attention_ref(
    q: jax.Array,  # (B, KVp, G, hd) — one decode token per sequence
    k_pages: jax.Array,  # (n_pages, psz, KVp, hd) bf16/f32/int8, or
    #                      (n_pages, psz, KVp, hd//2) uint8 int4-packed
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, n_pgs) int32 — padded entries → null page
    lengths: jax.Array,  # (B,) int32 — valid tokens per sequence
    *,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    k_scale_pages: Optional[jax.Array] = None,  # (n_pages, psz, KVp, 1) f32
    v_scale_pages: Optional[jax.Array] = None,
) -> jax.Array:
    """Paged decode attention oracle — and the XLA production fallback.

    Gathers each sequence's pages into position order (``page_table`` rows
    are position-ordered, so the gathered axis *is* the token axis) and
    delegates to :func:`repro.models.common.decode_attention` — a paged
    read over the same KV values is bit-identical to the contiguous read
    *by construction*, which is what makes the engine-level token-identity
    contract hold.  int8 pages are consumed with their scale planes; raw
    codes never enter the dots un-decoded.  uint8 pages are fold-in-half
    int4-packed (quant/pack.kv_pack_int4, last dim hd/2): unpacked to int8
    codes after the gather, then consumed exactly like int8 pages.
    """
    from repro.models.common import decode_attention  # the shared semantics

    B, KVp, G, hd = q.shape
    psz = k_pages.shape[1]
    S = page_table.shape[1] * psz
    k = k_pages[page_table]
    v = v_pages[page_table]
    if k_pages.dtype == jnp.uint8:  # int4-packed pages
        from repro.quant.pack import kv_unpack_int4

        k = kv_unpack_int4(k)
        v = kv_unpack_int4(v)
    k = k.reshape(B, S, KVp, hd)
    v = v.reshape(B, S, KVp, hd)
    ks = vs = None
    if k_scale_pages is not None:
        ks = k_scale_pages[page_table].reshape(B, S, KVp, 1)
        vs = v_scale_pages[page_table].reshape(B, S, KVp, 1)
    return decode_attention(
        q[:, None], k, v, lengths,
        window=window, attn_softcap=attn_softcap, k_scale=ks, v_scale=vs,
    )[:, 0]


def gram_ref(x: jax.Array) -> jax.Array:
    """Σ = X Xᵀ, fp32 accumulate (X: (p, n), any float dtype)."""
    x = x.astype(jnp.float32)
    return x @ x.T
