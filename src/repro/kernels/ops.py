"""Jit'd dispatch wrappers for the Pallas kernels.

Callers (repro.core.quantease, repro.serve) use these entry points; the
``interpret`` flag routes to Pallas interpret-mode on CPU (this container)
and compiled Mosaic on real TPUs.  ``ref.py`` holds the oracles; the
dispatchers never change semantics, only execution engines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.quantease_cd import quantease_block_sweep_pallas

__all__ = ["quantease_block_sweep", "dequant_matmul", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantease_block_sweep(
    beta0, sig_blk, w_old_blk, scale_blk, zero_blk, *, n_levels, quantize, interpret=None
):
    """Intra-block CD sweep.  2-D operands: one (q, B) block; a leading
    group dim (``beta0: (G, q, B)``, ``sig_blk: (G, B, B)``, …) sweeps G
    independent layers at once — pallas_call's batching rule folds the vmap
    into an extra grid dimension, so the grouped-block solver issues a
    single kernel launch per column block."""
    if interpret is None:
        interpret = not on_tpu()
    kernel = functools.partial(
        quantease_block_sweep_pallas,
        n_levels=n_levels,
        quantize=quantize,
        interpret=interpret,
    )
    if beta0.ndim == 3:
        return jax.vmap(kernel)(beta0, sig_blk, w_old_blk, scale_blk, zero_blk)
    return kernel(beta0, sig_blk, w_old_blk, scale_blk, zero_blk)


def dequant_matmul(
    x, codes, scale, zero, *, packed4=False, out_dtype=jnp.bfloat16, interpret=None
):
    """Serving GEMM.

    Dispatch: Mosaic kernel on TPU; pure-XLA reference elsewhere (dequant +
    dot — XLA fuses the dequant into the GEMM epilogue/prologue).  Pallas
    *interpret* mode is reserved for kernel tests (``interpret=True``) — it
    must never end up in lowered production graphs: its grid loops
    materialize per-step buffers and wreck both memory and cost analysis.
    Grouped grids always take the reference path.
    """
    if scale.ndim > 1 and scale.shape[1] > 1:
        return ref.dequant_matmul_ref(x, codes, scale, zero, out_dtype=out_dtype)
    if interpret is None:
        if not on_tpu():
            if packed4:
                from repro.quant import unpack_codes

                codes = unpack_codes(codes, 4, codes.shape[-1] * 2)
            return ref.dequant_matmul_ref(x, codes, scale, zero, out_dtype=out_dtype)
        interpret = False
    s = scale.reshape(-1)
    z = zero.reshape(-1)
    return dequant_matmul_pallas(
        x, codes, s, z, packed4=packed4, out_dtype=out_dtype, interpret=interpret
    )
