"""Jit'd dispatch wrappers for the Pallas kernels.

Callers (repro.core.quantease, repro.serve) use these entry points; the
``interpret`` flag routes to Pallas interpret-mode on CPU (this container)
and compiled Mosaic on real TPUs.  ``ref.py`` holds the oracles; the
dispatchers never change semantics, only execution engines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.faults import fault_point
from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.quantease_cd import (
    quantease_block_sweep_pallas,
    quantease_fused_iteration_pallas,
    quantease_outlier_iteration_pallas,
    quantease_outlier_iteration_t_pallas,
)

__all__ = [
    "quantease_block_sweep",
    "quantease_fused_iteration",
    "quantease_outlier_iteration",
    "quantease_outlier_iteration_t",
    "fused_iteration_tq",
    "fused_iteration_bytes",
    "outlier_iteration_tq",
    "outlier_iteration_bytes",
    "block_sweep_tq",
    "block_sweep_bytes",
    "dequant_matmul",
    "dequant_matmul_fits_vmem",
    "dequant_matmul_bytes",
    "paged_attention",
    "paged_attention_fits_vmem",
    "on_tpu",
]

_VMEM_BUDGET = 12 * 1024 * 1024  # of ~16 MB VMEM, leaving double-buffer headroom


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_sweep_bytes(bsz: int, tq: int) -> int:
    """VMEM working set of one block-sweep program: six (bsz × tq) fp32
    tiles (β₀, Ŵ_old, scale, zero in; Ŵ_new, Δ out) plus the (bsz × bsz)
    Σ̃ block shared by every program."""
    return 6 * bsz * tq * 4 + bsz * bsz * 4


def block_sweep_tq(q: int, bsz: int, tq: int = 256):
    """Pick a q-tile for the intra-block sweep kernel, or None if even the
    minimum tile cannot fit VMEM (only conceivable at absurd block sizes —
    the sweep's working set is tiny — but the dispatcher gates anyway so
    every pallas_call sits behind an explicit fit decision)."""
    tq = min(tq, max(q, 1))
    while tq > 128 and block_sweep_bytes(bsz, tq) > _VMEM_BUDGET:
        tq //= 2
    if block_sweep_bytes(bsz, tq) > _VMEM_BUDGET:
        return None
    return tq


def quantease_block_sweep(
    beta0, sig_blk, w_old_blk, scale_blk, zero_blk, *, n_levels, quantize, interpret=None
):
    """Intra-block CD sweep.  2-D operands: one (q, B) block; a leading
    group dim (``beta0: (G, q, B)``, ``sig_blk: (G, B, B)``, …) sweeps G
    independent layers at once — pallas_call's batching rule folds the vmap
    into an extra grid dimension, so the grouped-block solver issues a
    single kernel launch per column block."""
    if interpret is None:
        interpret = not on_tpu()
    q, bsz = beta0.shape[-2], beta0.shape[-1]
    tq = block_sweep_tq(q, bsz)
    if tq is None:
        ref_fn = functools.partial(
            ref.quantease_block_sweep_ref, n_levels=n_levels, quantize=quantize
        )
        if beta0.ndim == 3:
            return jax.vmap(ref_fn)(beta0, sig_blk, w_old_blk, scale_blk, zero_blk)
        return ref_fn(beta0, sig_blk, w_old_blk, scale_blk, zero_blk)
    kernel = functools.partial(
        quantease_block_sweep_pallas,
        n_levels=n_levels,
        quantize=quantize,
        tq=tq,
        interpret=interpret,
    )
    if beta0.ndim == 3:
        return jax.vmap(kernel)(beta0, sig_blk, w_old_blk, scale_blk, zero_blk)
    return kernel(beta0, sig_blk, w_old_blk, scale_blk, zero_blk)


def fused_iteration_bytes(
    p_pad: int, bsz: int, matmul_dtype: str, tq: int
) -> int:
    """VMEM working set of one fused-iteration program at tile ``tq``: the
    (p_pad × tq) fp32 Δ accumulator scratch, the (bsz × p_pad) Σ̃ᵀ
    correction slab (bf16 halves it), and ~7 (bsz × tq) fp32 tiles."""
    sig_bytes = bsz * p_pad * (2 if matmul_dtype == "bfloat16" else 4)
    return p_pad * tq * 4 + sig_bytes + 7 * bsz * tq * 4


def fused_iteration_tq(p_pad: int, bsz: int, matmul_dtype: str = "float32", tq: int = 256):
    """Pick a q-tile for the fused-iteration kernel, or None if it cannot
    fit VMEM.

    Only the Δ term of :func:`fused_iteration_bytes` shrinks with ``tq`` —
    the Σ̃ slab is fixed by ``bsz``, so very wide layers don't fit at any
    tq and the caller must fall back to the per-block XLA schedule (same
    iterates).
    """
    while tq > 128 and fused_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        tq //= 2
    if fused_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        return None
    return tq


def quantease_fused_iteration(
    base,
    sig_tilde,
    w_hat,
    scale_pc,
    zero_pc,
    delta_prev,
    *,
    n_levels,
    quantize,
    bsz,
    matmul_dtype="float32",
    interpret=None,
    tq=None,
):
    """One full CD iteration as a single fused kernel launch.

    2-D operands: one (q, p_pad) layer; a leading group dim batches G
    layers into one launch (vmap folds into the grid).  Returns
    ``(w_new, base_new, delta_new)``.  ``tq`` defaults to
    :func:`fused_iteration_tq`'s VMEM-fitted choice; callers should gate on
    that helper returning non-None before taking this path.
    """
    if interpret is None:
        interpret = not on_tpu()
    p_pad = sig_tilde.shape[-1]
    if tq is None:
        tq = fused_iteration_tq(p_pad, bsz, matmul_dtype)
        if tq is None:
            raise ValueError(
                f"fused iteration does not fit VMEM (p_pad={p_pad}, bsz={bsz}); "
                "use the XLA engine for this layer"
            )
    elif fused_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        raise ValueError(
            f"explicit tq={tq} overflows VMEM (p_pad={p_pad}, bsz={bsz}); "
            "pass tq=None to let fused_iteration_tq choose"
        )
    kernel = functools.partial(
        quantease_fused_iteration_pallas,
        n_levels=n_levels,
        quantize=quantize,
        bsz=bsz,
        tq=tq,
        matmul_dtype=matmul_dtype,
        interpret=interpret,
    )
    if base.ndim == 3:
        return jax.vmap(kernel)(
            base, sig_tilde, w_hat, scale_pc, zero_pc, delta_prev
        )
    return kernel(base, sig_tilde, w_hat, scale_pc, zero_pc, delta_prev)


def outlier_iteration_bytes(
    p_pad: int, bsz: int, matmul_dtype: str, tq: int
) -> int:
    """VMEM working set of one outlier-iteration program: beyond the base
    kernel's set, a second (p_pad × tq) fp32 slab (the R accumulator
    output) and a second (p_pad × bsz) Σ̃ slab (the suffix column block;
    bf16 halves both Σ̃ slabs)."""
    sig_bytes = 2 * bsz * p_pad * (2 if matmul_dtype == "bfloat16" else 4)
    return 2 * p_pad * tq * 4 + sig_bytes + 8 * bsz * tq * 4


def outlier_iteration_tq(
    p_pad: int, bsz: int, matmul_dtype: str = "float32", tq: int = 256
):
    """Pick a q-tile for the outlier-aware fused-iteration kernel, or None
    if it cannot fit VMEM.

    As with :func:`fused_iteration_tq`, only the p_pad×tq terms of
    :func:`outlier_iteration_bytes` shrink with ``tq`` — too-wide layers
    must take the XLA schedule.
    """
    while tq > 128 and outlier_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        tq //= 2
    if outlier_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        return None
    return tq


def quantease_outlier_iteration(
    base,
    sig_tilde,
    w_old,
    scale_pc,
    zero_pc,
    delta_prev,
    dh_prev,
    *,
    n_levels,
    quantize,
    bsz,
    matmul_dtype="float32",
    interpret=None,
    tq=None,
):
    """One outlier-aware fused CD iteration (sweep + exact residual) as a
    single kernel launch.

    2-D operands: one (q, p_pad) layer; a leading group dim batches G layers
    into one launch (vmap folds into the grid).  Returns
    ``(w_new, base_new, delta_pure, r)`` — see
    :func:`repro.kernels.quantease_cd.quantease_outlier_iteration_pallas`.
    """
    if interpret is None:
        interpret = not on_tpu()
    p_pad = sig_tilde.shape[-1]
    if tq is None:
        tq = outlier_iteration_tq(p_pad, bsz, matmul_dtype)
        if tq is None:
            raise ValueError(
                f"outlier fused iteration does not fit VMEM "
                f"(p_pad={p_pad}, bsz={bsz}); use the XLA engine for this layer"
            )
    elif outlier_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        raise ValueError(
            f"explicit tq={tq} overflows VMEM (p_pad={p_pad}, bsz={bsz}); "
            "pass tq=None to let outlier_iteration_tq choose"
        )
    kernel = functools.partial(
        quantease_outlier_iteration_pallas,
        n_levels=n_levels,
        quantize=quantize,
        bsz=bsz,
        tq=tq,
        matmul_dtype=matmul_dtype,
        interpret=interpret,
    )
    if base.ndim == 3:
        return jax.vmap(kernel)(
            base, sig_tilde, w_old, scale_pc, zero_pc, delta_prev, dh_prev
        )
    return kernel(base, sig_tilde, w_old, scale_pc, zero_pc, delta_prev, dh_prev)


def quantease_outlier_iteration_t(
    base_t,
    *,
    sig_corr,
    sig_t,
    w_old_t,
    scale_t,
    zero_t,
    dh_prev_t,
    delta_prev_t,
    n_levels,
    quantize,
    bsz,
    tq,
    matmul_dtype="float32",
    interpret=None,
):
    """Transposed-native outlier fused iteration (the scanned engine's hot
    entry): operands arrive in the resident (p_pad, qp) layout, so no
    per-iteration transposes cross the kernel boundary.  Loop-invariant
    operands (``sig_corr``/``sig_t``/``scale_t``/``zero_t``) are prepped
    once by the caller.  Returns ``(w_new_t, base_new_t, delta_pure_t,
    r_t)``, all (p_pad, qp)."""
    if interpret is None:
        interpret = not on_tpu()
    p_pad = base_t.shape[-2]
    if outlier_iteration_bytes(p_pad, bsz, matmul_dtype, tq) > _VMEM_BUDGET:
        raise ValueError(
            f"tq={tq} overflows VMEM for the transposed outlier iteration "
            f"(p_pad={p_pad}, bsz={bsz}); size it with outlier_iteration_tq"
        )
    return quantease_outlier_iteration_t_pallas(
        base_t,
        sig_corr=sig_corr,
        sig_t=sig_t,
        w_old_t=w_old_t,
        scale_t=scale_t,
        zero_t=zero_t,
        dh_prev_t=dh_prev_t,
        delta_prev_t=delta_prev_t,
        n_levels=n_levels,
        quantize=quantize,
        bsz=bsz,
        tq=tq,
        matmul_dtype=matmul_dtype,
        interpret=interpret,
    )


def paged_attention_fits_vmem(
    page_size: int, kvp: int, g: int, hd: int, *,
    kv_bytes: float = 2, quantized: bool = False,
) -> bool:
    """VMEM fit gate for the paged-attention kernel.

    Resident per program: the double-buffered k/v page blocks (the only
    term that scales with ``page_size``), their fp32 scale planes when the
    pages are quantized, and the fixed per-sequence set (query tile, fp32
    softmax accumulators, output tile).  ``kv_bytes`` is per *element*:
    2 for bf16, 1 for int8, 0.5 for packed int4 (two codes per stored
    byte).  Same 12 MB budget/headroom policy as
    :func:`fused_iteration_tq`; a non-fit must take the XLA gather
    fallback — there is no smaller tile to retry, pages are the tile.
    """
    pages = int(2 * 2 * page_size * kvp * hd * kv_bytes)  # k+v, double-buffered
    if quantized:
        pages += 2 * 2 * page_size * kvp * 4
    fixed = kvp * g * hd * 4 * 3 + kvp * g * 4 * 2  # q + acc + out, m + l
    budget = 12 * 1024 * 1024
    return pages + fixed <= budget


def paged_attention(
    q, k_pages, v_pages, page_table, lengths, *,
    window=None, attn_softcap=None,
    k_scale_pages=None, v_scale_pages=None, interpret=None,
):
    """Paged decode attention (serving hot path).

    Dispatch mirrors :func:`dequant_matmul`: Mosaic kernel on TPU when the
    page block fits VMEM (:func:`paged_attention_fits_vmem`); the XLA
    gather-based reference elsewhere.  Pallas *interpret* mode is reserved
    for kernel tests (``interpret=True``) and never reaches lowered
    production graphs.

    Quantized pages **must** arrive with both scale planes — they are
    either folded in-kernel or consumed explicitly by the reference; raw
    codes are never forwarded un-decoded (the grouped-dispatch audit that
    fixed ``dequant_matmul`` applies here from day one).  int8 pages carry
    one code per element; **uint8 pages are int4-packed** (two signed
    codes per byte, fold-in-half layout — quant/pack.kv_pack_int4), halving
    page HBM traffic again.
    """
    quantized = k_scale_pages is not None
    if (v_scale_pages is None) != (k_scale_pages is None):
        raise ValueError("k_scale_pages and v_scale_pages must be passed together")
    if k_pages.dtype == jnp.int8 and not quantized:
        raise ValueError("int8 KV pages require scale planes (dequant-in-kernel)")
    kv_packed4 = k_pages.dtype == jnp.uint8
    if kv_packed4 and not quantized:
        raise ValueError(
            "int4-packed KV pages require scale planes (dequant-in-kernel)"
        )

    def reference():
        return ref.paged_attention_ref(
            q, k_pages, v_pages, page_table, lengths,
            window=window, attn_softcap=attn_softcap,
            k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
        )

    # Injection point "kernel.dispatch" (DESIGN.md §Resilience): a "deny"
    # action simulates VMEM-gate pressure — the dispatcher degrades to the
    # XLA gather reference, which reads the same pages bitwise (tested), so
    # outputs are unchanged.  Fires at dispatch time (trace time under jit).
    if fault_point("kernel.dispatch") == "deny":
        return reference()
    if interpret is None:
        if not on_tpu():
            return reference()
        interpret = False
    psz = k_pages.shape[1]
    _, kvp, g, hd = q.shape
    if not paged_attention_fits_vmem(
        psz, kvp, g, hd,
        kv_bytes=0.5 if kv_packed4 else k_pages.dtype.itemsize,
        quantized=quantized,
    ):
        return reference()
    return paged_attention_pallas(
        q, k_pages, v_pages, page_table, lengths,
        window=window, attn_softcap=attn_softcap,
        k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
        interpret=interpret,
    )


def _unpacked(codes, packed4, pack_layout="linear", pack_tile=None):
    if not packed4:
        return codes
    from repro.quant import unpack_codes, unprepack_codes

    p = codes.shape[-1] * 2
    if pack_layout == "tile":
        return unprepack_codes(codes, 4, p, pack_tile)
    return unpack_codes(codes, 4, p)


def dequant_matmul_bytes(
    m: int, q: int, p: int, *, tm: int = 128, tq: int = 128, tk: int = 512
) -> int:
    """VMEM working set of one serving-GEMM program: the (tm × tk) fp32
    activation tile, the (tq × tk) codes tile (1 B/code stored — packed4
    halves HBM, not the unpacked VMEM tile), the scale/zero slabs expanded
    in-VMEM to (tq × tk) fp32 worst case, and the (tm × tq) fp32
    accumulator."""
    tm, tq, tk = min(tm, m), min(tq, q), min(tk, p)
    return tm * tk * 4 + tq * tk + 2 * tq * tk * 4 + tm * tq * 4


def dequant_matmul_fits_vmem(
    m: int, q: int, p: int, *, tm: int = 128, tq: int = 128, tk: int = 512
) -> bool:
    """VMEM fit gate for the serving GEMM.  The fixed 128/128/512 tiling
    keeps the working set near 0.8 MiB regardless of problem size, so this
    effectively always passes — it exists so the dispatch decision is an
    explicit, formula-checked gate (analysis/vmem.py re-evaluates it
    against every shipped config shape) rather than an implicit property
    of the tile constants."""
    return dequant_matmul_bytes(m, q, p, tm=tm, tq=tq, tk=tk) <= _VMEM_BUDGET


def dequant_matmul(
    x, codes, scale, zero, *, packed4=False, out_dtype=jnp.bfloat16,
    interpret=None, group_size=None, pack_layout="linear", pack_tile=None,
):
    """Serving GEMM.

    Dispatch: Mosaic kernel on TPU; pure-XLA reference elsewhere (dequant +
    dot — XLA fuses the dequant into the GEMM epilogue/prologue).  Pallas
    *interpret* mode is reserved for kernel tests (``interpret=True``) — it
    must never end up in lowered production graphs: its grid loops
    materialize per-step buffers and wreck both memory and cost analysis.

    Grouped grids (``scale: (q, n_groups)``, n_groups > 1) take the Pallas
    kernel too when the groups are uniform — the kernel tiles scale/zero
    per group; ragged layouts (a narrower last group) fall back to the XLA
    reference with the true ``group_size`` (packed4 codes are unpacked
    first — the reference consumes raw uint8 planes).  Pass ``group_size``
    (QuantizedTensor carries it) whenever the grid was built with one:
    without it a ragged layout is indistinguishable from a uniform
    ceil(p/n_groups) layout and would dequantize with wrong boundaries.

    ``pack_layout="tile"`` marks codes prepacked into the kernel's
    tile-native order at pack time (quant/pack.prepack_codes with k-tile
    ``pack_tile``, chosen by the roofline decision in serve/qparams.py):
    the kernel consumes them at exactly that tk with a contiguous
    concat-unpack; every fallback path (non-TPU, ragged groups) un-prepacks
    first, so the layout is transparent to semantics.
    """
    n_groups = scale.shape[1] if scale.ndim > 1 else 1
    p = codes.shape[-1] * (2 if packed4 else 1)
    gsz = group_size if group_size else (-(-p // n_groups) if n_groups > 1 else p)
    uniform = n_groups == 1 or (p % gsz == 0 and p // gsz == n_groups)
    tiled = packed4 and pack_layout == "tile"

    def reference():
        return ref.dequant_matmul_ref(
            x, _unpacked(codes, packed4, pack_layout, pack_tile), scale, zero,
            out_dtype=out_dtype, group_size=group_size,
        )

    # Injection point "kernel.dispatch": "deny" degrades to the XLA
    # reference (same semantics; see paged_attention's note).
    if fault_point("kernel.dispatch") == "deny":
        return reference()
    if interpret is None:
        if not on_tpu():
            return reference()
        interpret = False
    if not dequant_matmul_fits_vmem(x.shape[0], codes.shape[0], p):
        return reference()
    kw = dict(packed4=packed4, out_dtype=out_dtype, interpret=interpret)
    if tiled:
        if p % pack_tile:  # prepack left the ragged tail linear — ref only
            return reference()
        kw.update(pack_layout="tile", tk=pack_tile)
    if n_groups > 1:
        if not uniform:  # ragged last group — reference path only
            return reference()
        return dequant_matmul_pallas(x, codes, scale, zero, **kw)
    s = scale.reshape(-1)
    z = zero.reshape(-1)
    return dequant_matmul_pallas(x, codes, s, z, **kw)
