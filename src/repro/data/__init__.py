"""Synthetic data pipeline (deterministic, resumable)."""
