"""Deterministic synthetic data pipeline (corpus, calibration, modality stubs).

Offline container ⇒ no C4/WikiText; we synthesize a *learnable* corpus from a
seeded order-1 Markov chain over the vocab with Zipfian marginals.  The chain
gives a non-trivial optimal perplexity, so trained-then-quantized models
separate RTN/GPTQ/QuantEase cleanly (benchmarks mirror the paper's tables on
this corpus — DESIGN.md §7).

Determinism & fault tolerance: batch ``i`` is a pure function of
``(seed, split, i)`` — the pipeline "state" is just the step counter stored
in checkpoints, so resume (or elastic re-sharding onto a different
data-parallel layout) replays exactly.  Per-host sharding slices the batch
by ``jax.process_index()`` in real multi-host runs (single process here).

Splits: the ``split`` argument keys the per-step RNG with a per-split salt,
so the ``train`` / ``calib`` / ``eval`` streams are disjoint *by
construction* — no step of one split ever shares an RNG stream with any
step of another (distinct ``SeedSequence`` entropy tuples), which is the
no-calibration-leakage guarantee the eval subsystem depends on
(tests/test_eval.py pins it).  ``split="train"`` keeps the historical
``(seed, step)`` keying so existing checkpoints replay identically.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from repro.faults import fault_point

__all__ = ["SyntheticCorpus", "DataConfig", "make_batch_fn", "SPLITS"]

# Per-split RNG salts.  ``train`` is unsalted (historical keying); the other
# splits fold a large fixed salt into the SeedSequence entropy so their
# streams never coincide with the train stream — or each other — for any
# (seed, step) pair.
SPLITS = {"train": None, "calib": 0xCA11B, "eval": 0xE7A1}


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seed: int = 1234
    zipf_a: float = 1.2
    branching: int = 8  # plausible successors per token


class SyntheticCorpus:
    """Order-1 Markov chain with Zipf marginals and limited branching."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        marg = (np.arange(1, v + 1, dtype=np.float64)) ** (-cfg.zipf_a)
        marg /= marg.sum()
        # each token transitions to `branching` successors with Zipf weights
        succ = np.stack([rng.choice(v, cfg.branching, replace=False) for _ in range(v)])
        w = (np.arange(1, cfg.branching + 1)) ** (-1.0)
        w /= w.sum()
        self.succ = succ.astype(np.int32)
        self.w = w
        self.marg = marg

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int32)
        out[:, 0] = rng.choice(self.cfg.vocab, batch, p=self.marg)
        choices = rng.choice(self.cfg.branching, (batch, seq), p=self.w)
        for t in range(1, seq):
            out[:, t] = self.succ[out[:, t - 1], choices[:, t]]
        return out

    def entropy_floor(self) -> float:
        """Per-token entropy of the chain (nats) — the minimum achievable CE."""
        return float(-(self.w * np.log(self.w)).sum())


def make_batch_fn(
    data_cfg: DataConfig,
    model_cfg,
    batch: int,
    seq: int,
    split: str = "train",
):
    """Returns batch(step) → dict of numpy arrays matching the model family.

    ``split`` selects one of the disjoint deterministic streams (``train`` /
    ``calib`` / ``eval`` — see module docstring); all splits share the same
    underlying Markov chain, only the sampling stream differs.
    """
    if split not in SPLITS:
        raise ValueError(f"unknown split {split!r}; expected one of {sorted(SPLITS)}")
    salt = SPLITS[split]
    corpus = SyntheticCorpus(data_cfg)

    def get(step: int) -> dict:
        # Injection point "data.fetch" (DESIGN.md §Resilience): a transient
        # fault here models a flaky storage read; because batch ``step`` is
        # a pure function of (seed, split, step), a retry after the fault
        # reproduces the batch bit-identically — retries never skew data.
        fault_point("data.fetch")
        key = (data_cfg.seed, step) if salt is None else (data_cfg.seed, salt, step)
        rng = np.random.default_rng(key)
        out = {"tokens": corpus.sample(rng, batch, seq)}
        if model_cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (batch, model_cfg.n_frames, model_cfg.d_model)
            ).astype(np.float32)
        if model_cfg.n_prefix:
            out["patches"] = rng.standard_normal(
                (batch, model_cfg.n_prefix, model_cfg.d_model)
            ).astype(np.float32)
        return out

    return get, corpus
