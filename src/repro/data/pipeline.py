"""Deterministic synthetic data pipeline (corpus, calibration, modality stubs).

Offline container ⇒ no C4/WikiText; we synthesize a *learnable* corpus from a
seeded order-1 Markov chain over the vocab with Zipfian marginals.  The chain
gives a non-trivial optimal perplexity, so trained-then-quantized models
separate RTN/GPTQ/QuantEase cleanly (benchmarks mirror the paper's tables on
this corpus — DESIGN.md §7).

Determinism & fault tolerance: batch ``i`` is a pure function of
``(seed, i)`` — the pipeline "state" is just the step counter stored in
checkpoints, so resume (or elastic re-sharding onto a different data-parallel
layout) replays exactly.  Per-host sharding slices the batch by
``jax.process_index()`` in real multi-host runs (single process here).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

__all__ = ["SyntheticCorpus", "DataConfig", "make_batch_fn"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seed: int = 1234
    zipf_a: float = 1.2
    branching: int = 8  # plausible successors per token


class SyntheticCorpus:
    """Order-1 Markov chain with Zipf marginals and limited branching."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        marg = (np.arange(1, v + 1, dtype=np.float64)) ** (-cfg.zipf_a)
        marg /= marg.sum()
        # each token transitions to `branching` successors with Zipf weights
        succ = np.stack([rng.choice(v, cfg.branching, replace=False) for _ in range(v)])
        w = (np.arange(1, cfg.branching + 1)) ** (-1.0)
        w /= w.sum()
        self.succ = succ.astype(np.int32)
        self.w = w
        self.marg = marg

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int32)
        out[:, 0] = rng.choice(self.cfg.vocab, batch, p=self.marg)
        choices = rng.choice(self.cfg.branching, (batch, seq), p=self.w)
        for t in range(1, seq):
            out[:, t] = self.succ[out[:, t - 1], choices[:, t]]
        return out

    def entropy_floor(self) -> float:
        """Per-token entropy of the chain (nats) — the minimum achievable CE."""
        return float(-(self.w * np.log(self.w)).sum())


def make_batch_fn(
    data_cfg: DataConfig,
    model_cfg,
    batch: int,
    seq: int,
):
    """Returns batch(step) → dict of numpy arrays matching the model family."""
    corpus = SyntheticCorpus(data_cfg)

    def get(step: int) -> dict:
        rng = np.random.default_rng((data_cfg.seed, step))
        out = {"tokens": corpus.sample(rng, batch, seq)}
        if model_cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (batch, model_cfg.n_frames, model_cfg.d_model)
            ).astype(np.float32)
        if model_cfg.n_prefix:
            out["patches"] = rng.standard_normal(
                (batch, model_cfg.n_prefix, model_cfg.d_model)
            ).astype(np.float32)
        return out

    return get, corpus
