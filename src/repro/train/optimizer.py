"""AdamW in pure JAX, with optional quantized 8-bit moments.

No optax in this environment — and the paper gives us the machinery anyway:
the 8-bit moment states reuse the same uniform affine grids as the PTQ core.
For the ≥100B assigned configs this is what makes optimizer state fit
16 GB/chip (DESIGN.md §4): bytes/param for (m, v) drop from 8 (fp32) to 2.

Quantization granularity is **per last-axis vector** (one affine grid per
row), not bitsandbytes' flat 256-blocks: flat blocks would force a reshape
across sharded dims and GSPMD would re-gather every gradient each step.
Row-wise grids keep the uint8 moment arrays *exactly* param-shaped, so they
inherit the param's sharding verbatim — the whole point at 512 chips.
Leaves with ndim < 2 (norm scales, biases — negligible memory) stay fp32.

State per leaf: {"m": m, "v": v}; each moment is either an fp32 array or
{"q": uint8 (param shape), "scale": fp32 (..., 1), "zero": fp32 (..., 1)}.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "moment_axes",
    "lr_schedule",
    "global_norm",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments: str = "fp32"  # "fp32" | "int8"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


_V_FLOOR = 1e-16


def _q8_encode(x: jax.Array, signed: bool) -> dict:
    """Row-wise (last-axis) int8 encoding; x fp32.

    m (signed): linear symmetric around 0.
    v (unsigned): **log-domain** affine — a linear grid would round small
    entries of a heavy-tailed row to exactly 0 and the Adam update
    m/(√v+ε) would explode; log-domain keeps ~1%-relative precision across
    the row's whole dynamic range and can never produce zero.
    """
    if signed:
        scale = jnp.maximum(jnp.max(jnp.abs(x), -1, keepdims=True) / 127.0, 1e-20)
        q = jnp.clip(jnp.round(x / scale) + 128, 0, 255).astype(jnp.uint8)
        zero = jnp.full_like(scale, 128.0)
        return {"q": q, "scale": scale, "zero": zero}
    lx = jnp.log(x + _V_FLOOR)
    lo = jnp.min(lx, -1, keepdims=True)
    hi = jnp.max(lx, -1, keepdims=True)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    q = jnp.clip(jnp.round((lx - lo) / scale), 0, 255).astype(jnp.uint8)
    return {"q": q, "scale": scale, "zero": -lo / scale}  # log-affine


def _decode(m, signed: bool = True) -> jax.Array:
    if isinstance(m, dict):
        vals = (m["q"].astype(jnp.float32) - m["zero"]) * m["scale"]
        return vals if signed else jnp.exp(vals) - _V_FLOOR
    return m


def _use_int8(p) -> bool:
    return p.ndim >= 2


def adamw_init(params, cfg: AdamWConfig):
    def leaf_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.moments == "int8" and _use_int8(p):
            return {"m": _q8_encode(z, True), "v": _q8_encode(z, False)}
        return {"m": z, "v": z}

    mu = jax.tree.map(leaf_state, params)
    # JAX dedups identical constants into shared buffers; donation requires
    # every state leaf to own its buffer → force unique copies once at init.
    mu = jax.tree.map(jnp.copy, mu)
    return {"mu": mu, "count": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: AdamWConfig):
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def leaf(p, g, s):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(s["m"], True) + (1 - cfg.b1) * g
        v = jnp.maximum(
            cfg.b2 * _decode(s["v"], False) + (1 - cfg.b2) * g * g, 0.0
        )
        c = count.astype(jnp.float32)
        mhat = m / (1 - cfg.b1**c)
        vhat = v / (1 - cfg.b2**c)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (upd + decay * p.astype(jnp.float32))
        if cfg.moments == "int8" and _use_int8(p):
            new_s = {"m": _q8_encode(m, True), "v": _q8_encode(v, False)}
        else:
            new_s = {"m": m, "v": v}
        return new_p.astype(p.dtype), new_s

    is_state_leaf = lambda x: isinstance(x, dict) and set(x) == {"m", "v"}
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = jax.tree.flatten(state["mu"], is_leaf=is_state_leaf)[0]
    out = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}, {"grad_norm": gnorm, "lr": lr}


def moment_axes(params_shapes, param_axes_tree, cfg: AdamWConfig):
    """Logical-axes tree mirroring adamw_init's state structure."""

    def leaf(sds, ax):
        ax = tuple(ax)
        if cfg.moments == "int8" and len(sds.shape) >= 2:
            enc = {"q": ax, "scale": (*ax[:-1], None), "zero": (*ax[:-1], None)}
            return {"m": enc, "v": enc}
        return {"m": ax, "v": ax}

    flat_s, tdef = jax.tree.flatten(params_shapes)
    flat_ax = jax.tree.flatten(
        param_axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )[0]
    mu = jax.tree.unflatten(tdef, [leaf(s, a) for s, a in zip(flat_s, flat_ax)])
    return {"mu": mu, "count": ()}
