"""Trainer: mesh-aware jitted loop with checkpoint/restart and elasticity.

Wires together: sharding rules (dist/sharding.py) → jitted train_step with
explicit in/out shardings and donated (params, opt_state) → synthetic data
pipeline → atomic checkpoints → RetryingRunner for failure recovery.

On CPU (examples) pass ``mesh=None`` — everything runs unsharded, same code.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, make_batch_fn
from repro.dist import checkpoint as ckpt
from repro.dist.elastic import RetryingRunner, elastic_mesh
from repro.dist.sharding import Rules, axis_rules, make_rules
from repro.models import init_params, make_plan, param_axes, param_shapes
from repro.train.optimizer import AdamWConfig, adamw_init, moment_axes
from repro.train.train_step import make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 200
    batch: int = 8
    seq: int = 128
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    n_microbatches: int = 1
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        mesh=None,
        fsdp: bool = False,
    ):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        axis_n = mesh.shape.get("model", 1) if mesh is not None else 1
        self.plan = make_plan(model_cfg, axis_n)
        self.rules = (
            make_rules(
                mesh,
                n_heads=self.plan.heads.h_pad,
                n_kv_heads=self.plan.heads.n_kv,
                d_ff=model_cfg.d_ff,
                n_experts=model_cfg.n_experts,
                vocab=self.plan.vocab_pad,
                d_model=model_cfg.d_model,
                fsdp=fsdp,
            )
            if mesh is not None
            else None
        )
        self.batch_fn, self.corpus = make_batch_fn(
            DataConfig(vocab=model_cfg.vocab, seed=tcfg.seed),
            model_cfg,
            tcfg.batch,
            tcfg.seq,
        )
        self._build()

    # ------------------------------------------------------------------
    def _shard(self, tree, axes_tree):
        if self.rules is None:
            return tree
        flat_t, tdef = jax.tree.flatten(tree)
        flat_ax = jax.tree.flatten(axes_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
        out = [
            jax.device_put(t, self.rules.sharding(ax))
            for t, ax in zip(flat_t, flat_ax)
        ]
        return jax.tree.unflatten(tdef, out)

    def _build(self):
        plan = self.plan
        with axis_rules(self.rules):
            params = init_params(plan, jax.random.PRNGKey(self.tcfg.seed))
            if self.rules is not None:
                params = self._shard(params, param_axes(plan))
            opt_state = adamw_init(params, self.opt_cfg)
        self.params, self.opt_state = params, opt_state
        step_fn = make_train_step(plan, self.opt_cfg, self.tcfg.n_microbatches)

        def wrapped(params, opt_state, batch):
            with axis_rules(self.rules):
                return step_fn(params, opt_state, batch)

        self.train_step = jax.jit(wrapped, donate_argnums=(0, 1))
        self.data_step = 0
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def _put_batch(self, batch_np: dict):
        if self.rules is None:
            return {k: jnp.asarray(v) for k, v in batch_np.items()}
        out = {}
        for k, v in batch_np.items():
            ax = ("batch",) + (None,) * (v.ndim - 1)
            out[k] = jax.device_put(v, self.rules.sharding(ax))
        return out

    def save(self, step: int):
        state = {"params": self.params, "opt": self.opt_state}
        ckpt.save_checkpoint(
            self.tcfg.ckpt_dir, step, state, meta={"data_step": self.data_step}
        )

    def restore(self) -> int:
        state_like = {"params": self.params, "opt": self.opt_state}
        state, manifest = ckpt.load_checkpoint(self.tcfg.ckpt_dir, state_like)
        self.params, self.opt_state = state["params"], state["opt"]
        if self.rules is not None:
            self.params = self._shard(self.params, param_axes(self.plan))
        self.data_step = manifest["meta"]["data_step"]
        return manifest["step"]

    def run(self, fault_hook=None) -> dict:
        tcfg = self.tcfg
        ckpt.cleanup_tmp(tcfg.ckpt_dir)
        start = 0
        if ckpt.latest_step(tcfg.ckpt_dir) is not None:
            start = self.restore()

        def do_step(state, step):
            params, opt_state = state
            batch = self._put_batch(self.batch_fn(step))
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            self.params, self.opt_state = params, opt_state
            self.data_step = step + 1
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                self.metrics_log.append(m)
            if (step + 1) % tcfg.ckpt_every == 0:
                self.save(step + 1)
            return (params, opt_state)

        def restore_state():
            step = self.restore() if ckpt.latest_step(tcfg.ckpt_dir) is not None else 0
            return (self.params, self.opt_state), step

        runner = RetryingRunner(
            step_fn=do_step, restore_fn=restore_state, fault_hook=fault_hook
        )
        state, _ = runner.run((self.params, self.opt_state), start, tcfg.steps - start)
        self.params, self.opt_state = state
        return {
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "recoveries": runner.recoveries,
            "log": self.metrics_log,
        }
