"""Train step: value_and_grad + microbatch accumulation + AdamW.

Microbatching reshapes the global batch (B, ...) into ``n_mb`` sequential
slices scanned with fp32 gradient accumulation — the activation-memory lever
for the ≥100B configs (DESIGN.md §4).  The optimizer update runs once per
step on the mean gradient.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import train_loss
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_train_step"]


def _split_mb(batch: dict, n_mb: int):
    def r(x):
        b = x.shape[0]
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_train_step(
    plan, opt_cfg: AdamWConfig, n_microbatches: int = 1, grad_shardings=None
):
    """Returns train_step(params, opt_state, batch) → (params', state', metrics).

    ``grad_shardings``: optional pytree of NamedShardings (the FSDP param
    layout); constraining each microbatch's grads before accumulation lets
    GSPMD reduce-scatter straight into the sharded accumulator instead of
    all-reducing full fp32 weight grads per microbatch (§Perf H3)."""

    def loss_fn(params, mb):
        return train_loss(plan, params, mb)

    def _pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, grad_shardings
        )

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin(grads)
        else:
            mbs = _split_mb(batch, n_microbatches)
            g0 = _pin(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )

            def acc(carry, mb):
                tot, g_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), _pin(g_acc), _pin(grads)
                )
                return (tot + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), g0), mbs)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)

        new_params, new_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step
