"""Deterministic fault injection (DESIGN.md §Resilience).

A seeded :class:`FaultPlan` schedules transient/permanent errors, pool
exhaustion spikes, and checkpoint byte corruption at named injection
points instrumented throughout serving and the long-running pipelines, so
every failure path is a reproducible test (tests/test_chaos.py) instead of
a production surprise.
"""

from repro.faults.plan import (
    SITES,
    FaultError,
    FaultPlan,
    FaultSpec,
    PermanentFault,
    TransientFault,
    active_plan,
    corrupt_bytes,
    fault_plan,
    fault_point,
)

__all__ = [
    "SITES",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "PermanentFault",
    "TransientFault",
    "active_plan",
    "corrupt_bytes",
    "fault_plan",
    "fault_point",
]
