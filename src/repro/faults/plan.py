"""Seeded, deterministic fault injection for serving and pipelines.

Every failure path in this repo is exercised as a *reproducible test*, not
discovered as a production surprise: a :class:`FaultPlan` is a pure function
of ``(specs, seed)`` and of the per-site invocation counters, so the same
plan driven through the same workload fires the same faults at the same
instants every time (the chaos suite's whole premise —
tests/test_chaos.py).

Injection points are registered by name (:data:`SITES`) and instrumented in
the production code with a single :func:`fault_point` call each:

=====================  ====================================================
``engine.step``        top of ``ServingEngine.step`` / ``PagedServingEngine
                       .step`` — before any state mutation, so a transient
                       fault is a pure no-op retry
``pool.alloc``         ``serve.kv_cache.PagePool.alloc`` — a ``deny``
                       action simulates a pool-exhaustion spike (alloc
                       returns None as if the pool were dry)
``ckpt.write``         per-leaf in ``dist.checkpoint.save_checkpoint`` —
                       ``corrupt`` flips one seeded byte of the shard on
                       disk (the manifest checksum still describes the true
                       bytes, so the read side *must* detect it)
``ckpt.read``          per-leaf in ``dist.checkpoint.load_checkpoint``
``kernel.dispatch``    Pallas dispatch wrappers (``kernels.ops``) — a
                       ``deny`` action simulates VMEM-gate pressure and
                       forces the (bit-equivalent) XLA fallback; fires at
                       dispatch time, i.e. trace time under jit
``data.fetch``         ``data.pipeline.make_batch_fn``'s batch getter
=====================  ====================================================

Fault kinds:

* ``transient`` — raises :class:`TransientFault`; the consumer is expected
  to retry (engines count and retry the step; pipeline loops go through
  ``dist.elastic.RetryingRunner``'s backoff).
* ``permanent`` — raises :class:`PermanentFault`; never retried
  (``RetryingRunner`` classifies it and re-raises immediately).
* ``deny`` — soft action returned to the caller (pool alloc failure, VMEM
  gate failure); no exception.
* ``corrupt`` — soft action; the caller damages its payload (checkpoint
  shard bytes) in a seeded, reproducible way via :func:`corrupt_bytes`.

Activation is lexically scoped — ``with fault_plan(plan): ...`` — and when
no plan is active every ``fault_point`` is a cheap no-op, so the hooks cost
nothing in production.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Optional

import numpy as np

__all__ = [
    "SITES",
    "FaultError",
    "TransientFault",
    "PermanentFault",
    "FaultSpec",
    "FaultPlan",
    "fault_plan",
    "fault_point",
    "active_plan",
    "corrupt_bytes",
]

SITES = (
    "engine.step",
    "pool.alloc",
    "ckpt.read",
    "ckpt.write",
    "kernel.dispatch",
    "data.fetch",
)

_KINDS = ("transient", "permanent", "deny", "corrupt")


class FaultError(Exception):
    """Base class for injected faults; carries the site and invocation."""

    def __init__(self, site: str, invocation: int):
        self.site = site
        self.invocation = invocation
        super().__init__(f"injected fault at {site}#{invocation}")


class TransientFault(FaultError):
    """Recoverable: consumers retry (engine step retry, runner backoff)."""


class PermanentFault(FaultError):
    """Unrecoverable: never retried (RetryingRunner re-raises at once)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault at one site.

    A spec fires on a per-site invocation ``n`` (0-based) when ``n ∈ at``,
    or ``window[0] <= n < window[1]``, or a seeded Bernoulli draw with
    probability ``p`` succeeds — whichever triggers are set (any of them
    firing fires the spec).  ``max_fires`` caps the total fires of this
    spec (None = unbounded); probability draws are consumed on *every*
    invocation of the site so the fire schedule never depends on what other
    specs did.
    """

    site: str
    kind: str
    at: tuple = ()
    window: Optional[tuple] = None
    p: float = 0.0
    max_fires: Optional[int] = None

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; expected one of {SITES}")
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected one of {_KINDS}")
        object.__setattr__(self, "at", tuple(int(a) for a in self.at))
        if self.window is not None:
            a, b = self.window
            object.__setattr__(self, "window", (int(a), int(b)))
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"p={self.p} not a probability")


class FaultPlan:
    """A deterministic fault schedule over the registered injection sites.

    ``check(site)`` advances the site's invocation counter and returns the
    action the instrumented code must take (``"ok"`` / ``"deny"`` /
    ``"corrupt"``) or raises (``transient`` / ``permanent``).  The first
    matching spec wins, in construction order.  ``plan.fired`` is the audit
    trail: ``(site, invocation, kind)`` per fire.
    """

    def __init__(self, specs, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.counts: dict[str, int] = {s: 0 for s in SITES}
        self.fired: list[tuple] = []
        self._fires_left = [
            float("inf") if sp.max_fires is None else int(sp.max_fires)
            for sp in self.specs
        ]
        # One independent RNG stream per spec, keyed (seed, spec index):
        # each spec's p-draws are a pure function of the site's invocation
        # sequence, untouched by the other specs' draws.
        self._rngs = [
            np.random.default_rng((self.seed, i)) for i in range(len(self.specs))
        ]
        # Seeded stream for payload corruption (byte choice).
        self._corrupt_rng = np.random.default_rng((self.seed, 0xC0FFEE))

    @classmethod
    def from_spec(cls, doc) -> "FaultPlan":
        """Build from a JSON document (dict, JSON string, or path to one):
        ``{"seed": 0, "faults": [{"site": ..., "kind": ..., "at": [...],
        "window": [a, b], "p": 0.0, "max_fires": null}, ...]}``."""
        if isinstance(doc, str):
            try:
                doc = json.loads(doc)
            except json.JSONDecodeError:
                with open(doc) as f:
                    doc = json.load(f)
        if not isinstance(doc, dict):
            raise ValueError(f"fault plan must be a JSON object, got {type(doc).__name__}")
        unknown_top = set(doc) - {"seed", "faults"}
        if unknown_top:
            raise ValueError(
                f"unknown fault-plan key(s) {sorted(unknown_top)}; "
                'expected {"seed", "faults"}'
            )
        specs = []
        for i, d in enumerate(doc.get("faults", [])):
            if not isinstance(d, dict):
                raise ValueError(f"faults[{i}]: expected an object, got {type(d).__name__}")
            unknown = set(d) - {"site", "kind", "at", "window", "p", "max_fires"}
            if unknown:
                raise ValueError(
                    f"faults[{i}]: unknown key(s) {sorted(unknown)}; expected "
                    '{"site", "kind", "at", "window", "p", "max_fires"}'
                )
            missing = {"site", "kind"} - set(d)
            if missing:
                raise ValueError(f"faults[{i}]: missing required key(s) {sorted(missing)}")
            try:
                # FaultSpec validates site against SITES and kind against
                # _KINDS — the same registry analysis/faultsites.py audits
                # for production parity, so a name this accepts is
                # guaranteed to have a live fault_point arm.
                spec = FaultSpec(
                    site=d["site"],
                    kind=d["kind"],
                    at=tuple(d.get("at", ())),
                    window=tuple(d["window"]) if d.get("window") else None,
                    p=float(d.get("p", 0.0)),
                    max_fires=d.get("max_fires"),
                )
            except ValueError as e:
                raise ValueError(f"faults[{i}]: {e}") from None
            specs.append(spec)
        return cls(specs, seed=int(doc.get("seed", 0)))

    def check(self, site: str) -> str:
        if site not in self.counts:
            raise ValueError(f"unknown fault site {site!r}; expected one of {SITES}")
        n = self.counts[site]
        self.counts[site] = n + 1
        action = "ok"
        for i, sp in enumerate(self.specs):
            if sp.site != site:
                continue
            fire = n in sp.at
            if sp.window is not None:
                fire = fire or (sp.window[0] <= n < sp.window[1])
            if sp.p > 0.0:
                # Always draw: the stream position is the invocation index.
                fire = bool(self._rngs[i].random() < sp.p) or fire
            if not fire or self._fires_left[i] <= 0:
                continue
            self._fires_left[i] -= 1
            self.fired.append((site, n, sp.kind))
            if sp.kind == "transient":
                raise TransientFault(site, n)
            if sp.kind == "permanent":
                raise PermanentFault(site, n)
            action = sp.kind  # deny | corrupt — first match wins
            break
        return action

    def corrupt_index(self, n: int) -> int:
        """Seeded byte index into an ``n``-byte payload (for ``corrupt``)."""
        return int(self._corrupt_rng.integers(0, max(n, 1)))


_ACTIVE: list[FaultPlan] = []


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def fault_plan(plan: Optional[FaultPlan]):
    """Activate ``plan`` for the dynamic extent of the block (re-entrant:
    the innermost plan wins).  ``None`` is accepted and is a no-op, so
    callers can thread an optional plan without branching."""
    if plan is None:
        yield None
        return
    _ACTIVE.append(plan)
    try:
        yield plan
    finally:
        _ACTIVE.pop()


def fault_point(site: str) -> str:
    """The single instrumentation hook: consult the active plan (if any).

    Returns the soft action (``"ok"`` / ``"deny"`` / ``"corrupt"``) or
    raises :class:`TransientFault` / :class:`PermanentFault`.  With no
    active plan this is a dict-lookup-free no-op.
    """
    plan = active_plan()
    if plan is None:
        return "ok"
    return plan.check(site)


def corrupt_bytes(plan: FaultPlan, data: bytes) -> bytes:
    """Flip one seeded byte of ``data`` (XOR 0xFF so the flip never
    round-trips to the original value) — the reproducible shard-corruption
    primitive behind ``ckpt.write``'s ``corrupt`` action."""
    if not data:
        return data
    idx = plan.corrupt_index(len(data))
    out = bytearray(data)
    out[idx] ^= 0xFF
    return bytes(out)
