"""Compatibility shims for the range of jax versions this repo runs on.

The codebase targets the modern ``jax.make_mesh(..., axis_types=...)`` API
(jax ≥ 0.5); the container image pins jax 0.4.37, which has ``jax.make_mesh``
but neither the ``axis_types`` kwarg nor ``jax.sharding.AxisType``.  On 0.4.x
every mesh axis already behaves as GSPMD-auto, so the shim is semantically a
no-op: it adds the enum and swallows the kwarg.  Imported for its side
effects from ``repro/__init__.py`` so any ``import repro.*`` activates it.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
            del axis_types  # 0.4.x: every axis is implicitly Auto
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh


_install()
