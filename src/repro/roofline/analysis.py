"""Roofline analysis from compiled artifacts (no hardware required).

Three terms per (arch × shape × mesh), all in seconds **per step**:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ link-bytes per device / ICI_bw

`compiled.cost_analysis()` on the SPMD-partitioned module reports
**per-device** flops / bytes (verified empirically — see DESIGN.md §3), so
no ÷chips is applied.  Collective bytes are not in cost_analysis: we parse
the post-partitioning HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, converting
to per-device *link* bytes with ring-algorithm factors over the size of the
participating group.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9
    ici_bw: float = 50e9  # per link (one direction)


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device *link* bytes by collective kind (ring-algorithm factors).

    For a group of size g over per-device output/input bytes b:
      all-gather:        each device receives (g−1)/g · (total bytes) ≈ b_out·(g−1)/g
      reduce-scatter:    same as all-gather on input bytes
      all-reduce:        2·(g−1)/g · b (ring RS+AG)
      all-to-all:        (g−1)/g · b
      collective-permute: b
    Output-shape bytes are HLO *result* shapes, which are already global for
    AG (gathered) and per-device for RS — we account accordingly.
    """
    out = {
        "all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double-count of async pairs (count the -start)
        b = _shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        if g <= 1 or b == 0:
            continue
        f = (g - 1) / g
        if kind == "all-gather":
            out[kind] += b * f  # result = gathered global shape
        elif kind == "reduce-scatter":
            out[kind] += b * (g - 1)  # result = per-device shard
        elif kind == "all-reduce":
            out[kind] += 2 * b * f  # ring RS + AG
        elif kind == "all-to-all":
            out[kind] += b * f
        else:  # collective-permute
            out[kind] += b
        counts[kind] += 1
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float
    collective_link_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    model_flops_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)
    coll_detail: dict
    memory_stats: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_compiled(
    compiled,
    n_devices: int,
    model_flops: float,
    hw: HW = HW(),
) -> RooflineReport:
    from repro.roofline.hlo_cost import analyze_hlo

    hlo = compiled.as_text()
    # Trip-count-aware re-derivation: XLA's cost_analysis() counts while
    # bodies once (scan-over-layers would be undercounted ~100×) — see
    # hlo_cost.py.  The raw cost_analysis numbers are kept for reference.
    cs = analyze_hlo(hlo, n_devices)
    flops = cs.flops
    byts = cs.hbm_bytes
    coll = dict(cs.collective_by_kind)
    coll["counts"] = cs.collective_counts
    coll["trip_counts"] = cs.while_trip_counts[:50]
    link_bytes = cs.collective_link_bytes
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = link_bytes / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    mem = compiled.memory_analysis()
    memory_stats = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_hbm_est": mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes,
    }
    return RooflineReport(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_link_bytes=link_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=max(terms, key=terms.get),
        model_flops=model_flops,
        model_flops_ratio=model_flops / max(flops * n_devices, 1.0),
        coll_detail=coll,
        memory_stats=memory_stats,
    )
