"""Roofline analysis from compiled artifacts (no hardware required).

Three terms per (arch × shape × mesh), all in seconds **per step**:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ link-bytes per device / ICI_bw

`compiled.cost_analysis()` on the SPMD-partitioned module reports
**per-device** flops / bytes (verified empirically — see DESIGN.md §3), so
no ÷chips is applied.  Collective bytes are not in cost_analysis: we parse
the post-partitioning HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, converting
to per-device *link* bytes with ring-algorithm factors over the size of the
participating group.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

__all__ = [
    "HW", "RooflineReport", "analyze_compiled", "collective_bytes",
    "WeightLayoutDecision", "choose_weight_layout", "weight_bytes",
    "paged_kv_bytes_per_token",
]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9
    ici_bw: float = 50e9  # per link (one direction)


# s4/u4 are *packed* two-per-byte in HBM (quant/pack.py, the paged int4 KV
# pages): 0.5 bytes/element, not 1 — at 1 the memory term of every packed
# layout came out 2× too high and the roofline could never prefer it.
_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        # Integer half-byte units so packed sub-byte dtypes round *up*: a
        # ragged s4 row still occupies its last half-filled byte.
        total += -(-n * int(2 * _DTYPE_BYTES[dtype]) // 2)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device *link* bytes by collective kind (ring-algorithm factors).

    For a group of size g over per-device output/input bytes b:
      all-gather:        each device receives (g−1)/g · (total bytes) ≈ b_out·(g−1)/g
      reduce-scatter:    same as all-gather on input bytes
      all-reduce:        2·(g−1)/g · b (ring RS+AG)
      all-to-all:        (g−1)/g · b
      collective-permute: b
    Output-shape bytes are HLO *result* shapes, which are already global for
    AG (gathered) and per-device for RS — we account accordingly.
    """
    out = {
        "all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double-count of async pairs (count the -start)
        b = _shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        if g <= 1 or b == 0:
            continue
        f = (g - 1) / g
        if kind == "all-gather":
            out[kind] += b * f  # result = gathered global shape
        elif kind == "reduce-scatter":
            out[kind] += b * (g - 1)  # result = per-device shard
        elif kind == "all-reduce":
            out[kind] += 2 * b * f  # ring RS + AG
        elif kind == "all-to-all":
            out[kind] += b * f
        else:  # collective-permute
            out[kind] += b
        counts[kind] += 1
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Pack-time layout decisions (serving GEMM / paged KV)
# ---------------------------------------------------------------------------
#
# The serving GEMM (kernels/dequant_matmul.py) can consume weight codes in
# three storage layouts; decode is memory-bound (m ≈ batch tokens, tiny), so
# the pack decision is a pure roofline call: minimize the memory term,
# modelling the *effective* bandwidth of each unpack pattern.
#
#   linear-unpacked : 1 B/elem, contiguous reads            (any bits)
#   linear-packed   : 0.5 B/elem, in-kernel nibble interleave — the
#                     stack([lo, hi]).reshape shuffle reads contiguous words
#                     but scatters them across lanes; modelled as a gather
#                     at `_INTERLEAVE_DERATE` of peak HBM bw (bits == 4)
#   tile-native     : 0.5 B/elem, codes pre-reordered so each k-tile's low
#                     nibbles are its first tk/2 columns and the high
#                     nibbles the rest — unpack is two shifts + a concat,
#                     contiguous words per tile, full bandwidth (bits == 4,
#                     p divisible by the kernel tile)

_INTERLEAVE_DERATE = 0.5  # effective-bw factor for the in-kernel interleave


def weight_bytes(q: int, p: int, *, bits: int, n_groups: int = 1,
                 packed: bool = False) -> float:
    """HBM bytes one decode step reads for a (q, p) quantized weight:
    codes (via _DTYPE_BYTES — 0.5 B/elem when packed int4) + the fp32
    scale/zero planes."""
    per_elem = _DTYPE_BYTES["u4"] if (packed and bits == 4) else _DTYPE_BYTES["u8"]
    return q * p * per_elem + q * n_groups * 8.0


def paged_kv_bytes_per_token(page_size: int, kvp: int, hd: int, n_periods: int,
                             *, kv_dtype: str, context_pages: float = 1.0) -> float:
    """Roofline-predicted KV-read bytes per decoded token: ``context_pages``
    pages × (k+v) × per-slot bytes × layers.  int8 stores 1 B/elem + an 8 B
    fp32 (k, v) scale pair per (token, head); int4 packs 2 elems/byte with
    the same scale planes."""
    elem = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}[kv_dtype]
    per_slot = kvp * hd * elem + (kvp * 4.0 if kv_dtype != "bf16" else 0.0)
    return 2.0 * per_slot * page_size * n_periods * context_pages


@dataclasses.dataclass(frozen=True)
class WeightLayoutDecision:
    kind: str  # "linear" | "tile"
    packed: bool  # codes stored two-per-byte
    tile_k: Optional[int]  # prepack k-tile (kind == "tile")
    tiling: str  # "whole-groups" | "tile-in-group" | "per-channel"
    bytes_per_step: float  # weight HBM bytes per decode step (memory term)
    memory_s: float  # bytes / effective bw — the decided-on quantity
    compute_s: float  # 2·m·q·p / peak — context only, decode never trips it

    @property
    def label(self) -> str:
        if self.kind == "tile":
            return f"tile{self.tile_k}/{self.tiling}"
        return "linear-packed" if self.packed else "linear"


def choose_weight_layout(
    q: int, p: int, *, bits: int, group_size: Optional[int] = None,
    tile_k: Optional[int] = None, backend: str = "tpu", m: int = 1,
    hw: HW = HW(),
) -> WeightLayoutDecision:
    """Pick the serving storage layout for one (q, p) quantized linear.

    ``tile_k`` is the Pallas kernel's snapped k-tile for this shape
    (kernels.dequant_matmul.select_tile_k) — pass None when the kernel
    cannot consume a tile-native plane for it (ragged groups, odd p, p not
    a tile multiple).  Non-TPU backends serve through the XLA reference,
    which un-prepacks; tile-native buys nothing there, so the decision
    degrades to the best linear layout.
    """
    gsz = group_size if group_size else p
    n_groups = -(-p // gsz)
    compute_s = 2.0 * m * q * p / hw.peak_flops

    def mem_s(packed, derate=1.0):
        return weight_bytes(q, p, bits=bits, n_groups=n_groups, packed=packed) / (
            hw.hbm_bw * derate
        )

    # Packed candidates lead so exact ties (the derate can cancel the byte
    # halving) resolve to the layout the artifact actually stores — serving
    # never unpacks checkpoint codes back into HBM.
    cands = []
    if bits == 4 and p % 2 == 0:
        cands.append(("linear", True, None, mem_s(True, _INTERLEAVE_DERATE)))
        if backend == "tpu" and tile_k is not None and p % tile_k == 0:
            cands.append(("tile", True, tile_k, mem_s(True)))
    cands.append(("linear", False, None, mem_s(False)))
    kind, packed, tk, memory_s = min(cands, key=lambda c: c[3])
    if kind == "tile":
        tiling = "whole-groups" if group_size and tk % gsz == 0 else (
            "tile-in-group" if group_size else "per-channel"
        )
    else:
        tiling = "per-channel" if not group_size else "whole-groups"
        tk = None
    return WeightLayoutDecision(
        kind=kind, packed=packed, tile_k=tk, tiling=tiling,
        bytes_per_step=weight_bytes(q, p, bits=bits, n_groups=n_groups, packed=packed),
        memory_s=memory_s, compute_s=compute_s,
    )


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float
    collective_link_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    model_flops_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)
    coll_detail: dict
    memory_stats: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_compiled(
    compiled,
    n_devices: int,
    model_flops: float,
    hw: HW = HW(),
) -> RooflineReport:
    from repro.roofline.hlo_cost import analyze_hlo

    hlo = compiled.as_text()
    # Trip-count-aware re-derivation: XLA's cost_analysis() counts while
    # bodies once (scan-over-layers would be undercounted ~100×) — see
    # hlo_cost.py.  The raw cost_analysis numbers are kept for reference.
    cs = analyze_hlo(hlo, n_devices)
    flops = cs.flops
    byts = cs.hbm_bytes
    coll = dict(cs.collective_by_kind)
    coll["counts"] = cs.collective_counts
    coll["trip_counts"] = cs.while_trip_counts[:50]
    link_bytes = cs.collective_link_bytes
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = link_bytes / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    mem = compiled.memory_analysis()
    memory_stats = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_hbm_est": mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes,
    }
    return RooflineReport(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_link_bytes=link_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=max(terms, key=terms.get),
        model_flops=model_flops,
        model_flops_ratio=model_flops / max(flops * n_devices, 1.0),
        coll_detail=coll,
        memory_stats=memory_stats,
    )
