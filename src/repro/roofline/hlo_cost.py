"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts every while-loop body **once**
(verified empirically — a 10-step `lax.scan` reports 1/10 of the unrolled
flops).  Our models are scan-over-periods × scan-over-microbatches ×
scan-over-chunks, so naive numbers are off by 2–3 orders of magnitude.

This module re-derives per-device flops / HBM bytes / collective link-bytes
from ``compiled.as_text()``:

  1. parse every computation and its ops (shapes from a per-computation
     symbol table),
  2. build the call graph — ``while`` bodies multiply by
     ``backend_config known_trip_count`` (emitted by XLA's loop analysis;
     falls back to 1 if absent), fusions/calls/reduce-appliers multiply by 1,
  3. flops: 2·prod(out)·prod(contracting) per ``dot`` (the only flop-dense
     op in this framework — no convolutions),
  4. bytes: Σ (result + operand) shape bytes over *top-level* ops per
     computation (insides of fusions are VMEM-local and skipped),
  5. collectives: ring-algorithm link bytes (see analysis.py), ×multiplier.

All numbers are per-device: the text is the SPMD-partitioned module.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["CostSummary", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
    "f8e5m2": 1, "token": 0, "s1": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->", re.M)
# NB: tuple result types may embed /*index=5*/ comments (with '='), so the
# type group must be fully lazy `.+?` rather than `[^=]+?`.
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=\{?%?([\w.\-]+)")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")


def _shape_elems_bytes(shape_str: str):
    total_b = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dtype]
    return total_b


def _first_shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    result: str
    kind: str
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    params: dict
    ops: list
    is_entry: bool = False


def _parse_computations(text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            params = {}
            # tolerant split: tuple-typed params contain commas/parens; we
            # only need name→type for scalar/array params (dot fallback).
            for p in re.split(r",\s*(?![^()\[\]]*[)\]])", hdr.group(3)):
                p = p.strip()
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    params[pname.strip().lstrip("%")] = ptype.strip()
            cur = _Comp(hdr.group(2), params, [], is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(_Op(m.group(1), m.group(2).strip(), m.group(3), line))
    return comps


def _symbol_table(comp: _Comp) -> dict:
    table = dict(comp.params)
    for op in comp.ops:
        table[op.name] = op.result
    return table


def _dot_flops(op: _Op, table: dict) -> float:
    out_elems = 1
    for d in _first_shape_dims(op.result):
        out_elems *= d
    # contracting sizes from the lhs operand shape
    mctr = _DOT_CONTRACT.search(op.line)
    if not mctr:
        return 2.0 * out_elems  # degenerate
    ctr_dims = [int(x) for x in mctr.group(1).split(",") if x]
    args = op.line.split("(", 1)[1]
    # first operand: either "type %name" (inline) or "%name"
    first = args.split(",")[0].strip()
    shape = _first_shape_dims(first)
    if not shape:
        nm = first.lstrip("%")
        shape = _first_shape_dims(table.get(nm, ""))
    k = 1
    for d in ctr_dims:
        if d < len(shape):
            k *= shape[d]
    return 2.0 * out_elems * k


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "iota",
}


def _op_hbm_bytes(op: _Op) -> float:
    """HBM bytes for one top-level op, honoring in-place/sparse semantics.

    Naive Σ(shapes on the line) bills a single-token KV-cache write the
    whole cache; XLA executes dynamic-update-slice / scatter in place and
    gather/dynamic-slice touch only the addressed elements.
    """
    shapes = [
        _shape_elems_bytes(m.group(0))
        for m in _SHAPE_RE.finditer(op.line.split(" metadata=")[0])
    ]
    if not shapes:
        return 0.0
    if op.kind == "dynamic-update-slice":
        # result, operand, update, indices… → read+write the update region
        upd = shapes[2] if len(shapes) > 2 else shapes[-1]
        return 2.0 * upd
    if op.kind == "scatter":
        upd = shapes[-1]
        idx = shapes[-2] if len(shapes) > 2 else 0
        return 2.0 * upd + idx
    if op.kind in ("gather", "dynamic-slice"):
        idx = shapes[2] if len(shapes) > 2 else 0
        return 2.0 * shapes[0] + idx
    return float(sum(shapes))


def _collective_link_bytes(op: _Op, n_devices: int) -> float:
    if op.kind.endswith("-done"):
        return 0.0
    base = next((k for k in _COLL_KINDS if op.kind.startswith(k)), None)
    if base is None:
        return 0.0
    b = _shape_elems_bytes(op.result)
    g = n_devices
    m = _GROUPS_V2_RE.search(op.line)
    if m:
        g = int(m.group(2))
    else:
        m = _GROUPS_RE.search(op.line)
        if m:
            g = max(len([x for x in m.group(1).strip("{}").split(",") if x.strip()]), 1)
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    if base == "all-gather":
        return b * f
    if base == "reduce-scatter":
        return b * (g - 1)
    if base == "all-reduce":
        return 2 * b * f
    if base in ("all-to-all", "ragged-all-to-all"):
        return b * f
    return b  # collective-permute


@dataclasses.dataclass
class CostSummary:
    flops: float
    hbm_bytes: float
    collective_link_bytes: float
    collective_by_kind: dict
    collective_counts: dict
    while_trip_counts: list

    def to_json(self):
        return dataclasses.asdict(self)


def analyze_hlo(text: str, n_devices: int) -> CostSummary:
    comps = _parse_computations(text)

    # call-graph multipliers
    mult: dict[str, float] = {}
    trips = []

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op in comp.ops:
            callees = _CALLED.findall(op.line)
            if not callees:
                continue
            child_m = m
            if op.kind == "while":
                t = _TRIP_RE.search(op.line)
                trip = int(t.group(1)) if t else 1
                child_m = m * trip
                trips.append(trip)
            for callee in callees:
                visit(callee, child_m)

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return CostSummary(0, 0, 0, {}, {}, [])
    visit(entry, 1.0)

    flops = 0.0
    hbm = 0.0
    coll_bytes = {k: 0.0 for k in _COLL_KINDS}
    coll_counts = {k: 0 for k in _COLL_KINDS}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        # fused computations: flops counted (dots can fuse), bytes skipped
        is_fused = name.startswith("fused_") or ".fused" in name
        table = _symbol_table(comp)
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, table)
            if not is_fused and op.kind not in _SKIP_BYTES:
                hbm += m * _op_hbm_bytes(op)
            base = next((k for k in _COLL_KINDS if op.kind.startswith(k)), None)
            if base and not op.kind.endswith("-done"):
                coll_bytes[base] += m * _collective_link_bytes(op, n_devices)
                coll_counts[base] += 1
    return CostSummary(
        flops=flops,
        hbm_bytes=hbm,
        collective_link_bytes=sum(coll_bytes.values()),
        collective_by_kind=coll_bytes,
        collective_counts=coll_counts,
        while_trip_counts=trips,
    )
