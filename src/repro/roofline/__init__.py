"""Roofline analysis from compiled HLO artifacts."""
