"""Batched serving engine for quantized models (continuous batching).

Request lifecycle (vLLM-style, sized to this framework's scope):

  submit → waiting queue → (padded) prefill into a free slot → shared
  batched decode steps with **per-slot positions** → finished

Up to ``max_batch`` sequences share one jitted decode executable; finished
slots are refilled from the queue between steps (continuous batching — the
decode step takes a (B,) position vector, so slots at different depths
coexist).  Prefills are right-padded to ``prefill_pad`` buckets so one
prefill executable serves all prompt lengths; the prompt's *last real
token* is replayed as the first decode so padding never pollutes the
distribution (pad positions remain invalid: each slot's validity mask is
its own position).

Weights may be dense bf16 or QuantizedTensor (the PTQ artifact) — the
engine is agnostic; the Pallas dequant-GEMM engages on TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.model import ModelPlan

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (n,) int32
    max_new_tokens: int = 16
    output: Optional[list] = None
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        plan: ModelPlan,
        params,
        *,
        max_batch: int = 4,
        max_seq: int = 512,
        prefill_pad: int = 32,
    ):
        self.plan = plan
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prefill_pad = prefill_pad

        self.cache = init_cache(plan, max_batch, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int64)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._last_tok = np.zeros((max_batch, 1), np.int32)

        self._decode = jax.jit(lambda p, t, c, pos: decode_step(plan, p, t, c, pos))
        self._prefill = jax.jit(lambda p, b, c: prefill(plan, p, b, c))
        self.n_decode_steps = 0
        self.n_prefills = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.output = []
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            n = len(req.prompt)
            pad = min(-(-n // self.prefill_pad) * self.prefill_pad, self.max_seq)
            toks = np.zeros((1, pad), np.int32)
            toks[0, :n] = req.prompt
            tmp_cache = init_cache(self.plan, 1, self.max_seq)
            _, tmp_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, tmp_cache
            )
            self.n_prefills += 1
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_slice(
                    big, one.astype(big.dtype), (0, slot) + (0,) * (big.ndim - 2)
                ),
                self.cache,
                tmp_cache,
            )
            self.slot_req[slot] = req
            # Positions [n, pad) hold pad-token kv; decode from position n by
            # replaying the last real token — the mask (pos<len) hides pads.
            self.slot_pos[slot] = n - 1
            self._last_tok[slot, 0] = int(req.prompt[-1])

    def _retire(self):
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.output) >= req.max_new_tokens or self.slot_pos[i] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None

    def step(self) -> bool:
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self.cache, pos
        )
        self.n_decode_steps += 1
        logits = np.asarray(logits.astype(jnp.float32))
        for i in active:
            tok = int(np.argmax(logits[i]))
            self._last_tok[i, 0] = tok
            self.slot_req[i].output.append(tok)
            self.slot_pos[i] += 1
        self._retire()
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.finished
