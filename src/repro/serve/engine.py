"""Batched serving engines for quantized models (continuous batching).

Two engines share the :class:`Request` lifecycle (submit → waiting queue →
prefill → shared batched decode with per-slot positions → finished) and the
greedy sampler; weights may be dense bf16 or QuantizedTensor (the PTQ
artifact) — both engines are agnostic, and the Pallas dequant-GEMM engages
on TPU.

:class:`ServingEngine` — the **contiguous** baseline: every slot reserves
``max_seq`` KV memory up front, prompts prefill in one padded shot into a
per-slot cache.  Kept as the numerical oracle (the paged engine must match
it token-for-token on bf16 KV) and as the benchmark baseline
(benchmarks/bench_serve.py).

:class:`PagedServingEngine` — the production path (DESIGN.md
§Paged-serving): KV lives in a shared pool of fixed-size pages
(serve/kv_cache.py), admission is gated by free *pages* instead of free
slots, prompts stream in **chunked prefills** interleaved with decode steps
(long prompts never stall the running batch), matching prompt prefixes
share pages (hash-chain prefix cache + copy-on-write partial hits), and
when the pool runs dry the newest sequence is **preempted** — its pages
freed, the request requeued, and later resumed by deterministic
re-prefill of prompt + already-generated tokens (greedy decode makes the
final output identical to an uninterrupted run).  Decode attends through
``ops.paged_attention`` — the Pallas paged kernel on TPU, the XLA gather
fallback elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    init_cache,
    init_paged_cache,
    paged_decode_step,
    paged_prefill_chunk,
    prefill,
)
from repro.models.model import ModelPlan
from repro.serve.kv_cache import NULL_PAGE, PagePool, page_nbytes

__all__ = ["Request", "ServingEngine", "PagedServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (n,) int32
    max_new_tokens: int = 16
    output: Optional[list] = None
    done: bool = False


class ServingEngine:
    """Contiguous-slot engine: per-slot ``max_seq`` KV reservation.

    Prefills are right-padded to ``prefill_pad`` buckets so one prefill
    executable serves all prompt lengths; the prompt's *last real token*
    is replayed as the first decode so padding never pollutes the
    distribution (pad positions remain invalid: each slot's validity mask
    is its own position).
    """

    def __init__(
        self,
        plan: ModelPlan,
        params,
        *,
        max_batch: int = 4,
        max_seq: int = 512,
        prefill_pad: int = 32,
        record_logits: bool = False,
    ):
        self.plan = plan
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prefill_pad = prefill_pad
        self.record_logits = record_logits
        self.logit_trace: dict[int, list] = {}

        self.cache = init_cache(plan, max_batch, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int64)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._last_tok = np.zeros((max_batch, 1), np.int32)

        self._decode = jax.jit(lambda p, t, c, pos: decode_step(plan, p, t, c, pos))
        self._prefill = jax.jit(lambda p, b, c: prefill(plan, p, b, c))
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_tokens = 0  # real prompt tokens (pad excluded)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # Same admission contract as the paged engine: every generated token
        # occupies a cache position, so prompt + max_new must fit the window.
        # In particular a prompt that exactly fills the window
        # (len == max_seq) cannot decode even token 0 — its replay decode
        # would have nowhere left to advance — and is rejected here instead
        # of silently finishing with an empty output (and a longer prompt
        # used to crash prefill with an opaque broadcast error).
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid} cannot fit: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} > max_seq {self.max_seq}"
            )
        req.output = []
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            n = len(req.prompt)
            pad = min(-(-n // self.prefill_pad) * self.prefill_pad, self.max_seq)
            toks = np.zeros((1, pad), np.int32)
            toks[0, :n] = req.prompt
            tmp_cache = init_cache(self.plan, 1, self.max_seq)
            _, tmp_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, tmp_cache
            )
            self.n_prefills += 1
            self.n_prefill_tokens += n
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_slice(
                    big, one.astype(big.dtype), (0, slot) + (0,) * (big.ndim - 2)
                ),
                self.cache,
                tmp_cache,
            )
            self.slot_req[slot] = req
            # Positions [n, pad) hold pad-token kv; decode from position n by
            # replaying the last real token — the mask (pos<len) hides pads.
            self.slot_pos[slot] = n - 1
            self._last_tok[slot, 0] = int(req.prompt[-1])

    def _retire(self):
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.output) >= req.max_new_tokens or self.slot_pos[i] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None

    def step(self) -> bool:
        self._admit()
        self._retire()  # max_new_tokens == 0 finishes without a decode
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self.cache, pos
        )
        self.n_decode_steps += 1
        logits = np.asarray(logits.astype(jnp.float32))
        for i in active:
            tok = int(np.argmax(logits[i]))
            if self.record_logits:
                self.logit_trace.setdefault(self.slot_req[i].rid, []).append(
                    logits[i]
                )
            self._last_tok[i, 0] = tok
            self.slot_req[i].output.append(tok)
            self.slot_pos[i] += 1
        self._retire()
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.finished


@dataclasses.dataclass
class _Seq:
    """Per-lane scheduler state of the paged engine."""

    req: Request
    tokens: list  # prompt + generated so far (resume recomputes from this)
    pages: list  # position-ordered page ids
    n_prefilled: int  # positions [0, n_prefilled) hold valid KV
    n_target: int  # == len(tokens) at admission; prefill ends here
    hashed_upto: int = 0  # pages registered into the prefix cache so far
    order: int = 0  # admission order (preemption picks the newest)


class PagedServingEngine:
    """Paged-KV engine: shared page pool, chunked prefill, prefix cache,
    preemption-by-eviction.  See the module docstring for the scheduler
    contract; on bf16 KV its outputs are token-identical to
    :class:`ServingEngine` (asserted in tests/test_paged_serve.py)."""

    def __init__(
        self,
        plan: ModelPlan,
        params,
        *,
        max_batch: int = 8,
        max_seq: int = 512,
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefill_chunk: int = 64,
        prefix_cache: bool = True,
        record_logits: bool = False,
    ):
        self.plan = plan
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_seq = -(-max_seq // page_size)
        if n_pages is None:
            n_pages = 1 + max_batch * self.pages_per_seq  # ample: no preemption
        self.n_pages = n_pages
        self.prefill_chunk = prefill_chunk
        self.prefix_cache = prefix_cache
        self.record_logits = record_logits

        self.cache = init_paged_cache(plan, n_pages, page_size)
        self.pool = PagePool(n_pages, page_size)
        self.table = np.full((max_batch, self.pages_per_seq), NULL_PAGE, np.int32)
        self._dev_table = None  # rebuilt lazily when self.table changes
        self.lanes: list[Optional[_Seq]] = [None] * max_batch
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.slot_pos = np.zeros(max_batch, np.int64)
        self._last_tok = np.zeros((max_batch, 1), np.int32)
        self._admitted = 0
        self.logit_trace: dict[int, list] = {}

        # The page pool is donated (same policy as launch/specs.py serve
        # specs): each step updates the pool in place instead of allocating
        # and copying a second full pool — self.cache is always reassigned
        # from the result, so the consumed buffer is never reused.
        self._decode = jax.jit(
            lambda p, t, c, pos, pt, pw: paged_decode_step(plan, p, t, c, pos, pt, pw),
            donate_argnums=(2,),
        )
        self._chunk = jax.jit(
            lambda p, t, c, pt, off: paged_prefill_chunk(plan, p, t, c, pt, off),
            donate_argnums=(2,),
        )
        # COW page copy: every leaf is (n_periods, n_pages, ...).
        self._copy_page = jax.jit(
            lambda c, s, d: jax.tree.map(lambda a: a.at[:, d].set(a[:, s]), c),
            donate_argnums=(0,),
        )

        self.n_decode_steps = 0
        self.n_prefill_chunks = 0
        self.n_prefill_tokens = 0
        self.n_prefix_hit_tokens = 0
        self.n_cow_hits = 0
        self.n_guard_copies = 0  # replay-target copies off registered pages
        self.n_preemptions = 0
        # KV pages streamed by decode attention: Σ over decode steps and
        # active lanes of ceil(context/page_size) — the roofline's
        # context_pages term, measured.  Periods are folded in by
        # :meth:`kv_read_bytes` (every page id spans all layers).
        self.n_kv_page_reads = 0

    def kv_read_bytes(self) -> int:
        """Decode-attention KV bytes implied by the page-read counter, in
        the same units as roofline.paged_kv_bytes_per_token — measured
        counterpart of the predicted bytes/token (benchmarks/report.py
        renders them side by side)."""
        hp = self.plan.heads
        per_page = page_nbytes(
            self.page_size, hp.kv_pad, hp.head_dim,
            self.plan.cfg.n_periods, self.plan.kv_cache_dtype,
        )
        return self.n_kv_page_reads * per_page

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        need = -(-(len(req.prompt) + req.max_new_tokens) // self.page_size)
        if need > self.n_pages - 1 or len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid} cannot fit: needs {need} pages / "
                f"{len(req.prompt) + req.max_new_tokens} positions"
            )
        req.output = []
        self.queue.append(req)

    def _dev_table_now(self):
        if self._dev_table is None:
            self._dev_table = jnp.asarray(self.table)
        return self._dev_table

    def _set_row(self, lane: int, pages: list):
        self.table[lane] = NULL_PAGE
        self.table[lane, : len(pages)] = pages
        self._dev_table = None

    # -- admission ------------------------------------------------------
    def _admit(self):
        for lane in range(self.max_batch):
            if self.lanes[lane] is not None or not self.queue:
                continue
            req = self.queue[0]
            if req.max_new_tokens <= 0:  # nothing to generate: skip the pool
                self.queue.pop(0)
                req.done = True
                self.finished.append(req)
                continue
            toks = list(map(int, req.prompt)) + list(req.output)
            T = len(toks)
            tt = tuple(toks)
            pages, n_cached, cow_src = [], 0, None
            if self.prefix_cache:
                pages, n_cached = self.pool.match_full(tt)
                cow_src = self.pool.match_partial(tt, n_cached)
            need = -(-T // self.page_size) - len(pages)
            fresh = self.pool.alloc(need)
            if fresh is None:  # head-of-line blocking keeps FIFO fairness
                for p in pages:
                    self.pool.release(p)
                break
            if cow_src is not None and fresh:
                # Copy-on-write partial hit: the first fresh page starts as
                # a copy of the cached page; the matched tail of the prompt
                # is then already-valid KV.
                self.cache = self._copy_page(self.cache, cow_src, fresh[0])
                n_cached = T
                self.n_cow_hits += 1
            elif pages and n_cached >= T:
                # Full-coverage hit: the replay decode will write position
                # T-1, and replay bytes are decode-path, not prefill-path
                # (≈1 ulp apart) — never write a shared page; give this
                # sequence a private copy of the last one (COW), which also
                # keeps its first-step logits bit-identical to a cold run.
                repl = self.pool.alloc(1)
                if repl is None:
                    for p in pages:
                        self.pool.release(p)
                    break
                self.cache = self._copy_page(self.cache, pages[-1], repl[0])
                self.pool.release(pages[-1])
                pages[-1] = repl[0]
                self.n_cow_hits += 1
            self.queue.pop(0)
            seq = _Seq(
                req=req, tokens=toks, pages=pages + fresh,
                n_prefilled=n_cached, n_target=T,
                hashed_upto=len(pages), order=self._admitted,
            )
            self._admitted += 1
            self.n_prefix_hit_tokens += n_cached
            self.lanes[lane] = seq
            self._set_row(lane, seq.pages)
            if seq.n_prefilled >= T:
                self._arm_decode(lane, seq)

    def _arm_decode(self, lane: int, seq: _Seq):
        # The replay decode writes position T-1 with decode-path bytes
        # (≈1 ulp from the prefill-path bytes).  If that page is already
        # registered in the prefix cache (page-aligned prompt: its final
        # page registered the moment prefill filled it), give the sequence
        # a private copy so registered content stays prefill-pure — a
        # later warm hit must read exactly what a cold prefill would have
        # written.  Shared (ref > 1) replay targets can't reach here: the
        # full-coverage admission branch already COWed them.
        pg = (seq.n_target - 1) // self.page_size
        pid = seq.pages[pg]
        if pid in self.pool.key_of:
            repl = self.pool.alloc(1)
            if repl is not None:
                self.cache = self._copy_page(self.cache, pid, repl[0])
                self.pool.release(pid)
                seq.pages[pg] = repl[0]
                self.table[lane, pg] = repl[0]
                self._dev_table = None
                self.n_guard_copies += 1
            else:
                # Pool dry: write in place, but drop the registration so no
                # future prefix hit reads the mutated bytes.
                self.pool._unregister(pid)
        self.slot_pos[lane] = seq.n_target - 1  # replay the last known token
        self._last_tok[lane, 0] = seq.tokens[-1]

    # -- chunked prefill -------------------------------------------------
    def _register_ready(self, seq: _Seq):
        psz = self.page_size
        while (seq.hashed_upto + 1) * psz <= seq.n_prefilled:
            i = seq.hashed_upto
            self.pool.register(seq.pages[i], tuple(seq.tokens[: (i + 1) * psz]))
            seq.hashed_upto = i + 1

    def _prefill_step(self) -> bool:
        """Run ONE prompt chunk (the oldest unfinished prefill) — prefill
        interleaves with decode instead of stalling the batch.  Chunks are
        always padded to ``prefill_chunk`` so a single executable serves
        every (offset, tail) shape: pad positions scatter into the null
        page or into not-yet-valid slots that decode rewrites before any
        length mask exposes them."""
        cand = [
            (s.order, lane, s)
            for lane, s in enumerate(self.lanes)
            if s is not None and s.n_prefilled < s.n_target
        ]
        if not cand:
            return False
        _, lane, seq = min(cand)
        off = seq.n_prefilled
        C = min(self.prefill_chunk, seq.n_target - off)
        buf = np.zeros((1, self.prefill_chunk), np.int32)
        buf[0, :C] = seq.tokens[off : off + C]
        self.cache = self._chunk(
            self.params, jnp.asarray(buf), self.cache,
            self._dev_table_now()[lane : lane + 1], np.int32(off),
        )
        seq.n_prefilled += C
        self.n_prefill_chunks += 1
        self.n_prefill_tokens += C
        if self.prefix_cache:
            self._register_ready(seq)
        if seq.n_prefilled >= seq.n_target:
            self._arm_decode(lane, seq)
        return True

    # -- decode ----------------------------------------------------------
    def _preempt(self, lane: int):
        seq = self.lanes[lane]
        for p in seq.pages:
            self.pool.release(p)
        self.lanes[lane] = None
        self._set_row(lane, [])
        self.queue.insert(0, seq.req)  # resume ASAP; output so far is kept
        self.n_preemptions += 1

    def _decode_ready(self):
        return [
            i for i, s in enumerate(self.lanes)
            if s is not None and s.n_prefilled >= s.n_target
        ]

    def _ensure_capacity(self) -> list[int]:
        """Grow each decoding lane's page list to cover its write position,
        preempting the newest sequence when the pool runs dry."""
        while True:
            active = self._decode_ready()
            blocked = None
            for i in active:
                seq = self.lanes[i]
                pg = int(self.slot_pos[i]) // self.page_size
                if pg < len(seq.pages):
                    continue
                got = self.pool.alloc(1)
                if got is None:
                    blocked = i
                    break
                seq.pages.append(got[0])
                self.table[i, pg] = got[0]
                self._dev_table = None
            if blocked is None:
                return self._decode_ready()
            victims = self._decode_ready() + [
                j for j, s in enumerate(self.lanes)
                if s is not None and s.n_prefilled < s.n_target
            ]
            victim = max(victims, key=lambda i: self.lanes[i].order)
            if victim == blocked and len(victims) == 1:
                raise RuntimeError(
                    "page pool too small for a single sequence"
                )  # pragma: no cover — submit() bounds prevent this
            self._preempt(victim)

    def _decode_step(self) -> bool:
        active = self._ensure_capacity()
        if not active:
            return False
        write_page = np.full(self.max_batch, NULL_PAGE, np.int32)
        pos = np.zeros(self.max_batch, np.int32)
        for i in active:
            seq = self.lanes[i]
            pos[i] = self.slot_pos[i]
            write_page[i] = seq.pages[int(self.slot_pos[i]) // self.page_size]
            self.n_kv_page_reads += -(-(int(self.slot_pos[i]) + 1) // self.page_size)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self.cache,
            jnp.asarray(pos), self._dev_table_now(), jnp.asarray(write_page),
        )
        self.n_decode_steps += 1
        logits = np.asarray(logits.astype(jnp.float32))
        for i in active:
            seq = self.lanes[i]
            tok = int(np.argmax(logits[i]))
            if self.record_logits:
                self.logit_trace.setdefault(seq.req.rid, []).append(logits[i])
            self._last_tok[i, 0] = tok
            seq.req.output.append(tok)
            seq.tokens.append(tok)
            self.slot_pos[i] += 1
        return True

    def _retire(self):
        for i, seq in enumerate(self.lanes):
            if seq is None or seq.n_prefilled < seq.n_target:
                continue
            req = seq.req
            if len(req.output) >= req.max_new_tokens or self.slot_pos[i] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                for p in seq.pages:
                    self.pool.release(p)
                self.lanes[i] = None
                self._set_row(i, [])

    # ------------------------------------------------------------------
    def step(self) -> bool:
        self._admit()
        progressed = self._prefill_step()
        # Nothing can decode yet (cold start / post-preemption ramp): drain
        # prefills instead of burning empty steps — time-to-first-token.
        while progressed and not self._decode_ready():
            if not self._prefill_step():
                break
        progressed |= self._decode_step()
        self._retire()
        return progressed

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.lanes)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.finished
