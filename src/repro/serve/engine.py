"""Batched serving engines for quantized models (continuous batching).

Two engines share the :class:`Request` lifecycle (submit → waiting queue →
prefill → shared batched decode with per-slot positions → finished) and the
greedy sampler; weights may be dense bf16 or QuantizedTensor (the PTQ
artifact) — both engines are agnostic, and the Pallas dequant-GEMM engages
on TPU.

:class:`ServingEngine` — the **contiguous** baseline: every slot reserves
``max_seq`` KV memory up front, prompts prefill in one padded shot into a
per-slot cache.  Kept as the numerical oracle (the paged engine must match
it token-for-token on bf16 KV) and as the benchmark baseline
(benchmarks/bench_serve.py).

:class:`PagedServingEngine` — the production path (DESIGN.md
§Paged-serving): KV lives in a shared pool of fixed-size pages
(serve/kv_cache.py), admission is gated by free *pages* instead of free
slots, prompts stream in **chunked prefills** interleaved with decode steps
(long prompts never stall the running batch), matching prompt prefixes
share pages (hash-chain prefix cache + copy-on-write partial hits), and
when the pool runs dry a sequence is **preempted** — its pages freed, the
request requeued, and later resumed by deterministic re-prefill of
prompt + already-generated tokens (greedy decode makes the final output
identical to an uninterrupted run).  Decode attends through
``ops.paged_attention`` — the Pallas paged kernel on TPU, the XLA gather
fallback elsewhere.

SLO scheduling (DESIGN.md §Resilience): requests carry an optional
``deadline_ms`` (relative to submit) and an integer ``priority`` (higher =
more important).  Under ``scheduler="slo"`` (the default) the engine

* admits in ``(priority desc, deadline asc, arrival)`` order — low-priority
  requests **park** in the queue under sustained pressure instead of
  competing for pages;
* **sheds** a request at admission when its deadline is *provably*
  unmeetable — the optimistic lower bound (its own prefill chunks + decode
  steps at the fastest step cost ever observed, i.e. assuming zero queueing
  and zero pool pressure) already overshoots the deadline;
* **expires** queued or running requests the moment their deadline passes
  (pages freed, partial output kept) instead of burning pool on work
  nobody can use;
* preempts by **deadline/priority**: the victim is the lowest-priority,
  most-slack, newest sequence — not simply the newest.

Every request leaves with a terminal ``status``: ``completed`` (all tokens,
never preempted), ``preempted_resumed`` (all tokens, survived ≥1
preemption — token-identical to an uninterrupted run by the deterministic
resume contract), ``shed``, or ``deadline_missed``.  ``scheduler="fifo"``
keeps the legacy FIFO/preempt-newest behaviour and ignores deadlines — the
benchmark baseline for the SLO scheduler.

Fault injection: both engines consult ``fault_point("engine.step")`` at the
top of :meth:`step` — before any state mutation — so an injected transient
fault is counted and retried as a pure no-op step; page allocation runs
through the ``pool.alloc`` injection point (a denial spike exercises
preemption and, if nothing else holds pages, self-preemption and retry
rather than a crash).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults import TransientFault, fault_point
from repro.models import (
    decode_step,
    init_cache,
    init_paged_cache,
    paged_decode_step,
    paged_prefill_chunk,
    paged_verify_tokens,
    prefill,
)
from repro.models.model import ModelPlan
from repro.serve.kv_cache import NULL_PAGE, PagePool, page_nbytes
from repro.serve.spec import DraftManager, SpecConfig, maybe_hoist

__all__ = ["Request", "ServingEngine", "PagedServingEngine", "TERMINAL_STATUSES"]

TERMINAL_STATUSES = ("completed", "preempted_resumed", "shed", "deadline_missed")

_INF = float("inf")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (n,) int32
    max_new_tokens: int = 16
    deadline_ms: Optional[float] = None  # SLO deadline, relative to submit
    priority: int = 0  # higher = more important (scheduler="slo" only)
    output: Optional[list] = None
    done: bool = False
    status: str = "pending"  # terminal: one of TERMINAL_STATUSES
    error: Optional[str] = None  # set when shed (the clear rejection reason)
    submit_t: Optional[float] = None  # engine-clock timestamps
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    n_preemptions: int = 0
    submit_order: int = -1  # arrival tie-break (assigned by the engine)
    # Speculative-decoding accounting (all zero when the engine doesn't
    # speculate): commit rounds this request went through, proposals the
    # target scored, and how many it accepted.  Every round commits
    # accepted + 1 tokens (the bonus token), so
    # ``len(output) == n_draft_accepted + n_spec_rounds`` exactly — the
    # accounting test pins this identity.
    n_spec_rounds: int = 0
    n_draft_tokens: int = 0
    n_draft_accepted: int = 0

    def acceptance_rate(self) -> Optional[float]:
        """Fraction of proposed draft tokens the target accepted (None
        when nothing was ever proposed for this request)."""
        if self.n_draft_tokens == 0:
            return None
        return self.n_draft_accepted / self.n_draft_tokens

    def deadline_at(self) -> float:
        """Absolute engine-clock deadline (inf when no SLO attached)."""
        if self.deadline_ms is None or self.submit_t is None:
            return _INF
        return self.submit_t + self.deadline_ms / 1e3


class ServingEngine:
    """Contiguous-slot engine: per-slot ``max_seq`` KV reservation.

    Prefills are right-padded to ``prefill_pad`` buckets so one prefill
    executable serves all prompt lengths; the prompt's *last real token*
    is replayed as the first decode so padding never pollutes the
    distribution (pad positions remain invalid: each slot's validity mask
    is its own position).
    """

    def __init__(
        self,
        plan: ModelPlan,
        params,
        *,
        max_batch: int = 4,
        max_seq: int = 512,
        prefill_pad: int = 32,
        record_logits: bool = False,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.plan = plan
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prefill_pad = prefill_pad
        self.record_logits = record_logits
        self.clock = clock or time.monotonic
        self.logit_trace: dict[int, list] = {}

        self.cache = init_cache(plan, max_batch, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int64)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._last_tok = np.zeros((max_batch, 1), np.int32)
        self._submitted = 0

        self._decode = jax.jit(lambda p, t, c, pos: decode_step(plan, p, t, c, pos))
        self._prefill = jax.jit(lambda p, b, c: prefill(plan, p, b, c))
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_tokens = 0  # real prompt tokens (pad excluded)
        self.n_transient_faults = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # Same admission contract as the paged engine: every generated token
        # occupies a cache position, so prompt + max_new must fit the window.
        # In particular a prompt that exactly fills the window
        # (len == max_seq) cannot decode even token 0 — its replay decode
        # would have nowhere left to advance — and is rejected here instead
        # of silently finishing with an empty output (and a longer prompt
        # used to crash prefill with an opaque broadcast error).
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid} cannot fit: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} > max_seq {self.max_seq}"
            )
        req.output = []
        req.status = "queued"
        req.submit_t = self.clock()
        req.submit_order = self._submitted
        self._submitted += 1
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            n = len(req.prompt)
            pad = min(-(-n // self.prefill_pad) * self.prefill_pad, self.max_seq)
            toks = np.zeros((1, pad), np.int32)
            toks[0, :n] = req.prompt
            tmp_cache = init_cache(self.plan, 1, self.max_seq)
            _, tmp_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, tmp_cache
            )
            self.n_prefills += 1
            self.n_prefill_tokens += n
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_slice(
                    big, one.astype(big.dtype), (0, slot) + (0,) * (big.ndim - 2)
                ),
                self.cache,
                tmp_cache,
            )
            self.slot_req[slot] = req
            # Positions [n, pad) hold pad-token kv; decode from position n by
            # replaying the last real token — the mask (pos<len) hides pads.
            self.slot_pos[slot] = n - 1
            self._last_tok[slot, 0] = int(req.prompt[-1])

    def _retire(self):
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if len(req.output) >= req.max_new_tokens or self.slot_pos[i] >= self.max_seq - 1:
                req.done = True
                req.status = "completed"
                req.finish_t = self.clock()
                self.finished.append(req)
                self.slot_req[i] = None

    def step(self) -> bool:
        try:
            fault_point("engine.step")
        except TransientFault:
            # Nothing mutated yet — a pure no-op step; retry next time.
            self.n_transient_faults += 1
            return True
        self._admit()
        self._retire()  # max_new_tokens == 0 finishes without a decode
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self.cache, pos
        )
        self.n_decode_steps += 1
        logits = np.asarray(logits.astype(jnp.float32))
        now = self.clock()
        for i in active:
            tok = int(np.argmax(logits[i]))
            if self.record_logits:
                self.logit_trace.setdefault(self.slot_req[i].rid, []).append(
                    logits[i]
                )
            self._last_tok[i, 0] = tok
            req = self.slot_req[i]
            if not req.output:
                req.first_token_t = now
            req.output.append(tok)
            self.slot_pos[i] += 1
        self._retire()
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.finished


@dataclasses.dataclass
class _Seq:
    """Per-lane scheduler state of the paged engine."""

    req: Request
    tokens: list  # prompt + generated so far (resume recomputes from this)
    pages: list  # position-ordered page ids
    n_prefilled: int  # positions [0, n_prefilled) hold valid KV
    n_target: int  # == len(tokens) at admission; prefill ends here
    hashed_upto: int = 0  # pages registered into the prefix cache so far
    order: int = 0  # admission order (the final preemption tie-break)


class PagedServingEngine:
    """Paged-KV engine: shared page pool, chunked prefill, prefix cache,
    SLO-aware scheduling with preemption-by-eviction.  See the module
    docstring for the scheduler contract; on bf16 KV its outputs are
    token-identical to :class:`ServingEngine` (tests/test_paged_serve.py)."""

    def __init__(
        self,
        plan: ModelPlan,
        params,
        *,
        max_batch: int = 8,
        max_seq: int = 512,
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefill_chunk: int = 64,
        prefix_cache: bool = True,
        record_logits: bool = False,
        scheduler: str = "slo",
        spec: Optional[SpecConfig] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        if scheduler not in ("slo", "fifo"):
            raise ValueError(f"unknown scheduler {scheduler!r}; expected slo|fifo")
        if spec is not None and spec.draft_plan.cfg.vocab != plan.cfg.vocab:
            raise ValueError(
                f"draft vocab {spec.draft_plan.cfg.vocab} != target vocab "
                f"{plan.cfg.vocab}: draft proposals would not be target tokens"
            )
        self.plan = plan
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_seq = -(-max_seq // page_size)
        if n_pages is None:
            n_pages = 1 + max_batch * self.pages_per_seq  # ample: no preemption
        self.n_pages = n_pages
        self.prefill_chunk = prefill_chunk
        self.prefix_cache = prefix_cache
        self.record_logits = record_logits
        self.scheduler = scheduler
        self.clock = clock or time.monotonic

        self.cache = init_paged_cache(plan, n_pages, page_size)
        self.pool = PagePool(n_pages, page_size)
        self.table = np.full((max_batch, self.pages_per_seq), NULL_PAGE, np.int32)
        self._dev_table = None  # rebuilt lazily when self.table changes
        self.lanes: list[Optional[_Seq]] = [None] * max_batch
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.slot_pos = np.zeros(max_batch, np.int64)
        self._last_tok = np.zeros((max_batch, 1), np.int32)
        self._admitted = 0
        self._submitted = 0
        self.logit_trace: dict[int, list] = {}

        # The page pool is donated (same policy as launch/specs.py serve
        # specs): each step updates the pool in place instead of allocating
        # and copying a second full pool — self.cache is always reassigned
        # from the result, so the consumed buffer is never reused.
        self._decode = jax.jit(
            lambda p, t, c, pos, pt, pw: paged_decode_step(plan, p, t, c, pos, pt, pw),
            donate_argnums=(2,),
        )
        self._chunk = jax.jit(
            lambda p, t, c, pt, off: paged_prefill_chunk(plan, p, t, c, pt, off),
            donate_argnums=(2,),
        )
        # COW page copy: every leaf is (n_periods, n_pages, ...).
        self._copy_page = jax.jit(
            lambda c, s, d: jax.tree.map(lambda a: a.at[:, d].set(a[:, s]), c),
            donate_argnums=(0,),
        )

        # Speculative decoding (DESIGN.md §Speculative-serving): a draft
        # stack proposes, one fused γ+1-position verify scores, the longest
        # target-greedy prefix + bonus token commits.  The verify runs the
        # decode step over B·γ+1 *virtual lanes* — decode-path KV bytes and
        # arithmetic per position — so speculative greedy output is
        # token-identical to the plain loop.
        self.spec = spec
        self.spec_mgr: Optional[DraftManager] = None
        if spec is not None:
            self.spec_mgr = DraftManager(
                spec, pool=self.pool, n_pages=n_pages, max_batch=max_batch,
                max_seq=max_seq, page_size=page_size,
                prefill_chunk=prefill_chunk,
            )
            self._verify_fn = jax.jit(
                lambda p, t, c, pos, pt, wp: paged_verify_tokens(
                    plan, p, t, c, pos, pt, wp
                ),
                donate_argnums=(2,),
            )
            # Verify-path weight view: where the GEMM dispatch would take
            # the XLA reference anyway (off-TPU), quantized leaves are
            # pre-dequantized ONCE (models/common.HoistedDequant) so the
            # γ+1-position scan doesn't re-dequantize loop-invariant
            # weights every position — bitwise-identical results, so the
            # token-identity invariant is untouched.  The legacy L=1
            # branch keeps self.params: its cost feeds the provable-shed
            # floor and its bytes are the pre-speculation hot path.
            self._verify_params = maybe_hoist(params, spec.hoist_dequant)

        self.n_decode_steps = 0
        self.n_prefill_chunks = 0
        self.n_prefill_tokens = 0
        self.n_prefix_hit_tokens = 0
        self.n_cow_hits = 0
        self.n_guard_copies = 0  # replay-target copies off registered pages
        self.n_preemptions = 0
        self.n_shed = 0
        self.n_deadline_missed = 0
        self.n_transient_faults = 0
        # Speculative counters (stay zero without a SpecConfig).
        self.n_spec_rounds = 0
        self.n_draft_tokens = 0
        self.n_draft_accepted = 0
        # Fastest step costs ever observed (engine clock): the optimistic
        # per-step floor behind provable-shed admission.  None until the
        # first measurement — admission cannot *prove* anything without
        # cost evidence, so it never sheds cold.
        self._min_decode_s: Optional[float] = None
        self._min_chunk_s: Optional[float] = None
        # KV pages streamed by decode attention: Σ over decode steps and
        # active lanes of ceil(context/page_size) — the roofline's
        # context_pages term, measured.  Periods are folded in by
        # :meth:`kv_read_bytes` (every page id spans all layers).
        self.n_kv_page_reads = 0

    def kv_read_bytes(self) -> int:
        """Decode-attention KV bytes implied by the page-read counter, in
        the same units as roofline.paged_kv_bytes_per_token — measured
        counterpart of the predicted bytes/token (benchmarks/report.py
        renders them side by side)."""
        hp = self.plan.heads
        per_page = page_nbytes(
            self.page_size, hp.kv_pad, hp.head_dim,
            self.plan.cfg.n_periods, self.plan.kv_cache_dtype,
        )
        return self.n_kv_page_reads * per_page

    def acceptance_rate(self) -> Optional[float]:
        """Engine-wide draft acceptance (None before any proposal)."""
        if self.n_draft_tokens == 0:
            return None
        return self.n_draft_accepted / self.n_draft_tokens

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        need = -(-(len(req.prompt) + req.max_new_tokens) // self.page_size)
        if need > self.n_pages - 1 or len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid} cannot fit: needs {need} pages / "
                f"{len(req.prompt) + req.max_new_tokens} positions"
            )
        req.output = []
        req.status = "queued"
        req.submit_t = self.clock()
        req.submit_order = self._submitted
        self._submitted += 1
        self.queue.append(req)

    def _finish(self, req: Request, status: str, error: Optional[str] = None):
        req.done = True
        req.status = status
        req.error = error
        req.finish_t = self.clock()
        if status == "shed":
            self.n_shed += 1
        elif status == "deadline_missed":
            self.n_deadline_missed += 1
        self.finished.append(req)

    def _release_lane(self, lane: int):
        seq = self.lanes[lane]
        for p in seq.pages:
            self.pool.release(p)
        self.lanes[lane] = None
        self._set_row(lane, [])
        if self.spec_mgr is not None:  # draft pages go with the lane
            self.spec_mgr.release_lane(lane)

    def _dev_table_now(self):
        if self._dev_table is None:
            self._dev_table = jnp.asarray(self.table)
        return self._dev_table

    def _set_row(self, lane: int, pages: list):
        self.table[lane] = NULL_PAGE
        self.table[lane, : len(pages)] = pages
        self._dev_table = None

    # -- SLO bookkeeping ------------------------------------------------
    def _queue_pick(self) -> int:
        """Index into ``self.queue`` of the next request to admit.

        ``fifo``: strict arrival order (preempted requests re-queue at the
        front).  ``slo``: highest priority first, then earliest deadline,
        then arrival — which is exactly how low-priority requests *park*
        under sustained pressure: they stay queued (holding no pages)
        while urgent work flows past them.
        """
        if self.scheduler == "fifo" or len(self.queue) == 1:
            return 0
        return min(
            range(len(self.queue)),
            key=lambda i: (
                -self.queue[i].priority,
                self.queue[i].deadline_at(),
                self.queue[i].submit_order,
            ),
        )

    def _provably_unmeetable(self, req: Request) -> Optional[str]:
        """A rejection reason when even the *optimistic* completion bound —
        the request's own prefill chunks plus its remaining decode steps at
        the fastest per-step cost ever observed, assuming zero queueing and
        zero pool pressure — overshoots the deadline.  Conservative by
        construction: real pressure only makes it later."""
        if req.deadline_ms is None:
            return None
        if self._min_decode_s is None:
            return None  # no cost evidence yet: nothing is provable
        now = self.clock()
        deadline = req.deadline_at()
        T = len(req.prompt) + len(req.output)
        n_chunks = -(-T // self.prefill_chunk)
        remaining = req.max_new_tokens - len(req.output)
        t_min = n_chunks * (self._min_chunk_s or 0.0) + remaining * self._min_decode_s
        if now + t_min > deadline:
            return (
                f"deadline {req.deadline_ms:.1f}ms provably unmeetable: "
                f"optimistic completion needs {t_min * 1e3:.1f}ms "
                f"({n_chunks} prefill chunks + {remaining} decode steps at "
                f"best-observed step cost) but only "
                f"{max(deadline - now, 0.0) * 1e3:.1f}ms remain"
            )
        return None

    def _expire_deadlines(self):
        """Terminate queued/running requests whose deadline has passed —
        partial output is kept, pages are freed immediately (degradation
        ladder rung 4: stop burning pool on work nobody can use)."""
        if self.scheduler != "slo":
            return
        now = self.clock()
        expired = [r for r in self.queue if r.deadline_at() <= now]
        for req in expired:
            self.queue.remove(req)
            self._finish(req, "deadline_missed")
        for lane, seq in enumerate(self.lanes):
            if seq is not None and seq.req.deadline_at() <= now:
                req = seq.req
                self._release_lane(lane)
                self._finish(req, "deadline_missed")

    # -- admission ------------------------------------------------------
    def _admit(self):
        for lane in range(self.max_batch):
            if self.lanes[lane] is not None or not self.queue:
                continue
            req = self.queue[self._queue_pick()]
            if self.scheduler == "slo":
                reason = self._provably_unmeetable(req)
                if reason is not None:
                    self.queue.remove(req)
                    self._finish(req, "shed", reason)
                    continue
            if req.max_new_tokens <= 0:  # nothing to generate: skip the pool
                self.queue.remove(req)
                self._finish(req, "completed")
                continue
            toks = list(map(int, req.prompt)) + list(req.output)
            T = len(toks)
            tt = tuple(toks)
            pages, n_cached, cow_src = [], 0, None
            if self.prefix_cache:
                pages, n_cached = self.pool.match_full(tt)
                cow_src = self.pool.match_partial(tt, n_cached)
            need = -(-T // self.page_size) - len(pages)
            fresh = self.pool.alloc(need)
            if fresh is None:  # head-of-line blocking keeps priority order
                for p in pages:
                    self.pool.release(p)
                break
            if cow_src is not None and fresh:
                # Copy-on-write partial hit: the first fresh page starts as
                # a copy of the cached page; the matched tail of the prompt
                # is then already-valid KV.
                self.cache = self._copy_page(self.cache, cow_src, fresh[0])
                n_cached = T
                self.n_cow_hits += 1
            elif pages and n_cached >= T:
                # Full-coverage hit: the replay decode will write position
                # T-1, and replay bytes are decode-path, not prefill-path
                # (≈1 ulp apart) — never write a shared page; give this
                # sequence a private copy of the last one (COW), which also
                # keeps its first-step logits bit-identical to a cold run.
                repl = self.pool.alloc(1)
                if repl is None:
                    for p in pages:
                        self.pool.release(p)
                    # Livelock audit: a full-coverage hit needs matched
                    # pages + 1 private COW page.  When that exceeds every
                    # page the pool could ever produce, no amount of
                    # waiting or eviction helps — the matched pages
                    # themselves exhaust the pool, and retrying each step
                    # re-matches them forever.  Reject with a clear error
                    # instead of livelocking the step loop.
                    if -(-T // self.page_size) + 1 > self.n_pages - 1:
                        self.queue.remove(req)
                        self._finish(
                            req, "shed",
                            f"request {req.rid} unsatisfiable: full prefix-"
                            f"cache hit needs {-(-T // self.page_size)} "
                            f"matched pages + 1 replay copy-on-write page, "
                            f"but the pool holds only {self.n_pages - 1} "
                            "allocatable pages — admission would livelock",
                        )
                        continue
                    break
                self.cache = self._copy_page(self.cache, pages[-1], repl[0])
                self.pool.release(pages[-1])
                pages[-1] = repl[0]
                self.n_cow_hits += 1
            self.queue.remove(req)
            seq = _Seq(
                req=req, tokens=toks, pages=pages + fresh,
                n_prefilled=n_cached, n_target=T,
                hashed_upto=len(pages), order=self._admitted,
            )
            self._admitted += 1
            self.n_prefix_hit_tokens += n_cached
            self.lanes[lane] = seq
            self._set_row(lane, seq.pages)
            if seq.n_prefilled >= T:
                self._arm_decode(lane, seq)

    def _arm_decode(self, lane: int, seq: _Seq):
        # The replay decode writes position T-1 with decode-path bytes
        # (≈1 ulp from the prefill-path bytes).  If that page is already
        # registered in the prefix cache (page-aligned prompt: its final
        # page registered the moment prefill filled it), give the sequence
        # a private copy so registered content stays prefill-pure — a
        # later warm hit must read exactly what a cold prefill would have
        # written.  Shared (ref > 1) replay targets can't reach here: the
        # full-coverage admission branch already COWed them.
        pg = (seq.n_target - 1) // self.page_size
        pid = seq.pages[pg]
        if pid in self.pool.key_of:
            repl = self.pool.alloc(1)
            if repl is not None:
                self.cache = self._copy_page(self.cache, pid, repl[0])
                self.pool.release(pid)
                seq.pages[pg] = repl[0]
                self.table[lane, pg] = repl[0]
                self._dev_table = None
                self.n_guard_copies += 1
            else:
                # Pool dry: write in place, but drop the registration so no
                # future prefix hit reads the mutated bytes.
                self.pool._unregister(pid)
        self.slot_pos[lane] = seq.n_target - 1  # replay the last known token
        self._last_tok[lane, 0] = seq.tokens[-1]
        if self.spec_mgr is not None:
            self.spec_mgr.attach(lane, seq)

    # -- chunked prefill -------------------------------------------------
    def _register_ready(self, seq: _Seq):
        psz = self.page_size
        while (seq.hashed_upto + 1) * psz <= seq.n_prefilled:
            i = seq.hashed_upto
            self.pool.register(seq.pages[i], tuple(seq.tokens[: (i + 1) * psz]))
            seq.hashed_upto = i + 1

    def _prefill_step(self) -> bool:
        """Run ONE prompt chunk (the oldest unfinished prefill) — prefill
        interleaves with decode instead of stalling the batch.  Chunks are
        always padded to ``prefill_chunk`` so a single executable serves
        every (offset, tail) shape: pad positions scatter into the null
        page or into not-yet-valid slots that decode rewrites before any
        length mask exposes them."""
        cand = [
            (s.order, lane, s)
            for lane, s in enumerate(self.lanes)
            if s is not None and s.n_prefilled < s.n_target
        ]
        if not cand:
            return False
        _, lane, seq = min(cand)
        off = seq.n_prefilled
        C = min(self.prefill_chunk, seq.n_target - off)
        buf = np.zeros((1, self.prefill_chunk), np.int32)
        buf[0, :C] = seq.tokens[off : off + C]
        t0 = self.clock()
        self.cache = self._chunk(
            self.params, jnp.asarray(buf), self.cache,
            self._dev_table_now()[lane : lane + 1], np.int32(off),
        )
        dt = self.clock() - t0
        if dt > 0:
            self._min_chunk_s = dt if self._min_chunk_s is None else min(self._min_chunk_s, dt)
        seq.n_prefilled += C
        self.n_prefill_chunks += 1
        self.n_prefill_tokens += C
        if self.prefix_cache:
            self._register_ready(seq)
        if seq.n_prefilled >= seq.n_target:
            self._arm_decode(lane, seq)
        return True

    # -- decode ----------------------------------------------------------
    def _preempt(self, lane: int):
        seq = self.lanes[lane]
        self._release_lane(lane)
        seq.req.n_preemptions += 1
        if self.scheduler == "fifo":
            self.queue.insert(0, seq.req)  # resume ASAP; output so far is kept
        else:
            # slo: _queue_pick favours the earliest submit_order within a
            # priority class, so the preempted request still resumes ahead
            # of later arrivals of equal urgency.
            self.queue.append(seq.req)
        self.n_preemptions += 1

    def _victim(self, victims: list) -> int:
        """Preemption victim: under ``slo``, evict the lowest-priority,
        most-slack (latest-deadline), newest sequence; under ``fifo``, the
        newest.  With no deadlines and uniform priorities the two policies
        coincide (the legacy determinism tests pin this)."""
        if self.scheduler == "fifo":
            return max(victims, key=lambda i: self.lanes[i].order)
        now = self.clock()
        return max(
            victims,
            key=lambda i: (
                -self.lanes[i].req.priority,
                self.lanes[i].req.deadline_at() - now,
                self.lanes[i].order,
            ),
        )

    def _decode_ready(self):
        return [
            i for i, s in enumerate(self.lanes)
            if s is not None and s.n_prefilled >= s.n_target
        ]

    def _ensure_capacity(self) -> list[int]:
        """Grow each decoding lane's page list to cover its write position,
        preempting by deadline/priority when the pool runs dry."""
        while True:
            active = self._decode_ready()
            blocked = None
            for i in active:
                seq = self.lanes[i]
                pg = int(self.slot_pos[i]) // self.page_size
                if pg < len(seq.pages):
                    continue
                got = self.pool.alloc(1)
                if got is None:
                    blocked = i
                    break
                seq.pages.append(got[0])
                self.table[i, pg] = got[0]
                self._dev_table = None
            if blocked is None:
                return self._decode_ready()
            victims = self._decode_ready() + [
                j for j, s in enumerate(self.lanes)
                if s is not None and s.n_prefilled < s.n_target
            ]
            victim = self._victim(victims)
            if victim == blocked and len(victims) == 1:
                seq = self.lanes[blocked]
                need = -(-(len(seq.req.prompt) + seq.req.max_new_tokens)
                         // self.page_size)
                if need > self.n_pages - 1:
                    raise RuntimeError(
                        "page pool too small for a single sequence"
                    )  # pragma: no cover — submit() bounds prevent this
                # The pool *can* hold this sequence, so the failure is a
                # transient denial (e.g. an injected exhaustion spike):
                # preempt the blocked sequence itself — its pages free, the
                # request requeues, and a later step resumes it
                # deterministically once allocation succeeds again.
            self._preempt(victim)

    def _decode_step(self) -> bool:
        """One decode round, decomposed into the propose → verify → commit
        contract (DESIGN.md §Speculative-serving).  Without a SpecConfig,
        propose returns empty proposals and verify runs the legacy
        single-token decode call — bit-for-bit the pre-speculation step
        loop (tests pin `record_logits` equality)."""
        active = self._ensure_capacity()
        if not active:
            return False
        proposals = self._propose(active)
        logits = self._verify(active, proposals)
        self._commit(active, proposals, logits)
        return True

    def _propose(self, active: list[int]) -> dict:
        """Draft proposals per active lane: ``{lane: [tokens]}`` (all
        empty without speculation).  The per-lane budget caps the depth
        so a verify round never writes past ``prompt + max_new - 2`` —
        γ overrunning ``max_new`` degrades to a shorter proposal, never
        an overshoot.  Draft page allocation happens inside the manager
        and degrades on a dry pool; it cannot preempt."""
        if self.spec_mgr is None:
            return {i: [] for i in active}
        items = []
        for i in active:
            seq = self.lanes[i]
            budget = min(
                seq.req.max_new_tokens - len(seq.req.output) - 1,
                self.max_seq - 2 - int(self.slot_pos[i]),
            )
            items.append((i, seq, int(self.slot_pos[i]), budget))
        return self.spec_mgr.propose(items)

    def _verify(self, active: list[int], proposals: dict) -> np.ndarray:
        """Score every lane's replay token + proposal in one target
        forward; returns fp32 logits (B, L, V).  With no proposals
        anywhere the legacy single-decode executable runs (L = 1) — the
        non-speculative hot path, and the only branch that feeds the
        provable-shed cost floor with true single-step costs.  Target
        lookahead pages are grown here; a dry pool *clamps the proposal*
        (speculation degrades) rather than preempting — only the legacy
        slot-position coverage in `_ensure_capacity` may preempt."""
        for i in active:
            if not proposals[i]:
                continue
            seq = self.lanes[i]
            d = len(proposals[i])
            while d:
                pg = (int(self.slot_pos[i]) + d) // self.page_size
                if pg < len(seq.pages):
                    break
                got = self.pool.alloc(1)
                if got is None:
                    d -= 1
                    continue
                seq.pages.append(got[0])
                self.table[i, len(seq.pages) - 1] = got[0]
                self._dev_table = None
            proposals[i] = proposals[i][:d]
        spec_round = any(proposals[i] for i in active)

        if not spec_round:
            write_page = np.full(self.max_batch, NULL_PAGE, np.int32)
            pos = np.zeros(self.max_batch, np.int32)
            for i in active:
                seq = self.lanes[i]
                pos[i] = self.slot_pos[i]
                write_page[i] = seq.pages[int(self.slot_pos[i]) // self.page_size]
                self.n_kv_page_reads += -(-(int(self.slot_pos[i]) + 1) // self.page_size)
            t0 = self.clock()
            logits, self.cache = self._decode(
                self.params, jnp.asarray(self._last_tok), self.cache,
                jnp.asarray(pos), self._dev_table_now(), jnp.asarray(write_page),
            )
            self.n_decode_steps += 1
            logits = np.asarray(logits.astype(jnp.float32))
            dt = self.clock() - t0
            if dt > 0:
                self._min_decode_s = dt if self._min_decode_s is None else min(self._min_decode_s, dt)
            return logits[:, None]

        L = self.spec.gamma + 1  # one executable for every outcome
        toks = np.zeros((self.max_batch, L), np.int32)
        wp = np.full((self.max_batch, L), NULL_PAGE, np.int32)
        pos = np.zeros(self.max_batch, np.int32)
        for i in active:
            seq = self.lanes[i]
            p0 = int(self.slot_pos[i])
            pos[i] = p0
            toks[i, 0] = self._last_tok[i, 0]
            props = proposals[i]
            toks[i, 1 : 1 + len(props)] = props
            for j in range(len(props) + 1):
                wp[i, j] = seq.pages[(p0 + j) // self.page_size]
                self.n_kv_page_reads += -(-(p0 + j + 1) // self.page_size)
        t0 = self.clock()
        logits, self.cache = self._verify_fn(
            self._verify_params, jnp.asarray(toks), self.cache, jnp.asarray(pos),
            self._dev_table_now(), jnp.asarray(wp),
        )
        self.n_decode_steps += 1
        self.n_spec_rounds += 1
        logits = np.asarray(logits.astype(jnp.float32))
        dt = self.clock() - t0
        if dt > 0:
            # Per-position floor: a scan position is never cheaper than
            # this, so the provable-shed bound stays a true lower bound.
            per = dt / L
            self._min_decode_s = per if self._min_decode_s is None else min(self._min_decode_s, per)
        return logits

    def _commit(self, active: list[int], proposals: dict, logits: np.ndarray):
        """Greedy acceptance per lane: commit the longest prefix of the
        proposal matching the target's own argmaxes, plus the bonus
        argmax at the first disagreement (= the whole round when nothing
        was proposed).  Every committed token is a target argmax over
        decode-path KV — exactly the non-speculative token stream, which
        is the engine's headline identity.  Draft pages past the new
        frontier roll back to the pool here."""
        now = self.clock()
        for i in active:
            seq = self.lanes[i]
            props = proposals[i]
            greedy = [int(np.argmax(logits[i, j])) for j in range(len(props) + 1)]
            a = 0
            while a < len(props) and props[a] == greedy[a]:
                a += 1
            if self.spec_mgr is not None:
                seq.req.n_spec_rounds += 1
                seq.req.n_draft_tokens += len(props)
                seq.req.n_draft_accepted += a
                self.n_draft_tokens += len(props)
                self.n_draft_accepted += a
            for j in range(a + 1):
                tok = greedy[j]
                if self.record_logits:
                    self.logit_trace.setdefault(seq.req.rid, []).append(
                        logits[i, j]
                    )
                self._last_tok[i, 0] = tok
                if not seq.req.output:
                    seq.req.first_token_t = now
                seq.req.output.append(tok)
                seq.tokens.append(tok)
                self.slot_pos[i] += 1
            if self.spec_mgr is not None:
                self.spec_mgr.commit(i, int(self.slot_pos[i]))

    def _retire(self):
        for i, seq in enumerate(self.lanes):
            if seq is None or seq.n_prefilled < seq.n_target:
                continue
            req = seq.req
            if len(req.output) >= req.max_new_tokens or self.slot_pos[i] >= self.max_seq - 1:
                self._release_lane(i)
                self._finish(
                    req,
                    "preempted_resumed" if req.n_preemptions else "completed",
                )

    # ------------------------------------------------------------------
    def step(self) -> bool:
        try:
            fault_point("engine.step")
        except TransientFault:
            # Raised before any state mutation: this step is a pure no-op
            # and the next one sees exactly the pre-fault scheduler state.
            self.n_transient_faults += 1
            return True
        self._expire_deadlines()
        self._admit()
        progressed = self._prefill_step()
        # Nothing can decode yet (cold start / post-preemption ramp): drain
        # prefills instead of burning empty steps — time-to-first-token.
        while progressed and not self._decode_ready():
            if not self._prefill_step():
                break
        progressed |= self._decode_step()
        self._retire()
        # Queued work with an idle engine and no progress means admission
        # was blocked by a transient allocation denial (nothing else holds
        # pages that could ever be freed) — keep stepping so the denial
        # window can pass, instead of reporting a dead engine.
        if not progressed and self.queue and not any(
            s is not None for s in self.lanes
        ):
            return True
        return progressed

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.lanes)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.finished
