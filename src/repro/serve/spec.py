"""Quantization-aware self-speculative decoding (DESIGN.md
§Speculative-serving).

The repo holds both a dense teacher and QuantEase-quantized artifacts,
plus a parity bridge proving their logit agreement — exactly the
self-speculation ingredients: a cheap *draft* stack proposes up to γ
greedy tokens per lane (one fused dispatch,
:func:`repro.models.paged_draft_tokens`), the served *target* scores all
proposals plus one bonus position in a second fused dispatch
(:func:`repro.models.paged_verify_tokens`), and the longest agreeing
prefix plus the bonus token commits.  Because every committed token is
the target's own greedy argmax, speculative output is **token-identical
to non-speculative greedy decode of the same target artifact** —
speculation is pure scheduling, never sampling drift.  The general
(stochastic) rejection-sampling rule of Leviathan et al. is kept here as
a host-side reference (:func:`rejection_sample_commit`) pinned by the
distribution-preservation property tests; greedy serving reduces to
:func:`greedy_accept_len`.

Draft KV lives in the **same** :class:`~repro.serve.kv_cache.PagePool`
as the target — no second pool, no new refcount rules:

* the :class:`DraftManager` allocates draft-owned pages per lane and
  **never registers them** in the prefix cache (their token-tuple keys
  would collide with target pages holding different bytes);
* after every verify, draft pages past the committed frontier **roll
  back** (release) so a rejected lookahead never holds pool capacity,
  and the whole set releases with the lane (retire / preempt / expire /
  shed) — pool refcount audits see zero leaks;
* draft page-allocation failure **degrades** the proposal length (down
  to 0 = plain decode) instead of preempting, and declines the pool's
  last free page so the target always wins the race for capacity —
  preemption and SLO semantics are untouched by speculation.

Draft flavours (``launch/serve.py`` exposes all three): a lower-bit
RTN-quantized copy of the target
(:func:`repro.serve.qparams.rtn_quantize_for_serving`, the 3-bit
outlier-aware stack of the paper story), a truncated-layer variant of
the target (:func:`truncate_draft` — first *k* periods of the stacked
decoder, same embeddings/head), or any separately-loaded checkpoint with
the same vocabulary.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    hoist_dequant,
    init_paged_cache,
    paged_cache_shapes,
    paged_draft_tokens,
    paged_prefill_chunk,
)
from repro.models.model import ModelPlan
from repro.serve.kv_cache import NULL_PAGE, PagePool

__all__ = [
    "SpecConfig",
    "DraftManager",
    "greedy_accept_len",
    "maybe_hoist",
    "rejection_sample_commit",
    "truncate_draft",
]


# ---------------------------------------------------------------------------
# Acceptance rules
# ---------------------------------------------------------------------------


def greedy_accept_len(draft_tokens, target_greedy) -> int:
    """Greedy-mode acceptance: length of the longest prefix on which the
    draft proposal agrees with the target's own greedy choices.
    ``target_greedy[j]`` is the target argmax at the position draft token
    ``draft_tokens[j]`` was proposed for; the engine then commits the
    accepted prefix plus ``target_greedy[a]`` as the bonus token, which
    is exactly what a non-speculative greedy loop would have emitted."""
    a = 0
    for d, t in zip(draft_tokens, target_greedy):
        if int(d) != int(t):
            break
        a += 1
    return a


def rejection_sample_commit(draft_tokens, draft_probs, target_probs, u, v):
    """Standard speculative rejection sampling (host-side reference rule).

    For each proposed token ``t_j``: accept when ``u[j] < min(1,
    p_target(t_j) / p_draft(t_j))``; at the first rejection, sample the
    replacement from the normalized residual ``max(p_target - p_draft,
    0)`` via inverse CDF with draw ``v[j]`` and stop; if every proposal
    survives, sample one bonus token from the target's next-position
    distribution with ``v[len(draft)]``.  The committed sequence is
    distributed exactly as ancestral sampling from the target — in
    particular **no committed token can have zero target probability**
    (zero-probability proposals always reject, residual and bonus mass
    live only where the target has mass), and with one-hot (greedy)
    target rows the rule collapses to longest-prefix acceptance plus the
    target argmax at the stop position, which is the rule the serving
    engine implements with integer comparisons.  The property tests in
    tests/test_spec_decode.py pin both facts.

    ``draft_probs``/``target_probs``: rows of per-position probabilities
    (target has one extra bonus row); ``u``: (len(draft),) accept draws
    in [0, 1); ``v``: (len(draft)+1,) inverse-CDF draws in [0, 1).
    Returns the committed token list (always ``accepted + 1`` long).
    """
    n = len(draft_tokens)
    if len(u) < n or len(v) < n + 1 or len(target_probs) < n + 1:
        raise ValueError("need n accept draws, n+1 CDF draws, n+1 target rows")

    def _inv_cdf(probs, draw):
        p = np.asarray(probs, np.float64)
        p = np.maximum(p, 0.0)
        tot = p.sum()
        if tot <= 0.0:
            raise ValueError("cannot sample from an all-zero distribution")
        cum = np.cumsum(p / tot)
        idx = int(np.searchsorted(cum, draw, side="right"))
        if idx >= p.size or p[idx] <= 0.0:
            # float round-off at the top of the CDF (draw ≥ cum[-1]) or a
            # zero-mass boundary: fall back to the heaviest token, which
            # always has positive mass.
            idx = int(np.argmax(p))
        return idx

    committed = []
    for j, t in enumerate(draft_tokens):
        t = int(t)
        pd = float(draft_probs[j][t])
        pt = float(target_probs[j][t])
        if pd <= 0.0:
            raise ValueError(
                f"draft proposed token {t} it assigns zero probability"
            )
        if u[j] < min(1.0, pt / pd):
            committed.append(t)
            continue
        # Rejected: p_target(t) < p_draft(t) strictly, so the residual has
        # positive total mass (the surplus lives elsewhere).
        resid = np.maximum(
            np.asarray(target_probs[j], np.float64)
            - np.asarray(draft_probs[j], np.float64),
            0.0,
        )
        committed.append(_inv_cdf(resid, v[j]))
        return committed
    committed.append(_inv_cdf(target_probs[n], v[n]))
    return committed


# ---------------------------------------------------------------------------
# Draft construction
# ---------------------------------------------------------------------------


def truncate_draft(plan: ModelPlan, params, n_periods: int):
    """Truncated-layer self-draft: the first ``n_periods`` periods of the
    target's stacked decoder, sharing its embeddings, final norm, and
    logit head.  Zero extra weight memory beyond views — every ``dec``
    leaf (dense or QuantizedTensor: codes, scales, outlier planes all
    carry the leading period axis) is sliced ``[:n_periods]``; the plan
    keeps the target's paddings and KV dtype so draft pages pack
    identically.  Returns ``(draft_plan, draft_params)``."""
    cfg = plan.cfg
    if not 1 <= n_periods <= cfg.n_periods:
        raise ValueError(
            f"truncated draft needs 1 <= n_periods <= {cfg.n_periods}, "
            f"got {n_periods}"
        )
    d_plan = dataclasses.replace(
        plan, cfg=dataclasses.replace(cfg, n_periods=n_periods)
    )
    d_params = dict(params)
    d_params["dec"] = jax.tree.map(lambda a: a[:n_periods], params["dec"])
    return d_plan, d_params


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding configuration handed to the paged engine.

    ``gamma`` is the maximum proposal depth per round; the engine's
    verify executable scores ``gamma + 1`` positions, so one compiled
    program serves every acceptance outcome."""

    draft_plan: ModelPlan
    draft_params: object
    gamma: int = 4
    label: str = "draft"
    # Hoist QuantizedTensor dequantization out of the multi-position scans
    # (models/common.HoistedDequant): None = auto (on wherever the GEMM
    # dispatch takes the XLA reference path, i.e. off-TPU — there the scan
    # would re-dequantize loop-invariant weights every position).  Bitwise
    # -transparent; trades ~32/bits × weight memory for one dequant per
    # call instead of per position.
    hoist_dequant: Optional[bool] = None

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")


def maybe_hoist(params, flag: Optional[bool]):
    """Resolve a SpecConfig.hoist_dequant flag against the backend: hoist
    exactly where dequant_matmul would take the XLA reference anyway, so
    hoisting can never swap a Pallas-kernel result for a reference one."""
    if flag is None:
        from repro.kernels.ops import on_tpu

        flag = not on_tpu()
    return hoist_dequant(params) if flag else params


# ---------------------------------------------------------------------------
# Draft-side paged state
# ---------------------------------------------------------------------------


class DraftManager:
    """Owns the draft stack's paged KV alongside the target's in the same
    :class:`PagePool` (module docstring: lifecycle + degradation rules).

    Per lane it tracks the draft-owned page list and two cursors:
    ``synced`` — prompt positions covered by draft chunked prefill — and
    ``frontier`` — the next position a draft step must write.  The
    engine drives it with :meth:`attach` (at decode arming),
    :meth:`propose` (each spec round), :meth:`commit` (after verify:
    clamps the frontier back to the committed position and rolls back
    pages past it), and :meth:`release_lane` (lane teardown of any
    kind)."""

    def __init__(
        self,
        cfg: SpecConfig,
        *,
        pool: PagePool,
        n_pages: int,
        max_batch: int,
        max_seq: int,
        page_size: int,
        prefill_chunk: int,
    ):
        # Same arch gate as the engine's own cache — loud, at init.
        paged_cache_shapes(cfg.draft_plan, n_pages, page_size)
        self.cfg = cfg
        self.pool = pool
        self.max_batch = max_batch
        self.page_size = page_size
        self.pages_per_seq = -(-max_seq // page_size)
        self.prefill_chunk = prefill_chunk
        self.cache = init_paged_cache(cfg.draft_plan, n_pages, page_size)
        self.table = np.full(
            (max_batch, self.pages_per_seq), NULL_PAGE, np.int32
        )
        self._dev_table = None
        self.pages: list[list] = [[] for _ in range(max_batch)]
        self.synced = [-1] * max_batch  # draft prefill progress; -1 detached
        self.frontier = [-1] * max_batch  # next position a draft step writes

        # Draft weights as consumed by the fused rollout/prefill calls —
        # hoisted-dequant where that is free of semantic drift (off-TPU).
        self.draft_params = maybe_hoist(cfg.draft_params, cfg.hoist_dequant)

        plan = cfg.draft_plan
        self._propose_fn = jax.jit(
            lambda p, f, nf, c, pos, pt, wp: paged_draft_tokens(
                plan, p, f, nf, c, pos, pt, wp
            ),
            donate_argnums=(3,),
        )
        self._chunk = jax.jit(
            lambda p, t, c, pt, off: paged_prefill_chunk(plan, p, t, c, pt, off),
            donate_argnums=(2,),
        )
        self.n_propose_calls = 0
        self.n_sync_chunks = 0

    # -- page plumbing (mirrors the engine's lazy device table) ----------
    def _dev_table_now(self):
        if self._dev_table is None:
            self._dev_table = jnp.asarray(self.table)
        return self._dev_table

    def _append_page(self, lane: int) -> bool:
        """Grow the lane's draft page list by one — declining the pool's
        last allocatable page (the target always wins the capacity race;
        speculation degrades instead)."""
        if len(self.pages[lane]) >= self.pages_per_seq:
            return False
        if self.pool.n_free < 2:
            return False
        got = self.pool.alloc(1)
        if got is None:  # injected denial ("pool.alloc") degrades too
            return False
        self.pages[lane].append(got[0])
        self.table[lane, len(self.pages[lane]) - 1] = got[0]
        self._dev_table = None
        return True

    def _covered(self, lane: int, pos: int) -> bool:
        while len(self.pages[lane]) <= pos // self.page_size:
            if not self._append_page(lane):
                return False
        return True

    # -- lifecycle -------------------------------------------------------
    def attach(self, lane: int, seq):
        """Lane armed for decode: reset draft state; the prompt syncs
        lazily at the first propose (chunked prefill of the draft)."""
        self.release_lane(lane)
        self.synced[lane] = 0
        self.frontier[lane] = seq.n_target - 1  # the replay position

    def release_lane(self, lane: int):
        for p in self.pages[lane]:
            self.pool.release(p)
        self.pages[lane] = []
        self.synced[lane] = -1
        self.frontier[lane] = -1
        if self.table[lane].any():  # NULL_PAGE == 0
            self.table[lane] = NULL_PAGE
            self._dev_table = None

    def commit(self, lane: int, new_pos: int):
        """Post-verify bookkeeping: the committed frontier moved to
        ``new_pos``.  Draft KV past it is stale (rejected lookahead) or
        missing (the bonus token after a fully-accepted round), so the
        write cursor clamps back and pages holding only positions beyond
        the frontier **roll back** to the pool."""
        if self.synced[lane] < 0:
            return
        self.frontier[lane] = min(self.frontier[lane], new_pos)
        keep = new_pos // self.page_size + 1
        while len(self.pages[lane]) > keep:
            self.pool.release(self.pages[lane].pop())
            self.table[lane, len(self.pages[lane])] = NULL_PAGE
            self._dev_table = None

    # -- prompt sync -----------------------------------------------------
    def _sync_prompt(self, lane: int, seq) -> bool:
        """Chunk-prefill the draft's KV for ``seq.tokens[:n_target]``.
        Incremental: page-starved progress is kept and resumed next
        round; returns False until fully synced."""
        T = seq.n_target
        while self.synced[lane] < T:
            off = self.synced[lane]
            hi = min(off + self.prefill_chunk, T)
            if not self._covered(lane, hi - 1):
                return False
            buf = np.zeros((1, self.prefill_chunk), np.int32)
            buf[0, : hi - off] = seq.tokens[off:hi]
            self.cache = self._chunk(
                self.draft_params, jnp.asarray(buf), self.cache,
                self._dev_table_now()[lane : lane + 1], np.int32(off),
            )
            self.synced[lane] = hi
            self.n_sync_chunks += 1
        return True

    # -- propose ---------------------------------------------------------
    def propose(self, items) -> dict:
        """One speculative round: for each ``(lane, seq, pos0, budget)``
        item (``pos0`` = the lane's replay position, ``budget`` = max
        tokens worth proposing), teacher-force the draft over committed
        tokens it hasn't seen (``frontier..pos0``) and roll the argmax
        feedback loop forward, all lanes in **one** fused dispatch.
        Returns ``{lane: [draft tokens]}`` — empty list whenever the lane
        is page-starved, unsynced, or out of budget (the engine then
        verifies just the replay column: plain decode)."""
        S = self.cfg.gamma + 1
        out = {it[0]: [] for it in items}
        live = []
        for lane, seq, pos0, budget in items:
            if self.synced[lane] < 0 or not self._sync_prompt(lane, seq):
                continue
            c = max(1, pos0 - self.frontier[lane] + 1)  # forced catch-up
            if c > S:
                # Too far behind for proposals this round (page starvation
                # in earlier rounds): a pure catch-up round.
                n_forced, d = S, 0
            else:
                n_forced = c
                d = max(0, min(self.cfg.gamma, budget, S - c + 1))
            steps = n_forced if d == 0 else c + d - 1
            start = self.frontier[lane]
            while steps > 0 and not self._covered(lane, start + steps - 1):
                steps -= 1
            if steps < n_forced:
                n_forced, d = steps, 0
            elif d:
                d = max(0, steps - c + 1)
            if steps <= 0:
                continue
            live.append((lane, n_forced, d, steps))
        if not live:
            return out
        forced = np.zeros((self.max_batch, S), np.int32)
        nf = np.zeros(self.max_batch, np.int32)
        pos = np.zeros(self.max_batch, np.int32)
        wp = np.full((self.max_batch, S), NULL_PAGE, np.int32)
        seq_of = {it[0]: it[1] for it in items}
        for lane, n_forced, d, steps in live:
            start = self.frontier[lane]
            pos[lane] = start
            nf[lane] = n_forced
            forced[lane, :n_forced] = seq_of[lane].tokens[
                start : start + n_forced
            ]
            for j in range(steps):
                wp[lane, j] = self.pages[lane][(start + j) // self.page_size]
        drafts, self.cache = self._propose_fn(
            self.draft_params, jnp.asarray(forced), jnp.asarray(nf),
            self.cache, jnp.asarray(pos), self._dev_table_now(),
            jnp.asarray(wp),
        )
        self.n_propose_calls += 1
        drafts = np.asarray(drafts)
        for lane, n_forced, d, steps in live:
            self.frontier[lane] += steps
            if d:
                out[lane] = [
                    int(t) for t in drafts[lane, n_forced - 1 : n_forced - 1 + d]
                ]
        return out
