"""Quantized serving: engines, paged KV pool, quantized param trees.

The deployment side of the reproduction (DESIGN.md §Paged-serving): the PTQ
artifact produced by ``core/solver.py emit="qt"`` serves through

* :class:`~repro.serve.engine.PagedServingEngine` — the production engine:
  shared fixed-size KV page pool (:mod:`repro.serve.kv_cache`), chunked
  prefill interleaved with continuous-batching decode, hash-chain prefix
  cache with copy-on-write, preemption-by-eviction, and the Pallas
  paged-attention decode kernel on TPU (bf16 or int8 pages, dequant
  in-kernel),
* :class:`~repro.serve.engine.ServingEngine` — the contiguous per-slot
  baseline, kept as the paged engine's numerical oracle and benchmark
  baseline (benchmarks/bench_serve.py),
* :mod:`repro.serve.qparams` — QuantizedTensor parameter trees + logical
  axes for the quantized serving footprint (dry-run memory accounting and
  Megatron-compatible sharding of the codes matrices),
* :mod:`repro.serve.spec` — quantization-aware self-speculative decoding
  (DESIGN.md §Speculative-serving): a draft stack (lower-bit, truncated
  -layer, or separate checkpoint) proposes γ greedy tokens per lane into
  draft-owned pages of the *same* pool, one fused multi-position target
  forward verifies, and the longest target-greedy prefix + bonus token
  commits — token-identical to non-speculative greedy decode.
"""

from repro.serve.engine import PagedServingEngine, Request, ServingEngine
from repro.serve.kv_cache import PagePool
from repro.serve.qparams import rtn_quantize_for_serving
from repro.serve.spec import SpecConfig, truncate_draft

__all__ = [
    "PagedServingEngine",
    "Request",
    "ServingEngine",
    "PagePool",
    "SpecConfig",
    "rtn_quantize_for_serving",
    "truncate_draft",
]
