"""Quantized serving: params, engine, batched requests."""
