"""Quantized serving: engines, paged KV pool, quantized param trees.

The deployment side of the reproduction (DESIGN.md §Paged-serving): the PTQ
artifact produced by ``core/solver.py emit="qt"`` serves through

* :class:`~repro.serve.engine.PagedServingEngine` — the production engine:
  shared fixed-size KV page pool (:mod:`repro.serve.kv_cache`), chunked
  prefill interleaved with continuous-batching decode, hash-chain prefix
  cache with copy-on-write, preemption-by-eviction, and the Pallas
  paged-attention decode kernel on TPU (bf16 or int8 pages, dequant
  in-kernel),
* :class:`~repro.serve.engine.ServingEngine` — the contiguous per-slot
  baseline, kept as the paged engine's numerical oracle and benchmark
  baseline (benchmarks/bench_serve.py),
* :mod:`repro.serve.qparams` — QuantizedTensor parameter trees + logical
  axes for the quantized serving footprint (dry-run memory accounting and
  Megatron-compatible sharding of the codes matrices).
"""

from repro.serve.engine import PagedServingEngine, Request, ServingEngine
from repro.serve.kv_cache import PagePool

__all__ = ["PagedServingEngine", "Request", "ServingEngine", "PagePool"]
