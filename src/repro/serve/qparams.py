"""Quantized serving parameters: abstract shapes + logical axes.

The serve path (prefill/decode) runs on **QuantizedTensor** leaves for every
PTQ-target linear (core/solver.py QUANTIZABLE); norms, biases, embeddings,
router, mamba dynamics stay bf16.  This module builds:

  * ``qt_param_shapes(plan, bits)`` — ShapeDtypeStruct tree used by the
    dry-run (uint8 codes ⇒ the memory_analysis shows the real 4-bit serving
    footprint; the paper's deployment story),
  * ``qt_param_axes(plan)`` — logical axes per leaf, with fused-out-dim
    names (the QT codes matrix is (out_fused, in)): column-parallel linears
    shard codes dim0, row-parallel linears shard dim1 ⇒ identical
    communication pattern to the bf16 Megatron layout.

Axes names introduced here (resolved in dist/sharding.make_rules extras):
``kv_fused`` (= n_kv·hd), ``ssm_fused`` (= nh·hd), ``heads_fused``
(= kv_pad·g_pad·hd).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.solver import QUANTIZABLE, _MOE_NAMES
from repro.models import model as M
from repro.quant import QuantizedTensor

__all__ = ["qt_param_shapes", "qt_param_axes", "quantize_params_for_serving", "qt_rules_extra"]


def _linear_meta(plan: M.ModelPlan, name: str):
    """(out_fused, d_in, axes_out, axes_in) for each quantizable leaf name."""
    cfg, hp = plan.cfg, plan.heads
    d, hd = cfg.d_model, cfg.hd
    table = {
        "wq": (hp.kv_pad * hp.g_pad * hd, d, "heads_fused", "embed"),
        "wk": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wv": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wo": (d, hp.kv_pad * hp.g_pad * hd, None, "heads_fused"),
        "wq_c": (hp.kv_pad * hp.g_pad * hd, d, "heads_fused", "embed"),
        "wk_c": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wv_c": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wo_c": (d, hp.kv_pad * hp.g_pad * hd, None, "heads_fused"),
        "wg": (cfg.d_ff, d, "ffn", "embed"),
        "wu": (cfg.d_ff, d, "ffn", "embed"),
        "wd": (d, cfg.d_ff, None, "ffn"),
        "wz": (cfg.ssm_nheads * cfg.ssm_headdim, d, "ssm_fused", "embed"),
        "wx": (cfg.ssm_nheads * cfg.ssm_headdim, d, "ssm_fused", "embed"),
        "wbc": (2 * cfg.ssm_ngroups * cfg.ssm_state, d, None, "embed"),
        "out_proj": (d, cfg.ssm_nheads * cfg.ssm_headdim, None, "ssm_fused"),
        "w_gate": (cfg.moe_ff, d, "expert_ffn", "embed"),
        "w_up": (cfg.moe_ff, d, "expert_ffn", "embed"),
        "w_down": (d, cfg.moe_ff, None, "expert_ffn"),
    }
    return table[name]


def qt_rules_extra(plan: M.ModelPlan, axis_n: int) -> dict:
    cfg, hp = plan.cfg, plan.heads

    def fits(n):
        return n > 0 and n % axis_n == 0

    return {
        "heads_fused": "model" if fits(hp.kv_pad * hp.g_pad * cfg.hd) else None,
        "kv_fused": "model" if fits(hp.n_kv * cfg.hd) else None,
        "ssm_fused": "model" if fits(cfg.ssm_nheads * cfg.ssm_headdim) else None,
    }


def _qt_leaf_shapes(plan, name, lead: tuple, bits: int):
    out_f, d_in, ax_o, ax_i = _linear_meta(plan, name)
    mk = lambda shape, dt: jax.ShapeDtypeStruct(lead + shape, dt)
    # int4 codes are stored packed two-per-byte (§Perf H1): weight HBM
    # traffic halves; the Pallas kernel unpacks in VMEM, the XLA ref path
    # unpacks inline (still reads only packed bytes from HBM).
    packed = bits == 4 and d_in % 2 == 0
    return QuantizedTensor(
        codes=mk((out_f, d_in // 2 if packed else d_in), jnp.uint8),
        scale=mk((out_f, 1), jnp.float32),
        zero=mk((out_f, 1), jnp.float32),
        bits=bits,
        group_size=None,
        packed=packed,
    )


def _qt_leaf_axes(plan, name, lead_axes: tuple):
    # Plain dict with the same *flatten order* as QuantizedTensor (codes,
    # scale, zero — Nones drop out), so shape/axes trees zip leaf-for-leaf.
    out_f, d_in, ax_o, ax_i = _linear_meta(plan, name)
    return {
        "codes": lead_axes + (ax_o, ax_i),
        "scale": lead_axes + (ax_o, None),
        "zero": lead_axes + (ax_o, None),
    }


def _map_stack(plan, stack_tree, pattern, fn_quant, fn_keep):
    """Rebuild a stacked block tree, replacing QUANTIZABLE leaves."""
    out = {}
    for key, blk in stack_tree.items():
        i = int(key[1:])
        b = pattern[i]
        new_blk = {}
        for name, leaf in blk.items():
            if name in QUANTIZABLE:
                new_blk[name] = fn_quant(name, leaf, b)
            else:
                new_blk[name] = fn_keep(name, leaf)
        out[key] = new_blk
    return out


def qt_param_shapes(plan: M.ModelPlan, bits: int = 4):
    dense = M.param_shapes(plan)
    cfg = plan.cfg

    def quant(name, leaf, b):
        lead = (cfg.n_periods,) if name not in _MOE_NAMES else (
            cfg.n_periods, cfg.n_experts,
        )
        return _qt_leaf_shapes(plan, name, lead, bits)

    out = dict(dense)
    out["dec"] = _map_stack(plan, dense["dec"], cfg.pattern, quant, lambda n, l: l)
    if "enc" in dense:
        def quant_enc(name, leaf, b):
            lead = (cfg.n_enc_periods,) if name not in _MOE_NAMES else (
                cfg.n_enc_periods, cfg.n_experts,
            )
            return _qt_leaf_shapes(plan, name, lead, bits)

        out["enc"] = _map_stack(plan, dense["enc"], cfg.enc_pattern, quant_enc, lambda n, l: l)
    return out


def qt_param_axes(plan: M.ModelPlan):
    dense = M.param_axes(plan)
    cfg = plan.cfg

    def quant(name, leaf, b):
        lead = ("layers",) if name not in _MOE_NAMES else ("layers", "experts")
        return _qt_leaf_axes(plan, name, lead)

    out = dict(dense)
    out["dec"] = _map_stack(plan, dense["dec"], cfg.pattern, quant, lambda n, l: l)
    if "enc" in dense:
        out["enc"] = _map_stack(plan, dense["enc"], cfg.enc_pattern, quant, lambda n, l: l)
    return out


def quantize_params_for_serving(plan: M.ModelPlan, params, solver_qt_dec: list):
    """Restack solver emit='qt' per-period block lists into the scan layout."""
    stacked = {}
    for key in solver_qt_dec[0]:
        leaves = [p[key] for p in solver_qt_dec]
        stacked[key] = jax.tree.map(lambda *ls: jnp.stack(ls), *leaves)
    out = dict(params)
    out["dec"] = stacked
    return out
