"""Quantized serving parameters: abstract shapes + logical axes.

The serve path (prefill/decode) runs on **QuantizedTensor** leaves for every
PTQ-target linear (core/solver.py QUANTIZABLE); norms, biases, embeddings,
router, mamba dynamics stay bf16.  This module builds:

  * ``qt_param_shapes(plan, bits)`` — ShapeDtypeStruct tree used by the
    dry-run (uint8 codes ⇒ the memory_analysis shows the real 4-bit serving
    footprint; the paper's deployment story),
  * ``qt_param_axes(plan)`` — logical axes per leaf, with fused-out-dim
    names (the QT codes matrix is (out_fused, in)): column-parallel linears
    shard codes dim0, row-parallel linears shard dim1 ⇒ identical
    communication pattern to the bf16 Megatron layout.

Axes names introduced here (resolved in dist/sharding.make_rules extras):
``kv_fused`` (= n_kv·hd), ``ssm_fused`` (= nh·hd), ``heads_fused``
(= kv_pad·g_pad·hd).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.solver import QUANTIZABLE, _MOE_NAMES
from repro.models import model as M
from repro.quant import QuantizedTensor

__all__ = [
    "qt_param_shapes",
    "qt_param_axes",
    "quantize_params_for_serving",
    "prepack_params_for_serving",
    "rtn_quantize_for_serving",
    "harmonize_qt_stack",
    "qt_rules_extra",
]


def _linear_meta(plan: M.ModelPlan, name: str):
    """(out_fused, d_in, axes_out, axes_in) for each quantizable leaf name."""
    cfg, hp = plan.cfg, plan.heads
    d, hd = cfg.d_model, cfg.hd
    table = {
        "wq": (hp.kv_pad * hp.g_pad * hd, d, "heads_fused", "embed"),
        "wk": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wv": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wo": (d, hp.kv_pad * hp.g_pad * hd, None, "heads_fused"),
        "wq_c": (hp.kv_pad * hp.g_pad * hd, d, "heads_fused", "embed"),
        "wk_c": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wv_c": (hp.n_kv * hd, d, "kv_fused", "embed"),
        "wo_c": (d, hp.kv_pad * hp.g_pad * hd, None, "heads_fused"),
        "wg": (cfg.d_ff, d, "ffn", "embed"),
        "wu": (cfg.d_ff, d, "ffn", "embed"),
        "wd": (d, cfg.d_ff, None, "ffn"),
        "wz": (cfg.ssm_nheads * cfg.ssm_headdim, d, "ssm_fused", "embed"),
        "wx": (cfg.ssm_nheads * cfg.ssm_headdim, d, "ssm_fused", "embed"),
        "wbc": (2 * cfg.ssm_ngroups * cfg.ssm_state, d, None, "embed"),
        "out_proj": (d, cfg.ssm_nheads * cfg.ssm_headdim, None, "ssm_fused"),
        "w_gate": (cfg.moe_ff, d, "expert_ffn", "embed"),
        "w_up": (cfg.moe_ff, d, "expert_ffn", "embed"),
        "w_down": (d, cfg.moe_ff, None, "expert_ffn"),
    }
    return table[name]


def qt_rules_extra(plan: M.ModelPlan, axis_n: int) -> dict:
    cfg, hp = plan.cfg, plan.heads

    def fits(n):
        return n > 0 and n % axis_n == 0

    return {
        "heads_fused": "model" if fits(hp.kv_pad * hp.g_pad * cfg.hd) else None,
        "kv_fused": "model" if fits(hp.n_kv * cfg.hd) else None,
        "ssm_fused": "model" if fits(cfg.ssm_nheads * cfg.ssm_headdim) else None,
    }


def _qt_leaf_shapes(plan, name, lead: tuple, bits: int):
    out_f, d_in, ax_o, ax_i = _linear_meta(plan, name)
    mk = lambda shape, dt: jax.ShapeDtypeStruct(lead + shape, dt)
    # int4 codes are stored packed two-per-byte (§Perf H1): weight HBM
    # traffic halves; the Pallas kernel unpacks in VMEM, the XLA ref path
    # unpacks inline (still reads only packed bytes from HBM).
    packed = bits == 4 and d_in % 2 == 0
    return QuantizedTensor(
        codes=mk((out_f, d_in // 2 if packed else d_in), jnp.uint8),
        scale=mk((out_f, 1), jnp.float32),
        zero=mk((out_f, 1), jnp.float32),
        bits=bits,
        group_size=None,
        packed=packed,
    )


def _qt_leaf_axes(plan, name, lead_axes: tuple):
    # Plain dict with the same *flatten order* as QuantizedTensor (codes,
    # scale, zero — Nones drop out), so shape/axes trees zip leaf-for-leaf.
    out_f, d_in, ax_o, ax_i = _linear_meta(plan, name)
    return {
        "codes": lead_axes + (ax_o, ax_i),
        "scale": lead_axes + (ax_o, None),
        "zero": lead_axes + (ax_o, None),
    }


def _map_stack(plan, stack_tree, pattern, fn_quant, fn_keep):
    """Rebuild a stacked block tree, replacing QUANTIZABLE leaves."""
    out = {}
    for key, blk in stack_tree.items():
        i = int(key[1:])
        b = pattern[i]
        new_blk = {}
        for name, leaf in blk.items():
            if name in QUANTIZABLE:
                new_blk[name] = fn_quant(name, leaf, b)
            else:
                new_blk[name] = fn_keep(name, leaf)
        out[key] = new_blk
    return out


def qt_param_shapes(plan: M.ModelPlan, bits: int = 4):
    dense = M.param_shapes(plan)
    cfg = plan.cfg

    def quant(name, leaf, b):
        lead = (cfg.n_periods,) if name not in _MOE_NAMES else (
            cfg.n_periods, cfg.n_experts,
        )
        return _qt_leaf_shapes(plan, name, lead, bits)

    out = dict(dense)
    out["dec"] = _map_stack(plan, dense["dec"], cfg.pattern, quant, lambda n, l: l)
    if "enc" in dense:
        def quant_enc(name, leaf, b):
            lead = (cfg.n_enc_periods,) if name not in _MOE_NAMES else (
                cfg.n_enc_periods, cfg.n_experts,
            )
            return _qt_leaf_shapes(plan, name, lead, bits)

        out["enc"] = _map_stack(plan, dense["enc"], cfg.enc_pattern, quant_enc, lambda n, l: l)
    return out


def qt_param_axes(plan: M.ModelPlan):
    dense = M.param_axes(plan)
    cfg = plan.cfg

    def quant(name, leaf, b):
        lead = ("layers",) if name not in _MOE_NAMES else ("layers", "experts")
        return _qt_leaf_axes(plan, name, lead)

    out = dict(dense)
    out["dec"] = _map_stack(plan, dense["dec"], cfg.pattern, quant, lambda n, l: l)
    if "enc" in dense:
        out["enc"] = _map_stack(plan, dense["enc"], cfg.enc_pattern, quant, lambda n, l: l)
    return out


def _qt_static_meta(qt: QuantizedTensor) -> tuple:
    """Everything that must agree for a plain leaf-for-leaf stack."""
    return (
        qt.bits,
        qt.group_size,
        qt.packed,
        qt.pack_layout,
        qt.pack_tile,
        None if qt.outlier_values is None else tuple(qt.outlier_values.shape),
        None if qt.outlier_col_idx is None else tuple(qt.outlier_col_idx.shape),
    )


def harmonize_qt_stack(leaves: list) -> list:
    """Normalize per-period QuantizedTensors to one common pytree structure.

    A mixed-precision artifact (per-layer bits from the auto-tuner) breaks
    the naive per-period stack: ``bits``/``packed`` are *static* pytree
    fields, so QuantizedTensors at different widths have different treedefs,
    and COO outlier planes come statically padded to per-layer ``s``.  The
    serving scan only needs shape/treedef uniformity — the dequant map
    ``(codes − zero)·scale`` is bits-independent once codes are unpacked —
    so heterogeneous stacks harmonize losslessly:

      * codes unpack to raw uint8 (``packed=False``; packing is a storage
        format, the scan slab is unpacked either way on the XLA ref path),
      * ``bits`` is set to the stack maximum (it only drives unpacking and
        the bits/weight accounting once ``packed`` is False; every period's
        codes are < 2^bits of *its own* grid, which the per-period
        scale/zero encode),
      * COO outlier planes pad to the stack-max ``s`` with (idx 0, value 0)
        entries — additive no-ops, the same padding contract the solver
        emits,
      * ``group_size`` must agree across the stack (per-period scale/zero
        column counts are shape-bearing); structured column outliers must
        be structurally identical (their ``.set`` semantics make padding
        destructive, so silent harmonization would corrupt column 0).

    Homogeneous stacks pass through untouched (packed 4-bit stays packed).
    """
    metas = {_qt_static_meta(l) for l in leaves}
    if len(metas) == 1:
        return leaves
    gsz = {l.group_size for l in leaves}
    if len(gsz) != 1:
        raise ValueError(
            f"heterogeneous group_size across stacked layers ({sorted(map(str, gsz))}) "
            "— per-period scale planes would not stack"
        )
    cols = {_qt_static_meta(l)[6] for l in leaves}
    if len(cols) != 1:
        raise ValueError(
            "structured column outliers must be structurally identical across "
            "a stack (padding a .set-semantics plane would clobber column 0)"
        )
    bits = max(l.bits for l in leaves)
    s_max = max(
        (0 if l.outlier_values is None else l.outlier_values.shape[-1])
        for l in leaves
    )
    out = []
    for l in leaves:
        codes = l.unpacked_codes()
        vals, idx = l.outlier_values, l.outlier_idx
        if s_max:
            if vals is None:
                lead = codes.shape[:-2]
                vals = jnp.zeros(lead + (s_max,), jnp.float16)
                idx = jnp.zeros(lead + (s_max,), jnp.int32)
            elif vals.shape[-1] < s_max:
                pad = [(0, 0)] * (vals.ndim - 1) + [(0, s_max - vals.shape[-1])]
                vals = jnp.pad(vals, pad)
                idx = jnp.pad(idx, pad)
        out.append(
            dataclasses.replace(
                l,
                codes=codes,
                bits=bits,
                packed=False,
                pack_layout="linear",
                pack_tile=None,
                outlier_values=vals,
                outlier_idx=idx,
            )
        )
    return out


def rtn_quantize_for_serving(plan: M.ModelPlan, params, *, bits: int,
                             outlier_frac: float = 0.0):
    """RTN-quantize every QUANTIZABLE dec leaf into the serving QT layout.

    The cheap artifact path: direct per-channel round-to-nearest over the
    dense stacked checkpoint — no calibration data, no solver.  It produces
    the same *byte layout* the solver pipeline emits — codes (packed
    two-per-byte at 4 bits), fp32 per-channel scale/zero, optional COO
    outlier planes (QuantEase Algorithm-3 structure: fp16 values + flat
    int32 indices) — so benchmarks (serving perf is weight-value
    independent) and on-the-fly draft construction (launch/serve.py
    ``--draft-bits``) can build a servable artifact from any dense
    checkpoint.  4-bit artifacts are then run through the roofline
    weight-layout decision (:func:`prepack_params_for_serving`).

    Returns ``(qt_params, layout_label)``.
    """
    import numpy as np

    from repro.quant import GridSpec, quantize_tensor
    from repro.quant.pack import pack_codes

    def qt_of(name, leaf):
        # Dense stacked leaves are (n_periods, in_dims..., out_dims...) with
        # fused head/ff axes; flatten through the same (out_f, d_in) meta the
        # serving QT layout uses (_linear_meta / core.solver._to_2d).
        n_p = leaf.shape[0]
        out_f, d_in = _linear_meta(plan, name)[:2]
        w = np.asarray(leaf, np.float32).reshape(n_p, d_in, out_f)
        w = w.transpose(0, 2, 1)  # (n_periods, out_f, d_in) — serving layout
        qts = []
        for i in range(n_p):
            qt = quantize_tensor(jnp.asarray(w[i]), GridSpec(bits=bits))
            if outlier_frac:
                resid = w[i] - np.asarray(qt.dequantize())
                s = max(1, int(outlier_frac * resid.size))
                idx = np.argsort(np.abs(resid).ravel())[-s:].astype(np.int32)
                qt = dataclasses.replace(
                    qt,
                    outlier_values=jnp.asarray(resid.ravel()[idx], jnp.float16),
                    outlier_idx=jnp.asarray(idx),
                )
            if bits == 4 and qt.codes.shape[-1] % 2 == 0:
                qt = dataclasses.replace(qt, codes=pack_codes(qt.codes, 4),
                                         packed=True)
            qts.append(qt)
        return jax.tree.map(lambda *ls: jnp.stack(ls), *qts)

    out = dict(params)
    out["dec"] = {
        key: {
            name: qt_of(name, leaf) if name in QUANTIZABLE else leaf
            for name, leaf in blk.items()
        }
        for key, blk in params["dec"].items()
    }
    out, decisions = prepack_params_for_serving(plan, out)
    labels = sorted(set(decisions.values())) or ["linear"]
    return out, "+".join(labels)


def quantize_params_for_serving(plan: M.ModelPlan, params, solver_qt_dec: list):
    """Restack solver emit='qt' per-period block lists into the scan layout.

    Heterogeneous-bits stacks (mixed-precision artifacts) are harmonized
    leaf-position-wise first — see :func:`harmonize_qt_stack`.
    """
    stacked = {}
    for key in solver_qt_dec[0]:
        blocks = [p[key] for p in solver_qt_dec]
        new_blk = {}
        for name in blocks[0]:
            leaves = [b[name] for b in blocks]
            if isinstance(leaves[0], QuantizedTensor):
                leaves = harmonize_qt_stack(leaves)
            new_blk[name] = jax.tree.map(lambda *ls: jnp.stack(ls), *leaves)
        stacked[key] = new_blk
    out = dict(params)
    out["dec"] = stacked
    return out


def prepack_params_for_serving(plan: M.ModelPlan, params, *, backend=None):
    """Roofline-selected weight-layout prepack (DESIGN.md §Packed-serving).

    Walks the serving param tree and, for every packed-4-bit
    QuantizedTensor still in the linear layout, asks
    :func:`repro.roofline.analysis.choose_weight_layout` whether the
    tile-native prepack (quant/pack.prepack_codes at the kernel's
    :func:`~repro.kernels.dequant_matmul.select_tile_k` k-tile) wins on the
    memory roofline for this backend.  Winning leaves are re-permuted
    **once, at pack time** — a pure column permutation, bit-exact under
    dequant — and tagged ``pack_layout="tile"`` / ``pack_tile=tk`` so the
    Pallas GEMM reads contiguous words per tile instead of interleaving.
    Off-TPU backends keep every leaf linear (the XLA ref path gains nothing
    from the reorder).

    Returns ``(params, decisions)`` where ``decisions`` maps
    ``"<block>.<name>"`` → the chosen
    :class:`~repro.roofline.analysis.WeightLayoutDecision` label (one entry
    per distinct leaf position; launch/serve.py prints them as the layout
    banner).
    """
    from repro.kernels.dequant_matmul import select_tile_k
    from repro.roofline.analysis import choose_weight_layout

    if backend is None:
        backend = jax.default_backend()
    decisions: dict[str, str] = {}

    def prepack_leaf(path: str, leaf):
        if not isinstance(leaf, QuantizedTensor):
            return leaf
        if not (leaf.packed and leaf.bits == 4 and leaf.pack_layout == "linear"):
            return leaf
        q, p = leaf.shape[-2], leaf.shape[-1]
        tk = select_tile_k(p, leaf.group_size)
        dec = choose_weight_layout(
            q, p, bits=4, group_size=leaf.group_size, tile_k=tk, backend=backend
        )
        if dec.kind != "tile":
            # The prepack never unpacks checkpoint codes back into HBM, so a
            # "linear (unpacked)" roofline pick still serves linear-packed —
            # record the layout the leaf actually keeps.
            decisions[path] = "linear-packed"
            return leaf
        decisions[path] = dec.label
        from repro.quant.pack import prepack_codes, unpack_codes

        codes = prepack_codes(unpack_codes(leaf.codes, 4, p), 4, tk)
        return dataclasses.replace(
            leaf, codes=codes, pack_layout="tile", pack_tile=tk
        )

    out = dict(params)
    for stack_key in ("dec", "enc"):
        if stack_key not in params:
            continue
        stacked = {}
        for key, blk in params[stack_key].items():
            stacked[key] = {
                name: prepack_leaf(f"{key}.{name}", leaf)
                for name, leaf in blk.items()
            }
        out[stack_key] = stacked
    return out, decisions
