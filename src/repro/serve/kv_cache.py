"""Paged KV-cache block pool: fixed-size pages, refcounts, prefix cache.

Host-side allocator behind the paged serving engine (DESIGN.md
§Paged-serving).  Device storage lives in the model's paged cache pytree
(``models.init_paged_cache``); this module manages page *ids* only:

* **Fixed-size pages.**  A page holds ``page_size`` token slots *in every
  layer at once* (the device arrays carry a leading period axis), so
  accounting is in shared token slots.  Page 0 is reserved as the **null
  page**: padded page-table entries and inactive-lane decode writes land
  there, keeping every device gather/scatter in-bounds with no masking.

* **Refcounts.**  A page holding a shared prompt prefix is owned by several
  sequences at once.  Shared and registered pages are **never written**:
  full pages are immutable once prefilled, and the engine routes the one
  write that could land in them — the last-token replay, whose bytes are
  decode-path, ≈1 ulp from the prefill-path bytes — into a private
  copy-on-write page instead (full-coverage prefix hits at admission,
  page-aligned prompts' own registered final page at decode arming), so
  registered content stays exactly what a cold prefill writes.

* **Hash-chain prefix cache.**  A *full* page is registered under the
  token prefix it completes (``tokens[:(j+1)·page_size]`` as the exact
  key — no hash collisions, eviction-safe).  Freed-but-registered pages
  park in an LRU "cached-free" list and are revived on a later prefix hit
  instead of being re-prefilled; they are only truly evicted
  (unregistered + reused) when the free list runs dry.

* **Copy-on-write partial hits.**  When a prompt's un-matched tail is
  shorter than a page and some registered page continues the matched
  prefix with those same tokens, :meth:`match_partial` returns it as a COW
  source: the engine copies the page device-side into a freshly allocated
  page and keeps writing there — the matched slots are valid (same tokens,
  same absolute positions ⇒ identical KV), the rest is masked garbage
  until decode overwrites it.
"""

from __future__ import annotations

from typing import Optional

from repro.faults import fault_point

__all__ = ["NULL_PAGE", "PagePool", "page_nbytes"]

NULL_PAGE = 0

_KV_ELEM_BYTES = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}


def page_nbytes(
    page_size: int, kvp: int, hd: int, n_periods: int, kv_dtype: str = "bf16"
) -> int:
    """Device bytes one page id costs across all layers: k + v codes at the
    dtype's element width (0.5 B for packed int4) plus the fp32
    per-(slot, head) scale planes quantized dtypes carry.  This is the unit
    for equal-**byte** KV budgets: at a fixed budget, int4 pools hold
    ~3.5× the pages of bf16 (benchmarks/bench_serve.py sizes pools with
    exactly this function)."""
    elem = _KV_ELEM_BYTES[kv_dtype]
    per_slot = kvp * hd * elem + (kvp * 4.0 if kv_dtype != "bf16" else 0.0)
    return int(2 * per_slot * page_size * n_periods)


class PagePool:
    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page beyond the null page")
        self.n_pages = n_pages
        self.page_size = page_size
        self.free: list[int] = list(range(1, n_pages))
        self.ref = [0] * n_pages
        # Prefix cache: exact token-prefix tuple -> page id completing it,
        # plus a parent-prefix index for O(1) partial-hit (COW) lookup.
        self.by_key: dict[tuple, int] = {}
        self.key_of: dict[int, tuple] = {}
        self.children: dict[tuple, set[int]] = {}
        self.cached_free: list[int] = []  # LRU order, registered pages w/ ref 0
        self.n_evictions = 0

    # -- allocation ---------------------------------------------------------

    @property
    def n_free(self) -> int:
        """Allocatable pages (truly free + evictable cached-free)."""
        return len(self.free) + len(self.cached_free)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Allocate ``n`` pages with refcount 1, or None if the pool can't
        satisfy the request (never partially allocates).  Prefers truly
        free pages; evicts cached-free pages LRU-first only when needed.

        Injection point ``pool.alloc`` (DESIGN.md §Resilience): a ``deny``
        action simulates a pool-exhaustion spike — the allocation fails as
        if the pool were dry, exercising the engine's preemption and
        head-of-line machinery without actually draining pages."""
        if n > 0 and fault_point("pool.alloc") == "deny":
            return None
        if self.n_free < n:
            return None
        out = []
        for _ in range(n):
            if self.free:
                pid = self.free.pop()
            else:
                pid = self.cached_free.pop(0)
                self._unregister(pid)
                self.n_evictions += 1
            self.ref[pid] = 1
            out.append(pid)
        return out

    def incref(self, pid: int):
        if self.ref[pid] == 0:  # revive a parked cached-free page
            self.cached_free.remove(pid)
        self.ref[pid] += 1

    def release(self, pid: int):
        """Drop one reference; at zero the page parks (if registered) or
        returns to the free list."""
        assert self.ref[pid] > 0, f"double free of page {pid}"
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            if pid in self.key_of:
                self.cached_free.append(pid)
            else:
                self.free.append(pid)

    def _unregister(self, pid: int):
        key = self.key_of.pop(pid, None)
        if key is not None:
            self.by_key.pop(key, None)
            parent = key[: -self.page_size]
            kids = self.children.get(parent)
            if kids is not None:
                kids.discard(pid)
                if not kids:
                    del self.children[parent]

    # -- prefix cache -------------------------------------------------------

    def register(self, pid: int, prefix: tuple):
        """Mark ``pid`` as holding the final (full) page of token
        ``prefix`` (len(prefix) must be a multiple of page_size).  A prefix
        already registered by another page keeps its first owner."""
        assert len(prefix) % self.page_size == 0 and prefix
        if prefix in self.by_key or pid in self.key_of:
            return
        self.by_key[prefix] = pid
        self.key_of[pid] = prefix
        self.children.setdefault(prefix[: -self.page_size], set()).add(pid)

    def match_full(self, tokens: tuple) -> tuple[list[int], int]:
        """Longest cached full-page prefix of ``tokens``.  Returns
        ``(pages, n_matched_tokens)`` with every returned page increfed
        (ownership transfers to the caller)."""
        psz = self.page_size
        pages: list[int] = []
        i = psz
        while i <= len(tokens):
            pid = self.by_key.get(tokens[:i])
            if pid is None:
                break
            self.incref(pid)
            pages.append(pid)
            i += psz
        return pages, len(pages) * psz

    def match_partial(self, tokens: tuple, n_matched: int) -> Optional[int]:
        """COW source for the tail ``tokens[n_matched:]`` (when shorter
        than a page): a registered page continuing the matched prefix
        whose leading tokens equal the tail.  Not increfed — the caller
        copies its contents synchronously into a fresh page."""
        psz = self.page_size
        rem = tokens[n_matched:]
        if not rem or len(rem) >= psz:
            return None
        for pid in self.children.get(tokens[:n_matched], ()):
            if self.key_of[pid][n_matched : n_matched + len(rem)] == rem:
                return pid
        return None
