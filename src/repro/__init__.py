"""repro — QuantEase (Behdin et al., 2023) as a production JAX framework."""

from repro import compat as _compat  # noqa: F401 — jax version shims (side effects)

__version__ = "0.1.0"
