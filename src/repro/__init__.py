"""repro — QuantEase (Behdin et al., 2023) as a production JAX framework."""

__version__ = "0.1.0"
