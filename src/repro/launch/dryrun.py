import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run driver.

For every (architecture × input-shape) cell: build abstract args on the
production mesh, ``jax.jit(fn).lower(...).compile()``, record
memory_analysis / cost_analysis / collective schedule and the three-term
roofline (repro/roofline).  Results land in ``benchmarks/dryrun_results/
<mesh>/<arch>__<shape>.json`` — EXPERIMENTS.md §Dry-run / §Roofline are
generated from these files.

Usage:
  python -m repro.launch.dryrun --arch stablelm_12b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh, mesh_devices
    from repro.launch.specs import build_cell, cell_is_skipped
    from repro.roofline.analysis import analyze_compiled

    mesh_name = "multi" if multi_pod else "single"
    skip = cell_is_skipped(arch, shape)
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "skip" if skip else "?",
    }
    if skip:
        result["reason"] = skip
        return _save(result, out_dir)

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh_devices(mesh)
        t0 = time.time()
        spec = build_cell(arch, shape, mesh)
        with mesh:
            # repro: allow[retrace-jit-per-call] -- AOT dry-run: one lower/compile per invocation is the product, the wrapper is never re-called
            lowered = jax.jit(spec.fn, donate_argnums=spec.donate).lower(*spec.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            print(mem)
            print({k: v for k, v in list(compiled.cost_analysis().items())[:6]})
        rep = analyze_compiled(compiled, n_dev, spec.model_flops)
        result.update(
            status="ok",
            note=spec.note,
            lower_s=round(t1 - t0, 1),
            compile_s=round(t2 - t1, 1),
            devices=n_dev,
            ideal_bytes=spec.ideal_bytes,
            roofline=rep.to_json(),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        result.update(status="fail", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-4000:])
    return _save(result, out_dir)


def _save(result: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{result['arch']}__{result['shape']}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    gb = None
    if result.get("roofline"):
        gb = result["roofline"]["memory_stats"]["peak_hbm_est"] / 1e9
    print(
        f"[{result['mesh']}] {result['arch']}/{result['shape']}: {result['status']}"
        + (f" peakHBM={gb:.2f}GB bottleneck={result['roofline']['bottleneck']}" if gb else "")
        + (f" — {result.get('error', '')}" if result["status"] == "fail" else "")
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    args = ap.parse_args()

    from repro.configs.base import ARCH_IDS
    from repro.launch.specs import CELLS

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(CELLS) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for multi in meshes:
        out_dir = os.path.join(args.out, "multi" if multi else "single")
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi, out_dir)
                n_fail += r["status"] == "fail"
    print(f"dryrun done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
