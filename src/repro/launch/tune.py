"""Auto-tuning launcher: budgeted mixed-precision search over a checkpoint.

Drives :func:`repro.tune.search.tune_model` end to end: probe per-layer
sensitivity (error tables at every candidate width + λ_max(Σ)), build the
greedy budgeted allocations (tune/allocate.py) alongside the uniform
baseline at equal average bits, re-quantize every candidate through the
whole-model PTQ driver with per-layer ``layer_specs`` overrides, and score
each on the **eval** split via the eval-harness scorer on the restacked
serving artifact.  The winner (lowest perplexity; never worse than uniform
since uniform is always candidate 0) is re-quantized once more and saved as
a checkpoint next to its allocation JSON.

Resume contract (mirrors launch/quantize.py's progress.jsonl machinery):

* every finished candidate appends one ``{"candidate": ...}`` record to
  ``<out-dir>/progress.jsonl`` (probe passes also log, as ``{"probe": ...}``
  records, for the audit trail);
* ``--resume`` replays the completed candidate records as prior results and
  evaluation continues with the next unfinished candidate — probing reruns
  (probes are cheap RTN passes; only candidate evaluation is the expensive,
  resumable unit).  Torn tails are tolerated via ``load_progress``.
* in-process crash recovery wraps the candidate loop in
  ``dist/elastic.RetryingRunner``: a failed candidate evaluation rolls back
  to the persisted results and retries (nothing partial is ever persisted,
  so restore == the progress file's view).

End-to-end on the reduced CPU configs:

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
        --reduce --steps 20 --ckpt-dir /tmp/repro_train
    PYTHONPATH=src python -m repro.launch.tune --arch stablelm_12b \
        --reduce --ckpt-dir /tmp/repro_train --budget-avg-bits 3 \
        --bits-candidates 2,3,4 --iterations 4
"""

import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser(
        description="Accuracy-driven per-layer mixed-precision auto-tuning."
    )
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="CPU-sized config (same reduction as launch/train.py)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--out-dir", default="/tmp/repro_tune")
    ap.add_argument("--budget-avg-bits", type=float, default=3.0,
                    help="global storage budget in average bits per weight "
                         "(COO outliers priced at 48 bits each)")
    ap.add_argument("--bits-candidates", default="2,3,4,8",
                    help="comma-separated ascending per-layer widths")
    ap.add_argument("--outlier-fracs", default="",
                    help="comma-separated COO outlier fractions offered as "
                         "allocator upgrades (empty = bits-only tuning)")
    ap.add_argument("--policies", default="sensitivity,error",
                    help="allocation policies to race (greedy candidates)")
    ap.add_argument("--method", default="quantease",
                    help="final-quantize CD method for candidates")
    ap.add_argument("--iterations", type=int, default=10)
    ap.add_argument("--awq-prepass", action="store_true",
                    help="auto-alpha AWQ rescale before CD (awq_then_quantease)")
    ap.add_argument("--group-size", type=int, default=0)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ppl-batches", type=int, default=2,
                    help="eval-split batches per candidate (the objective)")
    ap.add_argument("--data-seed", type=int, default=0,
                    help="corpus seed — must match the TRAINING corpus")
    ap.add_argument("--resume", action="store_true",
                    help="continue from <out-dir>/progress.jsonl candidate records")
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection plan: path to a JSON spec or an "
                         "inline JSON string (see repro.faults.FaultPlan)")
    args = ap.parse_args()

    from repro.faults import FaultPlan, fault_plan

    plan_obj = FaultPlan.from_spec(args.fault_plan) if args.fault_plan else None
    if plan_obj is not None:
        print(f"fault plan active: seed={plan_obj.seed}, "
              f"{len(plan_obj.specs)} spec(s)")
    with fault_plan(plan_obj):
        _run(args)


def _run(args):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, make_batch_fn
    from repro.dist import checkpoint as ckpt
    from repro.dist.elastic import RetryingRunner
    from repro.launch.progress import append_record, load_progress
    from repro.launch.train import reduced
    from repro.models import make_plan, param_shapes
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.tune import TuneConfig, quantize_candidate, tune_model
    from repro.tune.search import build_candidates

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    plan = make_plan(cfg, 1)

    tcfg = TuneConfig(
        budget_avg_bits=args.budget_avg_bits,
        bits_candidates=tuple(int(b) for b in args.bits_candidates.split(",")),
        outlier_frac_candidates=tuple(
            float(f) for f in args.outlier_fracs.split(",") if f
        ),
        policies=tuple(p for p in args.policies.split(",") if p),
        method=args.method,
        iterations=args.iterations,
        awq_prepass=args.awq_prepass,
        group_size=args.group_size or None,
        n_ppl_batches=args.ppl_batches,
    )

    os.makedirs(args.out_dir, exist_ok=True)
    progress_path = os.path.join(args.out_dir, "progress.jsonl")
    prior_results = []
    if args.resume:
        prior = [r for r in load_progress(progress_path) if "candidate" in r]
        prior_results = [r["candidate"] for r in prior]
        print(f"resume: {len(prior_results)} candidate(s) already evaluated")
    elif os.path.exists(progress_path):
        os.remove(progress_path)

    def log_record(rec: dict):
        append_record(progress_path, rec)

    like_params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), param_shapes(plan)
    )
    like = {"params": like_params, "opt": adamw_init(like_params, AdamWConfig())}
    state, manifest, skipped = ckpt.load_last_good(args.ckpt_dir, like)
    for step, reason in skipped:
        print(f"WARNING: skipped damaged checkpoint step_{step}: "
              f"{reason.splitlines()[0]}", file=sys.stderr)
    params = state["params"]
    print(f"loaded checkpoint step {manifest['step']}")

    dcfg = DataConfig(vocab=cfg.vocab, seed=args.data_seed)
    calib_fn, _ = make_batch_fn(dcfg, cfg, batch=4, seq=args.seq, split="calib")
    eval_fn, _ = make_batch_fn(dcfg, cfg, batch=4, seq=args.seq, split="eval")
    # Retried fetch: calib batch i is pure in (seed, "calib", i) — a
    # transient storage fault restarts the fetch and reproduces the exact
    # same calibration set.
    fetcher = RetryingRunner(
        lambda acc, i: acc + [{k: jnp.asarray(v) for k, v in calib_fn(i).items()}],
        lambda: ([], 0),
        max_retries=5,
    )
    calib, _ = fetcher.run([], 0, args.calib_batches)
    if fetcher.recoveries:
        print(f"calibration fetch recovered from {fetcher.recoveries} "
              "transient fault(s)")

    def progress(rec: dict):
        if "probe" in rec:
            print(f"[probe] {rec['probe']}: {rec['layers']} layers")
            log_record(rec)
        else:
            print(f"[candidate] {rec['candidate']}: ppl={rec['ppl']:.4f}")

    doc = tune_model(
        plan, params, calib, eval_fn, tcfg,
        prior_results=prior_results,
        result_cb=lambda res: log_record({"candidate": res}),
        runner_factory=lambda step, restore: RetryingRunner(step, restore),
        progress_cb=progress,
    )

    # Re-quantize the winner for the saved artifact.  Candidates are
    # deterministic for fixed (stats, tcfg), so rebuilding by label is exact.
    win_label = doc["best"]["label"]
    # Stats were consumed inside tune_model; rebuild candidate descriptors
    # from the winning result instead of re-probing: uniform rebuilds from
    # its bits, mixed re-runs the (deterministic) probe + allocation.
    if doc["best"]["kind"] == "uniform":
        cand = {"label": win_label, "kind": "uniform", "bits": tcfg.uniform_bits()}
    else:
        from repro.tune import probe_layer_stats

        stats = probe_layer_stats(
            plan, params, calib,
            bits_candidates=tcfg.bits_candidates,
            outlier_cells=tuple(
                (tcfg.bits_candidates[0], f) for f in tcfg.outlier_frac_candidates
            ),
            outlier_iterations=tcfg.probe_outlier_iterations,
        )
        cand = next(
            c for c in build_candidates(stats, tcfg) if c["label"] == win_label
        )
    qp, report = quantize_candidate(plan, params, calib, cand, tcfg)
    ckpt.save_checkpoint(
        args.out_dir, manifest["step"],
        {"params": qp},
        meta={"tuned": True, "label": win_label,
              "avg_bits": doc["best"]["avg_bits"],
              "report": {k: float(v) for k, v in report.items()}},
    )
    alloc_doc = dict(doc)
    if cand["kind"] == "mixed":
        alloc = cand["allocation"]
        alloc_doc["winner_allocation"] = {
            "bits": alloc.bits,
            "outlier_frac": alloc.outlier_frac,
            "trace": alloc.trace,
        }
    with open(os.path.join(args.out_dir, "tune.json"), "w") as f:
        json.dump(alloc_doc, f, indent=1)
    print(json.dumps({
        "best": doc["best"],
        "uniform_ppl": doc["uniform"]["ppl"],
        "out_dir": args.out_dir,
    }, indent=1))


if __name__ == "__main__":
    main()
