"""Per-cell lowering specs: (arch × input-shape) → abstract args + step fn.

The 40-cell matrix: 10 archs × {train_4k, prefill_32k, decode_32k,
long_500k}.  ``long_500k`` runs only for sub-quadratic archs (mamba2 SSD,
jamba hybrid, mixtral SWA) — pure full-attention archs are recorded as
explicit skips (DESIGN.md §5).

Everything returned is abstract (ShapeDtypeStruct + NamedSharding): the
dry-run lowers and compiles without allocating a byte of model state.
Serving cells (prefill/decode) lower on **QuantizedTensor** weights — the
paper's deployment artifact — so their memory_analysis shows the 4-bit
footprint.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.dist.sharding import Rules, axis_rules, make_rules
from repro.models import (
    cache_axes,
    cache_shapes,
    make_plan,
    param_axes,
    param_shapes,
)
from repro.models import model as M
from repro.serve.qparams import qt_param_axes, qt_param_shapes, qt_rules_extra
from repro.train.optimizer import AdamWConfig, adamw_init, moment_axes
from repro.train.train_step import make_train_step

__all__ = ["CELLS", "LONG_OK", "cell_is_skipped", "build_cell", "arch_train_knobs"]

CELLS = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

LONG_OK = {"mamba2_2_7b", "jamba_1_5_large", "mixtral_8x22b"}


def cell_is_skipped(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return "full-attention arch: 500k dense decode out of contract (DESIGN.md §5)"
    return None


def arch_train_knobs(arch: str) -> dict:
    cfg = get_config(arch)
    n = cfg.param_count()
    fsdp = n > 8e9
    mb = {
        "jamba_1_5_large": 8,
        "mixtral_8x22b": 16,
        "qwen15_32b": 8,
        "llava_next_34b": 8,
        "gemma2_27b": 8,
        "stablelm_12b": 4,
        "phi3_mini_3_8b": 2,
        "whisper_large_v3": 8,
        "olmoe_1b_7b": 4,
        "mamba2_2_7b": 8,
    }[arch]
    return dict(
        fsdp=fsdp,
        n_microbatches=mb,
        moments="int8" if fsdp else "fp32",
        qgather=False,  # int8 FSDP gather: XLA convert-pair elimination defeats
        # the narrow-dtype AG on this backend (see EXPERIMENTS §Perf H3) — needs
        # explicit shard_map collectives; code kept in dist/qgather.py
    )


def _rules_for(
    plan, mesh, *, fsdp: bool, seq_shard_cache: bool = False, batch: int = 0
) -> Rules:
    cfg = plan.cfg
    extra = dict(qt_rules_extra(plan, mesh.shape["model"]))
    # Tiny global batches (long_500k B=1) can't shard the batch axis.
    from repro.dist.sharding import mesh_axis_size

    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if batch and batch % mesh_axis_size(mesh, batch_axes) != 0:
        extra["batch"] = None
    return make_rules(
        mesh,
        n_heads=plan.heads.h_pad,
        n_kv_heads=plan.heads.n_kv,
        head_dim=cfg.hd,
        d_ff=cfg.d_ff,
        n_experts=cfg.n_experts,
        vocab=plan.vocab_pad,
        d_model=cfg.d_model,
        fsdp=fsdp,
        seq_sharded_cache=seq_shard_cache,
        extra=extra,
    )


def _shard_tree(shapes_tree, axes_tree, rules: Rules):
    flat_s, tdef = jax.tree.flatten(shapes_tree)
    flat_ax = jax.tree.flatten(axes_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
    assert len(flat_s) == len(flat_ax), (len(flat_s), len(flat_ax))
    out = [
        jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rules.sharding(ax))
        for s, ax in zip(flat_s, flat_ax)
    ]
    return jax.tree.unflatten(tdef, out)


def _batch_specs(plan, rules, batch: int, seq: int, kind: str):
    cfg = plan.cfg
    bs = lambda shape, dt, ax: jax.ShapeDtypeStruct(
        shape, dt, sharding=rules.sharding(ax)
    )
    n_text = seq - (cfg.n_prefix or 0)
    out = {"tokens": bs((batch, n_text), jnp.int32, ("batch", None))}
    if cfg.family == "encdec":
        out["frames"] = bs(
            (batch, cfg.n_frames, cfg.d_model), jnp.bfloat16, ("batch", None, None)
        )
    if cfg.n_prefix:
        out["patches"] = bs(
            (batch, cfg.n_prefix, cfg.d_model), jnp.bfloat16, ("batch", None, None)
        )
    return out


@dataclasses.dataclass
class CellSpec:
    fn: object  # callable to jit
    args: tuple  # abstract args
    donate: tuple  # donate_argnums
    model_flops: float
    rules: Rules
    note: str = ""
    ideal_bytes: float = 0.0  # one pass over all state, per device


def _tree_bytes(tree) -> float:
    tot = 0.0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= d
        tot += n * jnp.dtype(leaf.dtype).itemsize
    return tot


def build_cell(arch: str, shape: str, mesh, bits: int = 4) -> CellSpec:
    cfg = get_config(arch)
    cell = CELLS[shape]
    seq, batch, kind = cell["seq"], cell["batch"], cell["kind"]
    batch_shards = 1
    for ax in ("pod", "data"):
        batch_shards *= mesh.shape.get(ax, 1)
    plan = make_plan(
        cfg, mesh.shape["model"],
        kv_cache_dtype="bf16" if kind == "train" else "int8",
        dispatch_groups=batch_shards if batch % batch_shards == 0 else 1,
    )
    knobs = arch_train_knobs(arch)

    if kind == "train":
        rules = _rules_for(plan, mesh, fsdp=knobs["fsdp"], batch=batch)
        if knobs["fsdp"] and knobs.get("qgather"):
            from repro.dist.qgather import make_period_transform

            rep_rules = _rules_for(plan, mesh, fsdp=False, batch=batch)
            dec_axes = param_axes(plan)["dec"]
            period_axes = jax.tree.map(
                lambda ax: tuple(ax[1:]), dec_axes,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            plan = dataclasses.replace(
                plan,
                param_transform=make_period_transform(period_axes, rules, rep_rules),
            )
        with axis_rules(rules):
            p_shapes = param_shapes(plan)
            p_sharded = _shard_tree(p_shapes, param_axes(plan), rules)
            opt_cfg = AdamWConfig(moments=knobs["moments"])
            opt_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), p_shapes)
            opt_sharded = _shard_tree(
                opt_shapes, moment_axes(p_shapes, param_axes(plan), opt_cfg), rules
            )
            batch_specs = _batch_specs(plan, rules, batch, seq, kind)
            # Pin per-microbatch grads to the param layout for every arch:
            # without it GSPMD drops the sharding of stacked fp32 grads in
            # the scan transpose and replicates whole weight-stacks
            # (measured: 3.35 GB fp32[64,80,64,2560] buffers on mamba2).
            flat_ax = jax.tree.flatten(
                param_axes(plan), is_leaf=lambda x: isinstance(x, tuple)
            )[0]
            flat_p, tdef = jax.tree.flatten(p_shapes)
            grad_sh = jax.tree.unflatten(
                tdef, [rules.sharding(ax) for ax in flat_ax]
            )
            step = make_train_step(
                plan, opt_cfg, knobs["n_microbatches"], grad_shardings=grad_sh
            )

            def fn(params, opt_state, b):
                with axis_rules(rules):
                    return step(params, opt_state, b)

        tokens = batch * seq
        flops = 6.0 * cfg.active_param_count() * tokens
        n_dev = 1
        for v in mesh.shape.values():
            n_dev *= v
        return CellSpec(
            fn=fn,
            args=(p_sharded, opt_sharded, batch_specs),
            donate=(0, 1),
            model_flops=flops,
            rules=rules,
            note=f"fsdp={knobs['fsdp']} mb={knobs['n_microbatches']} moments={knobs['moments']}",
            ideal_bytes=(_tree_bytes(p_sharded) * 2 + _tree_bytes(opt_sharded)) / n_dev,
        )

    # ---- serving cells: quantized weights ----
    seq_shard = kind == "decode" and batch == 1
    rules = _rules_for(plan, mesh, fsdp=knobs["fsdp"], seq_shard_cache=seq_shard, batch=batch)
    with axis_rules(rules):
        p_sharded = _shard_tree(qt_param_shapes(plan, bits), qt_param_axes(plan), rules)
        cache_sh = _shard_tree(
            cache_shapes(plan, batch, seq),
            cache_axes(plan, seq_shard=seq_shard),
            rules,
        )

    if kind == "prefill":
        batch_specs = _batch_specs(plan, rules, batch, seq, kind)

        def fn(params, b, cache):
            with axis_rules(rules):
                return M.prefill(plan, params, b, cache)

        tokens = batch * seq
        flops = 2.0 * cfg.active_param_count() * tokens
        n_dev = 1
        for v in mesh.shape.values():
            n_dev *= v
        return CellSpec(
            fn=fn,
            args=(p_sharded, batch_specs, cache_sh),
            donate=(2,),
            model_flops=flops,
            rules=rules,
            note=f"qt{bits} serve-prefill",
            ideal_bytes=(_tree_bytes(p_sharded) + _tree_bytes(cache_sh)) / n_dev,
        )

    # decode
    tok = jax.ShapeDtypeStruct(
        (batch, 1), jnp.int32, sharding=rules.sharding(("batch", None))
    )
    pos = jnp.int32(seq - 1)

    def fn(params, tokens, cache):
        with axis_rules(rules):
            return M.decode_step(plan, params, tokens, cache, pos)

    flops = 2.0 * cfg.active_param_count() * batch
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    return CellSpec(
        fn=fn,
        args=(p_sharded, tok, cache_sh),
        donate=(2,),
        model_flops=flops,
        rules=rules,
        ideal_bytes=(_tree_bytes(p_sharded) + _tree_bytes(cache_sh)) / n_dev,
        note=f"qt{bits} decode seq_shard_cache={seq_shard}",
    )
