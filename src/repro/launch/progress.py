"""Shared progress.jsonl audit-trail helpers for the resumable launchers.

``launch/quantize.py``, ``launch/tune.py``, and the chaos resume paths all
persist one JSON record per completed unit of work to ``progress.jsonl``
and must tolerate a run killed mid-write (a torn or empty last line)
without masking real corruption.  One implementation lives here; the
quantize launcher re-exports :func:`load_progress` for backward
compatibility.
"""

from __future__ import annotations

import json
import os

__all__ = ["load_progress", "append_record"]


def load_progress(path: str) -> list:
    """Parse a ``progress.jsonl`` audit trail, tolerating a truncated tail.

    A run killed mid-write leaves a partial (or empty) last line; resume
    must report from the last *complete* record rather than crash on the
    torn one.  Any undecodable line after the last complete record is
    dropped; an undecodable line *followed by* complete records means real
    corruption and still raises (same policy as the train CLI's
    empty-metrics handling: degrade on torn tails, never mask corruption).
    """
    if not os.path.exists(path):
        return []
    records, bad_at = [], None
    with open(path) as f:
        for n, ln in enumerate(f):
            if not ln.strip():
                continue
            try:
                rec = json.loads(ln)
            except json.JSONDecodeError:
                if bad_at is None:
                    bad_at = n
                continue
            if bad_at is not None:
                raise ValueError(
                    f"{path}: undecodable record at line {bad_at + 1} "
                    "followed by later records — corrupt, not truncated"
                )
            records.append(rec)
    return records


def append_record(path: str, rec: dict):
    """Append one record; flush so a crash tears at most the last line
    (exactly the failure mode :func:`load_progress` tolerates)."""
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
        f.flush()
        os.fsync(f.fileno())
