"""Serving launcher: load a (quantized) checkpoint and serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_12b --reduce \
        --ckpt-dir /tmp/repro_quant --requests 8 --engine paged

``--engine paged`` (default for self-attention decoder archs) serves from
the paged-KV engine — shared page pool, chunked prefill, prefix caching,
SLO-aware scheduling; ``--engine contiguous`` keeps the per-slot max_seq
reservation baseline (and is the only choice for enc-dec / SSM-hybrid
archs — the fallback warns loudly, and ``--strict-engine`` turns it into a
hard error for deployments that must not silently lose paging).

SLO knobs (paged engine): ``--deadline-ms`` attaches a per-request
deadline, ``--priority`` a scheduling priority; requests finish with a
terminal status (completed / preempted_resumed / shed / deadline_missed).
``--fault-plan`` activates seeded fault injection (repro.faults) for chaos
drills.
"""

import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--quantized", action="store_true",
                    help="checkpoint holds fake-quant/dense params either way;"
                         " flag is informational")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--engine", choices=["paged", "contiguous"], default="paged")
    ap.add_argument("--strict-engine", action="store_true",
                    help="hard-error instead of falling back to the "
                         "contiguous engine when --engine paged is "
                         "unavailable for the arch")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="KV pool size in pages (0 = ample: no preemption)")
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--kv-dtype", choices=["bf16", "int8", "int4"], default="bf16",
                    help="KV cache storage; int4 packs two codes/byte and is "
                         "paged-engine only")
    ap.add_argument("--scheduler", choices=["slo", "fifo"], default="slo",
                    help="paged-engine scheduling policy (fifo = legacy "
                         "arrival order + preempt-newest)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request SLO deadline in ms (0 = none); "
                         "unmeetable requests are shed, overdue ones "
                         "finish as deadline_missed")
    ap.add_argument("--priority", type=int, default=0,
                    help="request priority (higher = more urgent; low-"
                         "priority work parks under pool pressure)")
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection plan: path to a JSON spec or an "
                         "inline JSON string (see repro.faults.FaultPlan)")
    args = ap.parse_args()

    from repro.faults import FaultPlan, fault_plan

    plan_obj = FaultPlan.from_spec(args.fault_plan) if args.fault_plan else None
    if plan_obj is not None:
        print(f"fault plan active: seed={plan_obj.seed}, "
              f"{len(plan_obj.specs)} spec(s)")
    with fault_plan(plan_obj):
        _run(args)


def _run(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.dist import checkpoint as ckpt
    from repro.launch.train import reduced
    from repro.models import make_plan, param_shapes
    from repro.serve.engine import PagedServingEngine, Request, ServingEngine

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    plan = make_plan(cfg, 1, kv_cache_dtype=args.kv_dtype)
    like = {"params": jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), param_shapes(plan))}
    try:
        state, manifest = ckpt.load_checkpoint(args.ckpt_dir, like)
        params = state["params"]
        print(f"loaded step {manifest['step']}")
    except FileNotFoundError:
        from repro.models import init_params

        print("no checkpoint found — serving random init (demo)")
        params = init_params(plan, jax.random.PRNGKey(0))

    # Roofline-selected weight layout (serve/qparams.py): packed-4-bit
    # QuantizedTensor leaves may re-permute into the GEMM kernel's
    # tile-native order.  Dense/bf16 checkpoints pass through untouched.
    from repro.serve.qparams import prepack_params_for_serving

    params, layout_decisions = prepack_params_for_serving(plan, params)
    if layout_decisions:
        labels = sorted(set(layout_decisions.values()))
        print(f"weight pack layout ({jax.default_backend()}): "
              + ", ".join(f"{lb} ×{sum(1 for v in layout_decisions.values() if v == lb)}"
                          for lb in labels))
    else:
        print("weight pack layout: linear (no packed 4-bit weight leaves)")

    if args.kv_dtype == "int4" and args.engine != "paged":
        raise SystemExit(
            "--kv-dtype int4 requires --engine paged: int4 KV lives in packed "
            "pages (quant/pack.kv_pack_int4); the contiguous engine supports "
            "bf16/int8 only"
        )
    rng = np.random.default_rng(0)
    if args.engine == "paged":
        try:  # probe arch support only — config errors must still surface
            from repro.models import paged_cache_shapes

            paged_cache_shapes(plan, 2, args.page_size)
        except ValueError as e:  # enc-dec / SSM-hybrid / prefix archs
            if args.kv_dtype == "int4":
                # No silent downgrade: the contiguous fallback cannot hold
                # int4 pages, so the request is unsatisfiable as stated.
                raise SystemExit(
                    f"--kv-dtype int4 unavailable for {args.arch}: {e}"
                )
            if args.strict_engine:
                raise SystemExit(
                    f"--strict-engine: paged engine unavailable for arch "
                    f"{args.arch!r} ({e}) and fallback is disabled"
                )
            print(
                f"WARNING: paged engine unavailable for arch {args.arch!r} "
                f"({e}) — FALLING BACK to the contiguous engine: no paged "
                "KV pool, no prefix cache, no SLO preemption; per-slot "
                "max_seq KV is reserved up front (pass --strict-engine to "
                "make this a hard error)",
                file=sys.stderr,
            )
            args.engine = "contiguous"
    if args.engine == "paged":
        eng = PagedServingEngine(
            plan, params, max_batch=args.max_batch, max_seq=512,
            page_size=args.page_size, n_pages=args.n_pages or None,
            prefill_chunk=args.prefill_chunk, scheduler=args.scheduler,
        )
    else:
        eng = ServingEngine(plan, params, max_batch=args.max_batch, max_seq=512)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 32)).astype(np.int32)
        eng.submit(Request(
            rid=i, prompt=prompt, max_new_tokens=args.max_new,
            deadline_ms=args.deadline_ms or None, priority=args.priority,
        ))
    finished = eng.run()
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req{r.rid} [{r.status}]: prompt[{len(r.prompt)}] -> {r.output}")
    if args.engine == "paged":
        print(f"{len(finished)} requests, {eng.n_decode_steps} decode steps, "
              f"{eng.n_prefill_chunks} prefill chunks "
              f"({eng.n_prefix_hit_tokens} prefix-cached tokens, "
              f"{eng.n_preemptions} preemptions, {eng.n_shed} shed, "
              f"{eng.n_deadline_missed} deadline-missed)")
    else:
        print(f"{len(finished)} requests, {eng.n_decode_steps} decode steps, "
              f"{eng.n_prefills} prefills")


if __name__ == "__main__":
    main()
